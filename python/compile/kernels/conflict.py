"""L1 Pallas kernel: batched bank-conflict analysis (paper Fig. 2).

The paper's read/write access controllers convert the bank field of each
of the 16 parallel addresses to a one-hot vector, population-count each
bank's column and take the maximum — that count is the cycles the
operation occupies the banked memory. This kernel performs the same
computation for a whole *batch* of operations at once; the Rust
coordinator uses its AOT artifact as the analytical timing oracle and
cross-checks it against the cycle-accurate controller model
(rust/src/mem/conflict.rs).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on the FPGA this is
16 popcounts + a sort network per cycle; here a [BLOCK, 16] tile of
addresses sits in VMEM and the one-hot/count/max pipeline maps onto the
VPU as dense [BLOCK, 16, BANKS] compares — batch-parallel rather than
pipelined.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the batch processed per grid step. 256 ops x 16 lanes x int32 =
# 16 KB in VMEM, plus the [256, 16, 16] one-hot intermediate (256 KB as
# int8-equivalent mask) — comfortably under a TPU core's ~16 MB VMEM with
# double buffering.
BLOCK_OPS = 256


def _conflict_kernel(addrs_ref, shift_ref, out_ref, *, n_banks: int):
    addrs = addrs_ref[...]  # [BLOCK_OPS, 16] int32
    shift = shift_ref[0]
    banks = (addrs >> shift) & (n_banks - 1)
    # One-hot bank matrix, summed along lanes = per-bank popcounts.
    lanes_onehot = banks[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, n_banks), 2
    )
    counts = lanes_onehot.astype(jnp.int32).sum(axis=1)  # [BLOCK_OPS, n_banks]
    out_ref[...] = counts.max(axis=1)


def conflict_cycles(addrs: jnp.ndarray, shift: jnp.ndarray, n_banks: int) -> jnp.ndarray:
    """Max per-bank access count for each 16-lane operation.

    ``addrs``: int32[ops, 16] (ops a multiple of BLOCK_OPS);
    ``shift``: int32 scalar — 0 for the LSB map, 2 for the Offset map.
    """
    ops, lanes = addrs.shape
    assert lanes == 16, "the paper's machine is 16-lane"
    assert ops % BLOCK_OPS == 0, f"ops must be a multiple of {BLOCK_OPS}"
    kernel = functools.partial(_conflict_kernel, n_banks=n_banks)
    return pl.pallas_call(
        kernel,
        grid=(ops // BLOCK_OPS,),
        in_specs=[
            pl.BlockSpec((BLOCK_OPS, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_OPS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ops,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(addrs, shift.reshape(1))
