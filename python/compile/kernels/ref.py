"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a twin here written in plain jax.numpy;
pytest (python/tests/) asserts allclose between the two across shapes and
seeds. The references are in turn validated against jnp.fft / plain
transposes, so the chain is: Pallas kernel == ref == numpy ground truth.
"""

import jax.numpy as jnp
import numpy as np


def conflict_ref(addrs: jnp.ndarray, shift: jnp.ndarray, n_banks: int) -> jnp.ndarray:
    """Max bank-conflict count per 16-lane operation.

    The paper's Fig. 2 computation: bank field -> one-hot matrix ->
    per-bank population count -> max. ``addrs`` is int32[ops, lanes];
    ``shift`` is the mapping's bit offset (0 = LSB map, 2 = Offset map).
    Returns int32[ops].
    """
    banks = (addrs >> shift) & (n_banks - 1)  # [ops, lanes]
    onehot = banks[..., None] == jnp.arange(n_banks)[None, None, :]
    counts = onehot.sum(axis=1)  # [ops, banks] — the popcounts
    return counts.max(axis=1).astype(jnp.int32)


def dft_matrix_ref(radix: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """DFT-R matrix (re, im): W_R^{km} with W = e^{-2 pi i / R}.

    Angles are evaluated in f64 (numpy) before the f32 cast, matching the
    kernels — evaluating trig in f32 shifts the constants by ~1e-5.
    """
    k = np.arange(radix)
    ang = -2.0 * np.pi * (k[:, None] * k[None, :]) / radix
    return jnp.asarray(np.cos(ang).astype(np.float32)), jnp.asarray(
        np.sin(ang).astype(np.float32)
    )


def butterfly_stage_ref(
    re: jnp.ndarray, im: jnp.ndarray, radix: int, stage: int, n: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One DIF Cooley-Tukey stage over the whole array (pure jnp).

    Stage ``s`` has L = n / radix**s; butterflies gather ``radix`` points
    spaced L/radix apart, apply a DFT-R, then multiply output k by the
    twiddle W_L^{jk} (trivial in the last stage, where L == radix).
    """
    L = n // radix**stage
    Ln = L // radix
    blocks = n // L
    xr = re.reshape(blocks, radix, Ln)
    xi = im.reshape(blocks, radix, Ln)
    dr, di = dft_matrix_ref(radix)
    yr = jnp.einsum("km,bmj->bkj", dr, xr) - jnp.einsum("km,bmj->bkj", di, xi)
    yi = jnp.einsum("km,bmj->bkj", dr, xi) + jnp.einsum("km,bmj->bkj", di, xr)
    if Ln > 1:  # non-trivial twiddles W_L^{jk}
        j = np.arange(Ln)[None, :]
        k = np.arange(radix)[:, None]
        ang = -2.0 * np.pi * (j * k) / L
        twr = jnp.asarray(np.cos(ang).astype(np.float32))[None]
        twi = jnp.asarray(np.sin(ang).astype(np.float32))[None]
        yr, yi = yr * twr - yi * twi, yr * twi + yi * twr
    return yr.reshape(n), yi.reshape(n)


def digit_reverse_indices(n: int, radix: int, stages: int) -> jnp.ndarray:
    """Permutation p with X_natural[k] = X_dif[p[k]] (p is an involution)."""
    idx = jnp.arange(n)
    out = jnp.zeros_like(idx)
    v = idx
    for _ in range(stages):
        out = out * radix + v % radix
        v = v // radix
    return out


def fft_ref(re: jnp.ndarray, im: jnp.ndarray, radix: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full DIF FFT via ``butterfly_stage_ref`` + digit-reversal unshuffle.

    Returns the spectrum in *natural* order (comparable to jnp.fft.fft).
    """
    n = re.shape[0]
    stages = 0
    v = 1
    while v < n:
        v *= radix
        stages += 1
    assert v == n, "n must be a power of the radix"
    for s in range(stages):
        re, im = butterfly_stage_ref(re, im, radix, s, n)
    perm = digit_reverse_indices(n, radix, stages)
    return re[perm], im[perm]


def transpose_ref(x: jnp.ndarray) -> jnp.ndarray:
    """N x N transpose."""
    return x.T
