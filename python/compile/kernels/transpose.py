"""L1 Pallas kernel: tiled N x N matrix transpose (the paper's
memory-intensive benchmark).

Grid cell (i, j) reads the *source* tile (j, i) and writes it transposed
to the destination tile (i, j): the BlockSpec index maps express exactly
the across-columns-read / down-columns-write pattern whose bank behaviour
Table II profiles, with the tile (32 x 32 f32 = 4 KB) as the VMEM unit of
transfer.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 32


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose(x: jnp.ndarray) -> jnp.ndarray:
    """Transpose an [n, n] f32 matrix, n a multiple of the 32-wide tile
    (or equal to a smaller power of two, handled as a single tile)."""
    n = x.shape[0]
    assert x.shape == (n, n), "square matrices only"
    tile = min(TILE, n)
    assert n % tile == 0
    g = n // tile
    return pl.pallas_call(
        _transpose_kernel,
        grid=(g, g),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
