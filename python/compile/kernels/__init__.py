"""L1 Pallas kernels (build-time only; lowered to HLO by compile.aot).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute real-TPU Mosaic custom-calls, so interpret mode is the CPU
correctness/lowering path; DESIGN.md estimates TPU behaviour from the
BlockSpec structure instead of wallclock.
"""

from . import butterfly, conflict, ref, transpose  # noqa: F401
