"""L1 Pallas kernel: one radix-R DIF butterfly stage (the paper's FFT
compute hot-spot).

A stage reshapes the N-point array to [blocks, R, L/R]; each butterfly
applies a DFT-R across the R axis and multiplies by the stage twiddles
W_L^{jk}. The kernel processes one block per grid step: its tile
(R x L/R complex = L points) is the VMEM working set, and the DFT-R is a
small constant-matrix contraction — on a real TPU the [R, L/R] x [R, R]
products ride the MXU while the twiddle multiply is elementwise VPU work.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
SPs execute the same butterfly scalar-by-scalar from banked shared
memory; the BlockSpec here expresses the HBM->VMEM schedule that banking
expressed on the FPGA.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dft_consts(radix: int) -> tuple[np.ndarray, np.ndarray]:
    k = np.arange(radix)
    ang = -2.0 * np.pi * (k[:, None] * k[None, :]) / radix
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def _butterfly_kernel(xr_ref, xi_ref, dr_ref, di_ref, twr_ref, twi_ref, yr_ref, yi_ref):
    xr = xr_ref[...]  # [1, R, Ln]
    xi = xi_ref[...]
    dr = dr_ref[...]  # [R, R] DFT matrix (constants must arrive as inputs)
    di = di_ref[...]
    # DFT-R along the radix axis: y_k = sum_m W^{km} x_m.
    yr = jnp.einsum("km,bmj->bkj", dr, xr) - jnp.einsum("km,bmj->bkj", di, xi)
    yi = jnp.einsum("km,bmj->bkj", dr, xi) + jnp.einsum("km,bmj->bkj", di, xr)
    # Twiddle W_L^{jk} (identity matrices are passed for the last stage).
    twr = twr_ref[...]  # [R, Ln]
    twi = twi_ref[...]
    yr_ref[...] = yr * twr[None] - yi * twi[None]
    yi_ref[...] = yr * twi[None] + yi * twr[None]


def butterfly_stage(
    re: jnp.ndarray, im: jnp.ndarray, radix: int, stage: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply DIF stage ``stage`` of a radix-``radix`` FFT to [n] arrays."""
    n = re.shape[0]
    L = n // radix**stage
    Ln = L // radix
    blocks = n // L
    xr = re.reshape(blocks, radix, Ln)
    xi = im.reshape(blocks, radix, Ln)
    # Stage twiddles (shared across blocks); trivial at the last stage.
    j = np.arange(Ln)[None, :]
    k = np.arange(radix)[:, None]
    ang = -2.0 * np.pi * (j * k) / L
    twr = jnp.asarray(np.cos(ang).astype(np.float32))
    twi = jnp.asarray(np.sin(ang).astype(np.float32))
    dr_np, di_np = _dft_consts(radix)
    dr = jnp.asarray(dr_np)
    di = jnp.asarray(di_np)
    yr, yi = pl.pallas_call(
        _butterfly_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((1, radix, Ln), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, radix, Ln), lambda b: (b, 0, 0)),
            pl.BlockSpec((radix, radix), lambda b: (0, 0)),
            pl.BlockSpec((radix, radix), lambda b: (0, 0)),
            pl.BlockSpec((radix, Ln), lambda b: (0, 0)),
            pl.BlockSpec((radix, Ln), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, radix, Ln), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, radix, Ln), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks, radix, Ln), jnp.float32),
            jax.ShapeDtypeStruct((blocks, radix, Ln), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xr, xi, dr, di, twr, twi)
    return yr.reshape(n), yi.reshape(n)
