"""L2 JAX model: the compute graphs that become the AOT artifacts.

Build-time only — the Rust coordinator executes the lowered HLO through
PJRT; Python never runs on the request path.

Three model families, mirroring the paper's evaluation:

- ``fft4096``: the 4096-point complex FFT composed from Pallas radix-4
  butterfly stages (natural-order output, comparable to jnp.fft.fft);
- ``transpose_n``: N x N transpose through the Pallas tiled kernel;
- ``conflict_batch``: the banked-memory conflict analyzer over operation
  batches (one artifact per bank count; the mapping shift is a runtime
  scalar input so one artifact serves both LSB and Offset maps).
"""

import functools

import jax.numpy as jnp

from .kernels import butterfly, conflict, ref, transpose

FFT_N = 4096
FFT_RADIX = 4
FFT_STAGES = 6


def fft4096(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """4096-point complex FFT, natural-order output.

    Six radix-4 DIF stages (each a Pallas kernel call) followed by the
    digit-reversal unshuffle. XLA fuses the inter-stage reshapes; the
    stage count is static so the whole pipeline lowers into one module.
    """
    for s in range(FFT_STAGES):
        re, im = butterfly.butterfly_stage(re, im, FFT_RADIX, s)
    perm = ref.digit_reverse_indices(FFT_N, FFT_RADIX, FFT_STAGES)
    return re[perm], im[perm]


def transpose_n(x: jnp.ndarray) -> jnp.ndarray:
    """N x N transpose (Pallas tiled kernel)."""
    return transpose.transpose(x)


def conflict_batch(n_banks: int):
    """Conflict analyzer for a fixed bank count: (addrs[ops,16], shift) ->
    max-conflict counts int32[ops]."""
    return functools.partial(conflict.conflict_cycles, n_banks=n_banks)
