"""AOT lowering: JAX model -> HLO *text* -> artifacts/*.hlo.txt.

HLO text, NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True`` so every artifact returns a tuple the Rust loader
unpacks uniformly (see /opt/xla-example/gen_hlo.py and rust/src/runtime).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Skips unchanged outputs so repeated ``make`` is a
no-op.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

LANES = 16
CONFLICT_OPS = 256  # batch rows per conflict-oracle call (fixed shape)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as `constant({...})`, which the Rust side's HLO text parser
    # (xla_extension 0.5.1) silently reads back as zeros — the DFT and
    # twiddle constants baked into the butterfly stages would vanish.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def artifact_specs():
    """(name, fn, example_args) for every artifact."""
    f32 = jnp.float32
    i32 = jnp.int32
    specs = [
        (
            "fft4096",
            model.fft4096,
            (
                jax.ShapeDtypeStruct((model.FFT_N,), f32),
                jax.ShapeDtypeStruct((model.FFT_N,), f32),
            ),
        ),
    ]
    for n in (32, 64, 128):
        specs.append(
            (
                f"transpose{n}",
                model.transpose_n,
                (jax.ShapeDtypeStruct((n, n), f32),),
            )
        )
    for banks in (4, 8, 16):
        specs.append(
            (
                f"conflict{banks}",
                model.conflict_batch(banks),
                (
                    jax.ShapeDtypeStruct((CONFLICT_OPS, LANES), i32),
                    jax.ShapeDtypeStruct((), i32),
                ),
            )
        )
    return specs


def emit(out_dir: str, force: bool = False) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, fn, args in artifact_specs():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        if os.path.exists(path) and not force:
            print(f"  {name}: up to date")
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"  {name}: wrote {len(text)} chars")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rewrite even if present")
    args = ap.parse_args()
    print(f"AOT-lowering artifacts to {args.out_dir}")
    emit(args.out_dir, force=args.force)


if __name__ == "__main__":
    main()
