"""Pallas kernels vs pure-jnp references — the core L1 correctness signal.

Hypothesis sweeps shapes/seeds/parameters; every kernel must match its
ref.py twin bit-for-bit (integer kernels) or to f32 tolerance (FP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import butterfly, conflict, ref, transpose


# ---------------------------------------------------------------- conflict
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    banks=st.sampled_from([4, 8, 16]),
    shift=st.sampled_from([0, 2]),
    blocks=st.integers(1, 3),
)
def test_conflict_kernel_matches_ref(seed, banks, shift, blocks):
    rng = np.random.default_rng(seed)
    ops = conflict.BLOCK_OPS * blocks
    addrs = jnp.asarray(rng.integers(0, 1 << 16, size=(ops, 16), dtype=np.int32))
    shift_arr = jnp.int32(shift)
    got = conflict.conflict_cycles(addrs, shift_arr, banks)
    want = ref.conflict_ref(addrs, shift_arr, banks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conflict_extremes():
    # All lanes on one bank -> 16; consecutive addresses -> 1.
    same = jnp.zeros((conflict.BLOCK_OPS, 16), jnp.int32)
    out = conflict.conflict_cycles(same, jnp.int32(0), 16)
    np.testing.assert_array_equal(np.asarray(out), 16)
    consec = jnp.tile(jnp.arange(16, dtype=jnp.int32), (conflict.BLOCK_OPS, 1))
    out = conflict.conflict_cycles(consec, jnp.int32(0), 16)
    np.testing.assert_array_equal(np.asarray(out), 1)


def test_conflict_offset_mapping_spreads_stride4():
    # Stride-4 addresses: LSB map -> 4 conflicts, Offset map -> 1.
    addrs = jnp.tile(4 * jnp.arange(16, dtype=jnp.int32), (conflict.BLOCK_OPS, 1))
    lsb = conflict.conflict_cycles(addrs, jnp.int32(0), 16)
    off = conflict.conflict_cycles(addrs, jnp.int32(2), 16)
    assert int(lsb[0]) == 4
    assert int(off[0]) == 1


def test_conflict_fig4_example():
    # The paper's Fig. 4: 8 lanes on banks [0,1,1,3,1,3,4,5] -> max 3.
    row = np.zeros(16, np.int32)
    row[:8] = [0, 1, 1, 3, 1, 3, 4, 5]
    # Upper lanes spread so no bank exceeds the figure's max of 3.
    row[8:] = [0, 2, 2, 4, 5, 6, 7, 7]
    addrs = jnp.asarray(np.tile(row, (conflict.BLOCK_OPS, 1)))
    out = conflict.conflict_cycles(addrs, jnp.int32(0), 8)
    assert int(out[0]) == 3


# --------------------------------------------------------------- butterfly
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    radix=st.sampled_from([4, 8, 16]),
    log_n=st.integers(0, 2),
)
def test_butterfly_stage_matches_ref(seed, radix, log_n):
    n = radix ** (log_n + 2)
    if n > 4096:
        n = radix**2
    rng = np.random.default_rng(seed)
    re = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    im = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    stages = int(round(np.log(n) / np.log(radix)))
    for s in range(stages):
        got_r, got_i = butterfly.butterfly_stage(re, im, radix, s)
        want_r, want_i = ref.butterfly_stage_ref(re, im, radix, s, n)
        # f32 tolerance scaled to the stage's magnitude (a DFT-R sums R
        # terms, so late radix-16 stages reach |x| ~ 1e2).
        scale = max(1.0, float(np.abs(np.asarray(want_r)).max()),
                    float(np.abs(np.asarray(want_i)).max()))
        np.testing.assert_allclose(np.asarray(got_r), np.asarray(want_r), atol=2e-6 * scale)
        np.testing.assert_allclose(np.asarray(got_i), np.asarray(want_i), atol=2e-6 * scale)
        re, im = got_r, got_i


@pytest.mark.parametrize("radix,n", [(4, 64), (8, 64), (16, 256), (4, 4096)])
def test_fft_ref_matches_jnp_fft(radix, n):
    rng = np.random.default_rng(7)
    re = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    im = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got_r, got_i = ref.fft_ref(re, im, radix)
    want = jnp.fft.fft(re + 1j * im)
    scale = float(jnp.abs(want).max())
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want.real), atol=2e-4 * scale)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(want.imag), atol=2e-4 * scale)


def test_digit_reverse_is_involution():
    for radix, stages in [(4, 6), (8, 4), (16, 3)]:
        n = radix**stages
        perm = np.asarray(ref.digit_reverse_indices(n, radix, stages))
        assert np.array_equal(perm[perm], np.arange(n))


# --------------------------------------------------------------- transpose
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([16, 32, 64, 128]))
def test_transpose_kernel_matches_ref(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    got = transpose.transpose(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.transpose_ref(x)))


def test_transpose_involution():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(transpose.transpose(transpose.transpose(x))), np.asarray(x)
    )


def test_transpose_preserves_dtype_bits():
    # NaN payloads and -0.0 survive (it is a pure data movement).
    x = jnp.asarray(np.array([[np.float32(-0.0), 1.0], [np.nan, 2.0]], dtype=np.float32))
    y = np.asarray(transpose.transpose(jnp.tile(x, (16, 16))))
    assert np.isnan(y).sum() == 256
