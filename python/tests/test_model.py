"""L2 model tests: the composed graphs behave like their ground truths and
lower cleanly to the HLO text the Rust runtime consumes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_fft4096_matches_jnp_fft():
    rng = np.random.default_rng(11)
    re = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    im = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    got_r, got_i = model.fft4096(re, im)
    want = jnp.fft.fft(re + 1j * im)
    scale = float(jnp.abs(want).max())
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want.real), atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(want.imag), atol=3e-4 * scale)


def test_fft4096_linearity():
    # FFT(a x) == a FFT(x): a cheap structural invariant of the pipeline.
    rng = np.random.default_rng(5)
    re = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    im = jnp.zeros(4096, jnp.float32)
    r1, i1 = model.fft4096(re, im)
    r2, i2 = model.fft4096(2.0 * re, im)
    np.testing.assert_allclose(np.asarray(r2), 2 * np.asarray(r1), atol=1e-2)
    np.testing.assert_allclose(np.asarray(i2), 2 * np.asarray(i1), atol=1e-2)


def test_fft4096_impulse():
    re = jnp.zeros(4096, jnp.float32).at[0].set(1.0)
    im = jnp.zeros(4096, jnp.float32)
    r, i = model.fft4096(re, im)
    np.testing.assert_allclose(np.asarray(r), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(i), 0.0, atol=1e-5)


def test_conflict_batch_shapes():
    fn = model.conflict_batch(16)
    addrs = jnp.zeros((256, 16), jnp.int32)
    out = fn(addrs, jnp.int32(0))
    assert out.shape == (256,)
    assert out.dtype == jnp.int32


@pytest.mark.parametrize("name", [s[0] for s in aot.artifact_specs()])
def test_artifacts_lower_to_hlo_text(name):
    # Every artifact must lower and convert to HLO text (the Rust
    # interchange format) without touching the filesystem.
    spec = next(s for s in aot.artifact_specs() if s[0] == name)
    _, fn, args = spec
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:64]
    # return_tuple=True: the root computation returns a tuple.
    assert "ROOT" in text


def test_artifact_specs_cover_rust_expectations():
    names = {s[0] for s in aot.artifact_specs()}
    assert names == {
        "fft4096",
        "transpose32",
        "transpose64",
        "transpose128",
        "conflict4",
        "conflict8",
        "conflict16",
    }


def test_emit_skips_up_to_date(tmp_path):
    # First emit writes everything; second emit is a no-op (the Makefile
    # contract: `make artifacts` twice does no extra work). Use the
    # smallest artifact set via monkeypatching would complicate; instead
    # emit into a temp dir once and compare mtimes.
    out = tmp_path / "artifacts"
    written = aot.emit(str(out))
    assert len(written) == 7
    again = aot.emit(str(out))
    assert again == []
