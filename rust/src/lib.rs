//! # soft-simt — Banked Memories for Soft SIMT Processors
//!
//! A cycle-accurate reproduction of *"Banked Memories for Soft SIMT
//! Processors"* (Langhammer & Constantinides, CS.AR 2025): a 16-lane soft
//! SIMT (GPGPU-like) processor with nine interchangeable shared-memory
//! architectures — multi-port (4R-1W, 4R-2W, 4R-1W-VB) and banked
//! (4/8/16 banks, LSB and Offset mappings) — plus the paper's benchmark
//! programs (matrix transposes and 4096-point Cooley–Tukey FFTs), area and
//! footprint models, and report generators that regenerate every table and
//! figure in the paper's evaluation.
//!
//! The original artifact is an FPGA bitstream; this library substitutes a
//! bit-faithful simulator (see `DESIGN.md §0`). Functional results of
//! simulated programs are validated against JAX/Pallas golden models that
//! are AOT-compiled to HLO and executed from Rust through PJRT
//! ([`runtime`]).
//!
//! ## Quick start
//!
//! ```no_run
//! use soft_simt::prelude::*;
//!
//! // Build a 16-bank, offset-mapped machine and run a 32x32 transpose.
//! let arch = MemoryArchKind::Banked { banks: 16, mapping: BankMapping::offset() };
//! let program = transpose_program(32);
//! let mut machine = Machine::new(MachineConfig::for_arch(arch));
//! let report = machine.run_program(&program).unwrap();
//! println!("total cycles: {}", report.total_cycles());
//! ```
//!
//! ## Layer map (see DESIGN.md)
//!
//! - **L3 (this crate)**: simulator, memories, programs, coordinator, CLI.
//! - **L2/L1 (python/compile, build-time only)**: JAX model + Pallas
//!   kernels, lowered to `artifacts/*.hlo.txt`.
//! - **bridge** ([`runtime`]): PJRT loads the artifacts for golden
//!   validation and the analytical timing oracle (behind the `pjrt`
//!   feature; the default build ships a stub that degrades to host
//!   references).
//!
//! ## Two-phase simulation (DESIGN.md §Two-phase)
//!
//! The simulator is decoupled into an architecture-independent
//! *functional core* ([`sim::exec`]) that runs a program once and emits a
//! complete [`sim::exec::MemTrace`], and a *timing replay engine*
//! ([`sim::replay`]) that charges any memory architecture's cost model
//! from that trace. [`sim::machine::Machine`] runs both in lockstep; the
//! sweep path ([`coordinator`]) caches traces so a 9-architecture sweep
//! executes each program once and replays timing 9×. On top of the
//! cache sits the **compiled batch replayer** ([`sim::compiled`],
//! DESIGN.md §Replay): a trace is compiled once into per-operation
//! conflict maxima for every bank-mapping family, and the **lane-packed
//! kernel** ([`sim::packed::replay_many_packed`]) then charges a whole
//! slate of architectures in a single trace walk, eight architectures
//! per gather row, with segment-parallel wavefront replay on the worker
//! pool ([`coordinator::runner::SweepRunner::replay_many_parallel`]);
//! the scalar [`sim::compiled::replay_many`] stays as the reference
//! model. The design-space explorer
//! ([`explore`]) pushes that to its conclusion: a parametric space of
//! hypothetical memories (banks 2–32 × mapping × ports × capacity),
//! Pareto-searched from a single functional execution per workload
//! (DESIGN.md §Explore).
//!
//! ## The service layer (DESIGN.md §Service)
//!
//! [`service`] is how the crate is consumed: a long-lived
//! [`service::SimtEngine`] session (worker pool + persistent trace
//! cache) answering typed [`service::Request`]s — every CLI command is
//! one — with unified [`service::ServiceError`] errors, plus a
//! line-delimited JSON wire codec and the `soft-simt serve` stdin/stdout
//! transport. A batch of {paper sweep + explore + N repeat runs} costs
//! exactly one functional execution per distinct workload. Session
//! telemetry — atomic counters, latency histograms, per-request phase
//! spans — lives in [`obs`], is threaded through the cache, runner and
//! explorer, and is queryable in-band via `Request::Stats` or the
//! `soft-simt stats` CLI (DESIGN.md §Observability).
//!
//! ## The server layer (DESIGN.md §Server)
//!
//! [`server`] makes one engine genuinely multi-client: the trace cache
//! is backed by a sharded, single-flight [`server::ShardedStore`] (warm
//! reads take only a shard read lock — the serving-side analogue of the
//! paper's banked memories), each client is a [`server::Session`] with
//! isolated bookkeeping over the shared `Arc<SimtEngine>`, batches fan
//! out concurrently onto the worker pool, a [`server::Dispatcher`]
//! bounds in-flight work (reject-with-`Overloaded` past a configurable
//! depth), and `soft-simt serve --listen ADDR` accepts TCP and
//! Unix-socket clients over the same wire transport as stdin.

pub mod area;
pub mod benchkit;
pub mod coordinator;
pub mod explore;
pub mod isa;
pub mod mem;
pub mod obs;
pub mod programs;
pub mod runtime;
pub mod server;
pub mod service;
pub mod sim;
pub mod util;

/// Convenient re-exports of the most commonly used types.
///
/// The **preferred entry point** for consumers is the service layer:
/// [`SimtEngine`](crate::service::SimtEngine) + typed
/// [`Request`](crate::service::Request)s. The lower-level pieces
/// (`SweepRunner`, `TraceCache`, `BenchJob`, `explore`) remain exported
/// for tests and embedders, but hand-wiring them is the deprecated path
/// — an engine session shares one cache and worker pool across
/// everything.
pub mod prelude {
    pub use crate::area::{footprint::Footprint, resources::Resources, table1};
    pub use crate::coordinator::{
        job::{BenchJob, BenchResult, TraceCache},
        report,
        runner::SweepRunner,
    };
    pub use crate::server::{Dispatcher, ListenAddr, Session, ShardedStore, SocketServer};
    pub use crate::service::{
        ExploreObjective, ExploreSpec, ExploreStrategy, Request, Response, ServiceError,
        SimtEngine, StatsScope, TableKind,
    };
    pub use crate::explore::{
        explore, explore_system, DesignPoint, DesignSpace, Exhaustive, ExploreResult,
        ParetoFront, SearchStrategy, SuccessiveHalving, SystemExploreResult, SystemPoint,
        SystemSpace,
    };
    pub use crate::isa::{
        asm::{assemble, disassemble},
        inst::Instruction,
        opcode::Opcode,
        program::Program,
    };
    pub use crate::mem::{
        arch::{MemoryArchKind, SharedMemory},
        mapping::BankMapping,
    };
    pub use crate::obs::{Counter, MetricsRegistry, MetricsSnapshot, Phase, Span};
    pub use crate::programs::{
        fft::{fft_program, FftPlan},
        registry::{self, KernelFamily, OpCountModel, Workload},
        transpose::transpose_program,
    };
    pub use crate::sim::{
        compiled::{replay_compiled, replay_many, CompiledTrace},
        config::MachineConfig,
        exec::{execute, ExecMemory, ExecParams, FlatMemory, MemTrace, SimError},
        machine::Machine,
        packed::{replay_many_packed, LaneChunk},
        replay::replay,
        stats::{CycleStats, RunReport},
    };
}
