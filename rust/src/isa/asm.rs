//! Two-pass text assembler and disassembler.
//!
//! Syntax (semicolon or `#` comments, case-insensitive mnemonics):
//!
//! ```text
//! .name  transpose32      ; optional program name
//! .threads 1024           ; block size (required)
//!
//! start:
//!     tid   r0
//!     ldi   r1, 32
//!     iadd  r2, r0, r1
//!     ld    r3, [r2]      ; shared-memory read
//!     st    [r2], r3      ; blocking write
//!     stnb  [r2], r3      ; non-blocking write
//!     bnz   r4, start     ; per-lane branch (label or absolute pc)
//!     halt
//! ```
//!
//! Immediates accept decimal, hex (`0x..`), binary (`0b..`) and `'-'`
//! (encoded two's-complement into 16 bits).

use super::inst::{Instruction, NUM_REGS};
use super::opcode::Opcode;
use super::program::Program;
use std::collections::HashMap;

/// Assembly error with line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for AsmError {}

fn err(line: usize, msg: impl Into<String>) -> AsmError {
    AsmError { line, msg: msg.into() }
}

/// Strip comments, returning the code part of a line.
fn strip_comment(line: &str) -> &str {
    let cut = line.find([';', '#']).unwrap_or(line.len());
    line[..cut].trim()
}

/// Parse `rN`.
fn parse_reg(tok: &str, line: usize) -> Result<u8, AsmError> {
    let t = tok.trim();
    let body = t
        .strip_prefix('r')
        .or_else(|| t.strip_prefix('R'))
        .ok_or_else(|| err(line, format!("expected register, got '{t}'")))?;
    let n: usize = body
        .parse()
        .map_err(|_| err(line, format!("bad register '{t}'")))?;
    if n >= NUM_REGS {
        return Err(err(line, format!("register r{n} out of range (0..{})", NUM_REGS - 1)));
    }
    Ok(n as u8)
}

/// Parse `[rN]`.
fn parse_mem_reg(tok: &str, line: usize) -> Result<u8, AsmError> {
    let t = tok.trim();
    let inner = t
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| err(line, format!("expected [rN], got '{t}'")))?;
    parse_reg(inner, line)
}

/// Parse an immediate (decimal/hex/binary, optionally negative).
fn parse_imm(tok: &str, line: usize) -> Result<u16, AsmError> {
    let t = tok.trim();
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t),
    };
    let v: i64 = if let Some(h) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        i64::from_str_radix(h, 16).map_err(|_| err(line, format!("bad immediate '{tok}'")))?
    } else if let Some(b) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        i64::from_str_radix(b, 2).map_err(|_| err(line, format!("bad immediate '{tok}'")))?
    } else {
        t.parse()
            .map_err(|_| err(line, format!("bad immediate '{tok}'")))?
    };
    let v = if neg { -v } else { v };
    if !(-(1 << 15)..(1 << 16)).contains(&v) {
        return Err(err(line, format!("immediate {v} does not fit in 16 bits")));
    }
    Ok(v as u16)
}

/// Split an operand list on commas.
fn operands(rest: &str) -> Vec<&str> {
    if rest.trim().is_empty() {
        vec![]
    } else {
        rest.split(',').map(str::trim).collect()
    }
}

/// Assemble source text into a [`Program`].
///
/// Two passes: the first collects labels and directives; the second encodes
/// instructions with labels resolved to absolute PCs.
pub fn assemble(src: &str) -> Result<Program, AsmError> {
    let mut labels: HashMap<String, u16> = HashMap::new();
    let mut name = String::from("program");
    let mut threads: Option<u32> = None;

    // Pass 1: labels + directives.
    let mut pc: u16 = 0;
    for (ln, raw) in src.lines().enumerate() {
        let line_no = ln + 1;
        let mut code = strip_comment(raw);
        if code.is_empty() {
            continue;
        }
        // Labels (possibly followed by an instruction on the same line).
        while let Some(colon) = code.find(':') {
            let label = code[..colon].trim();
            if label.is_empty() || label.contains(char::is_whitespace) {
                return Err(err(line_no, format!("bad label '{label}'")));
            }
            if labels.insert(label.to_string(), pc).is_some() {
                return Err(err(line_no, format!("duplicate label '{label}'")));
            }
            code = code[colon + 1..].trim();
        }
        if code.is_empty() {
            continue;
        }
        if let Some(rest) = code.strip_prefix('.') {
            let mut it = rest.split_whitespace();
            match it.next() {
                Some("name") => {
                    name = it
                        .next()
                        .ok_or_else(|| err(line_no, ".name needs a value"))?
                        .to_string();
                }
                Some("threads") => {
                    let v: u32 = it
                        .next()
                        .ok_or_else(|| err(line_no, ".threads needs a value"))?
                        .parse()
                        .map_err(|_| err(line_no, "bad .threads value"))?;
                    threads = Some(v);
                }
                Some(d) => return Err(err(line_no, format!("unknown directive '.{d}'"))),
                None => return Err(err(line_no, "empty directive")),
            }
            continue;
        }
        pc = pc
            .checked_add(1)
            .ok_or_else(|| err(line_no, "program too long (max 65536 instructions)"))?;
    }

    let threads = threads.ok_or_else(|| err(0, "missing .threads directive"))?;

    // Pass 2: encode.
    let mut insts = Vec::with_capacity(pc as usize);
    for (ln, raw) in src.lines().enumerate() {
        let line_no = ln + 1;
        let mut code = strip_comment(raw);
        while let Some(colon) = code.find(':') {
            code = code[colon + 1..].trim();
        }
        if code.is_empty() || code.starts_with('.') {
            continue;
        }
        let (mn, rest) = match code.find(char::is_whitespace) {
            Some(i) => (&code[..i], code[i..].trim()),
            None => (code, ""),
        };
        let op: Opcode = mn
            .to_ascii_lowercase()
            .parse()
            .map_err(|e: super::opcode::UnknownMnemonic| err(line_no, e.to_string()))?;
        let ops = operands(rest);
        let imm_or_label = |tok: &str| -> Result<u16, AsmError> {
            if let Some(&target) = labels.get(tok.trim()) {
                Ok(target)
            } else {
                parse_imm(tok, line_no)
            }
        };
        let need = |n: usize| -> Result<(), AsmError> {
            if ops.len() == n {
                Ok(())
            } else {
                Err(err(line_no, format!("{mn} expects {n} operand(s), got {}", ops.len())))
            }
        };
        use Opcode::*;
        let inst = match op {
            Nop | Halt => {
                need(0)?;
                Instruction::z(op)
            }
            Tid => {
                need(1)?;
                Instruction::i(op, parse_reg(ops[0], line_no)?, 0, 0)
            }
            Jmp => {
                need(1)?;
                Instruction::i(op, 0, 0, imm_or_label(ops[0])?)
            }
            Bnz => {
                need(2)?;
                Instruction::i(op, parse_reg(ops[0], line_no)?, 0, imm_or_label(ops[1])?)
            }
            Ldi | Lui => {
                need(2)?;
                Instruction::i(op, parse_reg(ops[0], line_no)?, 0, parse_imm(ops[1], line_no)?)
            }
            Fneg | Itof => {
                need(2)?;
                Instruction::r(op, parse_reg(ops[0], line_no)?, parse_reg(ops[1], line_no)?, 0)
            }
            Ld => {
                need(2)?;
                Instruction::i(op, parse_reg(ops[0], line_no)?, parse_mem_reg(ops[1], line_no)?, 0)
            }
            St | Stnb => {
                need(2)?;
                Instruction::r(op, 0, parse_mem_reg(ops[0], line_no)?, parse_reg(ops[1], line_no)?)
            }
            Iaddi | Imuli | Iandi | Iori | Ixori | Ishli | Ishri => {
                need(3)?;
                Instruction::i(
                    op,
                    parse_reg(ops[0], line_no)?,
                    parse_reg(ops[1], line_no)?,
                    parse_imm(ops[2], line_no)?,
                )
            }
            _ => {
                need(3)?;
                Instruction::r(
                    op,
                    parse_reg(ops[0], line_no)?,
                    parse_reg(ops[1], line_no)?,
                    parse_reg(ops[2], line_no)?,
                )
            }
        };
        insts.push(inst);
    }

    Ok(Program::new(name, threads, insts))
}

/// Disassemble a program back to source text that `assemble` accepts
/// (round-trip tested).
pub fn disassemble(p: &Program) -> String {
    let mut out = String::new();
    out.push_str(&format!(".name {}\n.threads {}\n\n", p.name, p.threads));
    for inst in &p.insts {
        out.push_str("    ");
        out.push_str(&inst.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
.name sample
.threads 64
; add tid to a constant, read and write back
start:
    tid   r0
    ldi   r1, 0x20
    iadd  r2, r0, r1
    ld    r3, [r2]
    st    [r2], r3
    stnb  [r2], r3
    bnz   r3, start
    halt
"#;

    #[test]
    fn assembles_sample() {
        let p = assemble(SAMPLE).unwrap();
        assert_eq!(p.name, "sample");
        assert_eq!(p.threads, 64);
        assert_eq!(p.insts.len(), 8);
        assert_eq!(p.insts[1], Instruction::i(Opcode::Ldi, 1, 0, 32));
        // bnz target resolves to pc 0 (the 'start' label).
        assert_eq!(p.insts[6], Instruction::i(Opcode::Bnz, 3, 0, 0));
    }

    #[test]
    fn disassemble_roundtrip_sample() {
        let p = assemble(SAMPLE).unwrap();
        let q = assemble(&disassemble(&p)).unwrap();
        assert_eq!(p.insts, q.insts);
        assert_eq!(p.threads, q.threads);
        assert_eq!(p.name, q.name);
    }

    // The random-program asm→disasm→asm round-trip property lives in
    // `rust/tests/asm_roundtrip.rs` (one canonical-operand-form
    // generator; it also pins binary encode/decode, disassembly
    // idempotence, and the typed errors on mutated inputs).

    #[test]
    fn missing_threads_is_error() {
        let e = assemble("halt\n").unwrap_err();
        assert!(e.msg.contains(".threads"));
    }

    #[test]
    fn duplicate_label_is_error() {
        let e = assemble(".threads 1\na:\na:\nhalt\n").unwrap_err();
        assert!(e.msg.contains("duplicate label"));
    }

    #[test]
    fn unknown_mnemonic_reports_line() {
        let e = assemble(".threads 1\n\nfrob r1\n").unwrap_err();
        assert_eq!(e.line, 3);
    }

    #[test]
    fn register_out_of_range() {
        let e = assemble(".threads 1\nldi r64, 0\n").unwrap_err();
        assert!(e.msg.contains("out of range"));
    }

    #[test]
    fn operand_count_checked() {
        let e = assemble(".threads 1\niadd r1, r2\n").unwrap_err();
        assert!(e.msg.contains("expects 3"));
    }

    #[test]
    fn negative_and_binary_immediates() {
        let p = assemble(".threads 1\niaddi r1, r1, -1\nldi r2, 0b101\nhalt\n").unwrap();
        assert_eq!(p.insts[0].imm, 0xFFFF);
        assert_eq!(p.insts[1].imm, 5);
    }

    #[test]
    fn label_and_inst_same_line() {
        let p = assemble(".threads 1\nstart: halt\n").unwrap();
        assert_eq!(p.insts.len(), 1);
    }

    #[test]
    fn hash_comments_accepted() {
        let p = assemble(".threads 1\nhalt # trailing\n").unwrap();
        assert_eq!(p.insts.len(), 1);
    }
}
