//! A program: instructions plus block metadata (thread count, name) and the
//! cycle-class census the paper's "Common Ops" rows report.

use super::inst::Instruction;
use super::opcode::OpClass;
use std::collections::BTreeMap;

/// An assembled SIMT program.
#[derive(Debug, Clone)]
pub struct Program {
    /// Human-readable name (appears in reports), e.g. `"transpose32"`.
    pub name: String,
    /// Number of threads in the block (the paper's examples use 256–4096).
    pub threads: u32,
    /// The instruction stream.
    pub insts: Vec<Instruction>,
}

impl Program {
    pub fn new(name: impl Into<String>, threads: u32, insts: Vec<Instruction>) -> Self {
        assert!(threads > 0, "program needs at least one thread");
        Self { name: name.into(), threads, insts }
    }

    /// Static census of instructions by cycle class (dynamic counts can
    /// differ when the program branches; the simulator reports those).
    pub fn static_census(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for inst in &self.insts {
            let k = match inst.op.class() {
                OpClass::Int => "int",
                OpClass::Imm => "imm",
                OpClass::Fp => "fp",
                OpClass::Other => "other",
                OpClass::Load => "load",
                OpClass::Store => "store",
            };
            *m.entry(k).or_insert(0) += 1;
        }
        m
    }

    /// Encode the whole program to binary words (the simulator decodes them
    /// back — keeping encode/decode on the hot path honest).
    pub fn encode(&self) -> Vec<u64> {
        self.insts.iter().map(|i| i.encode()).collect()
    }

    /// Decode a binary image.
    pub fn decode(name: impl Into<String>, threads: u32, words: &[u64]) -> Result<Self, DecodeError> {
        let insts = words
            .iter()
            .enumerate()
            .map(|(pc, &w)| Instruction::decode(w).ok_or(DecodeError { pc, word: w }))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self::new(name, threads, insts))
    }
}

/// Typed binary-decode failure: the offending word and its pc. Converts
/// into [`crate::sim::exec::SimError`] (and from there into the service
/// layer's `ServiceError`), so no `String`-typed error escapes the ISA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError {
    pub pc: usize,
    pub word: u64,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid instruction at pc {} (word {:#012x})", self.pc, self.word)
    }
}

impl std::error::Error for DecodeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::opcode::Opcode;

    fn tiny() -> Program {
        Program::new(
            "tiny",
            16,
            vec![
                Instruction::i(Opcode::Tid, 0, 0, 0),
                Instruction::i(Opcode::Ldi, 1, 0, 5),
                Instruction::r(Opcode::Iadd, 2, 0, 1),
                Instruction::i(Opcode::Ld, 3, 2, 0),
                Instruction::z(Opcode::Halt),
            ],
        )
    }

    #[test]
    fn census_counts_classes() {
        let c = tiny().static_census();
        assert_eq!(c["imm"], 1);
        assert_eq!(c["int"], 1);
        assert_eq!(c["load"], 1);
        assert_eq!(c["other"], 2); // tid + halt
    }

    #[test]
    fn binary_roundtrip() {
        let p = tiny();
        let words = p.encode();
        let q = Program::decode("tiny", p.threads, &words).unwrap();
        assert_eq!(p.insts, q.insts);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Program::decode("bad", 16, &[u64::MAX]).is_err());
    }

    #[test]
    #[should_panic(expected = "at least one thread")]
    fn zero_threads_rejected() {
        Program::new("z", 0, vec![]);
    }
}
