//! The soft SIMT processor's instruction set.
//!
//! The paper's benchmarks are "written in assembler" for the eGPU, whose
//! ISA is not published in full; this module defines a faithful-in-spirit
//! SIMT ISA with the features the paper's programs need and the cycle
//! classes its tables report:
//!
//! | Table row        | Instruction class                    |
//! |------------------|--------------------------------------|
//! | `INT OPs`        | register-register integer ALU        |
//! | `Immediate OPs`  | any op carrying an immediate operand |
//! | `FP OPs`         | IEEE-754 single-precision ALU        |
//! | `Other OPs`      | TID/NOP/HALT/control flow            |
//! | `Load/Store`     | shared-memory LD / ST / STNB         |
//!
//! Sixteen lanes execute each instruction for every thread in the block
//! (threads/16 *operations* per instruction); see [`crate::sim`]. Control
//! flow may diverge per lane: [`cfg`] computes the immediate
//! post-dominators the execution core reconverges at.

pub mod asm;
pub mod cfg;
pub mod inst;
pub mod opcode;
pub mod program;

pub use inst::Instruction;
pub use opcode::{OpClass, Opcode};
pub use program::Program;
