//! Control-flow analysis over the instruction stream: immediate
//! post-dominators, the reconvergence points of the divergence model.
//!
//! When a `bnz` splits the block (some lanes take the branch, some fall
//! through), the execution core serializes the two paths and rejoins them
//! at the branch's *immediate post-dominator* — the first instruction
//! every path from the branch to program exit must pass through
//! (DESIGN.md §Divergence). This module computes that point for every
//! instruction, once per program, from the static CFG:
//!
//! * `halt` flows to a single virtual exit node;
//! * `jmp` flows to its target;
//! * `bnz` flows to both its target and the fall-through;
//! * everything else falls through;
//! * a control transfer outside the program flows to exit (execution
//!   faults there, which ends the path).
//!
//! The algorithm is Cooper–Harvey–Kennedy ("A Simple, Fast Dominance
//! Algorithm") run on the reversed CFG with the exit node as the root, so
//! its immediate *dominators* are our immediate *post*-dominators. It is
//! effectively linear for the structured programs the builder emits and
//! needs no per-node bitsets, so even a pathological 64 Ki-instruction
//! program stays cheap.

use crate::isa::inst::Instruction;
use crate::isa::opcode::Opcode;

/// Sentinel for "no post-dominator inside the program": the only common
/// point past this instruction is program exit. A reconvergence stack
/// entry carrying this value can never match a real PC, so paths under it
/// retire through `halt` alone.
pub const EXIT: usize = usize::MAX;

/// Immediate post-dominator of every instruction (`EXIT` where none
/// exists inside the program, e.g. a branch whose arms halt separately,
/// or code that cannot reach `halt` at all).
pub fn immediate_postdoms(insts: &[Instruction]) -> Vec<usize> {
    let n = insts.len();
    let exit = n; // virtual exit node appended after the last instruction
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (pc, inst) in insts.iter().enumerate() {
        let clamp = |t: usize| if t < n { t } else { exit };
        let fall = clamp(pc + 1);
        match inst.op {
            Opcode::Halt => succ[pc].push(exit),
            Opcode::Jmp => succ[pc].push(clamp(inst.imm as usize)),
            Opcode::Bnz => {
                let target = clamp(inst.imm as usize);
                succ[pc].push(target);
                if fall != target {
                    succ[pc].push(fall);
                }
            }
            _ => succ[pc].push(fall),
        }
    }

    // Adjacency of the reversed CFG (edges exit-ward become edges
    // entry-ward): the DFS below walks it from the exit root.
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
    for (pc, ss) in succ.iter().enumerate() {
        for &s in ss {
            preds[s].push(pc);
        }
    }

    // Postorder of the reversed CFG from exit. Nodes never reached here
    // cannot reach exit in the original CFG: their post-dominators are
    // undefined and they report `EXIT`.
    let mut order = Vec::with_capacity(n + 1);
    let mut number = vec![usize::MAX; n + 1];
    let mut visited = vec![false; n + 1];
    let mut dfs = vec![(exit, 0usize)];
    visited[exit] = true;
    while let Some(frame) = dfs.last_mut() {
        let (node, edge) = (frame.0, frame.1);
        if edge < preds[node].len() {
            frame.1 += 1;
            let next = preds[node][edge];
            if !visited[next] {
                visited[next] = true;
                dfs.push((next, 0));
            }
        } else {
            dfs.pop();
            number[node] = order.len();
            order.push(node);
        }
    }

    // Cooper–Harvey–Kennedy fixpoint in reverse postorder. `idom` (of the
    // reversed graph) is indexed by node; MAX marks "not yet known".
    let mut idom = vec![usize::MAX; n + 1];
    idom[exit] = exit;
    let rpo: Vec<usize> = order.iter().rev().copied().filter(|&v| v != exit).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for &b in &rpo {
            let mut new_idom = usize::MAX;
            // Predecessors of `b` in the reversed graph are its CFG
            // successors; only those already processed participate.
            for &p in &succ[b] {
                if idom[p] == usize::MAX {
                    continue;
                }
                new_idom = if new_idom == usize::MAX {
                    p
                } else {
                    intersect(p, new_idom, &idom, &number)
                };
            }
            if new_idom != usize::MAX && idom[b] != new_idom {
                idom[b] = new_idom;
                changed = true;
            }
        }
    }

    (0..n)
        .map(|pc| if idom[pc] == usize::MAX || idom[pc] == exit { EXIT } else { idom[pc] })
        .collect()
}

/// Walk two nodes up the (post-)dominator tree to their common ancestor,
/// comparing by postorder number (lower = further from the root).
fn intersect(mut a: usize, mut b: usize, idom: &[usize], number: &[usize]) -> usize {
    while a != b {
        while number[a] < number[b] {
            a = idom[a];
        }
        while number[b] < number[a] {
            b = idom[b];
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn ipdoms_of(src: &str) -> Vec<usize> {
        let p = assemble(src).expect("assembles");
        let insts: Vec<Instruction> =
            p.encode().iter().map(|&w| Instruction::decode(w).unwrap()).collect();
        immediate_postdoms(&insts)
    }

    #[test]
    fn straight_line_postdominates_by_fallthrough() {
        let pd = ipdoms_of(".threads 16\n tid r0\n iaddi r1, r0, 1\n halt\n");
        assert_eq!(pd, vec![1, 2, EXIT]);
    }

    #[test]
    fn if_else_reconverges_at_the_join() {
        // 0 tid, 1 bnz -> 3, 2 iaddi (fall arm), 3 iaddi (join), 4 halt
        let pd = ipdoms_of(
            ".threads 16\n tid r0\n bnz r0, join\n iaddi r1, r0, 1\njoin:\n iaddi r2, r0, 2\n halt\n",
        );
        assert_eq!(pd[1], 3, "branch reconverges at the label both paths reach");
        assert_eq!(pd[2], 3);
    }

    #[test]
    fn loop_branch_reconverges_at_fallthrough() {
        // 0 tid, 1 iaddi, 2 iaddi (body), 3 bnz -> 2, 4 halt
        let pd = ipdoms_of(
            ".threads 16\n tid r0\n iaddi r1, r0, 0\nbody:\n iaddi r1, r1, 1\n bnz r1, body\n halt\n",
        );
        assert_eq!(pd[3], 4, "back-edge branch reconverges at loop exit");
    }

    #[test]
    fn arms_that_halt_separately_have_no_join() {
        // 0 tid, 1 bnz -> 3, 2 halt (fall arm), 3 halt (taken arm)
        let pd = ipdoms_of(".threads 16\n tid r0\n bnz r0, taken\n halt\ntaken:\n halt\n");
        assert_eq!(pd[1], EXIT, "only the virtual exit joins the two halts");
    }

    #[test]
    fn out_of_range_target_counts_as_an_exit_edge() {
        // bnz to a PC past the end: the taken edge leaves the program, so
        // the branch's only in-program continuation is the fall-through —
        // but exit-bound paths keep the join at EXIT.
        let p = crate::isa::program::Program {
            name: "oob".into(),
            threads: 16,
            insts: vec![
                Instruction::i(Opcode::Bnz, 0, 0, 99),
                Instruction::z(Opcode::Halt),
            ],
        };
        let insts: Vec<Instruction> =
            p.encode().iter().map(|&w| Instruction::decode(w).unwrap()).collect();
        let pd = immediate_postdoms(&insts);
        assert_eq!(pd[0], EXIT);
    }
}
