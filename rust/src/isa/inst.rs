//! Instruction representation and 40-bit binary encoding (stored in u64).
//!
//! Encoding layout:
//!
//! ```text
//! 39        34 33   28 27   22 21    16 15        0
//! +-----------+-------+-------+--------+-----------+
//! |  opcode   |  rd   |  ra   |   rb   | (unused)  |   R-format
//! +-----------+-------+-------+--------+-----------+
//! |  opcode   |  rd   |  ra   |  (0)   |   imm16   |   I-format
//! +-----------+-------+-------+--------+-----------+
//! ```
//!
//! `Jmp`/`Bnz` store the (absolute) target PC in the imm16 field.

use super::opcode::Opcode;
use std::fmt;

/// Number of architectural registers per thread. The paper's SP carries
/// two M20Ks of register file (Table I); at 16 resident threads per SP
/// that is 64 registers per thread — enough to keep a radix-16 butterfly
/// (16 complex points) entirely in registers, as the paper's FFT
/// load/store counts imply.
pub const NUM_REGS: usize = 64;

/// A decoded instruction. `rd`/`ra`/`rb` index the per-thread register
/// file; `imm` is a zero-extended 16-bit immediate (or branch target).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    pub op: Opcode,
    pub rd: u8,
    pub ra: u8,
    pub rb: u8,
    pub imm: u16,
}

impl Instruction {
    /// R-format constructor.
    pub fn r(op: Opcode, rd: u8, ra: u8, rb: u8) -> Self {
        Self { op, rd, ra, rb, imm: 0 }
    }

    /// I-format constructor.
    pub fn i(op: Opcode, rd: u8, ra: u8, imm: u16) -> Self {
        Self { op, rd, ra, rb: 0, imm }
    }

    /// Zero-operand constructor (`nop`, `halt`).
    pub fn z(op: Opcode) -> Self {
        Self { op, rd: 0, ra: 0, rb: 0, imm: 0 }
    }

    /// Whether this opcode uses the imm16 field (I-format).
    pub fn is_i_format(op: Opcode) -> bool {
        use Opcode::*;
        matches!(
            op,
            Iaddi | Imuli | Iandi | Iori | Ixori | Ishli | Ishri | Ldi | Lui | Jmp | Bnz
        )
    }

    /// Encode to the 40-bit binary word (in a u64).
    pub fn encode(&self) -> u64 {
        assert!((self.rd as usize) < NUM_REGS, "rd out of range");
        assert!((self.ra as usize) < NUM_REGS, "ra out of range");
        assert!((self.rb as usize) < NUM_REGS, "rb out of range");
        let mut w = (self.op.code() as u64) << 34;
        w |= (self.rd as u64) << 28;
        w |= (self.ra as u64) << 22;
        if Self::is_i_format(self.op) {
            w |= self.imm as u64;
        } else {
            w |= (self.rb as u64) << 16;
        }
        w
    }

    /// Decode a 40-bit word. Returns `None` for an invalid opcode field or
    /// set bits above bit 39.
    pub fn decode(w: u64) -> Option<Self> {
        if w >> 40 != 0 {
            return None;
        }
        let op = Opcode::from_code((w >> 34) as u8)?;
        let rd = ((w >> 28) & 0x3F) as u8;
        let ra = ((w >> 22) & 0x3F) as u8;
        if Self::is_i_format(op) {
            Some(Self { op, rd, ra, rb: 0, imm: (w & 0xFFFF) as u16 })
        } else {
            Some(Self { op, rd, ra, rb: ((w >> 16) & 0x3F) as u8, imm: 0 })
        }
    }
}

impl fmt::Display for Instruction {
    /// Assembler syntax, e.g. `iadd r2, r0, r1` / `ldi r1, 32` /
    /// `ld r3, [r2]` / `st [r4], r3`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Opcode::*;
        let m = self.op.mnemonic();
        match self.op {
            Nop | Halt => write!(f, "{m}"),
            Jmp => write!(f, "{m} {}", self.imm),
            Bnz => write!(f, "{m} r{}, {}", self.rd, self.imm),
            Tid => write!(f, "{m} r{}", self.rd),
            Fneg | Itof => write!(f, "{m} r{}, r{}", self.rd, self.ra),
            Ldi | Lui => write!(f, "{m} r{}, {}", self.rd, self.imm),
            Ld => write!(f, "{m} r{}, [r{}]", self.rd, self.ra),
            St | Stnb => write!(f, "{m} [r{}], r{}", self.ra, self.rb),
            _ if Instruction::is_i_format(self.op) => {
                write!(f, "{m} r{}, r{}, {}", self.rd, self.ra, self.imm)
            }
            _ => write!(f, "{m} r{}, r{}, r{}", self.rd, self.ra, self.rb),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::XorShift64;

    fn random_inst(rng: &mut XorShift64) -> Instruction {
        let op = Opcode::ALL[rng.below(Opcode::ALL.len() as u32) as usize];
        if Instruction::is_i_format(op) {
            Instruction::i(op, rng.below(64) as u8, rng.below(64) as u8, rng.next_u32() as u16)
        } else {
            Instruction::r(op, rng.below(64) as u8, rng.below(64) as u8, rng.below(64) as u8)
        }
    }

    #[test]
    fn encode_decode_roundtrip_property() {
        check("inst encode/decode roundtrip", 2000, |rng| {
            let inst = random_inst(rng);
            let decoded = Instruction::decode(inst.encode()).expect("valid encoding");
            assert_eq!(decoded, inst);
        });
    }

    #[test]
    fn display_forms() {
        assert_eq!(Instruction::r(Opcode::Iadd, 2, 0, 1).to_string(), "iadd r2, r0, r1");
        assert_eq!(Instruction::i(Opcode::Ldi, 1, 0, 32).to_string(), "ldi r1, 32");
        assert_eq!(Instruction::i(Opcode::Ld, 3, 2, 0).to_string(), "ld r3, [r2]");
        assert_eq!(Instruction::r(Opcode::St, 0, 4, 3).to_string(), "st [r4], r3");
        assert_eq!(Instruction::z(Opcode::Halt).to_string(), "halt");
        assert_eq!(Instruction::i(Opcode::Tid, 5, 0, 0).to_string(), "tid r5");
    }

    #[test]
    fn invalid_opcode_field_decodes_none() {
        assert_eq!(Instruction::decode(63u64 << 34), None);
        assert_eq!(Instruction::decode(1u64 << 40), None);
    }

    #[test]
    #[should_panic(expected = "rd out of range")]
    fn encode_checks_register_range() {
        Instruction { op: Opcode::Iadd, rd: 64, ra: 0, rb: 0, imm: 0 }.encode();
    }

    #[test]
    fn imm_survives_full_16_bits() {
        let i = Instruction::i(Opcode::Ldi, 0, 0, 0xFFFF);
        assert_eq!(Instruction::decode(i.encode()).unwrap().imm, 0xFFFF);
    }
}
