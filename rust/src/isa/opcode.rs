//! Opcode definitions and their cycle-accounting classes.

use std::fmt;
use std::str::FromStr;

/// Cycle-accounting class, matching the "Common Ops" rows of the paper's
/// Tables II and III.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Register-register integer ALU ("INT OPs").
    Int,
    /// Ops carrying an immediate operand ("Immediate OPs").
    Imm,
    /// IEEE-754 FP32 ALU ("FP OPs").
    Fp,
    /// Control / miscellaneous ("Other OPs").
    Other,
    /// Shared-memory read.
    Load,
    /// Shared-memory write (blocking or non-blocking).
    Store,
}

/// Every instruction the soft SIMT core executes.
///
/// Format legend: `R` = rd,ra,rb · `RI` = rd,ra,imm16 · `DI` = rd,imm16 ·
/// `D` = rd · `M` = memory · `J` = label/none.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    // -- integer register-register (class Int) --
    /// rd = ra + rb
    Iadd,
    /// rd = ra - rb
    Isub,
    /// rd = ra * rb (low 32 bits)
    Imul,
    /// rd = ra & rb
    Iand,
    /// rd = ra | rb
    Ior,
    /// rd = ra ^ rb
    Ixor,
    /// rd = ra << (rb & 31)
    Ishl,
    /// rd = ra >> (rb & 31) (logical)
    Ishr,
    // -- integer immediate (class Imm) --
    /// rd = ra + imm
    Iaddi,
    /// rd = ra * imm
    Imuli,
    /// rd = ra & imm
    Iandi,
    /// rd = ra | imm
    Iori,
    /// rd = ra ^ imm
    Ixori,
    /// rd = ra << imm
    Ishli,
    /// rd = ra >> imm (logical)
    Ishri,
    /// rd = imm (zero-extended)
    Ldi,
    /// rd = imm << 16 | (rd & 0xFFFF) — builds 32-bit constants with Ldi
    Lui,
    // -- floating point (class Fp) --
    /// rd = ra + rb
    Fadd,
    /// rd = ra - rb
    Fsub,
    /// rd = ra * rb
    Fmul,
    /// rd = rd + ra * rb (fused)
    Fma,
    /// rd = -ra
    Fneg,
    /// rd = f32(int(ra)) — int-to-float convert
    Itof,
    // -- memory (classes Load / Store) --
    /// rd = shared[ra]
    Ld,
    /// shared[ra] = rb, blocking (pipeline held until the write drains)
    St,
    /// shared[ra] = rb, non-blocking (pipeline continues after issue)
    Stnb,
    // -- control / misc (class Other) --
    /// rd = thread id
    Tid,
    /// no-op
    Nop,
    /// stop the block
    Halt,
    /// unconditional jump (uniform by construction)
    Jmp,
    /// per-lane branch if rd != 0; lanes that disagree diverge onto the
    /// reconvergence stack (see `sim::exec`)
    Bnz,
}

impl Opcode {
    /// The cycle-accounting class of this opcode.
    pub fn class(self) -> OpClass {
        use Opcode::*;
        match self {
            Iadd | Isub | Imul | Iand | Ior | Ixor | Ishl | Ishr => OpClass::Int,
            Iaddi | Imuli | Iandi | Iori | Ixori | Ishli | Ishri | Ldi | Lui => OpClass::Imm,
            Fadd | Fsub | Fmul | Fma | Fneg | Itof => OpClass::Fp,
            Ld => OpClass::Load,
            St | Stnb => OpClass::Store,
            Tid | Nop | Halt | Jmp | Bnz => OpClass::Other,
        }
    }

    /// Mnemonic in assembler syntax.
    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            Iadd => "iadd",
            Isub => "isub",
            Imul => "imul",
            Iand => "iand",
            Ior => "ior",
            Ixor => "ixor",
            Ishl => "ishl",
            Ishr => "ishr",
            Iaddi => "iaddi",
            Imuli => "imuli",
            Iandi => "iandi",
            Iori => "iori",
            Ixori => "ixori",
            Ishli => "ishli",
            Ishri => "ishri",
            Ldi => "ldi",
            Lui => "lui",
            Fadd => "fadd",
            Fsub => "fsub",
            Fmul => "fmul",
            Fma => "fma",
            Fneg => "fneg",
            Itof => "itof",
            Ld => "ld",
            St => "st",
            Stnb => "stnb",
            Tid => "tid",
            Nop => "nop",
            Halt => "halt",
            Jmp => "jmp",
            Bnz => "bnz",
        }
    }

    /// All opcodes, for exhaustive tests and the assembler's mnemonic map.
    pub const ALL: [Opcode; 31] = {
        use Opcode::*;
        [
            Iadd, Isub, Imul, Iand, Ior, Ixor, Ishl, Ishr, Iaddi, Imuli, Iandi, Iori, Ixori,
            Ishli, Ishri, Ldi, Lui, Fadd, Fsub, Fmul, Fma, Fneg, Itof, Ld, St, Stnb, Tid, Nop,
            Halt, Jmp, Bnz,
        ]
    };

    /// Numeric encoding (6-bit field).
    pub fn code(self) -> u8 {
        Self::ALL.iter().position(|&o| o == self).unwrap() as u8
    }

    /// Decode a 6-bit opcode field.
    pub fn from_code(code: u8) -> Option<Opcode> {
        Self::ALL.get(code as usize).copied()
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

/// Typed mnemonic-lookup failure (carries the rejected token, so the
/// assembler can report it with line context).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownMnemonic(pub String);

impl fmt::Display for UnknownMnemonic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown mnemonic '{}'", self.0)
    }
}

impl std::error::Error for UnknownMnemonic {}

impl FromStr for Opcode {
    type Err = UnknownMnemonic;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Opcode::ALL
            .iter()
            .copied()
            .find(|o| o.mnemonic() == s)
            .ok_or_else(|| UnknownMnemonic(s.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        for op in Opcode::ALL {
            assert_eq!(Opcode::from_code(op.code()), Some(op));
        }
    }

    #[test]
    fn mnemonics_roundtrip() {
        for op in Opcode::ALL {
            assert_eq!(op.mnemonic().parse::<Opcode>().unwrap(), op);
        }
    }

    #[test]
    fn mnemonics_unique() {
        let mut seen = std::collections::HashSet::new();
        for op in Opcode::ALL {
            assert!(seen.insert(op.mnemonic()), "duplicate {op}");
        }
    }

    #[test]
    fn out_of_range_code_is_none() {
        assert_eq!(Opcode::from_code(63), None);
    }

    #[test]
    fn classes_cover_paper_rows() {
        use std::collections::HashSet;
        let classes: HashSet<_> = Opcode::ALL.iter().map(|o| o.class()).collect();
        assert!(classes.contains(&OpClass::Int));
        assert!(classes.contains(&OpClass::Imm));
        assert!(classes.contains(&OpClass::Fp));
        assert!(classes.contains(&OpClass::Other));
        assert!(classes.contains(&OpClass::Load));
        assert!(classes.contains(&OpClass::Store));
    }

    #[test]
    fn unknown_mnemonic_errors() {
        assert!("frobnicate".parse::<Opcode>().is_err());
    }
}
