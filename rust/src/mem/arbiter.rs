//! Carry-chain based arbitration (paper §III-C, Figs. 5 and 6).
//!
//! Each bank has an arbiter. A vector defining the accesses to that bank is
//! loaded ('1' = the lane uses this bank). Every cycle the circuit
//! subtracts one from the current value — on an FPGA this rides the ALM
//! carry chain — which flips the rightmost '1' to '0' *and* erroneously
//! re-asserts all lower bits. Transition detection repairs the state:
//! any 0→1 transition is zeroed, and the single 1→0 transition is emitted
//! as the one-hot grant (the bank↔lane mux control for that cycle).
//!
//! [`CarryChainArbiter`] simulates exactly that structure; the property
//! tests pin it against the closed form (isolate-lowest-set-bit) and
//! against the paper's worked example in Fig. 6.

use super::LaneMask;

/// Bit-exact model of the carry-chain arbiter circuit of Fig. 5.
#[derive(Debug, Clone)]
pub struct CarryChainArbiter {
    /// Current lane-marker vector (the register in Fig. 5).
    state: LaneMask,
}

impl CarryChainArbiter {
    /// Load the access vector for this bank (one column of the one-hot
    /// bank matrix).
    pub fn load(column: LaneMask) -> Self {
        Self { state: column }
    }

    /// Remaining requests.
    pub fn pending(&self) -> LaneMask {
        self.state
    }

    /// True when every request has been granted.
    pub fn done(&self) -> bool {
        self.state == 0
    }

    /// One clock cycle: returns the one-hot grant (`None` when idle —
    /// this bank is not used by the operation).
    ///
    /// Implemented exactly as the hardware: subtract one, detect the 1→0
    /// transition (grant), zero the 0→1 re-assertion errors.
    pub fn step(&mut self) -> Option<LaneMask> {
        if self.state == 0 {
            return None;
        }
        let v = self.state;
        let sub = v.wrapping_sub(1);
        // 1→0 transition: was set, now clear — the active lane.
        let grant = v & !sub;
        // 0→1 transitions (re-assertion errors) are zeroed; surviving
        // bits are those set both before and after the subtract.
        self.state = v & sub;
        debug_assert!(grant != 0 && grant & (grant - 1) == 0, "grant must be one-hot");
        Some(grant)
    }

    /// Run to completion, returning the grant sequence (used by tests and
    /// the example walkthrough; the simulator steps cycle by cycle).
    pub fn run(mut self) -> Vec<LaneMask> {
        let mut grants = Vec::with_capacity(self.state.count_ones() as usize);
        while let Some(g) = self.step() {
            grants.push(g);
        }
        grants
    }
}

/// The whole arbitration stage of Fig. 3: one arbiter per bank, stepped in
/// lock-step. Produces, per cycle, the bank→lane mux controls; the output
/// mux controls are the delayed transpose of the same matrix.
#[derive(Debug, Clone)]
pub struct BankArbiters {
    arbiters: Vec<CarryChainArbiter>,
}

impl BankArbiters {
    /// Load one arbiter per bank from the one-hot matrix columns.
    pub fn load(columns: &[LaneMask]) -> Self {
        Self {
            arbiters: columns.iter().map(|&c| CarryChainArbiter::load(c)).collect(),
        }
    }

    pub fn done(&self) -> bool {
        self.arbiters.iter().all(CarryChainArbiter::done)
    }

    /// One clock: `grants[b]` = one-hot lane granted at bank `b` (0 if
    /// idle). On any given cycle there is only one mapping from any
    /// individual memory bank to any individual lane.
    pub fn step(&mut self) -> Vec<LaneMask> {
        self.arbiters
            .iter_mut()
            .map(|a| a.step().unwrap_or(0))
            .collect()
    }

    /// Run all banks to completion; returns the cycle-by-cycle grant
    /// matrix (`schedule[cycle][bank]`).
    pub fn run(mut self) -> Vec<Vec<LaneMask>> {
        let mut schedule = Vec::new();
        while !self.done() {
            schedule.push(self.step());
        }
        schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::conflict::analyze;
    use crate::mem::mapping::{BankMap, BankMapping};
    use crate::mem::LANES;
    use crate::util::bits::lowest_set_bit;
    use crate::util::proptest::check;

    /// Paper Fig. 6: bank 1 of the Fig. 4 example is requested by lanes
    /// 1, 2 and 4 (vector 00010110). The grant sequence is lane 1, then
    /// lane 2, then lane 4 — three cycles, matching the stored conflict
    /// count of 3.
    #[test]
    fn paper_fig6_walkthrough() {
        let grants = CarryChainArbiter::load(0b0001_0110).run();
        assert_eq!(grants, vec![0b0000_0010, 0b0000_0100, 0b0001_0000]);
    }

    #[test]
    fn all_ones_takes_sixteen_cycles() {
        let grants = CarryChainArbiter::load(0xFFFF).run();
        assert_eq!(grants.len(), 16);
        for (i, g) in grants.iter().enumerate() {
            assert_eq!(*g, 1 << i, "equal priority starting from the rightmost lane");
        }
    }

    #[test]
    fn all_zeros_is_idle() {
        let mut a = CarryChainArbiter::load(0);
        assert!(a.done());
        assert_eq!(a.step(), None);
    }

    #[test]
    fn grants_one_hot_each_served_once_property() {
        check("arbiter: one-hot grants, each lane exactly once", 2000, |rng| {
            let column = rng.next_u32() as u16;
            let grants = CarryChainArbiter::load(column).run();
            // Cycle count equals the population count (the conflict count
            // the controller stored for the operation).
            assert_eq!(grants.len() as u32, column.count_ones());
            let mut union = 0u16;
            for g in &grants {
                assert!(*g != 0 && g & (g - 1) == 0, "grant {g:#b} not one-hot");
                assert_eq!(union & g, 0, "lane granted twice");
                union |= g;
            }
            assert_eq!(union, column, "every requesting lane granted exactly once");
        });
    }

    #[test]
    fn matches_lowest_set_bit_closed_form_property() {
        check("carry-chain == isolate-lowest-set-bit", 2000, |rng| {
            let column = rng.next_u32() as u16;
            let mut v = column;
            let mut arb = CarryChainArbiter::load(column);
            while v != 0 {
                let expect = lowest_set_bit(v);
                assert_eq!(arb.step(), Some(expect));
                v &= v - 1;
            }
            assert!(arb.done());
        });
    }

    #[test]
    fn bank_arbiters_schedule_is_conflict_free_property() {
        check("per-cycle schedule: ≤1 lane per bank, ≤1 bank per lane", 500, |rng| {
            let map = BankMap::new(16, BankMapping::Lsb);
            let mut addrs = [0u32; LANES];
            for a in addrs.iter_mut() {
                *a = rng.below(1 << 14);
            }
            let mask = rng.next_u32() as u16;
            let info = analyze(&addrs, mask, &map);
            let schedule = BankArbiters::load(&info.columns).run();
            assert_eq!(schedule.len() as u32, info.max_conflicts);
            for row in &schedule {
                let mut lanes_this_cycle = 0u16;
                for &g in row {
                    assert!(g == 0 || g & (g - 1) == 0);
                    assert_eq!(lanes_this_cycle & g, 0, "lane mapped to two banks in one cycle");
                    lanes_this_cycle |= g;
                }
            }
            // Every lane served exactly once across the schedule.
            let mut total = 0u16;
            for row in &schedule {
                for &g in row {
                    total |= g;
                }
            }
            assert_eq!(total, mask);
        });
    }

    #[test]
    fn fig4_full_schedule() {
        // The Fig. 4 operation completes in 3 cycles (max conflict = 3);
        // bank 2 stays idle throughout.
        let map = BankMap::new(8, BankMapping::Lsb);
        let mut addrs = [0u32; LANES];
        for (lane, &b) in [0u32, 1, 1, 3, 1, 3, 4, 5].iter().enumerate() {
            addrs[lane] = b;
        }
        let info = analyze(&addrs, 0x00FF, &map);
        let schedule = BankArbiters::load(&info.columns).run();
        assert_eq!(schedule.len(), 3);
        assert!(schedule.iter().all(|row| row[2] == 0), "bank 2 unused");
    }
}
