//! Address→bank mapping (paper §III-B.2).
//!
//! The simplest map takes the LSBs of the word address as the bank index.
//! The **Offset** map shifts the extracted field up — for complex data with
//! interleaved I/Q components (adjacent addresses), extracting bits
//! `[shift+b-1 : shift]` instead of `[b-1:0]` spreads strided accesses
//! across banks and "can provide significant performance advantages"
//! (the paper's Offset columns in Tables II and III).

use crate::util::bits::log2_exact;

/// How the bank index is extracted from a word address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BankMapping {
    /// Bank = `addr[b-1:0]` — the default map.
    Lsb,
    /// Bank = `addr[shift+b-1:shift]` — the shifted-field family. The
    /// paper's **Offset** map is `shift = 2` (tuned for interleaved
    /// complex data, where I/Q pairs occupy adjacent addresses); the
    /// design-space explorer sweeps the shift as a free parameter up to
    /// [`BankMapping::MAX_SHIFT`].
    Offset { shift: u32 },
    /// Bank = `addr[b-1:0] ^ addr[2b-1:b]` — XOR interleaving, the
    /// classic conflict-randomizing map. Not benchmarked in the paper
    /// (its §VII names "varying the bank mapping" as the FPGA's open
    /// flexibility); included here as the ablation the mapping advisor
    /// and `bench mapping_ablation` explore.
    Xor,
}

impl BankMapping {
    /// Largest constructible `Offset` shift (keeps `shift + bank bits`
    /// well inside the 32-bit word-address space).
    pub const MAX_SHIFT: u32 = 8;

    /// The paper's Offset map: bank field extracted at bit 2.
    pub const fn offset() -> Self {
        BankMapping::Offset { shift: 2 }
    }

    /// The bit offset at which the bank field starts (shift-based maps;
    /// the paper's two benchmark maps are both of this form).
    pub fn shift(self) -> u32 {
        match self {
            BankMapping::Lsb => 0,
            BankMapping::Offset { shift } => shift,
            BankMapping::Xor => 0,
        }
    }

    /// Short label used in table headers ("" / "Offset" / "Offset3" /
    /// "XOR"). The paper's shift-2 map keeps its bare "Offset" name; any
    /// other shift carries the shift in the label so labels stay
    /// parseable round-trip ([`crate::mem::arch::MemoryArchKind::parse`]).
    pub fn label(self) -> String {
        match self {
            BankMapping::Lsb => String::new(),
            BankMapping::Offset { shift: 2 } => "Offset".to_string(),
            BankMapping::Offset { shift } => format!("Offset{shift}"),
            BankMapping::Xor => "XOR".to_string(),
        }
    }

    /// Whether this mapping is constructible (the validity predicate the
    /// design space and `parse` share).
    pub fn is_valid(self) -> bool {
        match self {
            BankMapping::Lsb | BankMapping::Xor => true,
            BankMapping::Offset { shift } => shift <= Self::MAX_SHIFT,
        }
    }

    /// Whether the `conflict{B}` PJRT oracle artifact covers this map
    /// (the artifact takes a shift parameter; XOR is simulator-only).
    pub fn oracle_supported(self) -> bool {
        !matches!(self, BankMapping::Xor)
    }
}

/// A concrete bank-index extractor for `banks` banks (power of two).
#[derive(Debug, Clone, Copy)]
pub struct BankMap {
    banks: u32,
    bits: u32,
    shift: u32,
    xor: bool,
}

impl BankMap {
    pub fn new(banks: u32, mapping: BankMapping) -> Self {
        let bits = log2_exact(banks);
        Self {
            banks,
            bits,
            shift: mapping.shift(),
            xor: matches!(mapping, BankMapping::Xor),
        }
    }

    /// Like [`Self::new`], but clamps a shifted bank field to the
    /// capacity's address width: the shift maps are only bijections on
    /// `[0, words)` when `shift + log2(banks) <= log2(words)`, and an
    /// unclamped extreme descriptor (e.g. `banked32-offset8` on a
    /// 1 Ki-word memory) would compute rows past the end of a bank. The
    /// memory's data and timing paths share the one clamped map, so
    /// coupled runs and trace replays stay consistent. XOR maps need no
    /// clamp (`row = addr >> bits` is always in range).
    pub fn for_capacity(banks: u32, mapping: BankMapping, words: usize) -> Self {
        let mut m = Self::new(banks, mapping);
        if !m.xor {
            let addr_bits = words.trailing_zeros(); // capacity is a power of two
            m.shift = m.shift.min(addr_bits.saturating_sub(m.bits));
        }
        m
    }

    #[inline]
    pub fn banks(&self) -> u32 {
        self.banks
    }

    /// Bank index of a word address.
    #[inline]
    pub fn bank_of(&self, addr: u32) -> u32 {
        if self.xor {
            (addr ^ (addr >> self.bits)) & (self.banks - 1)
        } else {
            (addr >> self.shift) & (self.banks - 1)
        }
    }

    /// Row within the bank. Together with [`Self::bank_of`] this is a
    /// bijection on addresses: for the shift maps the bank field is
    /// squeezed out and the remaining bits concatenated; for the XOR map
    /// the row is simply the upper bits (the XOR is invertible given the
    /// row).
    #[inline]
    pub fn row_of(&self, addr: u32) -> u32 {
        if self.xor {
            addr >> self.bits
        } else {
            let low = addr & ((1 << self.shift) - 1);
            let high = addr >> (self.shift + self.bits);
            (high << self.shift) | low
        }
    }

    /// Reconstruct the address from (bank, row) — inverse of the pair
    /// ([`Self::bank_of`], [`Self::row_of`]).
    #[inline]
    pub fn addr_of(&self, bank: u32, row: u32) -> u32 {
        if self.xor {
            let low = (bank ^ row) & (self.banks - 1);
            (row << self.bits) | low
        } else {
            let low = row & ((1 << self.shift) - 1);
            let high = row >> self.shift;
            (high << (self.shift + self.bits)) | (bank << self.shift) | low
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn lsb_mapping_16_banks() {
        let m = BankMap::new(16, BankMapping::Lsb);
        for a in 0..64 {
            assert_eq!(m.bank_of(a), a % 16);
        }
    }

    #[test]
    fn offset_mapping_16_banks() {
        // Offset map uses bits [5:2]: consecutive I/Q pairs of the same
        // point share a bank; points stride across banks.
        let m = BankMap::new(16, BankMapping::offset());
        assert_eq!(m.bank_of(0), 0);
        assert_eq!(m.bank_of(1), 0);
        assert_eq!(m.bank_of(4), 1);
        assert_eq!(m.bank_of(63), 15);
        assert_eq!(m.bank_of(64), 0);
    }

    #[test]
    fn paper_fig4_example() {
        // Fig. 4: 8-bank system, mapping on the 3 LSBs. Addresses shown
        // map lane 0→bank 0, lane 1→bank 1, and lanes {1,2,4}→bank 1 in
        // the conflicted row.
        let m = BankMap::new(8, BankMapping::Lsb);
        assert_eq!(m.bank_of(8), 0);
        assert_eq!(m.bank_of(9), 1);
        assert_eq!(m.bank_of(17), 1);
        assert_eq!(m.bank_of(25), 1);
    }

    #[test]
    fn bank_row_bijective_property() {
        check("bank/row bijection", 3000, |rng| {
            let banks = [4u32, 8, 16][rng.below(3) as usize];
            let mapping = [
                BankMapping::Lsb,
                BankMapping::Offset { shift: rng.below(BankMapping::MAX_SHIFT + 1) },
                BankMapping::Xor,
            ][rng.below(3) as usize];
            let m = BankMap::new(banks, mapping);
            let addr = rng.below(1 << 20);
            let (b, r) = (m.bank_of(addr), m.row_of(addr));
            assert!(b < banks);
            assert_eq!(m.addr_of(b, r), addr, "addr {addr} banks {banks} {mapping:?}");
        });
    }

    #[test]
    fn xor_mapping_breaks_power_of_two_strides() {
        // The XOR map's purpose: stride-16 addresses (all bank 0 under
        // LSB) spread across all 16 banks.
        let lsb = BankMap::new(16, BankMapping::Lsb);
        let xor = BankMap::new(16, BankMapping::Xor);
        let addrs: Vec<u32> = (0..16).map(|l| l * 16).collect();
        let lsb_banks: std::collections::HashSet<u32> =
            addrs.iter().map(|&a| lsb.bank_of(a)).collect();
        let xor_banks: std::collections::HashSet<u32> =
            addrs.iter().map(|&a| xor.bank_of(a)).collect();
        assert_eq!(lsb_banks.len(), 1);
        assert_eq!(xor_banks.len(), 16);
    }

    #[test]
    fn distinct_addrs_distinct_slots_property() {
        check("no two addresses share a (bank,row) slot", 500, |rng| {
            let m = BankMap::new(16, BankMapping::offset());
            let a = rng.below(1 << 16);
            let b = rng.below(1 << 16);
            if a != b {
                assert!(
                    (m.bank_of(a), m.row_of(a)) != (m.bank_of(b), m.row_of(b)),
                    "collision {a} vs {b}"
                );
            }
        });
    }
}
