//! Banked shared memory (paper §III, Figs. 1–6).
//!
//! N single-ported banks (M20Ks are 1R+1W true dual port, so read and
//! write paths do not contend with each other), a bank-index mapping, and
//! per-bank carry-chain arbiters. A 16-lane operation costs as many cycles
//! as the maximum number of lanes landing in one bank.
//!
//! Two timing paths are provided and property-tested equal:
//!
//! - **exact**: run the per-bank [`BankArbiters`] schedule cycle by cycle,
//!   routing each granted lane through its bank — the structural model;
//! - **fast**: the closed form (max per-bank population count), used on
//!   the simulator hot path after the §Perf pass.

use super::arch::{MemoryArchKind, OpKind, ReadOp, SharedMemory};
use super::conflict::max_conflicts;
use super::mapping::{BankMap, BankMapping};
use super::{timing, LaneMask, LANES, MAX_BANKS};

/// Timing fidelity of the banked model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimingMode {
    /// Bit-level arbiter schedule (default for tests and validation).
    Exact,
    /// Closed-form max-popcount (identical cycle counts, no schedule
    /// materialization — the optimized hot path).
    Fast,
}

/// Banked shared memory.
#[derive(Debug, Clone)]
pub struct BankedMemory {
    /// Per-bank storage: `banks[b][row]`.
    banks: Vec<Vec<u32>>,
    map: BankMap,
    mapping: BankMapping,
    mode: TimingMode,
    /// §IV-A half-bank split (448 KB node-locked variant): +2 cycles of
    /// bank latency, timing otherwise unchanged.
    half_banked: bool,
}

impl BankedMemory {
    pub fn new(words: usize, n_banks: u32, mapping: BankMapping) -> Self {
        assert!(words.is_power_of_two(), "capacity must be a power of two");
        assert!(
            n_banks.is_power_of_two() && (2..=MAX_BANKS as u32).contains(&n_banks),
            "bank count must be a power of two in 2..={MAX_BANKS}"
        );
        assert!(
            words as u32 % n_banks == 0,
            "capacity must divide evenly across banks"
        );
        let map = BankMap::for_capacity(n_banks, mapping, words);
        let rows = words / n_banks as usize;
        Self {
            banks: vec![vec![0u32; rows]; n_banks as usize],
            map,
            mapping,
            mode: TimingMode::Exact,
            half_banked: false,
        }
    }

    /// Switch the timing path (cycle counts are identical; see the
    /// `exact_equals_fast` property test).
    pub fn with_mode(mut self, mode: TimingMode) -> Self {
        self.mode = mode;
        self
    }

    /// Enable the §IV-A half-bank configuration.
    pub fn with_half_banks(mut self) -> Self {
        self.half_banked = true;
        self
    }

    pub fn n_banks(&self) -> u32 {
        self.map.banks()
    }

    pub fn mode(&self) -> TimingMode {
        self.mode
    }

    #[inline]
    fn load(&self, addr: u32) -> u32 {
        self.banks[self.map.bank_of(addr) as usize][self.map.row_of(addr) as usize]
    }

    #[inline]
    fn store(&mut self, addr: u32, v: u32) {
        let (b, r) = (self.map.bank_of(addr) as usize, self.map.row_of(addr) as usize);
        self.banks[b][r] = v;
    }

    /// Build the one-hot bank-matrix columns on the stack (§Perf: the
    /// heap-allocating [`analyze`] stayed on the tests/diagnostics path;
    /// the memory hot path uses this).
    #[inline]
    fn columns(&self, addrs: &[u32; LANES], mask: LaneMask) -> [LaneMask; MAX_BANKS] {
        let mut columns = [0 as LaneMask; MAX_BANKS];
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            columns[self.map.bank_of(addrs[lane]) as usize] |= 1 << lane;
        }
        columns
    }

    /// Cycles-only arbiter schedule: step the per-bank carry-chain
    /// arbiters exactly as [`Self::read_exact`] does, but without touching
    /// any bank data — the exact-mode timing charge for the replayer.
    fn schedule_cycles(&self, addrs: &[u32; LANES], mask: LaneMask) -> u32 {
        let mut state = self.columns(addrs, mask);
        let n_banks = self.map.banks() as usize;
        let mut cycles = 0u32;
        let mut pending = mask != 0;
        while pending {
            pending = false;
            cycles += 1;
            for v in state.iter_mut().take(n_banks) {
                if *v != 0 {
                    *v &= v.wrapping_sub(1); // grant (and clear) one lane
                    pending |= *v != 0;
                }
            }
        }
        cycles.max(1)
    }

    /// Exact path: step the per-bank carry-chain arbiters in lock-step,
    /// serving one lane per bank per cycle. The arbiter state machine is
    /// inlined (subtract-one + transition detect, exactly
    /// [`CarryChainArbiter::step`]) over a stack array of lane vectors.
    fn read_exact(&mut self, addrs: &[u32; LANES], mask: LaneMask) -> ReadOp {
        let mut state = self.columns(addrs, mask);
        let n_banks = self.map.banks() as usize;
        let mut data = [0u32; LANES];
        let mut cycles = 0u32;
        let mut pending = mask != 0;
        while pending {
            pending = false;
            cycles += 1;
            for (bank, v) in state.iter_mut().enumerate().take(n_banks) {
                if *v != 0 {
                    let grant = *v & !v.wrapping_sub(1); // 1→0 transition
                    *v &= v.wrapping_sub(1); // zero the re-assertions
                    pending |= *v != 0;
                    let lane = grant.trailing_zeros() as usize;
                    debug_assert_eq!(self.map.bank_of(addrs[lane]) as usize, bank);
                    data[lane] = self.banks[bank][self.map.row_of(addrs[lane]) as usize];
                }
            }
        }
        ReadOp { data, cycles: cycles.max(1) }
    }

    fn write_exact(&mut self, addrs: &[u32; LANES], data: &[u32; LANES], mask: LaneMask) -> u32 {
        let mut state = self.columns(addrs, mask);
        let n_banks = self.map.banks() as usize;
        let mut cycles = 0u32;
        let mut pending = mask != 0;
        while pending {
            pending = false;
            cycles += 1;
            for (bank, v) in state.iter_mut().enumerate().take(n_banks) {
                if *v != 0 {
                    let grant = *v & !v.wrapping_sub(1);
                    *v &= v.wrapping_sub(1);
                    pending |= *v != 0;
                    let lane = grant.trailing_zeros() as usize;
                    debug_assert_eq!(self.map.bank_of(addrs[lane]) as usize, bank);
                    let row = self.map.row_of(addrs[lane]) as usize;
                    self.banks[bank][row] = data[lane];
                }
            }
        }
        cycles.max(1)
    }
}

impl SharedMemory for BankedMemory {
    fn arch(&self) -> MemoryArchKind {
        MemoryArchKind::Banked { banks: self.map.banks(), mapping: self.mapping }
    }

    fn words(&self) -> usize {
        self.banks.len() * self.banks[0].len()
    }

    fn peek(&self, addr: u32) -> u32 {
        self.load(addr)
    }

    fn poke(&mut self, addr: u32, value: u32) {
        self.store(addr, value);
    }

    fn read_op(&mut self, addrs: &[u32; LANES], mask: LaneMask) -> ReadOp {
        match self.mode {
            TimingMode::Exact => self.read_exact(addrs, mask),
            TimingMode::Fast => {
                let cycles = max_conflicts(addrs, mask, &self.map).max(1);
                let mut data = [0u32; LANES];
                let mut m = mask;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    data[lane] = self.load(addrs[lane]);
                }
                ReadOp { data, cycles }
            }
        }
    }

    fn write_op(&mut self, addrs: &[u32; LANES], data: &[u32; LANES], mask: LaneMask) -> u32 {
        match self.mode {
            TimingMode::Exact => self.write_exact(addrs, data, mask),
            TimingMode::Fast => {
                let cycles = max_conflicts(addrs, mask, &self.map).max(1);
                // Lane order matches the arbiter's rightmost-first grant
                // order, so address collisions resolve identically: the
                // *highest* lane writes last and wins in both paths.
                let mut m = mask;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    self.store(addrs[lane], data[lane]);
                }
                cycles
            }
        }
    }

    fn op_cost(&self, _kind: OpKind, addrs: &[u32; LANES], mask: LaneMask) -> u32 {
        // Reads and writes cost the same on the banked path: the max
        // per-bank population count (true dual-port banks keep the two
        // streams independent, §III-A).
        match self.mode {
            TimingMode::Exact => self.schedule_cycles(addrs, mask),
            TimingMode::Fast => max_conflicts(addrs, mask, &self.map).max(1),
        }
    }

    fn overhead(&self, kind: OpKind) -> u32 {
        match kind {
            OpKind::Read => timing::banked_read_overhead(self.half_banked),
            OpKind::Write => timing::banked_write_overhead(self.half_banked),
        }
    }

    fn image(&self) -> Vec<u32> {
        (0..self.words() as u32).map(|a| self.load(a)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::FULL_MASK;
    use crate::util::proptest::check;

    fn seq_addrs(base: u32, stride: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = base + l as u32 * stride;
        }
        a
    }

    #[test]
    fn conflict_free_read_is_one_cycle() {
        let mut m = BankedMemory::new(1024, 16, BankMapping::Lsb);
        assert_eq!(m.read_op(&seq_addrs(0, 1), FULL_MASK).cycles, 1);
    }

    #[test]
    fn same_bank_stride_serializes() {
        let mut m = BankedMemory::new(1024, 16, BankMapping::Lsb);
        assert_eq!(m.read_op(&seq_addrs(0, 16), FULL_MASK).cycles, 16);
        // 8 banks: stride 8 also fully serializes.
        let mut m8 = BankedMemory::new(1024, 8, BankMapping::Lsb);
        assert_eq!(m8.read_op(&seq_addrs(0, 8), FULL_MASK).cycles, 16);
    }

    #[test]
    fn offset_mapping_spreads_stride4() {
        // Stride-4 word addresses: LSB map → 4 banks × 4 lanes = 4 cycles;
        // Offset map (shift 2) → 16 distinct banks = 1 cycle. This is the
        // complex-data case the paper designed the Offset map for.
        let mut lsb = BankedMemory::new(1024, 16, BankMapping::Lsb);
        let mut off = BankedMemory::new(1024, 16, BankMapping::offset());
        assert_eq!(lsb.read_op(&seq_addrs(0, 4), FULL_MASK).cycles, 4);
        assert_eq!(off.read_op(&seq_addrs(0, 4), FULL_MASK).cycles, 1);
    }

    #[test]
    fn data_roundtrip_all_mappings() {
        for mapping in [BankMapping::Lsb, BankMapping::offset()] {
            for banks in [2u32, 4, 8, 16, 32] {
                let mut m = BankedMemory::new(256, banks, mapping);
                let addrs = seq_addrs(32, 3);
                let mut data = [0u32; LANES];
                for (l, d) in data.iter_mut().enumerate() {
                    *d = 0xA000 + l as u32;
                }
                m.write_op(&addrs, &data, FULL_MASK);
                let r = m.read_op(&addrs, FULL_MASK);
                assert_eq!(r.data, data, "banks={banks} mapping={mapping:?}");
            }
        }
    }

    #[test]
    fn exact_equals_fast_property() {
        check("banked exact == fast (cycles and data)", 500, |rng| {
            let banks = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
            let mapping = if rng.chance(0.5) { BankMapping::Lsb } else { BankMapping::offset() };
            let mut exact = BankedMemory::new(4096, banks, mapping);
            let mut fast = BankedMemory::new(4096, banks, mapping).with_mode(TimingMode::Fast);
            // Seed both with the same image.
            for a in 0..4096u32 {
                let v = rng.next_u32();
                exact.poke(a, v);
                fast.poke(a, v);
            }
            for _ in 0..8 {
                let mut addrs = [0u32; LANES];
                for a in addrs.iter_mut() {
                    *a = rng.below(4096);
                }
                let mask = rng.next_u32() as u16;
                let is_read = rng.chance(0.5);
                if is_read {
                    let re = exact.read_op(&addrs, mask);
                    let rf = fast.read_op(&addrs, mask);
                    assert_eq!(re.cycles, rf.cycles);
                    assert_eq!(re.data, rf.data);
                } else {
                    let mut data = [0u32; LANES];
                    for d in data.iter_mut() {
                        *d = rng.next_u32();
                    }
                    let ce = exact.write_op(&addrs, &data, mask);
                    let cf = fast.write_op(&addrs, &data, mask);
                    assert_eq!(ce, cf);
                    assert_eq!(exact.image(), fast.image());
                }
            }
        });
    }

    #[test]
    fn op_cost_matches_executed_ops_property() {
        check("banked op_cost == read_op/write_op cycles", 500, |rng| {
            let banks = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
            let mapping = if rng.chance(0.5) { BankMapping::Lsb } else { BankMapping::offset() };
            let mode = if rng.chance(0.5) { TimingMode::Exact } else { TimingMode::Fast };
            let mut m = BankedMemory::new(4096, banks, mapping).with_mode(mode);
            let mut addrs = [0u32; LANES];
            for a in addrs.iter_mut() {
                *a = rng.below(4096);
            }
            let mask = rng.next_u32() as u16;
            assert_eq!(m.op_cost(OpKind::Read, &addrs, mask), m.read_op(&addrs, mask).cycles);
            let data = [0u32; LANES];
            assert_eq!(
                m.op_cost(OpKind::Write, &addrs, mask),
                m.write_op(&addrs, &data, mask)
            );
        });
    }

    #[test]
    fn masked_read_leaves_inactive_lanes_zero() {
        let mut m = BankedMemory::new(64, 4, BankMapping::Lsb);
        for a in 0..64 {
            m.poke(a, a + 1);
        }
        let r = m.read_op(&seq_addrs(0, 1), 0x0005); // lanes 0 and 2
        assert_eq!(r.data[0], 1);
        assert_eq!(r.data[2], 3);
        assert_eq!(r.data[1], 0);
    }

    #[test]
    fn overheads_match_paper_pipeline() {
        let m = BankedMemory::new(64, 16, BankMapping::Lsb);
        assert_eq!(m.overhead(OpKind::Read), 12); // 5 + 3 + 3 + 1
        assert_eq!(m.overhead(OpKind::Write), 5);
        let h = BankedMemory::new(64, 16, BankMapping::Lsb).with_half_banks();
        assert_eq!(h.overhead(OpKind::Read), 14);
    }

    #[test]
    fn image_matches_pokes() {
        let mut m = BankedMemory::new(128, 8, BankMapping::offset());
        for a in 0..128 {
            m.poke(a, a * 7);
        }
        let img = m.image();
        for a in 0..128usize {
            assert_eq!(img[a], a as u32 * 7);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn capacity_must_be_pow2() {
        BankedMemory::new(100, 4, BankMapping::Lsb);
    }

    #[test]
    fn extreme_offset_shift_clamped_to_capacity() {
        // banked32-offset8 on a 1 Ki-word memory: unclamped, address
        // 1023 would land on row 255 of a 32-row bank (out of bounds).
        // The capacity clamp keeps the map a bijection on [0, words).
        let mut m = BankedMemory::new(1024, 32, BankMapping::Offset { shift: 8 });
        for a in 0..1024u32 {
            m.poke(a, a ^ 0xABCD);
        }
        for a in 0..1024u32 {
            assert_eq!(m.peek(a), a ^ 0xABCD, "addr {a}");
        }
        let mut addrs = [0u32; LANES];
        for (l, v) in addrs.iter_mut().enumerate() {
            *v = 1023 - l as u32;
        }
        let r = m.read_op(&addrs, FULL_MASK);
        assert_eq!(r.data[0], 1023 ^ 0xABCD);
    }
}
