//! Closed-form operation-cost compilation — the `mem/` half of the
//! compiled-trace batch-replay pipeline (DESIGN.md §Replay).
//!
//! The per-operation cost of every architecture the crate can construct
//! is a pure function of quantities that do **not** depend on which
//! architecture is being charged:
//!
//! - **banked** (`B` banks, shift-family or XOR mapping): the maximum
//!   per-bank population count of the 16 lane addresses under that
//!   mapping ([`crate::mem::conflict`]);
//! - **multiport** (`R`R×`W`W, optional VB): `⌈active/ports⌉`, a pure
//!   function of the lane-population count.
//!
//! So a memory operation can be *compiled once* into a small vector of
//! per-family conflict maxima plus its active-lane count, after which
//! charging any architecture is an O(1) table lookup — no address
//! re-hashing, no `dyn SharedMemory` dispatch. Two facts keep the family
//! table tiny:
//!
//! 1. every shift-family map (`Lsb` is shift 0, `Offset { shift }` up to
//!    [`BankMapping::MAX_SHIFT`]) extracts `bank = (addr >> s) & (B-1)`,
//!    and the per-bank counts for `B` banks are a pairwise *fold* of the
//!    counts for `2B` banks (`count_B[i] = count_2B[i] + count_2B[i+B]`),
//!    so one 32-bucket histogram per shift yields the max for every bank
//!    count;
//! 2. the XOR map depends on `log2(B)` directly, so it gets one slot per
//!    bank count.
//!
//! That is [`FAMILY_COUNT`] = 5 bank sizes × 9 shifts + 5 XOR = 50 bytes
//! per operation. [`family_of`] maps an architecture descriptor (with the
//! same capacity clamp as [`BankMap::for_capacity`]) to its slot;
//! [`ArchCost`] bundles the slot with the §III-A overheads and the write
//! buffer depth — everything the replayer asks a [`SharedMemory`] for,
//! derived once per architecture. The property tests below pin
//! `ArchCost` byte-for-byte against the live `SharedMemory::op_cost`
//! charge path on random operations.

use super::arch::{MemoryArchKind, OpKind};
use super::mapping::BankMapping;
use super::{timing, LaneMask, LANES, MAX_BANKS};
use crate::util::bits::ceil_div;

/// Number of constructible bank counts (powers of two `2..=MAX_BANKS`).
pub const BANK_SIZES: usize = 5;

/// Number of shift-family positions (`0..=BankMapping::MAX_SHIFT`).
pub const SHIFT_COUNT: usize = BankMapping::MAX_SHIFT as usize + 1;

/// Conflict families compiled per operation: every (bank count, shift)
/// pair plus one XOR slot per bank count.
pub const FAMILY_COUNT: usize = BANK_SIZES * SHIFT_COUNT + BANK_SIZES;

/// Extra gather slot holding the operation's active-lane count, appended
/// after the conflict families so the lane-packed replayer resolves
/// *every* architecture's per-op cost with the same branch-free gather:
/// `cost_table[row[gather_slot]]` — banked lanes index a family slot,
/// multiport lanes index this one (DESIGN.md §Replay).
pub const ACTIVE_SLOT: usize = FAMILY_COUNT;

/// Bytes per compiled gather row: the conflict families plus the
/// active-lane count ([`ACTIVE_SLOT`]).
pub const GATHER_WIDTH: usize = FAMILY_COUNT + 1;

/// Entries in a per-lane cost table: gathered bytes are conflict maxima
/// or lane-population counts, both in `0..=LANES`.
pub const COST_TABLE_LEN: usize = LANES + 1;

/// Slot index of a bank count within a shift family (2→0 … 32→4).
#[inline]
fn bank_slot(banks: u32) -> usize {
    debug_assert!(banks.is_power_of_two() && (2..=MAX_BANKS as u32).contains(&banks));
    banks.trailing_zeros() as usize - 1
}

/// Family slot of `(banks, mapping)` on a memory of `mem_words` capacity.
///
/// Applies the same shift clamp as [`BankMap::for_capacity`]
/// (`shift ≤ log2(words) − log2(banks)`), so compiled lookups agree with
/// a live [`crate::mem::banked::BankedMemory`] built at that capacity.
///
/// [`BankMap::for_capacity`]: crate::mem::mapping::BankMap::for_capacity
pub fn family_of(banks: u32, mapping: BankMapping, mem_words: usize) -> usize {
    let slot = bank_slot(banks);
    match mapping {
        BankMapping::Xor => BANK_SIZES * SHIFT_COUNT + slot,
        m => {
            let bits = banks.trailing_zeros();
            let addr_bits = mem_words.trailing_zeros(); // capacity is a power of two
            let shift = m.shift().min(addr_bits.saturating_sub(bits));
            shift as usize * BANK_SIZES + slot
        }
    }
}

/// Compile one 16-lane operation: fill `out[f]` with the maximum
/// per-bank population count under family `f`, for every family.
///
/// One pass over the active lanes per shift builds a 32-bucket
/// histogram; folding it in halves yields the maxima for 16/8/4/2 banks
/// for free. The XOR families each take their own (cheap) lane pass.
pub fn compile_op(addrs: &[u32; LANES], mask: LaneMask, out: &mut [u8; FAMILY_COUNT]) {
    for s in 0..SHIFT_COUNT {
        let mut counts = [0u8; MAX_BANKS];
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            counts[((addrs[lane] >> s) & (MAX_BANKS as u32 - 1)) as usize] += 1;
        }
        let mut width = MAX_BANKS;
        for slot in (0..BANK_SIZES).rev() {
            out[s * BANK_SIZES + slot] = counts[..width].iter().copied().max().unwrap_or(0);
            width /= 2;
            for i in 0..width {
                counts[i] += counts[i + width];
            }
        }
    }
    for slot in 0..BANK_SIZES {
        let bits = slot as u32 + 1;
        let banks = 1u32 << bits;
        let mut counts = [0u8; MAX_BANKS];
        let mut max = 0u8;
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            let a = addrs[lane];
            let b = ((a ^ (a >> bits)) & (banks - 1)) as usize;
            counts[b] += 1;
            max = max.max(counts[b]);
        }
        out[BANK_SIZES * SHIFT_COUNT + slot] = max;
    }
}

/// The closed-form cost model of one architecture: everything the
/// timing replayer asks a [`SharedMemory`] for — per-operation cost,
/// §III-A overheads, write buffer depth — with the per-operation cost
/// reduced to a compiled-family lookup (banked) or a popcount division
/// (multiport). Built once per `(architecture, capacity)` by
/// [`ArchCost::new`]; the replay-diff harness pins it
/// `RunReport`-identical to the `dyn SharedMemory` charge path.
///
/// [`SharedMemory`]: crate::mem::arch::SharedMemory
#[derive(Debug, Clone, Copy)]
pub struct ArchCost {
    arch: MemoryArchKind,
    kind: CostKind,
    read_overhead: u32,
    write_overhead: u32,
    write_buffer_ops: u32,
}

#[derive(Debug, Clone, Copy)]
enum CostKind {
    /// Conflict-family slot in a compiled operation's family vector.
    Banked { family: usize },
    /// `⌈active/read_ports⌉` reads, `⌈active/write_div⌉` writes
    /// (`write_div` already folds the VB mode's effective 2W bandwidth).
    MultiPort { read_ports: u32, write_div: u32 },
}

impl ArchCost {
    /// Cost model for `arch` on a `mem_words`-word memory (the standard,
    /// non-half-banked configuration every sweep/replay path uses).
    pub fn new(arch: MemoryArchKind, mem_words: usize) -> Self {
        Self::with_half_banks(arch, mem_words, false)
    }

    /// As [`Self::new`], with the §IV-A half-bank latency knob.
    pub fn with_half_banks(arch: MemoryArchKind, mem_words: usize, half_banks: bool) -> Self {
        match arch {
            MemoryArchKind::Banked { banks, mapping } => Self {
                arch,
                kind: CostKind::Banked { family: family_of(banks, mapping, mem_words) },
                read_overhead: timing::banked_read_overhead(half_banks),
                write_overhead: timing::banked_write_overhead(half_banks),
                write_buffer_ops: timing::WRITE_BUFFER_OPS,
            },
            MemoryArchKind::MultiPort { read_ports, write_ports, vb } => Self {
                arch,
                kind: CostKind::MultiPort {
                    read_ports,
                    write_div: if vb { 2 } else { write_ports },
                },
                read_overhead: timing::MULTIPORT_OVERHEAD,
                write_overhead: timing::MULTIPORT_OVERHEAD,
                write_buffer_ops: timing::WRITE_BUFFER_OPS,
            },
        }
    }

    /// The architecture this model charges for.
    pub fn arch(&self) -> MemoryArchKind {
        self.arch
    }

    /// Fixed per-instruction overhead, as [`SharedMemory::overhead`].
    ///
    /// [`SharedMemory::overhead`]: crate::mem::arch::SharedMemory::overhead
    #[inline]
    pub fn overhead(&self, kind: OpKind) -> u32 {
        match kind {
            OpKind::Read => self.read_overhead,
            OpKind::Write => self.write_overhead,
        }
    }

    /// Write-controller buffer depth, as [`SharedMemory::write_buffer_ops`].
    ///
    /// [`SharedMemory::write_buffer_ops`]: crate::mem::arch::SharedMemory::write_buffer_ops
    #[inline]
    pub fn write_buffer_ops(&self) -> u32 {
        self.write_buffer_ops
    }

    /// Cycles one compiled operation occupies the memory pipeline.
    /// `conflicts` is the operation's [`FAMILY_COUNT`]-long family
    /// vector, `active` its lane-population count. Already floored at 1
    /// (the `op_cost(..).max(1)` charge the replayer applies).
    #[inline]
    pub fn op_cost(&self, kind: OpKind, conflicts: &[u8], active: u8) -> u32 {
        match self.kind {
            CostKind::Banked { family } => u32::from(conflicts[family]).max(1),
            CostKind::MultiPort { read_ports, write_div } => {
                let div = match kind {
                    OpKind::Read => read_ports,
                    OpKind::Write => write_div,
                };
                ceil_div(u32::from(active), div).max(1)
            }
        }
    }

    /// The gather-row slot this architecture's per-op cost is a function
    /// of: its conflict-family slot (banked) or [`ACTIVE_SLOT`]
    /// (multiport — cost depends only on the lane-population count).
    /// The same slot serves reads and writes; only the cost *table*
    /// differs by [`OpKind`]. Always `< GATHER_WIDTH`.
    #[inline]
    pub fn gather_slot(&self) -> usize {
        match self.kind {
            CostKind::Banked { family } => family,
            CostKind::MultiPort { .. } => ACTIVE_SLOT,
        }
    }

    /// Dense cost table over every gatherable byte value: for any
    /// compiled operation, `cost_table(kind)[row[gather_slot(kind)]]`
    /// equals [`Self::op_cost`] — the lane-packed replayer's whole
    /// per-op cost resolution, pre-resolved once per chunk setup.
    pub fn cost_table(&self, kind: OpKind) -> [u32; COST_TABLE_LEN] {
        let mut table = [0u32; COST_TABLE_LEN];
        for (v, slot) in table.iter_mut().enumerate() {
            *slot = match self.kind {
                CostKind::Banked { .. } => (v as u32).max(1),
                CostKind::MultiPort { read_ports, write_div } => {
                    let div = match kind {
                        OpKind::Read => read_ports,
                        OpKind::Write => write_div,
                    };
                    ceil_div(v as u32, div).max(1)
                }
            };
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::SharedMemory;
    use crate::mem::conflict::max_conflicts;
    use crate::mem::mapping::BankMap;
    use crate::util::proptest::check;
    use crate::util::XorShift64;

    fn random_op(rng: &mut XorShift64, addr_space: u32) -> ([u32; LANES], LaneMask) {
        let mut addrs = [0u32; LANES];
        for a in addrs.iter_mut() {
            *a = rng.below(addr_space);
        }
        (addrs, rng.next_u32() as LaneMask)
    }

    fn random_mapping(rng: &mut XorShift64) -> BankMapping {
        match rng.below(3) {
            0 => BankMapping::Lsb,
            1 => BankMapping::Offset { shift: rng.below(BankMapping::MAX_SHIFT + 1) },
            _ => BankMapping::Xor,
        }
    }

    #[test]
    fn family_table_shape() {
        assert_eq!(FAMILY_COUNT, 50);
        // Distinct valid (banks, mapping) descriptors get distinct slots
        // on a capacity where no clamp binds.
        let mut seen = std::collections::HashSet::new();
        for banks in [2u32, 4, 8, 16, 32] {
            for shift in 0..=BankMapping::MAX_SHIFT {
                let f = family_of(banks, BankMapping::Offset { shift }, 1 << 16);
                assert!(f < FAMILY_COUNT);
                assert!(seen.insert(f), "slot collision banks={banks} shift={shift}");
            }
            let f = family_of(banks, BankMapping::Xor, 1 << 16);
            assert!(f < FAMILY_COUNT && seen.insert(f));
            // Lsb aliases shift 0 — by construction, not by accident.
            assert_eq!(
                family_of(banks, BankMapping::Lsb, 1 << 16),
                family_of(banks, BankMapping::Offset { shift: 0 }, 1 << 16)
            );
        }
        assert_eq!(seen.len(), FAMILY_COUNT);
    }

    #[test]
    fn family_clamp_matches_bank_map() {
        // banked32-offset8 on 1 Ki words: BankMap clamps the shift to 5;
        // the family slot must land on the same effective shift.
        let f = family_of(32, BankMapping::Offset { shift: 8 }, 1024);
        assert_eq!(f, family_of(32, BankMapping::Offset { shift: 5 }, 1024));
        // No clamp at 64 Ki words.
        assert_ne!(
            family_of(32, BankMapping::Offset { shift: 8 }, 1 << 16),
            family_of(32, BankMapping::Offset { shift: 5 }, 1 << 16)
        );
    }

    #[test]
    fn compiled_families_match_live_conflict_maths_property() {
        check("compile_op == max_conflicts for every family", 500, |rng| {
            let words = 1usize << (8 + rng.below(9)); // 256 .. 64 Ki
            let (addrs, mask) = random_op(rng, words as u32);
            let mut out = [0u8; FAMILY_COUNT];
            compile_op(&addrs, mask, &mut out);
            for banks in [2u32, 4, 8, 16, 32] {
                for mapping in [
                    BankMapping::Lsb,
                    BankMapping::Offset { shift: rng.below(BankMapping::MAX_SHIFT + 1) },
                    BankMapping::Xor,
                ] {
                    let map = BankMap::for_capacity(banks, mapping, words);
                    assert_eq!(
                        u32::from(out[family_of(banks, mapping, words)]),
                        max_conflicts(&addrs, mask, &map),
                        "banks={banks} {mapping:?} words={words}"
                    );
                }
            }
        });
    }

    #[test]
    fn arch_cost_matches_shared_memory_property() {
        check("ArchCost == live SharedMemory charge path", 400, |rng| {
            let words = 1usize << (10 + rng.below(7)); // 1 Ki .. 64 Ki
            let arch = if rng.chance(0.5) {
                MemoryArchKind::Banked {
                    banks: [2u32, 4, 8, 16, 32][rng.below(5) as usize],
                    mapping: random_mapping(rng),
                }
            } else {
                let write_ports = 1 + rng.below(2);
                MemoryArchKind::MultiPort {
                    read_ports: 1 << rng.below(4),
                    write_ports,
                    vb: write_ports == 1 && rng.chance(0.3),
                }
            };
            let mem = arch.build(words);
            let cost = ArchCost::new(arch, words);
            assert_eq!(cost.arch(), arch);
            assert_eq!(cost.overhead(OpKind::Read), mem.overhead(OpKind::Read));
            assert_eq!(cost.overhead(OpKind::Write), mem.overhead(OpKind::Write));
            assert_eq!(cost.write_buffer_ops(), mem.write_buffer_ops());
            for _ in 0..4 {
                let (addrs, mask) = random_op(rng, words as u32);
                let mut out = [0u8; FAMILY_COUNT];
                compile_op(&addrs, mask, &mut out);
                let active = mask.count_ones() as u8;
                for kind in [OpKind::Read, OpKind::Write] {
                    assert_eq!(
                        cost.op_cost(kind, &out, active),
                        mem.op_cost(kind, &addrs, mask).max(1),
                        "{arch} {kind:?} mask={mask:#06x}"
                    );
                }
            }
        });
    }

    #[test]
    fn gather_table_matches_op_cost_property() {
        // The lane-packed replayer's whole cost resolution —
        // `cost_table(kind)[row[gather_slot()]]` — must equal the scalar
        // `op_cost` for every architecture kind on random operations.
        check("cost_table gather == op_cost", 300, |rng| {
            let words = 1usize << (10 + rng.below(7));
            let arch = if rng.chance(0.5) {
                MemoryArchKind::Banked {
                    banks: [2u32, 4, 8, 16, 32][rng.below(5) as usize],
                    mapping: random_mapping(rng),
                }
            } else {
                let write_ports = 1 + rng.below(2);
                MemoryArchKind::MultiPort {
                    read_ports: 1 << rng.below(4),
                    write_ports,
                    vb: write_ports == 1 && rng.chance(0.3),
                }
            };
            let cost = ArchCost::new(arch, words);
            let slot = cost.gather_slot();
            assert!(slot < GATHER_WIDTH);
            let (addrs, mask) = random_op(rng, words as u32);
            let mut row = [0u8; GATHER_WIDTH];
            let families = (&mut row[..FAMILY_COUNT]).try_into().unwrap();
            compile_op(&addrs, mask, families);
            row[ACTIVE_SLOT] = mask.count_ones() as u8;
            for kind in [OpKind::Read, OpKind::Write] {
                let table = cost.cost_table(kind);
                assert_eq!(
                    table[row[slot] as usize],
                    cost.op_cost(kind, &row[..FAMILY_COUNT], row[ACTIVE_SLOT]),
                    "{arch} {kind:?}"
                );
            }
        });
    }

    #[test]
    fn empty_mask_costs_one_cycle() {
        let (addrs, mask) = ([7u32; LANES], 0);
        let mut out = [0u8; FAMILY_COUNT];
        compile_op(&addrs, mask, &mut out);
        assert!(out.iter().all(|&c| c == 0));
        for arch in MemoryArchKind::table3_nine() {
            let cost = ArchCost::new(arch, 1 << 16);
            assert_eq!(cost.op_cost(OpKind::Read, &out, 0), 1, "{arch}");
            assert_eq!(cost.op_cost(OpKind::Write, &out, 0), 1, "{arch}");
        }
    }

    #[test]
    fn full_conflict_compiles_to_sixteen() {
        // All 16 lanes on one address: every family maxes at 16.
        let addrs = [32u32; LANES];
        let mut out = [0u8; FAMILY_COUNT];
        compile_op(&addrs, crate::mem::FULL_MASK, &mut out);
        assert!(out.iter().all(|&c| c == 16), "{out:?}");
    }
}
