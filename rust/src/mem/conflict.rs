//! Bank-conflict computation (paper §III-A, Fig. 2 maths).
//!
//! The lower bank-field bits of each of the 16 parallel addresses are
//! converted to one-hot vectors; each vector forms a row of a 2D matrix
//! indicating which bank that lane accesses. Each *column* of the matrix
//! feeds a population counter (a 5-bit result), and the 16 counts are
//! sorted (a max-reduce in our model) to find the number of clock cycles
//! the operation needs.
//!
//! This module is the L3 twin of the L1 Pallas kernel
//! `python/compile/kernels/conflict.py`; integration tests assert the two
//! agree on random batches through the PJRT-loaded artifact.

use super::mapping::BankMap;
use super::{LaneMask, LANES, MAX_BANKS};

/// The per-operation conflict analysis result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConflictInfo {
    /// `columns[b]` = lane mask of requests hitting bank `b` (the columns
    /// of the paper's one-hot matrix).
    pub columns: Vec<LaneMask>,
    /// Per-bank access counts (the population-counter outputs).
    pub counts: Vec<u32>,
    /// Maximum bank conflict — the cycles the operation occupies the
    /// memory (0 if no lane is active).
    pub max_conflicts: u32,
    /// Number of active lanes.
    pub active: u32,
}

/// Build the one-hot bank matrix and conflict counts for one operation
/// (up to 16 lane addresses, masked).
pub fn analyze(addrs: &[u32; LANES], mask: LaneMask, map: &BankMap) -> ConflictInfo {
    let banks = map.banks() as usize;
    let mut columns = vec![0u16; banks];
    for lane in 0..LANES {
        if mask >> lane & 1 == 1 {
            let b = map.bank_of(addrs[lane]) as usize;
            columns[b] |= 1 << lane;
        }
    }
    let counts: Vec<u32> = columns.iter().map(|c| c.count_ones()).collect();
    let max_conflicts = counts.iter().copied().max().unwrap_or(0);
    ConflictInfo {
        columns,
        counts,
        max_conflicts,
        active: mask.count_ones(),
    }
}

/// Fast path: only the maximum conflict count (the controller's circular
/// buffer stores exactly this value alongside the request info). Avoids
/// allocating the column vectors on the simulator hot path.
///
/// §Perf: per-bank counters live in a fixed stack array and the running
/// maximum is tracked *during* accumulation, so no second scan over the
/// banks is needed (a packed-u128 variant with a trailing scan measured
/// ~1.8× slower — EXPERIMENTS.md §Perf).
#[inline]
pub fn max_conflicts(addrs: &[u32; LANES], mask: LaneMask, map: &BankMap) -> u32 {
    let mut counts = [0u8; MAX_BANKS];
    let mut max = 0u8;
    let mut m = mask;
    while m != 0 {
        let lane = m.trailing_zeros() as usize;
        m &= m - 1;
        let b = map.bank_of(addrs[lane]) as usize;
        debug_assert!(b < MAX_BANKS);
        // SAFETY: bank_of masks to banks-1 < MAX_BANKS.
        let c = unsafe {
            let slot = counts.get_unchecked_mut(b);
            *slot += 1;
            *slot
        };
        if c > max {
            max = c;
        }
    }
    max as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::mapping::{BankMap, BankMapping};
    use crate::mem::FULL_MASK;
    use crate::util::proptest::check;

    /// The 8-lane / 8-bank example of the paper's Fig. 4: lanes access
    /// banks [0,1,1,3,1,3,4,5] (reading the figure left to right); bank 1
    /// has 3 accesses, bank 3 has 2, bank 2 none.
    #[test]
    fn paper_fig4_matrix() {
        let map = BankMap::new(8, BankMapping::Lsb);
        let mut addrs = [0u32; LANES];
        let banks_by_lane = [0u32, 1, 1, 3, 1, 3, 4, 5];
        for (lane, &b) in banks_by_lane.iter().enumerate() {
            addrs[lane] = 8 + b; // any address with these LSBs
        }
        let info = analyze(&addrs, 0x00FF, &map);
        assert_eq!(info.counts[0], 1);
        assert_eq!(info.counts[1], 3);
        assert_eq!(info.counts[2], 0);
        assert_eq!(info.counts[3], 2);
        assert_eq!(info.max_conflicts, 3);
        // Bank 1 is accessed by lanes 1, 2 and 4 (the paper's worked row).
        assert_eq!(info.columns[1], 0b0001_0110);
        // "If there is any bank with more than one access, then there must
        // be a bank with zero accesses."
        assert!(info.counts.iter().any(|&c| c == 0));
    }

    #[test]
    fn no_conflicts_when_addresses_consecutive() {
        let map = BankMap::new(16, BankMapping::Lsb);
        let mut addrs = [0u32; LANES];
        for (l, a) in addrs.iter_mut().enumerate() {
            *a = 100 + l as u32;
        }
        let info = analyze(&addrs, FULL_MASK, &map);
        assert_eq!(info.max_conflicts, 1);
        assert!(info.counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn maximal_conflict_all_lanes_one_bank() {
        let map = BankMap::new(16, BankMapping::Lsb);
        let addrs = [32u32; LANES]; // all the same address
        let info = analyze(&addrs, FULL_MASK, &map);
        assert_eq!(info.max_conflicts, 16);
        assert_eq!(info.counts[0], 16);
    }

    #[test]
    fn empty_mask_is_zero_cycles() {
        let map = BankMap::new(4, BankMapping::Lsb);
        let info = analyze(&[0; LANES], 0, &map);
        assert_eq!(info.max_conflicts, 0);
        assert_eq!(info.active, 0);
    }

    #[test]
    fn stride_pattern_conflicts() {
        // Stride-16 addresses with 16 LSB banks: every lane hits bank 0.
        let map = BankMap::new(16, BankMapping::Lsb);
        let mut addrs = [0u32; LANES];
        for (l, a) in addrs.iter_mut().enumerate() {
            *a = (l as u32) * 16;
        }
        assert_eq!(analyze(&addrs, FULL_MASK, &map).max_conflicts, 16);
        // The Offset map (shift 2) spreads the same stride over 4 banks.
        let map_off = BankMap::new(16, BankMapping::offset());
        assert_eq!(analyze(&addrs, FULL_MASK, &map_off).max_conflicts, 4);
    }

    #[test]
    fn counts_sum_equals_active_property() {
        check("conflict counts sum to active lanes", 1000, |rng| {
            let banks = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
            let mapping = if rng.chance(0.5) { BankMapping::Lsb } else { BankMapping::offset() };
            let map = BankMap::new(banks, mapping);
            let mut addrs = [0u32; LANES];
            for a in addrs.iter_mut() {
                *a = rng.below(1 << 16);
            }
            let mask = rng.next_u32() as u16;
            let info = analyze(&addrs, mask, &map);
            assert_eq!(info.counts.iter().sum::<u32>(), mask.count_ones());
            assert!(info.max_conflicts <= 16);
            // Union of columns == mask, columns disjoint.
            let mut seen = 0u16;
            for &c in &info.columns {
                assert_eq!(seen & c, 0, "columns must be disjoint");
                seen |= c;
            }
            assert_eq!(seen, mask);
        });
    }

    #[test]
    fn fast_max_matches_full_analysis_property() {
        check("max_conflicts fast path == analyze", 1000, |rng| {
            let banks = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
            let mapping = if rng.chance(0.5) { BankMapping::Lsb } else { BankMapping::offset() };
            let map = BankMap::new(banks, mapping);
            let mut addrs = [0u32; LANES];
            for a in addrs.iter_mut() {
                *a = rng.next_u32() & 0xFFFFF;
            }
            let mask = rng.next_u32() as u16;
            assert_eq!(
                max_conflicts(&addrs, mask, &map),
                analyze(&addrs, mask, &map).max_conflicts
            );
        });
    }
}
