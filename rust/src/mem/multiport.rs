//! Replicated multi-port shared memory (paper §II, §V).
//!
//! A 4R memory keeps four identical copies of the data so four lanes can
//! read per cycle; writes go to every copy through 1 or 2 write ports.
//! Access time is deterministic — the property that made the original eGPU
//! simple and fast — at the cost of 4× the M20K footprint:
//!
//! - read operation: `⌈active/4⌉` cycles,
//! - write operation: `⌈active/W⌉` cycles (W = 1 or 2),
//! - `4R-1W-VB`: an additional instruction mode makes the four copies act
//!   as four separate memories for a dataset; a write operation then costs
//!   the *maximum* number of lanes landing in any one of the four address
//!   regions (write bandwidth improves "on average to that of the 4R-2W
//!   memory, but at the higher system speed").

use super::arch::{MemoryArchKind, OpKind, ReadOp, SharedMemory};
use super::{timing, LaneMask, LANES};
use crate::util::bits::ceil_div;

/// Multi-port memory model. Storage is held once (the replicas are
/// identical by construction; replication is an *area* cost, modelled in
/// [`crate::area`]).
#[derive(Debug, Clone)]
pub struct MultiPortMemory {
    data: Vec<u32>,
    read_ports: u32,
    write_ports: u32,
    vb: bool,
}

impl MultiPortMemory {
    pub fn new(words: usize, read_ports: u32, write_ports: u32, vb: bool) -> Self {
        assert!(words.is_power_of_two(), "capacity must be a power of two");
        assert!(read_ports > 0 && write_ports > 0);
        Self { data: vec![0; words], read_ports, write_ports, vb }
    }

    /// VB write cost. The paper keeps the VM instruction's mechanics out
    /// of scope and states only its *effect*: "improve write bandwidth on
    /// average to that of the 4R-2W memory, but at the higher system
    /// speed of 771 MHz" — i.e. an effective two writes per cycle into
    /// the dataset's four split memories.
    fn vb_write_cycles(&self, mask: LaneMask) -> u32 {
        ceil_div(mask.count_ones(), 2).max(1)
    }
}

impl SharedMemory for MultiPortMemory {
    fn arch(&self) -> MemoryArchKind {
        MemoryArchKind::MultiPort {
            read_ports: self.read_ports,
            write_ports: self.write_ports,
            vb: self.vb,
        }
    }

    fn words(&self) -> usize {
        self.data.len()
    }

    fn peek(&self, addr: u32) -> u32 {
        self.data[addr as usize]
    }

    fn poke(&mut self, addr: u32, value: u32) {
        self.data[addr as usize] = value;
    }

    fn read_op(&mut self, addrs: &[u32; LANES], mask: LaneMask) -> ReadOp {
        let mut data = [0u32; LANES];
        let mut active = 0;
        for lane in 0..LANES {
            if mask >> lane & 1 == 1 {
                data[lane] = self.data[addrs[lane] as usize];
                active += 1;
            }
        }
        ReadOp {
            data,
            cycles: ceil_div(active, self.read_ports).max(1),
        }
    }

    fn write_op(&mut self, addrs: &[u32; LANES], data: &[u32; LANES], mask: LaneMask) -> u32 {
        let cycles = if self.vb {
            self.vb_write_cycles(mask)
        } else {
            ceil_div(mask.count_ones(), self.write_ports).max(1)
        };
        // Lanes commit in index order: on address collisions the highest
        // lane wins, matching sequential port arbitration.
        for lane in 0..LANES {
            if mask >> lane & 1 == 1 {
                self.data[addrs[lane] as usize] = data[lane];
            }
        }
        cycles
    }

    fn op_cost(&self, kind: OpKind, _addrs: &[u32; LANES], mask: LaneMask) -> u32 {
        // Deterministic access — the multiport memory's defining property:
        // cost depends only on the active-lane count, never on addresses.
        match kind {
            OpKind::Read => ceil_div(mask.count_ones(), self.read_ports).max(1),
            OpKind::Write => {
                if self.vb {
                    self.vb_write_cycles(mask)
                } else {
                    ceil_div(mask.count_ones(), self.write_ports).max(1)
                }
            }
        }
    }

    fn overhead(&self, _kind: OpKind) -> u32 {
        timing::MULTIPORT_OVERHEAD
    }

    fn image(&self) -> Vec<u32> {
        self.data.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::FULL_MASK;

    fn full_addrs(base: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = base + l as u32;
        }
        a
    }

    #[test]
    fn read_cost_is_ceil_active_over_ports() {
        let mut m = MultiPortMemory::new(1024, 4, 1, false);
        assert_eq!(m.read_op(&full_addrs(0), FULL_MASK).cycles, 4);
        assert_eq!(m.read_op(&full_addrs(0), 0x000F).cycles, 1);
        assert_eq!(m.read_op(&full_addrs(0), 0x001F).cycles, 2);
        assert_eq!(m.read_op(&full_addrs(0), 0x0001).cycles, 1);
        // An all-masked op still occupies one issue slot.
        assert_eq!(m.read_op(&full_addrs(0), 0).cycles, 1);
    }

    #[test]
    fn write_cost_1w_vs_2w() {
        let mut m1 = MultiPortMemory::new(1024, 4, 1, false);
        let mut m2 = MultiPortMemory::new(1024, 4, 2, false);
        let d = [7u32; LANES];
        assert_eq!(m1.write_op(&full_addrs(0), &d, FULL_MASK), 16);
        assert_eq!(m2.write_op(&full_addrs(0), &d, FULL_MASK), 8);
        assert_eq!(m1.write_op(&full_addrs(0), &d, 0x0003), 2);
        assert_eq!(m2.write_op(&full_addrs(0), &d, 0x0003), 1);
    }

    #[test]
    fn data_roundtrip() {
        let mut m = MultiPortMemory::new(64, 4, 1, false);
        let addrs = full_addrs(16);
        let mut data = [0u32; LANES];
        for (l, d) in data.iter_mut().enumerate() {
            *d = 100 + l as u32;
        }
        m.write_op(&addrs, &data, FULL_MASK);
        let r = m.read_op(&addrs, FULL_MASK);
        assert_eq!(r.data, data);
        assert_eq!(m.peek(16), 100);
    }

    #[test]
    fn masked_lanes_do_not_write() {
        let mut m = MultiPortMemory::new(64, 4, 1, false);
        m.poke(5, 999);
        let mut addrs = [0u32; LANES];
        addrs[3] = 5;
        let data = [1u32; LANES];
        m.write_op(&addrs, &data, 0x0001); // only lane 0 writes (to addr 0)
        assert_eq!(m.peek(5), 999);
        assert_eq!(m.peek(0), 1);
    }

    #[test]
    fn vb_writes_at_2w_bandwidth() {
        // §V: VB's effect is 4R-2W-level write bandwidth at 771 MHz.
        let mut m = MultiPortMemory::new(1024, 4, 1, true);
        let d = [0u32; LANES];
        assert_eq!(m.write_op(&full_addrs(0), &d, FULL_MASK), 8);
        assert_eq!(m.write_op(&full_addrs(0), &d, 0x0007), 2);
        assert_eq!(m.arch().fmax_mhz(), 771.0);
    }

    #[test]
    fn vb_reads_unchanged() {
        let mut m = MultiPortMemory::new(1024, 4, 1, true);
        assert_eq!(m.read_op(&full_addrs(0), FULL_MASK).cycles, 4);
    }

    #[test]
    fn op_cost_matches_executed_ops() {
        for (r, w, vb) in [(4u32, 1u32, false), (4, 2, false), (4, 1, true)] {
            let mut m = MultiPortMemory::new(1024, r, w, vb);
            let d = [0u32; LANES];
            for mask in [0u16, 1, 0x000F, 0x00FF, FULL_MASK] {
                assert_eq!(
                    m.op_cost(OpKind::Read, &full_addrs(0), mask),
                    m.read_op(&full_addrs(0), mask).cycles,
                    "read {r}R{w}W vb={vb} mask={mask:#x}"
                );
                assert_eq!(
                    m.op_cost(OpKind::Write, &full_addrs(0), mask),
                    m.write_op(&full_addrs(0), &d, mask),
                    "write {r}R{w}W vb={vb} mask={mask:#x}"
                );
            }
        }
    }

    #[test]
    fn zero_overhead_matches_paper_accounting() {
        let m = MultiPortMemory::new(64, 4, 1, false);
        assert_eq!(m.overhead(OpKind::Read), 0);
        assert_eq!(m.overhead(OpKind::Write), 0);
    }

    #[test]
    fn write_collision_last_lane_wins() {
        let mut m = MultiPortMemory::new(64, 4, 1, false);
        let addrs = [9u32; LANES];
        let mut data = [0u32; LANES];
        for (l, d) in data.iter_mut().enumerate() {
            *d = l as u32;
        }
        m.write_op(&addrs, &data, FULL_MASK);
        assert_eq!(m.peek(9), 15);
    }
}
