//! Pipeline latency constants, straight from the paper's prose.
//!
//! These are *per-instruction* overheads: the banked access controllers
//! pre-compute conflicts through a popcount + sort-network pipeline
//! (5 cycles, §III-A), the memory banks are 3-cycle (§III-B), and the
//! one-hot address/data muxes are 3-stage pipelines (§III-B). Because a
//! memory instruction streams hundreds of operations, this initial latency
//! "only has a minor impact on the performance".

/// Cycles between the controller receiving a read/write instruction and
/// issuing the first operation (the Fig. 2 sort-network pipeline depth).
pub const CTRL_INIT_LATENCY: u32 = 5;

/// M20K memory-bank read latency.
pub const BANK_LATENCY: u32 = 3;

/// One-hot address/data mux pipeline depth (input and output sides each).
pub const MUX_PIPELINE: u32 = 3;

/// Writeback into the SP register file.
pub const WRITEBACK_LATENCY: u32 = 1;

/// Extra bank latency when a bank is split into two half-banks (the
/// 448 KB node-locked configuration of §IV-A: "we had to split each
/// memory bank into two, with the upper address bit selecting a half
/// bank. The two additional latency cycles introduced had no material
/// impact").
pub const HALF_BANK_EXTRA_LATENCY: u32 = 2;

/// Fixed tail latency of a banked *read* instruction: conflict
/// pre-computation + bank + output mux + writeback.
pub const fn banked_read_overhead(half_banked: bool) -> u32 {
    CTRL_INIT_LATENCY
        + BANK_LATENCY
        + MUX_PIPELINE
        + WRITEBACK_LATENCY
        + if half_banked { HALF_BANK_EXTRA_LATENCY } else { 0 }
}

/// Fixed overhead of a banked *write* instruction (input side only —
/// no output mux or writeback on the write path, §III-B).
pub const fn banked_write_overhead(half_banked: bool) -> u32 {
    CTRL_INIT_LATENCY + if half_banked { HALF_BANK_EXTRA_LATENCY } else { 0 }
}

/// The multiport R/W control block is a thin fixed-function pipeline; the
/// paper's multiport cycle counts are exactly `ops × ⌈lanes/ports⌉`, i.e.
/// zero per-instruction overhead in its accounting. We keep that.
pub const MULTIPORT_OVERHEAD: u32 = 0;

/// Write-controller circular buffer depth, in operations. The paper's
/// write controllers carry 19–20 M20Ks of request buffering (Table I);
/// one M20K holds 512 × 40 bits, and a buffered operation is 16 lanes of
/// address+data spread across the M20K group — 512 operations of depth.
pub const WRITE_BUFFER_OPS: u32 = 512;

/// Clock frequencies (MHz). The processor closes timing at 771 MHz
/// (DSP-limited in FP32 mode) for every memory except 4R-2W, whose M20Ks
/// run in the slower emulated true-dual-port mode (600 MHz, §IV-A).
pub const FMAX_MHZ: f64 = 771.0;
/// 4R-2W emulated-TDP clock.
pub const FMAX_4R2W_MHZ: f64 = 600.0;
/// Unrestricted critical path outside the DSPs (§IV).
pub const FMAX_UNRESTRICTED_MHZ: f64 = 775.0;
/// Tightly-constrained (node-locked 448 KB) compile (§IV-A).
pub const FMAX_CONSTRAINED_MHZ: f64 = 738.0;
/// Deep-pipeline ceiling for banked configurations — the 950 MHz the
/// re-pipelined SIMT processor of arXiv:2504.07538 reaches on the same
/// device family. The system-level Fmax model
/// ([`crate::explore::system`]) scales wider-than-16-lane banked points
/// from the paper's 771 MHz toward this ceiling; multiport points keep
/// their mux-limited paper clocks.
pub const DEEP_FMAX_MHZ: f64 = 950.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_overhead_components() {
        assert_eq!(banked_read_overhead(false), 12);
        assert_eq!(banked_read_overhead(true), 14);
    }

    #[test]
    fn write_overhead_components() {
        assert_eq!(banked_write_overhead(false), 5);
        assert_eq!(banked_write_overhead(true), 7);
    }

    #[test]
    fn paper_frequencies() {
        assert_eq!(FMAX_MHZ, 771.0);
        assert_eq!(FMAX_4R2W_MHZ, 600.0);
        assert!(FMAX_UNRESTRICTED_MHZ > FMAX_MHZ);
    }

    #[test]
    fn deep_pipeline_ceiling_above_paper_clock() {
        assert_eq!(DEEP_FMAX_MHZ, 950.0);
        assert!(DEEP_FMAX_MHZ > FMAX_UNRESTRICTED_MHZ);
    }
}
