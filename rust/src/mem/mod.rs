//! Shared-memory architectures for the soft SIMT processor (paper §III).
//!
//! Nine architectures sit behind the [`arch::SharedMemory`] trait:
//!
//! | Name            | Kind                                    | Fmax    |
//! |-----------------|-----------------------------------------|---------|
//! | `4R-1W`         | multi-port, 4 read / 1 write            | 771 MHz |
//! | `4R-2W`         | multi-port, 4 read / 2 write (emulated TDP M20Ks) | 600 MHz |
//! | `4R-1W-VB`      | multi-port with the 4-region virtual-bank write mode | 771 MHz |
//! | `16/8/4 Banks`  | banked, LSB mapping                     | 771 MHz |
//! | `16/8/4 Banks Offset` | banked, shifted (bit `[shift+b-1:shift]`) mapping | 771 MHz |
//!
//! Beyond the paper's nine, every descriptor the design-space explorer
//! ([`crate::explore`]) enumerates is constructible: 2–32 banks, any
//! `Offset { shift }` field position, XOR interleaving, and the
//! {1,2,4,8}R × {1,2}W multiport family ([`MemoryArchKind::is_valid`]).
//!
//! The banked path is modelled at the level the paper describes it:
//! one-hot bank matrices and population counts ([`conflict`]), per-bank
//! carry-chain arbiters simulated bit-exactly ([`arbiter`]), access
//! controllers with a 5-cycle conflict pre-computation pipeline and
//! circular operation buffers ([`controller`]), 3-cycle memory banks and
//! 3-stage one-hot output muxes ([`timing`]).

pub mod arbiter;
pub mod arch;
pub mod banked;
pub mod compiled;
pub mod conflict;
pub mod controller;
pub mod mapping;
pub mod multiport;
pub mod timing;

pub use arch::{MemoryArchKind, OpKind, SharedMemory};
pub use compiled::ArchCost;
pub use mapping::BankMapping;

/// Number of SIMT lanes (SPs) — fixed at 16 in the paper's processor; the
/// memory *operation* width.
pub const LANES: usize = 16;

/// Largest constructible bank count. The paper benchmarks 4/8/16 banks;
/// the design-space explorer ([`crate::explore`]) sweeps 2–32, so the
/// banked hot paths size their stack arrays to this bound.
pub const MAX_BANKS: usize = 32;

/// A lane-request mask: bit `l` set means lane `l` participates in the
/// operation.
pub type LaneMask = u16;

/// All 16 lanes active.
pub const FULL_MASK: LaneMask = 0xFFFF;
