//! The [`SharedMemory`] trait and the nine paper architectures.

use super::banked::BankedMemory;
use super::mapping::BankMapping;
use super::multiport::MultiPortMemory;
use super::{timing, LaneMask, LANES};
use std::fmt;

/// One-line statement of everything [`MemoryArchKind::parse`] accepts
/// beyond the paper's nine labels. Stated exactly once: the CLI `list`
/// output and the service layer's unknown-memory error both quote this
/// string, so the hint can never drift from the grammar.
pub const PARSE_GRAMMAR: &str = "banked 2-32 banks x {lsb, offsetN, xor} mappings, multiport \
     {1,2,4,8}R x {1,2}W [-VB]; labels like 'banked8-offset3', '2r-1w' parse anywhere a memory \
     is accepted; system points are 'p{procs}x{lanes}:{memory}@{capacity}' like \
     'p4x32:banked16@64' (processors x lanes sharing one memory at a KB capacity)";

/// Whether an operation reads or writes (controllers differ, §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Read,
    Write,
}

/// A 16-lane read operation's result: per-lane data plus the cycles the
/// operation occupies the memory pipeline.
#[derive(Debug, Clone)]
pub struct ReadOp {
    pub data: [u32; LANES],
    pub cycles: u32,
}

/// One of the paper's shared-memory architectures, behind a common
/// interface: functional word storage plus the *operation cost model*
/// (cycles a 16-lane operation occupies the issue pipeline).
pub trait SharedMemory: Send {
    /// Architecture descriptor.
    fn arch(&self) -> MemoryArchKind;

    /// Capacity in 32-bit words.
    fn words(&self) -> usize;

    /// Functional single-word access (test/debug/harness use).
    fn peek(&self, addr: u32) -> u32;
    /// Functional single-word write (memory image loading).
    fn poke(&mut self, addr: u32, value: u32);

    /// Execute one 16-lane read operation: returns lane data and cycles.
    fn read_op(&mut self, addrs: &[u32; LANES], mask: LaneMask) -> ReadOp;

    /// Execute one 16-lane write operation: returns cycles.
    fn write_op(&mut self, addrs: &[u32; LANES], data: &[u32; LANES], mask: LaneMask) -> u32;

    /// Timing-only cost of one 16-lane operation (the cycles it occupies
    /// the memory pipeline), computed without moving any data — the
    /// charge path the timing replayer ([`crate::sim::replay`]) drives.
    ///
    /// Contract: must equal the `cycles` that [`Self::read_op`] /
    /// [`Self::write_op`] would report for the same addresses and mask
    /// (the replay-parity integration tests pin this across every
    /// architecture).
    fn op_cost(&self, kind: OpKind, addrs: &[u32; LANES], mask: LaneMask) -> u32;

    /// Fixed per-instruction overhead (initial latency + drain) by kind.
    fn overhead(&self, kind: OpKind) -> u32;

    /// Write-controller buffer depth in operations.
    fn write_buffer_ops(&self) -> u32 {
        timing::WRITE_BUFFER_OPS
    }

    /// Clock frequency this memory closes timing at.
    fn fmax_mhz(&self) -> f64 {
        self.arch().fmax_mhz()
    }

    /// Snapshot of the full memory image (validation against golden
    /// models).
    fn image(&self) -> Vec<u32>;
}

/// Descriptor for each of the paper's nine memory architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemoryArchKind {
    /// Replicated multi-port memory: `read_ports` read replicas and
    /// `write_ports` write ports. `vb` enables the 4R-1W-VB mode (§V),
    /// where an additional instruction lets the four replicas act as four
    /// separate memories for a dataset, raising write bandwidth.
    MultiPort {
        read_ports: u32,
        write_ports: u32,
        vb: bool,
    },
    /// Banked memory with `banks` banks and the given index mapping.
    Banked { banks: u32, mapping: BankMapping },
}

impl MemoryArchKind {
    /// `4R-1W`.
    pub fn mp_4r1w() -> Self {
        Self::MultiPort { read_ports: 4, write_ports: 1, vb: false }
    }
    /// `4R-2W`.
    pub fn mp_4r2w() -> Self {
        Self::MultiPort { read_ports: 4, write_ports: 2, vb: false }
    }
    /// `4R-1W-VB`.
    pub fn mp_4r1w_vb() -> Self {
        Self::MultiPort { read_ports: 4, write_ports: 1, vb: true }
    }
    /// Banked with LSB mapping.
    pub fn banked(banks: u32) -> Self {
        Self::Banked { banks, mapping: BankMapping::Lsb }
    }
    /// Banked with the paper's Offset (shift-2) mapping.
    pub fn banked_offset(banks: u32) -> Self {
        Self::Banked { banks, mapping: BankMapping::offset() }
    }

    /// Banked with XOR mapping.
    pub fn banked_xor(banks: u32) -> Self {
        Self::Banked { banks, mapping: BankMapping::Xor }
    }

    /// Whether this descriptor is constructible: power-of-two bank counts
    /// within 2..=[`crate::mem::MAX_BANKS`] and a valid mapping on the
    /// banked side; 1/2/4/8 read ports, 1 or 2 write ports, and VB only
    /// in its 1W form on the multiport side. `parse` accepts exactly the
    /// valid descriptors, and the design-space explorer enumerates within
    /// them.
    pub fn is_valid(&self) -> bool {
        match *self {
            Self::MultiPort { read_ports, write_ports, vb } => {
                matches!(read_ports, 1 | 2 | 4 | 8)
                    && matches!(write_ports, 1 | 2)
                    && (!vb || write_ports == 1)
            }
            Self::Banked { banks, mapping } => {
                banks.is_power_of_two()
                    && (2..=crate::mem::MAX_BANKS as u32).contains(&banks)
                    && mapping.is_valid()
            }
        }
    }

    /// The eight architectures of Table II (transpose study; no VB).
    pub fn table2_eight() -> Vec<Self> {
        vec![
            Self::mp_4r1w(),
            Self::mp_4r2w(),
            Self::banked(16),
            Self::banked_offset(16),
            Self::banked(8),
            Self::banked_offset(8),
            Self::banked(4),
            Self::banked_offset(4),
        ]
    }

    /// The nine architectures of Table III (FFT study).
    pub fn table3_nine() -> Vec<Self> {
        vec![
            Self::mp_4r1w(),
            Self::mp_4r2w(),
            Self::mp_4r1w_vb(),
            Self::banked(16),
            Self::banked_offset(16),
            Self::banked(8),
            Self::banked_offset(8),
            Self::banked(4),
            Self::banked_offset(4),
        ]
    }

    /// Short label matching the paper's column headers.
    pub fn label(&self) -> String {
        match *self {
            Self::MultiPort { read_ports, write_ports, vb } => {
                if vb {
                    format!("{read_ports}R-{write_ports}W-VB")
                } else {
                    format!("{read_ports}R-{write_ports}W")
                }
            }
            Self::Banked { banks, mapping } => {
                let m = mapping.label();
                if m.is_empty() {
                    format!("{banks} Banks")
                } else {
                    format!("{banks} Banks {m}")
                }
            }
        }
    }

    /// Parse a label back to a kind (CLI and explorer use): accepts the
    /// paper-style labels case-insensitively and shorthands (`banked16`,
    /// `banked16-offset`, `banked8-offset3`, `4r1w`, `2r-1w`, `4r1w-vb`).
    /// Round-trips `label()` for **every** valid descriptor — pinned by
    /// the `parse_label_roundtrip_property` test. The full accepted
    /// grammar is summarized in [`PARSE_GRAMMAR`].
    pub fn parse(s: &str) -> Option<Self> {
        let t = s.to_ascii_lowercase().replace([' ', '_'], "-");
        if let Some(mp) = Self::parse_multiport(&t) {
            return Some(mp);
        }
        let (body, mapping) = if let Some(b) = t.strip_suffix("-xor") {
            (b, BankMapping::Xor)
        } else if let Some(at) = t.rfind("-offset") {
            let digits = &t[at + "-offset".len()..];
            let shift = if digits.is_empty() { 2 } else { digits.parse().ok()? };
            (&t[..at], BankMapping::Offset { shift })
        } else {
            (t.as_str(), BankMapping::Lsb)
        };
        let banks: u32 = body
            .strip_prefix("banked")
            .or_else(|| body.strip_suffix("-banks"))?
            .trim_matches('-')
            .parse()
            .ok()?;
        let kind = Self::Banked { banks, mapping };
        kind.is_valid().then_some(kind)
    }

    /// Compact dash-joined label (`banked16-offset2`, `banked8-xor`,
    /// `2r-1w-vb`) — the form system-point labels embed, since the
    /// paper-style label's spaces would collide with the `pPxL:mem@KB`
    /// grammar. Always round-trips through [`Self::parse`] (the Offset
    /// shift is emitted explicitly, so `Offset { shift: 2 }` prints as
    /// `-offset2` rather than the bare `-offset` shorthand).
    pub fn compact_label(&self) -> String {
        match *self {
            Self::MultiPort { read_ports, write_ports, vb } => {
                if vb {
                    format!("{read_ports}r-{write_ports}w-vb")
                } else {
                    format!("{read_ports}r-{write_ports}w")
                }
            }
            Self::Banked { banks, mapping } => match mapping {
                BankMapping::Lsb => format!("banked{banks}"),
                BankMapping::Offset { shift } => format!("banked{banks}-offset{shift}"),
                BankMapping::Xor => format!("banked{banks}-xor"),
            },
        }
    }

    /// Parse the multiport family: `{R}r-{W}w` / `{R}r{W}w`, with an
    /// optional `vb` / `-vb` suffix.
    fn parse_multiport(t: &str) -> Option<Self> {
        let (body, vb) = match t.strip_suffix("vb") {
            Some(b) => (b.trim_end_matches('-'), true),
            None => (t, false),
        };
        let r_end = body.find(|c: char| !c.is_ascii_digit())?;
        let read_ports: u32 = body[..r_end].parse().ok()?;
        let rest = body[r_end..].strip_prefix('r')?;
        let rest = rest.strip_prefix('-').unwrap_or(rest);
        let w_end = rest.find(|c: char| !c.is_ascii_digit())?;
        let write_ports: u32 = rest[..w_end].parse().ok()?;
        if &rest[w_end..] != "w" {
            return None;
        }
        let kind = Self::MultiPort { read_ports, write_ports, vb };
        kind.is_valid().then_some(kind)
    }

    /// Clock frequency (MHz) the processor closes timing at with this
    /// memory (§IV-A; 4R-2W runs its M20Ks in emulated TDP mode).
    pub fn fmax_mhz(&self) -> f64 {
        match *self {
            Self::MultiPort { write_ports: 2, .. } => timing::FMAX_4R2W_MHZ,
            _ => timing::FMAX_MHZ,
        }
    }

    /// Build the memory with `words` 32-bit words of capacity.
    pub fn build(&self, words: usize) -> Box<dyn SharedMemory> {
        match *self {
            Self::MultiPort { read_ports, write_ports, vb } => {
                Box::new(MultiPortMemory::new(words, read_ports, write_ports, vb))
            }
            Self::Banked { banks, mapping } => Box::new(BankedMemory::new(words, banks, mapping)),
        }
    }

    /// True for banked kinds.
    pub fn is_banked(&self) -> bool {
        matches!(self, Self::Banked { .. })
    }
}

impl fmt::Display for MemoryArchKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_paper_headers() {
        assert_eq!(MemoryArchKind::mp_4r1w().label(), "4R-1W");
        assert_eq!(MemoryArchKind::mp_4r2w().label(), "4R-2W");
        assert_eq!(MemoryArchKind::mp_4r1w_vb().label(), "4R-1W-VB");
        assert_eq!(MemoryArchKind::banked(16).label(), "16 Banks");
        assert_eq!(MemoryArchKind::banked_offset(8).label(), "8 Banks Offset");
    }

    #[test]
    fn parse_roundtrips_labels() {
        for k in MemoryArchKind::table3_nine() {
            assert_eq!(MemoryArchKind::parse(&k.label()), Some(k), "label {}", k.label());
        }
    }

    #[test]
    fn parse_shorthands() {
        assert_eq!(MemoryArchKind::parse("banked16"), Some(MemoryArchKind::banked(16)));
        assert_eq!(
            MemoryArchKind::parse("banked4-offset"),
            Some(MemoryArchKind::banked_offset(4))
        );
        assert_eq!(
            MemoryArchKind::parse("banked8-xor"),
            Some(MemoryArchKind::Banked { banks: 8, mapping: BankMapping::Xor })
        );
        assert_eq!(MemoryArchKind::parse("4r1w"), Some(MemoryArchKind::mp_4r1w()));
        assert_eq!(MemoryArchKind::parse("banked5"), None);
        assert_eq!(MemoryArchKind::parse("weird"), None);
    }

    #[test]
    fn parse_generalized_variants() {
        assert_eq!(MemoryArchKind::parse("2 Banks"), Some(MemoryArchKind::banked(2)));
        assert_eq!(
            MemoryArchKind::parse("32 Banks Offset3"),
            Some(MemoryArchKind::Banked { banks: 32, mapping: BankMapping::Offset { shift: 3 } })
        );
        assert_eq!(
            MemoryArchKind::parse("2r-1w"),
            Some(MemoryArchKind::MultiPort { read_ports: 2, write_ports: 1, vb: false })
        );
        assert_eq!(
            MemoryArchKind::parse("8R-1W"),
            Some(MemoryArchKind::MultiPort { read_ports: 8, write_ports: 1, vb: false })
        );
        // Invalid descriptors stay rejected.
        assert_eq!(MemoryArchKind::parse("3r-1w"), None);
        assert_eq!(MemoryArchKind::parse("4r-3w"), None);
        assert_eq!(MemoryArchKind::parse("4r-2w-vb"), None);
        assert_eq!(MemoryArchKind::parse("banked64"), None);
        assert_eq!(MemoryArchKind::parse("banked1"), None);
        assert_eq!(MemoryArchKind::parse("16-banks-offset9"), None);
    }

    #[test]
    fn parse_label_roundtrip_property() {
        use crate::util::proptest::check;
        // Every *constructible* descriptor's label parses back to itself —
        // the contract the explorer's generated labels rely on.
        check("label/parse round-trip", 2000, |rng| {
            let kind = if rng.chance(0.5) {
                let banks = 2u32 << rng.below(5); // 2, 4, 8, 16, 32
                let mapping = match rng.below(3) {
                    0 => BankMapping::Lsb,
                    1 => BankMapping::Offset { shift: rng.below(BankMapping::MAX_SHIFT + 1) },
                    _ => BankMapping::Xor,
                };
                MemoryArchKind::Banked { banks, mapping }
            } else {
                let read_ports = 1u32 << rng.below(4); // 1, 2, 4, 8
                let write_ports = 1 + rng.below(2); // 1, 2
                let vb = write_ports == 1 && rng.chance(0.3);
                MemoryArchKind::MultiPort { read_ports, write_ports, vb }
            };
            assert!(kind.is_valid(), "{kind:?}");
            assert_eq!(
                MemoryArchKind::parse(&kind.label()),
                Some(kind),
                "label '{}' must round-trip",
                kind.label()
            );
        });
    }

    #[test]
    fn compact_labels_roundtrip_and_stay_dashed() {
        assert_eq!(MemoryArchKind::banked(16).compact_label(), "banked16");
        assert_eq!(MemoryArchKind::banked_offset(8).compact_label(), "banked8-offset2");
        assert_eq!(MemoryArchKind::banked_xor(4).compact_label(), "banked4-xor");
        assert_eq!(MemoryArchKind::mp_4r2w().compact_label(), "4r-2w");
        assert_eq!(MemoryArchKind::mp_4r1w_vb().compact_label(), "4r-1w-vb");
        for k in MemoryArchKind::table3_nine() {
            let c = k.compact_label();
            assert!(!c.contains(' '), "compact label '{c}' must be space-free");
            assert_eq!(MemoryArchKind::parse(&c), Some(k), "compact '{c}'");
        }
    }

    #[test]
    fn xor_label_roundtrip() {
        let k = MemoryArchKind::Banked { banks: 16, mapping: BankMapping::Xor };
        assert_eq!(k.label(), "16 Banks XOR");
        assert_eq!(MemoryArchKind::parse(&k.label()), Some(k));
    }

    #[test]
    fn table_sets_sizes() {
        assert_eq!(MemoryArchKind::table2_eight().len(), 8);
        assert_eq!(MemoryArchKind::table3_nine().len(), 9);
    }

    #[test]
    fn fmax_rules() {
        assert_eq!(MemoryArchKind::mp_4r2w().fmax_mhz(), 600.0);
        assert_eq!(MemoryArchKind::mp_4r1w().fmax_mhz(), 771.0);
        assert_eq!(MemoryArchKind::banked(16).fmax_mhz(), 771.0);
    }
}
