//! Instruction-level access-controller model (paper §III-A, Fig. 2).
//!
//! The read and write access controllers sit between fetch/decode and the
//! shared memory. Their timing contract, from the paper:
//!
//! - a **read** instruction pauses fetch/decode: its operations stream
//!   into the memory spaced by their conflict counts, plus a fixed
//!   5-cycle conflict-pre-computation latency and the bank/mux/writeback
//!   tail;
//! - a **blocking write** (`st`) holds the pipeline until the write
//!   controller has drained every operation;
//! - a **non-blocking write** (`stnb`) lets the pipeline continue after
//!   issue (one operation enters the circular buffer per cycle); the
//!   controller drains the buffer in the background. When the circular
//!   buffer fills, issue stalls — the eGPU's "write bandwidth was found
//!   to be a significant performance bottleneck";
//! - reads and writes use separate controllers and the M20K banks are
//!   true-dual-port (1R+1W), so the two streams do not contend for
//!   cycles. Read-after-write consistency across the two streams is the
//!   *programmer's* contract: use `st` when "the same data will likely be
//!   used immediately" (e.g. between FFT passes).

use std::collections::VecDeque;

/// State of the write access controller across instructions.
#[derive(Debug, Clone)]
pub struct WritePipeline {
    /// Absolute cycle at which the last buffered operation completes.
    busy_until: u64,
    /// Completion times of buffered (not yet drained) operations.
    in_flight: VecDeque<u64>,
    /// Circular-buffer capacity in operations.
    depth: u32,
}

impl WritePipeline {
    pub fn new(depth: u32) -> Self {
        assert!(depth > 0);
        Self { busy_until: 0, in_flight: VecDeque::new(), depth }
    }

    /// Absolute cycle when all currently buffered writes have drained.
    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }

    /// Number of operations still in the buffer at time `now`.
    pub fn occupancy(&mut self, now: u64) -> u32 {
        while matches!(self.in_flight.front(), Some(&t) if t <= now) {
            self.in_flight.pop_front();
        }
        self.in_flight.len() as u32
    }

    /// Issue one *non-blocking* write operation at `now`.
    ///
    /// `op_cycles` is the memory cost of the operation (max bank conflict
    /// or ⌈active/W⌉); `overhead` is the per-instruction controller
    /// latency, charged when the buffer is empty (pipeline refill).
    ///
    /// Returns the cycle at which the *issue* completes (the SP pipeline
    /// may continue from there) — normally `now + 1`, later if the buffer
    /// was full.
    pub fn issue_nonblocking(&mut self, now: u64, op_cycles: u32, overhead: u32) -> u64 {
        let mut now = now;
        // Buffer-full stall: wait for the oldest operation to drain.
        if self.occupancy(now) >= self.depth {
            now = self.in_flight.pop_front().expect("depth > 0");
        }
        // Service starts after the previous buffered op and the controller
        // latency (only visible when the controller pipeline is empty).
        let service_start = (now + overhead as u64).max(self.busy_until);
        let completion = service_start + op_cycles as u64;
        self.busy_until = completion;
        self.in_flight.push_back(completion);
        now + 1
    }

    /// Wait for every buffered write to complete. A *blocking* write
    /// instruction is `issue_nonblocking` for each operation followed by
    /// `drain` — the pipeline is held until the controller empties.
    pub fn drain(&mut self, now: u64) -> u64 {
        let t = now.max(self.busy_until);
        self.in_flight.clear();
        t
    }
}

/// Structure-of-arrays write pipeline: `N` independent [`WritePipeline`]s
/// advanced in lockstep by the lane-packed batch replayer
/// ([`crate::sim::packed`]), one lane per candidate architecture.
///
/// Semantically each lane is exactly a `WritePipeline` (the property
/// tests below pin this lane for lane); the representation differs:
///
/// - per-lane `VecDeque`s become one flat ring-buffer arena. Completion
///   times are pushed in non-decreasing order (`completion =
///   max(now+overhead, busy_until) + cost ≥ busy_until` = the previous
///   completion), and occupancy never exceeds `depth` (a full buffer
///   pops before pushing), so a fixed `depth`-slot ring per lane
///   suffices and no lane ever reallocates mid-walk;
/// - the hot per-lane scalars (`busy_until`, head, length) live in
///   `[_; N]` arrays so the packed store loop touches contiguous state.
///
/// Suspend/resume: [`Self::checkpoint`] captures the full drain state
/// (busy clock + in-flight completion times per lane) and
/// [`Self::restore`] rebuilds it — the write-pipeline half of a replay
/// segment seam (DESIGN.md §Replay).
#[derive(Debug, Clone)]
pub struct LaneWritePipes<const N: usize> {
    busy_until: [u64; N],
    /// Ring head slot per lane (`0..depth`).
    head: [u32; N],
    /// Buffered (not yet drained) operations per lane (`0..=depth`).
    len: [u32; N],
    depth: [u32; N],
    /// Lane-major ring arena: lane `l` owns `ring[l*stride .. l*stride+depth[l]]`.
    ring: Vec<u64>,
    stride: usize,
}

/// The in-flight write state a [`LaneWritePipes`] carries across a
/// replay-segment seam: per-lane busy clock + buffered completion times
/// (oldest first).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipesCheckpoint<const N: usize> {
    pub busy_until: [u64; N],
    pub in_flight: Vec<Vec<u64>>,
}

impl<const N: usize> LaneWritePipes<N> {
    /// One pipeline per lane, with per-lane circular-buffer depths.
    pub fn new(depths: [u32; N]) -> Self {
        assert!(depths.iter().all(|&d| d > 0));
        let stride = depths.iter().copied().max().unwrap_or(1) as usize;
        Self {
            busy_until: [0; N],
            head: [0; N],
            len: [0; N],
            depth: depths,
            ring: vec![0; stride * N],
            stride,
        }
    }

    /// Absolute cycle when all of `lane`'s buffered writes have drained.
    #[inline]
    pub fn busy_until(&self, lane: usize) -> u64 {
        self.busy_until[lane]
    }

    #[inline]
    fn pop_front(&mut self, lane: usize) -> u64 {
        debug_assert!(self.len[lane] > 0);
        let t = self.ring[lane * self.stride + self.head[lane] as usize];
        self.head[lane] = (self.head[lane] + 1) % self.depth[lane];
        self.len[lane] -= 1;
        t
    }

    /// Issue one non-blocking write on `lane` — identical contract to
    /// [`WritePipeline::issue_nonblocking`].
    #[inline]
    pub fn issue(&mut self, lane: usize, now: u64, op_cycles: u32, overhead: u32) -> u64 {
        let mut now = now;
        // Lazy-pop drained operations; monotone completion times mean the
        // front is always the oldest.
        while self.len[lane] > 0
            && self.ring[lane * self.stride + self.head[lane] as usize] <= now
        {
            let _ = self.pop_front(lane);
        }
        // Buffer-full stall: wait for the oldest operation to drain.
        if self.len[lane] >= self.depth[lane] {
            now = self.pop_front(lane);
        }
        let service_start = (now + overhead as u64).max(self.busy_until[lane]);
        let completion = service_start + op_cycles as u64;
        self.busy_until[lane] = completion;
        let tail = (self.head[lane] + self.len[lane]) % self.depth[lane];
        self.ring[lane * self.stride + tail as usize] = completion;
        self.len[lane] += 1;
        now + 1
    }

    /// Wait out `lane`'s buffer — identical contract to
    /// [`WritePipeline::drain`].
    #[inline]
    pub fn drain(&mut self, lane: usize, now: u64) -> u64 {
        let t = now.max(self.busy_until[lane]);
        self.len[lane] = 0;
        t
    }

    /// Number of operations still buffered on `lane` at time `now`.
    pub fn occupancy(&mut self, lane: usize, now: u64) -> u32 {
        while self.len[lane] > 0
            && self.ring[lane * self.stride + self.head[lane] as usize] <= now
        {
            let _ = self.pop_front(lane);
        }
        self.len[lane]
    }

    /// Snapshot the drain state for a segment seam.
    pub fn checkpoint(&self) -> PipesCheckpoint<N> {
        let mut in_flight = Vec::with_capacity(N);
        for lane in 0..N {
            let mut q = Vec::with_capacity(self.len[lane] as usize);
            for i in 0..self.len[lane] {
                let slot = (self.head[lane] + i) % self.depth[lane];
                q.push(self.ring[lane * self.stride + slot as usize]);
            }
            in_flight.push(q);
        }
        PipesCheckpoint { busy_until: self.busy_until, in_flight }
    }

    /// Rebuild the drain state captured by [`Self::checkpoint`].
    pub fn restore(&mut self, cp: &PipesCheckpoint<N>) {
        assert_eq!(cp.in_flight.len(), N);
        self.busy_until = cp.busy_until;
        for lane in 0..N {
            let q = &cp.in_flight[lane];
            assert!(q.len() <= self.depth[lane] as usize);
            self.head[lane] = 0;
            self.len[lane] = q.len() as u32;
            self.ring[lane * self.stride..lane * self.stride + q.len()].copy_from_slice(q);
        }
    }
}

/// Timing summary of one memory instruction, accumulated by the machine.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InstrTiming {
    /// Cycles attributed to this instruction (overhead + op spacing).
    pub attributed: u64,
    /// Ideal cycles (one per operation — the 100%-bandwidth floor used by
    /// the paper's Bank Eff. columns).
    pub ideal: u64,
    /// Number of operations issued.
    pub ops: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nonblocking_issue_advances_one_cycle() {
        let mut w = WritePipeline::new(512);
        let t = w.issue_nonblocking(10, 16, 5);
        assert_eq!(t, 11, "pipeline continues after one issue cycle");
        assert_eq!(w.busy_until(), 10 + 5 + 16);
    }

    #[test]
    fn consecutive_ops_queue_behind_each_other() {
        let mut w = WritePipeline::new(512);
        let mut now = 0;
        for _ in 0..4 {
            now = w.issue_nonblocking(now, 16, 5);
        }
        assert_eq!(now, 4);
        // Service is serialized: 5 (overhead) + 4 × 16.
        assert_eq!(w.busy_until(), 5 + 64);
    }

    #[test]
    fn buffer_full_stalls_issue() {
        let mut w = WritePipeline::new(2);
        let mut now = 0;
        now = w.issue_nonblocking(now, 100, 0); // completes at 100
        now = w.issue_nonblocking(now, 100, 0); // completes at 200
        assert_eq!(now, 2);
        // Third op: buffer holds 2 → wait for the first to drain (t=100).
        now = w.issue_nonblocking(now, 100, 0);
        assert_eq!(now, 101);
        assert_eq!(w.busy_until(), 300);
    }

    #[test]
    fn drain_waits_for_all() {
        let mut w = WritePipeline::new(512);
        let now = w.issue_nonblocking(0, 50, 5);
        assert_eq!(w.drain(now), 55);
        assert_eq!(w.occupancy(55), 0);
        // Draining when already idle is a no-op.
        assert_eq!(w.drain(200), 200);
    }

    #[test]
    fn occupancy_decays_over_time() {
        let mut w = WritePipeline::new(512);
        let mut now = 0;
        for _ in 0..3 {
            now = w.issue_nonblocking(now, 10, 0);
        }
        assert_eq!(w.occupancy(now), 3);
        assert_eq!(w.occupancy(10), 2);
        assert_eq!(w.occupancy(30), 0);
        let _ = now;
    }

    #[test]
    fn lane_pipes_identical_to_scalar_pipeline_property() {
        // Each LaneWritePipes lane must be bit-identical to its own
        // WritePipeline under a random interleaving of issues and drains
        // — including deep buffer-full stalls (tiny depths) and the
        // cost-1 saturation boundary.
        use crate::util::proptest::check;
        check("LaneWritePipes lane == WritePipeline", 200, |rng| {
            const N: usize = 4;
            let mut depths = [0u32; N];
            for d in depths.iter_mut() {
                *d = 1 + rng.below(6); // 1..=6: stalls engage quickly
            }
            let mut lanes = LaneWritePipes::<N>::new(depths);
            let mut scalars: Vec<WritePipeline> =
                depths.iter().map(|&d| WritePipeline::new(d)).collect();
            let mut now = [0u64; N];
            for _ in 0..60 {
                if rng.chance(0.15) {
                    for l in 0..N {
                        let a = lanes.drain(l, now[l]);
                        let b = scalars[l].drain(now[l]);
                        assert_eq!(a, b, "drain lane {l}");
                        now[l] = a;
                    }
                } else {
                    let cost = rng.below(20);
                    let overhead = rng.below(6);
                    for l in 0..N {
                        let a = lanes.issue(l, now[l], cost, overhead);
                        let b = scalars[l].issue_nonblocking(now[l], cost, overhead);
                        assert_eq!(a, b, "issue lane {l} cost {cost} ovh {overhead}");
                        assert_eq!(lanes.busy_until(l), scalars[l].busy_until(), "lane {l}");
                        now[l] = a;
                    }
                }
            }
            for l in 0..N {
                assert_eq!(lanes.occupancy(l, now[l]), scalars[l].occupancy(now[l]));
            }
        });
    }

    #[test]
    fn lane_pipes_checkpoint_round_trips() {
        // checkpoint → fresh pipes → restore must continue bit-identically
        // to the uninterrupted pipeline — the segment-seam contract.
        const N: usize = 2;
        let depths = [3u32, 512];
        let mut a = LaneWritePipes::<N>::new(depths);
        let mut now = [0u64; N];
        for i in 0..10 {
            for l in 0..N {
                now[l] = a.issue(l, now[l], 10 + i, 2);
            }
        }
        let cp = a.checkpoint();
        let mut b = LaneWritePipes::<N>::new(depths);
        b.restore(&cp);
        assert_eq!(b.checkpoint(), cp, "restore reproduces the checkpoint");
        for i in 0..10 {
            for l in 0..N {
                let ta = a.issue(l, now[l], 5 + i, 2);
                let tb = b.issue(l, now[l], 5 + i, 2);
                assert_eq!(ta, tb, "post-seam issue lane {l}");
                assert_eq!(a.busy_until(l), b.busy_until(l));
                now[l] = ta;
            }
        }
        for l in 0..N {
            assert_eq!(a.drain(l, now[l]), b.drain(l, now[l]));
        }
    }

    #[test]
    fn fast_writes_drain_as_issued() {
        // Cost-1 ops drain as fast as they issue: the buffer never backs
        // up and busy_until trails issue by the overhead + 1.
        let mut w = WritePipeline::new(8);
        let mut now = 0;
        for _ in 0..100 {
            now = w.issue_nonblocking(now, 1, 0);
        }
        assert_eq!(now, 100);
        assert!(w.busy_until() <= 101);
    }
}
