//! Instruction-level access-controller model (paper §III-A, Fig. 2).
//!
//! The read and write access controllers sit between fetch/decode and the
//! shared memory. Their timing contract, from the paper:
//!
//! - a **read** instruction pauses fetch/decode: its operations stream
//!   into the memory spaced by their conflict counts, plus a fixed
//!   5-cycle conflict-pre-computation latency and the bank/mux/writeback
//!   tail;
//! - a **blocking write** (`st`) holds the pipeline until the write
//!   controller has drained every operation;
//! - a **non-blocking write** (`stnb`) lets the pipeline continue after
//!   issue (one operation enters the circular buffer per cycle); the
//!   controller drains the buffer in the background. When the circular
//!   buffer fills, issue stalls — the eGPU's "write bandwidth was found
//!   to be a significant performance bottleneck";
//! - reads and writes use separate controllers and the M20K banks are
//!   true-dual-port (1R+1W), so the two streams do not contend for
//!   cycles. Read-after-write consistency across the two streams is the
//!   *programmer's* contract: use `st` when "the same data will likely be
//!   used immediately" (e.g. between FFT passes).

use std::collections::VecDeque;

/// State of the write access controller across instructions.
#[derive(Debug, Clone)]
pub struct WritePipeline {
    /// Absolute cycle at which the last buffered operation completes.
    busy_until: u64,
    /// Completion times of buffered (not yet drained) operations.
    in_flight: VecDeque<u64>,
    /// Circular-buffer capacity in operations.
    depth: u32,
}

impl WritePipeline {
    pub fn new(depth: u32) -> Self {
        assert!(depth > 0);
        Self { busy_until: 0, in_flight: VecDeque::new(), depth }
    }

    /// Absolute cycle when all currently buffered writes have drained.
    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }

    /// Number of operations still in the buffer at time `now`.
    pub fn occupancy(&mut self, now: u64) -> u32 {
        while matches!(self.in_flight.front(), Some(&t) if t <= now) {
            self.in_flight.pop_front();
        }
        self.in_flight.len() as u32
    }

    /// Issue one *non-blocking* write operation at `now`.
    ///
    /// `op_cycles` is the memory cost of the operation (max bank conflict
    /// or ⌈active/W⌉); `overhead` is the per-instruction controller
    /// latency, charged when the buffer is empty (pipeline refill).
    ///
    /// Returns the cycle at which the *issue* completes (the SP pipeline
    /// may continue from there) — normally `now + 1`, later if the buffer
    /// was full.
    pub fn issue_nonblocking(&mut self, now: u64, op_cycles: u32, overhead: u32) -> u64 {
        let mut now = now;
        // Buffer-full stall: wait for the oldest operation to drain.
        if self.occupancy(now) >= self.depth {
            now = self.in_flight.pop_front().expect("depth > 0");
        }
        // Service starts after the previous buffered op and the controller
        // latency (only visible when the controller pipeline is empty).
        let service_start = (now + overhead as u64).max(self.busy_until);
        let completion = service_start + op_cycles as u64;
        self.busy_until = completion;
        self.in_flight.push_back(completion);
        now + 1
    }

    /// Wait for every buffered write to complete. A *blocking* write
    /// instruction is `issue_nonblocking` for each operation followed by
    /// `drain` — the pipeline is held until the controller empties.
    pub fn drain(&mut self, now: u64) -> u64 {
        let t = now.max(self.busy_until);
        self.in_flight.clear();
        t
    }
}

/// Timing summary of one memory instruction, accumulated by the machine.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InstrTiming {
    /// Cycles attributed to this instruction (overhead + op spacing).
    pub attributed: u64,
    /// Ideal cycles (one per operation — the 100%-bandwidth floor used by
    /// the paper's Bank Eff. columns).
    pub ideal: u64,
    /// Number of operations issued.
    pub ops: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nonblocking_issue_advances_one_cycle() {
        let mut w = WritePipeline::new(512);
        let t = w.issue_nonblocking(10, 16, 5);
        assert_eq!(t, 11, "pipeline continues after one issue cycle");
        assert_eq!(w.busy_until(), 10 + 5 + 16);
    }

    #[test]
    fn consecutive_ops_queue_behind_each_other() {
        let mut w = WritePipeline::new(512);
        let mut now = 0;
        for _ in 0..4 {
            now = w.issue_nonblocking(now, 16, 5);
        }
        assert_eq!(now, 4);
        // Service is serialized: 5 (overhead) + 4 × 16.
        assert_eq!(w.busy_until(), 5 + 64);
    }

    #[test]
    fn buffer_full_stalls_issue() {
        let mut w = WritePipeline::new(2);
        let mut now = 0;
        now = w.issue_nonblocking(now, 100, 0); // completes at 100
        now = w.issue_nonblocking(now, 100, 0); // completes at 200
        assert_eq!(now, 2);
        // Third op: buffer holds 2 → wait for the first to drain (t=100).
        now = w.issue_nonblocking(now, 100, 0);
        assert_eq!(now, 101);
        assert_eq!(w.busy_until(), 300);
    }

    #[test]
    fn drain_waits_for_all() {
        let mut w = WritePipeline::new(512);
        let now = w.issue_nonblocking(0, 50, 5);
        assert_eq!(w.drain(now), 55);
        assert_eq!(w.occupancy(55), 0);
        // Draining when already idle is a no-op.
        assert_eq!(w.drain(200), 200);
    }

    #[test]
    fn occupancy_decays_over_time() {
        let mut w = WritePipeline::new(512);
        let mut now = 0;
        for _ in 0..3 {
            now = w.issue_nonblocking(now, 10, 0);
        }
        assert_eq!(w.occupancy(now), 3);
        assert_eq!(w.occupancy(10), 2);
        assert_eq!(w.occupancy(30), 0);
        let _ = now;
    }

    #[test]
    fn fast_writes_drain_as_issued() {
        // Cost-1 ops drain as fast as they issue: the buffer never backs
        // up and busy_until trails issue by the overhead + 1.
        let mut w = WritePipeline::new(8);
        let mut now = 0;
        for _ in 0..100 {
            now = w.issue_nonblocking(now, 1, 0);
        }
        assert_eq!(now, 100);
        assert!(w.busy_until() <= 101);
    }
}
