//! Benchmark coordination: jobs, the parallel sweep runner, golden
//! validation and the table/figure renderers that regenerate the paper's
//! evaluation (Tables I–III, Fig. 9).

pub mod advisor;
pub mod job;
pub mod report;
pub mod runner;
pub mod validate;

pub use job::{BenchJob, BenchResult, TraceCache};
pub use runner::SweepRunner;
