//! Parallel sweep runner: a worker pool over benchmark jobs, with a
//! trace-cached fast path that executes each program once, compiles the
//! trace once, and batch-replays every architecture from single trace
//! walks (DESIGN.md §Replay).
//!
//! tokio is unavailable offline, so this is a plain `std::thread` pool
//! with a shared work queue — ample for a simulator sweep, and the
//! results arrive in deterministic (input) order regardless of worker
//! scheduling.

use super::job::{BenchJob, BenchResult, TraceCache, TraceKey};
use crate::mem::arch::MemoryArchKind;
use crate::obs::{Counter, MetricsRegistry};
use crate::sim::compiled::CompiledTrace;
use crate::sim::config::MachineConfig;
use crate::sim::machine::SimError;
use crate::sim::packed::{
    replay_many_packed_counted, LaneChunk, ReplayTally, ARCH_LANES, SEGMENT_INSTRS,
};
use crate::sim::stats::RunReport;
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Wall time of a cached sweep's three phases, for span attribution
/// (the engine maps capture → `Phase::Execute`, compile →
/// `Phase::Compile`, replay → `Phase::Replay`).
#[derive(Debug, Default, Clone, Copy)]
pub struct SweepPhases {
    pub capture: Duration,
    pub compile: Duration,
    pub replay: Duration,
}

/// Thread-pool sweep runner.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    workers: usize,
    /// Session metrics (attached by the owning engine). `None` — the
    /// standalone wiring paths — counts nothing and costs nothing.
    metrics: Option<Arc<MetricsRegistry>>,
}

impl Default for SweepRunner {
    fn default() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(16))
    }
}

impl SweepRunner {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { workers, metrics: None }
    }

    /// This runner, reporting into the session's metrics registry.
    /// Counters are flushed once per batch-replay driver call from
    /// local tallies — the packed walk itself never touches an atomic.
    pub fn with_metrics(mut self, metrics: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// The attached session registry, if any.
    pub fn metrics(&self) -> Option<&Arc<MetricsRegistry>> {
        self.metrics.as_ref()
    }

    /// Flush a packed walk's local tally — plus the replayed runs'
    /// write-pipeline stall cycles — into the registry, if attached.
    fn flush_packed<'a>(
        &self,
        tally: &ReplayTally,
        reports: impl Iterator<Item = &'a Result<RunReport, SimError>>,
    ) {
        let Some(m) = &self.metrics else { return };
        m.add(Counter::ReplayPackedInvocations, tally.invocations);
        m.add(Counter::ReplayPackedChunks, tally.chunks);
        m.add(Counter::ReplayPackedLanesUsed, tally.lanes_used);
        m.add(Counter::ReplayPackedLaneSlots, tally.lane_slots);
        m.add(Counter::ReplayWavefrontSegments, tally.segments);
        let stalls: u64 =
            reports.filter_map(|r| r.as_ref().ok()).map(|r| r.stats.wbuf_stall_cycles).sum();
        m.add(Counter::ReplayWbufStallCycles, stalls);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every item on the worker pool; results come back in
    /// input order regardless of scheduling.
    fn parallel_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(items.len().max(1)) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let result = f(&items[i]);
                    slots.lock().unwrap()[i] = Some(result);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|s| s.expect("every slot filled"))
            .collect()
    }

    /// Run `f` over arbitrary items on the worker pool (public for the
    /// design-space explorer, whose units of work are architecture
    /// replays rather than [`BenchJob`]s); results come back in input
    /// order regardless of scheduling.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        self.parallel_map(items, f)
    }

    /// Charge one compiled trace against a whole candidate slate on the
    /// worker pool, as a **segment wavefront** over lane-packed chunks
    /// (DESIGN.md §Replay): candidates pack into [`ARCH_LANES`]-wide
    /// [`LaneChunk`]s, and the pool advances every chunk through the
    /// same [`SEGMENT_INSTRS`]-instruction segment before any chunk
    /// moves to the next — the segment's compiled rows stay hot across
    /// workers, and chunks whose candidates have all blown `max_cycles`
    /// are swap-compacted out of the active set at each barrier.
    ///
    /// Results in `archs` order, `RunReport`-bit-identical to the scalar
    /// [`crate::sim::compiled::replay_many`] (and hence to the reference
    /// per-architecture replay) — segmentation stitches exactly
    /// (`rust/tests/replay_diff.rs`).
    pub fn replay_many_parallel(
        &self,
        trace: &CompiledTrace,
        archs: &[MemoryArchKind],
        max_cycles: u64,
    ) -> Vec<Result<RunReport, SimError>> {
        if archs.is_empty() {
            return Vec::new();
        }
        let chunks: Vec<Mutex<LaneChunk>> = archs
            .chunks(ARCH_LANES)
            .map(|c| Mutex::new(LaneChunk::new(trace, c)))
            .collect();
        // Work tally, accumulated in the sequential driver between
        // barriers (never inside the walk) and flushed once at the end.
        let mut tally = ReplayTally {
            invocations: 1,
            chunks: chunks.len() as u64,
            lanes_used: archs.len() as u64,
            lane_slots: (chunks.len() * ARCH_LANES) as u64,
            segments: 0,
        };
        let n_instrs = trace.n_instrs();
        let mut active: Vec<usize> = (0..chunks.len()).collect();
        let mut start = 0;
        while start < n_instrs && !active.is_empty() {
            let end = (start + SEGMENT_INSTRS).min(n_instrs);
            // One barrier-synchronized wave: each worker claims chunks
            // and advances them through this segment.
            let failed = self.parallel_map(&active, |&c| {
                let mut chunk = chunks[c].lock().unwrap();
                chunk.advance(trace, start..end);
                chunk.all_failed(max_cycles)
            });
            tally.segments += active.len() as u64;
            let survivors =
                active.iter().zip(&failed).filter(|(_, &f)| !f).map(|(&c, _)| c).collect();
            active = survivors;
            start = end;
        }
        let reports: Vec<Result<RunReport, SimError>> = chunks
            .into_iter()
            .flat_map(|chunk| {
                let chunk = chunk.into_inner().unwrap();
                if chunk.all_failed(max_cycles) {
                    chunk.fail_all(max_cycles)
                } else {
                    chunk.finish(trace, max_cycles)
                }
            })
            .collect();
        self.flush_packed(&tally, reports.iter());
        reports
    }

    /// Run every job coupled (execute + replay per cell); results come
    /// back in job order. The first simulator error aborts the sweep (the
    /// paper's benchmarks never fault; an error here is a bug or a bad
    /// custom program).
    pub fn run(&self, jobs: &[BenchJob]) -> Result<Vec<BenchResult>, SimError> {
        self.parallel_map(jobs, |job| job.run()).into_iter().collect()
    }

    /// Run every job through a fresh trace cache: each distinct
    /// `(program, data image)` is functionally executed once, compiled
    /// once, then every job's architecture is charged from batched trace
    /// walks. Cycle-identical to [`Self::run`] (pinned by
    /// `rust/tests/replay_parity.rs` and `rust/tests/replay_diff.rs`),
    /// ~`A×` cheaper in functional work for an `A`-architecture sweep and
    /// a further batch win on the replay side (one walk charges a whole
    /// chunk of architectures).
    ///
    /// **Deprecated wiring path** for external consumers: prefer a
    /// [`crate::service::SimtEngine`] session (`Request::Sweep`), whose
    /// persistent cache also shares these traces with every other
    /// request. The per-call cache here is cold every time.
    pub fn run_cached(&self, jobs: &[BenchJob]) -> Result<Vec<BenchResult>, SimError> {
        let cache = TraceCache::new();
        self.run_with_cache(jobs, &cache)
    }

    /// [`Self::run_cached`] against a caller-owned cache, so traces (and
    /// their compiled forms) survive across sweeps (e.g. re-running the
    /// paper sweep while exploring hypothetical architectures).
    ///
    /// Three phases, each sharded on the worker pool:
    ///
    /// 1. **capture** — each distinct uncached trace key, executed once;
    /// 2. **compile** — each distinct key's [`CompiledTrace`], built (or
    ///    fetched) once;
    /// 3. **batch replay** — each key's cells are chunked and every chunk
    ///    charged in a single lane-packed
    ///    [`crate::sim::packed::replay_many_packed`] trace
    ///    walk (eight architectures per lock-step lane group).
    pub fn run_with_cache(
        &self,
        jobs: &[BenchJob],
        cache: &TraceCache,
    ) -> Result<Vec<BenchResult>, SimError> {
        self.run_with_cache_timed(jobs, cache).map(|(results, _)| results)
    }

    /// [`Self::run_with_cache`] plus the wall time of each phase, so the
    /// engine can attribute a sweep's span to execute/compile/replay.
    /// The timing is three `Instant` reads per *sweep* — always on.
    pub fn run_with_cache_timed(
        &self,
        jobs: &[BenchJob],
        cache: &TraceCache,
    ) -> Result<(Vec<BenchResult>, SweepPhases), SimError> {
        let mut phases = SweepPhases::default();
        // Capture phase. The bulk filter peeks (uncounted) per cell;
        // hit/miss metrics are charged per *distinct key* below, which
        // is the sharing the cache actually provides a sweep.
        let t0 = Instant::now();
        let mut seen = HashSet::new();
        let pending: Vec<&BenchJob> = jobs
            .iter()
            .filter(|job| {
                let key = job.trace_key();
                cache.peek(&key).is_none() && seen.insert(key)
            })
            .collect();
        // Captures go through the cache's single-flight cells, so a
        // concurrent sweep (or engine request) racing on the same key
        // joins this sweep's capture instead of duplicating it.
        let captured: Result<Vec<Arc<_>>, SimError> = self
            .parallel_map(&pending, |job| cache.get_or_capture(job))
            .into_iter()
            .collect();
        captured?;
        phases.capture = t0.elapsed();

        // Compile phase: group cells by trace key, compile each distinct
        // key at most once (memoized in the cache).
        let t0 = Instant::now();
        let mut keys: Vec<TraceKey> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            let key = job.trace_key();
            match keys.iter().position(|k| *k == key) {
                Some(g) => groups[g].push(i),
                None => {
                    keys.push(key);
                    groups.push(vec![i]);
                }
            }
        }
        if let Some(m) = &self.metrics {
            m.add(Counter::TraceCacheMisses, pending.len() as u64);
            m.add(Counter::TraceCacheHits, (keys.len() - pending.len()) as u64);
        }
        let compiled: Vec<Arc<CompiledTrace>> = self.parallel_map(&keys, |key| {
            let trace = cache.peek(key).expect("trace captured in phase 1");
            cache.get_or_compile(key, &trace)
        });
        phases.compile = t0.elapsed();

        // Batch-replay phase: chunk against the *whole* batch so the
        // unit count lands near the worker count — sizing chunks per
        // group would collapse to one-arch walks on many-core pools
        // (e.g. 9-arch groups ÷ 16 workers), forfeiting the batch
        // amortization. The floor and rounding are [`ARCH_LANES`]-aware:
        // every unit the lane-packed kernel charges should fill whole
        // 8-wide chunks (a 2-arch unit wastes six lanes of every packed
        // step), so units are at least one full chunk and a multiple of
        // the lane width. Chunks never span groups (a walk charges one
        // trace).
        let t0 = Instant::now();
        let chunk =
            jobs.len().div_ceil(self.workers).next_multiple_of(ARCH_LANES).max(ARCH_LANES);
        let mut units: Vec<(usize, Vec<usize>)> = Vec::new();
        for (g, idxs) in groups.iter().enumerate() {
            for c in idxs.chunks(chunk) {
                units.push((g, c.to_vec()));
            }
        }
        let replayed = self.parallel_map(&units, |(g, idxs)| {
            let archs: Vec<MemoryArchKind> = idxs.iter().map(|&i| jobs[i].arch).collect();
            replay_many_packed_counted(&compiled[*g], &archs, MachineConfig::DEFAULT_MAX_CYCLES)
        });
        // Fold each unit's local tally and flush once for the sweep.
        let mut tally = ReplayTally::default();
        for (_, unit_tally) in &replayed {
            tally.merge(unit_tally);
        }
        self.flush_packed(&tally, replayed.iter().flat_map(|(reports, _)| reports.iter()));
        if let Some(m) = &self.metrics {
            m.observe(crate::obs::Hist::ReplayMicros, t0.elapsed().as_micros() as u64);
        }
        let mut slots: Vec<Option<BenchResult>> = (0..jobs.len()).map(|_| None).collect();
        for ((_, idxs), (reports, _)) in units.iter().zip(replayed) {
            for (&i, report) in idxs.iter().zip(reports) {
                slots[i] = Some(BenchResult { job: jobs[i].clone(), report: report? });
            }
        }
        phases.replay = t0.elapsed();
        Ok((slots.into_iter().map(|s| s.expect("every cell replayed")).collect(), phases))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;

    #[test]
    fn runs_jobs_in_order() {
        let jobs = vec![
            BenchJob::new("transpose32", MemoryArchKind::mp_4r1w()),
            BenchJob::new("transpose32", MemoryArchKind::banked(16)),
            BenchJob::new("transpose32", MemoryArchKind::banked_offset(4)),
        ];
        let results = SweepRunner::new(2).run(&jobs).unwrap();
        assert_eq!(results.len(), 3);
        for (j, r) in jobs.iter().zip(&results) {
            assert_eq!(&r.job, j);
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let jobs = vec![
            BenchJob::new("transpose32", MemoryArchKind::banked(8)),
            BenchJob::new("transpose64", MemoryArchKind::banked(8)),
        ];
        let par = SweepRunner::new(4).run(&jobs).unwrap();
        let ser = SweepRunner::new(1).run(&jobs).unwrap();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.report.total_cycles(), b.report.total_cycles());
        }
    }

    #[test]
    fn error_propagates() {
        let jobs = vec![BenchJob::new("bogus", MemoryArchKind::mp_4r1w())];
        assert!(SweepRunner::new(2).run(&jobs).is_err());
        assert!(SweepRunner::new(2).run_cached(&jobs).is_err());
    }

    #[test]
    fn default_has_workers() {
        assert!(SweepRunner::default().workers() >= 1);
    }

    #[test]
    fn cached_sweep_equals_coupled_sweep() {
        // Every Table II arch on one program: one functional execution,
        // eight replays — all cycle-identical to the coupled path.
        let jobs: Vec<BenchJob> = MemoryArchKind::table2_eight()
            .into_iter()
            .map(|arch| BenchJob::new("transpose32", arch))
            .collect();
        let runner = SweepRunner::new(4);
        let coupled = runner.run(&jobs).unwrap();
        let cache = TraceCache::new();
        let cached = runner.run_with_cache(&jobs, &cache).unwrap();
        assert_eq!(cache.len(), 1, "eight cells share one trace");
        for (a, b) in coupled.iter().zip(&cached) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.report.stats, b.report.stats, "{}", a.job.arch);
            assert_eq!(a.report.total_cycles(), b.report.total_cycles());
        }
    }

    #[test]
    fn batched_sweep_shares_one_compiled_trace() {
        let jobs: Vec<BenchJob> = MemoryArchKind::table3_nine()
            .into_iter()
            .map(|arch| BenchJob::new("transpose32", arch))
            .collect();
        let cache = TraceCache::new();
        let runner = SweepRunner::new(3);
        let results = runner.run_with_cache(&jobs, &cache).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.compiled_len(), 1, "nine cells share one compiled trace");
        // Every batched cell equals the reference per-arch replay.
        let trace = cache.get(&jobs[0].trace_key()).unwrap();
        for (job, r) in jobs.iter().zip(&results) {
            let reference = job.replay_trace(&trace).unwrap();
            assert_eq!(r.report.stats, reference.report.stats, "{}", job.arch);
            assert_eq!(r.report.total_cycles(), reference.report.total_cycles());
        }
    }

    #[test]
    fn parallel_segment_wavefront_equals_scalar_replay() {
        use crate::sim::compiled::replay_many;
        // A real workload trace, a mixed slate wider than one chunk, and
        // a limit that splits the verdicts: the BSP wavefront must agree
        // with the scalar reference result for result, verdict for
        // verdict, on any worker count.
        let trace = BenchJob::new("transpose64", MemoryArchKind::banked(16))
            .capture_trace()
            .unwrap();
        let compiled = CompiledTrace::compile(&trace);
        let mut archs = MemoryArchKind::table3_nine();
        archs.extend(MemoryArchKind::table3_nine()); // 18 archs → 3 chunks
        let cycles: Vec<u64> = replay_many(&compiled, &archs, u64::MAX)
            .into_iter()
            .map(|r| r.unwrap().total_cycles())
            .collect();
        let limit = (cycles.iter().min().unwrap() + cycles.iter().max().unwrap()) / 2;
        for workers in [1, 4] {
            let runner = SweepRunner::new(workers);
            for max_cycles in [limit, u64::MAX] {
                let par = runner.replay_many_parallel(&compiled, &archs, max_cycles);
                let ser = replay_many(&compiled, &archs, max_cycles);
                assert_eq!(par.len(), ser.len());
                for ((arch, p), s) in archs.iter().zip(&par).zip(&ser) {
                    match (p, s) {
                        (Ok(a), Ok(b)) => {
                            assert_eq!(a.stats, b.stats, "{arch} ({workers}w)");
                            assert_eq!(a.elapsed_cycles, b.elapsed_cycles, "{arch}");
                        }
                        (Err(a), Err(b)) => assert_eq!(format!("{a}"), format!("{b}")),
                        other => panic!("{arch}: verdicts diverged: {other:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn cache_survives_across_sweeps() {
        let jobs = vec![BenchJob::new("transpose32", MemoryArchKind::banked(4))];
        let runner = SweepRunner::new(2);
        let cache = TraceCache::new();
        runner.run_with_cache(&jobs, &cache).unwrap();
        assert_eq!(cache.len(), 1);
        // Second sweep over more architectures reuses the cached trace.
        let more: Vec<BenchJob> = MemoryArchKind::table3_nine()
            .into_iter()
            .map(|arch| BenchJob::new("transpose32", arch))
            .collect();
        runner.run_with_cache(&more, &cache).unwrap();
        assert_eq!(cache.len(), 1);
    }
}
