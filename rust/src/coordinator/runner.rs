//! Parallel sweep runner: a worker pool over benchmark jobs.
//!
//! tokio is unavailable offline, so this is a plain `std::thread` pool
//! with a shared work queue — ample for a simulator sweep, and the
//! results arrive in deterministic (input) order regardless of worker
//! scheduling.

use super::job::{BenchJob, BenchResult};
use crate::sim::machine::SimError;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Thread-pool sweep runner.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    workers: usize,
}

impl Default for SweepRunner {
    fn default() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self { workers: n.min(16) }
    }
}

impl SweepRunner {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every job; results come back in job order. The first simulator
    /// error aborts the sweep (the paper's benchmarks never fault; an
    /// error here is a bug or a bad custom program).
    pub fn run(&self, jobs: &[BenchJob]) -> Result<Vec<BenchResult>, SimError> {
        let next = Arc::new(AtomicUsize::new(0));
        let slots: Arc<Mutex<Vec<Option<Result<BenchResult, SimError>>>>> =
            Arc::new(Mutex::new((0..jobs.len()).map(|_| None).collect()));
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(jobs.len().max(1)) {
                let next = Arc::clone(&next);
                let slots = Arc::clone(&slots);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let result = jobs[i].run();
                    slots.lock().unwrap()[i] = Some(result);
                });
            }
        });
        let slots = Arc::try_unwrap(slots).unwrap().into_inner().unwrap();
        slots
            .into_iter()
            .map(|s| s.expect("every slot filled"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;

    #[test]
    fn runs_jobs_in_order() {
        let jobs = vec![
            BenchJob::new("transpose32", MemoryArchKind::mp_4r1w()),
            BenchJob::new("transpose32", MemoryArchKind::banked(16)),
            BenchJob::new("transpose32", MemoryArchKind::banked_offset(4)),
        ];
        let results = SweepRunner::new(2).run(&jobs).unwrap();
        assert_eq!(results.len(), 3);
        for (j, r) in jobs.iter().zip(&results) {
            assert_eq!(&r.job, j);
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let jobs = vec![
            BenchJob::new("transpose32", MemoryArchKind::banked(8)),
            BenchJob::new("transpose64", MemoryArchKind::banked(8)),
        ];
        let par = SweepRunner::new(4).run(&jobs).unwrap();
        let ser = SweepRunner::new(1).run(&jobs).unwrap();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.report.total_cycles(), b.report.total_cycles());
        }
    }

    #[test]
    fn error_propagates() {
        let jobs = vec![BenchJob::new("bogus", MemoryArchKind::mp_4r1w())];
        assert!(SweepRunner::new(2).run(&jobs).is_err());
    }

    #[test]
    fn default_has_workers() {
        assert!(SweepRunner::default().workers() >= 1);
    }
}
