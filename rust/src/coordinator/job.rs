//! One benchmark job: a (program, memory architecture) combination with a
//! deterministic input seed — one cell of Table II or III — plus the
//! trace cache that lets a sweep execute each program once and replay its
//! timing on every architecture (DESIGN.md §Trace cache).

use crate::mem::arch::MemoryArchKind;
use crate::obs::{Counter, MetricsRegistry};
use crate::programs::library::{program_by_name, Workload};
use crate::programs::registry;
use crate::server::store::ShardedStore;
use crate::sim::compiled::{self, CompiledTrace};
use crate::sim::config::MachineConfig;
use crate::sim::exec::{self, ExecParams, FlatMemory, MemTrace};
use crate::sim::machine::{Machine, SimError};
use crate::sim::replay;
use crate::sim::stats::RunReport;
use std::sync::{Arc, OnceLock};

/// Job descriptor (cheap to clone and ship to worker threads).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BenchJob {
    /// Registered program name (see [`crate::programs::library`]).
    pub program: String,
    /// Memory architecture.
    pub arch: MemoryArchKind,
    /// Input-data seed. The seed deterministically fixes the input
    /// image, hence the whole trace — that is what makes
    /// `(program, seed)` a sound trace-cache key even for kernels whose
    /// access patterns depend on the data (the histogram's
    /// gather/scatter), and keeps validation exact for the rest.
    pub seed: u64,
    /// Use the fast banked timing path (identical cycles; see
    /// [`crate::mem::banked::TimingMode`]).
    pub fast_timing: bool,
}

/// Key identifying a functional execution: the program and its input
/// image. Everything else (architecture, timing mode) only affects
/// replay.
pub type TraceKey = (String, u64);

impl BenchJob {
    pub fn new(program: impl Into<String>, arch: MemoryArchKind) -> Self {
        Self { program: program.into(), arch, seed: 0x5EED, fast_timing: true }
    }

    /// Every cell of one registry half: each sweep member crossed with
    /// its family's architecture slate, in registry order.
    fn matrix_jobs(paper: Option<bool>) -> Vec<BenchJob> {
        registry::benchmark_matrix(paper)
            .into_iter()
            .flat_map(|(name, archs)| {
                archs.into_iter().map(move |arch| BenchJob::new(name.clone(), arch))
            })
            .collect()
    }

    /// The full paper sweep: Table II's 24 transpose cells + Table III's
    /// 27 FFT cells = 51 benchmark combinations — the registry's `paper`
    /// half.
    pub fn paper_sweep() -> Vec<BenchJob> {
        Self::matrix_jobs(Some(true))
    }

    /// The whole benchmark matrix: the paper sweep plus every registered
    /// extension family's cells (reduction, scan, histogram, stencil,
    /// GEMM on the Table III slate) — the `sweep --all` set, 100+ cells
    /// across the registry's seven kernel families.
    pub fn extended_sweep() -> Vec<BenchJob> {
        Self::matrix_jobs(None)
    }

    /// The cache key of this job's functional execution.
    pub fn trace_key(&self) -> TraceKey {
        (self.program.clone(), self.seed)
    }

    fn workload(&self) -> Result<Workload, SimError> {
        program_by_name(&self.program)
            .ok_or_else(|| SimError::BadProgram(format!("unknown program '{}'", self.program)))
    }

    fn config_for(&self, workload: &Workload) -> MachineConfig {
        let mut cfg = MachineConfig::for_arch(self.arch).with_mem_words(workload.mem_words());
        if let Some(region) = workload.tw_region() {
            cfg = cfg.with_tw_region(region);
        }
        if self.fast_timing {
            cfg = cfg.with_fast_timing();
        }
        cfg
    }

    /// Materialize the workload, build the machine, load the input image
    /// and run (execute + replay in lockstep). Returns the full report.
    ///
    /// **Deprecated wiring path** for external consumers: prefer a
    /// [`crate::service::SimtEngine`] session (`Request::Run`), which
    /// serves the same report from its shared trace cache — N runs of
    /// one workload cost one functional execution instead of N.
    pub fn run(&self) -> Result<BenchResult, SimError> {
        let workload = self.workload()?;
        let mut machine = Machine::new(self.config_for(&workload));
        workload.load_input(&mut machine, self.seed);
        let report = machine.run_program(workload.program())?;
        Ok(BenchResult { job: self.clone(), report })
    }

    /// Functionally execute this job's program once — against a flat
    /// memory, with no architecture instantiated — and return the
    /// complete trace. The result is valid for *every* architecture
    /// sharing this job's [`Self::trace_key`].
    pub fn capture_trace(&self) -> Result<MemTrace, SimError> {
        let workload = self.workload()?;
        let mut mem = FlatMemory::new(workload.mem_words());
        workload.load_input(&mut mem, self.seed);
        let params = ExecParams {
            tw_region: workload.tw_region(),
            max_cycles: MachineConfig::DEFAULT_MAX_CYCLES,
            ..ExecParams::default()
        };
        exec::execute(workload.program(), &mut mem, &params)
    }

    /// Replay a previously captured trace against this job's memory
    /// architecture. No program execution, no data image, not even a
    /// workload lookup — the trace is self-describing (capacity rides in
    /// [`MemTrace::mem_words`]), so the per-cell marginal cost is the
    /// timing model alone. Cycle-identical to [`Self::run`].
    ///
    /// This is the **reference** replay path (`dyn SharedMemory` charge
    /// loop); the sweep/engine hot path charges a [`CompiledTrace`]
    /// instead ([`Self::replay_compiled`]), which the differential
    /// harness pins identical to this.
    pub fn replay_trace(&self, trace: &MemTrace) -> Result<BenchResult, SimError> {
        let mut cfg = MachineConfig::for_arch(self.arch).with_mem_words(trace.mem_words);
        if self.fast_timing {
            cfg = cfg.with_fast_timing();
        }
        let mem = cfg.build_memory();
        let report = replay::replay(trace, mem.as_ref(), cfg.max_cycles)?;
        Ok(BenchResult { job: self.clone(), report })
    }

    /// Replay this job's architecture from a compiled trace — the
    /// closed-form O(1)-per-op charge path (DESIGN.md §Replay), through
    /// the allocation-free single-arch walk (the engine's warm `Run`
    /// path; multi-arch slates go through the lane-packed
    /// [`crate::sim::packed`] kernel instead).
    /// `RunReport`-identical to [`Self::replay_trace`] and [`Self::run`]
    /// (`rust/tests/replay_diff.rs`); the banked timing-mode knob is
    /// irrelevant here because exact and fast modes are property-equal.
    pub fn replay_compiled(&self, trace: &CompiledTrace) -> Result<BenchResult, SimError> {
        let report =
            compiled::replay_compiled(trace, self.arch, MachineConfig::DEFAULT_MAX_CYCLES)?;
        Ok(BenchResult { job: self.clone(), report })
    }
}

/// A completed benchmark cell.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub job: BenchJob,
    pub report: RunReport,
}

/// Shared cache of functional-execution traces keyed by
/// `(program, data-image seed)`. A 9-architecture × N-program sweep hits
/// the expensive functional simulation once per program and replays
/// timing 9×. The cache also memoizes each trace's **compiled** form
/// ([`CompiledTrace`], built exactly once per key), so the batch
/// replayer's one-walk-per-slate kernel is as shareable as the traces
/// themselves.
///
/// Both maps are [`ShardedStore`]s (DESIGN.md §Server): warm lookups
/// take only a shard read lock and clone an `Arc`, so any number of
/// concurrent sessions read without serializing, and cold captures and
/// compilations are **single-flight** — however many requests race for
/// an absent key, the expensive work runs once and everyone shares the
/// one result. Capture outcomes are cached *including errors*: the
/// trace of a `(program, seed)` key is deterministic, so a failed
/// capture is a failed capture forever and re-serving the cached
/// [`SimError`] is both correct and cheap.
#[derive(Debug, Default)]
pub struct TraceCache {
    traces: ShardedStore<Result<Arc<MemTrace>, SimError>>,
    compiled: ShardedStore<Arc<CompiledTrace>>,
    /// Session metrics, attached once by the owning engine. Hit/miss
    /// counting rides the cache so every consumer (engine, runner,
    /// explorer, advisor) reports through one set of counters.
    metrics: OnceLock<Arc<MetricsRegistry>>,
}

impl TraceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the session's metrics registry (first attach wins; the
    /// engine does this at construction). A cache without a registry
    /// counts nothing — the standalone/deprecated wiring paths stay
    /// zero-overhead.
    pub fn attach_metrics(&self, metrics: Arc<MetricsRegistry>) {
        let _ = self.metrics.set(metrics);
    }

    /// The attached session registry, if any.
    pub fn metrics(&self) -> Option<&Arc<MetricsRegistry>> {
        self.metrics.get()
    }

    fn count(&self, counter: Counter) {
        if let Some(m) = self.metrics.get() {
            m.inc(counter);
        }
    }

    fn metrics_ref(&self) -> Option<&MetricsRegistry> {
        self.metrics.get().map(Arc::as_ref)
    }

    /// Number of successfully cached traces (cached capture *errors*
    /// are excluded — they occupy a single-flight cell, not a trace).
    pub fn len(&self) -> usize {
        self.traces.count_initialized(|r| r.is_ok())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a cached trace, counting the lookup as a
    /// `trace_cache.{hits,misses}` metric. One logical access should be
    /// counted once: re-checks after a counted `get` go through
    /// [`Self::peek`] (as [`Self::get_or_capture`] does internally).
    pub fn get(&self, key: &TraceKey) -> Option<Arc<MemTrace>> {
        let found = self.peek(key);
        self.count(if found.is_some() {
            Counter::TraceCacheHits
        } else {
            Counter::TraceCacheMisses
        });
        found
    }

    /// Look up a cached trace without touching the hit/miss counters
    /// (for re-checks and bulk filters that account for themselves,
    /// e.g. the sweep runner's capture phase). Shard-read-lock only: an
    /// in-flight capture on another thread reads as absent (joining it
    /// is [`Self::get_or_capture`]'s job).
    pub fn peek(&self, key: &TraceKey) -> Option<Arc<MemTrace>> {
        self.traces.get(key, self.metrics_ref()).and_then(|r| r.ok())
    }

    /// Insert a trace (first insert wins; concurrent duplicates are
    /// dropped).
    pub fn insert(&self, key: TraceKey, trace: Arc<MemTrace>) {
        self.traces.cell(&key, self.metrics_ref()).get_or_init(|| Ok(trace));
    }

    /// Fetch the job's trace, capturing it on a miss — **single-flight**:
    /// concurrent callers racing on the same absent key block on the
    /// one capture in flight and share its result, so each distinct key
    /// is functionally executed exactly once however the requests
    /// interleave (counted `exec.functional_executions` inside the
    /// initializer, which is what keeps that counter exact under
    /// concurrency). The warm path is shard-read-lock only.
    ///
    /// The internal warm check is an uncounted [`Self::peek`]: callers
    /// that want the lookup on the hit/miss counters (the engine, the
    /// explorer's evaluator) do a counted [`Self::get`] first, so one
    /// logical access never counts twice.
    pub fn get_or_capture(&self, job: &BenchJob) -> Result<Arc<MemTrace>, SimError> {
        let key = job.trace_key();
        let cell = self.traces.cell(&key, self.metrics_ref());
        cell.get_or_init(|| {
            let trace = job.capture_trace()?;
            self.count(Counter::FunctionalExecutions);
            Ok(Arc::new(trace))
        })
        .clone()
    }

    /// Fetch the compiled form of `trace` under `key`, compiling on a
    /// miss — single-flight like captures, so each key's compilation is
    /// built **exactly once** even under concurrent first touches
    /// (losing racers block on the winner and share the memo). The
    /// compilation is the one-walk family precomputation of DESIGN.md
    /// §Replay — cached here so repeat sweeps, explorations and engine
    /// `Run`s over a warm trace never re-hash an address.
    ///
    /// Counted as `compiled.{hits,builds}`: every call lands exactly
    /// one of the two, and `compiled.builds` equals
    /// [`Self::compiled_len`] growth.
    pub fn get_or_compile(&self, key: &TraceKey, trace: &MemTrace) -> Arc<CompiledTrace> {
        let cell = self.compiled.cell(key, self.metrics_ref());
        let mut built = false;
        let compiled = cell.get_or_init(|| {
            built = true;
            self.count(Counter::CompiledBuilds);
            Arc::new(CompiledTrace::compile(trace))
        });
        if !built {
            self.count(Counter::CompiledHits);
        }
        Arc::clone(compiled)
    }

    /// Number of cached compiled traces (≤ [`Self::len`]).
    pub fn compiled_len(&self) -> usize {
        self.compiled.count_initialized(|_| true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sweep_is_51_combinations() {
        // "we ... run a total of 51 benchmarks (different combinations of
        // algorithms, data sizes and processor memories)".
        assert_eq!(BenchJob::paper_sweep().len(), 51);
    }

    #[test]
    fn extended_sweep_is_the_registry_matrix() {
        let jobs = BenchJob::extended_sweep();
        assert_eq!(jobs.len(), crate::programs::registry::matrix_cells(None));
        assert!(jobs.len() >= 100, "expanded matrix floor: got {}", jobs.len());
        assert_eq!(jobs.iter().filter(|j| j.program == "reduction4096").count(), 9);
        assert_eq!(jobs.iter().filter(|j| j.program == "gemm64").count(), 9);
        // The paper half leads, unchanged.
        assert_eq!(&jobs[..51], &BenchJob::paper_sweep()[..]);
    }

    #[test]
    fn job_runs_and_reports() {
        let r = BenchJob::new("transpose32", MemoryArchKind::mp_4r1w())
            .run()
            .unwrap();
        assert_eq!(r.report.stats.d_load_cycles, 256); // Table II row
        assert_eq!(r.report.stats.store_cycles, 1024);
    }

    #[test]
    fn unknown_program_is_error() {
        assert!(BenchJob::new("nope", MemoryArchKind::mp_4r1w()).run().is_err());
        assert!(BenchJob::new("nope", MemoryArchKind::mp_4r1w()).capture_trace().is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let job = BenchJob::new("fft4096r8", MemoryArchKind::banked_offset(16));
        let a = job.run().unwrap();
        let b = job.run().unwrap();
        assert_eq!(a.report.total_cycles(), b.report.total_cycles());
        assert_eq!(a.report.stats, b.report.stats);
    }

    #[test]
    fn replayed_trace_matches_coupled_run() {
        // One trace, two architectures: each replay must equal its
        // coupled run exactly.
        let base = BenchJob::new("transpose32", MemoryArchKind::banked(16));
        let trace = base.capture_trace().unwrap();
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::mp_4r2w()] {
            let job = BenchJob::new("transpose32", arch);
            let coupled = job.run().unwrap();
            let replayed = job.replay_trace(&trace).unwrap();
            assert_eq!(replayed.report.stats, coupled.report.stats, "{arch}");
            assert_eq!(replayed.report.total_cycles(), coupled.report.total_cycles());
        }
    }

    #[test]
    fn compiled_replay_matches_reference_replay() {
        let base = BenchJob::new("transpose32", MemoryArchKind::banked(16));
        let trace = base.capture_trace().unwrap();
        let compiled = CompiledTrace::compile(&trace);
        for arch in MemoryArchKind::table3_nine() {
            let job = BenchJob::new("transpose32", arch);
            let reference = job.replay_trace(&trace).unwrap();
            let fast = job.replay_compiled(&compiled).unwrap();
            assert_eq!(fast.report.stats, reference.report.stats, "{arch}");
            assert_eq!(fast.report.total_cycles(), reference.report.total_cycles(), "{arch}");
        }
    }

    #[test]
    fn cache_memoizes_compiled_traces() {
        let cache = TraceCache::new();
        let job = BenchJob::new("transpose32", MemoryArchKind::banked(16));
        let trace = cache.get_or_capture(&job).unwrap();
        assert_eq!(cache.compiled_len(), 0, "compilation is on demand");
        let a = cache.get_or_compile(&job.trace_key(), &trace);
        let b = cache.get_or_compile(&job.trace_key(), &trace);
        assert!(Arc::ptr_eq(&a, &b), "one compilation per trace key");
        assert_eq!(cache.compiled_len(), 1);
        assert_eq!(a.n_ops() as u64, trace.mem_op_count());
    }

    #[test]
    fn trace_cache_dedupes_by_program_and_seed() {
        let cache = TraceCache::new();
        let a = BenchJob::new("transpose32", MemoryArchKind::banked(16));
        let b = BenchJob::new("transpose32", MemoryArchKind::mp_4r1w());
        let ta = cache.get_or_capture(&a).unwrap();
        let tb = cache.get_or_capture(&b).unwrap();
        assert!(Arc::ptr_eq(&ta, &tb), "same (program, seed) shares one trace");
        assert_eq!(cache.len(), 1);
        let mut c = BenchJob::new("transpose32", MemoryArchKind::banked(16));
        c.seed = 1234;
        cache.get_or_capture(&c).unwrap();
        assert_eq!(cache.len(), 2, "different data image, different trace");
    }
}
