//! One benchmark job: a (program, memory architecture) combination with a
//! deterministic input seed — one cell of Table II or III.

use crate::mem::arch::MemoryArchKind;
use crate::programs::library::{program_by_name, Workload};
use crate::sim::config::MachineConfig;
use crate::sim::machine::{Machine, SimError};
use crate::sim::stats::RunReport;
use crate::util::XorShift64;

/// Job descriptor (cheap to clone and ship to worker threads).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BenchJob {
    /// Registered program name (see [`crate::programs::library`]).
    pub program: String,
    /// Memory architecture.
    pub arch: MemoryArchKind,
    /// Input-data seed (the data does not change timing — access patterns
    /// are address-driven — but determinism keeps validation exact).
    pub seed: u64,
    /// Use the fast banked timing path (identical cycles; see
    /// [`crate::mem::banked::TimingMode`]).
    pub fast_timing: bool,
}

impl BenchJob {
    pub fn new(program: impl Into<String>, arch: MemoryArchKind) -> Self {
        Self { program: program.into(), arch, seed: 0x5EED, fast_timing: true }
    }

    /// The full paper sweep: Table II's 24 transpose cells + Table III's
    /// 27 FFT cells = 51 benchmark combinations.
    pub fn paper_sweep() -> Vec<BenchJob> {
        let mut jobs = Vec::new();
        for n in [32, 64, 128] {
            for arch in MemoryArchKind::table2_eight() {
                jobs.push(BenchJob::new(format!("transpose{n}"), arch));
            }
        }
        for r in [4, 8, 16] {
            for arch in MemoryArchKind::table3_nine() {
                jobs.push(BenchJob::new(format!("fft4096r{r}"), arch));
            }
        }
        jobs
    }

    /// Materialize the workload, build the machine, load the input image
    /// and run. Returns the full report.
    pub fn run(&self) -> Result<BenchResult, SimError> {
        let workload = program_by_name(&self.program)
            .ok_or_else(|| SimError::BadProgram(format!("unknown program '{}'", self.program)))?;
        let mut cfg = MachineConfig::for_arch(self.arch).with_mem_words(workload.mem_words());
        if let Some(region) = workload.tw_region() {
            cfg = cfg.with_tw_region(region);
        }
        if self.fast_timing {
            cfg = cfg.with_fast_timing();
        }
        let mut machine = Machine::new(cfg);
        let mut rng = XorShift64::new(self.seed);
        match &workload {
            Workload::Transpose(plan, _) => {
                let src: Vec<u32> = (0..plan.n * plan.n).map(|_| rng.next_u32()).collect();
                machine.load_image(plan.src_base, &src);
            }
            Workload::Fft(plan, _) => {
                let data = rng.f32_vec(2 * plan.n as usize);
                machine.load_f32_image(plan.data_base, &data);
                machine.load_f32_image(plan.tw_base, &plan.twiddles);
            }
        }
        let report = machine.run_program(workload.program())?;
        Ok(BenchResult { job: self.clone(), report })
    }
}

/// A completed benchmark cell.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub job: BenchJob,
    pub report: RunReport,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sweep_is_51_combinations() {
        // "we ... run a total of 51 benchmarks (different combinations of
        // algorithms, data sizes and processor memories)".
        assert_eq!(BenchJob::paper_sweep().len(), 51);
    }

    #[test]
    fn job_runs_and_reports() {
        let r = BenchJob::new("transpose32", MemoryArchKind::mp_4r1w())
            .run()
            .unwrap();
        assert_eq!(r.report.stats.d_load_cycles, 256); // Table II row
        assert_eq!(r.report.stats.store_cycles, 1024);
    }

    #[test]
    fn unknown_program_is_error() {
        assert!(BenchJob::new("nope", MemoryArchKind::mp_4r1w()).run().is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let job = BenchJob::new("fft4096r8", MemoryArchKind::banked_offset(16));
        let a = job.run().unwrap();
        let b = job.run().unwrap();
        assert_eq!(a.report.total_cycles(), b.report.total_cycles());
        assert_eq!(a.report.stats, b.report.stats);
    }
}
