//! Table and figure renderers — each regenerates one artifact of the
//! paper's evaluation from sweep results (plain text and CSV).

use super::job::BenchResult;
use crate::area::fig9::{self, Fig9Point};
use crate::area::table1;
use crate::mem::arch::MemoryArchKind;
use crate::mem::timing;
use crate::util::fmt::{pct, us, TextTable};

/// Find a result cell.
fn cell<'a>(results: &'a [BenchResult], program: &str, arch: MemoryArchKind) -> &'a BenchResult {
    results
        .iter()
        .find(|r| r.job.program == program && r.job.arch == arch)
        .unwrap_or_else(|| panic!("missing sweep cell {program}/{arch}"))
}

fn opt_pct(v: Option<f64>) -> String {
    v.map(pct).unwrap_or_else(|| "-".into())
}

/// Render Table I (resource counts) plus the modelled Fmax notes.
pub fn render_table1() -> String {
    let mut t = TextTable::new(["Group", "Module", "No.", "ALMs", "Regs", "M20K", "DSP"]);
    for r in table1::rows() {
        let name = if r.submodule { format!("  {}", r.module) } else { r.module.to_string() };
        t.row([
            r.group.to_string(),
            name,
            r.count.to_string(),
            r.per_instance.alms.to_string(),
            r.per_instance.regs.to_string(),
            r.per_instance.m20k.to_string(),
            r.per_instance.dsp.to_string(),
        ]);
    }
    let core = table1::core_total();
    format!(
        "TABLE I: Processor Resources (per-instance; submodules indented)\n{}\n\
         Common core total: {} ALMs, {} M20K, {} DSP\n\
         Modelled Fmax: {} MHz (DSP-limited FP32), {} MHz unrestricted, \
         {} MHz 4R-2W (emulated TDP), {} MHz constrained 448 KB\n",
        t.render(),
        core.alms,
        core.m20k,
        core.dsp,
        timing::FMAX_MHZ,
        timing::FMAX_UNRESTRICTED_MHZ,
        timing::FMAX_4R2W_MHZ,
        timing::FMAX_CONSTRAINED_MHZ,
    )
}

/// Render Table II (transpose profiling) from sweep results.
pub fn render_table2(results: &[BenchResult]) -> String {
    let archs = MemoryArchKind::table2_eight();
    let mut out = String::from("TABLE II: Transpose Profiling - Different Memory Architectures\n");
    for n in [32u32, 64, 128] {
        let program = format!("transpose{n}");
        let mut t = TextTable::new(
            std::iter::once("Type".to_string()).chain(archs.iter().map(|a| a.label())),
        );
        let c0 = &cell(results, &program, archs[0]).report;
        out.push_str(&format!(
            "\n{n}x{n}  (Common Ops — INT: {}, Immediate: {}, FP: {}, Other: {}; Load/Store ops {}/{})\n",
            c0.stats.int_cycles,
            c0.stats.imm_cycles,
            c0.stats.fp_cycles,
            c0.stats.other_cycles,
            c0.stats.d_load_ops,
            c0.stats.store_ops,
        ));
        let row = |label: &str, f: &dyn Fn(&BenchResult) -> String| {
            let mut cells = vec![label.to_string()];
            for &a in &archs {
                cells.push(f(cell(results, &program, a)));
            }
            cells
        };
        t.row(row("Load Cycles", &|r| r.report.stats.d_load_cycles.to_string()));
        t.row(row("Store Cycles", &|r| r.report.stats.store_cycles.to_string()));
        t.row(row("Total", &|r| r.report.total_cycles().to_string()));
        t.row(row("Time (us)", &|r| us(r.report.time_us())));
        t.row(row("R Bank Eff. (%)", &|r| opt_pct(r.report.r_bank_eff())));
        t.row(row("W Bank Eff. (%)", &|r| opt_pct(r.report.w_bank_eff())));
        out.push_str(&t.render());
    }
    out
}

/// Render Table III (FFT profiling) from sweep results.
pub fn render_table3(results: &[BenchResult]) -> String {
    let archs = MemoryArchKind::table3_nine();
    let mut out = String::from("TABLE III: FFT Profiling - Different Memory Architectures\n");
    for radix in [4u32, 8, 16] {
        let program = format!("fft4096r{radix}");
        let c0 = &cell(results, &program, archs[0]).report;
        out.push_str(&format!(
            "\nRadix {radix}  (Common Ops — FP: {}, INT: {}, Immediate: {}, Other: {}; \
             D Load/Store ops {}/{}; TW Load ops {})\n",
            c0.stats.fp_cycles,
            c0.stats.int_cycles,
            c0.stats.imm_cycles,
            c0.stats.other_cycles,
            c0.stats.d_load_ops,
            c0.stats.store_ops,
            c0.stats.tw_load_ops,
        ));
        let mut t = TextTable::new(
            std::iter::once("Type".to_string()).chain(archs.iter().map(|a| a.label())),
        );
        let row = |label: &str, f: &dyn Fn(&BenchResult) -> String| {
            let mut cells = vec![label.to_string()];
            for &a in &archs {
                cells.push(f(cell(results, &program, a)));
            }
            cells
        };
        t.row(row("D Load Cycles", &|r| r.report.stats.d_load_cycles.to_string()));
        t.row(row("W Load Cycles", &|r| r.report.stats.tw_load_cycles.to_string()));
        t.row(row("Store Cycles", &|r| r.report.stats.store_cycles.to_string()));
        t.row(row("Total", &|r| r.report.total_cycles().to_string()));
        t.row(row("Time (us)", &|r| us(r.report.time_us())));
        t.row(row("Efficiency (%)", &|r| pct(r.report.compute_efficiency())));
        t.row(row("D Bank Eff. (%)", &|r| opt_pct(r.report.r_bank_eff())));
        t.row(row("TW Bank Eff. (%)", &|r| opt_pct(r.report.tw_bank_eff())));
        out.push_str(&t.render());
    }
    out
}

/// Render one extension member's profile table (the Table II/III shape,
/// on whatever part of the family's declared architecture slate is
/// present in `results`). Empty when the member was not swept.
fn render_extension_member(
    results: &[BenchResult],
    program: &str,
    title: &str,
    slate: &[MemoryArchKind],
) -> String {
    let archs: Vec<MemoryArchKind> = slate
        .iter()
        .copied()
        .filter(|a| results.iter().any(|r| r.job.program == program && r.job.arch == *a))
        .collect();
    if archs.is_empty() {
        return String::new();
    }
    let mut out = format!(
        "{}: {} Profiling - Different Memory Architectures\n",
        program.to_uppercase(),
        title
    );
    let c0 = &cell(results, program, archs[0]).report;
    out.push_str(&format!(
        "\n{} threads  (Common Ops — INT: {}, Immediate: {}, FP: {}, Other: {}; \
         Load/Store ops {}/{})\n",
        c0.threads,
        c0.stats.int_cycles,
        c0.stats.imm_cycles,
        c0.stats.fp_cycles,
        c0.stats.other_cycles,
        c0.stats.d_load_ops,
        c0.stats.store_ops,
    ));
    let mut t = TextTable::new(
        std::iter::once("Type".to_string()).chain(archs.iter().map(|a| a.label())),
    );
    let row = |label: &str, f: &dyn Fn(&BenchResult) -> String| {
        let mut cells = vec![label.to_string()];
        for &a in &archs {
            cells.push(f(cell(results, program, a)));
        }
        cells
    };
    t.row(row("Load Cycles", &|r| r.report.stats.d_load_cycles.to_string()));
    t.row(row("Store Cycles", &|r| r.report.stats.store_cycles.to_string()));
    t.row(row("Total", &|r| r.report.total_cycles().to_string()));
    t.row(row("Time (us)", &|r| us(r.report.time_us())));
    t.row(row("R Bank Eff. (%)", &|r| opt_pct(r.report.r_bank_eff())));
    t.row(row("W Bank Eff. (%)", &|r| opt_pct(r.report.w_bank_eff())));
    out.push_str(&t.render());
    out
}

/// Render the extension tables (`sweep --all`): one profile table per
/// registry extension member present in `results` (reduction, scan,
/// histogram, stencil, GEMM cells) — the access patterns beyond the
/// paper's own tables, enumerated from the registry so a new kernel
/// family reports without touching this module.
pub fn render_extensions(results: &[BenchResult]) -> String {
    use crate::programs::registry;
    let mut out = String::new();
    for fam in registry::families().iter().filter(|f| !f.paper) {
        let slate = fam.sweep_archs.archs();
        for member in fam.sweep_members() {
            let table = render_extension_member(results, &member, fam.title, &slate);
            if !table.is_empty() {
                if !out.is_empty() {
                    out.push('\n');
                }
                out.push_str(&table);
            }
        }
    }
    out
}

/// Build the Fig. 9 series from sweep results (radix-16 FFT is the
/// performance benchmark, §VI).
pub fn fig9_points(results: &[BenchResult]) -> Vec<Fig9Point> {
    let times: Vec<(MemoryArchKind, f64)> = MemoryArchKind::table3_nine()
        .into_iter()
        .map(|a| (a, cell(results, "fft4096r16", a).report.time_us()))
        .collect();
    fig9::series(&times)
}

/// Render Fig. 9 (cost vs performance) as a table: one row per
/// architecture, cost columns per capacity, plus normalized performance.
pub fn render_fig9(results: &[BenchResult]) -> String {
    let points = fig9_points(results);
    let mut t = TextTable::new([
        "Memory".to_string(),
        "64KB ALMs".into(),
        "112KB ALMs".into(),
        "168KB ALMs".into(),
        "224KB ALMs".into(),
        "Time (us)".into(),
        "Norm. perf".into(),
    ]);
    for arch in MemoryArchKind::table3_nine() {
        let per_size: Vec<String> = fig9::SIZES_KB
            .iter()
            .map(|&kb| {
                points
                    .iter()
                    .find(|p| p.arch == arch && p.size_kb == kb)
                    .and_then(|p| p.footprint)
                    .map(|f| f.total_alms().to_string())
                    .unwrap_or_else(|| "over cap".into())
            })
            .collect();
        let p0 = points.iter().find(|p| p.arch == arch).unwrap();
        t.row([
            arch.label(),
            per_size[0].clone(),
            per_size[1].clone(),
            per_size[2].clone(),
            per_size[3].clone(),
            us(p0.time_us),
            format!("{:.3}", p0.normalized),
        ]);
    }
    format!(
        "Fig. 9: Cost vs. Performance (lower normalized perf is better; \
         radix-16 4096-pt FFT)\n{}",
        t.render()
    )
}

/// Everything as CSV rows (program, arch label, metrics) — machine-
/// readable counterpart of Tables II and III for downstream plotting.
pub fn sweep_csv(results: &[BenchResult]) -> String {
    let mut t = TextTable::new([
        "program", "arch", "threads", "int", "imm", "fp", "other", "d_load_ops", "tw_load_ops",
        "store_ops", "d_load_cycles", "tw_load_cycles", "store_cycles", "total_cycles", "time_us",
        "r_bank_eff", "tw_bank_eff", "w_bank_eff", "efficiency",
    ]);
    for r in results {
        let s = &r.report.stats;
        t.row([
            r.job.program.clone(),
            r.job.arch.label(),
            r.report.threads.to_string(),
            s.int_cycles.to_string(),
            s.imm_cycles.to_string(),
            s.fp_cycles.to_string(),
            s.other_cycles.to_string(),
            s.d_load_ops.to_string(),
            s.tw_load_ops.to_string(),
            s.store_ops.to_string(),
            s.d_load_cycles.to_string(),
            s.tw_load_cycles.to_string(),
            s.store_cycles.to_string(),
            r.report.total_cycles().to_string(),
            format!("{:.3}", r.report.time_us()),
            r.report.r_bank_eff().map(|v| format!("{v:.4}")).unwrap_or_default(),
            r.report.tw_bank_eff().map(|v| format!("{v:.4}")).unwrap_or_default(),
            r.report.w_bank_eff().map(|v| format!("{v:.4}")).unwrap_or_default(),
            format!("{:.4}", r.report.compute_efficiency()),
        ]);
    }
    t.to_csv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::BenchJob;
    use crate::coordinator::runner::SweepRunner;

    fn mini_sweep() -> Vec<BenchResult> {
        // A reduced sweep that still covers every column the renderers
        // need: all archs for transpose32 and fft4096r16 only (full
        // paper sweep is exercised in integration tests and benches).
        let mut jobs = Vec::new();
        for arch in MemoryArchKind::table2_eight() {
            jobs.push(BenchJob::new("transpose32", arch));
            jobs.push(BenchJob::new("transpose64", arch));
            jobs.push(BenchJob::new("transpose128", arch));
        }
        for arch in MemoryArchKind::table3_nine() {
            jobs.push(BenchJob::new("fft4096r4", arch));
            jobs.push(BenchJob::new("fft4096r8", arch));
            jobs.push(BenchJob::new("fft4096r16", arch));
        }
        SweepRunner::default().run(&jobs).unwrap()
    }

    #[test]
    fn renders_all_tables() {
        let results = mini_sweep();
        let t1 = render_table1();
        assert!(t1.contains("16 Banks") && t1.contains("13105"));
        let t2 = render_table2(&results);
        assert!(t2.contains("32x32") && t2.contains("R Bank Eff."));
        let t3 = render_table3(&results);
        assert!(t3.contains("Radix 16") && t3.contains("4R-1W-VB"));
        let f9 = render_fig9(&results);
        assert!(f9.contains("over cap"), "4R-1W must exceed capacity at 168 KB");
        let csv = sweep_csv(&results);
        assert_eq!(csv.lines().count(), results.len() + 1);
    }

    #[test]
    fn renders_extension_tables() {
        let jobs: Vec<BenchJob> = MemoryArchKind::table3_nine()
            .into_iter()
            .flat_map(|arch| {
                [
                    BenchJob::new("reduction4096", arch),
                    BenchJob::new("scan1024", arch),
                    BenchJob::new("gemm32", arch),
                ]
            })
            .collect();
        let results = SweepRunner::default().run_cached(&jobs).unwrap();
        let out = render_extensions(&results);
        assert!(out.contains("REDUCTION4096: Strided Tree-Sum"));
        assert!(out.contains("SCAN1024: Work-Efficient Prefix Sum"));
        assert!(out.contains("GEMM32: Tiled GEMM"));
        assert!(!out.contains("HISTOGRAM"), "unswept members render nothing");
        assert!(out.contains("16 Banks Offset"));
        // Without extension cells the renderer degrades to empty.
        assert_eq!(render_extensions(&[]), "");
    }

    #[test]
    #[should_panic(expected = "missing sweep cell")]
    fn missing_cell_panics_with_context() {
        let results: Vec<BenchResult> = Vec::new();
        let _ = render_table2(&results);
    }
}
