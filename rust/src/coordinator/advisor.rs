//! The memory-architecture advisor: the deployable form of the paper's
//! conclusion.
//!
//! §VII: "The best choice of shared memory architecture is then most
//! likely determined by the dataset size ... The choice between the two
//! types of memory will also be influenced by memory access patterns ...
//! The one advantage of the FPGA is that we will be able to change our
//! memory architecture to suit our particular design."
//!
//! Given a workload (any member of the kernel registry's name grammar —
//! `transposeN`, `fft4096rR`, `reductionN`, `scanN`, `histogramN`,
//! `stencilN`, `gemmN` — see [`crate::programs::registry`]), the
//! advisor ranks every candidate memory — the paper's nine plus the
//! XOR-mapped extensions — by time, area and perf-per-area.
//!
//! Since PR 2 the advisor is a thin consumer of the design-space
//! explorer ([`crate::explore`]): its candidate set is one small
//! [`DesignSpace`] pinned at the workload's dataset capacity, evaluated
//! by exhaustive cached-trace replay (one functional execution for all
//! twelve candidates). Cycle counts, time ranking and the `fastest`
//! recommendation are unchanged from the coupled per-candidate
//! simulation this replaced (replay parity pins the cycles). The area
//! columns use the shared footprint model, which the same PR *corrects*
//! for multiport candidates (a 700-ALM R/W-control double count —
//! see [`crate::area::footprint`]), so perf-per-area figures are lower
//! by that amount for multiport entries than in earlier releases.

use super::job::TraceCache;
use super::runner::SweepRunner;
use crate::explore::system::{SystemEvaluator, SystemPoint};
use crate::explore::{explore, DesignSpace, Exhaustive};
use crate::mem::arch::MemoryArchKind;
use crate::mem::mapping::BankMapping;
use crate::sim::machine::SimError;
use crate::util::fmt::TextTable;

/// One candidate's scorecard.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub arch: MemoryArchKind,
    pub total_cycles: u64,
    pub time_us: f64,
    /// Whole-processor ALM footprint at the workload's dataset size
    /// (`None` = the architecture cannot hold the dataset).
    pub footprint_alms: Option<u32>,
    /// 1 / (time × sectors); `None` past the capacity roofline.
    pub perf_per_area: Option<f64>,
}

/// The advisor's output: candidates sorted by time, plus the two
/// recommendations the paper's decision rule produces and the system
/// model's scale-out footnote.
#[derive(Debug, Clone)]
pub struct Advice {
    pub program: String,
    pub dataset_kb: u32,
    pub candidates: Vec<Candidate>,
    /// The best {1,2,4}-core shape of the fastest placeable memory under
    /// the system contention + Fmax model ([`crate::explore::system`]),
    /// by throughput per ALM. `None` only if no candidate is placeable.
    pub scale_out: Option<ScaleOut>,
}

/// One system-model data point for the advisor's scale-out footnote.
#[derive(Debug, Clone, Copy)]
pub struct ScaleOut {
    pub point: SystemPoint,
    pub throughput_per_alm: f64,
}

/// Candidate set: the paper's nine plus XOR-mapped banked variants.
pub fn candidate_archs() -> Vec<MemoryArchKind> {
    let mut v = MemoryArchKind::table3_nine();
    for banks in [4, 8, 16] {
        v.push(MemoryArchKind::Banked { banks, mapping: BankMapping::Xor });
    }
    v
}

/// The advisor's candidate design space: the candidate architectures at
/// exactly the workload's dataset capacity, order-preserving and without
/// a roofline filter (over-capacity candidates stay visible, marked).
pub fn candidate_space(dataset_kb: u32) -> DesignSpace {
    DesignSpace::from_archs(candidate_archs(), dataset_kb)
}

/// Run the advisor for a registered program with a private runner and a
/// cold trace cache.
///
/// **Deprecated wiring path**: prefer routing through
/// [`crate::service::SimtEngine`] (a `Request::Advise`), which owns a
/// persistent cache and worker pool so the advisor's functional
/// execution is shared with every other request in the session. This
/// free function remains for one-shot library use and delegates to
/// [`advise_with`].
pub fn advise(program: &str) -> Result<Advice, SimError> {
    advise_with(program, &SweepRunner::default(), &TraceCache::new())
}

/// Run the advisor against a caller-owned worker pool and trace cache:
/// one exhaustive exploration of the candidate space (at most a single
/// functional execution — zero on a warm cache — and one timing replay
/// per candidate).
pub fn advise_with(
    program: &str,
    runner: &SweepRunner,
    cache: &TraceCache,
) -> Result<Advice, SimError> {
    let workload = crate::programs::library::program_by_name(program)
        .ok_or_else(|| SimError::BadProgram(format!("unknown program '{program}'")))?;
    let dataset_kb = workload.dataset_kb();
    let space = candidate_space(dataset_kb);
    let result = explore(program, &space, &Exhaustive, runner, cache)?;
    let mut candidates: Vec<Candidate> = result
        .scored
        .iter()
        .map(|s| Candidate {
            arch: s.point.arch,
            total_cycles: s.cycles,
            time_us: s.time_us,
            footprint_alms: s.footprint_alms,
            perf_per_area: s.perf_per_area,
        })
        .collect();
    candidates.sort_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap());
    let scale_out = scale_out_for(program, dataset_kb, &candidates, cache)?;
    Ok(Advice { program: program.to_string(), dataset_kb, candidates, scale_out })
}

/// The advisor's system-model footnote: score the fastest placeable
/// candidate at {1,2,4} cores × 16 lanes and keep the best throughput
/// per ALM. Rides the same trace cache — no new functional execution.
fn scale_out_for(
    program: &str,
    dataset_kb: u32,
    candidates: &[Candidate],
    cache: &TraceCache,
) -> Result<Option<ScaleOut>, SimError> {
    let Some(fastest) = candidates.iter().find(|c| c.footprint_alms.is_some()) else {
        return Ok(None);
    };
    let sys = SystemEvaluator::new(program, cache)?;
    let mut best: Option<ScaleOut> = None;
    for processors in [1u32, 2, 4] {
        let point = SystemPoint {
            processors,
            lanes: 16,
            mem: fastest.arch,
            capacity_kb: dataset_kb.max(1),
        };
        if !point.is_valid() {
            continue;
        }
        let cost = sys.score(point)?;
        let Some(throughput_per_alm) = cost.throughput_per_alm(sys.stream_ops(), processors)
        else {
            continue;
        };
        // Strictly-greater keeps the smallest winning core count on ties.
        if best.map_or(true, |b| throughput_per_alm > b.throughput_per_alm) {
            best = Some(ScaleOut { point, throughput_per_alm });
        }
    }
    Ok(best)
}

impl Advice {
    /// Fastest architecture that can hold the dataset.
    pub fn fastest(&self) -> &Candidate {
        self.candidates
            .iter()
            .find(|c| c.footprint_alms.is_some())
            .expect("banked memories always fit the benchmark datasets")
    }

    /// Best performance per unit area (the paper's efficiency criterion).
    pub fn most_efficient(&self) -> &Candidate {
        self.candidates
            .iter()
            .max_by(|a, b| {
                a.perf_per_area
                    .unwrap_or(0.0)
                    .partial_cmp(&b.perf_per_area.unwrap_or(0.0))
                    .unwrap()
            })
            .unwrap()
    }

    /// Render the scorecard.
    pub fn render(&self) -> String {
        let mut t = TextTable::new([
            "memory", "cycles", "time (us)", "ALMs", "perf/area",
        ]);
        for c in &self.candidates {
            t.row([
                c.arch.label(),
                c.total_cycles.to_string(),
                format!("{:.2}", c.time_us),
                c.footprint_alms
                    .map(|a| a.to_string())
                    .unwrap_or_else(|| "over cap".into()),
                c.perf_per_area
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        let mut out = format!(
            "advisor: {} ({} KB dataset)\n{}\nfastest: {}   most perf/area: {}\n",
            self.program,
            self.dataset_kb,
            t.render(),
            self.fastest().arch.label(),
            self.most_efficient().arch.label(),
        );
        if let Some(s) = &self.scale_out {
            out.push_str(&format!(
                "scale-out (system model): {} — {:.6} ops/us/ALM at {:.0} MHz\n",
                s.point.label(),
                s.throughput_per_alm,
                s.point.fmax_mhz(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advises_transpose32() {
        let advice = advise("transpose32").unwrap();
        assert_eq!(advice.candidates.len(), 12);
        // Sorted by time.
        for w in advice.candidates.windows(2) {
            assert!(w[0].time_us <= w[1].time_us);
        }
        let out = advice.render();
        assert!(out.contains("fastest:"));
        assert!(out.contains("XOR"));
    }

    #[test]
    fn advises_fft_and_prefers_offset16_among_paper_nine() {
        let advice = advise("fft4096r16").unwrap();
        // Among the paper's nine, Table III's winner heads the ranking...
        let paper_nine = MemoryArchKind::table3_nine();
        let fastest_paper = advice
            .candidates
            .iter()
            .find(|c| paper_nine.contains(&c.arch))
            .unwrap();
        assert_eq!(fastest_paper.arch.label(), "16 Banks Offset");
        // ...and the XOR extension beats it outright (it randomizes the
        // power-of-two stride conflicts the Offset map only shifts) —
        // the §VII "varying the bank mapping" headroom, quantified in
        // EXPERIMENTS.md §Extensions.
        let fastest = advice.fastest();
        if let MemoryArchKind::Banked { banks, mapping } = fastest.arch {
            assert_eq!(banks, 16);
            assert!(matches!(mapping, BankMapping::Xor | BankMapping::Offset { .. }));
        } else {
            panic!("a banked memory must win the FFT");
        }
        // Smaller banked cores win perf/area (Fig. 9's observation).
        let eff = advice.most_efficient();
        if let MemoryArchKind::Banked { banks, .. } = eff.arch {
            assert!(banks <= 8, "perf/area winner should be a small banked core");
        }
    }

    #[test]
    fn scale_out_footnote_scores_the_fastest_memory() {
        let advice = advise("transpose32").unwrap();
        let s = advice.scale_out.expect("placeable fastest candidate");
        assert_eq!(s.point.lanes, 16);
        assert!([1, 2, 4].contains(&s.point.processors));
        assert_eq!(s.point.mem, advice.fastest().arch);
        assert_eq!(s.point.capacity_kb, advice.dataset_kb.max(1));
        assert!(s.throughput_per_alm > 0.0);
        let out = advice.render();
        assert!(out.contains("scale-out (system model): p"), "{out}");
    }

    #[test]
    fn scale_out_shares_the_advice_trace() {
        let runner = SweepRunner::new(2);
        let cache = TraceCache::new();
        let advice = advise_with("transpose32", &runner, &cache).unwrap();
        assert!(advice.scale_out.is_some());
        assert_eq!(cache.len(), 1, "the footnote rides the advisor's one capture");
    }

    #[test]
    fn unknown_program_errors() {
        assert!(advise("nope").is_err());
    }

    #[test]
    fn advise_with_reuses_warm_cache() {
        let runner = SweepRunner::new(2);
        let cache = TraceCache::new();
        let a = advise_with("transpose32", &runner, &cache).unwrap();
        assert_eq!(cache.len(), 1);
        let b = advise_with("transpose32", &runner, &cache).unwrap();
        assert_eq!(cache.len(), 1, "warm cache: no second functional execution");
        assert_eq!(a.candidates.len(), b.candidates.len());
    }
}
