//! The memory-architecture advisor: the deployable form of the paper's
//! conclusion.
//!
//! §VII: "The best choice of shared memory architecture is then most
//! likely determined by the dataset size ... The choice between the two
//! types of memory will also be influenced by memory access patterns ...
//! The one advantage of the FPGA is that we will be able to change our
//! memory architecture to suit our particular design."
//!
//! Given a workload (a registered benchmark or a custom program), the
//! advisor simulates it across every candidate memory — the paper's nine
//! plus the XOR-mapped extensions — folds in the footprint model at the
//! workload's dataset size, and ranks by time, area and perf-per-area.

use super::job::BenchJob;
use crate::area::footprint;
use crate::mem::arch::MemoryArchKind;
use crate::mem::mapping::BankMapping;
use crate::sim::machine::SimError;
use crate::util::fmt::TextTable;

/// One candidate's scorecard.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub arch: MemoryArchKind,
    pub total_cycles: u64,
    pub time_us: f64,
    /// Whole-processor ALM footprint at the workload's dataset size
    /// (`None` = the architecture cannot hold the dataset).
    pub footprint_alms: Option<u32>,
    /// 1 / (time × sectors); `None` past the capacity roofline.
    pub perf_per_area: Option<f64>,
}

/// The advisor's output: candidates sorted by time, plus the two
/// recommendations the paper's decision rule produces.
#[derive(Debug, Clone)]
pub struct Advice {
    pub program: String,
    pub dataset_kb: u32,
    pub candidates: Vec<Candidate>,
}

/// Candidate set: the paper's nine plus XOR-mapped banked variants.
pub fn candidate_archs() -> Vec<MemoryArchKind> {
    let mut v = MemoryArchKind::table3_nine();
    for banks in [4, 8, 16] {
        v.push(MemoryArchKind::Banked { banks, mapping: BankMapping::Xor });
    }
    v
}

/// Run the advisor for a registered program.
pub fn advise(program: &str) -> Result<Advice, SimError> {
    let workload = crate::programs::library::program_by_name(program)
        .ok_or_else(|| SimError::BadProgram(format!("unknown program '{program}'")))?;
    let dataset_kb = (workload.mem_words() * 4 / 1024) as u32;
    let mut candidates = Vec::new();
    for arch in candidate_archs() {
        let result = BenchJob::new(program, arch).run()?;
        let fp = footprint::processor_footprint(arch, dataset_kb);
        let time_us = result.report.time_us();
        candidates.push(Candidate {
            arch,
            total_cycles: result.report.total_cycles(),
            time_us,
            footprint_alms: fp.map(|f| f.total_alms()),
            perf_per_area: fp.map(|f| 1.0 / (time_us * f.sectors())),
        });
    }
    candidates.sort_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap());
    Ok(Advice { program: program.to_string(), dataset_kb, candidates })
}

impl Advice {
    /// Fastest architecture that can hold the dataset.
    pub fn fastest(&self) -> &Candidate {
        self.candidates
            .iter()
            .find(|c| c.footprint_alms.is_some())
            .expect("banked memories always fit the benchmark datasets")
    }

    /// Best performance per unit area (the paper's efficiency criterion).
    pub fn most_efficient(&self) -> &Candidate {
        self.candidates
            .iter()
            .max_by(|a, b| {
                a.perf_per_area
                    .unwrap_or(0.0)
                    .partial_cmp(&b.perf_per_area.unwrap_or(0.0))
                    .unwrap()
            })
            .unwrap()
    }

    /// Render the scorecard.
    pub fn render(&self) -> String {
        let mut t = TextTable::new([
            "memory", "cycles", "time (us)", "ALMs", "perf/area",
        ]);
        for c in &self.candidates {
            t.row([
                c.arch.label(),
                c.total_cycles.to_string(),
                format!("{:.2}", c.time_us),
                c.footprint_alms
                    .map(|a| a.to_string())
                    .unwrap_or_else(|| "over cap".into()),
                c.perf_per_area
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        format!(
            "advisor: {} ({} KB dataset)\n{}\nfastest: {}   most perf/area: {}\n",
            self.program,
            self.dataset_kb,
            t.render(),
            self.fastest().arch.label(),
            self.most_efficient().arch.label(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advises_transpose32() {
        let advice = advise("transpose32").unwrap();
        assert_eq!(advice.candidates.len(), 12);
        // Sorted by time.
        for w in advice.candidates.windows(2) {
            assert!(w[0].time_us <= w[1].time_us);
        }
        let out = advice.render();
        assert!(out.contains("fastest:"));
        assert!(out.contains("XOR"));
    }

    #[test]
    fn advises_fft_and_prefers_offset16_among_paper_nine() {
        let advice = advise("fft4096r16").unwrap();
        // Among the paper's nine, Table III's winner heads the ranking...
        let paper_nine = MemoryArchKind::table3_nine();
        let fastest_paper = advice
            .candidates
            .iter()
            .find(|c| paper_nine.contains(&c.arch))
            .unwrap();
        assert_eq!(fastest_paper.arch.label(), "16 Banks Offset");
        // ...and the XOR extension beats it outright (it randomizes the
        // power-of-two stride conflicts the Offset map only shifts) —
        // the §VII "varying the bank mapping" headroom, quantified in
        // EXPERIMENTS.md §Extensions.
        let fastest = advice.fastest();
        if let MemoryArchKind::Banked { banks, mapping } = fastest.arch {
            assert_eq!(banks, 16);
            assert!(matches!(mapping, BankMapping::Xor | BankMapping::Offset));
        } else {
            panic!("a banked memory must win the FFT");
        }
        // Smaller banked cores win perf/area (Fig. 9's observation).
        let eff = advice.most_efficient();
        if let MemoryArchKind::Banked { banks, .. } = eff.arch {
            assert!(banks <= 8, "perf/area winner should be a small banked core");
        }
    }

    #[test]
    fn unknown_program_errors() {
        assert!(advise("nope").is_err());
    }
}
