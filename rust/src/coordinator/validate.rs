//! End-to-end validation: every benchmark program, on every memory
//! architecture, must compute the same answer — and that answer must match
//! the golden models (host reference always; PJRT artifacts when built).

use crate::mem::arch::MemoryArchKind;
use crate::programs::fft::{digit_reverse, fft_program, reference_fft};
use crate::programs::transpose::{transpose_program, TransposePlan};
use crate::runtime::golden;
use crate::runtime::ArtifactRuntime;
use crate::sim::config::MachineConfig;
use crate::sim::machine::Machine;
use crate::util::XorShift64;

/// Outcome of one validation check.
#[derive(Debug, Clone)]
pub struct Check {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

impl Check {
    fn pass(name: impl Into<String>, detail: impl Into<String>) -> Self {
        Self { name: name.into(), passed: true, detail: detail.into() }
    }
    fn fail(name: impl Into<String>, detail: impl Into<String>) -> Self {
        Self { name: name.into(), passed: false, detail: detail.into() }
    }
}

/// Validate the transpose programs against a host transpose on every
/// Table II architecture.
pub fn validate_transposes(rt: Option<&ArtifactRuntime>) -> Vec<Check> {
    let mut checks = Vec::new();
    for n in [32u32, 64, 128] {
        let plan = TransposePlan::new(n);
        let program = transpose_program(n);
        let mut rng = XorShift64::new(1000 + n as u64);
        let src: Vec<f32> = rng.f32_vec((n * n) as usize);
        for arch in MemoryArchKind::table2_eight() {
            let cfg = MachineConfig::for_arch(arch)
                .with_mem_words((plan.words as usize).next_power_of_two())
                .with_fast_timing();
            let mut m = Machine::new(cfg);
            m.load_f32_image(plan.src_base, &src);
            let name = format!("transpose{n} on {arch}");
            if let Err(e) = m.run_program(&program) {
                checks.push(Check::fail(name, e.to_string()));
                continue;
            }
            let out = m.read_f32_image(plan.dst_base, (n * n) as usize);
            let host_ok = (0..n as usize).all(|i| {
                (0..n as usize).all(|j| out[j * n as usize + i] == src[i * n as usize + j])
            });
            if !host_ok {
                checks.push(Check::fail(name, "mismatch vs host transpose"));
                continue;
            }
            // Against the PJRT golden artifact, when available.
            if let Some(rt) = rt.filter(|rt| rt.has_artifact(&format!("transpose{n}"))) {
                match golden::golden_transpose(rt, n as usize, &src) {
                    Ok(g) => {
                        if g == out {
                            checks.push(Check::pass(name, "host + PJRT golden agree"));
                        } else {
                            checks.push(Check::fail(name, "mismatch vs PJRT golden"));
                        }
                    }
                    Err(e) => checks.push(Check::fail(name, format!("golden error: {e:#}"))),
                }
            } else {
                checks.push(Check::pass(name, "host golden agrees (no artifact)"));
            }
        }
    }
    checks
}

/// Validate the FFT programs against the host reference FFT (and the PJRT
/// golden FFT when built) on every Table III architecture.
pub fn validate_ffts(rt: Option<&ArtifactRuntime>) -> Vec<Check> {
    let mut checks = Vec::new();
    for radix in [4u32, 8, 16] {
        let (plan, program) = fft_program(radix);
        let mut rng = XorShift64::new(2000 + radix as u64);
        let n = plan.n as usize;
        let re: Vec<f32> = rng.f32_vec(n);
        let im: Vec<f32> = rng.f32_vec(n);
        let mut interleaved = Vec::with_capacity(2 * n);
        for i in 0..n {
            interleaved.push(re[i]);
            interleaved.push(im[i]);
        }
        let (hr, hi) = reference_fft(&re, &im);
        for arch in MemoryArchKind::table3_nine() {
            let cfg = MachineConfig::for_arch(arch)
                .with_mem_words(plan.mem_words())
                .with_tw_region(plan.tw_region())
                .with_fast_timing();
            let mut m = Machine::new(cfg);
            m.load_f32_image(plan.data_base, &interleaved);
            m.load_f32_image(plan.tw_base, &plan.twiddles);
            let name = format!("fft4096r{radix} on {arch}");
            if let Err(e) = m.run_program(&program) {
                checks.push(Check::fail(name, e.to_string()));
                continue;
            }
            let out = m.read_f32_image(plan.data_base, 2 * n);
            let mut max_err = 0.0f64;
            let mut max_mag = 1e-30f64;
            for k in 0..n {
                let p = digit_reverse(k as u32, plan.radix, plan.stages) as usize;
                let e = ((out[2 * p] as f64 - hr[k]).powi(2)
                    + (out[2 * p + 1] as f64 - hi[k]).powi(2))
                .sqrt();
                max_err = max_err.max(e);
                max_mag = max_mag.max((hr[k].powi(2) + hi[k].powi(2)).sqrt());
            }
            let rel = max_err / max_mag;
            if rel > 2e-5 {
                checks.push(Check::fail(name, format!("host rel err {rel:.2e}")));
                continue;
            }
            if let Some(rt) = rt.filter(|rt| rt.has_artifact("fft4096")) {
                match golden::validate_fft(rt, &m, &plan, &re, &im) {
                    Ok(rel) if rel < 2e-5 => {
                        checks.push(Check::pass(name, format!("PJRT golden rel err {rel:.2e}")))
                    }
                    Ok(rel) => {
                        checks.push(Check::fail(name, format!("PJRT golden rel err {rel:.2e}")))
                    }
                    Err(e) => checks.push(Check::fail(name, format!("golden error: {e:#}"))),
                }
            } else {
                checks.push(Check::pass(name, format!("host rel err {rel:.2e} (no artifact)")));
            }
        }
    }
    checks
}

/// The architecture slate the registry-driven validator covers: the
/// paper's nine plus the parametric extremes the explorer sweeps (2 and
/// 32 banks, XOR mapping).
pub fn workload_validation_archs() -> Vec<MemoryArchKind> {
    let mut archs = MemoryArchKind::table3_nine();
    archs.push(MemoryArchKind::banked(2));
    archs.push(MemoryArchKind::banked(32));
    archs.push(MemoryArchKind::banked_xor(16));
    archs
}

/// Validate every registry **extension** member against its exact
/// host-reference image on [`workload_validation_archs`]. The paper
/// families keep their specialized validators ([`validate_transposes`],
/// [`validate_ffts`] — the latter by tolerance, f32 pipelines have no
/// exact image), so no member is simulated twice. Purely host-side —
/// the extension kernels have no PJRT artifacts — and enumerated from
/// the registry, so a newly registered kernel is validated without
/// touching this module.
pub fn validate_workloads(_rt: Option<&ArtifactRuntime>) -> Vec<Check> {
    use crate::programs::registry;
    let mut checks = Vec::new();
    let members = registry::families()
        .iter()
        .filter(|fam| !fam.paper)
        .flat_map(|fam| fam.sweep_members());
    for (idx, member) in members.enumerate() {
        let Some(workload) = registry::program_by_name(&member) else {
            checks.push(Check::fail(member, "workload failed to build"));
            continue;
        };
        let seed = 3000 + idx as u64;
        let Some(expected) = workload.expected_image(seed) else {
            checks.push(Check::fail(member, "extension members must carry a host reference"));
            continue;
        };
        for arch in workload_validation_archs() {
            let cfg = MachineConfig::for_arch(arch)
                .with_mem_words(workload.mem_words())
                .with_fast_timing();
            let mut m = Machine::new(cfg);
            workload.load_input(&mut m, seed);
            let name = format!("{member} on {arch}");
            if let Err(e) = m.run_program(workload.program()) {
                checks.push(Check::fail(name, e.to_string()));
                continue;
            }
            let got = m.read_image(expected.base, expected.words.len());
            if got == expected.words {
                checks.push(Check::pass(
                    name,
                    format!("host reference agrees ({} words)", expected.words.len()),
                ));
            } else {
                let bad = got.iter().zip(&expected.words).position(|(g, e)| g != e).unwrap();
                checks.push(Check::fail(
                    name,
                    format!(
                        "word {} (addr {}): {:#x} != host {:#x}",
                        bad,
                        expected.base + bad as u32,
                        got[bad],
                        expected.words[bad]
                    ),
                ));
            }
        }
    }
    checks
}

/// Cross-check the Pallas conflict oracle against the cycle-accurate L3
/// conflict model on random operation batches.
pub fn validate_conflict_oracle(rt: &ArtifactRuntime, seed: u64) -> Vec<Check> {
    use crate::mem::conflict::max_conflicts;
    use crate::mem::mapping::{BankMap, BankMapping};
    use crate::mem::{FULL_MASK, LANES};
    let mut checks = Vec::new();
    let mut rng = XorShift64::new(seed);
    for banks in [4u32, 8, 16] {
        let name = format!("conflict oracle {banks} banks");
        if !rt.has_artifact(&format!("conflict{banks}")) {
            checks.push(Check::pass(name, "artifact not built; skipped"));
            continue;
        }
        let ops: Vec<[u32; LANES]> = (0..512)
            .map(|_| {
                let mut a = [0u32; LANES];
                for x in a.iter_mut() {
                    *x = rng.below(1 << 14);
                }
                a
            })
            .collect();
        let mut ok = true;
        for mapping in [BankMapping::Lsb, BankMapping::offset()] {
            let map = BankMap::new(banks, mapping);
            match golden::conflict_oracle(rt, banks, &ops, mapping.shift()) {
                Ok(oracle) => {
                    for (op, &o) in ops.iter().zip(&oracle) {
                        let l3 = max_conflicts(op, FULL_MASK, &map);
                        if l3 != o {
                            checks.push(Check::fail(
                                name.clone(),
                                format!("{mapping:?}: oracle {o} != simulator {l3}"),
                            ));
                            ok = false;
                            break;
                        }
                    }
                }
                Err(e) => {
                    checks.push(Check::fail(name.clone(), format!("{e:#}")));
                    ok = false;
                }
            }
        }
        if ok {
            checks.push(Check::pass(name, "1024 random ops agree (LSB + Offset)"));
        }
    }
    checks
}

/// Run the whole validation suite. `rt` enables the PJRT-artifact checks.
pub fn validate_all(rt: Option<&ArtifactRuntime>) -> Vec<Check> {
    let mut checks = validate_transposes(rt);
    checks.extend(validate_ffts(rt));
    checks.extend(validate_workloads(rt));
    if let Some(rt) = rt {
        checks.extend(validate_conflict_oracle(rt, 0xC0DE));
    }
    checks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transposes_validate_without_artifacts() {
        let checks = validate_transposes(None);
        assert_eq!(checks.len(), 24);
        for c in &checks {
            assert!(c.passed, "{}: {}", c.name, c.detail);
        }
    }

    // The registry-driven workload validation (every non-FFT member ×
    // 12 architectures) and the FFT validation across all nine
    // architectures are covered by rust/tests/validation.rs (they are
    // the long poles of the unit suite).
}
