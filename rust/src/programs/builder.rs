//! A small codegen layer over the ISA: register pool, labels, FP-constant
//! materialization and complex arithmetic emitters.
//!
//! Multiplication by `-i` is handled by *register renaming* (swap re/im
//! and negate), the trick a hand assembler would use; the emitters
//! therefore operate on [`CReg`] descriptors rather than fixed register
//! pairs.

use crate::isa::inst::{Instruction, NUM_REGS};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;

/// A complex value held in two scalar registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CReg {
    pub re: u8,
    pub im: u8,
}

/// Builder for assembler programs.
pub struct ProgramBuilder {
    name: String,
    threads: u32,
    insts: Vec<Instruction>,
    /// Registers available for allocation (stack).
    free: Vec<u8>,
}

impl ProgramBuilder {
    pub fn new(name: impl Into<String>, threads: u32) -> Self {
        Self {
            name: name.into(),
            threads,
            insts: Vec::new(),
            // r0 is conventionally the tid; allocate from r1 upward.
            free: (1..NUM_REGS as u8).rev().collect(),
        }
    }

    /// Allocate a scalar register.
    pub fn alloc(&mut self) -> u8 {
        self.free.pop().expect("register pool exhausted")
    }

    /// Release a scalar register.
    pub fn release(&mut self, r: u8) {
        debug_assert!(!self.free.contains(&r), "double free of r{r}");
        self.free.push(r);
    }

    /// Allocate a complex register pair.
    pub fn alloc_c(&mut self) -> CReg {
        CReg { re: self.alloc(), im: self.alloc() }
    }

    /// Release a complex register pair.
    pub fn release_c(&mut self, c: CReg) {
        self.release(c.re);
        self.release(c.im);
    }

    /// Registers still free (codegen budget assertions).
    pub fn free_regs(&self) -> usize {
        self.free.len()
    }

    /// Current instruction count (next emission PC — label use).
    pub fn pc(&self) -> u16 {
        self.insts.len() as u16
    }

    pub fn emit(&mut self, inst: Instruction) {
        self.insts.push(inst);
    }

    // --- scalar helpers ------------------------------------------------

    pub fn tid(&mut self, rd: u8) {
        self.emit(Instruction::i(Opcode::Tid, rd, 0, 0));
    }

    pub fn ldi(&mut self, rd: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Ldi, rd, 0, imm));
    }

    /// Materialize an arbitrary 32-bit constant (1 or 2 Imm ops).
    pub fn const32(&mut self, rd: u8, value: u32) {
        self.ldi(rd, value as u16);
        if value >> 16 != 0 {
            self.emit(Instruction::i(Opcode::Lui, rd, 0, (value >> 16) as u16));
        }
    }

    /// Materialize an IEEE-754 f32 constant bit-exactly (2 Imm ops; the
    /// LUI path is always needed for a non-zero exponent).
    pub fn fconst(&mut self, rd: u8, value: f32) {
        let bits = value.to_bits();
        self.ldi(rd, bits as u16);
        self.emit(Instruction::i(Opcode::Lui, rd, 0, (bits >> 16) as u16));
    }

    pub fn iaddi(&mut self, rd: u8, ra: u8, imm: i32) {
        assert!((-32768..=32767).contains(&imm) || (0..=65535).contains(&imm));
        self.emit(Instruction::i(Opcode::Iaddi, rd, ra, imm as u16));
    }

    pub fn imuli(&mut self, rd: u8, ra: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Imuli, rd, ra, imm));
    }

    pub fn iandi(&mut self, rd: u8, ra: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Iandi, rd, ra, imm));
    }

    pub fn ishli(&mut self, rd: u8, ra: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Ishli, rd, ra, imm));
    }

    pub fn ishri(&mut self, rd: u8, ra: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Ishri, rd, ra, imm));
    }

    pub fn ixori(&mut self, rd: u8, ra: u8, imm: u16) {
        self.emit(Instruction::i(Opcode::Ixori, rd, ra, imm));
    }

    pub fn iadd(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Iadd, rd, ra, rb));
    }

    pub fn isub(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Isub, rd, ra, rb));
    }

    pub fn iand(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Iand, rd, ra, rb));
    }

    pub fn ixor(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Ixor, rd, ra, rb));
    }

    // --- control flow ---------------------------------------------------

    /// Branch to `target` where `rd != 0` — per-lane: disagreeing lanes
    /// diverge and reconverge at the branch's post-dominator.
    pub fn bnz(&mut self, rd: u8, target: u16) {
        self.emit(Instruction::i(Opcode::Bnz, rd, 0, target));
    }

    /// Unconditional jump.
    pub fn jmp(&mut self, target: u16) {
        self.emit(Instruction::i(Opcode::Jmp, 0, 0, target));
    }

    /// Emit a forward branch whose target is not yet known; patch it with
    /// [`Self::patch_target`] once the label PC is reached.
    pub fn bnz_fwd(&mut self, rd: u8) -> u16 {
        let at = self.pc();
        self.bnz(rd, 0);
        at
    }

    /// Emit a forward jump whose target is not yet known.
    pub fn jmp_fwd(&mut self) -> u16 {
        let at = self.pc();
        self.jmp(0);
        at
    }

    /// Resolve a forward branch/jump emitted by [`Self::bnz_fwd`] /
    /// [`Self::jmp_fwd`] to `target`.
    pub fn patch_target(&mut self, at: u16, target: u16) {
        let inst = &mut self.insts[at as usize];
        assert!(
            matches!(inst.op, Opcode::Bnz | Opcode::Jmp),
            "patch_target on non-branch at pc {at}"
        );
        inst.imm = target;
    }

    pub fn ld(&mut self, rd: u8, raddr: u8) {
        self.emit(Instruction::i(Opcode::Ld, rd, raddr, 0));
    }

    pub fn st(&mut self, raddr: u8, rval: u8) {
        self.emit(Instruction::r(Opcode::St, 0, raddr, rval));
    }

    pub fn stnb(&mut self, raddr: u8, rval: u8) {
        self.emit(Instruction::r(Opcode::Stnb, 0, raddr, rval));
    }

    pub fn fadd(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Fadd, rd, ra, rb));
    }

    pub fn fsub(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Fsub, rd, ra, rb));
    }

    pub fn fmul(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Fmul, rd, ra, rb));
    }

    /// `rd = rd + ra·rb` (fused multiply-add).
    pub fn fma(&mut self, rd: u8, ra: u8, rb: u8) {
        self.emit(Instruction::r(Opcode::Fma, rd, ra, rb));
    }

    pub fn fneg(&mut self, rd: u8, ra: u8) {
        self.emit(Instruction::r(Opcode::Fneg, rd, ra, 0));
    }

    pub fn halt(&mut self) {
        self.emit(Instruction::z(Opcode::Halt));
    }

    // --- complex helpers (allocate destinations from the pool) ---------

    /// `dst = a + b` (2 FP ops).
    pub fn cadd(&mut self, dst: CReg, a: CReg, b: CReg) {
        self.fadd(dst.re, a.re, b.re);
        self.fadd(dst.im, a.im, b.im);
    }

    /// `dst = a - b` (2 FP ops).
    pub fn csub(&mut self, dst: CReg, a: CReg, b: CReg) {
        self.fsub(dst.re, a.re, b.re);
        self.fsub(dst.im, a.im, b.im);
    }

    /// `x *= (c_re, c_im)` in place, with two scratch registers
    /// (6 FP ops: 4 mul, 1 sub, 1 add).
    pub fn cmul_inplace(&mut self, x: CReg, c_re: u8, c_im: u8, t0: u8, t1: u8) {
        self.fmul(t0, x.re, c_im); // t0 = re·ci (cross term, saved)
        self.fmul(x.re, x.re, c_re); // re = re·cr
        self.fmul(t1, x.im, c_im); // t1 = im·ci
        self.fsub(x.re, x.re, t1); // re = re·cr − im·ci
        self.fmul(t1, x.im, c_re); // t1 = im·cr
        self.fadd(x.im, t0, t1); // im = re·ci + im·cr
    }

    /// `x *= -i` — free: rename (re,im) → (im,−re) with one FNEG.
    pub fn cmul_negi(&mut self, x: CReg) -> CReg {
        self.fneg(x.re, x.re);
        CReg { re: x.im, im: x.re }
    }

    /// Finish: returns the program.
    pub fn build(mut self) -> Program {
        assert!(
            matches!(self.insts.last(), Some(i) if i.op == Opcode::Halt),
            "program must end with halt"
        );
        let insts = std::mem::take(&mut self.insts);
        Program::new(self.name.clone(), self.threads, insts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_and_read(b: ProgramBuilder, n: usize) -> Vec<f32> {
        let p = b.build();
        let mut m =
            Machine::new(MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(4096));
        m.run_program(&p).expect("runs");
        m.read_f32_image(0, n)
    }

    #[test]
    fn fconst_is_bit_exact() {
        let mut b = ProgramBuilder::new("fc", 16);
        let c = b.alloc();
        let a = b.alloc();
        b.fconst(c, std::f32::consts::FRAC_1_SQRT_2);
        b.tid(a);
        b.st(a, c);
        b.halt();
        let out = run_and_read(b, 1);
        assert_eq!(out[0].to_bits(), std::f32::consts::FRAC_1_SQRT_2.to_bits());
    }

    #[test]
    fn cmul_matches_complex_arithmetic() {
        // (3 + 4i) · (0.6 − 0.8i) = (1.8+3.2) + (−2.4+2.4)i = 5 + 0i
        let mut b = ProgramBuilder::new("cm", 16);
        let x = b.alloc_c();
        let (cr, ci) = (b.alloc(), b.alloc());
        let (t0, t1) = (b.alloc(), b.alloc());
        let addr = b.alloc();
        b.fconst(x.re, 3.0);
        b.fconst(x.im, 4.0);
        b.fconst(cr, 0.6);
        b.fconst(ci, -0.8);
        b.cmul_inplace(x, cr, ci, t0, t1);
        b.tid(addr);
        b.ishli(addr, addr, 1);
        b.st(addr, x.re);
        b.iaddi(addr, addr, 1);
        b.st(addr, x.im);
        b.halt();
        let out = run_and_read(b, 2);
        assert!((out[0] - 5.0).abs() < 1e-5, "re = {}", out[0]);
        assert!(out[1].abs() < 1e-5, "im = {}", out[1]);
    }

    #[test]
    fn cmul_negi_renames() {
        // (2 + 3i)·(−i) = 3 − 2i, via renaming.
        let mut b = ProgramBuilder::new("negi", 16);
        let x = b.alloc_c();
        let addr = b.alloc();
        b.fconst(x.re, 2.0);
        b.fconst(x.im, 3.0);
        let y = b.cmul_negi(x);
        b.tid(addr);
        b.ishli(addr, addr, 1);
        b.st(addr, y.re);
        b.iaddi(addr, addr, 1);
        b.st(addr, y.im);
        b.halt();
        let out = run_and_read(b, 2);
        assert_eq!(out[0], 3.0);
        assert_eq!(out[1], -2.0);
    }

    #[test]
    fn fma_accumulates_into_rd() {
        // rd = rd + ra·rb, the contract the GEMM kernel's inner loop
        // (and its bit-exact host reference) depends on.
        let mut b = ProgramBuilder::new("fma", 16);
        let acc = b.alloc();
        let (x, y) = (b.alloc(), b.alloc());
        let addr = b.alloc();
        b.fconst(acc, 10.0);
        b.fconst(x, 3.0);
        b.fconst(y, 4.0);
        b.fma(acc, x, y);
        b.tid(addr);
        b.st(addr, acc);
        b.halt();
        let out = run_and_read(b, 1);
        assert_eq!(out[0], 22.0);
    }

    #[test]
    fn alloc_release_reuses() {
        let mut b = ProgramBuilder::new("a", 16);
        let before = b.free_regs();
        let r = b.alloc();
        assert_eq!(b.free_regs(), before - 1);
        b.release(r);
        assert_eq!(b.free_regs(), before);
    }

    #[test]
    #[should_panic(expected = "must end with halt")]
    fn build_requires_halt() {
        let b = ProgramBuilder::new("nohalt", 16);
        let _ = b.build();
    }

    #[test]
    fn const32_small_is_one_op() {
        let mut b = ProgramBuilder::new("c", 16);
        let r = b.alloc();
        b.const32(r, 42);
        assert_eq!(b.pc(), 1);
        b.const32(r, 0x12345);
        assert_eq!(b.pc(), 3);
        b.halt();
    }
}
