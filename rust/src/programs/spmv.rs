//! CSR sparse matrix–vector product — divergent loop trip counts plus a
//! data-dependent gather.
//!
//! One thread per row of an N-row CSR matrix with *skewed* row lengths:
//! every 16th row carries [`HEAVY_LEN`] nonzeros, the rest [`LIGHT_LEN`]
//! — one heavy lane per warp, the worst case for lockstep execution.
//! Each thread loads its row's length and start offset *from memory* and
//! runs a data-driven accumulation loop (`bnz cnt, body`): light lanes
//! fall out after 4 trips while the heavy lane keeps the block looping to
//! 32, so the loop body's memory ops issue under progressively sparser
//! masks. The `x[col[k]]` gather inside the body hits banks decided by
//! the random column indices — the data-dependent conflict profile the
//! paper's configurable memories are for.
//!
//! Memory image (word addresses, `nnz = 23·N/4`):
//!
//! | region | range                    |
//! |--------|--------------------------|
//! | x      | `[0, N)`                 |
//! | y      | `[N, 2N)`                |
//! | len    | `[2N, 3N)`               |
//! | ptr    | `[3N, 4N)`               |
//! | col    | `[4N, 4N+nnz)`           |
//! | val    | `[4N+nnz, 4N+2nnz)` (f32)|
//!
//! The host reference accumulates with `f32::mul_add` in the same order
//! as the kernel's `fma`, so machine and host images match bit for bit.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::XorShift64;

/// Nonzeros in a heavy row (rows `r % 16 == 0` — lane 0 of every warp).
pub const HEAVY_LEN: u32 = 32;
/// Nonzeros in every other row.
pub const LIGHT_LEN: u32 = 4;

/// Placement metadata for an SpMV run.
#[derive(Debug, Clone, Copy)]
pub struct SpmvPlan {
    /// Rows N = thread count (power of two, 64..=2048).
    pub n: u32,
    /// Total nonzeros across all rows.
    pub nnz: u32,
}

impl SpmvPlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (64..=2048).contains(&n));
        let heavy = n / 16;
        Self { n, nnz: heavy * HEAVY_LEN + (n - heavy) * LIGHT_LEN }
    }

    pub fn row_len(&self, r: u32) -> u32 {
        if r % 16 == 0 {
            HEAVY_LEN
        } else {
            LIGHT_LEN
        }
    }

    /// CSR row-start offsets (deterministic: lengths depend only on N).
    pub fn row_ptrs(&self) -> Vec<u32> {
        let mut ptrs = Vec::with_capacity(self.n as usize);
        let mut at = 0u32;
        for r in 0..self.n {
            ptrs.push(at);
            at += self.row_len(r);
        }
        ptrs
    }

    pub fn y_base(&self) -> u32 {
        self.n
    }
    pub fn len_base(&self) -> u32 {
        2 * self.n
    }
    pub fn ptr_base(&self) -> u32 {
        3 * self.n
    }
    pub fn col_base(&self) -> u32 {
        4 * self.n
    }
    pub fn val_base(&self) -> u32 {
        4 * self.n + self.nnz
    }
    /// Words the image occupies (before rounding to a power of two).
    pub fn words(&self) -> u32 {
        4 * self.n + 2 * self.nnz
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (64..=2048).contains(&n)
}

/// Generate the SpMV program for an N-row matrix.
pub fn spmv_program(n: u32) -> (SpmvPlan, Program) {
    let plan = SpmvPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &SpmvPlan) -> Program {
    let mut b = ProgramBuilder::new(format!("spmv{}", plan.n), plan.n);

    let tid = 0u8; // conventional: one thread per row
    b.tid(tid);
    let addr = b.alloc();
    let cnt = b.alloc();
    let cp = b.alloc();
    let vp = b.alloc();
    let col = b.alloc();
    let xv = b.alloc();
    let vv = b.alloc();
    let acc = b.alloc();

    // Row descriptor loads: trip count and start offset come from memory,
    // so the loop below is genuinely data-driven.
    b.iaddi(addr, tid, plan.len_base() as i32);
    b.ld(cnt, addr);
    b.iaddi(addr, tid, plan.ptr_base() as i32);
    b.ld(cp, addr);
    b.iaddi(vp, cp, plan.val_base() as i32);
    b.iaddi(cp, cp, plan.col_base() as i32);
    b.fconst(acc, 0.0);

    // Do-while over the row's nonzeros (every row has at least one).
    // Light lanes retire after 4 trips; the heavy lane in each warp keeps
    // the block looping to 32 under shrinking masks.
    let body = b.pc();
    b.ld(col, cp); // column index
    b.ld(xv, col); // x gather — banks decided by the data
    b.ld(vv, vp);
    b.fma(acc, vv, xv); // acc += val·x, host order identical
    b.iaddi(cp, cp, 1);
    b.iaddi(vp, vp, 1);
    b.iaddi(cnt, cnt, -1);
    b.bnz(cnt, body);

    b.iaddi(addr, tid, plan.y_base() as i32);
    b.st(addr, acc);
    b.halt();
    b.build()
}

/// Deterministic-given-seed CSR content: column indices, values, and the
/// dense vector. Shared by the fill and the host reference so both draw
/// the identical stream.
fn gen_input(plan: &SpmvPlan, seed: u64) -> (Vec<u32>, Vec<f32>, Vec<f32>) {
    let mut rng = XorShift64::new(seed);
    let cols: Vec<u32> = (0..plan.nnz).map(|_| rng.below(plan.n)).collect();
    let vals: Vec<f32> = (0..plan.nnz).map(|_| rng.signed_f32()).collect();
    let x: Vec<f32> = (0..plan.n).map(|_| rng.signed_f32()).collect();
    (cols, vals, x)
}

/// Host reference: per-row sequential `mul_add` in nonzero order — the
/// exact FP sequence the kernel's `fma` loop performs per lane.
pub fn reference_spmv(plan: &SpmvPlan, cols: &[u32], vals: &[f32], x: &[f32]) -> Vec<f32> {
    let ptrs = plan.row_ptrs();
    (0..plan.n)
        .map(|r| {
            let start = ptrs[r as usize] as usize;
            let end = start + plan.row_len(r) as usize;
            let mut acc = 0.0f32;
            for k in start..end {
                acc = vals[k].mul_add(x[cols[k] as usize], acc);
            }
            acc
        })
        .collect()
}

/// Build the registered workload for `spmv{n}`.
pub fn workload(n: u32) -> Workload {
    let plan = SpmvPlan::new(n);
    let (_, program) = spmv_program(n);
    Workload::new(program, (plan.words() as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let (cols, vals, x) = gen_input(&plan, seed);
            for (i, &v) in x.iter().enumerate() {
                mem.write_word(i as u32, v.to_bits());
            }
            for r in 0..plan.n {
                mem.write_word(plan.len_base() + r, plan.row_len(r));
            }
            for (r, &p) in plan.row_ptrs().iter().enumerate() {
                mem.write_word(plan.ptr_base() + r as u32, p);
            }
            for (k, &c) in cols.iter().enumerate() {
                mem.write_word(plan.col_base() + k as u32, c);
            }
            for (k, &v) in vals.iter().enumerate() {
                mem.write_word(plan.val_base() + k as u32, v.to_bits());
            }
        })
        .with_expected(move |seed| {
            let (cols, vals, x) = gen_input(&plan, seed);
            let y = reference_spmv(&plan, &cols, &vals, &x);
            ExpectedImage {
                base: plan.y_base(),
                words: y.iter().map(|v| v.to_bits()).collect(),
            }
        })
}

/// Analytical golden model: the loop always runs to the heavy length
/// (every warp holds a heavy lane, so the block never exits earlier) and
/// each executed memory/FP instruction issues one op slot per warp
/// regardless of mask: 2 descriptor loads + 3 loads and 1 fma per trip,
/// one store.
pub fn model(n: u32) -> OpCountModel {
    let warps = n as u64 / 16;
    let trips = HEAVY_LEN as u64;
    OpCountModel {
        d_load_ops: (2 + 3 * trips) * warps,
        tw_load_ops: 0,
        store_ops: warps,
        fp_ops: trips * warps,
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "spmv",
    prefix: "spmv",
    title: "CSR SpMV (skewed rows)",
    grammar: "spmvN — N rows, power of two, 64..=2048",
    valid,
    build: workload,
    model,
    sweep_params: &[256, 1024],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_spmv(n: u32, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let plan = SpmvPlan::new(n);
        let w = workload(n);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch).with_mem_words(w.mem_words()).with_fast_timing(),
        );
        w.load_input(&mut m, seed);
        m.run_program(w.program()).expect("spmv runs");
        let (cols, vals, x) = gen_input(&plan, seed);
        let want: Vec<u32> =
            reference_spmv(&plan, &cols, &vals, &x).iter().map(|v| v.to_bits()).collect();
        (m.read_image(plan.y_base(), plan.n as usize), want)
    }

    #[test]
    fn bit_exact_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (got, want) = run_spmv(128, arch, 9);
            assert_eq!(got, want, "{arch}");
        }
    }

    #[test]
    fn bit_exact_across_seeds() {
        for seed in [1, 3, 77] {
            let (got, want) = run_spmv(256, MemoryArchKind::mp_4r1w(), seed);
            assert_eq!(got, want, "seed {seed}");
        }
    }

    #[test]
    fn model_matches_traced_ops() {
        let w = workload(256);
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(16))
                .with_mem_words(w.mem_words())
                .with_fast_timing(),
        );
        w.load_input(&mut m, 5);
        m.run_program(w.program()).expect("runs");
        let trace = m.mem_trace().expect("trace captured");
        assert_eq!(OpCountModel::of_trace(trace), model(256));
    }

    #[test]
    fn skew_gives_one_heavy_lane_per_warp() {
        let plan = SpmvPlan::new(256);
        assert_eq!(plan.nnz, 23 * 256 / 4);
        assert_eq!(plan.row_len(0), HEAVY_LEN);
        assert_eq!(plan.row_len(16), HEAVY_LEN);
        assert_eq!(plan.row_len(1), LIGHT_LEN);
        let ptrs = plan.row_ptrs();
        assert_eq!(ptrs[0], 0);
        assert_eq!(ptrs[1], HEAVY_LEN);
        assert_eq!(*ptrs.last().unwrap() + plan.row_len(plan.n - 1), plan.nnz);
    }

    #[test]
    #[should_panic]
    fn too_small_rejected() {
        SpmvPlan::new(32);
    }
}
