//! The workload registry — the single, data-driven source of truth for
//! every benchmark the suite knows.
//!
//! Each kernel module exports one [`KernelFamily`]: a name grammar
//! (`<prefix><param>` plus a validity predicate), a workload builder, an
//! **analytical golden model** (closed-form 16-lane operation counts,
//! asserted against the functional executor in `rust/tests/registry.rs`
//! — so every kernel's correctness is pinned independently of timing),
//! and the members + architecture slate it contributes to the benchmark
//! matrix. Everything that used to keep its own hand-written workload
//! list — `library::is_known_program`, `BenchJob::paper_sweep` /
//! `extended_sweep`, `validate`, the `--all` report tables, the service
//! `List` — enumerates from [`REGISTRY`] instead, so the lists can never
//! drift (`rust/tests/registry.rs` asserts there are no stragglers).

use crate::isa::program::Program;
use crate::mem::arch::MemoryArchKind;
use crate::sim::exec::{ExecMemory, LoadClass, MemAccessKind, MemTrace};
use std::ops::Range;

use super::{bitonic, fft, gemm, histogram, reduction, scan, spmv, stencil, transpose};

/// A buildable benchmark: the generated program plus the workload
/// metadata the harness needs (memory capacity, twiddle region, input
/// image, host reference). Construction is by the builder methods so a
/// kernel module states only what it has (an FFT has a twiddle region
/// and no exact host image; an integer kernel has the reverse).
pub struct Workload {
    program: Program,
    mem_words: usize,
    tw_region: Option<Range<u32>>,
    fill: Box<dyn Fn(&mut dyn ExecMemory, u64) + Send + Sync>,
    expected: Option<Box<dyn Fn(u64) -> ExpectedImage + Send + Sync>>,
    scalar_addr: Option<u32>,
}

/// A host-reference result region: `words[i]` is the expected content of
/// shared-memory address `base + i` after the program runs on an input
/// image derived from the same seed.
pub struct ExpectedImage {
    pub base: u32,
    pub words: Vec<u32>,
}

impl Workload {
    /// A workload with no input image and no host reference (builder
    /// methods add both). `mem_words` must be a power of two.
    pub fn new(program: Program, mem_words: usize) -> Self {
        debug_assert!(mem_words.is_power_of_two());
        Self {
            program,
            mem_words,
            tw_region: None,
            fill: Box::new(|_, _| {}),
            expected: None,
            scalar_addr: None,
        }
    }

    /// Twiddle region for load classification (FFTs only).
    pub fn with_tw_region(mut self, region: Range<u32>) -> Self {
        self.tw_region = Some(region);
        self
    }

    /// The deterministic input-image filler (see [`Self::load_input`]).
    pub fn with_fill(
        mut self,
        fill: impl Fn(&mut dyn ExecMemory, u64) + Send + Sync + 'static,
    ) -> Self {
        self.fill = Box::new(fill);
        self
    }

    /// The host-reference result region for a given input seed.
    pub fn with_expected(
        mut self,
        expected: impl Fn(u64) -> ExpectedImage + Send + Sync + 'static,
    ) -> Self {
        self.expected = Some(Box::new(expected));
        self
    }

    /// Address within the expected region whose value is the workload's
    /// scalar result (reductions: the sum; scans: the running total).
    pub fn with_scalar_at(mut self, addr: u32) -> Self {
        self.scalar_addr = Some(addr);
        self
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn name(&self) -> &str {
        &self.program.name
    }

    /// Shared-memory words required (power of two).
    pub fn mem_words(&self) -> usize {
        self.mem_words
    }

    /// Dataset size in KB — the capacity the footprint model charges for
    /// holding this workload (shared by the advisor, the explorer CLI
    /// and the trace-derived figure in `explore::Evaluator`).
    pub fn dataset_kb(&self) -> u32 {
        (self.mem_words * 4 / 1024) as u32
    }

    /// Twiddle region for load classification (FFTs only).
    pub fn tw_region(&self) -> Option<Range<u32>> {
        self.tw_region.clone()
    }

    /// Deterministically fill `mem` with this workload's input image,
    /// derived from `seed`.
    ///
    /// Input data never changes *timing* for the address-driven kernels
    /// (and determinism keeps functional validation and trace-cache keys
    /// exact either way): the same `(program, seed)` pair always produces
    /// the same memory image, hence the same trace.
    pub fn load_input<M: ExecMemory>(&self, mem: &mut M, seed: u64) {
        (self.fill)(mem, seed);
    }

    /// Host-reference expected contents of the result region, when one
    /// exists. The FFTs return `None` (their f32 pipeline is validated
    /// against a tolerance, not bit-exactly — see
    /// [`crate::coordinator::validate::validate_ffts`]); every integer
    /// kernel and the bit-deterministic GEMM return the exact image.
    pub fn expected_image(&self, seed: u64) -> Option<ExpectedImage> {
        self.expected.as_ref().map(|f| f(seed))
    }

    /// Host-reference expected value at the workload's scalar result
    /// location, when one exists.
    pub fn expected_scalar(&self, seed: u64) -> Option<u32> {
        let addr = self.scalar_addr?;
        let img = self.expected_image(seed)?;
        Some(img.words[(addr - img.base) as usize])
    }
}

/// Closed-form operation counts for one benchmark member — the
/// analytical golden model. Units are **16-lane operations** (exactly
/// what [`crate::sim::stats::CycleStats`] counts in `d_load_ops` /
/// `tw_load_ops` / `store_ops`, and what `fp_cycles` charges — one cycle
/// per 16-wide FP operation on every architecture).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpCountModel {
    pub d_load_ops: u64,
    pub tw_load_ops: u64,
    pub store_ops: u64,
    /// 16-wide FP operations (`== stats.fp_cycles`).
    pub fp_ops: u64,
}

impl OpCountModel {
    /// Total memory operations.
    pub fn mem_ops(&self) -> u64 {
        self.d_load_ops + self.tw_load_ops + self.store_ops
    }

    /// The same counts, measured from a captured functional trace — the
    /// quantity the analytical model is asserted against.
    pub fn of_trace(trace: &MemTrace) -> Self {
        let mut m = OpCountModel { d_load_ops: 0, tw_load_ops: 0, store_ops: 0, fp_ops: 0 };
        for seg in &trace.segments {
            m.fp_ops += seg.before.fp_cycles;
            let ops = seg.mem.ops.len() as u64;
            match seg.mem.kind {
                MemAccessKind::Load(LoadClass::Data) => m.d_load_ops += ops,
                MemAccessKind::Load(LoadClass::Twiddle) => m.tw_load_ops += ops,
                MemAccessKind::Store { .. } => m.store_ops += ops,
            }
        }
        m.fp_ops += trace.tail.fp_cycles;
        m
    }
}

/// Which architecture slate a family's sweep members are timed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepArchs {
    /// Table II's eight (the transpose slate).
    Table2,
    /// Table III's nine (everything else).
    Table3,
}

impl SweepArchs {
    pub fn archs(self) -> Vec<MemoryArchKind> {
        match self {
            SweepArchs::Table2 => MemoryArchKind::table2_eight(),
            SweepArchs::Table3 => MemoryArchKind::table3_nine(),
        }
    }
}

/// One kernel family: the name grammar, builder, analytical model and
/// benchmark-matrix contribution of one kernel module. All fields are
/// plain data / fn pointers so registration is a `const` in the module
/// and the registry is a static array — adding a kernel is adding one
/// entry, never a new match arm.
pub struct KernelFamily {
    /// Family id, e.g. `"scan"`.
    pub family: &'static str,
    /// Member-name prefix: members are `<prefix><param>` (e.g.
    /// `scan4096`, `fft4096r8`).
    pub prefix: &'static str,
    /// Human title for report tables, e.g. `"Work-Efficient Prefix Sum"`.
    pub title: &'static str,
    /// Human-readable member grammar, for `list` and error hints.
    pub grammar: &'static str,
    /// Whether `param` names a buildable member.
    pub valid: fn(u32) -> bool,
    /// Build the member workload (param must satisfy [`Self::valid`]).
    pub build: fn(u32) -> Workload,
    /// The analytical golden model for a member.
    pub model: fn(u32) -> OpCountModel,
    /// Params of the members enumerated into the benchmark matrix
    /// (`sweep --all`, validation, the `list` payload).
    pub sweep_params: &'static [u32],
    /// Architecture slate those members are timed on.
    pub sweep_archs: SweepArchs,
    /// Paper benchmark (Tables II/III) vs suite extension.
    pub paper: bool,
}

impl KernelFamily {
    /// Member name for a param.
    pub fn name_of(&self, param: u32) -> String {
        format!("{}{}", self.prefix, param)
    }

    /// Sweep member names, in param order.
    pub fn sweep_members(&self) -> Vec<String> {
        self.sweep_params.iter().map(|&p| self.name_of(p)).collect()
    }
}

/// Every registered kernel family, in benchmark-matrix order (the two
/// paper families first, then the extensions; the divergent irregular
/// kernels close the list).
pub static REGISTRY: [KernelFamily; 9] = [
    transpose::FAMILY,
    fft::FAMILY,
    reduction::FAMILY,
    scan::FAMILY,
    histogram::FAMILY,
    stencil::FAMILY,
    gemm::FAMILY,
    bitonic::FAMILY,
    spmv::FAMILY,
];

/// The registered families.
pub fn families() -> &'static [KernelFamily] {
    &REGISTRY
}

/// Parse a program name into its family and parameter, without building
/// anything — the grammar check every consumer shares.
pub fn parse(name: &str) -> Option<(&'static KernelFamily, u32)> {
    for fam in &REGISTRY {
        if let Some(rest) = name.strip_prefix(fam.prefix) {
            // Strict canonical digits: `scan+4`, `scan 4` and the
            // zero-padded alias `scan064` are not member names — each
            // member has exactly one name, so it is exactly one
            // trace-cache key.
            if rest.is_empty()
                || !rest.bytes().all(|b| b.is_ascii_digit())
                || (rest.len() > 1 && rest.starts_with('0'))
            {
                continue;
            }
            let param: u32 = rest.parse().ok()?;
            return (fam.valid)(param).then_some((fam, param));
        }
    }
    None
}

/// Whether `name` is a buildable program, without building it — the
/// cheap validity probe the service layer's hot path uses (a warm cached
/// `run` must not pay codegen just to re-validate a name).
pub fn is_known_program(name: &str) -> bool {
    parse(name).is_some()
}

/// Build a workload by name.
pub fn program_by_name(name: &str) -> Option<Workload> {
    let (fam, param) = parse(name)?;
    Some((fam.build)(param))
}

/// The analytical golden model for a registered name.
pub fn model_by_name(name: &str) -> Option<OpCountModel> {
    let (fam, param) = parse(name)?;
    Some((fam.model)(param))
}

/// Every benchmark-matrix member name, in registry order — what `list`
/// reports and validation covers.
pub fn program_names() -> Vec<String> {
    REGISTRY.iter().flat_map(|f| f.sweep_members()).collect()
}

/// The benchmark matrix: every sweep member crossed with its family's
/// architecture slate, in registry order. `paper` filters to the
/// Tables II/III half (51 cells) or the extension half.
pub fn benchmark_matrix(paper: Option<bool>) -> Vec<(String, Vec<MemoryArchKind>)> {
    REGISTRY
        .iter()
        .filter(|f| match paper {
            None => true,
            Some(p) => f.paper == p,
        })
        .flat_map(|f| {
            f.sweep_params
                .iter()
                .map(move |&param| (f.name_of(param), f.sweep_archs.archs()))
        })
        .collect()
}

/// Total benchmark cells in the matrix (programs × their arch slates).
pub fn matrix_cells(paper: Option<bool>) -> usize {
    benchmark_matrix(paper).iter().map(|(_, archs)| archs.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_spans_nine_families() {
        assert_eq!(REGISTRY.len(), 9);
        let ids: std::collections::HashSet<&str> =
            REGISTRY.iter().map(|f| f.family).collect();
        assert_eq!(ids.len(), 9, "family ids unique");
        assert_eq!(REGISTRY.iter().filter(|f| f.paper).count(), 2, "transpose + fft");
    }

    #[test]
    fn matrix_meets_the_expanded_floor() {
        // ISSUE 5 acceptance (≥ 100 cells) plus the divergent families:
        // bitonic and spmv add 2 members × 9 archs each → 150 total.
        assert_eq!(matrix_cells(Some(true)), 51, "the paper half is unchanged");
        assert_eq!(matrix_cells(None), 150, "full matrix with the divergent kernels");
    }

    #[test]
    fn parse_is_strict() {
        assert!(parse("scan4096").is_some());
        assert!(parse("scan+64").is_none(), "sign prefixes are not digits");
        assert!(parse("scan064").is_none(), "zero-padded aliases would split the trace cache");
        assert!(parse("scan").is_none());
        assert!(parse("scan4096x").is_none());
        assert!(parse("scan99999999999999").is_none(), "overflow rejected, not panicked");
        assert!(parse("").is_none());
    }

    #[test]
    fn every_sweep_member_parses_to_its_family() {
        for fam in families() {
            for &p in fam.sweep_params {
                assert!((fam.valid)(p), "{} sweep param {p} must be valid", fam.family);
                let name = fam.name_of(p);
                let (parsed, param) = parse(&name).expect("sweep member parses");
                assert_eq!(parsed.family, fam.family, "{name}");
                assert_eq!(param, p);
            }
        }
    }
}
