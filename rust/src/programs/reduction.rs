//! Tree-sum reduction over a strided array — the third access pattern of
//! the benchmark suite, beyond the paper's transpose (unit-stride reads,
//! N-stride writes) and FFT (butterfly strides).
//!
//! The input is an N-element array of 32-bit integers laid out with a
//! power-of-two element stride (default 4 — the layout of a structure-
//! of-4-words array, or fully interleaved complex-pair data). The kernel
//! folds it pairwise in log2(N) passes: pass with `len` partial sums
//! computes `A[i] += A[i + len]` for `i < len`. Timing-wise this is the
//! pattern the paper's tables don't cover:
//!
//! - every access walks a **stride-4** address sequence (4-way conflicts
//!   under the LSB map, conflict-free under Offset shift-2);
//! - each pass *halves* the live set, so the final passes have fewer
//!   sums than lanes — redundant lanes recompute the same element
//!   (`i = tid & (len-1)`), piling duplicate addresses into single banks
//!   exactly like a SIMT reduction tail on real hardware;
//! - reads and blocking writes alternate tightly (each pass must commit
//!   before the next reads it), so write-controller drain latency is on
//!   the critical path, unlike the store-heavy transpose.
//!
//! Functionally the final wrapping sum lands at element 0; validation
//! compares it (and the whole image) against a host reference.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Placement metadata for a reduction run.
#[derive(Debug, Clone, Copy)]
pub struct ReductionPlan {
    /// Element count N (power of two, 32..=4096).
    pub n: u32,
    /// Word stride between consecutive elements (power of two).
    pub stride: u32,
    /// Word address of element 0.
    pub base: u32,
    /// Thread-block size used.
    pub threads: u32,
    /// Shared-memory words the benchmark touches (`n * stride`).
    pub words: u32,
}

impl ReductionPlan {
    /// Default element stride: 4 words between consecutive elements.
    pub const STRIDE: u32 = 4;

    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (32..=4096).contains(&n));
        let threads = (n / 2).min(2048);
        Self { n, stride: Self::STRIDE, base: 0, threads, words: n * Self::STRIDE }
    }

    /// Word address of element `i`.
    pub fn addr_of(&self, i: u32) -> u32 {
        self.base + i * self.stride
    }

    /// Reduction passes (`log2 n`).
    pub fn passes(&self) -> u32 {
        log2_exact(self.n)
    }
}

/// Generate the tree-sum program for an N-element strided array.
pub fn reduction_program(n: u32) -> (ReductionPlan, Program) {
    let plan = ReductionPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &ReductionPlan) -> Program {
    let log_s = log2_exact(plan.stride) as u16;
    let mut b = ProgramBuilder::new(format!("reduction{}", plan.n), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let i = b.alloc();
    let a_addr = b.alloc();
    let b_addr = b.alloc();
    let v0 = b.alloc();
    let v1 = b.alloc();

    // `threads = n/2` covers every pass's live set in one shot (one
    // element per thread); when the live set shrinks below the block,
    // lanes alias (i = tid mod len) and recompute the same sum — the
    // redundant SIMT reduction tail.
    let mut len = plan.n / 2;
    while len >= 1 {
        b.iandi(i, tid, (len - 1) as u16);
        // a = base + i·stride; b = a + len·stride.
        b.ishli(a_addr, i, log_s);
        if plan.base > 0 {
            b.iaddi(a_addr, a_addr, plan.base as i32);
        }
        b.iaddi(b_addr, a_addr, (len * plan.stride) as i32);
        b.ld(v0, a_addr);
        b.ld(v1, b_addr);
        b.iadd(v0, v0, v1);
        // Blocking store: the next pass reads these sums ("use st when
        // the same data will likely be used immediately").
        b.st(a_addr, v0);
        len /= 2;
    }
    b.halt();
    b.build()
}

/// Host reference: the wrapping sum of the input elements.
pub fn reference_sum(elements: &[u32]) -> u32 {
    elements.iter().fold(0u32, |acc, &v| acc.wrapping_add(v))
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (32..=4096).contains(&n)
}

/// Build the registered workload for `reduction{n}`.
pub fn workload(n: u32) -> Workload {
    let (plan, program) = reduction_program(n);
    Workload::new(program, (plan.words as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n {
                mem.write_word(plan.addr_of(i), rng.next_u32());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let elements: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
            ExpectedImage { base: plan.base, words: vec![reference_sum(&elements)] }
        })
        .with_scalar_at(0)
}

/// Analytical golden model: every pass issues 2 loads + 1 store per warp
/// across all `min(N/2, 2048)` threads (redundant tail lanes included),
/// over `log2(N)` passes.
pub fn model(n: u32) -> OpCountModel {
    let warps = (n as u64 / 2).min(2048) / 16;
    let passes = log2_exact(n) as u64;
    OpCountModel {
        d_load_ops: 2 * passes * warps,
        tw_load_ops: 0,
        store_ops: passes * warps,
        fp_ops: 0,
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "reduction",
    prefix: "reduction",
    title: "Strided Tree-Sum",
    grammar: "reductionN — N power of two, 32..=4096",
    valid,
    build: workload,
    model,
    sweep_params: &[4096],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;
    use crate::util::XorShift64;

    fn run_reduction(n: u32, arch: MemoryArchKind) -> (Machine, u32, crate::sim::stats::RunReport) {
        let (plan, program) = reduction_program(n);
        let words = (plan.words as usize).max(4096);
        let mut m = Machine::new(MachineConfig::for_arch(arch).with_mem_words(words));
        let mut rng = XorShift64::new(7);
        let elements: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        for (i, &v) in elements.iter().enumerate() {
            m.load_image(plan.addr_of(i as u32), &[v]);
        }
        let r = m.run_program(&program).expect("reduction runs");
        (m, reference_sum(&elements), r)
    }

    #[test]
    fn functional_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (m, expected, _) = run_reduction(256, arch);
            assert_eq!(m.read_image(0, 1)[0], expected, "{arch}");
        }
    }

    #[test]
    fn functional_at_scale_and_on_parametric_archs() {
        for arch in [
            MemoryArchKind::banked(2),
            MemoryArchKind::banked(32),
            MemoryArchKind::banked_xor(16),
        ] {
            let (m, expected, _) = run_reduction(4096, arch);
            assert_eq!(m.read_image(0, 1)[0], expected, "{arch}");
        }
    }

    #[test]
    fn plan_shapes() {
        let p = ReductionPlan::new(4096);
        assert_eq!(p.threads, 2048);
        assert_eq!(p.words, 16_384);
        assert_eq!(p.passes(), 12);
        assert_eq!(p.addr_of(3), 12);
        let small = ReductionPlan::new(32);
        assert_eq!(small.threads, 16);
        assert!(small.words.is_power_of_two());
    }

    #[test]
    fn op_counts_halve_per_pass_until_warp_floor() {
        // n=256, 128 threads → 8 warps. Passes at len ≥ 128 issue 8 ops
        // per load; smaller passes still issue all 8 warps (redundant
        // lanes), so load ops = 2 × 8 × passes.
        let (_, _, r) = run_reduction(256, MemoryArchKind::banked(16));
        let passes = ReductionPlan::new(256).passes() as u64;
        assert_eq!(r.stats.d_load_ops, 2 * 8 * passes);
        assert_eq!(r.stats.store_ops, 8 * passes);
    }

    #[test]
    fn offset_mapping_beats_lsb_on_strided_reduction() {
        // The whole array is stride-4: the shift-2 Offset map should win
        // clearly over LSB on 16 banks.
        let (_, _, lsb) = run_reduction(1024, MemoryArchKind::banked(16));
        let (_, _, off) = run_reduction(1024, MemoryArchKind::banked_offset(16));
        assert!(
            off.total_cycles() < lsb.total_cycles(),
            "offset {} !< lsb {}",
            off.total_cycles(),
            lsb.total_cycles()
        );
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        ReductionPlan::new(100);
    }
}
