//! Work-efficient inclusive prefix sum (Blelloch-style reduce-then-scan)
//! — log-depth passes whose power-of-two strides stress the shift-family
//! bank mappings.
//!
//! The array is N 32-bit words at unit stride. The kernel runs
//! `2·log2(N) − 1` passes:
//!
//! - **up-sweep** pass `d` (d = 1, 2, …, N/2): `A[2id + 2d−1] +=
//!   A[2id + d−1]` for `i < N/2d` — lane addresses stride by `2d`, so
//!   every pass exercises a different shift position of the
//!   `bank = (addr >> s) & (B−1)` family, and the late passes collapse
//!   onto single banks under LSB exactly where the Offset/XOR maps
//!   spread them;
//! - **down-sweep** pass `d` (d = N/4, …, 1): `A[2(i+1)d + d−1] +=
//!   A[2(i+1)d − 1]` — the inclusive-scan completion, same stride
//!   family in reverse order.
//!
//! Threads are `N/2`; passes with fewer live pairs alias lanes
//! (`i = tid & (m−1)`), so redundant lanes recompute the same element —
//! the SIMT reduction-tail pattern, piling duplicate addresses into
//! single banks. The down-sweep's aliased ghost lane (`i = m−1`) lands
//! its write in the scratch half `[N, N + d)` of the 2N-word image, so
//! the result region `[0, N)` is the exact inclusive scan
//! ([`reference_scan`]).

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Placement metadata for a scan run.
#[derive(Debug, Clone, Copy)]
pub struct ScanPlan {
    /// Element count N (power of two, 64..=4096).
    pub n: u32,
    /// Thread-block size (`N/2` — one pair per thread on the widest
    /// pass).
    pub threads: u32,
    /// Shared-memory words: the array plus an equal-sized scratch half
    /// absorbing the down-sweep's aliased ghost writes.
    pub words: u32,
}

impl ScanPlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (64..=4096).contains(&n));
        Self { n, threads: n / 2, words: 2 * n }
    }

    /// Total passes (`2·log2(N) − 1`).
    pub fn passes(&self) -> u32 {
        2 * log2_exact(self.n) - 1
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (64..=4096).contains(&n)
}

/// Generate the scan program for an N-element array.
pub fn scan_program(n: u32) -> (ScanPlan, Program) {
    let plan = ScanPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &ScanPlan) -> Program {
    let n = plan.n;
    let mut b = ProgramBuilder::new(format!("scan{n}"), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let i = b.alloc();
    let t = b.alloc();
    let a_addr = b.alloc();
    let b_addr = b.alloc();
    let v0 = b.alloc();
    let v1 = b.alloc();

    // Up-sweep: d = 1, 2, …, N/2.
    let mut d = 1u32;
    while d < n {
        let m = n / (2 * d); // live pairs this pass
        let log_2d = log2_exact(2 * d) as u16;
        b.iandi(i, tid, (m - 1) as u16);
        b.ishli(t, i, log_2d); // t = 2·i·d
        b.iaddi(a_addr, t, (d - 1) as i32);
        b.iaddi(b_addr, t, (2 * d - 1) as i32);
        b.ld(v0, a_addr);
        b.ld(v1, b_addr);
        b.iadd(v1, v1, v0);
        // Blocking store: the next pass reads these partial sums.
        b.st(b_addr, v1);
        d *= 2;
    }
    // Down-sweep: d = N/4, …, 1 (the inclusive-scan completion).
    let mut d = n / 4;
    while d >= 1 {
        let m = n / (2 * d);
        let log_2d = log2_exact(2 * d) as u16;
        b.iandi(i, tid, (m - 1) as u16);
        b.iaddi(i, i, 1);
        b.ishli(t, i, log_2d); // t = 2·(i+1)·d
        b.iaddi(a_addr, t, -1); // src = 2(i+1)d − 1
        b.iaddi(b_addr, t, (d - 1) as i32); // dst (ghost lane i = m−1 → [N, N+d))
        b.ld(v0, a_addr);
        b.ld(v1, b_addr);
        b.iadd(v1, v1, v0);
        b.st(b_addr, v1);
        d /= 2;
    }
    b.halt();
    b.build()
}

/// Host reference: the wrapping inclusive prefix sums of the input.
pub fn reference_scan(elements: &[u32]) -> Vec<u32> {
    let mut acc = 0u32;
    elements
        .iter()
        .map(|&v| {
            acc = acc.wrapping_add(v);
            acc
        })
        .collect()
}

/// Build the registered workload for `scan{n}`.
pub fn workload(n: u32) -> Workload {
    let (plan, program) = scan_program(n);
    Workload::new(program, plan.words as usize)
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n {
                mem.write_word(i, rng.next_u32());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let elements: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
            ExpectedImage { base: 0, words: reference_scan(&elements) }
        })
        .with_scalar_at(n - 1)
}

/// Analytical golden model: every pass issues 2 loads + 1 store per warp
/// across all `N/2` threads (aliased lanes included), over
/// `2·log2(N) − 1` passes.
pub fn model(n: u32) -> OpCountModel {
    let warps = (n as u64 / 2) / 16;
    let passes = (2 * log2_exact(n) - 1) as u64;
    OpCountModel {
        d_load_ops: 2 * passes * warps,
        tw_load_ops: 0,
        store_ops: passes * warps,
        fp_ops: 0,
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "scan",
    prefix: "scan",
    title: "Work-Efficient Prefix Sum",
    grammar: "scanN — N power of two, 64..=4096",
    valid,
    build: workload,
    model,
    sweep_params: &[1024, 4096],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_scan(n: u32, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let w = workload(n);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch).with_mem_words(w.mem_words()).with_fast_timing(),
        );
        w.load_input(&mut m, seed);
        let input = m.read_image(0, n as usize);
        m.run_program(w.program()).expect("scan runs");
        (input, m.read_image(0, n as usize))
    }

    #[test]
    fn functional_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (input, out) = run_scan(256, arch, 7);
            assert_eq!(out, reference_scan(&input), "{arch}");
        }
    }

    #[test]
    fn functional_at_scale_and_on_parametric_archs() {
        for arch in [
            MemoryArchKind::banked(2),
            MemoryArchKind::banked(32),
            MemoryArchKind::banked_xor(16),
        ] {
            let (input, out) = run_scan(4096, arch, 11);
            assert_eq!(out, reference_scan(&input), "{arch}");
        }
    }

    #[test]
    fn scalar_is_the_total() {
        let w = workload(1024);
        let mut rng = XorShift64::new(42);
        let total =
            (0..1024).fold(0u32, |acc, _| acc.wrapping_add(rng.next_u32()));
        assert_eq!(w.expected_scalar(42), Some(total));
    }

    #[test]
    fn plan_shapes() {
        let p = ScanPlan::new(4096);
        assert_eq!(p.threads, 2048);
        assert_eq!(p.words, 8192);
        assert_eq!(p.passes(), 23);
        assert_eq!(ScanPlan::new(64).passes(), 11);
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        ScanPlan::new(100);
    }
}
