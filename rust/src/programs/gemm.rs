//! Tiled FP32 matrix multiply over shared memory — the suite's
//! compute-dense kernel, blending FP, integer address math and memory
//! traffic like the paper's FFTs.
//!
//! `C = A·B` for N×N row-major f32 matrices, one thread per output
//! element, the k-loop unrolled in [`TILE`]-wide tiles. Per k-step a
//! warp's 16 consecutive threads (for N ≥ 16: one row of C) issue
//!
//! - `A[i·N + k]` — all 16 lanes read the **same address** (the
//!   broadcast case of the bank-conflict matrix; one bank serves the
//!   whole warp),
//! - `B[k·N + j]` — 16 consecutive addresses (the friendly case),
//!
//! then one fused multiply-add — so the instruction mix interleaves a
//! degenerate-conflict load, an ideal load and an FP op at a 1:1:1
//! rate, with a single consecutive store sweep at the end. Accumulation
//! is bit-deterministic (`fma` in ascending k), so the host reference
//! ([`reference_gemm`]) matches the machine image **bit for bit**.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Tile width of the unrolled k-loop (one warp's worth of k-steps).
pub const TILE: u32 = 16;

/// Placement metadata for a GEMM run.
#[derive(Debug, Clone, Copy)]
pub struct GemmPlan {
    /// Matrix dimension N (power of two, 8..=64).
    pub n: u32,
    /// Word address of B (A occupies `[0, n²)`).
    pub b_base: u32,
    /// Word address of C.
    pub c_base: u32,
    /// Thread-block size (`N²` — one output element per thread).
    pub threads: u32,
    /// Shared-memory words the benchmark touches.
    pub words: u32,
}

impl GemmPlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (8..=64).contains(&n));
        let nn = n * n;
        Self { n, b_base: nn, c_base: 2 * nn, threads: nn, words: 3 * nn }
    }

    /// k-tiles per output element.
    pub fn tiles(&self) -> u32 {
        self.n.div_ceil(TILE)
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (8..=64).contains(&n)
}

/// Generate the GEMM program for N×N matrices.
pub fn gemm_program(n: u32) -> (GemmPlan, Program) {
    let plan = GemmPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &GemmPlan) -> Program {
    let n = plan.n;
    let log_n = log2_exact(n) as u16;
    let mut b = ProgramBuilder::new(format!("gemm{n}"), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let a_addr = b.alloc();
    let b_addr = b.alloc();
    let av = b.alloc();
    let bv = b.alloc();
    let acc = b.alloc();

    // a walks A's row i from i·N = (tid >> log N) << log N;
    // b walks B's column j from b_base + (tid & (N−1)).
    b.ishri(a_addr, tid, log_n);
    b.ishli(a_addr, a_addr, log_n);
    b.iandi(b_addr, tid, (n - 1) as u16);
    b.iaddi(b_addr, b_addr, plan.b_base as i32);
    b.fconst(acc, 0.0);

    // k-loop in TILE-wide tiles: addresses advance incrementally inside
    // a tile (the per-step immediates a tiled kernel keeps in registers).
    for tile in 0..plan.tiles() {
        for k in tile * TILE..((tile + 1) * TILE).min(n) {
            b.ld(av, a_addr); // broadcast: one address per warp row
            b.ld(bv, b_addr); // consecutive across the warp
            b.fma(acc, av, bv);
            if k + 1 < n {
                b.iaddi(a_addr, a_addr, 1);
                b.iaddi(b_addr, b_addr, n as i32);
            }
        }
    }
    // C[i·N + j] = C base + tid — one consecutive sweep, never re-read.
    b.iaddi(a_addr, tid, plan.c_base as i32);
    b.stnb(a_addr, acc);
    b.halt();
    b.build()
}

/// Host reference: C bits with the machine's exact accumulation order
/// (`acc = A[i][k].mul_add(B[k][j], acc)`, k ascending).
pub fn reference_gemm(ab: &[f32], n: usize) -> Vec<u32> {
    assert_eq!(ab.len(), 2 * n * n);
    let (a, b) = ab.split_at(n * n);
    let mut c = vec![0u32; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc = a[i * n + k].mul_add(b[k * n + j], acc);
            }
            c[i * n + j] = acc.to_bits();
        }
    }
    c
}

/// Build the registered workload for `gemm{n}`.
pub fn workload(n: u32) -> Workload {
    let (plan, program) = gemm_program(n);
    Workload::new(program, (plan.words as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            // A then B, contiguous from address 0.
            for (i, v) in rng.f32_vec(2 * (plan.n * plan.n) as usize).iter().enumerate() {
                mem.write_word(i as u32, v.to_bits());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let ab = rng.f32_vec(2 * (plan.n * plan.n) as usize);
            ExpectedImage {
                base: plan.c_base,
                words: reference_gemm(&ab, plan.n as usize),
            }
        })
}

/// Analytical golden model: per k-step one A load, one B load and one
/// fma across `N²/16` warps; one store sweep — `N³/8` loads, `N²/16`
/// stores, `N³/16` 16-wide FP ops.
pub fn model(n: u32) -> OpCountModel {
    let n = n as u64;
    OpCountModel {
        d_load_ops: n * n * n / 8,
        tw_load_ops: 0,
        store_ops: n * n / 16,
        fp_ops: n * n * n / 16,
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "gemm",
    prefix: "gemm",
    title: "Tiled GEMM",
    grammar: "gemmN — N power of two, 8..=64",
    valid,
    build: workload,
    model,
    sweep_params: &[32, 64],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_gemm(n: u32, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, GemmPlan, Machine) {
        let plan = GemmPlan::new(n);
        let w = workload(n);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch).with_mem_words(w.mem_words()).with_fast_timing(),
        );
        w.load_input(&mut m, seed);
        m.run_program(w.program()).expect("gemm runs");
        let out = m.read_image(plan.c_base, (n * n) as usize);
        (out, plan, m)
    }

    #[test]
    fn bit_exact_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (out, plan, _) = run_gemm(16, arch, 21);
            let mut rng = XorShift64::new(21);
            let ab = rng.f32_vec(2 * (plan.n * plan.n) as usize);
            assert_eq!(out, reference_gemm(&ab, plan.n as usize), "{arch}");
        }
    }

    #[test]
    fn bit_exact_at_scale_and_on_parametric_archs() {
        for arch in [MemoryArchKind::banked(32), MemoryArchKind::banked_xor(16)] {
            let (out, plan, _) = run_gemm(64, arch, 23);
            let mut rng = XorShift64::new(23);
            let ab = rng.f32_vec(2 * (plan.n * plan.n) as usize);
            assert_eq!(out, reference_gemm(&ab, plan.n as usize), "{arch}");
        }
    }

    #[test]
    fn identity_times_a_is_a() {
        // B = I: C must equal A bit for bit (fma with 0/1 is exact).
        let n = 8usize;
        let plan = GemmPlan::new(8);
        let program = build(&plan);
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(16))
                .with_mem_words(((plan.words as usize).next_power_of_two()).max(64)),
        );
        let mut rng = XorShift64::new(1);
        let a = rng.f32_vec(n * n);
        m.load_f32_image(0, &a);
        let mut ident = vec![0.0f32; n * n];
        for i in 0..n {
            ident[i * n + i] = 1.0;
        }
        m.load_f32_image(plan.b_base, &ident);
        m.run_program(&program).unwrap();
        let c = m.read_f32_image(plan.c_base, n * n);
        for (got, want) in c.iter().zip(&a) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn plan_shapes() {
        let p = GemmPlan::new(64);
        assert_eq!(p.threads, 4096);
        assert_eq!(p.words, 3 * 4096);
        assert_eq!(p.tiles(), 4);
        assert_eq!(GemmPlan::new(8).tiles(), 1);
    }

    #[test]
    #[should_panic]
    fn too_big_rejected() {
        GemmPlan::new(128);
    }
}
