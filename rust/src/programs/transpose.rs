//! Matrix-transpose benchmark programs (paper Table II).
//!
//! Out-of-place transpose `B[j][i] = A[i][j]` of an N×N matrix of 32-bit
//! words: `A` at address 0, `B` at `N²`. Threads cover the matrix with
//! consecutive linear indices, so:
//!
//! - **reads** sweep consecutive addresses ("across columns … naturally
//!   mapped in different banks"),
//! - **writes** stride by N ("down columns, where individual columns might
//!   well be mapped to a single bank") — the pattern that pins the paper's
//!   write bank efficiency at ≈6.1%.
//!
//! Thread blocks are capped at 4096 (the paper's example configuration);
//! larger matrices unroll multiple elements per thread.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Placement metadata for a transpose run.
#[derive(Debug, Clone, Copy)]
pub struct TransposePlan {
    /// Matrix dimension N (power of two).
    pub n: u32,
    /// Word address of the source matrix A.
    pub src_base: u32,
    /// Word address of the destination matrix B.
    pub dst_base: u32,
    /// Thread-block size used.
    pub threads: u32,
    /// Shared-memory words the benchmark touches.
    pub words: u32,
}

impl TransposePlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (4..=1024).contains(&n));
        let threads = (n * n).min(4096);
        Self { n, src_base: 0, dst_base: n * n, threads, words: 2 * n * n }
    }

    /// Elements each thread moves.
    pub fn elems_per_thread(&self) -> u32 {
        self.n * self.n / self.threads
    }
}

/// Generate the transpose program for an N×N matrix.
pub fn transpose_program(n: u32) -> Program {
    let plan = TransposePlan::new(n);
    build(&plan)
}

/// Generate from an explicit plan (tests use non-default placements).
pub fn build(plan: &TransposePlan) -> Program {
    let n = plan.n;
    let log_n = log2_exact(n) as u16;
    let mut b = ProgramBuilder::new(format!("transpose{n}"), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let idx = b.alloc();
    let row = b.alloc();
    let col = b.alloc();
    let dst = b.alloc();
    let val = b.alloc();
    let dst_base = b.alloc();
    // Destination base can exceed the 16-bit immediate for large matrices;
    // materialize it once.
    b.const32(dst_base, plan.dst_base);

    for e in 0..plan.elems_per_thread() {
        // idx = tid + e·threads — consecutive addresses across the warp.
        // Walk incrementally so the stride always fits the immediate.
        if e == 0 {
            b.iaddi(idx, tid, plan.src_base as i32);
        } else {
            b.iaddi(idx, idx, plan.threads as i32);
        }
        // row = idx >> log2(N); col = idx & (N−1).
        b.ishri(row, idx, log_n);
        b.iandi(col, idx, (n - 1) as u16);
        // dst = dst_base + col·N + row.
        b.ishli(dst, col, log_n);
        b.iadd(dst, dst, row);
        b.iadd(dst, dst, dst_base);
        // Move the element: consecutive-address read, stride-N write.
        b.ld(val, idx);
        b.st(dst, val);
    }
    b.halt();
    b.build()
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (4..=1024).contains(&n)
}

/// Build the registered workload for `transpose{n}`.
pub fn workload(n: u32) -> Workload {
    let plan = TransposePlan::new(n);
    let program = transpose_program(n);
    Workload::new(program, (plan.words as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n * plan.n {
                mem.write_word(plan.src_base + i, rng.next_u32());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let n = plan.n as usize;
            let src: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
            let mut out = vec![0u32; n * n];
            for i in 0..n {
                for j in 0..n {
                    out[j * n + i] = src[i * n + j];
                }
            }
            ExpectedImage { base: plan.dst_base, words: out }
        })
}

/// Analytical golden model (Table II's Load/Store op rows): one load and
/// one store per element, `N²/16` warps-worth of each.
pub fn model(n: u32) -> OpCountModel {
    let ops = (n as u64 * n as u64) / 16;
    OpCountModel { d_load_ops: ops, tw_load_ops: 0, store_ops: ops, fp_ops: 0 }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "transpose",
    prefix: "transpose",
    title: "Matrix Transpose",
    grammar: "transposeN — N power of two, 4..=1024",
    valid,
    build: workload,
    model,
    sweep_params: &[32, 64, 128],
    sweep_archs: SweepArchs::Table2,
    paper: true,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_transpose(n: u32, arch: MemoryArchKind) -> (Machine, crate::sim::stats::RunReport) {
        let plan = TransposePlan::new(n);
        let p = transpose_program(n);
        let words = (plan.words as usize).next_power_of_two().max(4096);
        let mut m = Machine::new(MachineConfig::for_arch(arch).with_mem_words(words));
        let mut rng = XorShift64::new(2025);
        let src: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
        m.load_image(plan.src_base, &src);
        let r = m.run_program(&p).expect("transpose runs");
        (m, r)
    }

    fn check_functional(n: u32, arch: MemoryArchKind) {
        let plan = TransposePlan::new(n);
        let (m, _) = run_transpose(n, arch);
        let src = m.read_image(plan.src_base, (n * n) as usize);
        let dst = m.read_image(plan.dst_base, (n * n) as usize);
        for i in 0..n as usize {
            for j in 0..n as usize {
                assert_eq!(
                    dst[j * n as usize + i],
                    src[i * n as usize + j],
                    "B[{j}][{i}] != A[{i}][{j}] (n={n}, arch={arch})"
                );
            }
        }
    }

    #[test]
    fn functional_32_all_paper_archs() {
        for arch in MemoryArchKind::table2_eight() {
            check_functional(32, arch);
        }
    }

    #[test]
    fn functional_64_and_128_on_banked16() {
        check_functional(64, MemoryArchKind::banked(16));
        check_functional(128, MemoryArchKind::banked_offset(16));
    }

    #[test]
    fn plan_thread_caps() {
        assert_eq!(TransposePlan::new(32).threads, 1024);
        assert_eq!(TransposePlan::new(32).elems_per_thread(), 1);
        assert_eq!(TransposePlan::new(64).threads, 4096);
        assert_eq!(TransposePlan::new(128).threads, 4096);
        assert_eq!(TransposePlan::new(128).elems_per_thread(), 4);
    }

    #[test]
    fn load_store_op_counts_match_paper() {
        // Table II: 32×32 → 64/64 load/store ops; 64×64 → 256/256;
        // 128×128 → 1024/1024.
        for (n, ops) in [(32u32, 64u64), (64, 256), (128, 1024)] {
            let (_, r) = run_transpose(n, MemoryArchKind::banked(16));
            assert_eq!(r.stats.d_load_ops, ops, "n={n}");
            assert_eq!(r.stats.store_ops, ops, "n={n}");
        }
    }

    #[test]
    fn multiport_cycles_match_paper_exactly() {
        // The deterministic multiport model must reproduce Table II's
        // load/store cycle rows exactly: loads = ops×4, stores = ops×16
        // (1W) or ops×8 (2W).
        for (n, ops) in [(32u32, 64u64), (64, 256), (128, 1024)] {
            let (_, r1) = run_transpose(n, MemoryArchKind::mp_4r1w());
            assert_eq!(r1.stats.d_load_cycles, ops * 4, "4R-1W loads n={n}");
            assert_eq!(r1.stats.store_cycles, ops * 16, "4R-1W stores n={n}");
            let (_, r2) = run_transpose(n, MemoryArchKind::mp_4r2w());
            assert_eq!(r2.stats.store_cycles, ops * 8, "4R-2W stores n={n}");
        }
    }

    #[test]
    fn banked_write_efficiency_pinned_low() {
        // Stride-N writes serialize: W bank eff ≈ 6.1% for 16 banks
        // (the paper's constant across the whole banked Table II row).
        let (_, r) = run_transpose(32, MemoryArchKind::banked(16));
        let eff = r.w_bank_eff().unwrap();
        assert!((0.055..0.07).contains(&eff), "w eff = {eff}");
    }

    #[test]
    fn banked_reads_efficient() {
        let (_, r) = run_transpose(32, MemoryArchKind::banked(16));
        assert!(r.r_bank_eff().unwrap() > 0.5, "consecutive reads should be near-ideal");
    }

    #[test]
    fn offset_mapping_improves_transpose_total() {
        // Paper: "The complex bank mapping improves the performance of the
        // transpose benchmarks by about 10%".
        let (_, lsb) = run_transpose(32, MemoryArchKind::banked(16));
        let (_, off) = run_transpose(32, MemoryArchKind::banked_offset(16));
        assert!(
            off.total_cycles() < lsb.total_cycles(),
            "offset {} should beat lsb {}",
            off.total_cycles(),
            lsb.total_cycles()
        );
    }

    #[test]
    fn fewer_banks_slower() {
        let (_, b16) = run_transpose(64, MemoryArchKind::banked(16));
        let (_, b8) = run_transpose(64, MemoryArchKind::banked(8));
        let (_, b4) = run_transpose(64, MemoryArchKind::banked(4));
        assert!(b16.total_cycles() <= b8.total_cycles());
        assert!(b8.total_cycles() <= b4.total_cycles());
    }
}
