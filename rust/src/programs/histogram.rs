//! Lockstep chunk histogram — data-dependent bank conflicts, the
//! adversarial case for the LSB mapping.
//!
//! Each thread loads one element (consecutive addresses — the friendly
//! half), masks it to one of [`BINS`] bins, then read-modify-writes the
//! bin counter: `ld hist[bin]; +1; st hist[bin]`. The bin addresses are
//! **data-dependent**: which banks the gather and scatter hit — and how
//! many lanes collide on one bank — is decided by the input values, not
//! the address arithmetic, so no shift-family mapping can be conflict-free
//! by construction. This is the access pattern the paper's §VII names as
//! the reason a configurable memory matters.
//!
//! **Semantics.** The ISA has no atomics, and all lanes of the block
//! execute the RMW in lockstep (every lane reads the pre-instruction
//! counter; colliding lanes all write the same `old + 1`). The kernel is
//! therefore defined as the *lockstep chunk histogram*: per pass of
//! `threads` elements, each bin hit by the chunk advances by exactly one
//! ([`reference_histogram`] replicates this bit for bit). The memory
//! traffic — a data-dependent gather + scatter per element chunk — is
//! identical to a real histogram's; only the counter arithmetic is
//! chunk-granular.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::XorShift64;

/// Histogram bins (power of two; bin = value & (BINS − 1)).
pub const BINS: u32 = 64;

/// Placement metadata for a histogram run.
#[derive(Debug, Clone, Copy)]
pub struct HistogramPlan {
    /// Element count N (power of two, 64..=4096).
    pub n: u32,
    /// Word address of the bin counters (the data occupies `[0, n)`).
    pub hist_base: u32,
    /// Thread-block size.
    pub threads: u32,
    /// Shared-memory words the benchmark touches.
    pub words: u32,
}

impl HistogramPlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (64..=4096).contains(&n));
        let threads = n.min(2048);
        Self { n, hist_base: n, threads, words: n + BINS }
    }

    /// Elements each thread classifies.
    pub fn elems_per_thread(&self) -> u32 {
        self.n / self.threads
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (64..=4096).contains(&n)
}

/// Generate the histogram program for an N-element input.
pub fn histogram_program(n: u32) -> (HistogramPlan, Program) {
    let plan = HistogramPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &HistogramPlan) -> Program {
    let mut b = ProgramBuilder::new(format!("histogram{}", plan.n), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let idx = b.alloc();
    let v = b.alloc();
    let bin = b.alloc();
    let h = b.alloc();

    for e in 0..plan.elems_per_thread() {
        // idx = tid + e·threads — consecutive addresses across the warp.
        if e == 0 {
            b.iaddi(idx, tid, 0);
        } else {
            b.iaddi(idx, idx, plan.threads as i32);
        }
        b.ld(v, idx);
        // bin address = hist_base + (v & (BINS−1)) — data-dependent.
        b.iandi(bin, v, (BINS - 1) as u16);
        b.iaddi(bin, bin, plan.hist_base as i32);
        b.ld(h, bin); // gather: conflicts decided by the data
        b.iaddi(h, h, 1);
        // Blocking store: the next chunk's gather reads these counters.
        b.st(bin, h);
    }
    b.halt();
    b.build()
}

/// Host reference: the lockstep chunk histogram — per chunk of `threads`
/// elements, every bin hit by the chunk advances by one (see the module
/// docs for why this is the kernel's exact semantics).
pub fn reference_histogram(elements: &[u32], threads: usize) -> Vec<u32> {
    let mut hist = vec![0u32; BINS as usize];
    for chunk in elements.chunks(threads) {
        let mut hit = vec![false; BINS as usize];
        for &v in chunk {
            hit[(v & (BINS - 1)) as usize] = true;
        }
        for (counter, &h) in hist.iter_mut().zip(&hit) {
            if h {
                *counter += 1;
            }
        }
    }
    hist
}

/// Build the registered workload for `histogram{n}`.
pub fn workload(n: u32) -> Workload {
    let (plan, program) = histogram_program(n);
    Workload::new(program, (plan.words as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n {
                mem.write_word(i, rng.next_u32());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let elements: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
            ExpectedImage {
                base: plan.hist_base,
                words: reference_histogram(&elements, plan.threads as usize),
            }
        })
}

/// Analytical golden model: per element chunk, one data load + one bin
/// gather + one bin scatter per warp — `2N/16` loads, `N/16` stores.
pub fn model(n: u32) -> OpCountModel {
    let n = n as u64;
    OpCountModel { d_load_ops: 2 * n / 16, tw_load_ops: 0, store_ops: n / 16, fp_ops: 0 }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "histogram",
    prefix: "histogram",
    title: "Lockstep Chunk Histogram",
    grammar: "histogramN — N power of two, 64..=4096 (64 bins)",
    valid,
    build: workload,
    model,
    sweep_params: &[4096],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_histogram(n: u32, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let plan = HistogramPlan::new(n);
        let w = workload(n);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch).with_mem_words(w.mem_words()).with_fast_timing(),
        );
        w.load_input(&mut m, seed);
        let input = m.read_image(0, n as usize);
        m.run_program(w.program()).expect("histogram runs");
        (input, m.read_image(plan.hist_base, BINS as usize))
    }

    #[test]
    fn functional_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (input, out) = run_histogram(256, arch, 5);
            assert_eq!(
                out,
                reference_histogram(&input, HistogramPlan::new(256).threads as usize),
                "{arch}"
            );
        }
    }

    #[test]
    fn functional_at_scale_multichunk() {
        // n = 4096 with 2048 threads: two chunks, so the chunk-granular
        // counter semantics are actually exercised.
        let plan = HistogramPlan::new(4096);
        assert_eq!(plan.elems_per_thread(), 2);
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::banked_xor(16)] {
            let (input, out) = run_histogram(4096, arch, 3);
            assert_eq!(out, reference_histogram(&input, plan.threads as usize), "{arch}");
        }
    }

    #[test]
    fn chunk_reference_counts_chunks_not_elements() {
        // 32 equal elements in one chunk of 32 → the bin advances once.
        let elements = vec![5u32; 32];
        let hist = reference_histogram(&elements, 32);
        assert_eq!(hist[5], 1);
        // Two chunks of 16 → twice.
        let hist = reference_histogram(&elements, 16);
        assert_eq!(hist[5], 2);
    }

    #[test]
    #[should_panic]
    fn too_small_rejected() {
        HistogramPlan::new(32);
    }
}
