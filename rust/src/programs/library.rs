//! Named program library — the thin facade over the workload registry
//! ([`super::registry`]) that the CLI, the sweep runner and the service
//! layer import. The registry owns the grammar, the builders and the
//! benchmark matrix; this module re-exports the lookup surface under its
//! historical names so `programs::library::program_by_name` keeps
//! working everywhere.

pub use super::registry::{
    is_known_program, model_by_name, program_by_name, ExpectedImage, OpCountModel, Workload,
};

/// The benchmark-matrix member names (every family's sweep members, in
/// registry order) — what `list` reports and `sweep --all` times.
pub fn program_names() -> Vec<String> {
    super::registry::program_names()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registered_names_build() {
        for name in program_names() {
            let w = program_by_name(&name).unwrap_or_else(|| panic!("{name} must build"));
            assert_eq!(w.name(), name);
            assert!(w.mem_words().is_power_of_two());
        }
    }

    #[test]
    fn the_paper_names_are_registered() {
        for name in [
            "transpose32", "transpose64", "transpose128", "fft4096r4", "fft4096r8",
            "fft4096r16", "reduction4096",
        ] {
            assert!(program_names().iter().any(|n| n == name), "{name} missing");
        }
    }

    #[test]
    fn unknown_names_rejected() {
        assert!(program_by_name("transpose33").is_none());
        assert!(program_by_name("fft4096r5").is_none());
        assert!(program_by_name("reduction100").is_none());
        assert!(program_by_name("reduction8192").is_none());
        assert!(program_by_name("scan33").is_none());
        assert!(program_by_name("gemm128").is_none());
        assert!(program_by_name("quicksort").is_none());
    }

    #[test]
    fn is_known_program_agrees_with_builder() {
        for name in [
            "transpose32", "transpose33", "transpose1024", "transpose2048", "fft4096r8",
            "fft4096r5", "reduction4096", "reduction100", "reduction8192", "scan4096",
            "scan100", "histogram4096", "histogram32", "stencil4096", "gemm64", "gemm7",
            "quicksort", "",
        ] {
            assert_eq!(
                is_known_program(name),
                program_by_name(name).is_some(),
                "probe and builder disagree on '{name}'"
            );
        }
    }

    #[test]
    fn reduction_workload_matches_host_reference() {
        use crate::mem::arch::MemoryArchKind;
        use crate::sim::config::MachineConfig;
        use crate::sim::machine::Machine;
        let w = program_by_name("reduction256").unwrap();
        let mut machine = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked_offset(16))
                .with_mem_words(w.mem_words()),
        );
        w.load_input(&mut machine, 0x5EED);
        machine.run_program(w.program()).unwrap();
        let expected = w.expected_scalar(0x5EED).unwrap();
        assert_eq!(machine.read_image(0, 1)[0], expected);
        assert!(w.expected_scalar(1234) != w.expected_scalar(0x5EED), "seed-dependent");
        assert!(program_by_name("transpose32").unwrap().expected_scalar(1).is_none());
    }

    #[test]
    fn expected_images_exist_for_every_non_fft_member() {
        for name in program_names() {
            let w = program_by_name(&name).unwrap();
            let has_image = w.expected_image(1).is_some();
            assert_eq!(
                has_image,
                !name.starts_with("fft"),
                "{name}: only the FFTs validate by tolerance instead of exact image"
            );
        }
    }

    #[test]
    fn fft_workloads_have_tw_regions() {
        assert!(program_by_name("fft4096r4").unwrap().tw_region().is_some());
        assert!(program_by_name("transpose32").unwrap().tw_region().is_none());
    }

    #[test]
    fn load_input_agrees_across_memory_backends() {
        use crate::mem::arch::MemoryArchKind;
        use crate::sim::config::MachineConfig;
        use crate::sim::exec::FlatMemory;
        use crate::sim::machine::Machine;
        for name in ["transpose32", "gemm16", "histogram256"] {
            let w = program_by_name(name).unwrap();
            let mut flat = FlatMemory::new(w.mem_words());
            w.load_input(&mut flat, 0x5EED);
            let mut machine = Machine::new(
                MachineConfig::for_arch(MemoryArchKind::banked(16))
                    .with_mem_words(w.mem_words()),
            );
            w.load_input(&mut machine, 0x5EED);
            assert_eq!(machine.mem().image(), flat.image(), "{name}");
        }
    }

    #[test]
    fn non_paper_sizes_also_build() {
        // The library generalizes beyond the registered sweep sizes.
        assert!(program_by_name("transpose16").is_some());
        assert!(program_by_name("transpose256").is_some());
        assert!(program_by_name("scan128").is_some());
        assert!(program_by_name("gemm8").is_some());
    }
}
