//! Named program registry — the benchmark suite by name, for the CLI and
//! the sweep runner.

use super::fft::{fft_program, FftPlan};
use super::reduction::{reduction_program, ReductionPlan};
use super::transpose::{transpose_program, TransposePlan};
use crate::isa::program::Program;
use crate::sim::exec::ExecMemory;
use crate::util::XorShift64;

/// A registered benchmark: the program plus the workload metadata the
/// harness needs (memory image layout, twiddle region, capacity).
pub enum Workload {
    Transpose(TransposePlan, Program),
    Fft(FftPlan, Program),
    Reduction(ReductionPlan, Program),
}

impl Workload {
    pub fn program(&self) -> &Program {
        match self {
            Workload::Transpose(_, p) => p,
            Workload::Fft(_, p) => p,
            Workload::Reduction(_, p) => p,
        }
    }

    pub fn name(&self) -> &str {
        &self.program().name
    }

    /// Shared-memory words required (power of two).
    pub fn mem_words(&self) -> usize {
        match self {
            Workload::Transpose(plan, _) => (plan.words as usize).next_power_of_two(),
            Workload::Fft(plan, _) => plan.mem_words(),
            Workload::Reduction(plan, _) => (plan.words as usize).next_power_of_two(),
        }
    }

    /// Dataset size in KB — the capacity the footprint model charges for
    /// holding this workload (shared by the advisor, the explorer CLI
    /// and the trace-derived figure in `explore::Evaluator`).
    pub fn dataset_kb(&self) -> u32 {
        (self.mem_words() * 4 / 1024) as u32
    }

    /// Twiddle region for load classification (FFTs only).
    pub fn tw_region(&self) -> Option<std::ops::Range<u32>> {
        match self {
            Workload::Transpose(..) | Workload::Reduction(..) => None,
            Workload::Fft(plan, _) => Some(plan.tw_region()),
        }
    }

    /// Deterministically fill `mem` with this workload's input image
    /// (source matrix / signal + twiddle table), derived from `seed`.
    ///
    /// Input data never changes *timing* (access patterns are
    /// address-driven), but determinism keeps functional validation and
    /// trace-cache keys exact: the same `(program, seed)` pair always
    /// produces the same memory image, hence the same trace.
    pub fn load_input<M: ExecMemory>(&self, mem: &mut M, seed: u64) {
        let mut rng = XorShift64::new(seed);
        match self {
            Workload::Transpose(plan, _) => {
                for i in 0..plan.n * plan.n {
                    mem.write_word(plan.src_base + i, rng.next_u32());
                }
            }
            Workload::Fft(plan, _) => {
                let data = rng.f32_vec(2 * plan.n as usize);
                for (i, &v) in data.iter().enumerate() {
                    mem.write_word(plan.data_base + i as u32, v.to_bits());
                }
                for (i, &v) in plan.twiddles.iter().enumerate() {
                    mem.write_word(plan.tw_base + i as u32, v.to_bits());
                }
            }
            Workload::Reduction(plan, _) => {
                for i in 0..plan.n {
                    mem.write_word(plan.addr_of(i), rng.next_u32());
                }
            }
        }
    }

    /// Host-reference expected value at the workload's result location,
    /// when one exists (reductions: the wrapping sum at element 0).
    pub fn expected_scalar(&self, seed: u64) -> Option<u32> {
        match self {
            Workload::Reduction(plan, _) => {
                let mut rng = XorShift64::new(seed);
                let elements: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
                Some(super::reduction::reference_sum(&elements))
            }
            _ => None,
        }
    }
}

/// The benchmark names of the paper's evaluation, plus the strided
/// tree-sum reduction (the suite's third access pattern).
pub fn program_names() -> Vec<&'static str> {
    vec![
        "transpose32",
        "transpose64",
        "transpose128",
        "fft4096r4",
        "fft4096r8",
        "fft4096r16",
        "reduction4096",
    ]
}

/// A parsed-but-not-built program name: the grammar and bounds checks
/// without any codegen, so name validation is free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ParsedName {
    Transpose(u32),
    Fft(u32),
    Reduction(u32),
}

/// Parse a program name (`transposeN` for powers of two 4..=1024;
/// `fft4096rR` for R ∈ {4, 8, 16}; `reductionN` for powers of two
/// 32..=4096) without constructing the workload.
fn parse_name(name: &str) -> Option<ParsedName> {
    if let Some(n) = name.strip_prefix("transpose") {
        let n: u32 = n.parse().ok()?;
        return (n.is_power_of_two() && (4..=1024).contains(&n))
            .then_some(ParsedName::Transpose(n));
    }
    if let Some(r) = name.strip_prefix("fft4096r") {
        let r: u32 = r.parse().ok()?;
        return matches!(r, 4 | 8 | 16).then_some(ParsedName::Fft(r));
    }
    if let Some(n) = name.strip_prefix("reduction") {
        let n: u32 = n.parse().ok()?;
        return (n.is_power_of_two() && (32..=4096).contains(&n))
            .then_some(ParsedName::Reduction(n));
    }
    None
}

/// Whether `name` is a buildable program, without building it — the
/// cheap validity probe the service layer's hot path uses (a warm
/// cached `run` must not pay FFT codegen just to re-validate a name).
pub fn is_known_program(name: &str) -> bool {
    parse_name(name).is_some()
}

/// Build a workload by name (see [`is_known_program`] for the grammar:
/// `transposeN`, `fft4096rR`, `reductionN`).
pub fn program_by_name(name: &str) -> Option<Workload> {
    match parse_name(name)? {
        ParsedName::Transpose(n) => {
            Some(Workload::Transpose(TransposePlan::new(n), transpose_program(n)))
        }
        ParsedName::Fft(r) => {
            let (plan, program) = fft_program(r);
            Some(Workload::Fft(plan, program))
        }
        ParsedName::Reduction(n) => {
            let (plan, program) = reduction_program(n);
            Some(Workload::Reduction(plan, program))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registered_names_build() {
        for name in program_names() {
            let w = program_by_name(name).unwrap_or_else(|| panic!("{name} must build"));
            assert_eq!(w.name(), name);
            assert!(w.mem_words().is_power_of_two());
        }
    }

    #[test]
    fn unknown_names_rejected() {
        assert!(program_by_name("transpose33").is_none());
        assert!(program_by_name("fft4096r5").is_none());
        assert!(program_by_name("reduction100").is_none());
        assert!(program_by_name("reduction8192").is_none());
        assert!(program_by_name("quicksort").is_none());
    }

    #[test]
    fn is_known_program_agrees_with_builder() {
        for name in [
            "transpose32", "transpose33", "transpose1024", "transpose2048", "fft4096r8",
            "fft4096r5", "reduction4096", "reduction100", "reduction8192", "quicksort", "",
        ] {
            assert_eq!(
                is_known_program(name),
                program_by_name(name).is_some(),
                "probe and builder disagree on '{name}'"
            );
        }
    }

    #[test]
    fn reduction_workload_matches_host_reference() {
        use crate::mem::arch::MemoryArchKind;
        use crate::sim::config::MachineConfig;
        use crate::sim::machine::Machine;
        let w = program_by_name("reduction256").unwrap();
        let mut machine = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked_offset(16))
                .with_mem_words(w.mem_words()),
        );
        w.load_input(&mut machine, 0x5EED);
        machine.run_program(w.program()).unwrap();
        let expected = w.expected_scalar(0x5EED).unwrap();
        assert_eq!(machine.read_image(0, 1)[0], expected);
        assert!(w.expected_scalar(1234) != w.expected_scalar(0x5EED), "seed-dependent");
        assert!(program_by_name("transpose32").unwrap().expected_scalar(1).is_none());
    }

    #[test]
    fn fft_workloads_have_tw_regions() {
        assert!(program_by_name("fft4096r4").unwrap().tw_region().is_some());
        assert!(program_by_name("transpose32").unwrap().tw_region().is_none());
    }

    #[test]
    fn load_input_agrees_across_memory_backends() {
        use crate::mem::arch::MemoryArchKind;
        use crate::sim::config::MachineConfig;
        use crate::sim::exec::FlatMemory;
        use crate::sim::machine::Machine;
        let w = program_by_name("transpose32").unwrap();
        let mut flat = FlatMemory::new(w.mem_words());
        w.load_input(&mut flat, 0x5EED);
        let mut machine = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(w.mem_words()),
        );
        w.load_input(&mut machine, 0x5EED);
        assert_eq!(machine.mem().image(), flat.image());
    }

    #[test]
    fn non_paper_sizes_also_build() {
        // The library generalizes beyond the paper's three sizes.
        assert!(program_by_name("transpose16").is_some());
        assert!(program_by_name("transpose256").is_some());
    }
}
