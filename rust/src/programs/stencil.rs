//! 1D periodic stencil with configurable radius — halo exchange over a
//! power-of-two ring.
//!
//! `out[i] = Σ_{o=−r..+r} in[(i + o) mod N]` (wrapping 32-bit adds).
//! Reads are `2r + 1` unit-stride sweeps shifted by the tap offset —
//! heavily overlapping, read-dominated traffic where the banked memories
//! approach their read roofline — and the halo wrap (`& (N−1)`) folds
//! the boundary lanes of each warp onto the far end of the ring, the
//! halo-exchange pattern of a distributed stencil. Writes are one
//! consecutive sweep into the output half.
//!
//! The registered members (`stencilN`) use radius [`RADIUS`]; the plan
//! API ([`StencilPlan::with_radius`]) generates any radius 1..=8 for
//! experiments.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::XorShift64;

/// Radius of the registered benchmark members (7-point stencil).
pub const RADIUS: u32 = 3;

/// Placement metadata for a stencil run.
#[derive(Debug, Clone, Copy)]
pub struct StencilPlan {
    /// Ring size N (power of two, 64..=4096).
    pub n: u32,
    /// Stencil radius (taps = 2·radius + 1).
    pub radius: u32,
    /// Word address of the output (the input ring occupies `[0, n)`).
    pub out_base: u32,
    /// Thread-block size.
    pub threads: u32,
    /// Shared-memory words the benchmark touches.
    pub words: u32,
}

impl StencilPlan {
    pub fn new(n: u32) -> Self {
        Self::with_radius(n, RADIUS)
    }

    /// A plan with an explicit radius (1..=8).
    pub fn with_radius(n: u32, radius: u32) -> Self {
        assert!(n.is_power_of_two() && (64..=4096).contains(&n));
        assert!((1..=8).contains(&radius));
        let threads = n.min(2048);
        Self { n, radius, out_base: n, threads, words: 2 * n }
    }

    /// Elements each thread computes.
    pub fn elems_per_thread(&self) -> u32 {
        self.n / self.threads
    }

    /// Taps per output element.
    pub fn taps(&self) -> u32 {
        2 * self.radius + 1
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (64..=4096).contains(&n)
}

/// Generate the stencil program for an N-point ring at the default
/// radius.
pub fn stencil_program(n: u32) -> (StencilPlan, Program) {
    let plan = StencilPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &StencilPlan) -> Program {
    let n = plan.n;
    let mut b = ProgramBuilder::new(format!("stencil{n}"), plan.threads);

    let tid = 0u8; // conventional
    b.tid(tid);
    let idx = b.alloc();
    let a = b.alloc();
    let v = b.alloc();
    let acc = b.alloc();

    for e in 0..plan.elems_per_thread() {
        if e == 0 {
            b.iaddi(idx, tid, 0);
        } else {
            b.iaddi(idx, idx, plan.threads as i32);
        }
        for k in 0..plan.taps() {
            let off = k as i32 - plan.radius as i32;
            // a = (idx + off) mod N — the wrap is exact because the
            // sign-extended add is mod 2^32 and N divides 2^32.
            b.iaddi(a, idx, off);
            b.iandi(a, a, (n - 1) as u16);
            b.ld(v, a);
            if k == 0 {
                b.iaddi(acc, v, 0);
            } else {
                b.iadd(acc, acc, v);
            }
        }
        b.iaddi(a, idx, plan.out_base as i32);
        b.stnb(a, acc); // out is never re-read: non-blocking
    }
    b.halt();
    b.build()
}

/// Host reference: the periodic wrapping tap sum.
pub fn reference_stencil(elements: &[u32], radius: u32) -> Vec<u32> {
    let n = elements.len();
    (0..n)
        .map(|i| {
            (-(radius as i64)..=radius as i64).fold(0u32, |acc, o| {
                acc.wrapping_add(elements[(i as i64 + o).rem_euclid(n as i64) as usize])
            })
        })
        .collect()
}

/// Build the registered workload for `stencil{n}` (radius [`RADIUS`]).
pub fn workload(n: u32) -> Workload {
    let (plan, program) = stencil_program(n);
    Workload::new(program, plan.words as usize)
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n {
                mem.write_word(i, rng.next_u32());
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let elements: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
            ExpectedImage {
                base: plan.out_base,
                words: reference_stencil(&elements, plan.radius),
            }
        })
}

/// Analytical golden model: `2r + 1` tap loads and one store per element,
/// `N/16` warps-worth of each.
pub fn model(n: u32) -> OpCountModel {
    let n = n as u64;
    let taps = (2 * RADIUS + 1) as u64;
    OpCountModel { d_load_ops: taps * n / 16, tw_load_ops: 0, store_ops: n / 16, fp_ops: 0 }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "stencil",
    prefix: "stencil",
    title: "1D Periodic Stencil",
    grammar: "stencilN — N power of two, 64..=4096 (radius 3)",
    valid,
    build: workload,
    model,
    sweep_params: &[4096],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_stencil(plan: &StencilPlan, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let program = build(plan);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch)
                .with_mem_words(plan.words as usize)
                .with_fast_timing(),
        );
        let mut rng = XorShift64::new(seed);
        let input: Vec<u32> = (0..plan.n).map(|_| rng.next_u32()).collect();
        m.load_image(0, &input);
        m.run_program(&program).expect("stencil runs");
        let out = m.read_image(plan.out_base, plan.n as usize);
        (input, out)
    }

    #[test]
    fn functional_on_all_paper_archs() {
        let plan = StencilPlan::new(256);
        for arch in MemoryArchKind::table3_nine() {
            let (input, out) = run_stencil(&plan, arch, 9);
            assert_eq!(out, reference_stencil(&input, plan.radius), "{arch}");
        }
    }

    #[test]
    fn radii_are_configurable() {
        for radius in [1u32, 4, 8] {
            let plan = StencilPlan::with_radius(128, radius);
            let (input, out) = run_stencil(&plan, MemoryArchKind::banked(16), 13);
            assert_eq!(out, reference_stencil(&input, radius), "radius {radius}");
        }
    }

    #[test]
    fn halo_wraps_the_ring() {
        // A single impulse at index 0 shows up in the last `radius`
        // outputs — the periodic halo.
        let mut input = vec![0u32; 64];
        input[0] = 1;
        let out = reference_stencil(&input, 3);
        assert_eq!(out[63], 1);
        assert_eq!(out[61], 1);
        assert_eq!(out[60], 0);
        let plan = StencilPlan::new(64);
        let program = build(&plan);
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(16))
                .with_mem_words(plan.words as usize),
        );
        m.load_image(0, &input);
        m.run_program(&program).unwrap();
        assert_eq!(m.read_image(plan.out_base, 64), out);
    }

    #[test]
    fn multichunk_at_scale() {
        let plan = StencilPlan::new(4096);
        assert_eq!(plan.elems_per_thread(), 2);
        let (input, out) = run_stencil(&plan, MemoryArchKind::banked_offset(16), 17);
        assert_eq!(out, reference_stencil(&input, plan.radius));
    }

    #[test]
    #[should_panic]
    fn radius_bounds() {
        StencilPlan::with_radius(128, 0);
    }
}
