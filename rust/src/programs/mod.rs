//! Benchmark program generators (paper §V: "All benchmarks were written
//! in assembler").
//!
//! The generators emit the same memory-access *patterns* the paper's
//! hand-written assembler produces — consecutive-address reads and
//! stride-N writes for the transposes; stride-varying butterfly and
//! twiddle accesses with interleaved I/Q complex data for the FFTs —
//! because those patterns are what drive the bank-conflict behaviour the
//! paper measures. The [`reduction`] tree-sum adds a third pattern the
//! paper's tables don't cover (strided reads with a redundant SIMT
//! reduction tail), giving the design-space explorer a scenario beyond
//! the paper's two.

pub mod builder;
pub mod fft;
pub mod library;
pub mod reduction;
pub mod transpose;

pub use fft::{fft_program, FftPlan};
pub use library::{program_by_name, program_names};
pub use reduction::{reduction_program, ReductionPlan};
pub use transpose::{transpose_program, TransposePlan};
