//! Benchmark program generators (paper §V: "All benchmarks were written
//! in assembler") behind a data-driven workload registry.
//!
//! The paper families emit the same memory-access *patterns* the paper's
//! hand-written assembler produces — consecutive-address reads and
//! stride-N writes for the transposes; stride-varying butterfly and
//! twiddle accesses with interleaved I/Q complex data for the FFTs —
//! because those patterns are what drive the bank-conflict behaviour the
//! paper measures. Five extension families grow the matrix beyond the
//! paper's tables with the access patterns §VII gestures at:
//!
//! - [`reduction`] — strided tree sum (SIMT reduction tail);
//! - [`scan`] — work-efficient prefix sum (log-depth shift-family
//!   strides);
//! - [`histogram`] — data-dependent gather/scatter (the adversarial
//!   case for any fixed mapping);
//! - [`stencil`] — periodic halo reads, read-roofline traffic;
//! - [`gemm`] — tiled FP matmul (broadcast + consecutive loads, FP-dense).
//!
//! Two *divergent* families exercise the per-lane divergence model
//! (data-dependent control flow, masked memory ops):
//!
//! - [`bitonic`] — compare-exchange sort: owner predication plus a
//!   data-dependent swap branch;
//! - [`spmv`] — CSR gather with skewed row lengths: per-lane loop trip
//!   counts and a data-dependent `x[col]` gather.
//!
//! Every family registers one [`registry::KernelFamily`] — name grammar,
//! builder, analytical op-count golden model, sweep members — and every
//! consumer (sweeps, validation, the advisor, the service `List`)
//! enumerates [`registry::REGISTRY`] instead of keeping its own list.

pub mod bitonic;
pub mod builder;
pub mod fft;
pub mod gemm;
pub mod histogram;
pub mod library;
pub mod reduction;
pub mod registry;
pub mod scan;
pub mod spmv;
pub mod stencil;
pub mod transpose;

pub use fft::{fft_program, FftPlan};
pub use library::{program_by_name, program_names};
pub use reduction::{reduction_program, ReductionPlan};
pub use registry::{KernelFamily, OpCountModel, Workload};
pub use transpose::{transpose_program, TransposePlan};
