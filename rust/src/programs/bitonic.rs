//! Bitonic sort — the first *divergent* kernel family: per-step owner
//! predication plus a data-dependent compare-exchange branch.
//!
//! The classic in-place bitonic network, fully unrolled: for each stage
//! pair `(k, j)` (`k = 2,4,..,N`; `j = k/2,..,1`) thread `i` with
//! `i & j == 0` owns the pair `(i, i + j)` and compare-exchanges it in
//! the direction selected by bit `k` of `i`. Two divergence shapes per
//! step:
//!
//! * the **owner branch** `bnz (tid & j), skip` predicates half the lanes
//!   off — deterministic divergence, whole warps idle once `j >= 16`
//!   (their memory ops issue with empty masks), intra-warp half-masks
//!   below;
//! * the **swap branch** is decided by the *loaded data* — both arms are
//!   pure register moves, so the memory/FP op counts stay closed-form
//!   (the golden model below) even though the executed instruction
//!   stream is input-dependent.
//!
//! Values are masked to 31 bits so the sign of a wrapping subtraction is
//! an exact comparison (the ISA has no compare instruction). The host
//! reference is simply the sorted input: the network sorts ascending for
//! any input, which the machine/host equivalence tests lean on.

use super::builder::ProgramBuilder;
use super::registry::{ExpectedImage, KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Placement metadata for a bitonic run.
#[derive(Debug, Clone, Copy)]
pub struct BitonicPlan {
    /// Element count N = thread count (power of two, 64..=2048).
    pub n: u32,
    /// Compare-exchange steps: log2(N)·(log2(N)+1)/2.
    pub steps: u32,
}

impl BitonicPlan {
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two() && (64..=2048).contains(&n));
        let logn = log2_exact(n);
        Self { n, steps: logn * (logn + 1) / 2 }
    }
}

fn valid(n: u32) -> bool {
    n.is_power_of_two() && (64..=2048).contains(&n)
}

/// Generate the bitonic program for an N-element array at word 0.
pub fn bitonic_program(n: u32) -> (BitonicPlan, Program) {
    let plan = BitonicPlan::new(n);
    let program = build(&plan);
    (plan, program)
}

/// Generate from an explicit plan.
pub fn build(plan: &BitonicPlan) -> Program {
    let n = plan.n;
    let mut b = ProgramBuilder::new(format!("bitonic{n}"), n);

    let tid = 0u8; // conventional
    b.tid(tid);
    let own = b.alloc();
    let laddr = b.alloc();
    let av = b.alloc();
    let bv = b.alloc();
    let dir = b.alloc();
    let gt = b.alloc();
    let lt = b.alloc();
    let sw = b.alloc();
    let lo = b.alloc();
    let hi = b.alloc();

    let mut k = 2u32;
    while k <= n {
        let logk = log2_exact(k);
        let mut j = k / 2;
        while j >= 1 {
            // Owner predicate: lanes with tid & j != 0 sit this step out.
            b.iandi(own, tid, j as u16);
            let skip = b.bnz_fwd(own);

            b.ld(av, tid); // a = data[i]
            b.iaddi(laddr, tid, j as i32); // partner = i + j (i & j == 0)
            b.ld(bv, laddr); // b = data[i + j]

            // Direction bit: 0 = ascending (min at i), 1 = descending.
            b.iandi(dir, tid, k as u16);
            b.ishri(dir, dir, logk as u16);

            // Sign-bit comparisons (values are < 2^31, so exact):
            // gt = (a > b), lt = (a < b).
            b.isub(gt, bv, av);
            b.ishri(gt, gt, 31);
            b.isub(lt, av, bv);
            b.ishri(lt, lt, 31);
            // swap = dir == 0 ? gt : lt  —  gt ^ ((gt ^ lt) & dir).
            b.ixor(sw, gt, lt);
            b.iand(sw, sw, dir);
            b.ixor(sw, sw, gt);

            // Data-dependent select: both arms are register moves only,
            // so the traced memory/FP ops below stay input-independent.
            b.iaddi(lo, av, 0);
            b.iaddi(hi, bv, 0);
            let doswap = b.bnz_fwd(sw);
            let store = b.jmp_fwd();
            let at = b.pc();
            b.patch_target(doswap, at);
            b.iaddi(lo, bv, 0);
            b.iaddi(hi, av, 0);
            let at = b.pc();
            b.patch_target(store, at);
            b.st(tid, lo);
            b.st(laddr, hi);

            let at = b.pc();
            b.patch_target(skip, at);
            j /= 2;
        }
        k *= 2;
    }
    b.halt();
    b.build()
}

/// Host reference: a full bitonic network sorts ascending.
pub fn reference_bitonic(input: &[u32]) -> Vec<u32> {
    let mut out = input.to_vec();
    out.sort_unstable();
    out
}

/// Build the registered workload for `bitonic{n}`.
pub fn workload(n: u32) -> Workload {
    let plan = BitonicPlan::new(n);
    let (_, program) = bitonic_program(n);
    Workload::new(program, (plan.n as usize).next_power_of_two())
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            for i in 0..plan.n {
                // 31-bit values keep the kernel's sign-trick compare exact.
                mem.write_word(i, rng.next_u32() >> 1);
            }
        })
        .with_expected(move |seed| {
            let mut rng = XorShift64::new(seed);
            let input: Vec<u32> = (0..plan.n).map(|_| rng.next_u32() >> 1).collect();
            ExpectedImage { base: 0, words: reference_bitonic(&input) }
        })
}

/// Analytical golden model: every step issues exactly 2 loads + 2 stores
/// over the whole block (divergence masks lanes off but never removes a
/// warp's op slot), so counts are closed-form despite the data-dependent
/// swap branch. No FP work — it's an integer sort.
pub fn model(n: u32) -> OpCountModel {
    let steps = BitonicPlan::new(n).steps as u64;
    let warps = n as u64 / 16;
    OpCountModel {
        d_load_ops: steps * 2 * warps,
        tw_load_ops: 0,
        store_ops: steps * 2 * warps,
        fp_ops: 0,
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "bitonic",
    prefix: "bitonic",
    title: "Bitonic Sort (divergent)",
    grammar: "bitonicN — N power of two, 64..=2048",
    valid,
    build: workload,
    model,
    sweep_params: &[256, 1024],
    sweep_archs: SweepArchs::Table3,
    paper: false,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;

    fn run_bitonic(n: u32, arch: MemoryArchKind, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let w = workload(n);
        let mut m = Machine::new(
            MachineConfig::for_arch(arch).with_mem_words(w.mem_words()).with_fast_timing(),
        );
        w.load_input(&mut m, seed);
        let input = m.read_image(0, n as usize);
        m.run_program(w.program()).expect("bitonic runs");
        (input, m.read_image(0, n as usize))
    }

    #[test]
    fn sorts_on_all_paper_archs() {
        for arch in MemoryArchKind::table3_nine() {
            let (input, out) = run_bitonic(128, arch, 11);
            assert_eq!(out, reference_bitonic(&input), "{arch}");
        }
    }

    #[test]
    fn sorts_multiple_seeds_at_larger_sizes() {
        for seed in [1, 2, 42] {
            let (input, out) = run_bitonic(512, MemoryArchKind::banked(16), seed);
            assert_eq!(out, reference_bitonic(&input), "seed {seed}");
        }
    }

    #[test]
    fn model_matches_traced_ops() {
        let w = workload(256);
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(16))
                .with_mem_words(w.mem_words())
                .with_fast_timing(),
        );
        w.load_input(&mut m, 7);
        m.run_program(w.program()).expect("runs");
        let trace = m.mem_trace().expect("trace captured");
        assert_eq!(OpCountModel::of_trace(trace), model(256));
    }

    #[test]
    #[should_panic]
    fn too_small_rejected() {
        BitonicPlan::new(32);
    }
}
