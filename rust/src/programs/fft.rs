//! 4096-point Cooley–Tukey FFT benchmark programs (paper Table III).
//!
//! The paper programs its FFTs "using the standard Cooley-Tukey algorithm"
//! (not constant-geometry Pease/Stockham), radix 4, 8 and 16, in-place,
//! with complex data stored interleaved (I/Q in adjacent addresses — the
//! layout the Offset bank mapping is designed for) and twiddle factors in
//! shared memory ("TW Load" rows).
//!
//! Structure (decimation in frequency): stage `s` has `L = N/Rˢ`,
//! butterflies gather `R` points spaced `L/R` apart, apply a DFT-R, then
//! multiply outputs `k ≥ 1` by `W_L^{jk}` (trivial in the last stage).
//! After `log_R N` stages the array holds `X[digit_reverse_R(p)]` at
//! position `p` ([`digit_reverse`]).
//!
//! One thread per butterfly: `N/R` threads (256 for radix-16, the paper's
//! §III-A example). Stores are *blocking* (`st`): "a blocking write is
//! used if the same data will likely be used immediately, such as the
//! reordering of data between passes of an FFT".
//!
//! DFT-R micro-kernels use the register-renaming `−i` trick and shared
//! FP constants, keeping the FP-op budget close to the paper's counts
//! (radix-4 ≈ 34 FP instructions per butterfly; see Table III "Common
//! Ops" checks in the tests).

use super::builder::{CReg, ProgramBuilder};
use super::registry::{KernelFamily, OpCountModel, SweepArchs, Workload};
use crate::isa::program::Program;
use crate::util::bits::log2_exact;
use crate::util::XorShift64;

/// Layout and metadata of one FFT benchmark instance.
#[derive(Debug, Clone)]
pub struct FftPlan {
    /// Transform size (power of the radix).
    pub n: u32,
    /// Radix (4, 8 or 16).
    pub radix: u32,
    /// Number of stages (`log_R N`).
    pub stages: u32,
    /// Word address of the interleaved complex data (re at `2i`, im at
    /// `2i+1`).
    pub data_base: u32,
    /// Word address of the twiddle table: a single shared `W_N` table
    /// (interleaved complex, `2N` words). Stage-`s` butterflies index it
    /// at `(j·k·Rˢ) mod N` — the classic Cooley–Tukey shared table, whose
    /// strided accesses at late stages produce the paper's low "TW Bank
    /// Eff." numbers, and which makes data + twiddles exactly 64 KB
    /// ("nearly 64KB with the required twiddle coefficients").
    pub tw_base: u32,
    /// Interleaved twiddle table contents (`W_N^m`, m = 0..N).
    pub twiddles: Vec<f32>,
    /// Thread-block size (`N/R` — one butterfly per thread per stage).
    pub threads: u32,
    /// Total shared-memory words the benchmark needs.
    pub words: u32,
}

impl FftPlan {
    /// Build the plan (twiddle layout + tables) for an N-point radix-R
    /// FFT.
    pub fn new(n: u32, radix: u32) -> Self {
        assert!(matches!(radix, 4 | 8 | 16), "paper radices are 4, 8, 16");
        let stages = {
            let mut s = 0u32;
            let mut v = 1u64;
            while v < n as u64 {
                v *= radix as u64;
                s += 1;
            }
            assert_eq!(v, n as u64, "n must be a power of the radix");
            s
        };
        let data_base = 0u32;
        let tw_base = 2 * n;
        let mut twiddles = Vec::with_capacity(2 * n as usize);
        for m in 0..n {
            let ang = -2.0 * std::f64::consts::PI * m as f64 / n as f64;
            twiddles.push(ang.cos() as f32);
            twiddles.push(ang.sin() as f32);
        }
        let words = tw_base + twiddles.len() as u32;
        Self { n, radix, stages, data_base, tw_base, twiddles, threads: n / radix, words }
    }

    /// Twiddle-region address range (for the simulator's TW-load
    /// classification).
    pub fn tw_region(&self) -> std::ops::Range<u32> {
        self.tw_base..self.tw_base + self.twiddles.len() as u32
    }

    /// Shared-memory words rounded up to a power of two.
    pub fn mem_words(&self) -> usize {
        (self.words as usize).next_power_of_two()
    }
}

/// Digit-reverse `idx` in base `radix` over `stages` digits — the output
/// permutation of the in-place DIF FFT.
pub fn digit_reverse(idx: u32, radix: u32, stages: u32) -> u32 {
    let mut v = idx;
    let mut out = 0;
    for _ in 0..stages {
        out = out * radix + v % radix;
        v /= radix;
    }
    out
}

/// FP constants shared by the butterfly kernels, materialized once.
struct Consts {
    /// `cos(π/4)` = 1/√2.
    c: u8,
    /// `−1/√2`.
    nc: u8,
    /// `cos(π/8)`.
    c1: u8,
    /// `−sin(π/8)` (the im part of `W16¹`).
    s1: u8,
    /// `−cos(π/8)`.
    nc1: u8,
    /// `sin(π/8)`.
    ns1: u8,
}

impl Consts {
    fn emit(b: &mut ProgramBuilder, radix: u32) -> Consts {
        let c = b.alloc();
        let nc = b.alloc();
        b.fconst(c, std::f32::consts::FRAC_1_SQRT_2);
        b.fconst(nc, -std::f32::consts::FRAC_1_SQRT_2);
        let (c1, s1, nc1, ns1) = if radix == 16 {
            let (c1, s1, nc1, ns1) = (b.alloc(), b.alloc(), b.alloc(), b.alloc());
            let cos = (std::f64::consts::PI / 8.0).cos() as f32;
            let sin = (std::f64::consts::PI / 8.0).sin() as f32;
            b.fconst(c1, cos);
            b.fconst(s1, -sin);
            b.fconst(nc1, -cos);
            b.fconst(ns1, sin);
            (c1, s1, nc1, ns1)
        } else {
            (0, 0, 0, 0)
        };
        Consts { c, nc, c1, s1, nc1, ns1 }
    }
}

/// DFT-4 on `x`, in place up to renaming: `y_k = Σ_m x_m W4^{km}`.
/// Returns the output registers in natural `k` order (16 FP ops).
fn dft4(b: &mut ProgramBuilder, x: [CReg; 4]) -> [CReg; 4] {
    let t0 = b.alloc_c();
    let t1 = b.alloc_c();
    let t2 = b.alloc_c();
    let t3 = b.alloc_c();
    b.cadd(t0, x[0], x[2]); // t0 = x0 + x2
    b.csub(t1, x[0], x[2]); // t1 = x0 − x2
    b.cadd(t2, x[1], x[3]); // t2 = x1 + x3
    b.csub(t3, x[1], x[3]); // t3 = x1 − x3
    // y0 = t0 + t2, y2 = t0 − t2 (reuse x0/x2 registers).
    b.cadd(x[0], t0, t2);
    b.csub(x[2], t0, t2);
    // y1 = t1 − i·t3 = (t1r + t3i, t1i − t3r); y3 = t1 + i·t3.
    b.fadd(x[1].re, t1.re, t3.im);
    b.fsub(x[1].im, t1.im, t3.re);
    b.fsub(x[3].re, t1.re, t3.im);
    b.fadd(x[3].im, t1.im, t3.re);
    b.release_c(t0);
    b.release_c(t1);
    b.release_c(t2);
    b.release_c(t3);
    [x[0], x[1], x[2], x[3]]
}

/// DFT-8 via the 2×4 split: `a_m = x_m + x_{m+4}`, `b_m = (x_m − x_{m+4})
/// · W8^m`, `X[2r] = DFT4(a)[r]`, `X[2r+1] = DFT4(b)[r]`.
fn dft8(b: &mut ProgramBuilder, x: [CReg; 8], k: &Consts) -> [CReg; 8] {
    let (t0, t1) = (b.alloc(), b.alloc());
    let mut a = [CReg { re: 0, im: 0 }; 4];
    let mut bb = [CReg { re: 0, im: 0 }; 4];
    for m in 0..4 {
        a[m] = b.alloc_c();
        b.cadd(a[m], x[m], x[m + 4]);
        b.csub(x[m], x[m], x[m + 4]); // b_m lands in x_m's registers
        bb[m] = x[m];
        b.release_c(x[m + 4]);
    }
    // Twiddle the odd path: W8¹ = (c, −c), W8² = −i, W8³ = (−c, −c).
    b.cmul_inplace(bb[1], k.c, k.nc, t0, t1);
    bb[2] = b.cmul_negi(bb[2]);
    b.cmul_inplace(bb[3], k.nc, k.nc, t0, t1);
    b.release(t0);
    b.release(t1);
    let ya = dft4(b, a);
    let yb = dft4(b, bb);
    [ya[0], yb[0], ya[1], yb[1], ya[2], yb[2], ya[3], yb[3]]
}

/// DFT-16 via the 4×4 split: inner DFT4s over the stride-4 quadruples,
/// the nine nontrivial `W16^{mr}` twiddles, then outer DFT4s.
fn dft16(b: &mut ProgramBuilder, x: [CReg; 16], k: &Consts) -> [CReg; 16] {
    let mut slot = x;
    // Step 1: c_{m,r} = DFT4(x_m, x_{m+4}, x_{m+8}, x_{m+12}) → slot m+4r.
    for m in 0..4 {
        let q = [slot[m], slot[m + 4], slot[m + 8], slot[m + 12]];
        let y = dft4(b, q);
        for (r, yy) in y.into_iter().enumerate() {
            slot[m + 4 * r] = yy;
        }
    }
    // Step 2: d_{m,r} = c_{m,r} · W16^{mr} for m,r ≥ 1.
    let (t0, t1) = (b.alloc(), b.alloc());
    for m in 1..4u32 {
        for r in 1..4u32 {
            let idx = (m + 4 * r) as usize;
            match (m * r) % 16 {
                1 => b.cmul_inplace(slot[idx], k.c1, k.s1, t0, t1),
                2 => b.cmul_inplace(slot[idx], k.c, k.nc, t0, t1),
                3 => b.cmul_inplace(slot[idx], k.ns1, k.nc1, t0, t1),
                4 => slot[idx] = b.cmul_negi(slot[idx]),
                6 => b.cmul_inplace(slot[idx], k.nc, k.nc, t0, t1),
                9 => b.cmul_inplace(slot[idx], k.nc1, k.ns1, t0, t1),
                other => unreachable!("W16^{other} cannot appear"),
            }
        }
    }
    b.release(t0);
    b.release(t1);
    // Step 3: X[r+4p] = DFT4 over m of d_{m,r} → slot 4r+p.
    for r in 0..4 {
        let q = [slot[4 * r], slot[4 * r + 1], slot[4 * r + 2], slot[4 * r + 3]];
        let y = dft4(b, q);
        for (p, yy) in y.into_iter().enumerate() {
            slot[4 * r + p] = yy;
        }
    }
    // Output k = r + 4p lives in slot 4r + p.
    let mut out = [CReg { re: 0, im: 0 }; 16];
    for r in 0..4 {
        for p in 0..4 {
            out[r + 4 * p] = slot[4 * r + p];
        }
    }
    out
}

/// Generate the FFT program for a plan.
pub fn build(plan: &FftPlan) -> Program {
    let r = plan.radix as usize;
    let mut b = ProgramBuilder::new(format!("fft{}r{}", plan.n, plan.radix), plan.threads);
    let tid = 0u8;
    b.tid(tid);
    let consts = Consts::emit(&mut b, plan.radix);

    // Persistent scratch for address math.
    let j = b.alloc();
    let base = b.alloc();
    let dbase = b.alloc();
    let a = b.alloc();
    let tw = b.alloc_c();
    // Data registers for one butterfly.
    let mut x = Vec::with_capacity(r);
    for _ in 0..r {
        x.push(b.alloc_c());
    }

    for s in 0..plan.stages {
        let l = plan.n / plan.radix.pow(s);
        let ln = l / plan.radix;
        let log_ln = log2_exact(ln) as u16;
        let log_l = log2_exact(l) as u16;

        // j = tid & (Ln−1); base = ((tid >> log Ln) << log L) + j.
        b.iandi(j, tid, (ln - 1) as u16);
        b.ishri(base, tid, log_ln);
        b.ishli(base, base, log_l);
        b.iadd(base, base, j);
        // dbase = data_base + 2·base.
        b.ishli(dbase, base, 1);
        if plan.data_base != 0 {
            b.iaddi(dbase, dbase, plan.data_base as i32);
        }

        // Loads: x_k ← data[base + k·Ln] (interleaved re/im).
        for (kk, xk) in x.iter().enumerate() {
            let off = 2 * kk as u32 * ln;
            assert!(off + 1 <= u16::MAX as u32);
            b.iaddi(a, dbase, off as i32);
            b.ld(xk.re, a);
            b.iaddi(a, a, 1);
            b.ld(xk.im, a);
        }

        // Butterfly.
        let y: Vec<CReg> = match plan.radix {
            4 => dft4(&mut b, [x[0], x[1], x[2], x[3]]).to_vec(),
            8 => dft8(&mut b, [x[0], x[1], x[2], x[3], x[4], x[5], x[6], x[7]], &consts).to_vec(),
            16 => {
                let arr: [CReg; 16] = x.clone().try_into().unwrap();
                dft16(&mut b, arr, &consts).to_vec()
            }
            _ => unreachable!(),
        };

        // Twiddles W_L^{jk} = W_N^{j·k·Rˢ} from the shared table (all
        // stages except the last).
        if s + 1 < plan.stages {
            assert!(plan.tw_base <= u16::MAX as u32);
            let rs = plan.radix.pow(s);
            let (t0, t1) = (b.alloc(), b.alloc());
            for (kk, yk) in y.iter().enumerate().skip(1) {
                // a = tw_base + 2·((j·k·Rˢ) mod N).
                let step = kk as u32 * rs;
                assert!(step <= u16::MAX as u32);
                b.imuli(a, j, step as u16);
                b.iandi(a, a, (plan.n - 1) as u16);
                b.ishli(a, a, 1);
                b.iaddi(a, a, plan.tw_base as i32);
                b.ld(tw.re, a);
                b.iaddi(a, a, 1);
                b.ld(tw.im, a);
                b.cmul_inplace(*yk, tw.re, tw.im, t0, t1);
            }
            b.release(t0);
            b.release(t1);
        }

        // Stores (blocking — data is reused by the next pass).
        for (kk, yk) in y.iter().enumerate() {
            let off = 2 * kk as u32 * ln;
            b.iaddi(a, dbase, off as i32);
            b.st(a, yk.re);
            b.iaddi(a, a, 1);
            b.st(a, yk.im);
        }

        // Renaming may have permuted the register pairs; carry them over.
        for (xk, yk) in x.iter_mut().zip(y.iter()) {
            *xk = *yk;
        }
    }
    b.halt();
    b.build()
}

/// Convenience: plan + program for the paper's 4096-point benchmark.
pub fn fft_program(radix: u32) -> (FftPlan, Program) {
    let plan = FftPlan::new(4096, radix);
    let program = build(&plan);
    (plan, program)
}

fn valid(radix: u32) -> bool {
    matches!(radix, 4 | 8 | 16)
}

/// Build the registered workload for `fft4096r{radix}`. No exact host
/// image (f32 pipelines validate by tolerance —
/// [`crate::coordinator::validate::validate_ffts`]).
pub fn workload(radix: u32) -> Workload {
    let (plan, program) = fft_program(radix);
    let mem_words = plan.mem_words();
    let tw = plan.tw_region();
    Workload::new(program, mem_words)
        .with_tw_region(tw)
        .with_fill(move |mem, seed| {
            let mut rng = XorShift64::new(seed);
            let data = rng.f32_vec(2 * plan.n as usize);
            for (i, &v) in data.iter().enumerate() {
                mem.write_word(plan.data_base + i as u32, v.to_bits());
            }
            for (i, &v) in plan.twiddles.iter().enumerate() {
                mem.write_word(plan.tw_base + i as u32, v.to_bits());
            }
        })
}

/// Analytical golden model, read straight off [`build`]: every stage
/// loads and stores `2R` words per butterfly (interleaved re/im of R
/// points); every stage but the last loads `2(R−1)` twiddle words and
/// spends `6(R−1)` FP ops applying them; the DFT-R micro-kernels cost
/// 16 / 61 / 177 FP ops for R = 4 / 8 / 16 (the radix-4 total of
/// 16 + 18 = 34 per butterfly matches the paper's "≈34 FP instructions").
pub fn model(radix: u32) -> OpCountModel {
    let n = 4096u64;
    let r = radix as u64;
    let stages = match radix {
        4 => 6u64,
        8 => 4,
        16 => 3,
        _ => unreachable!("valid() gates the radices"),
    };
    let warps = (n / r) / 16;
    let data = stages * 2 * r * warps;
    let dft_fp = match radix {
        4 => 16u64,
        8 => 61,
        16 => 177,
        _ => unreachable!(),
    };
    OpCountModel {
        d_load_ops: data,
        tw_load_ops: (stages - 1) * 2 * (r - 1) * warps,
        store_ops: data,
        fp_ops: warps * (stages * dft_fp + (stages - 1) * 6 * (r - 1)),
    }
}

pub const FAMILY: KernelFamily = KernelFamily {
    family: "fft",
    prefix: "fft4096r",
    title: "4096-Point Cooley-Tukey FFT",
    grammar: "fft4096rR — R in {4, 8, 16}",
    valid,
    build: workload,
    model,
    sweep_params: &[4, 8, 16],
    sweep_archs: SweepArchs::Table3,
    paper: true,
};

/// Iterative radix-2 reference FFT in f64 (host-side oracle for tests and
/// golden validation; `jnp.fft` plays the same role on the Python side).
pub fn reference_fft(re: &[f32], im: &[f32]) -> (Vec<f64>, Vec<f64>) {
    let n = re.len();
    assert!(n.is_power_of_two() && n == im.len());
    let mut xr: Vec<f64> = re.iter().map(|&v| v as f64).collect();
    let mut xi: Vec<f64> = im.iter().map(|&v| v as f64).collect();
    // Bit-reverse permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            xr.swap(i, j);
            xi.swap(i, j);
        }
    }
    // Butterfly passes.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (i0, i1) = (start + k, start + k + len / 2);
                let (tr, ti) = (xr[i1] * cr - xi[i1] * ci, xr[i1] * ci + xi[i1] * cr);
                xr[i1] = xr[i0] - tr;
                xi[i1] = xi[i0] - ti;
                xr[i0] += tr;
                xi[i0] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len *= 2;
    }
    (xr, xi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::sim::config::MachineConfig;
    use crate::sim::machine::Machine;
    use crate::sim::stats::RunReport;
    use crate::util::XorShift64;

    /// Run an FFT program on a machine and return (machine, report, plan).
    fn run_fft(radix: u32, arch: MemoryArchKind, seed: u64) -> (Machine, RunReport, FftPlan) {
        let (plan, program) = fft_program(radix);
        let cfg = MachineConfig::for_arch(arch)
            .with_mem_words(plan.mem_words())
            .with_tw_region(plan.tw_region())
            .with_fast_timing();
        let mut m = Machine::new(cfg);
        let mut rng = XorShift64::new(seed);
        let mut interleaved = Vec::with_capacity(2 * plan.n as usize);
        for _ in 0..plan.n {
            interleaved.push(rng.signed_f32());
            interleaved.push(rng.signed_f32());
        }
        m.load_f32_image(plan.data_base, &interleaved);
        m.load_f32_image(plan.tw_base, &plan.twiddles);
        let r = m.run_program(&program).expect("fft runs");
        (m, r, plan)
    }

    /// Validate the simulated FFT against the host reference.
    fn check_numerics(radix: u32, arch: MemoryArchKind) {
        let seed = 42 + radix as u64;
        let (m, _, plan) = run_fft(radix, arch, seed);
        // Reconstruct the input from the same seed.
        let mut rng = XorShift64::new(seed);
        let n = plan.n as usize;
        let (mut ire, mut iim) = (Vec::with_capacity(n), Vec::with_capacity(n));
        for _ in 0..n {
            ire.push(rng.signed_f32());
            iim.push(rng.signed_f32());
        }
        let (er, ei) = reference_fft(&ire, &iim);
        let out = m.read_f32_image(plan.data_base, 2 * n);
        // data[p] == X[digit_reverse(p)]; equivalently X[k] = data[rev(k)].
        let mut max_err = 0.0f64;
        let mut max_mag = 0.0f64;
        for k in 0..n {
            let p = digit_reverse(k as u32, plan.radix, plan.stages) as usize;
            let (gr, gi) = (out[2 * p] as f64, out[2 * p + 1] as f64);
            let err = ((gr - er[k]).powi(2) + (gi - ei[k]).powi(2)).sqrt();
            max_err = max_err.max(err);
            max_mag = max_mag.max((er[k].powi(2) + ei[k].powi(2)).sqrt());
        }
        let rel = max_err / max_mag;
        assert!(rel < 2e-5, "radix-{radix} on {arch}: rel err {rel}");
    }

    #[test]
    fn radix4_numerics_banked16() {
        check_numerics(4, MemoryArchKind::banked(16));
    }

    #[test]
    fn radix8_numerics_offset8() {
        check_numerics(8, MemoryArchKind::banked_offset(8));
    }

    #[test]
    fn radix16_numerics_4r1w() {
        check_numerics(16, MemoryArchKind::mp_4r1w());
    }

    #[test]
    fn radix16_numerics_vb() {
        check_numerics(16, MemoryArchKind::mp_4r1w_vb());
    }

    #[test]
    fn plan_matches_paper_geometry() {
        let p4 = FftPlan::new(4096, 4);
        assert_eq!(p4.stages, 6);
        assert_eq!(p4.threads, 1024);
        let p8 = FftPlan::new(4096, 8);
        assert_eq!(p8.stages, 4);
        assert_eq!(p8.threads, 512);
        let p16 = FftPlan::new(4096, 16);
        assert_eq!(p16.stages, 3);
        // "the 4096-point, Radix-16 FFT used in this work uses 256 threads"
        assert_eq!(p16.threads, 256);
        // "a large dataset (nearly 64KB with the required twiddle
        // coefficients)" — 32 KB data + 32 KB shared W_N table = 64 KB,
        // identical across radices ("The 4096-point FFT requires 64KB
        // (data and twiddles)", §VI).
        assert_eq!(p4.words * 4, 65_536);
        assert_eq!(p8.words * 4, 65_536);
        assert_eq!(p16.words * 4, 65_536);
    }

    #[test]
    fn load_store_ops_match_paper() {
        // Table III: D Load/Store ops 3072 (r4), 2048 (r8), 1536 (r16);
        // TW loads 1920 (r4), 1344 (r8), 960 (r16).
        for (radix, d_ops, tw_ops) in [(4u32, 3072u64, 1920u64), (8, 2048, 1344), (16, 1536, 960)]
        {
            let (_, r, _) = run_fft(radix, MemoryArchKind::banked(16), 7);
            assert_eq!(r.stats.d_load_ops, d_ops, "radix {radix} D loads");
            assert_eq!(r.stats.store_ops, d_ops, "radix {radix} stores");
            assert_eq!(r.stats.tw_load_ops, tw_ops, "radix {radix} TW loads");
        }
    }

    #[test]
    fn multiport_fft_cycles_deterministic() {
        // 4R loads: ops×4. 1W stores: ops×16; 2W: ops×8.
        let (_, r1, _) = run_fft(4, MemoryArchKind::mp_4r1w(), 3);
        assert_eq!(r1.stats.d_load_cycles, 3072 * 4);
        assert_eq!(r1.stats.tw_load_cycles, 1920 * 4);
        assert_eq!(r1.stats.store_cycles, 3072 * 16);
        let (_, r2, _) = run_fft(4, MemoryArchKind::mp_4r2w(), 3);
        assert_eq!(r2.stats.store_cycles, 3072 * 8);
    }

    #[test]
    fn vb_write_bandwidth_between_1w_and_2w() {
        // §V: VB "improve[s] write bandwidth on average to that of the
        // 4R-2W memory, but at the higher system speed".
        let (_, r1w, _) = run_fft(16, MemoryArchKind::mp_4r1w(), 5);
        let (_, rvb, _) = run_fft(16, MemoryArchKind::mp_4r1w_vb(), 5);
        assert!(rvb.stats.store_cycles < r1w.stats.store_cycles);
        assert!(rvb.time_us() < r1w.time_us());
    }

    #[test]
    fn fp_op_budget_near_paper() {
        // Paper radix-4: 13440 FP cycles over 64-op instructions and 6
        // stages ⇒ 35 FP instructions per stage. Ours should be within a
        // few instructions of that (34 for the classic 3-cmul + 8-cadd
        // radix-4 butterfly).
        let (plan, program) = fft_program(4);
        let fp = program.static_census()["fp"] as u32;
        let per_stage = (fp - 4 /* shared consts */) / plan.stages;
        assert!(
            (30..=40).contains(&per_stage),
            "radix-4 FP instructions/stage = {per_stage}"
        );
        // Radix-16: paper 12384 / 16 ops / 3 stages = 258.
        let (plan16, program16) = fft_program(16);
        let fp16 = program16.static_census()["fp"] as u32 / plan16.stages;
        assert!(
            (220..=300).contains(&fp16),
            "radix-16 FP instructions/stage = {fp16}"
        );
    }

    #[test]
    fn digit_reverse_involution() {
        for (radix, stages) in [(4u32, 6u32), (8, 4), (16, 3)] {
            for idx in [0u32, 1, 17, 4095, 2048] {
                let r = digit_reverse(idx, radix, stages);
                assert!(r < 4096);
                assert_eq!(digit_reverse(r, radix, stages), idx);
            }
        }
    }

    #[test]
    fn reference_fft_dc_and_impulse() {
        // DC input → X[0] = N, rest 0.
        let n = 64;
        let re = vec![1.0f32; n];
        let im = vec![0.0f32; n];
        let (xr, xi) = reference_fft(&re, &im);
        assert!((xr[0] - n as f64).abs() < 1e-9);
        for k in 1..n {
            assert!(xr[k].abs() < 1e-9 && xi[k].abs() < 1e-9);
        }
        // Impulse → flat spectrum.
        let mut re = vec![0.0f32; n];
        re[0] = 1.0;
        let (xr, xi) = reference_fft(&re, &im);
        for k in 0..n {
            assert!((xr[k] - 1.0).abs() < 1e-9 && xi[k].abs() < 1e-9);
        }
    }

    #[test]
    fn offset_mapping_beats_lsb_for_fft() {
        // The headline of Table III: complex interleaved data + Offset
        // mapping beats the LSB map on banked memories.
        let (_, lsb, _) = run_fft(4, MemoryArchKind::banked(16), 11);
        let (_, off, _) = run_fft(4, MemoryArchKind::banked_offset(16), 11);
        assert!(
            off.total_cycles() < lsb.total_cycles(),
            "offset {} !< lsb {}",
            off.total_cycles(),
            lsb.total_cycles()
        );
    }

    #[test]
    fn all_nine_archs_agree_functionally() {
        // Timing differs wildly; the numbers must not.
        let mut images = Vec::new();
        for arch in MemoryArchKind::table3_nine() {
            let (m, _, plan) = run_fft(8, arch, 99);
            images.push(m.read_image(plan.data_base, 2 * plan.n as usize));
        }
        for img in &images[1..] {
            assert_eq!(img, &images[0]);
        }
    }
}
