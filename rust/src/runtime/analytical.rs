//! Analytical timing mode: estimate a program's banked-memory cycles from
//! its memory-operation trace through the **Pallas conflict-kernel
//! artifact** — the L1 kernel running on the Rust hot path via PJRT.
//!
//! This is the batch counterpart of the cycle-accurate controllers: one
//! PJRT call scores 256 operations at once instead of stepping arbiters
//! per cycle. Integration tests pin the estimate to the simulator's
//! attributed load/store cycles exactly (same conflict maths, same
//! overhead model), which is also the repo's strongest evidence that the
//! L1 kernel and the L3 controller implement the same architecture.
//!
//! Since the execution/timing split, both estimators consume the same
//! [`MemTrace`] the decoupled simulator produces — the analytical oracle
//! is simply a *third* timing backend for a captured trace, next to the
//! cycle-accurate replayer ([`crate::sim::replay`]).

use super::client::ArtifactRuntime;
use super::golden::conflict_oracle;
use super::{RtError, RtResult};
use crate::mem::arch::{MemoryArchKind, OpKind};
use crate::mem::timing;
use crate::mem::{FULL_MASK, LANES};
use crate::sim::exec::MemTrace;

/// Cycle estimate for one program trace on one banked architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AnalyticalEstimate {
    /// Estimated read-instruction cycles (data + twiddle loads together;
    /// the oracle has no address-region classifier).
    pub load_cycles: u64,
    /// Estimated write-instruction cycles.
    pub store_cycles: u64,
    /// Operations scored.
    pub ops: u64,
}

impl AnalyticalEstimate {
    pub fn total_mem_cycles(&self) -> u64 {
        self.load_cycles + self.store_cycles
    }
}

/// Score a memory trace for a banked architecture through the PJRT
/// conflict oracle.
///
/// Requirements: a banked `arch` whose mapping the `conflict{B}` artifact
/// covers (LSB/Offset; the XOR map is simulator-only), and full lane
/// masks (the paper's benchmarks always run multiples of 16 threads).
pub fn estimate_banked(
    rt: &ArtifactRuntime,
    arch: MemoryArchKind,
    trace: &MemTrace,
) -> RtResult<AnalyticalEstimate> {
    let MemoryArchKind::Banked { banks, mapping } = arch else {
        return Err(RtError::new(
            "analytical mode scores banked architectures (multiport is closed-form)",
        ));
    };
    if !mapping.oracle_supported() {
        return Err(RtError::new(format!(
            "the conflict artifact does not cover the {mapping:?} map"
        )));
    }
    // Flatten the trace, remembering instruction boundaries and kinds.
    let mut flat: Vec<[u32; LANES]> = Vec::new();
    for instr in trace.mem_instrs() {
        for &(addrs, mask) in &instr.ops {
            if mask != FULL_MASK {
                return Err(RtError::new("analytical mode requires full 16-lane operations"));
            }
            flat.push(addrs);
        }
    }
    let costs = conflict_oracle(rt, banks, &flat, mapping.shift())?;
    // Re-apply the §III-A instruction overhead model.
    let mut est = AnalyticalEstimate { load_cycles: 0, store_cycles: 0, ops: flat.len() as u64 };
    let mut cursor = 0usize;
    for instr in trace.mem_instrs() {
        let n = instr.ops.len();
        let spacing: u64 = costs[cursor..cursor + n]
            .iter()
            .map(|&c| c.max(1) as u64)
            .sum();
        cursor += n;
        match instr.op_kind() {
            OpKind::Read => {
                est.load_cycles += timing::banked_read_overhead(false) as u64 + spacing;
            }
            OpKind::Write => {
                est.store_cycles += timing::banked_write_overhead(false) as u64 + spacing;
            }
        }
    }
    Ok(est)
}

/// Closed-form multiport estimate (no oracle needed): ⌈16/R⌉ per read op,
/// ⌈16/W⌉ per write op — deterministic access is the multiport memory's
/// defining property.
pub fn estimate_multiport(arch: MemoryArchKind, trace: &MemTrace) -> RtResult<AnalyticalEstimate> {
    let MemoryArchKind::MultiPort { read_ports, write_ports, vb } = arch else {
        return Err(RtError::new("not a multiport architecture"));
    };
    let mut est = AnalyticalEstimate { load_cycles: 0, store_cycles: 0, ops: 0 };
    for instr in trace.mem_instrs() {
        for &(_, mask) in &instr.ops {
            let active = mask.count_ones();
            est.ops += 1;
            match instr.op_kind() {
                OpKind::Read => {
                    est.load_cycles += crate::util::bits::ceil_div(active, read_ports).max(1) as u64
                }
                OpKind::Write => {
                    let w = if vb { 2 } else { write_ports };
                    est.store_cycles += crate::util::bits::ceil_div(active, w).max(1) as u64
                }
            }
        }
    }
    Ok(est)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::mapping::BankMapping;
    use crate::sim::exec::{LoadClass, MemAccessKind, MemInstr};

    fn trace_one(kind: OpKind, ops: usize) -> MemTrace {
        let mut addrs = [0u32; LANES];
        for (l, a) in addrs.iter_mut().enumerate() {
            *a = l as u32;
        }
        let kind = match kind {
            OpKind::Read => MemAccessKind::Load(LoadClass::Data),
            OpKind::Write => MemAccessKind::Store { blocking: true },
        };
        MemTrace::from_mem_instrs(
            "synthetic",
            16 * ops as u32,
            vec![MemInstr { kind, ops: vec![(addrs, FULL_MASK); ops] }],
        )
    }

    #[test]
    fn multiport_closed_form() {
        let est = estimate_multiport(MemoryArchKind::mp_4r1w(), &trace_one(OpKind::Read, 64))
            .unwrap();
        assert_eq!(est.load_cycles, 64 * 4);
        let est = estimate_multiport(MemoryArchKind::mp_4r1w(), &trace_one(OpKind::Write, 64))
            .unwrap();
        assert_eq!(est.store_cycles, 64 * 16);
        let est = estimate_multiport(MemoryArchKind::mp_4r1w_vb(), &trace_one(OpKind::Write, 64))
            .unwrap();
        assert_eq!(est.store_cycles, 64 * 8);
    }

    #[test]
    fn multiport_rejects_banked() {
        let empty = MemTrace::from_mem_instrs("empty", 16, vec![]);
        assert!(estimate_multiport(MemoryArchKind::banked(16), &empty).is_err());
    }

    #[test]
    fn banked_rejects_xor_and_partial_masks() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        let xor = MemoryArchKind::Banked { banks: 16, mapping: BankMapping::Xor };
        let empty = MemTrace::from_mem_instrs("empty", 16, vec![]);
        assert!(estimate_banked(&rt, xor, &empty).is_err());
        let partial = MemTrace::from_mem_instrs(
            "partial",
            8,
            vec![MemInstr {
                kind: MemAccessKind::Load(LoadClass::Data),
                ops: vec![([0u32; LANES], 0x00FF)],
            }],
        );
        assert!(estimate_banked(&rt, MemoryArchKind::banked(16), &partial).is_err());
    }

    // The oracle-vs-simulator equality is integration-tested in
    // rust/tests/analytical.rs (needs `make artifacts` and the `pjrt`
    // feature).
}
