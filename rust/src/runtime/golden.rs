//! Golden-model validation through the AOT artifacts.
//!
//! Three oracles, all produced by `python/compile/aot.py` from the L2 JAX
//! model (which itself is pytest-validated against pure-jnp references):
//!
//! - `fft4096` — the 4096-point complex FFT (Pallas butterfly stages);
//!   validates the simulated FFT programs end to end,
//! - `transposeN` — N×N transpose (Pallas tiled kernel),
//! - `conflictB` — the batched bank-conflict analyzer (the L1 twin of
//!   [`crate::mem::conflict`]); powers the *analytical timing mode* and is
//!   cross-checked against the cycle-accurate controllers.
//!
//! Without the `pjrt` feature every function here returns an error; the
//! stub [`ArtifactRuntime`] reports no artifacts, so callers never reach
//! these paths (they take their host-reference branches instead).

use super::client::ArtifactRuntime;
use super::RtResult;
use crate::mem::LANES;
use crate::programs::fft::FftPlan;
use crate::sim::machine::Machine;

#[cfg(not(feature = "pjrt"))]
use super::RtError;
#[cfg(feature = "pjrt")]
use super::{rt_err, RtError};
#[cfg(feature = "pjrt")]
use crate::programs::fft::digit_reverse;

/// Batch rows per conflict-oracle call (fixed in the artifact's shape).
pub const CONFLICT_BATCH: usize = 256;

/// Run the golden 4096-point FFT on split re/im inputs.
#[cfg(feature = "pjrt")]
pub fn golden_fft(rt: &ArtifactRuntime, re: &[f32], im: &[f32]) -> RtResult<(Vec<f32>, Vec<f32>)> {
    if re.len() != 4096 || im.len() != 4096 {
        return Err(RtError::new("golden_fft expects 4096-point inputs"));
    }
    let outs = rt.execute_f32("fft4096", &[re, im])?;
    if outs.len() != 2 {
        return Err(RtError::new(format!(
            "fft4096 artifact must return (re, im), got {} outputs",
            outs.len()
        )));
    }
    let mut it = outs.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap()))
}

/// Run the golden N×N transpose.
#[cfg(feature = "pjrt")]
pub fn golden_transpose(rt: &ArtifactRuntime, n: usize, x: &[f32]) -> RtResult<Vec<f32>> {
    if x.len() != n * n {
        return Err(RtError::new(format!("transpose input must be {n}x{n}")));
    }
    let lit = xla::Literal::vec1(x)
        .reshape(&[n as i64, n as i64])
        .map_err(|e| rt_err("reshaping transpose input", e))?;
    let outs = rt.execute(&format!("transpose{n}"), &[lit])?;
    if outs.len() != 1 {
        return Err(RtError::new("transpose artifact must return a single output"));
    }
    outs[0]
        .to_vec::<f32>()
        .map_err(|e| rt_err("reading transpose output", e))
}

/// Batched bank-conflict oracle: max per-bank access count for each
/// 16-lane operation, through the Pallas `conflict{banks}` artifact.
/// `shift` is the mapping's bit offset (0 = LSB, 2 = Offset).
#[cfg(feature = "pjrt")]
pub fn conflict_oracle(
    rt: &ArtifactRuntime,
    banks: u32,
    ops: &[[u32; LANES]],
    shift: u32,
) -> RtResult<Vec<u32>> {
    let name = format!("conflict{banks}");
    let mut out = Vec::with_capacity(ops.len());
    for chunk in ops.chunks(CONFLICT_BATCH) {
        // Pad the final chunk with zero-address rows (conflict 16, sliced
        // off below).
        let mut flat: Vec<i32> = Vec::with_capacity(CONFLICT_BATCH * LANES);
        for row in chunk {
            flat.extend(row.iter().map(|&a| a as i32));
        }
        flat.resize(CONFLICT_BATCH * LANES, 0);
        let lit = xla::Literal::vec1(&flat)
            .reshape(&[CONFLICT_BATCH as i64, LANES as i64])
            .map_err(|e| rt_err("reshaping conflict batch", e))?;
        let shift_lit = xla::Literal::scalar(shift as i32);
        let outs = rt
            .execute(&name, &[lit, shift_lit])
            .map_err(|e| rt_err(format!("conflict oracle banks={banks}"), e))?;
        let counts = outs[0]
            .to_vec::<i32>()
            .map_err(|e| rt_err("reading conflict counts", e))?;
        out.extend(counts[..chunk.len()].iter().map(|&c| c as u32));
    }
    Ok(out)
}

/// Validate a simulated FFT memory image against the golden FFT.
/// `machine` must have just run the program of `plan` on inputs `re`/`im`.
/// Returns the max relative error.
#[cfg(feature = "pjrt")]
pub fn validate_fft(
    rt: &ArtifactRuntime,
    machine: &Machine,
    plan: &FftPlan,
    re: &[f32],
    im: &[f32],
) -> RtResult<f64> {
    let (gr, gi) = golden_fft(rt, re, im)?;
    let out = machine.read_f32_image(plan.data_base, 2 * plan.n as usize);
    let mut max_err = 0.0f64;
    let mut max_mag = 0.0f64;
    for k in 0..plan.n as usize {
        let p = digit_reverse(k as u32, plan.radix, plan.stages) as usize;
        let (sr, si) = (out[2 * p] as f64, out[2 * p + 1] as f64);
        let err = ((sr - gr[k] as f64).powi(2) + (si - gi[k] as f64).powi(2)).sqrt();
        max_err = max_err.max(err);
        max_mag = max_mag.max(((gr[k] as f64).powi(2) + (gi[k] as f64).powi(2)).sqrt());
    }
    Ok(max_err / max_mag.max(1e-30))
}

// ------------------------------------------------------------- stubs

/// Stub: the PJRT bridge is not compiled in.
#[cfg(not(feature = "pjrt"))]
pub fn golden_fft(rt: &ArtifactRuntime, re: &[f32], im: &[f32]) -> RtResult<(Vec<f32>, Vec<f32>)> {
    if re.len() != 4096 || im.len() != 4096 {
        return Err(RtError::new("golden_fft expects 4096-point inputs"));
    }
    Err(rt.unavailable("golden FFT"))
}

/// Stub: the PJRT bridge is not compiled in.
#[cfg(not(feature = "pjrt"))]
pub fn golden_transpose(rt: &ArtifactRuntime, n: usize, x: &[f32]) -> RtResult<Vec<f32>> {
    if x.len() != n * n {
        return Err(RtError::new(format!("transpose input must be {n}x{n}")));
    }
    Err(rt.unavailable("golden transpose"))
}

/// Stub: the PJRT bridge is not compiled in.
#[cfg(not(feature = "pjrt"))]
pub fn conflict_oracle(
    rt: &ArtifactRuntime,
    banks: u32,
    _ops: &[[u32; LANES]],
    _shift: u32,
) -> RtResult<Vec<u32>> {
    Err(rt.unavailable(&format!("conflict oracle banks={banks}")))
}

/// Stub: the PJRT bridge is not compiled in.
#[cfg(not(feature = "pjrt"))]
pub fn validate_fft(
    rt: &ArtifactRuntime,
    _machine: &Machine,
    _plan: &FftPlan,
    _re: &[f32],
    _im: &[f32],
) -> RtResult<f64> {
    Err(rt.unavailable("golden FFT validation"))
}

#[cfg(test)]
mod tests {
    // PJRT-dependent paths are integration-tested in rust/tests/golden.rs
    // (they require `make artifacts`). Here: input validation only — the
    // size checks hold in both the real and stub builds.
    use super::*;

    #[test]
    fn golden_fft_rejects_wrong_size() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        let v = vec![0.0f32; 8];
        assert!(golden_fft(&rt, &v, &v).is_err());
    }

    #[test]
    fn golden_transpose_rejects_non_square() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        assert!(golden_transpose(&rt, 32, &[0.0; 10]).is_err());
    }
}
