//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust (Python never runs at this point — `make artifacts` already did).
//!
//! Interchange format is **HLO text**: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see DESIGN.md §Bridge and
//! `python/compile/aot.py`).
//!
//! ## The `pjrt` feature
//!
//! The PJRT bridge needs the `xla` bindings, which this offline build
//! cannot fetch. The default build therefore compiles a **stub**
//! [`ArtifactRuntime`]: same API, but `has_artifact` always reports
//! `false` and every golden/oracle call returns an error — so every
//! consumer (validation suite, analytical oracle, examples, integration
//! tests) degrades to its host-reference path exactly as it already does
//! on a checkout without `make artifacts`. Enable `--features pjrt` in an
//! environment that provides the `xla` crate (see DESIGN.md §Features)
//! to compile the real client.

pub mod analytical;
pub mod client;
pub mod golden;

pub use client::ArtifactRuntime;

use std::fmt;

/// Minimal runtime-bridge error (anyhow-free: the default build carries
/// no external dependencies).
#[derive(Debug, Clone)]
pub struct RtError(String);

impl RtError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Result alias used across the runtime bridge.
pub type RtResult<T> = std::result::Result<T, RtError>;

/// Annotate a lower-level error with what was being attempted.
pub fn rt_err(context: impl fmt::Display, e: impl fmt::Display) -> RtError {
    RtError::new(format!("{context}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_formats_with_context() {
        let e = rt_err("loading artifact 'fft4096'", "file not found");
        let s = format!("{e:#}");
        assert!(s.contains("fft4096") && s.contains("file not found"));
    }
}
