//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust (Python never runs at this point — `make artifacts` already did).
//!
//! Interchange format is **HLO text**: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md` and
//! `python/compile/aot.py`).

pub mod analytical;
pub mod client;
pub mod golden;

pub use client::ArtifactRuntime;
