//! PJRT CPU client + compiled-executable cache (real under the `pjrt`
//! feature; a stub otherwise — see [`crate::runtime`] module docs).

use super::RtResult;
use std::path::{Path, PathBuf};

/// Loads `artifacts/<name>.hlo.txt`, compiles on the PJRT CPU client and
/// caches the executable per artifact name. Compilation happens once; the
/// request path only executes.
///
/// Without the `pjrt` feature this is a stub whose `has_artifact` always
/// reports `false`, steering every consumer onto its host-reference path.
#[cfg(feature = "pjrt")]
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: std::sync::Mutex<std::collections::HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl ArtifactRuntime {
    /// Create a runtime rooted at an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> RtResult<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| super::rt_err("creating PJRT CPU client", e))?;
        Ok(Self {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Default artifacts directory: `$SOFT_SIMT_ARTIFACTS` or
    /// `./artifacts`.
    pub fn from_env() -> RtResult<Self> {
        let dir = std::env::var("SOFT_SIMT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact file path for a name.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// True if the artifact file exists (lets callers degrade gracefully
    /// when `make artifacts` has not run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Compile (or fetch from cache) and execute an artifact on `inputs`.
    /// Returns the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> RtResult<Vec<xla::Literal>> {
        // Compile under the lock only on first use.
        {
            let mut cache = self.cache.lock().unwrap();
            if !cache.contains_key(name) {
                let path = self.artifact_path(name);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| super::rt_err(format!("loading HLO text {}", path.display()), e))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| super::rt_err(format!("compiling artifact '{name}'"), e))?;
                cache.insert(name.to_string(), exe);
            }
        }
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| super::rt_err(format!("executing artifact '{name}'"), e))?[0][0]
            .to_literal_sync()
            .map_err(|e| super::rt_err(format!("fetching result of '{name}'"), e))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        result
            .to_tuple()
            .map_err(|e| super::rt_err(format!("untupling result of '{name}'"), e))
    }

    /// Execute with f32 vector inputs/outputs (the common case).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> RtResult<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        let outs = self.execute(name, &lits)?;
        outs.into_iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| super::rt_err(format!("reading f32 output of '{name}'"), e))
            })
            .collect()
    }
}

/// Stub runtime: the PJRT bridge is not compiled in (no `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct ArtifactRuntime {
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl ArtifactRuntime {
    /// Create a (stub) runtime rooted at an artifacts directory. Always
    /// succeeds; execution paths report the missing feature.
    pub fn new(dir: impl AsRef<Path>) -> RtResult<Self> {
        Ok(Self { dir: dir.as_ref().to_path_buf() })
    }

    /// Default artifacts directory: `$SOFT_SIMT_ARTIFACTS` or
    /// `./artifacts`.
    pub fn from_env() -> RtResult<Self> {
        let dir = std::env::var("SOFT_SIMT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    /// Platform diagnostic string.
    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// Artifact file path for a name.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Always `false`: without the bridge no artifact can be *executed*,
    /// so consumers must take their host-reference paths even if the
    /// file exists on disk.
    pub fn has_artifact(&self, _name: &str) -> bool {
        false
    }

    pub(crate) fn unavailable(&self, what: &str) -> super::RtError {
        super::RtError::new(format!(
            "{what}: PJRT bridge not compiled in (rebuild with `--features pjrt`)"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_name_mangled() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        assert_eq!(
            rt.artifact_path("conflict16"),
            PathBuf::from("artifacts/conflict16.hlo.txt")
        );
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_artifact_is_reported() {
        let rt = ArtifactRuntime::new("/nonexistent-dir").expect("client still builds");
        assert!(!rt.has_artifact("fft4096"));
        let err = match rt.execute("fft4096", &[]) {
            Err(e) => e,
            Ok(_) => panic!("executing a missing artifact must fail"),
        };
        assert!(format!("{err:#}").contains("fft4096"));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn platform_is_cpu() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_never_promises_artifacts() {
        let rt = ArtifactRuntime::from_env().unwrap();
        assert!(!rt.has_artifact("fft4096"));
        assert!(rt.platform().contains("stub"));
        let err = rt.unavailable("conflict oracle");
        assert!(format!("{err}").contains("pjrt"));
    }
}
