//! PJRT CPU client + compiled-executable cache.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Loads `artifacts/<name>.hlo.txt`, compiles on the PJRT CPU client and
/// caches the executable per artifact name. Compilation happens once; the
/// request path only executes.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl ArtifactRuntime {
    /// Create a runtime rooted at an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$SOFT_SIMT_ARTIFACTS` or
    /// `./artifacts`.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("SOFT_SIMT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact file path for a name.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// True if the artifact file exists (lets callers degrade gracefully
    /// when `make artifacts` has not run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Compile (or fetch from cache) and execute an artifact on `inputs`.
    /// Returns the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // Compile under the lock only on first use.
        {
            let mut cache = self.cache.lock().unwrap();
            if !cache.contains_key(name) {
                let path = self.artifact_path(name);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("loading HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact '{name}'"))?;
                cache.insert(name.to_string(), exe);
            }
        }
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{name}'"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }

    /// Execute with f32 vector inputs/outputs (the common case).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        let outs = self.execute(name, &lits)?;
        outs.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The full PJRT round-trip is exercised by rust/tests/golden.rs (it
    // needs `make artifacts`); these tests cover the artifact-less paths.

    #[test]
    fn missing_artifact_is_reported() {
        let rt = ArtifactRuntime::new("/nonexistent-dir").expect("client still builds");
        assert!(!rt.has_artifact("fft4096"));
        let err = match rt.execute("fft4096", &[]) {
            Err(e) => e,
            Ok(_) => panic!("executing a missing artifact must fail"),
        };
        assert!(format!("{err:#}").contains("fft4096"));
    }

    #[test]
    fn paths_are_name_mangled() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        assert_eq!(
            rt.artifact_path("conflict16"),
            PathBuf::from("artifacts/conflict16.hlo.txt")
        );
    }

    #[test]
    fn platform_is_cpu() {
        let rt = ArtifactRuntime::new("artifacts").unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }
}
