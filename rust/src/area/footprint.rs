//! Sector-equivalent footprint model ("True Cost of a Processor", §IV-A
//! and §VI).
//!
//! Methodology from the paper: memories are node-locked to sectors (one
//! Agilex sector = 16640 ALMs of footprint); everything else places
//! unconstrained, where ALMs dominate. Consequences:
//!
//! - a 16-bank memory (up to 448 KB, 224 M20Ks) costs exactly **one
//!   sector**; 8 banks cost 1/2, 4 banks 1/4 — *constant in capacity*;
//! - a multiport memory is tiny (< 1 K ALMs) up to 64 KB, then needs
//!   progressively more pipelining to span M20K columns (Fig. 8): we
//!   model the paper's stated rule — "a 64KB (or smaller) memory would
//!   require no additional logic, and there would be a linear increase in
//!   pipelining required up to a full sector of memory";
//! - capacity rooflines: 4R-1W tops out at 112 KB, 4R-2W (quad-port
//!   M20Ks) at 224 KB, banked at 448 KB/16 banks (scaled by bank count).

use super::table1;
use crate::mem::arch::MemoryArchKind;

/// One Agilex sector, in ALM footprint.
pub const SECTOR_ALMS: u32 = 16_640;

/// An M20K stores 2 KB of 32-bit data (512 × 40 bits incl. ECC bits).
pub const M20K_KBYTES: u32 = 2;

/// Maximum shared-memory capacity in KB per architecture (§VI).
pub fn max_capacity_kb(arch: MemoryArchKind) -> u32 {
    match arch {
        MemoryArchKind::MultiPort { write_ports: 2, .. } => 224,
        MemoryArchKind::MultiPort { .. } => 112,
        // "a 16 bank, 448 KB shared memory ... one sector"; fewer banks
        // scale down proportionally ("no point in increasing the memory
        // size of the 4 bank memory beyond 112KB").
        MemoryArchKind::Banked { banks, .. } => 448 * banks / 16,
    }
}

/// Footprint of one processor variant at a given shared-memory capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Footprint {
    /// Memory subsystem ALM footprint (sector-equivalent).
    pub memory_alms: u32,
    /// Rest of the processor (SPs, fetch/decode, access controllers),
    /// placed unconstrained.
    pub rest_alms: u32,
    /// M20Ks consumed by the shared memory (including replication).
    pub m20k: u32,
}

impl Footprint {
    pub fn total_alms(&self) -> u32 {
        self.memory_alms + self.rest_alms
    }

    /// Footprint in sector equivalents.
    pub fn sectors(&self) -> f64 {
        self.total_alms() as f64 / SECTOR_ALMS as f64
    }
}

/// M20Ks needed for `size_kb` of shared memory under `arch` (multiport
/// replicates data once per read port).
pub fn m20k_count(arch: MemoryArchKind, size_kb: u32) -> u32 {
    let per_copy = size_kb.div_ceil(M20K_KBYTES);
    match arch {
        MemoryArchKind::MultiPort { read_ports, .. } => per_copy * read_ports,
        MemoryArchKind::Banked { .. } => per_copy,
    }
}

/// Memory-subsystem ALM footprint at `size_kb`. Returns `None` when the
/// capacity exceeds the architecture's roofline.
pub fn memory_alms(arch: MemoryArchKind, size_kb: u32) -> Option<u32> {
    if size_kb > max_capacity_kb(arch) {
        return None;
    }
    match arch {
        MemoryArchKind::Banked { banks, .. } => {
            // Constant: a full/half/quarter sector regardless of capacity.
            Some(SECTOR_ALMS * banks / 16)
        }
        MemoryArchKind::MultiPort { .. } => {
            let base = table1::memory_total(arch).alms; // < 1 K unconstrained
            if size_kb <= 64 {
                Some(base)
            } else {
                // Linear pipelining growth from the 64 KB base to a full
                // sector at the capacity roofline (Fig. 8 right).
                let max = max_capacity_kb(arch);
                let frac = (size_kb - 64) as f64 / (max - 64) as f64;
                Some(base + ((SECTOR_ALMS - base) as f64 * frac).round() as u32)
            }
        }
    }
}

/// Whole-processor footprint at `size_kb` of shared memory.
pub fn processor_footprint(arch: MemoryArchKind, size_kb: u32) -> Option<Footprint> {
    let memory = memory_alms(arch, size_kb)?;
    // Rest of the processor: common core + the variant's access
    // controllers (banked) or R/W control (multiport), placed
    // unconstrained.
    let ctl = match arch {
        MemoryArchKind::Banked { .. } => {
            let m = table1::memory_total(arch);
            let shared = match arch {
                MemoryArchKind::Banked { banks: 4, .. } => 3225,
                MemoryArchKind::Banked { banks: 8, .. } => 6526,
                _ => 13_105,
            };
            m.alms - shared // read + write controllers only
        }
        MemoryArchKind::MultiPort { .. } => 700, // R/W control row
    };
    let rest = table1::core_total().alms + ctl;
    Some(Footprint { memory_alms: memory, rest_alms: rest, m20k: m20k_count(arch, size_kb) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banked_footprint_constant_in_capacity() {
        let a = memory_alms(MemoryArchKind::banked(16), 64).unwrap();
        let b = memory_alms(MemoryArchKind::banked(16), 448).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, SECTOR_ALMS);
        assert_eq!(memory_alms(MemoryArchKind::banked(8), 100).unwrap(), SECTOR_ALMS / 2);
        assert_eq!(memory_alms(MemoryArchKind::banked(4), 100).unwrap(), SECTOR_ALMS / 4);
    }

    #[test]
    fn multiport_grows_past_64kb() {
        let mp = MemoryArchKind::mp_4r1w();
        let small = memory_alms(mp, 64).unwrap();
        assert!(small < 1000);
        let mid = memory_alms(mp, 88).unwrap();
        let max = memory_alms(mp, 112).unwrap();
        assert!(small < mid && mid < max);
        // "needed ... a full sector" at the 112 KB roofline.
        assert_eq!(max, SECTOR_ALMS);
    }

    #[test]
    fn capacity_rooflines() {
        assert_eq!(memory_alms(MemoryArchKind::mp_4r1w(), 113), None);
        assert!(memory_alms(MemoryArchKind::mp_4r2w(), 224).is_some());
        assert_eq!(memory_alms(MemoryArchKind::mp_4r2w(), 225), None);
        assert!(memory_alms(MemoryArchKind::banked(16), 448).is_some());
        assert_eq!(memory_alms(MemoryArchKind::banked(16), 449), None);
        assert_eq!(max_capacity_kb(MemoryArchKind::banked(4)), 112);
    }

    #[test]
    fn m20k_replication() {
        // 4R multiport replicates ×4; banked stores data once.
        assert_eq!(m20k_count(MemoryArchKind::mp_4r1w(), 32), 64); // the paper's example config
        assert_eq!(m20k_count(MemoryArchKind::banked(16), 448), 224); // the §IV-A sector fill
        assert_eq!(m20k_count(MemoryArchKind::banked(16), 64), 32);
    }

    #[test]
    fn multiport_m20k_cost_prohibitive_at_size() {
        // The paper's core claim: "the effective footprint cost of the
        // multiport memories quickly becomes prohibitive as dataset sizes
        // increase" — at equal capacity the 4R replication costs 4× the
        // M20Ks, so 112 KB of 4R-1W equals 448 KB of banked memory.
        let mp = m20k_count(MemoryArchKind::mp_4r1w(), 112);
        assert_eq!(mp, 4 * m20k_count(MemoryArchKind::banked(16), 112));
        assert_eq!(mp, m20k_count(MemoryArchKind::banked(16), 448));
    }

    #[test]
    fn processor_totals_ordering_at_64kb() {
        // At 64 KB the multiport processor is *smaller* than the 16-bank
        // one (the paper's small-dataset conclusion)...
        let mp = processor_footprint(MemoryArchKind::mp_4r1w(), 64).unwrap();
        let b16 = processor_footprint(MemoryArchKind::banked(16), 64).unwrap();
        assert!(mp.total_alms() < b16.total_alms());
        // ...but the 4-bank memory is smaller still on the memory side.
        let b4 = processor_footprint(MemoryArchKind::banked(4), 64).unwrap();
        assert!(b4.memory_alms < b16.memory_alms);
    }

    #[test]
    fn rest_of_processor_reasonable() {
        // §VI: a full sector of memory "is twice the cost of the rest of
        // the processor" — rest ≈ 8.3 K ALMs for the 16-bank variant.
        let fp = processor_footprint(MemoryArchKind::banked(16), 224).unwrap();
        let ratio = fp.memory_alms as f64 / fp.rest_alms as f64;
        assert!((1.4..2.4).contains(&ratio), "memory/rest ratio {ratio}");
    }

    #[test]
    fn sectors_metric() {
        let fp = processor_footprint(MemoryArchKind::banked(4), 64).unwrap();
        assert!(fp.sectors() < 1.0);
        let fp16 = processor_footprint(MemoryArchKind::banked(16), 448).unwrap();
        assert!(fp16.sectors() > 1.0);
    }
}
