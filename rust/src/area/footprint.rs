//! Sector-equivalent footprint model ("True Cost of a Processor", §IV-A
//! and §VI).
//!
//! Methodology from the paper: memories are node-locked to sectors (one
//! Agilex sector = 16640 ALMs of footprint); everything else places
//! unconstrained, where ALMs dominate. Consequences:
//!
//! - a 16-bank memory (up to 448 KB, 224 M20Ks) costs exactly **one
//!   sector**; 8 banks cost 1/2, 4 banks 1/4 — *constant in capacity*;
//! - a multiport memory is tiny (< 1 K ALMs) up to 64 KB, then needs
//!   progressively more pipelining to span M20K columns (Fig. 8): we
//!   model the paper's stated rule — "a 64KB (or smaller) memory would
//!   require no additional logic, and there would be a linear increase in
//!   pipelining required up to a full sector of memory";
//! - capacity rooflines: 4R-1W tops out at 112 KB, 4R-2W (quad-port
//!   M20Ks) at 224 KB, banked at 448 KB/16 banks (scaled by bank count).

use super::table1;
use crate::mem::arch::MemoryArchKind;
use crate::mem::LANES;

/// One Agilex sector, in ALM footprint.
pub const SECTOR_ALMS: u32 = 16_640;

/// Table I's Multi-Port "R/W Control" row — pure logic, placed
/// unconstrained with the rest of the processor. Counted once, in
/// [`processor_footprint`]'s rest-of-processor term; the sector-side
/// [`memory_alms`] carries only the shared-memory wrapper.
const MP_RW_CONTROL_ALMS: u32 = 700;

/// An M20K stores 2 KB of 32-bit data (512 × 40 bits incl. ECC bits).
pub const M20K_KBYTES: u32 = 2;

/// Maximum shared-memory capacity in KB per architecture (§VI).
pub fn max_capacity_kb(arch: MemoryArchKind) -> u32 {
    match arch {
        // A sector holds 224 M20Ks = 448 KB of data. A multiport memory
        // replicates once per read port, and emulated multi-port M20K
        // modes serve `write_ports` copies' worth per primitive — the
        // paper's anchors fall out: 4R-1W fills the sector at 112 KB,
        // 4R-2W (quad-port M20Ks) at 224 KB. The explorer's 2R/8R
        // variants scale the same way (2R-1W: 224 KB, 8R-1W: 56 KB).
        MemoryArchKind::MultiPort { read_ports, write_ports, .. } => {
            448 * write_ports / read_ports
        }
        // "a 16 bank, 448 KB shared memory ... one sector"; fewer banks
        // scale down proportionally ("no point in increasing the memory
        // size of the 4 bank memory beyond 112KB").
        MemoryArchKind::Banked { banks, .. } => 448 * banks / 16,
    }
}

/// Footprint of one processor variant at a given shared-memory capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Footprint {
    /// Memory subsystem ALM footprint (sector-equivalent).
    pub memory_alms: u32,
    /// Rest of the processor (SPs, fetch/decode, access controllers),
    /// placed unconstrained.
    pub rest_alms: u32,
    /// M20Ks consumed by the shared memory (including replication).
    pub m20k: u32,
}

impl Footprint {
    pub fn total_alms(&self) -> u32 {
        self.memory_alms + self.rest_alms
    }

    /// Footprint in sector equivalents.
    pub fn sectors(&self) -> f64 {
        self.total_alms() as f64 / SECTOR_ALMS as f64
    }
}

/// M20Ks needed for `size_kb` of shared memory under `arch`: multiport
/// replicates data once per read port, and emulated multi-port M20K
/// modes (4R-2W's quad-port primitives) serve `write_ports` copies per
/// M20K — the same model [`max_capacity_kb`]'s rooflines derive from.
pub fn m20k_count(arch: MemoryArchKind, size_kb: u32) -> u32 {
    let per_copy = size_kb.div_ceil(M20K_KBYTES);
    match arch {
        MemoryArchKind::MultiPort { read_ports, write_ports, .. } => {
            (per_copy * read_ports).div_ceil(write_ports)
        }
        MemoryArchKind::Banked { .. } => per_copy,
    }
}

/// Memory-subsystem ALM footprint at `size_kb`. Returns `None` when the
/// capacity exceeds the architecture's roofline.
pub fn memory_alms(arch: MemoryArchKind, size_kb: u32) -> Option<u32> {
    if size_kb > max_capacity_kb(arch) {
        return None;
    }
    match arch {
        MemoryArchKind::Banked { banks, .. } => {
            // Constant: a full/half/quarter sector regardless of capacity.
            Some(SECTOR_ALMS * banks / 16)
        }
        MemoryArchKind::MultiPort { .. } => {
            // Shared-memory wrapper only (131 ALMs); the R/W control row
            // is logic and lives in `processor_footprint`'s rest term.
            let base = table1::memory_total(arch).alms - MP_RW_CONTROL_ALMS;
            // The paper's rule for 4R-1W: no additional logic up to
            // 64 KB, then linear pipelining growth to a full sector at
            // the 112 KB roofline (Fig. 8 right). Pipelining is driven
            // by M20K-column *occupancy*, so for other port configs the
            // ramp scales with the roofline (same 64/112 = 4/7 sector
            // fraction): an 8R-1W memory filling its sector at 56 KB
            // pays the full-sector cost there, not the flat base.
            let max = max_capacity_kb(arch);
            let ramp_start = max * 4 / 7; // = 64 KB for 4R-1W
            if size_kb <= ramp_start {
                Some(base)
            } else {
                let frac = (size_kb - ramp_start) as f64 / (max - ramp_start) as f64;
                Some(base + ((SECTOR_ALMS - base) as f64 * frac).round() as u32)
            }
        }
    }
}

/// Read + write access-controller ALMs for a banked variant, as a
/// function of bank count. Anchored exactly on the paper's Table I rows
/// (4 → 1153, 8 → 1605, 16 → 2296 ALMs = Read Ctl. + Write Ctl.);
/// between anchors it interpolates linearly, and past them it
/// extrapolates with the nearest segment's slope — the paper's own
/// scaling claim ("the logic area of the read and write access
/// controllers varies linearly with the number of banks") applied to the
/// 2–32-bank space the design explorer sweeps.
pub fn banked_ctl_alms(banks: u32) -> u32 {
    const ANCHORS: [(u32, u32); 3] = [(4, 1153), (8, 1605), (16, 2296)];
    let lerp = |(x0, y0): (u32, u32), (x1, y1): (u32, u32), x: u32| -> u32 {
        let slope = (y1 as f64 - y0 as f64) / (x1 as f64 - x0 as f64);
        (y0 as f64 + slope * (x as f64 - x0 as f64)).round().max(0.0) as u32
    };
    if banks <= ANCHORS[1].0 {
        lerp(ANCHORS[0], ANCHORS[1], banks)
    } else {
        lerp(ANCHORS[1], ANCHORS[2], banks)
    }
}

/// Whole-processor footprint at `size_kb` of shared memory.
pub fn processor_footprint(arch: MemoryArchKind, size_kb: u32) -> Option<Footprint> {
    let memory = memory_alms(arch, size_kb)?;
    // Rest of the processor: common core + the variant's access
    // controllers (banked) or R/W control (multiport), placed
    // unconstrained.
    let ctl = match arch {
        MemoryArchKind::Banked { banks, .. } => banked_ctl_alms(banks),
        MemoryArchKind::MultiPort { .. } => MP_RW_CONTROL_ALMS,
    };
    let rest = table1::core_total().alms + ctl;
    Some(Footprint { memory_alms: memory, rest_alms: rest, m20k: m20k_count(arch, size_kb) })
}

/// Arbitration-mux ALMs per shared-memory lane per *extra* core: each
/// core past the first adds one request-select mux level across the
/// memory's lane-wide datapath (address + data + enable ≈ 3 packed
/// 8:1-mux ALMs per lane, × the round-robin grant logic). Small next to
/// a core (7.1 K ALMs) but real: a p8x64 system pays ~10.7 K ALMs of
/// arbitration — most of a sector.
const SYSTEM_ARBITER_ALMS_PER_LANE: u32 = 24;

/// Whole-*system* footprint: `processors` cores of `lanes` lanes
/// sharing one `arch` memory of `size_kb` (the system explorer's area
/// model, [`crate::explore::system`]).
///
/// Composition, per the Table I split [`processor_footprint`] uses:
///
/// - the shared memory is counted **once** ([`memory_alms`] and
///   [`m20k_count`] — replication across cores is the whole point of a
///   shared banked memory);
/// - the shared access controllers (read/write sort network or R/W
///   control) are counted **once** — cores arbitrate into one
///   controller front-end;
/// - each core pays the Table I core total scaled by its datapath width
///   in [`LANES`]-wide groups (SPs dominate the core, and they scale
///   linearly with lanes);
/// - each core past the first adds an arbitration-mux stage across the
///   memory datapath ([`SYSTEM_ARBITER_ALMS_PER_LANE`]).
///
/// At `processors=1, lanes=16` this is exactly
/// [`processor_footprint`] — pinned by tests.
pub fn system_footprint(
    processors: u32,
    lanes: u32,
    arch: MemoryArchKind,
    size_kb: u32,
) -> Option<Footprint> {
    assert!(
        processors >= 1 && lanes >= LANES as u32 && lanes % LANES as u32 == 0,
        "unconstructible system shape: {processors} cores × {lanes} lanes"
    );
    let memory = memory_alms(arch, size_kb)?;
    let ctl = match arch {
        MemoryArchKind::Banked { banks, .. } => banked_ctl_alms(banks),
        MemoryArchKind::MultiPort { .. } => MP_RW_CONTROL_ALMS,
    };
    let groups = lanes / LANES as u32;
    let cores = processors * table1::core_total().alms * groups;
    let arbiter = (processors - 1) * lanes * SYSTEM_ARBITER_ALMS_PER_LANE;
    Some(Footprint {
        memory_alms: memory,
        rest_alms: cores + ctl + arbiter,
        m20k: m20k_count(arch, size_kb),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banked_footprint_constant_in_capacity() {
        let a = memory_alms(MemoryArchKind::banked(16), 64).unwrap();
        let b = memory_alms(MemoryArchKind::banked(16), 448).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, SECTOR_ALMS);
        assert_eq!(memory_alms(MemoryArchKind::banked(8), 100).unwrap(), SECTOR_ALMS / 2);
        assert_eq!(memory_alms(MemoryArchKind::banked(4), 100).unwrap(), SECTOR_ALMS / 4);
    }

    #[test]
    fn multiport_grows_past_64kb() {
        let mp = MemoryArchKind::mp_4r1w();
        let small = memory_alms(mp, 64).unwrap();
        assert!(small < 1000);
        let mid = memory_alms(mp, 88).unwrap();
        let max = memory_alms(mp, 112).unwrap();
        assert!(small < mid && mid < max);
        // "needed ... a full sector" at the 112 KB roofline.
        assert_eq!(max, SECTOR_ALMS);
    }

    #[test]
    fn capacity_rooflines() {
        assert_eq!(memory_alms(MemoryArchKind::mp_4r1w(), 113), None);
        assert!(memory_alms(MemoryArchKind::mp_4r2w(), 224).is_some());
        assert_eq!(memory_alms(MemoryArchKind::mp_4r2w(), 225), None);
        assert!(memory_alms(MemoryArchKind::banked(16), 448).is_some());
        assert_eq!(memory_alms(MemoryArchKind::banked(16), 449), None);
        assert_eq!(max_capacity_kb(MemoryArchKind::banked(4)), 112);
    }

    #[test]
    fn m20k_replication() {
        // 4R multiport replicates ×4; banked stores data once.
        assert_eq!(m20k_count(MemoryArchKind::mp_4r1w(), 32), 64); // the paper's example config
        assert_eq!(m20k_count(MemoryArchKind::banked(16), 448), 224); // the §IV-A sector fill
        assert_eq!(m20k_count(MemoryArchKind::banked(16), 64), 32);
    }

    #[test]
    fn multiport_m20k_cost_prohibitive_at_size() {
        // The paper's core claim: "the effective footprint cost of the
        // multiport memories quickly becomes prohibitive as dataset sizes
        // increase" — at equal capacity the 4R replication costs 4× the
        // M20Ks, so 112 KB of 4R-1W equals 448 KB of banked memory.
        let mp = m20k_count(MemoryArchKind::mp_4r1w(), 112);
        assert_eq!(mp, 4 * m20k_count(MemoryArchKind::banked(16), 112));
        assert_eq!(mp, m20k_count(MemoryArchKind::banked(16), 448));
    }

    #[test]
    fn processor_totals_ordering_at_64kb() {
        // At 64 KB the multiport processor is *smaller* than the 16-bank
        // one (the paper's small-dataset conclusion)...
        let mp = processor_footprint(MemoryArchKind::mp_4r1w(), 64).unwrap();
        let b16 = processor_footprint(MemoryArchKind::banked(16), 64).unwrap();
        assert!(mp.total_alms() < b16.total_alms());
        // ...but the 4-bank memory is smaller still on the memory side.
        let b4 = processor_footprint(MemoryArchKind::banked(4), 64).unwrap();
        assert!(b4.memory_alms < b16.memory_alms);
    }

    #[test]
    fn rest_of_processor_reasonable() {
        // §VI: a full sector of memory "is twice the cost of the rest of
        // the processor" — rest ≈ 8.3 K ALMs for the 16-bank variant.
        let fp = processor_footprint(MemoryArchKind::banked(16), 224).unwrap();
        let ratio = fp.memory_alms as f64 / fp.rest_alms as f64;
        assert!((1.4..2.4).contains(&ratio), "memory/rest ratio {ratio}");
    }

    #[test]
    fn parametric_multiport_rooflines_scale_with_replication() {
        let mp = |r, w| MemoryArchKind::MultiPort { read_ports: r, write_ports: w, vb: false };
        assert_eq!(max_capacity_kb(mp(2, 1)), 224);
        assert_eq!(max_capacity_kb(mp(8, 1)), 56);
        assert_eq!(max_capacity_kb(mp(1, 1)), 448);
        // At its roofline each variant's replicated copies fill one
        // sector of M20Ks, same as 4R-1W at 112 KB...
        assert_eq!(m20k_count(mp(8, 1), 56), 224);
        assert_eq!(m20k_count(mp(2, 1), 224), 224);
        assert_eq!(m20k_count(MemoryArchKind::mp_4r2w(), 224), 224);
        assert_eq!(memory_alms(mp(8, 1), 57), None);
        // ...and the pipelining ramp reaches a full sector of ALMs at
        // sector fill, whatever the roofline (the ramp scales with it).
        assert_eq!(memory_alms(mp(8, 1), 56), Some(SECTOR_ALMS));
        assert_eq!(memory_alms(mp(2, 1), 224), Some(SECTOR_ALMS));
        assert!(memory_alms(mp(8, 1), 32).unwrap() < 1000, "flat base below the ramp");
    }

    #[test]
    fn multiport_control_counted_once() {
        // The Table I Multi-Port group (R/W Control 700 + Shared Mem.
        // 131) must appear exactly once in the whole-processor total.
        let fp = processor_footprint(MemoryArchKind::mp_4r1w(), 64).unwrap();
        assert_eq!(
            fp.total_alms(),
            table1::core_total().alms + table1::memory_total(MemoryArchKind::mp_4r1w()).alms
        );
        assert_eq!(fp.memory_alms, 131);
    }

    #[test]
    fn banked_ctl_exact_at_table1_anchors() {
        // Table I: Read Ctl. + Write Ctl. ALMs.
        assert_eq!(banked_ctl_alms(4), 342 + 811);
        assert_eq!(banked_ctl_alms(8), 511 + 1094);
        assert_eq!(banked_ctl_alms(16), 789 + 1507);
    }

    #[test]
    fn banked_ctl_monotone_across_explorer_range() {
        let vals: Vec<u32> = [2u32, 4, 8, 16, 32].iter().map(|&b| banked_ctl_alms(b)).collect();
        for w in vals.windows(2) {
            assert!(w[0] < w[1], "controller ALMs must grow with banks: {vals:?}");
        }
    }

    #[test]
    fn parametric_bank_counts_have_footprints() {
        // The explorer's 2- and 32-bank points are placeable: 1/8 and 2
        // sectors of memory respectively.
        let b2 = processor_footprint(MemoryArchKind::banked(2), 32).unwrap();
        assert_eq!(b2.memory_alms, SECTOR_ALMS / 8);
        let b32 = processor_footprint(MemoryArchKind::banked(32), 512).unwrap();
        assert_eq!(b32.memory_alms, 2 * SECTOR_ALMS);
        assert_eq!(max_capacity_kb(MemoryArchKind::banked(32)), 896);
        assert_eq!(max_capacity_kb(MemoryArchKind::banked(2)), 56);
        // Rooflines still bind.
        assert_eq!(processor_footprint(MemoryArchKind::banked(2), 57), None);
    }

    #[test]
    fn system_footprint_reduces_to_processor_footprint() {
        // The system model's P=1, 16-lane anchor: exactly the
        // single-processor footprint, for every paper architecture and
        // several capacities.
        for arch in MemoryArchKind::table3_nine() {
            for kb in [8u32, 64, 112] {
                assert_eq!(
                    system_footprint(1, 16, arch, kb),
                    processor_footprint(arch, kb),
                    "{arch} @ {kb} KB"
                );
            }
        }
    }

    #[test]
    fn system_footprint_shares_memory_and_scales_cores() {
        let b16 = MemoryArchKind::banked(16);
        let one = system_footprint(1, 16, b16, 64).unwrap();
        let four = system_footprint(4, 16, b16, 64).unwrap();
        // Memory (ALMs and M20Ks) is shared, not replicated.
        assert_eq!(four.memory_alms, one.memory_alms);
        assert_eq!(four.m20k, one.m20k);
        // Cores replicate: 4 cores cost more than 3× but less than 4×
        // the single-processor rest (the shared controller amortizes,
        // the arbiter adds back).
        assert!(four.rest_alms > 3 * table1::core_total().alms);
        assert!(four.rest_alms < 4 * one.rest_alms);
        // Wider lanes scale the core block too.
        let wide = system_footprint(1, 64, b16, 64).unwrap();
        assert_eq!(
            wide.rest_alms - banked_ctl_alms(16),
            4 * table1::core_total().alms
        );
    }

    #[test]
    fn system_footprint_monotone_in_processors_and_lanes() {
        let b16 = MemoryArchKind::banked(16);
        let mut prev = 0u32;
        for p in [1u32, 2, 4, 8] {
            let t = system_footprint(p, 32, b16, 64).unwrap().total_alms();
            assert!(t > prev, "p{p}: {t} <= {prev}");
            prev = t;
        }
        let mut prev = 0u32;
        for lanes in [16u32, 32, 64] {
            let t = system_footprint(2, lanes, b16, 64).unwrap().total_alms();
            assert!(t > prev, "{lanes} lanes: {t} <= {prev}");
            prev = t;
        }
    }

    #[test]
    fn system_footprint_respects_rooflines() {
        assert_eq!(system_footprint(4, 32, MemoryArchKind::mp_4r1w(), 113), None);
        assert!(system_footprint(4, 32, MemoryArchKind::banked(16), 448).is_some());
    }

    #[test]
    #[should_panic(expected = "unconstructible system shape")]
    fn system_footprint_rejects_ragged_lanes() {
        let _ = system_footprint(2, 24, MemoryArchKind::banked(16), 64);
    }

    #[test]
    fn sectors_metric() {
        let fp = processor_footprint(MemoryArchKind::banked(4), 64).unwrap();
        assert!(fp.sectors() < 1.0);
        let fp16 = processor_footprint(MemoryArchKind::banked(16), 448).unwrap();
        assert!(fp16.sectors() > 1.0);
    }
}
