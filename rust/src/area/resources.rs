//! FPGA resource vectors (ALMs, registers, M20Ks, DSPs).

use std::ops::{Add, AddAssign, Mul};

/// One module's resource usage, Table I column order.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Resources {
    /// Adaptive logic modules (fracturable 6-LUTs).
    pub alms: u32,
    /// Flip-flops.
    pub regs: u32,
    /// M20K embedded memories.
    pub m20k: u32,
    /// DSP blocks.
    pub dsp: u32,
}

impl Resources {
    pub const fn new(alms: u32, regs: u32, m20k: u32, dsp: u32) -> Self {
        Self { alms, regs, m20k, dsp }
    }

    pub const ZERO: Resources = Resources::new(0, 0, 0, 0);
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            alms: self.alms + o.alms,
            regs: self.regs + o.regs,
            m20k: self.m20k + o.m20k,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl Mul<u32> for Resources {
    type Output = Resources;
    fn mul(self, k: u32) -> Resources {
        Resources {
            alms: self.alms * k,
            regs: self.regs * k,
            m20k: self.m20k * k,
            dsp: self.dsp * k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Resources::new(100, 200, 3, 1);
        let b = Resources::new(10, 20, 1, 0);
        assert_eq!(a + b, Resources::new(110, 220, 4, 1));
        assert_eq!(b * 16, Resources::new(160, 320, 16, 0));
        let mut c = Resources::ZERO;
        c += a;
        assert_eq!(c, a);
    }
}
