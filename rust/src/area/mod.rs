//! FPGA area and footprint models (paper §IV, §VI).
//!
//! The paper's central cost argument is that raw resource counts mislead:
//! a memory's *true* footprint is the sector-equivalent area it occupies
//! once node-locked and routed ("True Cost of a Processor", §IV-A). This
//! module carries:
//!
//! - the published per-module resource counts (Table I) as data
//!   ([`table1`]),
//! - the sector-equivalent footprint model ([`footprint`]): banked
//!   memories cost a fixed fraction of a sector regardless of capacity;
//!   multiport memories grow linearly past 64 KB because of the
//!   pipelining needed to span M20K columns (Fig. 8),
//! - the Fig. 9 cost-vs-performance series generator ([`fig9`]).
//!
//! Fmax values are modelled constants (the one paper quantity that cannot
//! be reproduced without the FPGA fitter — see DESIGN.md §0).

pub mod fig9;
pub mod footprint;
pub mod resources;
pub mod table1;

pub use footprint::Footprint;
pub use resources::Resources;
