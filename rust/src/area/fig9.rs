//! Figure 9 generator: cost (ALM footprint) vs normalized radix-16 FFT
//! performance, across shared-memory sizes of 64/112/168/224 KB.
//!
//! Cost comes from [`super::footprint`]; performance (total radix-16 FFT
//! cycles at each architecture's Fmax) is supplied by the caller — the
//! coordinator runs the simulator sweep and feeds the times in, keeping
//! this module free of a circular dependency on the simulator.

use super::footprint::{self, Footprint};
use crate::mem::arch::MemoryArchKind;

/// The paper's Fig. 9 capacity grid, in KB.
pub const SIZES_KB: [u32; 4] = [64, 112, 168, 224];

/// One Fig. 9 point: a (architecture, capacity) cell.
#[derive(Debug, Clone)]
pub struct Fig9Point {
    pub arch: MemoryArchKind,
    pub size_kb: u32,
    /// Whole-processor footprint, `None` past the capacity roofline.
    pub footprint: Option<Footprint>,
    /// Radix-16 FFT execution time in µs.
    pub time_us: f64,
    /// Performance normalized to the slowest core (lower is better).
    pub normalized: f64,
}

/// Build the Fig. 9 series: `times_us[arch]` is the radix-16 4096-point
/// FFT time for each architecture (capacity-independent — every size in
/// the grid fits the 64 KB dataset, as the paper notes).
pub fn series(times_us: &[(MemoryArchKind, f64)]) -> Vec<Fig9Point> {
    let slowest = times_us.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
    let mut out = Vec::new();
    for &(arch, t) in times_us {
        for &size_kb in &SIZES_KB {
            out.push(Fig9Point {
                arch,
                size_kb,
                footprint: footprint::processor_footprint(arch, size_kb),
                time_us: t,
                normalized: t / slowest,
            });
        }
    }
    out
}

/// Performance per unit area (1 / (normalized time × sectors)), the
/// paper's "more efficient (performance per unit area)" comparison.
/// `None` past the roofline.
pub fn perf_per_area(p: &Fig9Point) -> Option<f64> {
    p.footprint.map(|f| 1.0 / (p.normalized * f.sectors()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_times() -> Vec<(MemoryArchKind, f64)> {
        // Shaped like Table III radix-16: multiport fastest, 4-bank slowest.
        vec![
            (MemoryArchKind::mp_4r1w(), 64.0),
            (MemoryArchKind::mp_4r2w(), 62.0),
            (MemoryArchKind::banked_offset(16), 61.0),
            (MemoryArchKind::banked(4), 84.0),
        ]
    }

    #[test]
    fn normalization_to_slowest() {
        let s = series(&fake_times());
        let slow: Vec<_> = s.iter().filter(|p| p.arch == MemoryArchKind::banked(4)).collect();
        assert!(slow.iter().all(|p| (p.normalized - 1.0).abs() < 1e-12));
        assert!(s.iter().all(|p| p.normalized <= 1.0));
    }

    #[test]
    fn multiport_hits_roofline_in_grid() {
        // 4R-1W supports only 112 KB: the 168/224 KB cells must be None.
        let s = series(&fake_times());
        for p in &s {
            if p.arch == MemoryArchKind::mp_4r1w() {
                assert_eq!(p.footprint.is_none(), p.size_kb > 112, "size {}", p.size_kb);
            }
        }
    }

    #[test]
    fn banked_cost_flat_multiport_growing() {
        let s = series(&fake_times());
        let get = |arch: MemoryArchKind, kb: u32| {
            s.iter()
                .find(|p| p.arch == arch && p.size_kb == kb)
                .and_then(|p| p.footprint)
                .map(|f| f.total_alms())
        };
        assert_eq!(
            get(MemoryArchKind::banked_offset(16), 64),
            get(MemoryArchKind::banked_offset(16), 224)
        );
        assert!(get(MemoryArchKind::mp_4r2w(), 224) > get(MemoryArchKind::mp_4r2w(), 64));
    }

    #[test]
    fn crossover_multiport_small_banked_large() {
        // The paper's §VI conclusion: multiport cheaper at 64 KB; at
        // 224 KB the 4R-1W roofline is exceeded entirely and the 8-bank
        // memory (capacity 224 KB) is cheaper than 4R-2W.
        let mut times = fake_times();
        times.push((MemoryArchKind::banked(8), 70.0));
        let s = series(&times);
        let alms = |arch: MemoryArchKind, kb: u32| {
            s.iter()
                .find(|p| p.arch == arch && p.size_kb == kb)
                .and_then(|p| p.footprint)
                .map(|f| f.total_alms())
                .unwrap()
        };
        assert!(alms(MemoryArchKind::mp_4r1w(), 64) < alms(MemoryArchKind::banked_offset(16), 64));
        assert!(alms(MemoryArchKind::banked(8), 224) < alms(MemoryArchKind::mp_4r2w(), 224));
        assert!(s
            .iter()
            .find(|p| p.arch == MemoryArchKind::mp_4r1w() && p.size_kb == 224)
            .unwrap()
            .footprint
            .is_none());
    }

    #[test]
    fn perf_per_area_prefers_small_banked() {
        // "The smaller banked memories are more efficient (performance per
        // unit area) than the larger banked memories."
        let times = vec![
            (MemoryArchKind::banked_offset(16), 61.0),
            (MemoryArchKind::banked(4), 84.0),
        ];
        let s = series(&times);
        let ppa = |arch: MemoryArchKind| {
            s.iter()
                .find(|p| p.arch == arch && p.size_kb == 64)
                .map(|p| perf_per_area(p).unwrap())
                .unwrap()
        };
        assert!(ppa(MemoryArchKind::banked(4)) > ppa(MemoryArchKind::banked_offset(16)));
    }
}
