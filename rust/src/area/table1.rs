//! The paper's Table I — per-module resource counts for each processor
//! variant — as data, with the derived whole-processor sums the paper's
//! prose quotes ("the 16 bank memory needs about 13K ALMs by itself, and
//! the cost including the read and write controllers is twice that of the
//! SIMT core").

use super::resources::Resources;
use crate::mem::arch::MemoryArchKind;

/// One Table I row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Processor variant grouping ("Common", "4 Banks", …).
    pub group: &'static str,
    /// Module name.
    pub module: &'static str,
    /// Module instance count.
    pub count: u32,
    /// Whether this row is a submodule (indented in the paper's table and
    /// already included in its parent's totals).
    pub submodule: bool,
    /// Per-instance resources.
    pub per_instance: Resources,
}

impl Table1Row {
    const fn new(
        group: &'static str,
        module: &'static str,
        count: u32,
        submodule: bool,
        r: Resources,
    ) -> Self {
        Self { group, module, count, submodule, per_instance: r }
    }
}

/// Table I verbatim. The 4-bank shared-memory M20K count is printed
/// garbled in the paper ("2 2 6"); we use 32, consistent with the 8-bank
/// (64) and 16-bank (128) rows — 8 M20Ks per bank.
pub fn rows() -> Vec<Table1Row> {
    use Table1Row as R;
    vec![
        R::new("Common", "SP", 16, false, Resources::new(430, 1100, 2, 2)),
        R::new("Common", "Fetch/Decode", 1, false, Resources::new(233, 508, 2, 0)),
        R::new("4 Banks", "Read Ctl.", 1, false, Resources::new(342, 1105, 6, 0)),
        R::new("4 Banks", "Write Ctl.", 1, false, Resources::new(811, 3114, 19, 0)),
        R::new("4 Banks", "Shared Mem.", 1, false, Resources::new(3225, 10389, 32, 0)),
        R::new("4 Banks", "Read Arb.", 4, true, Resources::new(135, 372, 0, 0)),
        R::new("4 Banks", "Write Arb.", 4, true, Resources::new(441, 1166, 0, 0)),
        R::new("4 Banks", "Output Mux", 16, true, Resources::new(40, 118, 0, 0)),
        R::new("8 Banks", "Read Ctl.", 1, false, Resources::new(511, 1595, 7, 0)),
        R::new("8 Banks", "Write Ctl.", 1, false, Resources::new(1094, 4072, 19, 0)),
        R::new("8 Banks", "Shared Mem.", 1, false, Resources::new(6526, 20324, 64, 0)),
        R::new("8 Banks", "Read Arb.", 8, true, Resources::new(145, 384, 0, 0)),
        R::new("8 Banks", "Write Arb.", 8, true, Resources::new(448, 1165, 0, 0)),
        R::new("8 Banks", "Output Mux", 16, true, Resources::new(80, 188, 0, 0)),
        R::new("16 Banks", "Read Ctl.", 1, false, Resources::new(789, 2151, 7, 0)),
        R::new("16 Banks", "Write Ctl.", 1, false, Resources::new(1507, 5245, 20, 0)),
        R::new("16 Banks", "Shared Mem.", 1, false, Resources::new(13105, 39805, 128, 0)),
        R::new("16 Banks", "Read Arb.", 16, true, Resources::new(138, 369, 0, 0)),
        R::new("16 Banks", "Write Arb.", 16, true, Resources::new(438, 1164, 0, 0)),
        R::new("16 Banks", "Output Mux", 16, true, Resources::new(173, 353, 0, 0)),
        R::new("Multi-Port", "R/W Control", 1, false, Resources::new(700, 795, 0, 0)),
        R::new("Multi-Port", "4R-1W Shared Mem.", 1, false, Resources::new(131, 237, 64, 0)),
    ]
}

/// The common core (16 SPs + fetch/decode) total.
pub fn core_total() -> Resources {
    rows()
        .iter()
        .filter(|r| r.group == "Common")
        .fold(Resources::ZERO, |acc, r| acc + r.per_instance * r.count)
}

/// Memory-subsystem total (controllers + shared memory, submodules
/// excluded — they are folded into the shared-memory row) for a variant.
pub fn memory_total(arch: MemoryArchKind) -> Resources {
    let group = match arch {
        MemoryArchKind::Banked { banks: 4, .. } => "4 Banks",
        MemoryArchKind::Banked { banks: 8, .. } => "8 Banks",
        MemoryArchKind::Banked { banks: 16, .. } => "16 Banks",
        MemoryArchKind::MultiPort { .. } => "Multi-Port",
        MemoryArchKind::Banked { .. } => panic!("no Table I data for this bank count"),
    };
    rows()
        .iter()
        .filter(|r| r.group == group && !r.submodule)
        .fold(Resources::ZERO, |acc, r| acc + r.per_instance * r.count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_is_about_7k_alms() {
        // 16×430 + 233 = 7113 ALMs, 34 M20Ks, 32 DSPs.
        let c = core_total();
        assert_eq!(c.alms, 7113);
        assert_eq!(c.m20k, 34);
        assert_eq!(c.dsp, 32);
    }

    #[test]
    fn sixteen_bank_memory_is_13k_alms() {
        // "The 16 bank memory needs about 13K ALMs by itself".
        let m = memory_total(MemoryArchKind::banked(16));
        assert_eq!(m.alms - 789 - 1507, 13_105);
        // "...and the cost including the read and write controllers is
        // twice that of the SIMT core" (15.4K vs 7.1K).
        assert!(m.alms as f64 > 2.0 * core_total().alms as f64);
    }

    #[test]
    fn multiport_memory_under_1k_alms() {
        // "the multi-port memory (4R-1W, 4R-2W) requires less than 1K ALMs
        // in an unconstrained placement".
        let m = memory_total(MemoryArchKind::mp_4r1w());
        assert!(m.alms < 1000, "{} ALMs", m.alms);
    }

    #[test]
    fn controller_logic_scales_linearly_with_banks() {
        // "The logic area of the read and write access controllers varies
        // linearly with the number of banks" — check monotone growth and
        // rough proportionality between 8 and 16 banks.
        let read_ctl = |g: &str| {
            rows()
                .iter()
                .find(|r| r.group == g && r.module == "Read Ctl.")
                .unwrap()
                .per_instance
                .alms as f64
        };
        let (r4, r8, r16) = (read_ctl("4 Banks"), read_ctl("8 Banks"), read_ctl("16 Banks"));
        assert!(r4 < r8 && r8 < r16);
        let ratio = r16 / r8;
        assert!((1.3..2.0).contains(&ratio), "16/8 read-ctl ratio {ratio}");
    }

    #[test]
    fn arbiter_cost_constant_per_core() {
        // "The individual read and write arbitrate cores always use about
        // the same amount of logic" across bank counts.
        let arb = |g: &str, m: &str| {
            rows()
                .iter()
                .find(|r| r.group == g && r.module == m)
                .unwrap()
                .per_instance
                .alms as f64
        };
        for m in ["Read Arb.", "Write Arb."] {
            let vals = [arb("4 Banks", m), arb("8 Banks", m), arb("16 Banks", m)];
            let (lo, hi) = (vals.iter().cloned().fold(f64::MAX, f64::min),
                            vals.iter().cloned().fold(0.0, f64::max));
            assert!(hi / lo < 1.1, "{m} varies too much: {vals:?}");
        }
    }

    #[test]
    fn arbiters_and_muxes_dominate_banked_logic() {
        // "The number of arbitration circuits and the output muxes comprise
        // about 90% of the logic of the bank memory resources."
        let rows = rows();
        let shared = rows
            .iter()
            .find(|r| r.group == "16 Banks" && r.module == "Shared Mem.")
            .unwrap()
            .per_instance
            .alms as f64;
        let parts: f64 = rows
            .iter()
            .filter(|r| r.group == "16 Banks" && r.submodule)
            .map(|r| (r.per_instance.alms * r.count) as f64)
            .sum();
        let frac = parts / shared;
        assert!((0.75..=1.0).contains(&frac), "arbiter+mux fraction {frac}");
    }

    #[test]
    fn memory_total_rejects_odd_bank_counts() {
        let r = std::panic::catch_unwind(|| memory_total(MemoryArchKind::banked(2)));
        assert!(r.is_err());
    }
}
