//! Point evaluation: compiled-trace replay + footprint model.
//!
//! One [`Evaluator`] owns one workload's [`CompiledTrace`] (fetched
//! through the shared [`TraceCache`], so a workload is functionally
//! executed — and compiled — at most **once** no matter how many points
//! are scored; the counter [`Evaluator::captures`] is the executable
//! statement of that guarantee). Per-architecture timing is a pure
//! closed-form charge over the compiled trace (DESIGN.md §Replay),
//! memoized across the design points that share an architecture and
//! batched per strategy wave ([`Evaluator::replay_batch`]: the
//! lane-packed segment wavefront charges eight candidates per lock-step
//! chunk across the worker pool); capacity only enters through the ALM
//! footprint model.
//!
//! For pruning strategies the evaluator also offers a **lower bound** on
//! replay cycles, computed in O(1) per architecture from a popcount
//! histogram of the trace: every memory operation costs at least
//! ⌈active/banks⌉ (banked; the true cost is the max per-bank count) or
//! exactly ⌈active/ports⌉ (multiport), stores issue at least one cycle
//! per operation, and the fixed §III-A per-instruction overheads always
//! apply. `lower_bound_cycles(arch) <= replay cycles` is property-tested
//! (`lower_bound_is_sound_property` in `rust/tests/explore.rs`).

use super::pareto::Cost;
use super::space::DesignPoint;
use crate::area::footprint::{self, Footprint};
use crate::coordinator::job::{BenchJob, TraceCache};
use crate::coordinator::runner::SweepRunner;
use crate::mem::arch::MemoryArchKind;
use crate::mem::{timing, LANES};
use crate::obs::{Counter, MetricsRegistry};
use crate::sim::compiled::{replay_compiled, CompiledTrace};
use crate::sim::config::MachineConfig;
use crate::sim::exec::{MemAccessKind, MemTrace, SimError};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The two objectives plus derived metrics for one scored point.
#[derive(Debug, Clone, Copy)]
pub struct PointCost {
    /// Total replayed cycles (architecture-dependent, capacity-free).
    pub cycles: u64,
    /// Wall time at the architecture's Fmax.
    pub time_us: f64,
    /// Whole-processor footprint at the point's capacity; `None` when
    /// the capacity exceeds the architecture's roofline.
    pub footprint: Option<Footprint>,
}

impl PointCost {
    pub fn alms(&self) -> Option<u32> {
        self.footprint.map(|f| f.total_alms())
    }

    pub fn sectors(&self) -> Option<f64> {
        self.footprint.map(|f| f.sectors())
    }

    /// The paper's efficiency criterion: 1 / (time × sectors).
    pub fn perf_per_area(&self) -> Option<f64> {
        self.footprint.map(|f| 1.0 / (self.time_us * f.sectors()))
    }

    /// Objective-space position; `None` when the point is unplaceable
    /// (over the roofline) and therefore never enters a frontier.
    pub fn objective(&self) -> Option<Cost> {
        self.alms().map(|alms| Cost { cycles: self.cycles, alms })
    }
}

/// Popcount histogram of the trace — everything the lower-bound model
/// needs, precomputed once so each per-architecture bound is O(LANES).
#[derive(Debug, Clone, Default)]
struct TraceProfile {
    alu_cycles: u64,
    load_instrs: u64,
    load_hist: [u64; LANES + 1],
    blocking_store_instrs: u64,
    blocking_hist: [u64; LANES + 1],
    nonblocking_ops: u64,
}

impl TraceProfile {
    fn from_trace(trace: &MemTrace) -> Self {
        let mut p = TraceProfile { alu_cycles: trace.tail.cycles(), ..Default::default() };
        for seg in &trace.segments {
            p.alu_cycles += seg.before.cycles();
            match seg.mem.kind {
                MemAccessKind::Load(_) => {
                    p.load_instrs += 1;
                    for (_, mask) in &seg.mem.ops {
                        p.load_hist[mask.count_ones() as usize] += 1;
                    }
                }
                MemAccessKind::Store { blocking: true } => {
                    p.blocking_store_instrs += 1;
                    for (_, mask) in &seg.mem.ops {
                        p.blocking_hist[mask.count_ones() as usize] += 1;
                    }
                }
                MemAccessKind::Store { blocking: false } => {
                    p.nonblocking_ops += seg.mem.ops.len() as u64;
                }
            }
        }
        p
    }
}

/// Workload-bound evaluator shared across strategies and worker threads.
pub struct Evaluator {
    program: String,
    dataset_kb: u32,
    /// Compiled form of the workload trace (DESIGN.md §Replay): every
    /// per-architecture score is a closed-form charge over this, with no
    /// address re-hashing per candidate.
    compiled: Arc<CompiledTrace>,
    profile: TraceProfile,
    captures: u64,
    /// Per-architecture replay memo. The outer lock only guards the map
    /// shape; each architecture gets its own slot lock, so concurrent
    /// scores of the *same* architecture serialize on one replay (the
    /// counter stays exact) while different architectures replay in
    /// parallel on the worker pool.
    replays: Mutex<HashMap<MemoryArchKind, Arc<Mutex<Option<u64>>>>>,
    replay_count: AtomicU64,
    scored: AtomicU64,
    /// Session metrics, inherited from the cache (the engine attaches
    /// one registry to cache + runner; the explorer reports through the
    /// same one). `None` on standalone/cold-cache wiring.
    metrics: Option<Arc<MetricsRegistry>>,
}

impl Evaluator {
    /// Fetch (or capture) the workload's trace through `cache`. The
    /// capture runs at most once per `(program, seed)` — reusing a warm
    /// cache records zero captures.
    pub fn new(program: &str, cache: &TraceCache) -> Result<Self, SimError> {
        // Arch is irrelevant for capture; BenchJob only needs a valid one.
        let probe = BenchJob::new(program, MemoryArchKind::banked(16));
        let warm = cache.get(&probe.trace_key()).is_some();
        let trace = cache.get_or_capture(&probe)?;
        let profile = TraceProfile::from_trace(&trace);
        // Same figure as `Workload::dataset_kb()` — the trace carries the
        // workload's capacity, so no workload re-materialization is
        // needed here.
        let dataset_kb = (trace.mem_words * 4 / 1024) as u32;
        // The compiled form is memoized in the same cache, so a sweep,
        // an exploration and any number of engine `Run`s over one
        // workload share one compilation too.
        let compiled = cache.get_or_compile(&probe.trace_key(), &trace);
        Ok(Self {
            program: program.to_string(),
            dataset_kb,
            compiled,
            profile,
            captures: u64::from(!warm),
            replays: Mutex::new(HashMap::new()),
            replay_count: AtomicU64::new(0),
            scored: AtomicU64::new(0),
            metrics: cache.metrics().cloned(),
        })
    }

    pub fn program(&self) -> &str {
        &self.program
    }

    /// The workload's compiled trace — shared with the system-level
    /// evaluator ([`crate::explore::system`]), which layers inter-core
    /// contention onto the same per-op cost vectors instead of capturing
    /// or compiling anything of its own.
    pub(crate) fn compiled(&self) -> &CompiledTrace {
        &self.compiled
    }

    /// Workload dataset size in KB (the capacity floor).
    pub fn dataset_kb(&self) -> u32 {
        self.dataset_kb
    }

    /// Functional executions this evaluator triggered: 0 (warm cache) or
    /// 1 — never more, regardless of how many points were scored.
    pub fn captures(&self) -> u64 {
        self.captures
    }

    /// Distinct architecture replays performed so far.
    pub fn replays(&self) -> u64 {
        self.replay_count.load(Ordering::Relaxed)
    }

    /// Points scored so far (exact evaluations, shared replays included).
    pub fn points_scored(&self) -> u64 {
        self.scored.load(Ordering::Relaxed)
    }

    /// Replay the trace on `arch`'s timing model (memoized). Zero
    /// functional execution, zero address hashing: the compiled trace is
    /// charged against `arch`'s closed-form cost model
    /// ([`replay_compiled`]), bit-identical to the reference
    /// `BenchJob::replay_trace` path (`rust/tests/replay_diff.rs`).
    pub fn replay_arch(&self, arch: MemoryArchKind) -> Result<u64, SimError> {
        let slot = Arc::clone(self.replays.lock().unwrap().entry(arch).or_default());
        let mut slot = slot.lock().unwrap();
        if let Some(cycles) = *slot {
            return Ok(cycles);
        }
        let report = replay_compiled(&self.compiled, arch, MachineConfig::DEFAULT_MAX_CYCLES)?;
        if let Some(m) = &self.metrics {
            m.inc(Counter::ReplayScalarInvocations);
            m.add(Counter::ReplayWbufStallCycles, report.stats.wbuf_stall_cycles);
        }
        let cycles = report.total_cycles();
        self.replay_count.fetch_add(1, Ordering::Relaxed);
        *slot = Some(cycles);
        Ok(cycles)
    }

    /// Batch-replay every not-yet-memoized architecture in `archs`: the
    /// slate is deduplicated and charged through the lane-packed segment
    /// wavefront ([`SweepRunner::replay_many_parallel`]) — eight
    /// candidates per lock-step chunk, every worker advancing a chunk
    /// through the same trace segment — the explorer's unit of
    /// parallelism (strategies call this before scoring a wave).
    pub fn replay_batch(
        &self,
        archs: &[MemoryArchKind],
        runner: &SweepRunner,
    ) -> Result<(), SimError> {
        let mut todo: Vec<MemoryArchKind> = Vec::new();
        {
            let memo = self.replays.lock().unwrap();
            for &arch in archs {
                let known = memo.get(&arch).is_some_and(|slot| slot.lock().unwrap().is_some());
                if !known && !todo.contains(&arch) {
                    todo.push(arch);
                }
            }
        }
        if todo.is_empty() {
            return Ok(());
        }
        let replayed =
            runner.replay_many_parallel(&self.compiled, &todo, MachineConfig::DEFAULT_MAX_CYCLES);
        for (&arch, report) in todo.iter().zip(replayed) {
            let cycles = report?.total_cycles();
            let slot = Arc::clone(self.replays.lock().unwrap().entry(arch).or_default());
            let mut slot = slot.lock().unwrap();
            if slot.is_none() {
                *slot = Some(cycles);
                self.replay_count.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Exact score of one design point: memoized replay + footprint at
    /// the point's capacity.
    pub fn score(&self, point: &DesignPoint) -> Result<PointCost, SimError> {
        let cycles = self.replay_arch(point.arch)?;
        self.scored.fetch_add(1, Ordering::Relaxed);
        Ok(PointCost {
            cycles,
            time_us: cycles as f64 / point.arch.fmax_mhz(),
            footprint: footprint::processor_footprint(point.arch, point.capacity_kb),
        })
    }

    /// Footprint ALMs without any replay (the cheap objective — known
    /// exactly up front). `u32::MAX` for unplaceable points so they are
    /// trivially dominated and never survive to a frontier.
    pub fn alms_bound(&self, point: &DesignPoint) -> u32 {
        footprint::processor_footprint(point.arch, point.capacity_kb)
            .map(|f| f.total_alms())
            .unwrap_or(u32::MAX)
    }

    /// Cheap lower bound on `replay_arch(point.arch)` — see the module
    /// docs for the argument. Used by pruning strategies to cull points
    /// whose *best possible* cost is already dominated.
    pub fn lower_bound_cycles(&self, arch: MemoryArchKind) -> u64 {
        let (read_div, write_div, read_ovh, write_ovh) = match arch {
            MemoryArchKind::Banked { banks, .. } => (
                banks,
                banks,
                timing::banked_read_overhead(false),
                timing::banked_write_overhead(false),
            ),
            MemoryArchKind::MultiPort { read_ports, write_ports, vb } => {
                (read_ports, if vb { 2 } else { write_ports }, 0, 0)
            }
        };
        let p = &self.profile;
        let mut lb = p.alu_cycles
            + p.load_instrs * read_ovh as u64
            + p.blocking_store_instrs * write_ovh as u64
            + p.nonblocking_ops // at least one issue cycle each
            + 1; // halt
        for pop in 0..=LANES {
            let read_cost = (pop as u64).div_ceil(read_div as u64).max(1);
            let write_cost = (pop as u64).div_ceil(write_div as u64).max(1);
            lb += p.load_hist[pop] * read_cost;
            lb += p.blocking_hist[pop] * write_cost;
        }
        lb
    }

    /// The lower-bound position of a point in objective space (exact on
    /// the area axis, a lower bound on the time axis).
    pub fn lower_bound(&self, point: &DesignPoint) -> Cost {
        Cost { cycles: self.lower_bound_cycles(point.arch), alms: self.alms_bound(point) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::space::DesignSpace;

    #[test]
    fn capture_runs_once_across_many_scores() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        assert_eq!(eval.captures(), 1);
        for p in DesignSpace::parametric(eval.dataset_kb()).points() {
            eval.score(&p).unwrap();
        }
        assert_eq!(eval.captures(), 1, "no functional re-execution per point");
        assert_eq!(cache.len(), 1);
        // A second evaluator on the warm cache captures nothing.
        let again = Evaluator::new("transpose32", &cache).unwrap();
        assert_eq!(again.captures(), 0);
    }

    #[test]
    fn replays_memoized_per_arch() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        let a = DesignPoint { arch: MemoryArchKind::banked(16), capacity_kb: 8 };
        let b = DesignPoint { arch: MemoryArchKind::banked(16), capacity_kb: 16 };
        let ca = eval.score(&a).unwrap();
        let cb = eval.score(&b).unwrap();
        assert_eq!(eval.replays(), 1, "capacity variants share one replay");
        assert_eq!(ca.cycles, cb.cycles);
        assert!(ca.alms() <= cb.alms(), "banked footprint constant in capacity");
    }

    #[test]
    fn batch_replay_memoizes_and_agrees_with_coupled_runs() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        let runner = SweepRunner::new(2);
        let archs = [
            MemoryArchKind::banked(16),
            MemoryArchKind::mp_4r1w(),
            MemoryArchKind::banked(16), // duplicate: deduped in the slate
            MemoryArchKind::banked_offset(8),
        ];
        eval.replay_batch(&archs, &runner).unwrap();
        assert_eq!(eval.replays(), 3, "duplicates share one replay");
        eval.replay_batch(&archs, &runner).unwrap();
        assert_eq!(eval.replays(), 3, "second batch is fully memoized");
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::mp_4r1w()] {
            let batched = eval.replay_arch(arch).unwrap();
            let coupled = BenchJob::new("transpose32", arch).run().unwrap();
            assert_eq!(batched, coupled.report.total_cycles(), "{arch}");
        }
        assert_eq!(eval.replays(), 3, "memo reused by the single-arch path");
    }

    #[test]
    fn score_matches_bench_job_cycles() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        for arch in MemoryArchKind::table3_nine() {
            let p = DesignPoint { arch, capacity_kb: eval.dataset_kb() };
            let scored = eval.score(&p).unwrap();
            let coupled = BenchJob::new("transpose32", arch).run().unwrap();
            assert_eq!(scored.cycles, coupled.report.total_cycles(), "{arch}");
        }
    }

    #[test]
    fn lower_bound_below_exact_on_paper_archs() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("fft4096r8", &cache).unwrap();
        for arch in MemoryArchKind::table3_nine() {
            let lb = eval.lower_bound_cycles(arch);
            let exact = eval.replay_arch(arch).unwrap();
            assert!(lb <= exact, "{arch}: lb {lb} > exact {exact}");
            assert!(lb > 0);
        }
    }

    #[test]
    fn unplaceable_point_has_max_alms_bound() {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        let over = DesignPoint { arch: MemoryArchKind::mp_4r1w(), capacity_kb: 500 };
        assert_eq!(eval.alms_bound(&over), u32::MAX);
        let c = eval.score(&over).unwrap();
        assert!(c.footprint.is_none());
        assert!(c.objective().is_none());
    }

    #[test]
    fn unknown_program_errors() {
        assert!(Evaluator::new("nope", &TraceCache::new()).is_err());
    }
}
