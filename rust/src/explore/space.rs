//! Parametric design-space definition.
//!
//! A [`DesignSpace`] is an ordered set of memory-architecture descriptors
//! crossed with candidate capacities, filtered by named constraint
//! predicates. The paper evaluates 9 fixed architectures; this builder
//! spans the space its §VII names as the FPGA's real advantage — bank
//! count 2–32 × bank mapping (LSB / shifted Offset family / XOR) ×
//! multiport read/write-port configurations × memory capacity.

use crate::area::footprint;
use crate::mem::arch::MemoryArchKind;
use crate::mem::mapping::BankMapping;

/// One candidate configuration: an architecture at a concrete shared
/// memory capacity. Timing depends only on the architecture (replayed
/// from the workload trace); capacity feeds the footprint model and the
/// capacity constraints.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DesignPoint {
    pub arch: MemoryArchKind,
    pub capacity_kb: u32,
}

impl DesignPoint {
    /// Human label, e.g. `16 Banks Offset @ 64 KB`.
    pub fn label(&self) -> String {
        format!("{} @ {} KB", self.arch.label(), self.capacity_kb)
    }
}

/// A named constraint predicate over design points.
pub struct Constraint {
    pub name: &'static str,
    pred: Box<dyn Fn(&DesignPoint) -> bool + Send + Sync>,
}

impl std::fmt::Debug for Constraint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Constraint({})", self.name)
    }
}

/// Builder for a parametric design space.
#[derive(Debug, Default)]
pub struct DesignSpace {
    archs: Vec<MemoryArchKind>,
    capacities_kb: Vec<u32>,
    constraints: Vec<Constraint>,
}

impl DesignSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one architecture (deduplicated, insertion-ordered). Panics on
    /// a descriptor [`MemoryArchKind::is_valid`] rejects — the explorer
    /// never builds memories outside the constructible space.
    pub fn arch(mut self, kind: MemoryArchKind) -> Self {
        assert!(kind.is_valid(), "invalid architecture descriptor {kind:?}");
        if !self.archs.contains(&kind) {
            self.archs.push(kind);
        }
        self
    }

    /// Add the full banked grid: every bank count × every mapping.
    pub fn banked_grid(
        mut self,
        banks: impl IntoIterator<Item = u32>,
        mappings: impl IntoIterator<Item = BankMapping> + Clone,
    ) -> Self {
        for b in banks {
            for m in mappings.clone() {
                self = self.arch(MemoryArchKind::Banked { banks: b, mapping: m });
            }
        }
        self
    }

    /// Add one multiport configuration.
    pub fn multiport(self, read_ports: u32, write_ports: u32, vb: bool) -> Self {
        self.arch(MemoryArchKind::MultiPort { read_ports, write_ports, vb })
    }

    /// Candidate shared-memory capacities in KB (deduplicated, sorted).
    pub fn capacities_kb(mut self, kbs: impl IntoIterator<Item = u32>) -> Self {
        for kb in kbs {
            if !self.capacities_kb.contains(&kb) {
                self.capacities_kb.push(kb);
            }
        }
        self.capacities_kb.sort_unstable();
        self
    }

    /// Attach a named constraint predicate.
    pub fn constraint(
        mut self,
        name: &'static str,
        pred: impl Fn(&DesignPoint) -> bool + Send + Sync + 'static,
    ) -> Self {
        self.constraints.push(Constraint { name, pred: Box::new(pred) });
        self
    }

    /// Constraint: capacity must not exceed the architecture's roofline
    /// (§VI — 112 KB for 4R-1W, 224 KB for 4R-2W, 28 KB × banks banked).
    pub fn with_capacity_roofline(self) -> Self {
        self.constraint("capacity <= roofline", |p| {
            p.capacity_kb <= footprint::max_capacity_kb(p.arch)
        })
    }

    /// Constraint: capacity must hold the workload's dataset.
    pub fn fits_dataset(self, dataset_kb: u32) -> Self {
        self.constraint("capacity >= dataset", move |p| p.capacity_kb >= dataset_kb)
    }

    /// Number of distinct architectures before capacity crossing.
    pub fn arch_count(&self) -> usize {
        self.archs.len()
    }

    /// Constraint names, for reports.
    pub fn constraint_names(&self) -> Vec<&'static str> {
        self.constraints.iter().map(|c| c.name).collect()
    }

    /// Enumerate the constrained points, insertion-ordered by
    /// architecture then capacity. A space with no configured capacities
    /// yields no points (and `explore()` reports the empty space as an
    /// error) rather than fabricating a 0 KB memory.
    pub fn points(&self) -> Vec<DesignPoint> {
        let mut out = Vec::new();
        for &arch in &self.archs {
            for &capacity_kb in &self.capacities_kb {
                let p = DesignPoint { arch, capacity_kb };
                if self.constraints.iter().all(|c| (c.pred)(&p)) {
                    out.push(p);
                }
            }
        }
        out
    }

    /// The default CLI space: bank counts 2–32 × {LSB, Offset shifts
    /// 1–3, XOR} × the multiport family × three capacities from the
    /// dataset size up, under the roofline and fits-dataset constraints.
    /// On the small benchmarks this is a 90-point space served by 30
    /// trace replays and **one** functional execution.
    pub fn parametric(dataset_kb: u32) -> Self {
        let d = dataset_kb.max(1);
        Self::new()
            .banked_grid(
                [2u32, 4, 8, 16, 32],
                [
                    BankMapping::Lsb,
                    BankMapping::Offset { shift: 1 },
                    BankMapping::offset(),
                    BankMapping::Offset { shift: 3 },
                    BankMapping::Xor,
                ],
            )
            .multiport(4, 1, false)
            .multiport(4, 2, false)
            .multiport(4, 1, true)
            .multiport(2, 1, false)
            .multiport(8, 1, false)
            .capacities_kb([d, 2 * d, 4 * d])
            .with_capacity_roofline()
            .fits_dataset(d)
    }

    /// The advisor's candidate set: a fixed arch list at exactly the
    /// dataset capacity, order-preserving and **without** the roofline
    /// constraint — over-roofline candidates stay in the scorecard (with
    /// no footprint) exactly as the paper's comparison tables keep them.
    pub fn from_archs(archs: impl IntoIterator<Item = MemoryArchKind>, capacity_kb: u32) -> Self {
        let mut s = Self::new().capacities_kb([capacity_kb]);
        for a in archs {
            s = s.arch(a);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parametric_space_shape() {
        let s = DesignSpace::parametric(8);
        assert_eq!(s.arch_count(), 30, "25 banked + 5 multiport");
        let pts = s.points();
        assert_eq!(pts.len(), 90, "3 capacities all under every roofline at 8 KB");
        assert!(pts.len() > 50, "acceptance: >50-point space");
        // Points are unique.
        let set: std::collections::HashSet<_> = pts.iter().collect();
        assert_eq!(set.len(), pts.len());
    }

    #[test]
    fn roofline_constraint_prunes() {
        // At a 128 KB dataset the 2- and 4-bank memories (56/112 KB
        // rooflines) and the 4R-1W multiport (112 KB) drop out entirely.
        let pts = DesignSpace::parametric(128).points();
        assert!(pts
            .iter()
            .all(|p| p.capacity_kb <= footprint::max_capacity_kb(p.arch)));
        assert!(!pts.iter().any(|p| p.arch == MemoryArchKind::banked(2)));
        assert!(!pts.iter().any(|p| p.arch == MemoryArchKind::mp_4r1w()));
        assert!(pts.iter().any(|p| p.arch == MemoryArchKind::banked(32)));
    }

    #[test]
    fn from_archs_preserves_order_and_skips_roofline() {
        let archs = vec![
            MemoryArchKind::mp_4r1w(),
            MemoryArchKind::banked(16),
            MemoryArchKind::banked_offset(4),
        ];
        let s = DesignSpace::from_archs(archs.clone(), 400);
        let pts = s.points();
        // 400 KB exceeds every roofline except 16 banks — all kept anyway.
        assert_eq!(pts.len(), 3);
        for (p, a) in pts.iter().zip(&archs) {
            assert_eq!(p.arch, *a);
            assert_eq!(p.capacity_kb, 400);
        }
    }

    #[test]
    fn custom_constraints_and_dedup() {
        let s = DesignSpace::new()
            .arch(MemoryArchKind::banked(8))
            .arch(MemoryArchKind::banked(8))
            .capacities_kb([16, 32, 16])
            .constraint("even capacity only", |p| p.capacity_kb % 32 == 0);
        assert_eq!(s.arch_count(), 1);
        assert_eq!(s.points().len(), 1);
        assert_eq!(s.constraint_names(), vec!["even capacity only"]);
    }

    #[test]
    fn no_capacities_means_no_points() {
        let s = DesignSpace::new().arch(MemoryArchKind::banked(16));
        assert!(s.points().is_empty(), "no fabricated 0 KB points");
    }

    #[test]
    #[should_panic(expected = "invalid architecture")]
    fn invalid_arch_rejected() {
        let _ = DesignSpace::new().arch(MemoryArchKind::Banked {
            banks: 64,
            mapping: BankMapping::Lsb,
        });
    }

    #[test]
    fn point_labels_parse_back() {
        for p in DesignSpace::parametric(8).points() {
            assert_eq!(
                MemoryArchKind::parse(&p.arch.label()),
                Some(p.arch),
                "explorer-generated label '{}' must parse back",
                p.arch.label()
            );
        }
    }
}
