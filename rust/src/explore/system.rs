//! System-scale exploration: multi-processor × lanes × memory × capacity
//! (ROADMAP item 4, DESIGN.md §Explore).
//!
//! The paper evaluates one 32-lane processor against nine memories, but
//! pitches the banked memories as reusable building blocks; the scalable
//! soft-GPGPU line (arXiv:2401.04261) and the 950 MHz re-pipelined SIMT
//! processor (arXiv:2504.07538) show the real design question is *arrays
//! of cores sharing a banked memory at a target clock*. This module
//! extends the explorer to that space:
//!
//! - [`SystemPoint`] — `{processors, lanes, mem, capacity_kb}` with a
//!   parse/label grammar (`p4x32:banked16@64`) extending
//!   [`crate::mem::arch::PARSE_GRAMMAR`];
//! - an **inter-core contention model** layered on compiled-trace
//!   replay: `P` cores interleave independent warp streams onto the
//!   shared banks, so each memory operation pays its single-core cost
//!   plus `(P−1) × ⌈active / divisor⌉` arbitration-conflict cycles,
//!   where the divisor is the bank count (banked) or the port count
//!   (multiport) — the expected extra occupancy the other `P−1` streams
//!   add, computed from the per-op occupancy vectors already stored in
//!   [`crate::mem::compiled`]. No new functional executions; **P=1 is
//!   bit-identical to [`crate::sim::compiled::replay_compiled`]**
//!   (pinned by tests here and in `rust/tests/explore.rs`);
//! - a **lane-scaling model**: `lanes/16` lane groups retire the ALU
//!   stream proportionally faster (`⌈cycles/groups⌉`) while the memory
//!   stream is unchanged — wider datapaths don't add bank ports;
//! - a per-point **Fmax model** ([`SystemPoint::fmax_mhz`]): anchored on
//!   the paper's 771 MHz (banked) / 600 MHz (4R-2W) clocks; wider banked
//!   datapaths need the deeper pipelining of arXiv:2504.07538 and scale
//!   toward its 950 MHz ceiling ([`timing::DEEP_FMAX_MHZ`]), while
//!   multiport points stay mux-limited at their paper clocks; every
//!   processor doubling costs [`ARBITRATION_FMAX_PENALTY`] of clock for
//!   the shared-memory arbiter stage;
//! - a **throughput-per-ALM objective**: `ops × P / (cycles/fmax) /
//!   total ALMs`, the paper's perf-per-area criterion generalized to a
//!   system ([`SystemCost::throughput_per_alm`]), with the footprint
//!   from [`footprint::system_footprint`] (shared memory once, `P`
//!   scaled cores, an arbiter per extra core);
//! - [`SystemSpace`] / [`explore_system`] — the builder and the
//!   exhaustive scorer. Scoring a whole `{1,2,4} × {16,32,64} ×
//!   paper-nine × capacities` space costs **one functional execution**
//!   (the capture flows through the same [`Evaluator`] the flat explorer
//!   uses) and one closed-form system replay per distinct
//!   `(processors, lanes, memory)` triple, memoized across capacities.
//!
//! The Pareto frontier reuses [`ParetoFront`] with the time axis in
//! integer nanoseconds (cycles scaled by the point's Fmax) — the
//! generalization of the flat explorer's cycles × ALMs objective to a
//! space where points run at different clocks.

use crate::area::footprint::{self, Footprint};
use crate::coordinator::job::TraceCache;
use crate::explore::eval::Evaluator;
use crate::explore::pareto::{Cost, ParetoFront};
use crate::mem::arch::MemoryArchKind;
use crate::mem::compiled::{ArchCost, ACTIVE_SLOT, FAMILY_COUNT};
use crate::mem::controller::WritePipeline;
use crate::mem::{timing, OpKind, LANES};
use crate::sim::compiled::{CompiledInstr, CompiledTrace};
use crate::sim::config::MachineConfig;
use crate::sim::exec::{MemAccessKind, SimError};
use crate::util::fmt::{json_str, with_commas, TextTable};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Largest constructible core count (power-of-two array sizes only, the
/// scalable-GPGPU line's replication unit).
pub const MAX_PROCESSORS: u32 = 8;

/// Widest constructible datapath: 4 lane groups of [`LANES`].
pub const MAX_LANES: u32 = 64;

/// Fractional Fmax lost per processor-count doubling to the shared
/// memory arbiter stage (4% per doubling — one extra mux level each).
pub const ARBITRATION_FMAX_PENALTY: f64 = 0.04;

/// One system design point: `processors` cores of `lanes` lanes sharing
/// one `mem` memory of `capacity_kb`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SystemPoint {
    pub processors: u32,
    pub lanes: u32,
    pub mem: MemoryArchKind,
    pub capacity_kb: u32,
}

impl SystemPoint {
    /// The paper's single-processor baseline around `mem`.
    pub fn single(mem: MemoryArchKind, capacity_kb: u32) -> Self {
        Self { processors: 1, lanes: LANES as u32, mem, capacity_kb }
    }

    /// Constructible: power-of-two core count up to [`MAX_PROCESSORS`],
    /// a power-of-two number of [`LANES`]-wide lane groups up to
    /// [`MAX_LANES`], a valid memory, and a non-zero capacity.
    pub fn is_valid(&self) -> bool {
        self.processors.is_power_of_two()
            && self.processors <= MAX_PROCESSORS
            && self.lanes % LANES as u32 == 0
            && (self.lanes / LANES as u32).is_power_of_two()
            && self.lanes <= MAX_LANES
            && self.mem.is_valid()
            && self.capacity_kb > 0
    }

    /// Datapath width in [`LANES`]-wide groups (1, 2 or 4).
    pub fn lane_groups(&self) -> u32 {
        self.lanes / LANES as u32
    }

    /// Canonical label, `p{procs}x{lanes}:{memory}@{capacity}` — e.g.
    /// `p4x32:banked16@64`. Round-trips through [`SystemPoint::parse`]
    /// (property-tested over every constructible point).
    pub fn label(&self) -> String {
        format!(
            "p{}x{}:{}@{}",
            self.processors,
            self.lanes,
            self.mem.compact_label(),
            self.capacity_kb
        )
    }

    /// Parse a [`SystemPoint::label`]-style string (the system clause of
    /// [`crate::mem::arch::PARSE_GRAMMAR`]). Case-insensitive; the
    /// memory part accepts anything [`MemoryArchKind::parse`] does.
    /// Returns `None` for malformed or unconstructible points.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_lowercase();
        let rest = s.strip_prefix('p')?;
        let (procs, rest) = rest.split_once('x')?;
        let (lanes, rest) = rest.split_once(':')?;
        let (mem, cap) = rest.rsplit_once('@')?;
        let point = Self {
            processors: procs.parse().ok()?,
            lanes: lanes.parse().ok()?,
            mem: MemoryArchKind::parse(mem)?,
            capacity_kb: cap.parse().ok()?,
        };
        point.is_valid().then_some(point)
    }

    /// Modeled clock for this point, in MHz.
    ///
    /// Anchors: a single 16-lane core keeps its memory's paper clock
    /// exactly ([`MemoryArchKind::fmax_mhz`] — 771 MHz banked, 600 MHz
    /// 4R-2W). Wider banked datapaths require the deeper pipelining of
    /// arXiv:2504.07538 and interpolate toward its 950 MHz ceiling
    /// (half-way at 32 lanes, fully at 64); multiport memories stay
    /// limited by their replicated-port muxing and keep the base clock
    /// at any width. Every processor-count doubling then costs
    /// [`ARBITRATION_FMAX_PENALTY`] for the shared-memory arbiter stage.
    pub fn fmax_mhz(&self) -> f64 {
        let base = self.mem.fmax_mhz();
        let depth_frac = match self.mem {
            MemoryArchKind::Banked { .. } => {
                (self.lane_groups().trailing_zeros() as f64 / 2.0).min(1.0)
            }
            MemoryArchKind::MultiPort { .. } => 0.0,
        };
        let deep = base + (timing::DEEP_FMAX_MHZ - base) * depth_frac;
        deep * (1.0 - ARBITRATION_FMAX_PENALTY * f64::from(self.processors.trailing_zeros()))
    }
}

/// Arbitration divisor of `mem` for `kind` operations: how many
/// concurrent lane requests the memory retires per cycle — banks
/// (banked) or ports (multiport, write side halved under the
/// virtual-bank write restriction exactly as the timing model's cost
/// divisor is).
fn contention_divisor(mem: MemoryArchKind, kind: OpKind) -> u64 {
    match mem {
        MemoryArchKind::Banked { banks, .. } => banks.into(),
        MemoryArchKind::MultiPort { read_ports, write_ports, vb } => match kind {
            OpKind::Read => read_ports.into(),
            OpKind::Write => if vb { 2 } else { write_ports.into() },
        },
    }
}

/// Per-point replay state — the system-level mirror of the private
/// `ArchState` in [`crate::sim::compiled`]: the same clock/write-pipeline
/// advance sequence per compiled instruction, with two extensions that
/// both reduce to the identity at `P=1, lanes=16`:
///
/// - every memory operation costs `(P−1) × ⌈active/divisor⌉` extra
///   arbitration cycles (zero extra streams at `P=1`);
/// - ALU charges advance the clock by `⌈cycles/lane_groups⌉` (the whole
///   charge at one lane group).
struct SystemState {
    cost: ArchCost,
    read_div: u64,
    write_div: u64,
    /// `P − 1`: competing warp streams on the shared memory.
    extra_streams: u64,
    /// Datapath width in lane groups (ALU throughput multiplier).
    alu_div: u64,
    now: u64,
    pipe: WritePipeline,
}

impl SystemState {
    fn new(trace: &CompiledTrace, point: SystemPoint) -> Self {
        let cost = trace.arch_cost(point.mem);
        Self {
            pipe: WritePipeline::new(cost.write_buffer_ops()),
            read_div: contention_divisor(point.mem, OpKind::Read),
            write_div: contention_divisor(point.mem, OpKind::Write),
            extra_streams: u64::from(point.processors - 1),
            alu_div: u64::from(point.lane_groups()),
            cost,
            now: 0,
        }
    }

    /// Single-core closed-form cost of operation `op` plus the modeled
    /// arbitration conflicts the other `P−1` streams add.
    #[inline]
    fn op_cost(&self, trace: &CompiledTrace, kind: OpKind, op: usize) -> u32 {
        let row = trace.gather_row(op);
        let active = row[ACTIVE_SLOT];
        let base = self.cost.op_cost(kind, &row[..FAMILY_COUNT], active);
        let div = match kind {
            OpKind::Read => self.read_div,
            OpKind::Write => self.write_div,
        };
        base + (self.extra_streams * u64::from(active).div_ceil(div)) as u32
    }

    /// Charge one compiled memory instruction — the exact clock-advance
    /// sequence of the single-core replayer, with the contention and
    /// lane-scaling terms folded in.
    fn charge(&mut self, trace: &CompiledTrace, instr: &CompiledInstr) {
        self.now += instr.before.cycles().div_ceil(self.alu_div);
        match instr.kind {
            MemAccessKind::Load(_) => {
                let mut attributed = u64::from(self.cost.overhead(OpKind::Read));
                for op in instr.ops.clone() {
                    attributed += u64::from(self.op_cost(trace, OpKind::Read, op));
                }
                self.now += attributed;
            }
            MemAccessKind::Store { blocking } => {
                let overhead = self.cost.overhead(OpKind::Write);
                let mut iss = self.now;
                for op in instr.ops.clone() {
                    let cost = self.op_cost(trace, OpKind::Write, op);
                    iss = self.pipe.issue_nonblocking(iss, cost, overhead);
                }
                self.now = if blocking { self.pipe.drain(iss) } else { iss };
            }
        }
    }

    /// Tail charges + the halt/drain sequence; returns elapsed cycles.
    fn finish(mut self, trace: &CompiledTrace, max_cycles: u64) -> Result<u64, SimError> {
        self.now += trace.tail_charges().cycles().div_ceil(self.alu_div);
        if self.now > max_cycles {
            return Err(SimError::CycleLimit { limit: max_cycles });
        }
        self.now += 1;
        Ok(self.pipe.drain(self.now))
    }
}

/// Replay `trace` under the system model of `point`. At
/// `processors=1, lanes=16` the charge sequence is exactly the
/// single-core one, so the result is bit-identical to
/// [`crate::sim::compiled::replay_compiled`]'s elapsed cycles.
pub(crate) fn replay_system(
    trace: &CompiledTrace,
    point: SystemPoint,
    max_cycles: u64,
) -> Result<u64, SimError> {
    let mut state = SystemState::new(trace, point);
    for instr in trace.instrs() {
        state.charge(trace, instr);
        if state.now > max_cycles {
            return Err(SimError::CycleLimit { limit: max_cycles });
        }
    }
    state.finish(trace, max_cycles)
}

/// The scored objectives of one system point.
#[derive(Debug, Clone, Copy)]
pub struct SystemCost {
    /// Modeled shared-memory-clock cycles to retire one workload stream
    /// under `P`-way contention.
    pub cycles: u64,
    /// Modeled clock ([`SystemPoint::fmax_mhz`]).
    pub fmax_mhz: f64,
    /// Wall time at the modeled clock.
    pub time_us: f64,
    /// System footprint ([`footprint::system_footprint`]); `None` over
    /// the memory's capacity roofline.
    pub footprint: Option<Footprint>,
}

impl SystemCost {
    pub fn alms(&self) -> Option<u32> {
        self.footprint.map(|f| f.total_alms())
    }

    /// The system objective: total operation throughput per ALM —
    /// `ops × P / time_us / alms` (each of the `P` cores retires its own
    /// copy of the workload's operation stream in the modeled time).
    pub fn throughput_per_alm(&self, ops: u64, processors: u32) -> Option<f64> {
        self.alms()
            .map(|alms| (ops * u64::from(processors)) as f64 / self.time_us / f64::from(alms))
    }

    /// Objective-space position for the frontier: wall time in integer
    /// nanoseconds × ALMs (both minimized; integer-valued so frontier
    /// membership is exactly reproducible). `None` over the roofline.
    pub fn objective(&self) -> Option<Cost> {
        self.alms().map(|alms| Cost { cycles: self.time_ns(), alms })
    }

    /// Wall time in integer nanoseconds (`cycles / fmax` rounded).
    pub fn time_ns(&self) -> u64 {
        (self.cycles as f64 * 1000.0 / self.fmax_mhz).round() as u64
    }
}

/// Workload-bound system evaluator: wraps the flat [`Evaluator`] (one
/// shared capture + compile through the [`TraceCache`]) and memoizes one
/// closed-form system replay per distinct `(processors, lanes, memory)`
/// triple — capacity only enters through the footprint model, exactly as
/// in the flat explorer.
pub struct SystemEvaluator {
    eval: Evaluator,
    replays: Mutex<HashMap<(u32, u32, MemoryArchKind), u64>>,
    replay_count: AtomicU64,
}

impl SystemEvaluator {
    pub fn new(program: &str, cache: &TraceCache) -> Result<Self, SimError> {
        Ok(Self {
            eval: Evaluator::new(program, cache)?,
            replays: Mutex::new(HashMap::new()),
            replay_count: AtomicU64::new(0),
        })
    }

    pub fn program(&self) -> &str {
        self.eval.program()
    }

    pub fn dataset_kb(&self) -> u32 {
        self.eval.dataset_kb()
    }

    /// Functional executions triggered: 0 (warm cache) or 1, no matter
    /// how many system points are scored.
    pub fn captures(&self) -> u64 {
        self.eval.captures()
    }

    /// Distinct `(processors, lanes, memory)` system replays so far.
    pub fn replays(&self) -> u64 {
        self.replay_count.load(Ordering::Relaxed)
    }

    /// Total 16-wide operations in one workload stream (the numerator of
    /// the throughput objective, before the `× P` stream count).
    pub fn stream_ops(&self) -> u64 {
        self.eval.compiled().base_stats().operations
    }

    /// The flat single-core evaluator sharing this one's trace — the
    /// `P=1, lanes=16` baseline the bit-identity tests compare against.
    pub fn flat(&self) -> &Evaluator {
        &self.eval
    }

    /// Modeled cycles for `point` (memoized per `(P, lanes, memory)`).
    pub fn replay(&self, point: SystemPoint) -> Result<u64, SimError> {
        let key = (point.processors, point.lanes, point.mem);
        if let Some(&cycles) = self.replays.lock().unwrap().get(&key) {
            return Ok(cycles);
        }
        let cycles =
            replay_system(self.eval.compiled(), point, MachineConfig::DEFAULT_MAX_CYCLES)?;
        self.replay_count.fetch_add(1, Ordering::Relaxed);
        self.replays.lock().unwrap().insert(key, cycles);
        Ok(cycles)
    }

    /// Exact score of one system point.
    pub fn score(&self, point: SystemPoint) -> Result<SystemCost, SimError> {
        let cycles = self.replay(point)?;
        let fmax_mhz = point.fmax_mhz();
        Ok(SystemCost {
            cycles,
            fmax_mhz,
            time_us: cycles as f64 / fmax_mhz,
            footprint: footprint::system_footprint(
                point.processors,
                point.lanes,
                point.mem,
                point.capacity_kb,
            ),
        })
    }
}

/// Builder for a system design space: core counts × lane widths ×
/// memories × capacities, enumerated in insertion order with
/// unconstructible combinations filtered out.
#[derive(Debug, Clone, Default)]
pub struct SystemSpace {
    processors: Vec<u32>,
    lanes: Vec<u32>,
    archs: Vec<MemoryArchKind>,
    capacities_kb: Vec<u32>,
    /// Minimum modeled clock a point must reach (MHz), if any.
    min_fmax_mhz: Option<f64>,
}

impl SystemSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Candidate core counts (deduplicated, sorted).
    pub fn processors(mut self, counts: impl IntoIterator<Item = u32>) -> Self {
        for p in counts {
            if !self.processors.contains(&p) {
                self.processors.push(p);
            }
        }
        self.processors.sort_unstable();
        self
    }

    /// Candidate datapath widths in lanes (deduplicated, sorted).
    pub fn lanes(mut self, widths: impl IntoIterator<Item = u32>) -> Self {
        for l in widths {
            if !self.lanes.contains(&l) {
                self.lanes.push(l);
            }
        }
        self.lanes.sort_unstable();
        self
    }

    /// Add one memory architecture (deduplicated, insertion-ordered).
    /// Panics on a descriptor [`MemoryArchKind::is_valid`] rejects, like
    /// the flat [`crate::explore::DesignSpace`] builder.
    pub fn arch(mut self, kind: MemoryArchKind) -> Self {
        assert!(kind.is_valid(), "invalid architecture descriptor {kind:?}");
        if !self.archs.contains(&kind) {
            self.archs.push(kind);
        }
        self
    }

    /// Add several memory architectures.
    pub fn archs(mut self, kinds: impl IntoIterator<Item = MemoryArchKind>) -> Self {
        for k in kinds {
            self = self.arch(k);
        }
        self
    }

    /// Candidate shared-memory capacities in KB (deduplicated, sorted).
    pub fn capacities_kb(mut self, kbs: impl IntoIterator<Item = u32>) -> Self {
        for kb in kbs {
            if !self.capacities_kb.contains(&kb) {
                self.capacities_kb.push(kb);
            }
        }
        self.capacities_kb.sort_unstable();
        self
    }

    /// Keep only points whose modeled clock ([`SystemPoint::fmax_mhz`])
    /// reaches `mhz` — the spec's `target_clock_mhz` filter.
    pub fn target_clock_mhz(mut self, mhz: f64) -> Self {
        self.min_fmax_mhz = Some(mhz);
        self
    }

    /// Enumerate the constructible points: processors × lanes × archs ×
    /// capacities, [`SystemPoint::is_valid`]-filtered (plus the
    /// target-clock filter, when set).
    pub fn points(&self) -> Vec<SystemPoint> {
        let mut out = Vec::new();
        for &processors in &self.processors {
            for &lanes in &self.lanes {
                for &arch in &self.archs {
                    for &capacity_kb in &self.capacities_kb {
                        let p = SystemPoint { processors, lanes, mem: arch, capacity_kb };
                        let fast_enough =
                            self.min_fmax_mhz.map_or(true, |mhz| p.fmax_mhz() >= mhz);
                        if p.is_valid() && fast_enough {
                            out.push(p);
                        }
                    }
                }
            }
        }
        out
    }

    /// Distinct `(processors, lanes, memory)` replay triples the space
    /// needs — the cost of scoring it, independent of capacity count.
    pub fn replay_triples(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        for p in self.points() {
            seen.insert((p.processors, p.lanes, p.mem));
        }
        seen.len()
    }

    /// The acceptance-criteria space: {1,2,4} cores × {16,32,64} lanes ×
    /// the paper nine × three capacities from the dataset size up.
    pub fn parametric(dataset_kb: u32) -> Self {
        let d = dataset_kb.max(1);
        Self::new()
            .processors([1, 2, 4])
            .lanes([16, 32, 64])
            .archs(MemoryArchKind::table3_nine())
            .capacities_kb([d, 2 * d, 4 * d])
    }
}

/// One exactly-scored system point.
#[derive(Debug, Clone, Copy)]
pub struct ScoredSystemPoint {
    pub point: SystemPoint,
    pub cycles: u64,
    pub fmax_mhz: f64,
    pub time_us: f64,
    pub time_ns: u64,
    pub footprint_alms: Option<u32>,
    pub throughput_per_alm: Option<f64>,
}

impl ScoredSystemPoint {
    pub fn new(point: SystemPoint, cost: &SystemCost, stream_ops: u64) -> Self {
        Self {
            point,
            cycles: cost.cycles,
            fmax_mhz: cost.fmax_mhz,
            time_us: cost.time_us,
            time_ns: cost.time_ns(),
            footprint_alms: cost.alms(),
            throughput_per_alm: cost.throughput_per_alm(stream_ops, point.processors),
        }
    }
}

/// The system explorer's output for one workload.
#[derive(Debug, Clone)]
pub struct SystemExploreResult {
    pub program: String,
    pub dataset_kb: u32,
    pub points_total: usize,
    pub points_scored: usize,
    /// Distinct `(processors, lanes, memory)` system replays performed.
    pub replays: u64,
    /// Functional executions triggered (0 on a warm cache, else 1).
    pub captures: u64,
    /// Exact scores in enumeration order.
    pub scored: Vec<ScoredSystemPoint>,
    /// The time × ALMs Pareto frontier, sorted by time ascending.
    pub front: Vec<ScoredSystemPoint>,
}

impl SystemExploreResult {
    /// The frontier of a scorecard: wall-time nanoseconds × ALMs, both
    /// minimized (unplaceable over-roofline points never enter).
    pub fn frontier_of(scored: &[ScoredSystemPoint]) -> Vec<ScoredSystemPoint> {
        let mut front: ParetoFront<ScoredSystemPoint> = ParetoFront::new();
        for s in scored {
            if let Some(alms) = s.footprint_alms {
                front.insert(Cost { cycles: s.time_ns, alms }, *s);
            }
        }
        front.into_sorted().into_iter().map(|(_, s)| s).collect()
    }

    /// Scorecard ranked by the system objective: throughput per ALM,
    /// best first (unplaceable points last; ties break by area then
    /// label for determinism).
    pub fn ranked(&self) -> Vec<ScoredSystemPoint> {
        let mut v = self.scored.clone();
        v.sort_by(|a, b| {
            let ta = a.throughput_per_alm.unwrap_or(f64::NEG_INFINITY);
            let tb = b.throughput_per_alm.unwrap_or(f64::NEG_INFINITY);
            tb.partial_cmp(&ta)
                .unwrap()
                .then(a.footprint_alms.unwrap_or(u32::MAX).cmp(&b.footprint_alms.unwrap_or(u32::MAX)))
                .then(a.point.label().cmp(&b.point.label()))
        });
        v
    }

    fn row_of(s: &ScoredSystemPoint) -> [String; 6] {
        [
            s.point.label(),
            with_commas(s.cycles),
            format!("{:.0}", s.fmax_mhz),
            format!("{:.2}", s.time_us),
            s.footprint_alms.map(|a| a.to_string()).unwrap_or_else(|| "over cap".into()),
            s.throughput_per_alm.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into()),
        ]
    }

    /// Full text report: summary, frontier, top of the ranked scorecard.
    pub fn render(&self) -> String {
        let mut out = format!(
            "system explore: {} ({} KB dataset)\n\
             space: {} points, {} scored — {} system replays, \
             {} functional execution(s)\n\nPareto frontier (time × ALMs):\n",
            self.program, self.dataset_kb, self.points_total, self.points_scored, self.replays,
            self.captures,
        );
        let headers = ["system", "cycles", "fmax MHz", "time (us)", "ALMs", "thr/ALM"];
        let mut t = TextTable::new(headers);
        for s in &self.front {
            t.row(Self::row_of(s));
        }
        out.push_str(&t.render());
        let ranked = self.ranked();
        let top = ranked.len().min(10);
        out.push_str(&format!(
            "\ntop {top} of {} scored points by throughput per ALM:\n",
            ranked.len()
        ));
        let mut t = TextTable::new(headers);
        for s in &ranked[..top] {
            t.row(Self::row_of(s));
        }
        out.push_str(&t.render());
        out
    }

    /// Serialize to JSON (hand-rolled; the crate is dependency-free).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"program\": {},\n", json_str(&self.program)));
        out.push_str(&format!("  \"dataset_kb\": {},\n", self.dataset_kb));
        out.push_str(&format!("  \"points_total\": {},\n", self.points_total));
        out.push_str(&format!("  \"points_scored\": {},\n", self.points_scored));
        out.push_str(&format!("  \"replays\": {},\n", self.replays));
        out.push_str(&format!("  \"captures\": {},\n", self.captures));
        out.push_str("  \"front\": ");
        out.push_str(&json_system_points(&self.front, "  "));
        out.push_str(",\n  \"scorecard\": ");
        out.push_str(&json_system_points(&self.scored, "  "));
        out.push_str("\n}\n");
        out
    }
}

fn json_system_points(points: &[ScoredSystemPoint], indent: &str) -> String {
    if points.is_empty() {
        return "[]".to_string();
    }
    let rows: Vec<String> = points
        .iter()
        .map(|s| {
            format!(
                "{indent}  {{\"system\": {}, \"processors\": {}, \"lanes\": {}, \
                 \"memory\": {}, \"capacity_kb\": {}, \"cycles\": {}, \"fmax_mhz\": {:.1}, \
                 \"time_us\": {:.4}, \"alms\": {}, \"throughput_per_alm\": {}}}",
                json_str(&s.point.label()),
                s.point.processors,
                s.point.lanes,
                json_str(&s.point.mem.compact_label()),
                s.point.capacity_kb,
                s.cycles,
                s.fmax_mhz,
                s.time_us,
                s.footprint_alms.map(|a| a.to_string()).unwrap_or_else(|| "null".into()),
                s.throughput_per_alm.map(|v| format!("{v:.6}")).unwrap_or_else(|| "null".into()),
            )
        })
        .collect();
    format!("[\n{}\n{indent}]", rows.join(",\n"))
}

/// Explore the system space for one workload: one functional execution
/// at most (zero on a warm `cache`), one closed-form system replay per
/// distinct `(processors, lanes, memory)` triple, one footprint lookup
/// per point. Scoring is exhaustive — the space is small (hundreds of
/// points) and every replay is a closed-form trace charge, so the flat
/// explorer's lower-bound pruning has nothing worthwhile to cull.
pub fn explore_system(
    program: &str,
    space: &SystemSpace,
    cache: &TraceCache,
) -> Result<SystemExploreResult, SimError> {
    let points = space.points();
    if points.is_empty() {
        return Err(SimError::BadProgram(format!(
            "system design space for '{program}' is empty (need processors, lanes, \
             memories and capacities)"
        )));
    }
    let eval = SystemEvaluator::new(program, cache)?;
    let stream_ops = eval.stream_ops();
    let mut scored = Vec::with_capacity(points.len());
    for &p in &points {
        scored.push(ScoredSystemPoint::new(p, &eval.score(p)?, stream_ops));
    }
    assert!(
        eval.captures() <= 1,
        "system explore must functionally execute at most once (got {})",
        eval.captures()
    );
    let front = SystemExploreResult::frontier_of(&scored);
    Ok(SystemExploreResult {
        program: program.to_string(),
        dataset_kb: eval.dataset_kb(),
        points_total: points.len(),
        points_scored: scored.len(),
        replays: eval.replays(),
        captures: eval.captures(),
        scored,
        front,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::mapping::BankMapping;
    use crate::mem::{FULL_MASK, LaneMask};
    use crate::sim::compiled::replay_compiled;
    use crate::sim::exec::{LoadClass, MemInstr, MemTrace};
    use crate::util::proptest::check;
    use crate::util::rng::XorShift64;

    fn pt(p: u32, l: u32, mem: MemoryArchKind, cap: u32) -> SystemPoint {
        SystemPoint { processors: p, lanes: l, mem, capacity_kb: cap }
    }

    #[test]
    fn label_grammar_examples() {
        let p = pt(4, 32, MemoryArchKind::banked(16), 64);
        assert_eq!(p.label(), "p4x32:banked16@64");
        assert_eq!(SystemPoint::parse("p4x32:banked16@64"), Some(p));
        // Mapping suffixes, multiport and case-insensitivity all parse.
        assert_eq!(
            SystemPoint::parse("P2x64:Banked8-Offset3@128"),
            Some(pt(2, 64, MemoryArchKind::Banked { banks: 8, mapping: BankMapping::Offset { shift: 3 } }, 128))
        );
        assert_eq!(
            SystemPoint::parse("p1x16:4r-2w@8"),
            Some(pt(1, 16, MemoryArchKind::mp_4r2w(), 8))
        );
    }

    #[test]
    fn parse_rejects_malformed_and_unconstructible() {
        for s in [
            "",
            "p4x32",
            "4x32:banked16@64",      // missing the p prefix
            "p4x32:banked16",        // missing capacity
            "p3x32:banked16@64",     // non-power-of-two cores
            "p4x24:banked16@64",     // non-power-of-two lane groups
            "p16x32:banked16@64",    // over MAX_PROCESSORS
            "p4x128:banked16@64",    // over MAX_LANES
            "p4x32:banked7@64",      // invalid memory
            "p4x32:banked16@0",      // zero capacity
            "p4x32:@64",
        ] {
            assert_eq!(SystemPoint::parse(s), None, "{s:?} must not parse");
        }
    }

    #[test]
    fn parse_label_roundtrip_over_constructible_points() {
        // Satellite: parse ∘ label = id over every constructible point.
        let mappings = [
            BankMapping::Lsb,
            BankMapping::Offset { shift: 1 },
            BankMapping::offset(),
            BankMapping::Offset { shift: 3 },
            BankMapping::Xor,
        ];
        check("system_point_roundtrip", 300, |rng: &mut XorShift64| {
            let processors = 1 << rng.below(4);
            let lanes = 16 << rng.below(3);
            let mem = if rng.chance(0.5) {
                MemoryArchKind::Banked {
                    banks: 2 << rng.below(5),
                    mapping: mappings[rng.below(mappings.len() as u32) as usize],
                }
            } else {
                MemoryArchKind::MultiPort {
                    read_ports: 1 << rng.below(4),
                    write_ports: 1 + rng.below(2),
                    vb: false,
                }
            };
            let mem = if rng.chance(0.2) { MemoryArchKind::mp_4r1w_vb() } else { mem };
            let p = pt(processors, lanes, mem, 1 + rng.below(512));
            assert!(p.is_valid(), "{p:?}");
            assert_eq!(SystemPoint::parse(&p.label()), Some(p), "{}", p.label());
        });
    }

    #[test]
    fn fmax_anchors() {
        // A single 16-lane core keeps its memory's paper clock exactly.
        assert_eq!(pt(1, 16, MemoryArchKind::banked(16), 64).fmax_mhz(), 771.0);
        assert_eq!(pt(1, 16, MemoryArchKind::mp_4r2w(), 64).fmax_mhz(), 600.0);
        // 64 banked lanes reach the arXiv:2504.07538 deep-pipeline clock.
        assert_eq!(pt(1, 64, MemoryArchKind::banked(16), 64).fmax_mhz(), 950.0);
        // Multiport stays mux-limited at any width.
        assert_eq!(pt(1, 64, MemoryArchKind::mp_4r1w(), 64).fmax_mhz(), 771.0);
        // More cores only ever lower the clock.
        let f1 = pt(1, 32, MemoryArchKind::banked(16), 64).fmax_mhz();
        let f2 = pt(2, 32, MemoryArchKind::banked(16), 64).fmax_mhz();
        let f4 = pt(4, 32, MemoryArchKind::banked(16), 64).fmax_mhz();
        assert!(f1 > f2 && f2 > f4);
        assert!((f1 + f2 + f4) / 3.0 > 600.0, "penalties stay moderate");
    }

    fn seq_addrs(stride: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = l as u32 * stride;
        }
        a
    }

    /// A trace mixing conflict-heavy loads and stores of every kind.
    fn conflict_trace(rng: &mut XorShift64) -> MemTrace {
        let n = 1 + rng.below(5) as usize;
        let mut instrs = Vec::with_capacity(n);
        for _ in 0..n {
            let n_ops = 1 + rng.below(6) as usize;
            let ops: Vec<([u32; LANES], LaneMask)> = (0..n_ops)
                .map(|_| {
                    let stride = [1u32, 2, 4, 16][rng.below(4) as usize];
                    (seq_addrs(stride), (rng.next_u32() as LaneMask) | 1)
                })
                .collect();
            let kind = match rng.below(4) {
                0 => MemAccessKind::Load(LoadClass::Data),
                1 => MemAccessKind::Load(LoadClass::Twiddle),
                2 => MemAccessKind::Store { blocking: true },
                _ => MemAccessKind::Store { blocking: false },
            };
            instrs.push(MemInstr { kind, ops });
        }
        MemTrace::from_mem_instrs("prop", 1024, instrs)
    }

    #[test]
    fn p1_l16_bit_identical_to_single_core_replay() {
        // The tentpole's pinned invariant, over random traces × the
        // paper nine: the system replay at P=1, 16 lanes equals the
        // single-core compiled replay's elapsed cycles exactly.
        check("system_p1_bit_identity", 40, |rng: &mut XorShift64| {
            let ct = CompiledTrace::compile(&conflict_trace(rng));
            for arch in MemoryArchKind::table3_nine() {
                let single = replay_compiled(&ct, arch, u64::MAX).unwrap().total_cycles();
                let system = replay_system(&ct, pt(1, 16, arch, 8), u64::MAX).unwrap();
                assert_eq!(system, single, "{arch}");
            }
        });
    }

    #[test]
    fn more_processors_never_decrease_cycles() {
        // Satellite monotonicity proptest: adding processors adds
        // arbitration conflicts, never removes them.
        check("system_processor_monotonicity", 40, |rng: &mut XorShift64| {
            let ct = CompiledTrace::compile(&conflict_trace(rng));
            for arch in MemoryArchKind::table3_nine() {
                for lanes in [16u32, 32, 64] {
                    let mut prev = 0u64;
                    for p in [1u32, 2, 4, 8] {
                        let c = replay_system(&ct, pt(p, lanes, arch, 8), u64::MAX).unwrap();
                        assert!(c >= prev, "{arch} p{p}x{lanes}: {c} < {prev}");
                        prev = c;
                    }
                }
            }
        });
    }

    #[test]
    fn wider_lanes_never_increase_cycles() {
        check("system_lane_monotonicity", 40, |rng: &mut XorShift64| {
            let ct = CompiledTrace::compile(&conflict_trace(rng));
            for arch in MemoryArchKind::table3_nine() {
                for p in [1u32, 4] {
                    let mut prev = u64::MAX;
                    for lanes in [16u32, 32, 64] {
                        let c = replay_system(&ct, pt(p, lanes, arch, 8), u64::MAX).unwrap();
                        assert!(c <= prev, "{arch} p{p}x{lanes}: {c} > {prev}");
                        prev = c;
                    }
                }
            }
        });
    }

    #[test]
    fn contention_scales_with_active_lanes_and_banks() {
        // One fully-conflicted full-mask load op: banked16 base cost 16.
        // Each extra stream adds ceil(16/16) = 1 cycle of arbitration.
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(16), FULL_MASK)],
        };
        let ct = CompiledTrace::compile(&MemTrace::from_mem_instrs("one", 256, vec![mi]));
        let b16 = MemoryArchKind::banked(16);
        let base = replay_system(&ct, pt(1, 16, b16, 8), u64::MAX).unwrap();
        for p in [2u32, 4, 8] {
            let c = replay_system(&ct, pt(p, 16, b16, 8), u64::MAX).unwrap();
            assert_eq!(c, base + u64::from(p - 1), "p{p}");
        }
        // Fewer banks arbitrate more per stream: ceil(16/4) = 4.
        let b4 = MemoryArchKind::banked(4);
        let base4 = replay_system(&ct, pt(1, 16, b4, 8), u64::MAX).unwrap();
        let c4 = replay_system(&ct, pt(2, 16, b4, 8), u64::MAX).unwrap();
        assert_eq!(c4, base4 + 4);
    }

    #[test]
    fn space_parametric_shape_and_replay_triples() {
        let s = SystemSpace::parametric(8);
        let pts = s.points();
        assert_eq!(pts.len(), 3 * 3 * 9 * 3, "{{1,2,4}} × {{16,32,64}} × nine × 3 caps");
        assert_eq!(s.replay_triples(), 3 * 3 * 9);
        let set: std::collections::HashSet<_> = pts.iter().collect();
        assert_eq!(set.len(), pts.len());
        for p in &pts {
            assert!(p.is_valid());
        }
    }

    #[test]
    fn space_filters_unconstructible_combinations() {
        let s = SystemSpace::new()
            .processors([1, 3, 16])
            .lanes([16, 48])
            .arch(MemoryArchKind::banked(8))
            .capacities_kb([8]);
        assert_eq!(s.points().len(), 1, "only p1x16 survives");
    }

    #[test]
    #[should_panic(expected = "invalid architecture")]
    fn space_rejects_invalid_arch() {
        let _ = SystemSpace::new().arch(MemoryArchKind::Banked {
            banks: 7,
            mapping: BankMapping::Lsb,
        });
    }

    #[test]
    fn explore_system_end_to_end_single_capture() {
        let cache = TraceCache::new();
        let space = SystemSpace::parametric(8);
        let r = explore_system("transpose32", &space, &cache).unwrap();
        assert_eq!(r.captures, 1, "one functional execution for the whole space");
        assert_eq!(r.points_total, space.points().len());
        assert_eq!(r.points_scored, r.points_total);
        assert_eq!(r.replays, space.replay_triples() as u64, "memoized per (P, lanes, mem)");
        assert!(!r.front.is_empty());
        // Warm-cache rerun captures nothing and scores identically.
        let again = explore_system("transpose32", &space, &cache).unwrap();
        assert_eq!(again.captures, 0);
        assert_eq!(again.scored[0].cycles, r.scored[0].cycles);
    }

    #[test]
    fn explore_system_empty_space_is_error() {
        let cache = TraceCache::new();
        assert!(explore_system("transpose32", &SystemSpace::new(), &cache).is_err());
    }

    #[test]
    fn ranked_puts_best_throughput_first() {
        let cache = TraceCache::new();
        let r = explore_system("transpose32", &SystemSpace::parametric(8), &cache).unwrap();
        let ranked = r.ranked();
        for w in ranked.windows(2) {
            let a = w[0].throughput_per_alm.unwrap_or(f64::NEG_INFINITY);
            let b = w[1].throughput_per_alm.unwrap_or(f64::NEG_INFINITY);
            assert!(a >= b);
        }
    }

    #[test]
    fn render_and_json_mention_system_points() {
        let cache = TraceCache::new();
        let space = SystemSpace::new()
            .processors([1, 2])
            .lanes([16, 32])
            .archs([MemoryArchKind::banked(16), MemoryArchKind::mp_4r1w()])
            .capacities_kb([8]);
        let r = explore_system("transpose32", &space, &cache).unwrap();
        let out = r.render();
        assert!(out.contains("system explore: transpose32"));
        assert!(out.contains("Pareto frontier (time × ALMs)"));
        assert!(out.contains("p1x16:banked16@8"));
        assert!(out.contains("1 functional execution"));
        let j = r.to_json();
        assert!(j.contains("\"system\": \"p2x32:banked16@8\""));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
