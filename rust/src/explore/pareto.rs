//! Pareto-frontier container for the two-objective (cycles × ALMs)
//! design space.
//!
//! The dominance rule (DESIGN.md §Explore): point A **dominates** point B
//! when A is no worse on both objectives and strictly better on at least
//! one. The frontier keeps every non-dominated point; exact ties (equal
//! on both objectives) are all retained, which keeps the frontier a
//! well-defined *set* that search strategies can be compared against
//! (`pruning_front_equals_exhaustive_front` in `rust/tests/explore.rs`).

/// A point's position in objective space: total cycles (time) × total
/// processor ALMs (area). Both minimized. Integer-valued on purpose —
/// frontier membership must be exactly reproducible across strategies
/// and platforms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cost {
    pub cycles: u64,
    pub alms: u32,
}

impl Cost {
    /// Strict Pareto dominance: `self` no worse on both objectives,
    /// strictly better on at least one.
    pub fn dominates(self, other: Cost) -> bool {
        self.cycles <= other.cycles
            && self.alms <= other.alms
            && (self.cycles < other.cycles || self.alms < other.alms)
    }
}

/// A Pareto frontier with incremental insert.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront<T> {
    entries: Vec<(Cost, T)>,
}

impl<T> ParetoFront<T> {
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    /// Offer a point. Rejected (returns `false`) when an existing entry
    /// dominates it; otherwise it is admitted and every entry it
    /// dominates is evicted.
    pub fn insert(&mut self, cost: Cost, item: T) -> bool {
        if self.dominated(cost) {
            return false;
        }
        self.entries.retain(|(c, _)| !cost.dominates(*c));
        self.entries.push((cost, item));
        true
    }

    /// Whether some entry strictly dominates `cost`. The pruning search
    /// uses this against a point's *lower-bound* cost: a lower bound that
    /// is already dominated proves the exact point is dominated too.
    pub fn dominated(&self, cost: Cost) -> bool {
        self.entries.iter().any(|(c, _)| c.dominates(cost))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Frontier entries sorted by (cycles, alms) ascending.
    pub fn into_sorted(mut self) -> Vec<(Cost, T)> {
        self.entries.sort_by_key(|(c, _)| (c.cycles, c.alms));
        self.entries
    }

    /// Iterate entries in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = &(Cost, T)> {
        self.entries.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(cycles: u64, alms: u32) -> Cost {
        Cost { cycles, alms }
    }

    #[test]
    fn dominance_rule() {
        assert!(c(10, 10).dominates(c(11, 10)));
        assert!(c(10, 10).dominates(c(10, 11)));
        assert!(c(10, 10).dominates(c(11, 11)));
        assert!(!c(10, 10).dominates(c(10, 10)), "ties do not dominate");
        assert!(!c(10, 12).dominates(c(11, 11)), "trade-offs do not dominate");
        assert!(!c(11, 11).dominates(c(10, 12)));
    }

    #[test]
    fn insert_evicts_dominated() {
        let mut f = ParetoFront::new();
        assert!(f.insert(c(100, 50), "slow-small"));
        assert!(f.insert(c(50, 100), "fast-big"));
        assert_eq!(f.len(), 2, "trade-off pair coexists");
        // A point dominating both replaces both.
        assert!(f.insert(c(40, 40), "winner"));
        assert_eq!(f.len(), 1);
        // A dominated offer is rejected.
        assert!(!f.insert(c(41, 41), "loser"));
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn exact_ties_are_kept() {
        let mut f = ParetoFront::new();
        assert!(f.insert(c(10, 10), "a"));
        assert!(f.insert(c(10, 10), "b"));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn sorted_by_cycles_then_alms() {
        let mut f = ParetoFront::new();
        f.insert(c(30, 10), 0);
        f.insert(c(10, 30), 1);
        f.insert(c(20, 20), 2);
        let sorted = f.into_sorted();
        let order: Vec<u64> = sorted.iter().map(|(c, _)| c.cycles).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn incremental_equals_batch() {
        // Insertion order must not change the final frontier.
        let pts = [
            c(5, 90),
            c(10, 50),
            c(10, 50),
            c(20, 40),
            c(30, 45),
            c(50, 10),
            c(60, 9),
        ];
        let mut orders = vec![pts.to_vec()];
        let mut rev = pts.to_vec();
        rev.reverse();
        orders.push(rev);
        let fronts: Vec<Vec<Cost>> = orders
            .into_iter()
            .map(|order| {
                let mut f = ParetoFront::new();
                for p in order {
                    f.insert(p, ());
                }
                f.into_sorted().into_iter().map(|(c, _)| c).collect()
            })
            .collect();
        assert_eq!(fronts[0], fronts[1]);
    }
}
