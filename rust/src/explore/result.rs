//! Exploration results: ranked scorecards, the Pareto frontier, text
//! rendering and a dependency-free JSON serialization.

use super::eval::PointCost;
use super::pareto::{Cost, ParetoFront};
use super::space::DesignPoint;
use crate::util::fmt::{json_str, with_commas, TextTable};

/// One exactly-evaluated design point.
#[derive(Debug, Clone, Copy)]
pub struct ScoredPoint {
    pub point: DesignPoint,
    pub cycles: u64,
    pub time_us: f64,
    pub footprint_alms: Option<u32>,
    pub sectors: Option<f64>,
    pub perf_per_area: Option<f64>,
}

impl ScoredPoint {
    pub fn new(point: DesignPoint, cost: &PointCost) -> Self {
        Self {
            point,
            cycles: cost.cycles,
            time_us: cost.time_us,
            footprint_alms: cost.alms(),
            sectors: cost.sectors(),
            perf_per_area: cost.perf_per_area(),
        }
    }
}

/// The explorer's output for one workload.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub program: String,
    pub dataset_kb: u32,
    pub strategy: String,
    /// Points in the constrained space.
    pub points_total: usize,
    /// Points exactly evaluated (scorecard size).
    pub points_scored: usize,
    /// Points proved dominated from their lower bound, never scored.
    pub points_culled: usize,
    /// Distinct architecture replays performed.
    pub replays: u64,
    /// Functional executions triggered (0 on a warm trace cache, else 1).
    pub captures: u64,
    /// Exact scores in strategy evaluation order.
    pub scored: Vec<ScoredPoint>,
    /// The cycles × ALMs Pareto frontier, sorted by cycles ascending.
    pub front: Vec<ScoredPoint>,
}

impl ExploreResult {
    /// Build the frontier from a scorecard (unplaceable points — no
    /// footprint — never enter it).
    pub fn frontier_of(scored: &[ScoredPoint]) -> Vec<ScoredPoint> {
        let mut front: ParetoFront<ScoredPoint> = ParetoFront::new();
        for s in scored {
            if let Some(alms) = s.footprint_alms {
                front.insert(Cost { cycles: s.cycles, alms }, *s);
            }
        }
        front.into_sorted().into_iter().map(|(_, s)| s).collect()
    }

    /// Scorecard ranked by wall time, fastest first (cycles are scaled
    /// by each architecture's Fmax, so cycle order and time order can
    /// differ — e.g. 4R-2W's 600 MHz clock); ties break by area.
    pub fn ranked(&self) -> Vec<ScoredPoint> {
        let mut v = self.scored.clone();
        v.sort_by(|a, b| {
            let area_a = a.footprint_alms.unwrap_or(u32::MAX);
            let area_b = b.footprint_alms.unwrap_or(u32::MAX);
            a.time_us.partial_cmp(&b.time_us).unwrap().then(area_a.cmp(&area_b))
        });
        v
    }

    fn row_of(s: &ScoredPoint) -> [String; 6] {
        [
            s.point.arch.label(),
            s.point.capacity_kb.to_string(),
            with_commas(s.cycles),
            format!("{:.2}", s.time_us),
            s.footprint_alms.map(|a| a.to_string()).unwrap_or_else(|| "over cap".into()),
            s.perf_per_area.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into()),
        ]
    }

    /// Full text report: summary, frontier, top of the ranked scorecard.
    pub fn render(&self) -> String {
        let mut out = format!(
            "explore: {} ({} KB dataset, strategy {})\n\
             space: {} points, {} scored, {} culled — {} arch replays, \
             {} functional execution(s)\n\nPareto frontier (cycles × ALMs):\n",
            self.program,
            self.dataset_kb,
            self.strategy,
            self.points_total,
            self.points_scored,
            self.points_culled,
            self.replays,
            self.captures,
        );
        let mut t =
            TextTable::new(["memory", "cap KB", "cycles", "time (us)", "ALMs", "perf/area"]);
        for s in &self.front {
            t.row(Self::row_of(s));
        }
        out.push_str(&t.render());
        let ranked = self.ranked();
        let top = ranked.len().min(10);
        out.push_str(&format!("\ntop {top} of {} scored points by time:\n", ranked.len()));
        let mut t =
            TextTable::new(["memory", "cap KB", "cycles", "time (us)", "ALMs", "perf/area"]);
        for s in &ranked[..top] {
            t.row(Self::row_of(s));
        }
        out.push_str(&t.render());
        out
    }

    /// Serialize to JSON (hand-rolled; the crate is dependency-free).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"program\": {},\n", json_str(&self.program)));
        out.push_str(&format!("  \"dataset_kb\": {},\n", self.dataset_kb));
        out.push_str(&format!("  \"strategy\": {},\n", json_str(&self.strategy)));
        out.push_str(&format!("  \"points_total\": {},\n", self.points_total));
        out.push_str(&format!("  \"points_scored\": {},\n", self.points_scored));
        out.push_str(&format!("  \"points_culled\": {},\n", self.points_culled));
        out.push_str(&format!("  \"replays\": {},\n", self.replays));
        out.push_str(&format!("  \"captures\": {},\n", self.captures));
        out.push_str("  \"front\": ");
        out.push_str(&json_points(&self.front, "  "));
        out.push_str(",\n  \"scorecard\": ");
        out.push_str(&json_points(&self.scored, "  "));
        out.push_str("\n}\n");
        out
    }
}

fn json_points(points: &[ScoredPoint], indent: &str) -> String {
    if points.is_empty() {
        return "[]".to_string();
    }
    let rows: Vec<String> = points
        .iter()
        .map(|s| {
            format!(
                "{indent}  {{\"memory\": {}, \"capacity_kb\": {}, \"cycles\": {}, \
                 \"time_us\": {:.4}, \"alms\": {}, \"sectors\": {}, \"perf_per_area\": {}}}",
                json_str(&s.point.arch.label()),
                s.point.capacity_kb,
                s.cycles,
                s.time_us,
                s.footprint_alms.map(|a| a.to_string()).unwrap_or_else(|| "null".into()),
                s.sectors.map(|v| format!("{v:.4}")).unwrap_or_else(|| "null".into()),
                s.perf_per_area.map(|v| format!("{v:.6}")).unwrap_or_else(|| "null".into()),
            )
        })
        .collect();
    format!("[\n{}\n{indent}]", rows.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;

    fn sp(arch: MemoryArchKind, cap: u32, cycles: u64, alms: Option<u32>) -> ScoredPoint {
        ScoredPoint {
            point: DesignPoint { arch, capacity_kb: cap },
            cycles,
            time_us: cycles as f64 / arch.fmax_mhz(),
            footprint_alms: alms,
            sectors: alms.map(|a| a as f64 / 16_640.0),
            perf_per_area: alms.map(|a| 1.0 / (cycles as f64 * a as f64)),
        }
    }

    fn sample() -> ExploreResult {
        let scored = vec![
            sp(MemoryArchKind::banked(16), 64, 1000, Some(20_000)),
            sp(MemoryArchKind::banked(4), 64, 3000, Some(12_000)),
            sp(MemoryArchKind::banked(8), 64, 2000, Some(30_000)), // dominated
            sp(MemoryArchKind::mp_4r1w(), 500, 900, None),         // unplaceable
        ];
        let front = ExploreResult::frontier_of(&scored);
        ExploreResult {
            program: "transpose32".into(),
            dataset_kb: 8,
            strategy: "exhaustive".into(),
            points_total: 4,
            points_scored: 4,
            points_culled: 0,
            replays: 4,
            captures: 1,
            scored,
            front,
        }
    }

    #[test]
    fn frontier_excludes_dominated_and_unplaceable() {
        let r = sample();
        assert_eq!(r.front.len(), 2);
        let labels: Vec<String> = r.front.iter().map(|s| s.point.arch.label()).collect();
        assert_eq!(labels, vec!["16 Banks", "4 Banks"]);
        // Sorted by cycles ascending.
        assert!(r.front[0].cycles <= r.front[1].cycles);
    }

    #[test]
    fn render_mentions_summary_and_frontier() {
        let out = sample().render();
        assert!(out.contains("Pareto frontier"));
        assert!(out.contains("1 functional execution"));
        assert!(out.contains("16 Banks"));
        assert!(out.contains("over cap"));
    }

    #[test]
    fn json_is_well_formed_enough() {
        let j = sample().to_json();
        assert!(j.starts_with("{\n"));
        assert!(j.trim_end().ends_with('}'));
        assert!(j.contains("\"points_total\": 4"));
        assert!(j.contains("\"alms\": null"), "unplaceable point serializes null");
        assert_eq!(j.matches("\"memory\":").count(), 2 + 4);
        // Balanced braces/brackets (cheap structural check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn json_string_escaping() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
    }

    #[test]
    fn ranked_orders_by_time() {
        let r = sample();
        let ranked = r.ranked();
        for w in ranked.windows(2) {
            assert!(w[0].time_us <= w[1].time_us);
        }
    }
}
