//! Trace-driven design-space exploration (DESIGN.md §Explore).
//!
//! The paper's conclusion (§VII) is that the right memory architecture
//! depends on dataset size and access pattern, and that the FPGA's one
//! structural advantage is being able to *change* the memory to suit the
//! design. This subsystem operationalizes that: given a workload, it
//! functionally executes it **once** (through the shared
//! [`crate::coordinator::job::TraceCache`]), then searches a parametric
//! space of memory architectures — bank count 2–32 × bank mapping
//! (LSB / shifted-Offset family / XOR) × multiport port configurations ×
//! capacity — by charging the captured trace against each candidate's
//! timing model and folding in the [`crate::area::footprint`] ALM model.
//! The output is the Pareto frontier of cycles × footprint plus ranked
//! scorecards ([`result::ExploreResult`]).
//!
//! Components:
//!
//! - [`space::DesignSpace`] — ordered parametric space builder with
//!   named constraint predicates (capacity rooflines, dataset floor);
//! - [`eval::Evaluator`] — cached-trace point scoring (memoized per-arch
//!   replay; a capture counter proves single functional execution) and
//!   the O(1)-per-arch lower-bound cost model;
//! - [`strategy`] — the [`strategy::SearchStrategy`] contract with
//!   [`strategy::Exhaustive`] grid search and dominance-based
//!   [`strategy::SuccessiveHalving`] pruning (provably frontier-exact);
//! - [`pareto::ParetoFront`] — incremental two-objective frontier;
//! - [`result::ExploreResult`] — scorecards, frontier, text + JSON;
//! - [`system`] — the system-scale extension: {processors × lanes ×
//!   memory × capacity} points scored under an inter-core contention +
//!   Fmax + throughput-per-ALM model, from the same single capture.
//!
//! The advisor ([`crate::coordinator::advisor`]) is a thin consumer: the
//! paper's nine architectures plus the XOR extensions are just one small
//! `DesignSpace`.

pub mod eval;
pub mod pareto;
pub mod result;
pub mod space;
pub mod strategy;
pub mod system;

pub use eval::{Evaluator, PointCost};
pub use pareto::{Cost, ParetoFront};
pub use result::{ExploreResult, ScoredPoint};
pub use space::{DesignPoint, DesignSpace};
pub use strategy::{Exhaustive, SearchStrategy, SuccessiveHalving};
pub use system::{
    explore_system, ScoredSystemPoint, SystemEvaluator, SystemExploreResult, SystemPoint,
    SystemSpace,
};

use crate::coordinator::job::TraceCache;
use crate::coordinator::runner::SweepRunner;
use crate::sim::exec::SimError;

/// Explore `space` for the named workload: one functional execution (at
/// most — zero on a warm `cache`), one trace replay per distinct
/// architecture the strategy pays for, one footprint lookup per point.
///
/// **Deprecated wiring path** for external consumers: prefer a
/// [`crate::service::SimtEngine`] session (`Request::Explore`), which
/// supplies the runner and a persistent session cache — an exploration
/// after a sweep of the same workload captures nothing.
pub fn explore(
    program: &str,
    space: &DesignSpace,
    strategy: &dyn SearchStrategy,
    runner: &SweepRunner,
    cache: &TraceCache,
) -> Result<ExploreResult, SimError> {
    let points = space.points();
    if points.is_empty() {
        return Err(SimError::BadProgram(format!(
            "design space for '{program}' is empty (constraints: {:?})",
            space.constraint_names()
        )));
    }
    let eval = Evaluator::new(program, cache)?;
    let outcome = strategy.search(&points, &eval, runner)?;
    // The subsystem's defining invariant: scoring N points never costs
    // more than one functional execution.
    assert!(
        eval.captures() <= 1,
        "explore must functionally execute at most once (got {})",
        eval.captures()
    );
    let scored: Vec<ScoredPoint> = outcome
        .scored
        .iter()
        .map(|(p, c)| ScoredPoint::new(*p, c))
        .collect();
    let front = ExploreResult::frontier_of(&scored);
    Ok(ExploreResult {
        program: program.to_string(),
        dataset_kb: eval.dataset_kb(),
        strategy: strategy.name().to_string(),
        points_total: points.len(),
        points_scored: scored.len(),
        points_culled: outcome.culled,
        replays: eval.replays(),
        captures: eval.captures(),
        scored,
        front,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explore_end_to_end_small() {
        let space = DesignSpace::from_archs(
            [
                crate::mem::arch::MemoryArchKind::mp_4r1w(),
                crate::mem::arch::MemoryArchKind::banked(16),
                crate::mem::arch::MemoryArchKind::banked(4),
            ],
            8,
        );
        let cache = TraceCache::new();
        let r = explore("transpose32", &space, &Exhaustive, &SweepRunner::new(2), &cache).unwrap();
        assert_eq!(r.points_total, 3);
        assert_eq!(r.points_scored, 3);
        assert_eq!(r.captures, 1);
        assert_eq!(r.replays, 3);
        assert!(!r.front.is_empty());
    }

    #[test]
    fn empty_space_is_error() {
        let space = DesignSpace::new().constraint("nothing", |_| false).capacities_kb([8]);
        let cache = TraceCache::new();
        assert!(explore("transpose32", &space, &Exhaustive, &SweepRunner::new(1), &cache).is_err());
    }

    #[test]
    fn warm_cache_reports_zero_captures() {
        let cache = TraceCache::new();
        let space = DesignSpace::from_archs([crate::mem::arch::MemoryArchKind::banked(8)], 8);
        let runner = SweepRunner::new(1);
        let a = explore("transpose32", &space, &Exhaustive, &runner, &cache).unwrap();
        assert_eq!(a.captures, 1);
        let b = explore("transpose32", &space, &Exhaustive, &runner, &cache).unwrap();
        assert_eq!(b.captures, 0, "trace reused across explorations");
        assert_eq!(a.front[0].cycles, b.front[0].cycles);
    }
}
