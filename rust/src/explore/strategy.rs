//! Pluggable search strategies over a design space.
//!
//! The contract (DESIGN.md §Explore): a strategy receives the enumerated
//! points and the shared [`Evaluator`] and returns exact scores for a
//! subset of points that is guaranteed to contain the full Pareto
//! frontier of the *whole* space. Exhaustive search scores everything;
//! the successive-halving strategy culls points whose cheap
//! **lower-bound** cost is already strictly dominated by an exactly
//! evaluated point — sound because a dominated lower bound proves the
//! exact cost (which can only be worse on the time axis, and is known
//! exactly on the area axis) is dominated too. The two strategies
//! therefore produce identical frontiers, property-tested in
//! `rust/tests/explore.rs`.

use super::eval::{Evaluator, PointCost};
use super::pareto::ParetoFront;
use super::space::DesignPoint;
use crate::coordinator::runner::SweepRunner;
use crate::mem::arch::MemoryArchKind;
use crate::sim::exec::SimError;

/// What a strategy hands back: exact scores (in evaluation order) plus
/// how many points it proved dominated without scoring them.
#[derive(Debug)]
pub struct SearchOutcome {
    pub scored: Vec<(DesignPoint, PointCost)>,
    pub culled: usize,
}

/// A search strategy over an enumerated design space.
pub trait SearchStrategy: Sync {
    fn name(&self) -> &'static str;

    fn search(
        &self,
        points: &[DesignPoint],
        eval: &Evaluator,
        runner: &SweepRunner,
    ) -> Result<SearchOutcome, SimError>;
}

/// Batch-replay the distinct architectures of `points` that have not
/// been memoized yet: the evaluator chunks the slate and charges each
/// chunk in a single compiled-trace walk on the worker pool
/// ([`Evaluator::replay_batch`], DESIGN.md §Replay).
fn replay_batch(
    points: &[DesignPoint],
    eval: &Evaluator,
    runner: &SweepRunner,
) -> Result<(), SimError> {
    let archs: Vec<MemoryArchKind> = points.iter().map(|p| p.arch).collect();
    eval.replay_batch(&archs, runner)
}

/// Exhaustive grid search: every point scored.
#[derive(Debug, Default, Clone, Copy)]
pub struct Exhaustive;

impl SearchStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn search(
        &self,
        points: &[DesignPoint],
        eval: &Evaluator,
        runner: &SweepRunner,
    ) -> Result<SearchOutcome, SimError> {
        replay_batch(points, eval, runner)?;
        let scored = points
            .iter()
            .map(|p| eval.score(p).map(|c| (*p, c)))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SearchOutcome { scored, culled: 0 })
    }
}

/// Dominance-based successive halving.
///
/// Points are ranked by their cheap lower-bound cost (best first), then
/// evaluated in waves of half the surviving population. After each wave
/// the frontier of exactly-scored points culls every pending point whose
/// lower bound it strictly dominates — the promising half is always
/// paid for exactly, the doomed tail is proved doomed for free.
#[derive(Debug, Clone, Copy)]
pub struct SuccessiveHalving {
    /// Smallest wave size (avoids long tails of tiny waves).
    pub min_wave: usize,
}

impl Default for SuccessiveHalving {
    fn default() -> Self {
        Self { min_wave: 8 }
    }
}

impl SearchStrategy for SuccessiveHalving {
    fn name(&self) -> &'static str {
        "successive-halving"
    }

    fn search(
        &self,
        points: &[DesignPoint],
        eval: &Evaluator,
        runner: &SweepRunner,
    ) -> Result<SearchOutcome, SimError> {
        let bounds: Vec<_> = points.iter().map(|p| eval.lower_bound(p)).collect();
        let mut pending: Vec<usize> = (0..points.len()).collect();
        // Best lower bound first, index as the deterministic tie-break.
        pending.sort_by_key(|&i| (bounds[i].cycles, bounds[i].alms, i));

        let mut front: ParetoFront<()> = ParetoFront::new();
        let mut scored = Vec::with_capacity(points.len());
        let mut culled = 0usize;
        while !pending.is_empty() {
            let take = pending.len().div_ceil(2).max(self.min_wave).min(pending.len());
            let wave: Vec<DesignPoint> =
                pending.drain(..take).map(|i| points[i]).collect();
            replay_batch(&wave, eval, runner)?;
            for p in wave {
                let cost = eval.score(&p)?;
                if let Some(obj) = cost.objective() {
                    front.insert(obj, ());
                }
                scored.push((p, cost));
            }
            pending.retain(|&i| {
                let doomed = front.dominated(bounds[i]);
                culled += doomed as usize;
                !doomed
            });
        }
        Ok(SearchOutcome { scored, culled })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::TraceCache;
    use crate::explore::space::DesignSpace;

    fn run(strategy: &dyn SearchStrategy, space: &DesignSpace) -> SearchOutcome {
        let cache = TraceCache::new();
        let eval = Evaluator::new("transpose32", &cache).unwrap();
        let runner = SweepRunner::new(2);
        strategy.search(&space.points(), &eval, &runner).unwrap()
    }

    #[test]
    fn exhaustive_scores_everything() {
        let space = DesignSpace::parametric(8);
        let out = run(&Exhaustive, &space);
        assert_eq!(out.scored.len(), space.points().len());
        assert_eq!(out.culled, 0);
    }

    #[test]
    fn halving_covers_or_culls_everything() {
        let space = DesignSpace::parametric(8);
        let out = run(&SuccessiveHalving { min_wave: 4 }, &space);
        assert_eq!(out.scored.len() + out.culled, space.points().len());
    }

    #[test]
    fn strategy_error_propagates() {
        let cache = TraceCache::new();
        assert!(Evaluator::new("bogus", &cache).is_err());
    }
}
