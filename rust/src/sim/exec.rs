//! Functional execution core — the architecture-independent half of the
//! decoupled simulator (DESIGN.md §Two-phase).
//!
//! A program's *functional* behaviour (decode, ALU results, the branch
//! directions taken, and the address stream every memory instruction
//! emits) is identical across all nine shared-memory architectures — the
//! `all_archs_functionally_identical_on_random_programs` property test is
//! the executable statement of that fact. Only memory *timing* differs.
//!
//! [`execute`] therefore runs a program **once**, against any word-level
//! memory ([`ExecMemory`]), and emits a complete [`MemTrace`]: the full
//! per-instruction memory-operation stream (addresses + lane masks +
//! load classification + blocking flags) interleaved with the exact
//! ALU/issue cycle charges accumulated between memory instructions. The
//! trace is everything the timing replayer ([`crate::sim::replay`]) needs
//! to reproduce the coupled simulator's [`crate::sim::stats::RunReport`]
//! bit for bit on *any* architecture — so an N-architecture sweep
//! executes each program once and replays timing N times.

use super::regfile::RegFile;
use crate::isa::inst::Instruction;
use crate::isa::opcode::{OpClass, Opcode};
use crate::isa::program::Program;
use crate::mem::arch::{OpKind, SharedMemory};
use crate::mem::{LaneMask, LANES};
use std::ops::Range;

/// Simulation errors (all carry the faulting PC where one exists).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// A lane addressed past the end of shared memory.
    InvalidAddress { pc: usize, thread: u32, addr: u32, words: usize },
    /// Threads disagreed on a branch direction.
    DivergentBranch { pc: usize },
    /// Branch target outside the program.
    BadJumpTarget { pc: usize, target: u16 },
    /// The run exceeded `max_cycles` (runaway loop guard).
    CycleLimit { limit: u64 },
    /// The trace exceeded `max_trace_ops` memory operations (runaway
    /// loop guard on capture *memory*: a loop containing a store would
    /// otherwise buffer operations until the cycle guard trips).
    TraceLimit { ops: u64 },
    /// Execution fell off the end of the instruction stream.
    MissingHalt,
    /// Program binary failed to decode.
    BadProgram(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::InvalidAddress { pc, thread, addr, words } => write!(
                f,
                "pc {pc}: thread {thread} addressed {addr} beyond shared memory ({words} words)"
            ),
            SimError::DivergentBranch { pc } => {
                write!(f, "pc {pc}: divergent branch (threads disagree)")
            }
            SimError::BadJumpTarget { pc, target } => {
                write!(f, "pc {pc}: jump target {target} outside program")
            }
            SimError::CycleLimit { limit } => write!(f, "exceeded cycle limit {limit}"),
            SimError::TraceLimit { ops } => write!(
                f,
                "trace exceeded {ops} memory operations (raise ExecParams::max_trace_ops \
                 for legitimately huge programs)"
            ),
            SimError::MissingHalt => write!(f, "execution fell off the end (missing halt)"),
            SimError::BadProgram(m) => write!(f, "bad program: {m}"),
        }
    }
}

impl std::error::Error for SimError {}

/// A binary image that fails to decode is a bad program — the typed
/// ISA-layer error folds into the simulator's, so callers loading
/// binaries (`Program::decode` + `run_program`) can use `?` throughout
/// and the service layer sees one error lineage.
impl From<crate::isa::program::DecodeError> for SimError {
    fn from(e: crate::isa::program::DecodeError) -> Self {
        SimError::BadProgram(e.to_string())
    }
}

/// Word-addressed functional memory — the only thing the execution core
/// needs from a memory. Implemented by [`FlatMemory`] (the cheap backing
/// store for trace capture) and by the architectural memories (so the
/// [`crate::sim::machine::Machine`] facade executes against the same
/// image its `mem()` accessor exposes).
pub trait ExecMemory {
    /// Capacity in 32-bit words (the bounds-check limit).
    fn words(&self) -> usize;
    /// Functional single-word read.
    fn read_word(&self, addr: u32) -> u32;
    /// Functional single-word write.
    fn write_word(&mut self, addr: u32, value: u32);
}

/// A flat word array: the functional memory used when capturing a trace
/// without instantiating any shared-memory architecture.
#[derive(Debug, Clone)]
pub struct FlatMemory {
    words: Vec<u32>,
}

impl FlatMemory {
    pub fn new(words: usize) -> Self {
        Self { words: vec![0u32; words] }
    }

    /// Snapshot of the full image (functional-equivalence checks).
    pub fn image(&self) -> &[u32] {
        &self.words
    }
}

impl ExecMemory for FlatMemory {
    fn words(&self) -> usize {
        self.words.len()
    }

    fn read_word(&self, addr: u32) -> u32 {
        self.words[addr as usize]
    }

    fn write_word(&mut self, addr: u32, value: u32) {
        self.words[addr as usize] = value;
    }
}

impl ExecMemory for Box<dyn SharedMemory> {
    fn words(&self) -> usize {
        SharedMemory::words(&**self)
    }

    fn read_word(&self, addr: u32) -> u32 {
        (**self).peek(addr)
    }

    fn write_word(&mut self, addr: u32, value: u32) {
        (**self).poke(addr, value);
    }
}

/// Classification of one executed load, for the Table III D-load /
/// TW-load split. Decided by the (architecture-independent) twiddle
/// address region of the workload, so it lives in the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadClass {
    Data,
    Twiddle,
}

/// What one traced memory instruction was.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemAccessKind {
    /// `ld`, classified against the twiddle region.
    Load(LoadClass),
    /// `st` (blocking) or `stnb` (non-blocking).
    Store { blocking: bool },
}

/// One executed memory instruction: its kind and each 16-lane operation's
/// addresses + active-lane mask, in issue order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemInstr {
    pub kind: MemAccessKind,
    pub ops: Vec<([u32; LANES], LaneMask)>,
}

impl MemInstr {
    /// Read/write direction (what the §III-A controllers care about).
    pub fn op_kind(&self) -> OpKind {
        match self.kind {
            MemAccessKind::Load(_) => OpKind::Read,
            MemAccessKind::Store { .. } => OpKind::Write,
        }
    }
}

/// Exact ALU/issue cycle charges accumulated between two memory
/// instructions. These are architecture-independent: ALU classes cost one
/// cycle per 16-thread operation on every memory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AluCharges {
    /// Register-register integer cycles ("INT OPs").
    pub int_cycles: u64,
    /// Immediate-op cycles ("Immediate OPs").
    pub imm_cycles: u64,
    /// FP32 cycles ("FP OPs").
    pub fp_cycles: u64,
    /// Control/misc cycles ("Other OPs") — nop/jmp/bnz/tid.
    pub other_cycles: u64,
    /// 16-wide operations issued (ALU classes + tid).
    pub operations: u64,
    /// Dynamic instructions executed.
    pub instructions: u64,
}

impl AluCharges {
    /// Clock advance these charges represent.
    pub fn cycles(&self) -> u64 {
        self.int_cycles + self.imm_cycles + self.fp_cycles + self.other_cycles
    }
}

/// One trace segment: the ALU charges *preceding* a memory instruction,
/// then the memory instruction itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSegment {
    pub before: AluCharges,
    pub mem: MemInstr,
}

/// The complete, lossless record of one functional execution — the input
/// to the timing replayer. Unlike the old optional `MemTraceInstr`
/// capture, a `MemTrace` always carries every memory operation *and* the
/// interleaved ALU accounting, so timing on any architecture can be
/// reconstructed without re-executing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemTrace {
    /// Program name (propagated into replayed reports).
    pub program: String,
    /// Thread-block size.
    pub threads: u32,
    /// Shared-memory capacity (words) the program executed against —
    /// part of the functional execution, so replayers can build a
    /// matching memory without re-materializing the workload.
    pub mem_words: usize,
    /// Memory instructions in program order, each with its preceding ALU
    /// charges.
    pub segments: Vec<TraceSegment>,
    /// ALU charges after the last memory instruction, up to (but not
    /// including) `halt`.
    pub tail: AluCharges,
}

impl MemTrace {
    /// Build a trace from bare memory instructions (no ALU work) — handy
    /// for synthetic traces in tests and the analytical oracle. Capacity
    /// defaults to 64 Ki words (the [`crate::sim::config`] default).
    pub fn from_mem_instrs(
        program: impl Into<String>,
        threads: u32,
        instrs: Vec<MemInstr>,
    ) -> Self {
        Self {
            program: program.into(),
            threads,
            mem_words: 65_536,
            segments: instrs
                .into_iter()
                .map(|mem| TraceSegment { before: AluCharges::default(), mem })
                .collect(),
            tail: AluCharges::default(),
        }
    }

    /// The memory instructions in program order.
    pub fn mem_instrs(&self) -> impl Iterator<Item = &MemInstr> {
        self.segments.iter().map(|s| &s.mem)
    }

    /// Total 16-lane memory operations across the trace.
    pub fn mem_op_count(&self) -> u64 {
        self.mem_instrs().map(|i| i.ops.len() as u64).sum()
    }
}

/// Architecture-independent execution parameters.
#[derive(Debug, Clone)]
pub struct ExecParams {
    /// Address range whose loads are classified as twiddle loads
    /// ("TW Load" rows of Table III). `None` classifies every load as a
    /// data load.
    pub tw_region: Option<Range<u32>>,
    /// Runaway-loop guard, checked against an architecture-independent
    /// *lower bound* on the clock (every architecture charges at least
    /// one cycle per operation). The replayer re-checks against the real
    /// clock of its architecture.
    pub max_cycles: u64,
    /// Companion guard on trace *memory*: maximum 16-lane memory
    /// operations the capture may buffer. The cycle guard alone would
    /// let a runaway loop containing a store allocate
    /// `O(max_cycles)` trace segments before tripping; this caps the
    /// capture at a size (~1–2 GB at the default) far above any real
    /// workload (the paper's largest benchmark records ~4k operations).
    pub max_trace_ops: u64,
}

impl ExecParams {
    /// Default trace-size guard: 2^24 ≈ 16.8M operations.
    pub const DEFAULT_MAX_TRACE_OPS: u64 = 1 << 24;
}

impl Default for ExecParams {
    fn default() -> Self {
        Self {
            tw_region: None,
            max_cycles: 2_000_000_000,
            max_trace_ops: Self::DEFAULT_MAX_TRACE_OPS,
        }
    }
}

/// Run `program` to `halt` against `mem`, returning the complete trace.
///
/// The program is round-tripped through its binary encoding first — the
/// execution core consumes what the assembler would produce, keeping the
/// decode path honest.
pub fn execute<M: ExecMemory>(
    program: &Program,
    mem: &mut M,
    params: &ExecParams,
) -> Result<MemTrace, SimError> {
    let words = program.encode();
    let insts: Vec<Instruction> = words
        .iter()
        .enumerate()
        .map(|(pc, &w)| {
            Instruction::decode(w).ok_or_else(|| SimError::BadProgram(format!("pc {pc}")))
        })
        .collect::<Result<_, _>>()?;

    let threads = program.threads;
    let mut regs = RegFile::new(threads);
    let n_ops = (threads as u64).div_ceil(LANES as u64);
    let mem_words = mem.words();

    let mut segments = Vec::new();
    let mut charges = AluCharges::default();
    // Lower bound on the clock of *any* architecture (ALU cycles are
    // exact; memory operations cost at least one cycle each).
    let mut clock_floor = 0u64;
    // Memory operations buffered so far (the capture-size guard).
    let mut trace_ops = 0u64;

    let mut pc = 0usize;
    loop {
        if pc >= insts.len() {
            return Err(SimError::MissingHalt);
        }
        if clock_floor > params.max_cycles {
            return Err(SimError::CycleLimit { limit: params.max_cycles });
        }
        let inst = insts[pc];
        match inst.op.class() {
            OpClass::Int | OpClass::Imm | OpClass::Fp => {
                exec_alu(&mut regs, inst, threads);
                match inst.op.class() {
                    OpClass::Int => charges.int_cycles += n_ops,
                    OpClass::Imm => charges.imm_cycles += n_ops,
                    OpClass::Fp => charges.fp_cycles += n_ops,
                    _ => unreachable!(),
                }
                charges.operations += n_ops;
                charges.instructions += 1;
                clock_floor += n_ops;
                pc += 1;
            }
            OpClass::Other => match inst.op {
                Opcode::Halt => {
                    clock_floor += 1;
                    break;
                }
                Opcode::Nop => {
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    pc += 1;
                }
                Opcode::Jmp => {
                    let target = inst.imm as usize;
                    if target >= insts.len() {
                        return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                    }
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    pc = target;
                }
                Opcode::Bnz => {
                    let taken = regs.get(0, inst.rd) != 0;
                    for t in 1..threads {
                        if (regs.get(t, inst.rd) != 0) != taken {
                            return Err(SimError::DivergentBranch { pc });
                        }
                    }
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    if taken {
                        let target = inst.imm as usize;
                        if target >= insts.len() {
                            return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                        }
                        pc = target;
                    } else {
                        pc += 1;
                    }
                }
                Opcode::Tid => {
                    for t in 0..threads {
                        regs.set(t, inst.rd, t);
                    }
                    charges.other_cycles += n_ops;
                    charges.operations += n_ops;
                    charges.instructions += 1;
                    clock_floor += n_ops;
                    pc += 1;
                }
                _ => unreachable!("all Other opcodes handled"),
            },
            OpClass::Load => {
                let mi = exec_load(&mut regs, inst, threads, pc, mem, mem_words, params)?;
                clock_floor += mi.ops.len() as u64;
                trace_ops += mi.ops.len() as u64;
                if trace_ops > params.max_trace_ops {
                    return Err(SimError::TraceLimit { ops: trace_ops });
                }
                segments.push(TraceSegment { before: std::mem::take(&mut charges), mem: mi });
                pc += 1;
            }
            OpClass::Store => {
                let mi = exec_store(&mut regs, inst, threads, pc, mem, mem_words)?;
                clock_floor += mi.ops.len() as u64;
                trace_ops += mi.ops.len() as u64;
                if trace_ops > params.max_trace_ops {
                    return Err(SimError::TraceLimit { ops: trace_ops });
                }
                segments.push(TraceSegment { before: std::mem::take(&mut charges), mem: mi });
                pc += 1;
            }
        }
    }

    Ok(MemTrace { program: program.name.clone(), threads, mem_words, segments, tail: charges })
}

/// Execute an ALU instruction for every thread.
///
/// §Perf: the opcode dispatch is hoisted *outside* the thread loop (one
/// specialized tight loop per opcode) — this function is the simulator's
/// hottest path (≈27% before the split; see EXPERIMENTS.md §Perf).
fn exec_alu(regs: &mut RegFile, inst: Instruction, threads: u32) {
    use Opcode::*;
    let imm = inst.imm as u32;
    let (rd, ra, rb) = (inst.rd, inst.ra, inst.rb);
    macro_rules! int_rr {
        ($f:expr) => {
            for t in 0..threads {
                let v = $f(regs.get(t, ra), regs.get(t, rb));
                regs.set(t, rd, v);
            }
        };
    }
    macro_rules! int_ri {
        ($f:expr) => {
            for t in 0..threads {
                let v = $f(regs.get(t, ra));
                regs.set(t, rd, v);
            }
        };
    }
    macro_rules! fp_rr {
        ($f:expr) => {
            for t in 0..threads {
                let v = $f(regs.get_f32(t, ra), regs.get_f32(t, rb));
                regs.set_f32(t, rd, v);
            }
        };
    }
    match inst.op {
        Iadd => int_rr!(|a: u32, b: u32| a.wrapping_add(b)),
        Isub => int_rr!(|a: u32, b: u32| a.wrapping_sub(b)),
        Imul => int_rr!(|a: u32, b: u32| a.wrapping_mul(b)),
        Iand => int_rr!(|a, b| a & b),
        Ior => int_rr!(|a, b| a | b),
        Ixor => int_rr!(|a, b| a ^ b),
        Ishl => int_rr!(|a: u32, b: u32| a << (b & 31)),
        Ishr => int_rr!(|a: u32, b: u32| a >> (b & 31)),
        Iaddi => int_ri!(|a: u32| a.wrapping_add(sign_extend(imm))),
        Imuli => int_ri!(|a: u32| a.wrapping_mul(sign_extend(imm))),
        Iandi => int_ri!(|a| a & imm),
        Iori => int_ri!(|a| a | imm),
        Ixori => int_ri!(|a| a ^ imm),
        Ishli => int_ri!(|a: u32| a << (imm & 31)),
        Ishri => int_ri!(|a: u32| a >> (imm & 31)),
        Ldi => {
            for t in 0..threads {
                regs.set(t, rd, imm);
            }
        }
        Lui => {
            for t in 0..threads {
                let low = regs.get(t, rd) & 0xFFFF;
                regs.set(t, rd, (imm << 16) | low);
            }
        }
        Fadd => fp_rr!(|a, b| a + b),
        Fsub => fp_rr!(|a, b| a - b),
        Fmul => fp_rr!(|a, b| a * b),
        Fma => {
            for t in 0..threads {
                let acc = regs.get_f32(t, rd);
                let v = regs.get_f32(t, ra).mul_add(regs.get_f32(t, rb), acc);
                regs.set_f32(t, rd, v);
            }
        }
        Fneg => {
            for t in 0..threads {
                let v = -regs.get_f32(t, ra);
                regs.set_f32(t, rd, v);
            }
        }
        Itof => {
            for t in 0..threads {
                let v = regs.get(t, ra) as i32 as f32;
                regs.set_f32(t, rd, v);
            }
        }
        _ => unreachable!("not an ALU opcode"),
    }
}

/// Gather one warp's addresses from register `ra`, with bounds checks.
fn warp_addrs(
    regs: &RegFile,
    ra: u8,
    warp: u32,
    threads: u32,
    pc: usize,
    mem_words: usize,
) -> Result<([u32; LANES], LaneMask), SimError> {
    let base_t = warp * LANES as u32;
    let mut addrs = [0u32; LANES];
    let mut mask: LaneMask = 0;
    for lane in 0..LANES {
        let t = base_t + lane as u32;
        if t >= threads {
            break;
        }
        let addr = regs.get(t, ra);
        if addr as usize >= mem_words {
            return Err(SimError::InvalidAddress { pc, thread: t, addr, words: mem_words });
        }
        addrs[lane] = addr;
        mask |= 1 << lane;
    }
    Ok((addrs, mask))
}

/// Classify a load by its addresses (Table III splits data loads from
/// twiddle loads). Matches the coupled simulator: the first active lane
/// of the first warp decides.
fn classify_load(
    addrs: &[u32; LANES],
    mask: LaneMask,
    tw_region: &Option<Range<u32>>,
) -> LoadClass {
    if let Some(region) = tw_region {
        if mask != 0 {
            let lane = mask.trailing_zeros() as usize;
            if region.contains(&addrs[lane]) {
                return LoadClass::Twiddle;
            }
        }
    }
    LoadClass::Data
}

fn exec_load<M: ExecMemory>(
    regs: &mut RegFile,
    inst: Instruction,
    threads: u32,
    pc: usize,
    mem: &mut M,
    mem_words: usize,
    params: &ExecParams,
) -> Result<MemInstr, SimError> {
    let n_warps = (threads as usize).div_ceil(LANES);
    let mut ops = Vec::with_capacity(n_warps);
    let mut class = LoadClass::Data;
    for w in 0..n_warps {
        let (addrs, mask) = warp_addrs(regs, inst.ra, w as u32, threads, pc, mem_words)?;
        if w == 0 {
            class = classify_load(&addrs, mask, &params.tw_region);
        }
        let base_t = w as u32 * LANES as u32;
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            regs.set(base_t + lane as u32, inst.rd, mem.read_word(addrs[lane]));
        }
        ops.push((addrs, mask));
    }
    Ok(MemInstr { kind: MemAccessKind::Load(class), ops })
}

fn exec_store<M: ExecMemory>(
    regs: &mut RegFile,
    inst: Instruction,
    threads: u32,
    pc: usize,
    mem: &mut M,
    mem_words: usize,
) -> Result<MemInstr, SimError> {
    let n_warps = (threads as usize).div_ceil(LANES);
    let blocking = inst.op == Opcode::St;
    let mut ops = Vec::with_capacity(n_warps);
    for w in 0..n_warps {
        let (addrs, mask) = warp_addrs(regs, inst.ra, w as u32, threads, pc, mem_words)?;
        let base_t = w as u32 * LANES as u32;
        // Lanes commit in ascending order: on address collisions the
        // highest lane writes last and wins — the same resolution as the
        // banked arbiters and the multiport port arbitration.
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            mem.write_word(addrs[lane], regs.get(base_t + lane as u32, inst.rb));
        }
        ops.push((addrs, mask));
    }
    Ok(MemInstr { kind: MemAccessKind::Store { blocking }, ops })
}

/// 16-bit immediates are sign-extended for the arithmetic immediates
/// (`iaddi r, r, -1` must work); logical immediates use them zero-extended.
#[inline]
fn sign_extend(imm: u32) -> u32 {
    imm as u16 as i16 as i32 as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn run(src: &str) -> (FlatMemory, MemTrace) {
        let p = assemble(src).expect("assembles");
        let mut mem = FlatMemory::new(4096);
        let params = ExecParams { max_cycles: 1_000_000, ..ExecParams::default() };
        let t = execute(&p, &mut mem, &params).expect("executes");
        (mem, t)
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let src = "
.threads 64
    tid   r0
    ld    r1, [r0]
    iadd  r1, r1, r0
    st    [r0], r1
    halt
";
        let (_, trace) = run(src);
        assert_eq!(trace.segments.len(), 2);
        // Segment 0: tid before the load.
        let s0 = &trace.segments[0];
        assert_eq!(s0.before.other_cycles, 4);
        assert_eq!(s0.before.instructions, 1);
        assert_eq!(s0.mem.kind, MemAccessKind::Load(LoadClass::Data));
        assert_eq!(s0.mem.ops.len(), 4);
        // Segment 1: the iadd before the store.
        let s1 = &trace.segments[1];
        assert_eq!(s1.before.int_cycles, 4);
        assert_eq!(s1.mem.kind, MemAccessKind::Store { blocking: true });
        assert_eq!(trace.mem_op_count(), 8);
        assert_eq!(trace.tail, AluCharges::default());
    }

    #[test]
    fn functional_results_land_in_memory() {
        let src = "
.threads 32
    tid   r0
    imuli r1, r0, 3
    st    [r0], r1
    halt
";
        let (mem, trace) = run(src);
        for t in 0..32 {
            assert_eq!(mem.read_word(t), t * 3);
        }
        assert_eq!(trace.threads, 32);
    }

    #[test]
    fn tw_region_recorded_in_trace() {
        let src = "
.threads 16
    tid   r0
    iaddi r1, r0, 100
    ld    r2, [r1]
    ld    r3, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(4096);
        let params = ExecParams {
            tw_region: Some(100..200),
            max_cycles: 1_000_000,
            ..ExecParams::default()
        };
        let trace = execute(&p, &mut mem, &params).unwrap();
        let kinds: Vec<MemAccessKind> = trace.mem_instrs().map(|i| i.kind).collect();
        assert_eq!(
            kinds,
            vec![
                MemAccessKind::Load(LoadClass::Twiddle),
                MemAccessKind::Load(LoadClass::Data)
            ]
        );
    }

    #[test]
    fn nonblocking_store_flag_recorded() {
        let src = "
.threads 16
    tid  r0
    stnb [r0], r0
    halt
";
        let (_, trace) = run(src);
        assert_eq!(trace.segments[0].mem.kind, MemAccessKind::Store { blocking: false });
    }

    #[test]
    fn infinite_loop_hits_cycle_limit() {
        let p = assemble(".threads 16\nloop:\n jmp loop\n halt\n").unwrap();
        let mut mem = FlatMemory::new(64);
        let params = ExecParams { max_cycles: 1000, ..ExecParams::default() };
        assert!(matches!(
            execute(&p, &mut mem, &params),
            Err(SimError::CycleLimit { limit: 1000 })
        ));
    }

    #[test]
    fn trace_limit_bounds_runaway_capture_memory() {
        // A runaway loop *containing a store* must trip the trace-size
        // guard long before the (huge) cycle guard would — bounded
        // memory, clean error.
        let src = "
.threads 16
    tid  r0
loop:
    st   [r0], r0
    jmp  loop
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(64);
        let params = ExecParams {
            max_cycles: u64::MAX,
            max_trace_ops: 100,
            ..ExecParams::default()
        };
        assert!(matches!(
            execute(&p, &mut mem, &params),
            Err(SimError::TraceLimit { ops }) if ops > 100
        ));
    }

    #[test]
    fn out_of_bounds_reported_with_context() {
        let src = "
.threads 16
    ldi  r0, 0
    lui  r0, 1
    ld   r1, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(4096);
        match execute(&p, &mut mem, &ExecParams { max_cycles: 1000, ..ExecParams::default() }) {
            Err(SimError::InvalidAddress { addr, pc, .. }) => {
                assert_eq!(addr, 65536);
                assert_eq!(pc, 2);
            }
            other => panic!("expected InvalidAddress, got {other:?}"),
        }
    }

    #[test]
    fn synthetic_trace_constructor() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![([0u32; LANES], 0xFFFF)],
        };
        let t = MemTrace::from_mem_instrs("synthetic", 16, vec![mi]);
        assert_eq!(t.segments.len(), 1);
        assert_eq!(t.mem_op_count(), 1);
        assert_eq!(t.mem_instrs().next().unwrap().op_kind(), OpKind::Read);
    }
}
