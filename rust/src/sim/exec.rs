//! Functional execution core — the architecture-independent half of the
//! decoupled simulator (DESIGN.md §Two-phase).
//!
//! A program's *functional* behaviour (decode, ALU results, the per-lane
//! branch outcomes, and the address stream every memory instruction
//! emits) is identical across all nine shared-memory architectures — the
//! `all_archs_functionally_identical_on_random_programs` property test is
//! the executable statement of that fact. Only memory *timing* differs.
//!
//! Control flow may *diverge*: lanes that disagree on a `bnz` are split
//! onto a reconvergence stack (taken path first) and serialized until
//! they rejoin at the branch's immediate post-dominator
//! ([`crate::isa::cfg`], DESIGN.md §Divergence). The per-op lane masks in
//! the trace carry the divergence to every replay path unchanged.
//!
//! [`execute`] therefore runs a program **once**, against any word-level
//! memory ([`ExecMemory`]), and emits a complete [`MemTrace`]: the full
//! per-instruction memory-operation stream (addresses + lane masks +
//! load classification + blocking flags) interleaved with the exact
//! ALU/issue cycle charges accumulated between memory instructions. The
//! trace is everything the timing replayer ([`crate::sim::replay`]) needs
//! to reproduce the coupled simulator's [`crate::sim::stats::RunReport`]
//! bit for bit on *any* architecture — so an N-architecture sweep
//! executes each program once and replays timing N times.

use super::regfile::RegFile;
use crate::isa::inst::Instruction;
use crate::isa::opcode::{OpClass, Opcode};
use crate::isa::program::Program;
use crate::mem::arch::{OpKind, SharedMemory};
use crate::mem::{LaneMask, LANES};
use std::ops::Range;

/// Simulation errors (all carry the faulting PC where one exists).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// A lane addressed past the end of shared memory.
    InvalidAddress { pc: usize, thread: u32, addr: u32, words: usize },
    /// The reconvergence stack emptied at a reconvergence point — a
    /// malformed divergence structure (structured divergence itself is
    /// legal and never errors).
    ReconvergenceUnderflow { pc: usize },
    /// Branch target outside the program.
    BadJumpTarget { pc: usize, target: u16 },
    /// The run exceeded `max_cycles` (runaway loop guard).
    CycleLimit { limit: u64 },
    /// The trace exceeded `max_trace_ops` memory operations (runaway
    /// loop guard on capture *memory*: a loop containing a store would
    /// otherwise buffer operations until the cycle guard trips).
    TraceLimit { ops: u64 },
    /// Execution fell off the end of the instruction stream.
    MissingHalt,
    /// Program binary failed to decode.
    BadProgram(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::InvalidAddress { pc, thread, addr, words } => write!(
                f,
                "pc {pc}: thread {thread} addressed {addr} beyond shared memory ({words} words)"
            ),
            SimError::ReconvergenceUnderflow { pc } => {
                write!(f, "pc {pc}: reconvergence stack underflow (malformed divergence)")
            }
            SimError::BadJumpTarget { pc, target } => {
                write!(f, "pc {pc}: jump target {target} outside program")
            }
            SimError::CycleLimit { limit } => write!(f, "exceeded cycle limit {limit}"),
            SimError::TraceLimit { ops } => write!(
                f,
                "trace exceeded {ops} memory operations (raise ExecParams::max_trace_ops \
                 for legitimately huge programs)"
            ),
            SimError::MissingHalt => write!(f, "execution fell off the end (missing halt)"),
            SimError::BadProgram(m) => write!(f, "bad program: {m}"),
        }
    }
}

impl std::error::Error for SimError {}

/// A binary image that fails to decode is a bad program — the typed
/// ISA-layer error folds into the simulator's, so callers loading
/// binaries (`Program::decode` + `run_program`) can use `?` throughout
/// and the service layer sees one error lineage.
impl From<crate::isa::program::DecodeError> for SimError {
    fn from(e: crate::isa::program::DecodeError) -> Self {
        SimError::BadProgram(e.to_string())
    }
}

/// Word-addressed functional memory — the only thing the execution core
/// needs from a memory. Implemented by [`FlatMemory`] (the cheap backing
/// store for trace capture) and by the architectural memories (so the
/// [`crate::sim::machine::Machine`] facade executes against the same
/// image its `mem()` accessor exposes).
pub trait ExecMemory {
    /// Capacity in 32-bit words (the bounds-check limit).
    fn words(&self) -> usize;
    /// Functional single-word read.
    fn read_word(&self, addr: u32) -> u32;
    /// Functional single-word write.
    fn write_word(&mut self, addr: u32, value: u32);
}

/// A flat word array: the functional memory used when capturing a trace
/// without instantiating any shared-memory architecture.
#[derive(Debug, Clone)]
pub struct FlatMemory {
    words: Vec<u32>,
}

impl FlatMemory {
    pub fn new(words: usize) -> Self {
        Self { words: vec![0u32; words] }
    }

    /// Snapshot of the full image (functional-equivalence checks).
    pub fn image(&self) -> &[u32] {
        &self.words
    }
}

impl ExecMemory for FlatMemory {
    fn words(&self) -> usize {
        self.words.len()
    }

    fn read_word(&self, addr: u32) -> u32 {
        self.words[addr as usize]
    }

    fn write_word(&mut self, addr: u32, value: u32) {
        self.words[addr as usize] = value;
    }
}

impl ExecMemory for Box<dyn SharedMemory> {
    fn words(&self) -> usize {
        SharedMemory::words(&**self)
    }

    fn read_word(&self, addr: u32) -> u32 {
        (**self).peek(addr)
    }

    fn write_word(&mut self, addr: u32, value: u32) {
        (**self).poke(addr, value);
    }
}

/// Classification of one executed load, for the Table III D-load /
/// TW-load split. Decided by the (architecture-independent) twiddle
/// address region of the workload, so it lives in the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadClass {
    Data,
    Twiddle,
}

/// What one traced memory instruction was.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemAccessKind {
    /// `ld`, classified against the twiddle region.
    Load(LoadClass),
    /// `st` (blocking) or `stnb` (non-blocking).
    Store { blocking: bool },
}

/// One executed memory instruction: its kind and each 16-lane operation's
/// addresses + active-lane mask, in issue order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemInstr {
    pub kind: MemAccessKind,
    pub ops: Vec<([u32; LANES], LaneMask)>,
}

impl MemInstr {
    /// Read/write direction (what the §III-A controllers care about).
    pub fn op_kind(&self) -> OpKind {
        match self.kind {
            MemAccessKind::Load(_) => OpKind::Read,
            MemAccessKind::Store { .. } => OpKind::Write,
        }
    }
}

/// Exact ALU/issue cycle charges accumulated between two memory
/// instructions. These are architecture-independent: ALU classes cost one
/// cycle per 16-thread operation on every memory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AluCharges {
    /// Register-register integer cycles ("INT OPs").
    pub int_cycles: u64,
    /// Immediate-op cycles ("Immediate OPs").
    pub imm_cycles: u64,
    /// FP32 cycles ("FP OPs").
    pub fp_cycles: u64,
    /// Control/misc cycles ("Other OPs") — nop/jmp/bnz/tid.
    pub other_cycles: u64,
    /// 16-wide operations issued (ALU classes + tid).
    pub operations: u64,
    /// Dynamic instructions executed.
    pub instructions: u64,
}

impl AluCharges {
    /// Clock advance these charges represent.
    pub fn cycles(&self) -> u64 {
        self.int_cycles + self.imm_cycles + self.fp_cycles + self.other_cycles
    }
}

/// One trace segment: the ALU charges *preceding* a memory instruction,
/// then the memory instruction itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSegment {
    pub before: AluCharges,
    pub mem: MemInstr,
}

/// The complete, lossless record of one functional execution — the input
/// to the timing replayer. Unlike the old optional `MemTraceInstr`
/// capture, a `MemTrace` always carries every memory operation *and* the
/// interleaved ALU accounting, so timing on any architecture can be
/// reconstructed without re-executing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemTrace {
    /// Program name (propagated into replayed reports).
    pub program: String,
    /// Thread-block size.
    pub threads: u32,
    /// Shared-memory capacity (words) the program executed against —
    /// part of the functional execution, so replayers can build a
    /// matching memory without re-materializing the workload.
    pub mem_words: usize,
    /// Memory instructions in program order, each with its preceding ALU
    /// charges.
    pub segments: Vec<TraceSegment>,
    /// ALU charges after the last memory instruction, up to (but not
    /// including) `halt`.
    pub tail: AluCharges,
}

impl MemTrace {
    /// Build a trace from bare memory instructions (no ALU work) — handy
    /// for synthetic traces in tests and the analytical oracle. Capacity
    /// defaults to 64 Ki words (the [`crate::sim::config`] default).
    pub fn from_mem_instrs(
        program: impl Into<String>,
        threads: u32,
        instrs: Vec<MemInstr>,
    ) -> Self {
        Self {
            program: program.into(),
            threads,
            mem_words: 65_536,
            segments: instrs
                .into_iter()
                .map(|mem| TraceSegment { before: AluCharges::default(), mem })
                .collect(),
            tail: AluCharges::default(),
        }
    }

    /// The memory instructions in program order.
    pub fn mem_instrs(&self) -> impl Iterator<Item = &MemInstr> {
        self.segments.iter().map(|s| &s.mem)
    }

    /// Total 16-lane memory operations across the trace.
    pub fn mem_op_count(&self) -> u64 {
        self.mem_instrs().map(|i| i.ops.len() as u64).sum()
    }
}

/// Architecture-independent execution parameters.
#[derive(Debug, Clone)]
pub struct ExecParams {
    /// Address range whose loads are classified as twiddle loads
    /// ("TW Load" rows of Table III). `None` classifies every load as a
    /// data load.
    pub tw_region: Option<Range<u32>>,
    /// Runaway-loop guard, checked against an architecture-independent
    /// *lower bound* on the clock (every architecture charges at least
    /// one cycle per operation). The replayer re-checks against the real
    /// clock of its architecture.
    pub max_cycles: u64,
    /// Companion guard on trace *memory*: maximum 16-lane memory
    /// operations the capture may buffer. The cycle guard alone would
    /// let a runaway loop containing a store allocate
    /// `O(max_cycles)` trace segments before tripping; this caps the
    /// capture at a size (~1–2 GB at the default) far above any real
    /// workload (the paper's largest benchmark records ~4k operations).
    pub max_trace_ops: u64,
}

impl ExecParams {
    /// Default trace-size guard: 2^24 ≈ 16.8M operations.
    pub const DEFAULT_MAX_TRACE_OPS: u64 = 1 << 24;
}

impl Default for ExecParams {
    fn default() -> Self {
        Self {
            tw_region: None,
            max_cycles: 2_000_000_000,
            max_trace_ops: Self::DEFAULT_MAX_TRACE_OPS,
        }
    }
}

/// Dense per-thread active set for the whole block. Wider than a
/// [`LaneMask`] (blocks span many warps); maintains a popcount so the
/// all-active fast path is a single compare.
#[derive(Debug, Clone, PartialEq, Eq)]
struct ActiveSet {
    words: Vec<u64>,
    active: u32,
}

impl ActiveSet {
    fn full(threads: u32) -> Self {
        let n = (threads as usize).div_ceil(64);
        let mut words = vec![u64::MAX; n];
        let rem = threads as usize % 64;
        if rem != 0 {
            *words.last_mut().expect("threads > 0") = (1u64 << rem) - 1;
        }
        Self { words, active: threads }
    }

    fn empty_like(&self) -> Self {
        Self { words: vec![0; self.words.len()], active: 0 }
    }

    /// Insert a thread not currently in the set.
    fn insert(&mut self, t: u32) {
        self.words[t as usize / 64] |= 1 << (t % 64);
        self.active += 1;
    }

    fn contains(&self, t: u32) -> bool {
        self.words[t as usize / 64] >> (t % 64) & 1 != 0
    }

    fn is_empty(&self) -> bool {
        self.active == 0
    }

    fn is_full(&self, threads: u32) -> bool {
        self.active == threads
    }

    fn subtract(&mut self, other: &Self) {
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w &= !o;
        }
        self.active = self.words.iter().map(|w| w.count_ones()).sum();
    }

    fn union(&mut self, other: &Self) {
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w |= o;
        }
        self.active = self.words.iter().map(|w| w.count_ones()).sum();
    }

    fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.words.iter().enumerate().flat_map(|(i, &w)| {
            let mut m = w;
            std::iter::from_fn(move || {
                if m == 0 {
                    return None;
                }
                let bit = m.trailing_zeros();
                m &= m - 1;
                Some(i as u32 * 64 + bit)
            })
        })
    }
}

/// One entry of the SIMT reconvergence stack. The top entry is the
/// running path: it executes at `pc` under `mask` until `pc` reaches
/// `rpc` (its reconvergence point), at which point it pops and the entry
/// below — the other arm of the split, or the join carrying the
/// pre-divergence mask — resumes.
struct PathEntry {
    pc: usize,
    rpc: usize,
    mask: ActiveSet,
}

/// Run `program` to `halt` against `mem`, returning the complete trace.
///
/// The program is round-tripped through its binary encoding first — the
/// execution core consumes what the assembler would produce, keeping the
/// decode path honest.
///
/// Divergent `bnz` outcomes split the block onto a reconvergence stack:
/// the taken path runs first, the fall-through path second, and both
/// rejoin at the branch's immediate post-dominator. A path that halts
/// while other paths remain retires its lanes (charged as one Other-class
/// instruction); the final halt is charged by the replayer's finish
/// sequence exactly as in the uniform case, so uniform programs trace
/// bit-identically to the pre-divergence model.
pub fn execute<M: ExecMemory>(
    program: &Program,
    mem: &mut M,
    params: &ExecParams,
) -> Result<MemTrace, SimError> {
    let words = program.encode();
    let insts: Vec<Instruction> = words
        .iter()
        .enumerate()
        .map(|(pc, &w)| {
            Instruction::decode(w).ok_or_else(|| SimError::BadProgram(format!("pc {pc}")))
        })
        .collect::<Result<_, _>>()?;

    let threads = program.threads;
    let mut regs = RegFile::new(threads);
    let n_ops = (threads as u64).div_ceil(LANES as u64);
    let mem_words = mem.words();

    let mut segments = Vec::new();
    let mut charges = AluCharges::default();
    // Lower bound on the clock of *any* architecture (ALU cycles are
    // exact; memory operations cost at least one cycle each).
    let mut clock_floor = 0u64;
    // Memory operations buffered so far (the capture-size guard).
    let mut trace_ops = 0u64;

    // SIMT reconvergence stack: the outer frame runs the full block with
    // rpc = EXIT (it can only retire through `halt`). Post-dominators are
    // computed lazily on the first divergent branch — the overwhelmingly
    // common uniform program never pays for the CFG analysis.
    let mut stack = vec![PathEntry {
        pc: 0,
        rpc: crate::isa::cfg::EXIT,
        mask: ActiveSet::full(threads),
    }];
    // Lanes retired by a path-level halt while other paths kept running.
    // Join entries were pushed before those lanes halted, so every entry
    // is filtered against this set when it resumes.
    let mut exited = stack[0].mask.empty_like();
    let mut ipdoms: Option<Vec<usize>> = None;

    loop {
        let Some(top) = stack.last_mut() else {
            // Every lane retired through a path-level halt.
            break;
        };
        if !exited.is_empty() {
            top.mask.subtract(&exited);
        }
        if top.mask.is_empty() {
            stack.pop();
            continue;
        }
        if top.pc == top.rpc {
            // Path reached its reconvergence point: the entry below
            // (sibling arm or join) resumes.
            let at = top.pc;
            stack.pop();
            if stack.is_empty() {
                return Err(SimError::ReconvergenceUnderflow { pc: at });
            }
            continue;
        }
        let pc = top.pc;
        if pc >= insts.len() {
            return Err(SimError::MissingHalt);
        }
        if clock_floor > params.max_cycles {
            return Err(SimError::CycleLimit { limit: params.max_cycles });
        }
        let inst = insts[pc];
        match inst.op.class() {
            OpClass::Int | OpClass::Imm | OpClass::Fp => {
                exec_alu(&mut regs, inst, threads, &top.mask);
                match inst.op.class() {
                    OpClass::Int => charges.int_cycles += n_ops,
                    OpClass::Imm => charges.imm_cycles += n_ops,
                    OpClass::Fp => charges.fp_cycles += n_ops,
                    _ => unreachable!(),
                }
                charges.operations += n_ops;
                charges.instructions += 1;
                clock_floor += n_ops;
                top.pc += 1;
            }
            OpClass::Other => match inst.op {
                Opcode::Halt => {
                    if stack.len() == 1 {
                        // The whole remaining block retires; the replayer
                        // charges the final halt in its finish sequence.
                        clock_floor += 1;
                        break;
                    }
                    // A proper subset of the block halted early: the halt
                    // issues like any Other-class op, its lanes retire.
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    let done = stack.pop().expect("stack.len() > 1");
                    exited.union(&done.mask);
                }
                Opcode::Nop => {
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    top.pc += 1;
                }
                Opcode::Jmp => {
                    let target = inst.imm as usize;
                    if target >= insts.len() {
                        return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                    }
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    top.pc = target;
                }
                Opcode::Bnz => {
                    // Partition the active lanes on the per-lane
                    // predicate. The branch issues one Other-class cycle
                    // whether uniform or divergent; a divergent split's
                    // extra cost emerges from serializing both paths.
                    let mut taken = top.mask.empty_like();
                    let mut fall = top.mask.empty_like();
                    for t in top.mask.iter() {
                        if regs.get(t, inst.rd) != 0 {
                            taken.insert(t);
                        } else {
                            fall.insert(t);
                        }
                    }
                    charges.other_cycles += 1;
                    charges.instructions += 1;
                    clock_floor += 1;
                    let target = inst.imm as usize;
                    if taken.is_empty() {
                        top.pc += 1;
                    } else {
                        if target >= insts.len() {
                            return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                        }
                        if fall.is_empty() {
                            top.pc = target;
                        } else {
                            // Divergent: the running entry becomes the
                            // join at the branch's immediate
                            // post-dominator; the fall-through arm stacks
                            // below the taken arm, so taken runs first.
                            let rpc = *ipdoms
                                .get_or_insert_with(|| {
                                    crate::isa::cfg::immediate_postdoms(&insts)
                                })
                                .get(pc)
                                .unwrap_or(&crate::isa::cfg::EXIT);
                            top.pc = rpc;
                            stack.push(PathEntry { pc: pc + 1, rpc, mask: fall });
                            stack.push(PathEntry { pc: target, rpc, mask: taken });
                        }
                    }
                }
                Opcode::Tid => {
                    if top.mask.is_full(threads) {
                        for t in 0..threads {
                            regs.set(t, inst.rd, t);
                        }
                    } else {
                        for t in top.mask.iter() {
                            regs.set(t, inst.rd, t);
                        }
                    }
                    charges.other_cycles += n_ops;
                    charges.operations += n_ops;
                    charges.instructions += 1;
                    clock_floor += n_ops;
                    top.pc += 1;
                }
                _ => unreachable!("all Other opcodes handled"),
            },
            OpClass::Load => {
                let mi =
                    exec_load(&mut regs, inst, threads, pc, mem, mem_words, params, &top.mask)?;
                clock_floor += mi.ops.len() as u64;
                trace_ops += mi.ops.len() as u64;
                if trace_ops > params.max_trace_ops {
                    return Err(SimError::TraceLimit { ops: trace_ops });
                }
                segments.push(TraceSegment { before: std::mem::take(&mut charges), mem: mi });
                top.pc += 1;
            }
            OpClass::Store => {
                let mi = exec_store(&mut regs, inst, threads, pc, mem, mem_words, &top.mask)?;
                clock_floor += mi.ops.len() as u64;
                trace_ops += mi.ops.len() as u64;
                if trace_ops > params.max_trace_ops {
                    return Err(SimError::TraceLimit { ops: trace_ops });
                }
                segments.push(TraceSegment { before: std::mem::take(&mut charges), mem: mi });
                top.pc += 1;
            }
        }
    }

    Ok(MemTrace { program: program.name.clone(), threads, mem_words, segments, tail: charges })
}

/// Execute an ALU instruction for every *active* thread (inactive lanes
/// are predicated off: no register writes).
///
/// §Perf: the opcode dispatch is hoisted *outside* the thread loop (one
/// specialized tight loop per opcode) — this function is the simulator's
/// hottest path (≈27% before the split; see EXPERIMENTS.md §Perf). The
/// all-active case keeps the original dense loops; only divergent
/// regions pay for the sparse set-bit walk.
fn exec_alu(regs: &mut RegFile, inst: Instruction, threads: u32, active: &ActiveSet) {
    use Opcode::*;
    let imm = inst.imm as u32;
    let (rd, ra, rb) = (inst.rd, inst.ra, inst.rb);
    let all = active.is_full(threads);
    macro_rules! for_active {
        (|$t:ident| $body:expr) => {
            if all {
                for $t in 0..threads {
                    $body
                }
            } else {
                for $t in active.iter() {
                    $body
                }
            }
        };
    }
    macro_rules! int_rr {
        ($f:expr) => {
            for_active!(|t| {
                let v = $f(regs.get(t, ra), regs.get(t, rb));
                regs.set(t, rd, v);
            })
        };
    }
    macro_rules! int_ri {
        ($f:expr) => {
            for_active!(|t| {
                let v = $f(regs.get(t, ra));
                regs.set(t, rd, v);
            })
        };
    }
    macro_rules! fp_rr {
        ($f:expr) => {
            for_active!(|t| {
                let v = $f(regs.get_f32(t, ra), regs.get_f32(t, rb));
                regs.set_f32(t, rd, v);
            })
        };
    }
    match inst.op {
        Iadd => int_rr!(|a: u32, b: u32| a.wrapping_add(b)),
        Isub => int_rr!(|a: u32, b: u32| a.wrapping_sub(b)),
        Imul => int_rr!(|a: u32, b: u32| a.wrapping_mul(b)),
        Iand => int_rr!(|a, b| a & b),
        Ior => int_rr!(|a, b| a | b),
        Ixor => int_rr!(|a, b| a ^ b),
        Ishl => int_rr!(|a: u32, b: u32| a << (b & 31)),
        Ishr => int_rr!(|a: u32, b: u32| a >> (b & 31)),
        Iaddi => int_ri!(|a: u32| a.wrapping_add(sign_extend(imm))),
        Imuli => int_ri!(|a: u32| a.wrapping_mul(sign_extend(imm))),
        Iandi => int_ri!(|a| a & imm),
        Iori => int_ri!(|a| a | imm),
        Ixori => int_ri!(|a| a ^ imm),
        Ishli => int_ri!(|a: u32| a << (imm & 31)),
        Ishri => int_ri!(|a: u32| a >> (imm & 31)),
        Ldi => {
            for_active!(|t| {
                regs.set(t, rd, imm);
            })
        }
        Lui => {
            for_active!(|t| {
                let low = regs.get(t, rd) & 0xFFFF;
                regs.set(t, rd, (imm << 16) | low);
            })
        }
        Fadd => fp_rr!(|a, b| a + b),
        Fsub => fp_rr!(|a, b| a - b),
        Fmul => fp_rr!(|a, b| a * b),
        Fma => {
            for_active!(|t| {
                let acc = regs.get_f32(t, rd);
                let v = regs.get_f32(t, ra).mul_add(regs.get_f32(t, rb), acc);
                regs.set_f32(t, rd, v);
            })
        }
        Fneg => {
            for_active!(|t| {
                let v = -regs.get_f32(t, ra);
                regs.set_f32(t, rd, v);
            })
        }
        Itof => {
            for_active!(|t| {
                let v = regs.get(t, ra) as i32 as f32;
                regs.set_f32(t, rd, v);
            })
        }
        _ => unreachable!("not an ALU opcode"),
    }
}

/// Gather one warp's addresses from register `ra`, with bounds checks.
/// Only lanes that are both live (within the block) and active (not
/// predicated off by divergence) participate: inactive lanes contribute
/// no address, no mask bit, and no bounds check.
fn warp_addrs(
    regs: &RegFile,
    ra: u8,
    warp: u32,
    threads: u32,
    pc: usize,
    mem_words: usize,
    active: &ActiveSet,
) -> Result<([u32; LANES], LaneMask), SimError> {
    let base_t = warp * LANES as u32;
    let mut addrs = [0u32; LANES];
    let mut mask: LaneMask = 0;
    for lane in 0..LANES {
        let t = base_t + lane as u32;
        if t >= threads {
            break;
        }
        if !active.contains(t) {
            continue;
        }
        let addr = regs.get(t, ra);
        if addr as usize >= mem_words {
            return Err(SimError::InvalidAddress { pc, thread: t, addr, words: mem_words });
        }
        addrs[lane] = addr;
        mask |= 1 << lane;
    }
    Ok((addrs, mask))
}

/// Classify a load by its addresses (Table III splits data loads from
/// twiddle loads). Matches the coupled simulator: the first active lane
/// of the first warp decides.
fn classify_load(
    addrs: &[u32; LANES],
    mask: LaneMask,
    tw_region: &Option<Range<u32>>,
) -> LoadClass {
    if let Some(region) = tw_region {
        if mask != 0 {
            let lane = mask.trailing_zeros() as usize;
            if region.contains(&addrs[lane]) {
                return LoadClass::Twiddle;
            }
        }
    }
    LoadClass::Data
}

#[allow(clippy::too_many_arguments)]
fn exec_load<M: ExecMemory>(
    regs: &mut RegFile,
    inst: Instruction,
    threads: u32,
    pc: usize,
    mem: &mut M,
    mem_words: usize,
    params: &ExecParams,
    active: &ActiveSet,
) -> Result<MemInstr, SimError> {
    let n_warps = (threads as usize).div_ceil(LANES);
    let mut ops = Vec::with_capacity(n_warps);
    let mut class = LoadClass::Data;
    for w in 0..n_warps {
        let (addrs, mask) = warp_addrs(regs, inst.ra, w as u32, threads, pc, mem_words, active)?;
        if w == 0 {
            class = classify_load(&addrs, mask, &params.tw_region);
        }
        let base_t = w as u32 * LANES as u32;
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            regs.set(base_t + lane as u32, inst.rd, mem.read_word(addrs[lane]));
        }
        ops.push((addrs, mask));
    }
    Ok(MemInstr { kind: MemAccessKind::Load(class), ops })
}

fn exec_store<M: ExecMemory>(
    regs: &mut RegFile,
    inst: Instruction,
    threads: u32,
    pc: usize,
    mem: &mut M,
    mem_words: usize,
    active: &ActiveSet,
) -> Result<MemInstr, SimError> {
    let n_warps = (threads as usize).div_ceil(LANES);
    let blocking = inst.op == Opcode::St;
    let mut ops = Vec::with_capacity(n_warps);
    for w in 0..n_warps {
        let (addrs, mask) = warp_addrs(regs, inst.ra, w as u32, threads, pc, mem_words, active)?;
        let base_t = w as u32 * LANES as u32;
        // Lanes commit in ascending order: on address collisions the
        // highest lane writes last and wins — the same resolution as the
        // banked arbiters and the multiport port arbitration.
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            mem.write_word(addrs[lane], regs.get(base_t + lane as u32, inst.rb));
        }
        ops.push((addrs, mask));
    }
    Ok(MemInstr { kind: MemAccessKind::Store { blocking }, ops })
}

/// 16-bit immediates are sign-extended for the arithmetic immediates
/// (`iaddi r, r, -1` must work); logical immediates use them zero-extended.
#[inline]
fn sign_extend(imm: u32) -> u32 {
    imm as u16 as i16 as i32 as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn run(src: &str) -> (FlatMemory, MemTrace) {
        let p = assemble(src).expect("assembles");
        let mut mem = FlatMemory::new(4096);
        let params = ExecParams { max_cycles: 1_000_000, ..ExecParams::default() };
        let t = execute(&p, &mut mem, &params).expect("executes");
        (mem, t)
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let src = "
.threads 64
    tid   r0
    ld    r1, [r0]
    iadd  r1, r1, r0
    st    [r0], r1
    halt
";
        let (_, trace) = run(src);
        assert_eq!(trace.segments.len(), 2);
        // Segment 0: tid before the load.
        let s0 = &trace.segments[0];
        assert_eq!(s0.before.other_cycles, 4);
        assert_eq!(s0.before.instructions, 1);
        assert_eq!(s0.mem.kind, MemAccessKind::Load(LoadClass::Data));
        assert_eq!(s0.mem.ops.len(), 4);
        // Segment 1: the iadd before the store.
        let s1 = &trace.segments[1];
        assert_eq!(s1.before.int_cycles, 4);
        assert_eq!(s1.mem.kind, MemAccessKind::Store { blocking: true });
        assert_eq!(trace.mem_op_count(), 8);
        assert_eq!(trace.tail, AluCharges::default());
    }

    #[test]
    fn functional_results_land_in_memory() {
        let src = "
.threads 32
    tid   r0
    imuli r1, r0, 3
    st    [r0], r1
    halt
";
        let (mem, trace) = run(src);
        for t in 0..32 {
            assert_eq!(mem.read_word(t), t * 3);
        }
        assert_eq!(trace.threads, 32);
    }

    #[test]
    fn tw_region_recorded_in_trace() {
        let src = "
.threads 16
    tid   r0
    iaddi r1, r0, 100
    ld    r2, [r1]
    ld    r3, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(4096);
        let params = ExecParams {
            tw_region: Some(100..200),
            max_cycles: 1_000_000,
            ..ExecParams::default()
        };
        let trace = execute(&p, &mut mem, &params).unwrap();
        let kinds: Vec<MemAccessKind> = trace.mem_instrs().map(|i| i.kind).collect();
        assert_eq!(
            kinds,
            vec![
                MemAccessKind::Load(LoadClass::Twiddle),
                MemAccessKind::Load(LoadClass::Data)
            ]
        );
    }

    #[test]
    fn nonblocking_store_flag_recorded() {
        let src = "
.threads 16
    tid  r0
    stnb [r0], r0
    halt
";
        let (_, trace) = run(src);
        assert_eq!(trace.segments[0].mem.kind, MemAccessKind::Store { blocking: false });
    }

    #[test]
    fn infinite_loop_hits_cycle_limit() {
        let p = assemble(".threads 16\nloop:\n jmp loop\n halt\n").unwrap();
        let mut mem = FlatMemory::new(64);
        let params = ExecParams { max_cycles: 1000, ..ExecParams::default() };
        assert!(matches!(
            execute(&p, &mut mem, &params),
            Err(SimError::CycleLimit { limit: 1000 })
        ));
    }

    #[test]
    fn trace_limit_bounds_runaway_capture_memory() {
        // A runaway loop *containing a store* must trip the trace-size
        // guard long before the (huge) cycle guard would — bounded
        // memory, clean error.
        let src = "
.threads 16
    tid  r0
loop:
    st   [r0], r0
    jmp  loop
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(64);
        let params = ExecParams {
            max_cycles: u64::MAX,
            max_trace_ops: 100,
            ..ExecParams::default()
        };
        assert!(matches!(
            execute(&p, &mut mem, &params),
            Err(SimError::TraceLimit { ops }) if ops > 100
        ));
    }

    #[test]
    fn out_of_bounds_reported_with_context() {
        let src = "
.threads 16
    ldi  r0, 0
    lui  r0, 1
    ld   r1, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut mem = FlatMemory::new(4096);
        match execute(&p, &mut mem, &ExecParams { max_cycles: 1000, ..ExecParams::default() }) {
            Err(SimError::InvalidAddress { addr, pc, .. }) => {
                assert_eq!(addr, 65536);
                assert_eq!(pc, 2);
            }
            other => panic!("expected InvalidAddress, got {other:?}"),
        }
    }

    #[test]
    fn nested_if_else_reconverges_with_exact_charges() {
        // Outer split on tid bit 0, inner split (evens only) on bit 1.
        // All three arms rejoin at the store, which must therefore issue
        // with the full mask again.
        let src = "
.threads 32
    tid   r0
    iandi r1, r0, 1
    bnz   r1, odd
    iandi r2, r0, 2
    bnz   r2, even2
    ldi   r3, 100
    jmp   join
even2:
    ldi   r3, 200
    jmp   join
odd:
    ldi   r3, 300
join:
    st    [r0], r3
    halt
";
        let (mem, trace) = run(src);
        for t in 0..32u32 {
            let want = if t % 2 == 1 {
                300
            } else if t % 4 == 2 {
                200
            } else {
                100
            };
            assert_eq!(mem.read_word(t), want, "thread {t}");
        }
        // One memory instruction: the reconverged store, full masks.
        assert_eq!(trace.segments.len(), 1);
        let seg = &trace.segments[0];
        assert_eq!(seg.mem.kind, MemAccessKind::Store { blocking: true });
        assert_eq!(seg.mem.ops.len(), 2);
        assert!(seg.mem.ops.iter().all(|&(_, m)| m == 0xFFFF));
        // Exact serialized charges: tid + 2 bnz + 2 jmp = 6 other cycles;
        // 5 immediate-class instructions at 2 ops each = 10 imm cycles;
        // 10 dynamic instructions (both outer arms and both inner arms).
        assert_eq!(seg.before.other_cycles, 6);
        assert_eq!(seg.before.imm_cycles, 10);
        assert_eq!(seg.before.int_cycles, 0);
        assert_eq!(seg.before.instructions, 10);
        assert_eq!(seg.before.operations, 12);
        assert_eq!(trace.tail, AluCharges::default());
    }

    #[test]
    fn taken_path_executes_first() {
        // Both arms store to word 5. The taken arm (odd lanes, 111) must
        // run first, so the fall-through arm's 222 lands last and wins —
        // and the trace records the stores in that order.
        let src = "
.threads 16
    tid   r0
    ldi   r1, 5
    iandi r2, r0, 1
    bnz   r2, taken
    ldi   r3, 222
    st    [r1], r3
    jmp   join
taken:
    ldi   r3, 111
    st    [r1], r3
join:
    halt
";
        let (mem, trace) = run(src);
        let masks: Vec<LaneMask> = trace.segments.iter().map(|s| s.mem.ops[0].1).collect();
        assert_eq!(masks, vec![0xAAAA, 0x5555], "taken (odd) store first, then fall-through");
        assert_eq!(mem.read_word(5), 222);
    }

    #[test]
    fn loop_with_early_exit_lanes_reconverges_at_loop_exit() {
        // Per-lane trip counts 1..=4 (tid & 3 + 1): lanes drop out of the
        // loop over successive iterations, and the store after the loop
        // issues fully reconverged.
        let src = "
.threads 16
    tid   r0
    iandi r1, r0, 3
    iaddi r1, r1, 1
    ldi   r2, 0
body:
    iaddi r2, r2, 1
    iaddi r1, r1, -1
    bnz   r1, body
    st    [r0], r2
    halt
";
        let (mem, trace) = run(src);
        for t in 0..16u32 {
            assert_eq!(mem.read_word(t), (t & 3) + 1, "thread {t} trip count");
        }
        assert_eq!(trace.segments.len(), 1);
        let seg = &trace.segments[0];
        assert_eq!(seg.mem.ops.len(), 1);
        assert_eq!(seg.mem.ops[0].1, 0xFFFF, "store issues fully reconverged");
        // The body runs max-trip = 4 times under shrinking masks: 3
        // prologue + 4*2 body immediates = 11 imm cycles, tid + 4 bnz =
        // 5 other cycles, 4 + 4*3 = 16 dynamic instructions.
        assert_eq!(seg.before.imm_cycles, 11);
        assert_eq!(seg.before.other_cycles, 5);
        assert_eq!(seg.before.instructions, 16);
        assert_eq!(trace.tail, AluCharges::default());
    }

    #[test]
    fn early_halt_retires_lanes_without_reactivation() {
        // Even lanes halt before the store; the branch has no in-program
        // post-dominator (one arm halts), so the join carries EXIT and
        // the odd lanes run to their own halt. Both path-halts are
        // charged as Other-class instructions in the tail.
        let src = "
.threads 16
    tid   r0
    iandi r1, r0, 1
    bnz   r1, cont
    halt
cont:
    ldi   r2, 9
    st    [r0], r2
    halt
";
        let (mem, trace) = run(src);
        for t in 0..16u32 {
            assert_eq!(mem.read_word(t), if t % 2 == 1 { 9 } else { 0 });
        }
        assert_eq!(trace.segments.len(), 1);
        assert_eq!(trace.segments[0].mem.ops[0].1, 0xAAAA);
        assert_eq!(trace.tail.other_cycles, 2, "both path-halts issue");
        assert_eq!(trace.tail.instructions, 2);
    }

    #[test]
    fn synthetic_trace_constructor() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![([0u32; LANES], 0xFFFF)],
        };
        let t = MemTrace::from_mem_instrs("synthetic", 16, vec![mi]);
        assert_eq!(t.segments.len(), 1);
        assert_eq!(t.mem_op_count(), 1);
        assert_eq!(t.mem_instrs().next().unwrap().op_kind(), OpKind::Read);
    }
}
