//! Cycle accounting, mirroring the row structure of the paper's Tables II
//! and III.

use super::exec::AluCharges;
use crate::mem::arch::MemoryArchKind;

/// Cycle counters by instruction class. ALU classes count one cycle per
/// 16-thread operation; memory classes count controller-attributed cycles
/// (fixed overhead + per-operation spacing).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleStats {
    /// Register-register integer ALU cycles ("INT OPs").
    pub int_cycles: u64,
    /// Immediate-op cycles ("Immediate OPs").
    pub imm_cycles: u64,
    /// FP32 ALU cycles ("FP OPs").
    pub fp_cycles: u64,
    /// Control/misc cycles ("Other OPs").
    pub other_cycles: u64,
    /// Data-load cycles ("Load Cycles" / "D Load Cycles").
    pub d_load_cycles: u64,
    /// Twiddle-load cycles ("W Load Cycles" in Table III).
    pub tw_load_cycles: u64,
    /// Store cycles.
    pub store_cycles: u64,
    /// Ideal (one-cycle-per-operation) counts, the floor against which the
    /// paper's Bank Eff. columns measure.
    pub d_load_ops: u64,
    pub tw_load_ops: u64,
    pub store_ops: u64,
    /// Dynamic instruction count and total 16-wide operations issued.
    pub instructions: u64,
    pub operations: u64,
    /// Cycles the pipeline stalled because the write circular buffer was
    /// full (non-blocking writes).
    pub wbuf_stall_cycles: u64,
    /// Cycles spent waiting for the write controller to drain at a
    /// blocking-write boundary or at halt.
    pub drain_cycles: u64,
}

impl CycleStats {
    /// Sum of the "Common Ops" rows (INT + Immediate + FP + Other).
    pub fn common_cycles(&self) -> u64 {
        self.int_cycles + self.imm_cycles + self.fp_cycles + self.other_cycles
    }

    /// All load cycles (data + twiddle).
    pub fn load_cycles(&self) -> u64 {
        self.d_load_cycles + self.tw_load_cycles
    }

    /// Attributed total — the paper's "Total" row is this sum (its tables
    /// add the category rows); equals the elapsed clock when every write
    /// is blocking, as in the paper's benchmarks.
    pub fn attributed_total(&self) -> u64 {
        self.common_cycles() + self.load_cycles() + self.store_cycles
    }

    /// Fold the ALU charges accumulated between memory instructions into
    /// the per-class counters (no clock — callers that track a clock add
    /// `charges.cycles()` themselves). Shared by the reference replayer's
    /// `charge_alu`, the compiled batch replayer, and the trace-invariant
    /// base-stats precompute ([`crate::sim::compiled::CompiledTrace`]),
    /// so the three accountings cannot drift.
    pub fn add_alu(&mut self, charges: &AluCharges) {
        self.int_cycles += charges.int_cycles;
        self.imm_cycles += charges.imm_cycles;
        self.fp_cycles += charges.fp_cycles;
        self.other_cycles += charges.other_cycles;
        self.operations += charges.operations;
        self.instructions += charges.instructions;
    }
}

/// The result of one program run on one memory architecture: everything a
/// Table II/III column needs.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Program name (e.g. `transpose32`, `fft4096r16`).
    pub program: String,
    /// Memory architecture the run used.
    pub arch: MemoryArchKind,
    /// Thread-block size.
    pub threads: u32,
    /// Per-class cycle counters.
    pub stats: CycleStats,
    /// Elapsed machine clock at halt (includes final write drain; with
    /// non-blocking writes this can be *less* than the attributed sum).
    pub elapsed_cycles: u64,
}

impl RunReport {
    /// Total cycles — the paper's "Total" row (elapsed clock).
    pub fn total_cycles(&self) -> u64 {
        self.elapsed_cycles
    }

    /// Wall-clock in microseconds at the architecture's Fmax.
    pub fn time_us(&self) -> f64 {
        self.elapsed_cycles as f64 / self.arch.fmax_mhz()
    }

    /// Read bank efficiency: ideal operation count over actual cycles
    /// (data loads; the paper's "R Bank Eff." / "D Bank Eff.").
    pub fn r_bank_eff(&self) -> Option<f64> {
        eff(self.stats.d_load_ops, self.stats.d_load_cycles, self.arch)
    }

    /// Twiddle-load bank efficiency ("TW Bank Eff.").
    pub fn tw_bank_eff(&self) -> Option<f64> {
        eff(self.stats.tw_load_ops, self.stats.tw_load_cycles, self.arch)
    }

    /// Write bank efficiency ("W Bank Eff.").
    pub fn w_bank_eff(&self) -> Option<f64> {
        eff(self.stats.store_ops, self.stats.store_cycles, self.arch)
    }

    /// FFT efficiency: "the percentage of time that the core is
    /// calculating the FFT, which does not include address generation or
    /// shared memory accesses" — FP cycles over total.
    pub fn compute_efficiency(&self) -> f64 {
        self.stats.fp_cycles as f64 / self.elapsed_cycles.max(1) as f64
    }
}

/// Bank efficiency is only reported for banked architectures (the paper
/// leaves the multiport columns blank).
fn eff(ideal: u64, actual: u64, arch: MemoryArchKind) -> Option<f64> {
    if !arch.is_banked() || actual == 0 {
        None
    } else {
        Some(ideal as f64 / actual as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(stats: CycleStats, arch: MemoryArchKind) -> RunReport {
        RunReport {
            program: "t".into(),
            arch,
            threads: 1024,
            elapsed_cycles: stats.attributed_total(),
            stats,
        }
    }

    #[test]
    fn paper_table2_4r1w_row_arithmetic() {
        // 32x32 4R-1W: common 391, load 256, store 1024 → total 1671,
        // time 2.17 µs at 771 MHz.
        let stats = CycleStats {
            int_cycles: 256,
            imm_cycles: 129,
            other_cycles: 6,
            d_load_cycles: 256,
            store_cycles: 1024,
            d_load_ops: 64,
            store_ops: 64,
            ..Default::default()
        };
        let r = report(stats, MemoryArchKind::mp_4r1w());
        assert_eq!(r.total_cycles(), 1671);
        assert!((r.time_us() - 2.17).abs() < 0.01);
        assert!(r.r_bank_eff().is_none(), "multiport rows leave eff. blank");
    }

    #[test]
    fn paper_table2_16bank_efficiencies() {
        // 32x32 16 Banks: load 168 (eff 38.1%), store 1054 (eff 6.1%).
        let stats = CycleStats {
            d_load_cycles: 168,
            d_load_ops: 64,
            store_cycles: 1054,
            store_ops: 64,
            ..Default::default()
        };
        let r = report(stats, MemoryArchKind::banked(16));
        assert!((r.r_bank_eff().unwrap() - 0.381).abs() < 0.001);
        assert!((r.w_bank_eff().unwrap() - 0.0607).abs() < 0.001);
    }

    #[test]
    fn paper_table3_efficiency_formula() {
        // Radix-4 4R-1W: FP 13440 of total 86817 → 15.5%.
        let stats = CycleStats {
            fp_cycles: 13_440,
            ..Default::default()
        };
        let r = RunReport {
            program: "fft".into(),
            arch: MemoryArchKind::mp_4r1w(),
            threads: 1024,
            stats,
            elapsed_cycles: 86_817,
        };
        assert!((r.compute_efficiency() - 0.155).abs() < 0.001);
    }

    #[test]
    fn fmax_4r2w_time() {
        // Radix-4 4R-2W: 62214 cycles at 600 MHz = 103.7 µs.
        let r = RunReport {
            program: "fft".into(),
            arch: MemoryArchKind::mp_4r2w(),
            threads: 1024,
            stats: CycleStats::default(),
            elapsed_cycles: 62_214,
        };
        assert!((r.time_us() - 103.69).abs() < 0.05);
    }

    #[test]
    fn common_and_attributed_sums() {
        let s = CycleStats {
            int_cycles: 10,
            imm_cycles: 20,
            fp_cycles: 30,
            other_cycles: 5,
            d_load_cycles: 100,
            tw_load_cycles: 50,
            store_cycles: 200,
            ..Default::default()
        };
        assert_eq!(s.common_cycles(), 65);
        assert_eq!(s.load_cycles(), 150);
        assert_eq!(s.attributed_total(), 415);
    }
}
