//! Machine configuration.

use crate::mem::arch::{MemoryArchKind, SharedMemory};
use crate::mem::banked::{BankedMemory, TimingMode};
use crate::mem::LANES;
use std::ops::Range;

/// Configuration of one simulated soft SIMT processor.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    /// Shared-memory architecture (one of the paper's nine).
    pub arch: MemoryArchKind,
    /// Shared-memory capacity in 32-bit words (power of two). The default,
    /// 64 Ki words = 256 KB, holds every paper benchmark (the 4096-point
    /// FFT needs "nearly 64KB with the required twiddle coefficients").
    pub mem_words: usize,
    /// Use the closed-form banked timing path instead of stepping the
    /// carry-chain arbiters (identical cycle counts — property-tested —
    /// but faster simulation; see DESIGN.md §Perf).
    pub fast_timing: bool,
    /// §IV-A half-bank configuration (+2 cycles of bank latency).
    pub half_banks: bool,
    /// Address range whose loads are classified as twiddle loads
    /// ("TW Load" rows of Table III). `None` classifies every load as a
    /// data load.
    pub tw_region: Option<Range<u32>>,
    /// Abort threshold for runaway programs (simulated cycles).
    pub max_cycles: u64,
    /// Companion guard on trace capture *memory*: maximum 16-lane memory
    /// operations a run may record before aborting with
    /// [`crate::sim::exec::SimError::TraceLimit`]. Raise it for
    /// legitimately huge programs (the default, ~16.8M operations, is
    /// far above any paper workload).
    pub max_trace_ops: u64,
}

impl MachineConfig {
    /// Default runaway-loop guard (simulated cycles). Also used by
    /// trace capture, which runs before any architecture is chosen.
    pub const DEFAULT_MAX_CYCLES: u64 = 2_000_000_000;

    /// Default configuration for a memory architecture.
    pub fn for_arch(arch: MemoryArchKind) -> Self {
        Self {
            arch,
            mem_words: 65_536,
            fast_timing: false,
            half_banks: false,
            tw_region: None,
            max_cycles: Self::DEFAULT_MAX_CYCLES,
            max_trace_ops: crate::sim::exec::ExecParams::DEFAULT_MAX_TRACE_OPS,
        }
    }

    /// Builder: shared-memory capacity in words.
    pub fn with_mem_words(mut self, words: usize) -> Self {
        assert!(words.is_power_of_two());
        self.mem_words = words;
        self
    }

    /// Builder: twiddle address region.
    pub fn with_tw_region(mut self, region: Range<u32>) -> Self {
        self.tw_region = Some(region);
        self
    }

    /// Builder: fast banked timing.
    pub fn with_fast_timing(mut self) -> Self {
        self.fast_timing = true;
        self
    }

    /// Builder: trace-capture size guard (see `max_trace_ops`).
    pub fn with_max_trace_ops(mut self, ops: u64) -> Self {
        self.max_trace_ops = ops;
        self
    }

    /// Build the configured shared memory (honouring the banked timing
    /// mode and half-bank knobs). Used by the [`crate::sim::machine`]
    /// facade and by the trace replayer, which needs a memory's cost
    /// model but never its data.
    pub fn build_memory(&self) -> Box<dyn SharedMemory> {
        match self.arch {
            MemoryArchKind::Banked { banks, mapping } => {
                let mut b = BankedMemory::new(self.mem_words, banks, mapping);
                if self.fast_timing {
                    b = b.with_mode(TimingMode::Fast);
                }
                if self.half_banks {
                    b = b.with_half_banks();
                }
                Box::new(b)
            }
            _ => self.arch.build(self.mem_words),
        }
    }

    /// Number of SIMT lanes (fixed at 16 — the paper's warp).
    pub const fn lanes(&self) -> usize {
        LANES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::OpKind;

    #[test]
    fn defaults() {
        let c = MachineConfig::for_arch(MemoryArchKind::banked(16));
        assert_eq!(c.mem_words, 65_536);
        assert_eq!(c.lanes(), 16);
        assert!(!c.fast_timing);
        assert!(c.tw_region.is_none());
        assert_eq!(c.max_cycles, MachineConfig::DEFAULT_MAX_CYCLES);
        assert_eq!(
            c.max_trace_ops,
            crate::sim::exec::ExecParams::DEFAULT_MAX_TRACE_OPS
        );
        assert_eq!(c.with_max_trace_ops(10).max_trace_ops, 10);
    }

    #[test]
    fn builders_compose() {
        let c = MachineConfig::for_arch(MemoryArchKind::mp_4r1w())
            .with_mem_words(16_384)
            .with_tw_region(8192..10_240)
            .with_fast_timing();
        assert_eq!(c.mem_words, 16_384);
        assert_eq!(c.tw_region, Some(8192..10_240));
        assert!(c.fast_timing);
    }

    #[test]
    fn build_memory_honours_knobs() {
        let mem = MachineConfig::for_arch(MemoryArchKind::banked(16))
            .with_mem_words(4096)
            .build_memory();
        assert_eq!(mem.words(), 4096);
        assert_eq!(mem.arch(), MemoryArchKind::banked(16));
        let mut cfg = MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(4096);
        cfg.half_banks = true;
        assert_eq!(cfg.build_memory().overhead(OpKind::Read), 14);
        let mp = MachineConfig::for_arch(MemoryArchKind::mp_4r1w())
            .with_mem_words(1024)
            .build_memory();
        assert_eq!(mp.arch(), MemoryArchKind::mp_4r1w());
    }

    #[test]
    #[should_panic]
    fn non_pow2_capacity_rejected() {
        MachineConfig::for_arch(MemoryArchKind::banked(4)).with_mem_words(1000);
    }
}
