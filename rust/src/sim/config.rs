//! Machine configuration.

use crate::mem::arch::MemoryArchKind;
use crate::mem::LANES;
use std::ops::Range;

/// Configuration of one simulated soft SIMT processor.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    /// Shared-memory architecture (one of the paper's nine).
    pub arch: MemoryArchKind,
    /// Shared-memory capacity in 32-bit words (power of two). The default,
    /// 64 Ki words = 256 KB, holds every paper benchmark (the 4096-point
    /// FFT needs "nearly 64KB with the required twiddle coefficients").
    pub mem_words: usize,
    /// Use the closed-form banked timing path instead of stepping the
    /// carry-chain arbiters (identical cycle counts — property-tested —
    /// but faster simulation; see DESIGN.md §Perf).
    pub fast_timing: bool,
    /// §IV-A half-bank configuration (+2 cycles of bank latency).
    pub half_banks: bool,
    /// Address range whose loads are classified as twiddle loads
    /// ("TW Load" rows of Table III). `None` classifies every load as a
    /// data load.
    pub tw_region: Option<Range<u32>>,
    /// Abort threshold for runaway programs (simulated cycles).
    pub max_cycles: u64,
    /// Record the per-instruction memory-operation trace (addresses and
    /// lane masks) during the run — the input to the analytical timing
    /// oracle ([`crate::runtime::analytical`]).
    pub collect_mem_trace: bool,
}

impl MachineConfig {
    /// Default configuration for a memory architecture.
    pub fn for_arch(arch: MemoryArchKind) -> Self {
        Self {
            arch,
            mem_words: 65_536,
            fast_timing: false,
            half_banks: false,
            tw_region: None,
            max_cycles: 2_000_000_000,
            collect_mem_trace: false,
        }
    }

    /// Builder: shared-memory capacity in words.
    pub fn with_mem_words(mut self, words: usize) -> Self {
        assert!(words.is_power_of_two());
        self.mem_words = words;
        self
    }

    /// Builder: twiddle address region.
    pub fn with_tw_region(mut self, region: Range<u32>) -> Self {
        self.tw_region = Some(region);
        self
    }

    /// Builder: fast banked timing.
    pub fn with_fast_timing(mut self) -> Self {
        self.fast_timing = true;
        self
    }

    /// Builder: record the memory-operation trace.
    pub fn with_mem_trace(mut self) -> Self {
        self.collect_mem_trace = true;
        self
    }

    /// Number of SIMT lanes (fixed at 16 — the paper's warp).
    pub const fn lanes(&self) -> usize {
        LANES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let c = MachineConfig::for_arch(MemoryArchKind::banked(16));
        assert_eq!(c.mem_words, 65_536);
        assert_eq!(c.lanes(), 16);
        assert!(!c.fast_timing);
        assert!(c.tw_region.is_none());
    }

    #[test]
    fn builders_compose() {
        let c = MachineConfig::for_arch(MemoryArchKind::mp_4r1w())
            .with_mem_words(16_384)
            .with_tw_region(8192..10_240)
            .with_fast_timing();
        assert_eq!(c.mem_words, 16_384);
        assert_eq!(c.tw_region, Some(8192..10_240));
        assert!(c.fast_timing);
    }

    #[test]
    #[should_panic]
    fn non_pow2_capacity_rejected() {
        MachineConfig::for_arch(MemoryArchKind::banked(4)).with_mem_words(1000);
    }
}
