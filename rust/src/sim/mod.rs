//! The cycle-accurate SIMT machine (paper §III, Fig. 1).
//!
//! Sixteen SPs execute every instruction for all threads in the block,
//! sixteen threads per clock (one memory *operation* per clock, each
//! carrying up to sixteen *requests*). ALU instructions stream one
//! operation per cycle; memory instructions go through the shared-memory
//! access controllers whose timing depends on the configured architecture
//! ([`crate::mem`]).

pub mod config;
pub mod machine;
pub mod regfile;
pub mod stats;

pub use config::MachineConfig;
pub use machine::{Machine, SimError};
pub use stats::{CycleStats, RunReport};
