//! The cycle-accurate SIMT machine (paper §III, Fig. 1), decoupled into a
//! functional-execution core and a timing-replay engine.
//!
//! Sixteen SPs execute every instruction for all threads in the block,
//! sixteen threads per clock (one memory *operation* per clock, each
//! carrying up to sixteen *requests*). ALU instructions stream one
//! operation per cycle; memory instructions go through the shared-memory
//! access controllers whose timing depends on the configured architecture
//! ([`crate::mem`]).
//!
//! Layering (DESIGN.md §Two-phase):
//!
//! - [`exec`] — architecture-independent functional core: runs a program
//!   once, emits a complete [`exec::MemTrace`];
//! - [`replay`] — reference timing replay: charges any
//!   [`crate::mem::SharedMemory`] cost model from a trace, producing a
//!   [`stats::RunReport`];
//! - [`compiled`] — compiled-trace batch replay: a [`compiled::CompiledTrace`]
//!   precomputes every bank-mapping family's conflict maxima once, then
//!   [`compiled::replay_many`] charges a whole slate of architectures in a
//!   single trace walk, bit-identically to [`replay`] (DESIGN.md §Replay);
//! - [`packed`] — the lane-packed production kernel over the same
//!   compiled traces: [`packed::LaneChunk`]s advance eight architectures
//!   per step in structure-of-arrays form, resumable at instruction
//!   boundaries ([`packed::replay_many_packed`]), bit-identical to the
//!   scalar [`compiled::replay_many`];
//! - [`machine`] — the facade that runs execute + replay in lockstep,
//!   preserving the original coupled-simulator API.

pub mod compiled;
pub mod config;
pub mod exec;
pub mod machine;
pub mod packed;
pub mod regfile;
pub mod replay;
pub mod stats;

pub use compiled::{replay_compiled, replay_many, CompiledTrace};
pub use packed::{replay_many_packed, LaneChunk, ARCH_LANES};
pub use config::MachineConfig;
pub use exec::{execute, ExecMemory, ExecParams, FlatMemory, MemTrace, SimError};
pub use machine::Machine;
pub use replay::replay;
pub use stats::{CycleStats, RunReport};
