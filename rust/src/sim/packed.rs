//! Lane-packed, segment-resumable batch replay — the production kernel
//! behind every multi-architecture charge path (DESIGN.md §Replay).
//!
//! The scalar [`replay_many`](super::compiled::replay_many) advances one
//! [`ArchCost`] state at a time per instruction: per candidate it
//! dispatches on the cost kind, slices a conflict row, and updates a
//! full [`CycleStats`]. This module applies the source paper's own
//! trick — lock-step lanes over shared control flow — to the replayer
//! itself:
//!
//! - **Arch-lane packing.** Candidates are packed into [`LaneChunk`]s of
//!   [`ARCH_LANES`] architectures in structure-of-arrays form: the
//!   clocks, per-class memory-cycle counters and write-pipeline scalars
//!   are `[u64; ARCH_LANES]` arrays advanced together (plain indexed
//!   loops over fixed-size arrays on stable Rust — shaped for the
//!   autovectorizer, no `std::simd`). Per-lane costs are pre-resolved at
//!   chunk setup into dense 17-entry tables
//!   ([`ArchCost::cost_table`]), so the load/store inner loops are a
//!   branch-free gather — `table[lane][row[slot[lane]]]` — with no
//!   per-arch dispatch. The architecture-independent statistics are not
//!   touched at all: [`CompiledTrace`] precomputes them once
//!   (`base_stats`), and a lane only tracks the five memory-timing
//!   counters that actually depend on the architecture.
//!
//! - **Segments.** [`LaneChunk::advance`] replays any instruction
//!   subrange, and [`LaneChunk::suspend`]/[`LaneChunk::resume`] move the
//!   full seam state — clock offsets, partial memory-cycle counters, and
//!   the write pipelines' in-flight drain state
//!   ([`PipesCheckpoint`]) — so a trace can be replayed segment by
//!   segment and stitched bit-identically to the straight-through walk
//!   (`rust/tests/replay_diff.rs` pins this under random split points).
//!   The parallel driver ([`SweepRunner::replay_many_parallel`]) walks
//!   chunks over segments as a barrier-synchronized wavefront: every
//!   worker advances a different chunk through the *same* segment (the
//!   compiled rows of the segment stay hot in cache across workers), and
//!   chunks whose candidates have all exceeded the cycle limit are
//!   swap-compacted out of the active set at segment boundaries.
//!
//! - **Cycle limits without per-instruction checks.** Every charge is
//!   non-negative, so a lane's clock is monotone non-decreasing across
//!   instructions; the reference per-instruction `now > max_cycles`
//!   check therefore trips iff the *final* clock (after the tail
//!   charges) exceeds the limit. [`LaneChunk::finish`] applies exactly
//!   that end-of-walk check, yielding per-lane `CycleLimit` verdicts
//!   bit-identical to the scalar path without masking inside the hot
//!   loops. A failed lane keeps accumulating harmless (finite) garbage
//!   until its whole chunk fails and is compacted.
//!
//! [`SweepRunner::replay_many_parallel`]:
//!     crate::coordinator::runner::SweepRunner::replay_many_parallel

use super::compiled::CompiledTrace;
use super::exec::{LoadClass, MemAccessKind, SimError};
use super::stats::RunReport;
use crate::mem::arch::{MemoryArchKind, OpKind};
use crate::mem::compiled::{ArchCost, COST_TABLE_LEN, GATHER_WIDTH};
use crate::mem::controller::{LaneWritePipes, PipesCheckpoint};
use std::ops::Range;

/// Architectures charged per lock-step chunk. Eight `u64` lanes fill a
/// 512-bit vector register; the remainder chunk of a non-multiple slate
/// pads with copies of lane 0 (computed and discarded).
pub const ARCH_LANES: usize = 8;

/// Default instructions per replay segment: long enough that the
/// per-segment barrier and compaction sweep are noise, short enough that
/// a whole-slate cycle-limit failure is caught well before the end of a
/// multi-million-instruction trace.
pub const SEGMENT_INSTRS: usize = 4096;

/// A structure-of-arrays chunk of up to [`ARCH_LANES`] candidate
/// architectures replaying one [`CompiledTrace`] in lock step.
#[derive(Debug, Clone)]
pub struct LaneChunk {
    /// Real candidates in this chunk (`1..=ARCH_LANES`); higher lanes are
    /// padding that mirrors lane 0.
    lanes: usize,
    costs: [ArchCost; ARCH_LANES],
    // Per-lane cost resolution, pre-gathered at setup: slot into the
    // compiled gather row, then a dense table over the gathered byte.
    read_slot: [usize; ARCH_LANES],
    write_slot: [usize; ARCH_LANES],
    read_tab: [[u32; COST_TABLE_LEN]; ARCH_LANES],
    write_tab: [[u32; COST_TABLE_LEN]; ARCH_LANES],
    read_overhead: [u64; ARCH_LANES],
    write_overhead: [u32; ARCH_LANES],
    // Mutable lane state: the clock and the five architecture-dependent
    // counters (everything else comes from `CompiledTrace::base_stats`).
    now: [u64; ARCH_LANES],
    d_load_cycles: [u64; ARCH_LANES],
    tw_load_cycles: [u64; ARCH_LANES],
    store_cycles: [u64; ARCH_LANES],
    wbuf_stall_cycles: [u64; ARCH_LANES],
    pipes: LaneWritePipes<ARCH_LANES>,
}

/// Everything a [`LaneChunk`] carries across a segment seam: clock
/// offsets, the partial memory-cycle counters, and the write pipelines'
/// pending drain state. Applying `resume(suspend())` on a fresh chunk of
/// the same candidates continues the walk bit-identically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkCheckpoint {
    pub now: [u64; ARCH_LANES],
    pub d_load_cycles: [u64; ARCH_LANES],
    pub tw_load_cycles: [u64; ARCH_LANES],
    pub store_cycles: [u64; ARCH_LANES],
    pub wbuf_stall_cycles: [u64; ARCH_LANES],
    pub pipes: PipesCheckpoint<ARCH_LANES>,
}

impl LaneChunk {
    /// Pack `archs` (1..=[`ARCH_LANES`] candidates) against `trace`'s
    /// capacity: resolve every lane's cost tables and write-buffer depth
    /// once, before any instruction is walked.
    pub fn new(trace: &CompiledTrace, archs: &[MemoryArchKind]) -> Self {
        assert!(!archs.is_empty() && archs.len() <= ARCH_LANES);
        // Padding lanes replicate lane 0: they charge real (discarded)
        // work, keeping every inner loop branch-free over ARCH_LANES.
        let costs: [ArchCost; ARCH_LANES] =
            std::array::from_fn(|l| trace.arch_cost(archs[if l < archs.len() { l } else { 0 }]));
        let mut depths = [0u32; ARCH_LANES];
        for (d, c) in depths.iter_mut().zip(&costs) {
            *d = c.write_buffer_ops();
        }
        Self {
            lanes: archs.len(),
            read_slot: std::array::from_fn(|l| costs[l].gather_slot()),
            write_slot: std::array::from_fn(|l| costs[l].gather_slot()),
            read_tab: std::array::from_fn(|l| costs[l].cost_table(OpKind::Read)),
            write_tab: std::array::from_fn(|l| costs[l].cost_table(OpKind::Write)),
            read_overhead: std::array::from_fn(|l| u64::from(costs[l].overhead(OpKind::Read))),
            write_overhead: std::array::from_fn(|l| costs[l].overhead(OpKind::Write)),
            now: [0; ARCH_LANES],
            d_load_cycles: [0; ARCH_LANES],
            tw_load_cycles: [0; ARCH_LANES],
            store_cycles: [0; ARCH_LANES],
            wbuf_stall_cycles: [0; ARCH_LANES],
            pipes: LaneWritePipes::new(depths),
            costs,
        }
    }

    /// Real (non-padding) candidates in this chunk.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Advance every lane through the compiled instructions `instrs` — a
    /// whole trace (`0..trace.n_instrs()`) or one segment of it.
    pub fn advance(&mut self, trace: &CompiledTrace, instrs: Range<usize>) {
        for instr in &trace.instrs()[instrs] {
            let alu = instr.before.cycles();
            for now in self.now.iter_mut() {
                *now += alu;
            }
            match instr.kind {
                MemAccessKind::Load(class) => {
                    // Gather + lane-wise add: the hot loop. Costs are
                    // independent per op (reads don't queue), so the
                    // per-lane attributed sum accumulates locally and
                    // the clock/counters update once per instruction.
                    let mut acc = [0u64; ARCH_LANES];
                    for op in instr.ops.clone() {
                        let row = trace.gather_row(op);
                        for l in 0..ARCH_LANES {
                            acc[l] += u64::from(self.read_tab[l][row[self.read_slot[l]] as usize]);
                        }
                    }
                    let bucket = match class {
                        LoadClass::Data => &mut self.d_load_cycles,
                        LoadClass::Twiddle => &mut self.tw_load_cycles,
                    };
                    for l in 0..ARCH_LANES {
                        let attributed = self.read_overhead[l] + acc[l];
                        self.now[l] += attributed;
                        bucket[l] += attributed;
                    }
                }
                MemAccessKind::Store { blocking } => {
                    let start = self.now;
                    let mut iss = self.now;
                    for op in instr.ops.clone() {
                        let row = trace.gather_row(op);
                        for l in 0..ARCH_LANES {
                            let cost = self.write_tab[l][row[self.write_slot[l]] as usize];
                            let before = iss[l];
                            iss[l] = self.pipes.issue(l, before, cost, self.write_overhead[l]);
                            self.wbuf_stall_cycles[l] += iss[l].saturating_sub(before + 1);
                        }
                    }
                    if blocking {
                        for l in 0..ARCH_LANES {
                            let end = self.pipes.drain(l, iss[l]);
                            self.store_cycles[l] += end - start[l];
                            self.now[l] = end;
                        }
                    } else {
                        for l in 0..ARCH_LANES {
                            self.store_cycles[l] += self
                                .pipes
                                .busy_until(l)
                                .saturating_sub(start[l])
                                .max(iss[l] - start[l]);
                            self.now[l] = iss[l];
                        }
                    }
                }
            }
        }
    }

    /// True when every real lane's clock already exceeds `max_cycles` —
    /// the clock is monotone, so the chunk's verdicts are all sealed as
    /// [`SimError::CycleLimit`] and the walk can stop charging it.
    pub fn all_failed(&self, max_cycles: u64) -> bool {
        self.now[..self.lanes].iter().all(|&now| now > max_cycles)
    }

    /// Snapshot the seam state (see [`ChunkCheckpoint`]).
    pub fn suspend(&self) -> ChunkCheckpoint {
        ChunkCheckpoint {
            now: self.now,
            d_load_cycles: self.d_load_cycles,
            tw_load_cycles: self.tw_load_cycles,
            store_cycles: self.store_cycles,
            wbuf_stall_cycles: self.wbuf_stall_cycles,
            pipes: self.pipes.checkpoint(),
        }
    }

    /// Restore the seam state captured by [`Self::suspend`] — the chunk
    /// continues exactly where the suspended walk left off.
    pub fn resume(&mut self, cp: &ChunkCheckpoint) {
        self.now = cp.now;
        self.d_load_cycles = cp.d_load_cycles;
        self.tw_load_cycles = cp.tw_load_cycles;
        self.store_cycles = cp.store_cycles;
        self.wbuf_stall_cycles = cp.wbuf_stall_cycles;
        self.pipes.restore(&cp.pipes);
    }

    /// Tail charges + halt/drain per lane, producing one result per real
    /// candidate (in lane order). The single end-of-walk limit check is
    /// verdict-identical to the scalar per-instruction check (module
    /// docs: monotone clock).
    pub fn finish(mut self, trace: &CompiledTrace, max_cycles: u64) -> Vec<Result<RunReport, SimError>> {
        let tail = trace.tail_charges().cycles();
        (0..self.lanes)
            .map(|l| {
                let mut now = self.now[l] + tail;
                if now > max_cycles {
                    return Err(SimError::CycleLimit { limit: max_cycles });
                }
                now += 1;
                let drained = self.pipes.drain(l, now);
                let mut stats = trace.base_stats();
                stats.d_load_cycles = self.d_load_cycles[l];
                stats.tw_load_cycles = self.tw_load_cycles[l];
                stats.store_cycles = self.store_cycles[l];
                stats.wbuf_stall_cycles = self.wbuf_stall_cycles[l];
                stats.drain_cycles = drained - now;
                Ok(RunReport {
                    program: trace.program().to_string(),
                    arch: self.costs[l].arch(),
                    threads: trace.threads(),
                    stats,
                    elapsed_cycles: drained,
                })
            })
            .collect()
    }

    /// The candidate verdicts of a chunk compacted out mid-walk: every
    /// real lane sealed its [`SimError::CycleLimit`].
    pub fn fail_all(&self, max_cycles: u64) -> Vec<Result<RunReport, SimError>> {
        debug_assert!(self.all_failed(max_cycles));
        (0..self.lanes).map(|_| Err(SimError::CycleLimit { limit: max_cycles })).collect()
    }
}

/// Occupancy/work tally of one or more packed replay driver calls —
/// accumulated in **locals** during the walk and flushed into the
/// [`MetricsRegistry`](crate::obs::MetricsRegistry) once per call by
/// whoever holds a registry handle, so the packed kernel itself never
/// touches an atomic (DESIGN.md §Observability).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ReplayTally {
    /// Driver calls folded into this tally.
    pub invocations: u64,
    /// [`LaneChunk`]s charged.
    pub chunks: u64,
    /// Architecture lanes actually occupied across those chunks.
    pub lanes_used: u64,
    /// Lane slots available (`chunks × ARCH_LANES`); `lanes_used /
    /// lane_slots` is the packed occupancy.
    pub lane_slots: u64,
    /// Chunk-segment advances performed (one per chunk per
    /// [`SEGMENT_INSTRS`] window it stayed active for).
    pub segments: u64,
}

impl ReplayTally {
    /// Fold another driver call's tally into this one.
    pub fn merge(&mut self, other: &ReplayTally) {
        self.invocations += other.invocations;
        self.chunks += other.chunks;
        self.lanes_used += other.lanes_used;
        self.lane_slots += other.lane_slots;
        self.segments += other.segments;
    }
}

/// Charge every architecture in `archs` through the lane-packed kernel,
/// single-threaded: candidates pack into [`ARCH_LANES`]-wide chunks, and
/// each chunk walks the trace in [`SEGMENT_INSTRS`] segments with
/// all-failed chunks compacted out at segment boundaries. Results in
/// `archs` order, `RunReport`-bit-identical to the scalar
/// [`replay_many`](super::compiled::replay_many) (and so to the
/// reference [`replay`](super::replay::replay)) — pinned by
/// `rust/tests/replay_diff.rs`.
pub fn replay_many_packed(
    trace: &CompiledTrace,
    archs: &[MemoryArchKind],
    max_cycles: u64,
) -> Vec<Result<RunReport, SimError>> {
    replay_many_packed_counted(trace, archs, max_cycles).0
}

/// [`replay_many_packed`] plus the walk's [`ReplayTally`]. The tally
/// costs a few local integer adds per segment — callers without a
/// metrics registry use the plain wrapper and drop it.
pub fn replay_many_packed_counted(
    trace: &CompiledTrace,
    archs: &[MemoryArchKind],
    max_cycles: u64,
) -> (Vec<Result<RunReport, SimError>>, ReplayTally) {
    let mut chunks: Vec<LaneChunk> =
        archs.chunks(ARCH_LANES).map(|c| LaneChunk::new(trace, c)).collect();
    let mut tally = ReplayTally {
        invocations: 1,
        chunks: chunks.len() as u64,
        lanes_used: archs.len() as u64,
        lane_slots: (chunks.len() * ARCH_LANES) as u64,
        segments: 0,
    };
    let n_instrs = trace.n_instrs();
    // Active set of chunk indices; all-failed chunks swap-compact out.
    let mut active: Vec<usize> = (0..chunks.len()).collect();
    let mut start = 0;
    while start < n_instrs && !active.is_empty() {
        let end = (start + SEGMENT_INSTRS).min(n_instrs);
        let mut i = 0;
        while i < active.len() {
            let chunk = &mut chunks[active[i]];
            chunk.advance(trace, start..end);
            tally.segments += 1;
            if chunk.all_failed(max_cycles) {
                active.swap_remove(i);
            } else {
                i += 1;
            }
        }
        start = end;
    }
    let reports = chunks
        .into_iter()
        .flat_map(|chunk| {
            if chunk.all_failed(max_cycles) {
                chunk.fail_all(max_cycles)
            } else {
                chunk.finish(trace, max_cycles)
            }
        })
        .collect();
    (reports, tally)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{FULL_MASK, LANES};
    use crate::sim::compiled::{replay_many, CompiledTrace};
    use crate::sim::exec::{MemInstr, MemTrace};

    fn seq_addrs(stride: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = l as u32 * stride;
        }
        a
    }

    fn mixed_trace() -> MemTrace {
        let instrs = vec![
            MemInstr {
                kind: MemAccessKind::Load(LoadClass::Data),
                ops: vec![(seq_addrs(1), FULL_MASK), (seq_addrs(16), FULL_MASK)],
            },
            MemInstr {
                kind: MemAccessKind::Store { blocking: false },
                ops: vec![(seq_addrs(16), FULL_MASK); 4],
            },
            MemInstr {
                kind: MemAccessKind::Load(LoadClass::Twiddle),
                ops: vec![(seq_addrs(4), 0x0F0F)],
            },
            MemInstr {
                kind: MemAccessKind::Store { blocking: true },
                ops: vec![(seq_addrs(2), 0x00FF); 2],
            },
        ];
        MemTrace::from_mem_instrs("mixed", 256, instrs)
    }

    fn assert_same(a: &[Result<RunReport, SimError>], b: &[Result<RunReport, SimError>]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            match (x, y) {
                (Ok(p), Ok(q)) => {
                    assert_eq!(p.stats, q.stats, "{}", p.arch);
                    assert_eq!(p.elapsed_cycles, q.elapsed_cycles, "{}", p.arch);
                    assert_eq!(p.arch, q.arch);
                    assert_eq!(p.program, q.program);
                    assert_eq!(p.threads, q.threads);
                }
                (
                    Err(SimError::CycleLimit { limit: p }),
                    Err(SimError::CycleLimit { limit: q }),
                ) => assert_eq!(p, q),
                other => panic!("verdicts diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn packed_equals_scalar_on_paper_archs() {
        let trace = mixed_trace();
        let ct = CompiledTrace::compile(&trace);
        let archs = MemoryArchKind::table3_nine(); // 9: exercises a remainder lane
        let packed = replay_many_packed(&ct, &archs, u64::MAX);
        let scalar = replay_many(&ct, &archs, u64::MAX);
        assert_same(&packed, &scalar);
    }

    #[test]
    fn packed_cycle_limit_verdicts_match_scalar() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(16), FULL_MASK); 64],
        };
        let trace = MemTrace::from_mem_instrs("slow", 1024, vec![mi]);
        let ct = CompiledTrace::compile(&trace);
        let archs = [MemoryArchKind::mp_4r1w(), MemoryArchKind::banked(16)];
        for limit in [1, 100, 300, 2000, u64::MAX] {
            let packed = replay_many_packed(&ct, &archs, limit);
            let scalar = replay_many(&ct, &archs, limit);
            assert_same(&packed, &scalar);
        }
    }

    #[test]
    fn chunk_segmented_walk_stitches_bit_identically() {
        let trace = mixed_trace();
        let ct = CompiledTrace::compile(&trace);
        let archs = MemoryArchKind::table3_nine();
        let whole = replay_many_packed(&ct, &archs, u64::MAX);
        // Walk instruction-by-instruction through suspend/resume seams.
        let out: Vec<_> = archs
            .chunks(ARCH_LANES)
            .flat_map(|c| {
                let mut chunk = LaneChunk::new(&ct, c);
                for i in 0..ct.n_instrs() {
                    chunk.advance(&ct, i..i + 1);
                    let seam = chunk.suspend();
                    let mut fresh = LaneChunk::new(&ct, c);
                    fresh.resume(&seam);
                    assert_eq!(fresh.suspend(), seam);
                    chunk = fresh;
                }
                chunk.finish(&ct, u64::MAX)
            })
            .collect();
        assert_same(&out, &whole);
    }

    #[test]
    fn empty_trace_is_just_halt() {
        let trace = MemTrace::from_mem_instrs("empty", 16, vec![]);
        let ct = CompiledTrace::compile(&trace);
        let out = replay_many_packed(&ct, &MemoryArchKind::table3_nine(), 1000);
        for r in out {
            let r = r.unwrap();
            assert_eq!(r.total_cycles(), 1);
            assert_eq!(r.stats.instructions, 1);
        }
    }

    #[test]
    fn single_arch_chunk_pads_cleanly() {
        let ct = CompiledTrace::compile(&mixed_trace());
        let archs = [MemoryArchKind::banked_offset(8)];
        let packed = replay_many_packed(&ct, &archs, u64::MAX);
        let scalar = replay_many(&ct, &archs, u64::MAX);
        assert_eq!(packed.len(), 1);
        assert_same(&packed, &scalar);
    }
}
