//! Per-thread register file.
//!
//! The eGPU maps register files onto M20Ks (two per SP — Table I); with 16
//! resident threads per SP that is 64 registers per thread. The simulator
//! stores them as one flat array indexed `[thread * 64 + reg]` so warp
//! accesses stride contiguously.

use crate::isa::inst::NUM_REGS;

/// Register file for a whole thread block.
#[derive(Debug, Clone)]
pub struct RegFile {
    regs: Vec<u32>,
    threads: u32,
}

impl RegFile {
    pub fn new(threads: u32) -> Self {
        Self {
            regs: vec![0u32; threads as usize * NUM_REGS],
            threads,
        }
    }

    pub fn threads(&self) -> u32 {
        self.threads
    }

    /// Read register `r` of thread `t` as an integer.
    ///
    /// §Perf: this is the innermost memory access of the whole simulator
    /// (3 per ALU thread-op); the bound is enforced structurally instead
    /// of per access — `t < threads` is guaranteed by every caller's loop
    /// bound and `r < 64` by the 6-bit register fields of
    /// [`crate::isa::inst::Instruction::decode`] — and re-checked in
    /// debug builds.
    #[inline]
    pub fn get(&self, t: u32, r: u8) -> u32 {
        debug_assert!(t < self.threads && (r as usize) < NUM_REGS);
        // SAFETY: regs.len() == threads * NUM_REGS; t < threads and
        // r < NUM_REGS per above.
        unsafe { *self.regs.get_unchecked(t as usize * NUM_REGS + r as usize) }
    }

    /// Write register `r` of thread `t`.
    #[inline]
    pub fn set(&mut self, t: u32, r: u8, v: u32) {
        debug_assert!(t < self.threads && (r as usize) < NUM_REGS);
        // SAFETY: as in [`Self::get`].
        unsafe {
            *self.regs.get_unchecked_mut(t as usize * NUM_REGS + r as usize) = v;
        }
    }

    /// Read as IEEE-754 single (the SPs' FP view of the same registers).
    #[inline]
    pub fn get_f32(&self, t: u32, r: u8) -> f32 {
        f32::from_bits(self.get(t, r))
    }

    /// Write an IEEE-754 single.
    #[inline]
    pub fn set_f32(&mut self, t: u32, r: u8, v: f32) {
        self.set(t, r, v.to_bits());
    }

    /// Reset all registers to zero (block re-launch).
    pub fn clear(&mut self) {
        self.regs.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_roundtrip() {
        let mut rf = RegFile::new(4);
        rf.set(3, 63, 0xDEAD_BEEF);
        assert_eq!(rf.get(3, 63), 0xDEAD_BEEF);
        assert_eq!(rf.get(0, 63), 0);
    }

    #[test]
    fn f32_roundtrip_bit_exact() {
        let mut rf = RegFile::new(1);
        for v in [0.0f32, -0.0, 1.5, f32::INFINITY, f32::MIN_POSITIVE] {
            rf.set_f32(0, 1, v);
            assert_eq!(rf.get_f32(0, 1).to_bits(), v.to_bits());
        }
        // NaN payload preserved (registers are raw bits).
        rf.set(0, 2, 0x7FC0_1234);
        assert!(rf.get_f32(0, 2).is_nan());
        assert_eq!(rf.get(0, 2), 0x7FC0_1234);
    }

    #[test]
    fn threads_isolated() {
        let mut rf = RegFile::new(16);
        for t in 0..16 {
            rf.set(t, 5, t * 10);
        }
        for t in 0..16 {
            assert_eq!(rf.get(t, 5), t * 10);
        }
    }

    #[test]
    fn clear_zeroes_everything() {
        let mut rf = RegFile::new(2);
        rf.set(1, 1, 7);
        rf.clear();
        assert_eq!(rf.get(1, 1), 0);
    }
}
