//! The SIMT machine facade: functional execution + timing replay in
//! lockstep (paper Fig. 1; DESIGN.md §Two-phase).
//!
//! Execution model: one instruction at a time, executed for *every* thread
//! in the block before the next instruction starts (§III: "an instruction
//! will typically execute all threads before starting the next
//! instruction"). With `T` threads and 16 lanes, an instruction issues
//! `⌈T/16⌉` operations, one per clock for ALU classes; memory instructions
//! are timed by the configured [`SharedMemory`] and the §III-A controller
//! model ([`crate::mem::controller::WritePipeline`]).
//!
//! Since the execution/timing split, [`Machine::run_program`] is a thin
//! facade over the two decoupled halves: the architecture-independent
//! functional core ([`crate::sim::exec`]) runs the program against this
//! machine's shared memory and emits a complete [`MemTrace`]; the timing
//! replay engine ([`crate::sim::replay`]) then charges that trace against
//! the memory's cost model. The sweep path reuses the same two halves
//! with a trace cache ([`crate::coordinator::job::TraceCache`]) so one
//! functional execution times all nine memories.
//!
//! Control flow may diverge per lane: a `bnz` whose threads disagree
//! splits the block onto a reconvergence stack (taken path first) and
//! serializes both paths until they rejoin at the branch's immediate
//! post-dominator ([`crate::isa::cfg`], DESIGN.md §Divergence). The
//! resulting per-op lane masks flow through the trace, so every replay
//! path times divergent programs identically.
//!
//! Errors are [`SimError`] throughout (a proper `std::error::Error`;
//! typed ISA failures like [`crate::isa::program::DecodeError`] fold in
//! via `From`), and `SimError` in turn folds into the service layer's
//! [`crate::service::ServiceError`] — one error lineage from lane fault
//! to process exit code.

use super::config::MachineConfig;
use super::exec::{self, ExecParams, MemTrace};
use super::replay;
use super::stats::RunReport;
use crate::isa::program::Program;
use crate::mem::arch::SharedMemory;

pub use super::exec::SimError;

/// The simulated processor.
pub struct Machine {
    cfg: MachineConfig,
    mem: Box<dyn SharedMemory>,
    trace: Option<MemTrace>,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Self {
        let mem = cfg.build_memory();
        Self { cfg, mem, trace: None }
    }

    /// The complete memory-operation trace of the last successful run
    /// (`None` before the first run). Always captured — the decoupled
    /// execution core emits it as a by-product.
    pub fn mem_trace(&self) -> Option<&MemTrace> {
        self.trace.as_ref()
    }

    /// The machine configuration.
    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    /// Direct shared-memory access (image loading / validation).
    pub fn mem(&self) -> &dyn SharedMemory {
        self.mem.as_ref()
    }

    /// Load a word image into shared memory starting at `base`.
    pub fn load_image(&mut self, base: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            self.mem.poke(base + i as u32, w);
        }
    }

    /// Load an f32 image (bit-cast) into shared memory starting at `base`.
    pub fn load_f32_image(&mut self, base: u32, values: &[f32]) {
        for (i, &v) in values.iter().enumerate() {
            self.mem.poke(base + i as u32, v.to_bits());
        }
    }

    /// Read back `n` f32 words starting at `base`.
    pub fn read_f32_image(&self, base: u32, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| f32::from_bits(self.mem.peek(base + i as u32)))
            .collect()
    }

    /// Read back `n` u32 words starting at `base`.
    pub fn read_image(&self, base: u32, n: usize) -> Vec<u32> {
        (0..n).map(|i| self.mem.peek(base + i as u32)).collect()
    }

    /// Run a program to `halt`, returning the per-class cycle report.
    ///
    /// Execute-then-replay: the functional core runs the program once
    /// against this machine's memory image and emits the trace; the
    /// replay engine charges the trace against this memory's timing
    /// model. The report is bit-identical to the historical coupled
    /// simulator (the per-instruction charges are applied in the same
    /// order with the same state).
    pub fn run_program(&mut self, program: &Program) -> Result<RunReport, SimError> {
        let params = ExecParams {
            tw_region: self.cfg.tw_region.clone(),
            max_cycles: self.cfg.max_cycles,
            max_trace_ops: self.cfg.max_trace_ops,
        };
        let trace = exec::execute(program, &mut self.mem, &params)?;
        let report = replay::replay(&trace, self.mem.as_ref(), self.cfg.max_cycles)?;
        self.trace = Some(trace);
        Ok(report)
    }
}

impl exec::ExecMemory for Machine {
    fn words(&self) -> usize {
        SharedMemory::words(self.mem.as_ref())
    }

    fn read_word(&self, addr: u32) -> u32 {
        self.mem.peek(addr)
    }

    fn write_word(&mut self, addr: u32, value: u32) {
        self.mem.poke(addr, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;
    use crate::mem::arch::MemoryArchKind;

    fn machine(arch: MemoryArchKind) -> Machine {
        Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096))
    }

    fn run(src: &str, arch: MemoryArchKind) -> (Machine, RunReport) {
        let p = assemble(src).expect("assembles");
        let mut m = machine(arch);
        let r = m.run_program(&p).expect("runs");
        (m, r)
    }

    #[test]
    fn tid_and_store_roundtrip() {
        // Each thread writes its tid to shared[tid].
        let src = "
.threads 64
    tid  r0
    st   [r0], r0
    halt
";
        let (m, r) = run(src, MemoryArchKind::banked(16));
        for t in 0..64 {
            assert_eq!(m.mem().peek(t), t);
        }
        assert_eq!(r.stats.store_ops, 4);
        assert_eq!(r.threads, 64);
    }

    #[test]
    fn alu_cycle_accounting() {
        // 64 threads = 4 operations per instruction.
        let src = "
.threads 64
    tid   r0
    ldi   r1, 3
    iadd  r2, r0, r1
    itof  r3, r2
    fadd  r4, r3, r3
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        assert_eq!(r.stats.imm_cycles, 4); // ldi
        assert_eq!(r.stats.int_cycles, 4); // iadd
        assert_eq!(r.stats.fp_cycles, 8); // itof + fadd
        assert_eq!(r.stats.other_cycles, 4 + 1); // tid (per-op) + halt
        assert_eq!(r.total_cycles(), 21);
        assert_eq!(r.stats.attributed_total(), 21);
    }

    #[test]
    fn multiport_load_costs_match_paper_model() {
        // 64 threads → 4 read ops × ⌈16/4⌉ = 16 cycles, zero overhead.
        let src = "
.threads 64
    tid  r0
    ld   r1, [r0]
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        assert_eq!(r.stats.d_load_cycles, 16);
        assert_eq!(r.stats.d_load_ops, 4);
    }

    #[test]
    fn banked_conflict_free_load() {
        // Consecutive tids → conflict-free: 4 ops + 12 overhead.
        let src = "
.threads 64
    tid  r0
    ld   r1, [r0]
    halt
";
        let (_, r) = run(src, MemoryArchKind::banked(16));
        assert_eq!(r.stats.d_load_cycles, 12 + 4);
    }

    #[test]
    fn banked_full_conflict_store() {
        // Every thread writes address tid*16 → all lanes hit bank 0:
        // each op costs 16; blocking store = 5 (overhead) + 64 cycles.
        let src = "
.threads 64
    tid   r0
    ishli r1, r0, 4
    st    [r1], r0
    halt
";
        let (_, r) = run(src, MemoryArchKind::banked(16));
        assert_eq!(r.stats.store_cycles, 5 + 4 * 16);
    }

    #[test]
    fn blocking_vs_nonblocking_store_elapsed() {
        let blocking = "
.threads 256
    tid   r0
    ishli r1, r0, 4
    st    [r1], r0
    halt
";
        let nonblocking = blocking.replace("st ", "stnb ");
        let (_, rb) = run(blocking, MemoryArchKind::banked(16));
        let (_, rn) = run(&nonblocking, MemoryArchKind::banked(16));
        // Same memory work...
        assert_eq!(rb.stats.store_ops, rn.stats.store_ops);
        // ...but the non-blocking variant only pays at the final drain,
        // which happens at halt here, so elapsed matches (halt waits);
        // issuing work *between* stnb and halt would overlap. Verify via
        // an instruction stream that does ALU work after the store.
        let overlapped = "
.threads 256
    tid   r0
    ishli r1, r0, 4
    stnb  [r1], r0
    itof  r2, r0
    fadd  r2, r2, r2
    fmul  r2, r2, r2
    halt
";
        let (_, ro) = run(overlapped, MemoryArchKind::banked(16));
        // The 48 FP cycles hide inside the store drain: elapsed is within
        // a few cycles of the non-overlapped run.
        assert!(
            ro.total_cycles() < rn.total_cycles() + 10,
            "overlap should hide ALU work: {} vs {}",
            ro.total_cycles(),
            rn.total_cycles()
        );
        assert!(ro.stats.drain_cycles > 0);
    }

    #[test]
    fn uniform_loop_runs() {
        // Loop 10 times using a uniform counter in r1.
        let src = "
.threads 32
    ldi   r1, 10
loop:
    iaddi r1, r1, -1
    bnz   r1, loop
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        // ldi (2 ops) + 10×(iaddi 2 ops + bnz 1) + halt 1.
        assert_eq!(r.stats.imm_cycles, 2 + 20);
        assert_eq!(r.stats.other_cycles, 10 + 1);
    }

    #[test]
    fn divergent_branch_executes() {
        // Thread 0 falls through the branch and stores; every other
        // thread jumps straight to the halt. Divergence is a first-class
        // execution mode now, not an error.
        let src = "
.threads 32
    tid  r0
    bnz  r0, skip
    ldi  r1, 7
    st   [r0], r1
skip:
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::banked(4));
        let r = m.run_program(&p).expect("divergent program executes");
        assert_eq!(m.mem().peek(0), 7, "only thread 0 stored");
        assert_eq!(m.mem().peek(1), 0);
        assert!(r.total_cycles() > 0);
    }

    #[test]
    fn out_of_bounds_address_detected() {
        let src = "
.threads 16
    ldi  r0, 0
    lui  r0, 1
    ld   r1, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::banked(4));
        match m.run_program(&p) {
            Err(SimError::InvalidAddress { addr, .. }) => assert_eq!(addr, 65536),
            other => panic!("expected InvalidAddress, got {other:?}"),
        }
    }

    #[test]
    fn missing_halt_detected() {
        let p = assemble(".threads 16\nnop\n").unwrap();
        let mut m = machine(MemoryArchKind::mp_4r1w());
        assert!(matches!(m.run_program(&p), Err(SimError::MissingHalt)));
    }

    #[test]
    fn cycle_limit_guards_infinite_loops() {
        let src = "
.threads 16
loop:
    jmp loop
    halt
";
        let p = assemble(src).unwrap();
        let mut cfg = MachineConfig::for_arch(MemoryArchKind::mp_4r1w());
        cfg.max_cycles = 10_000;
        let mut m = Machine::new(cfg);
        assert!(matches!(m.run_program(&p), Err(SimError::CycleLimit { .. })));
    }

    #[test]
    fn sign_extended_immediates() {
        let src = "
.threads 16
    ldi   r0, 5
    iaddi r0, r0, -1
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::mp_4r1w());
        m.run_program(&p).unwrap();
        // No architectural way to observe registers directly; store them.
        let src2 = "
.threads 16
    ldi   r0, 5
    iaddi r0, r0, -1
    tid   r1
    st    [r1], r0
    halt
";
        let (m2, _) = run(src2, MemoryArchKind::mp_4r1w());
        assert_eq!(m2.mem().peek(0), 4);
    }

    #[test]
    fn fp_datapath_ieee() {
        let src = "
.threads 16
    tid   r0
    itof  r1, r0
    fmul  r2, r1, r1
    fneg  r3, r2
    fsub  r4, r2, r3
    st    [r0], r4
    halt
";
        let (m, _) = run(src, MemoryArchKind::banked(8));
        for t in 0..16u32 {
            let expect = 2.0 * (t as f32) * (t as f32);
            assert_eq!(f32::from_bits(m.mem().peek(t)), expect);
        }
    }

    #[test]
    fn fma_fused() {
        let src = "
.threads 16
    tid   r0
    itof  r1, r0
    ldi   r2, 3
    itof  r3, r2
    ldi   r4, 0
    itof  r5, r4
    fma   r5, r1, r3
    st    [r0], r5
    halt
";
        let (m, _) = run(src, MemoryArchKind::mp_4r1w());
        for t in 0..16u32 {
            assert_eq!(f32::from_bits(m.mem().peek(t)), 3.0 * t as f32);
        }
    }

    #[test]
    fn tw_region_classifies_loads() {
        let src = "
.threads 16
    tid   r0
    ld    r1, [r0]
    iaddi r2, r0, 100
    ld    r3, [r2]
    halt
";
        let p = assemble(src).unwrap();
        let cfg = MachineConfig::for_arch(MemoryArchKind::banked(16))
            .with_mem_words(4096)
            .with_tw_region(100..200);
        let mut m = Machine::new(cfg);
        let r = m.run_program(&p).unwrap();
        assert_eq!(r.stats.d_load_ops, 1);
        assert_eq!(r.stats.tw_load_ops, 1);
        assert!(r.stats.tw_load_cycles > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let src = "
.threads 128
    tid   r0
    ishli r1, r0, 2
    ld    r2, [r1]
    iadd  r2, r2, r0
    st    [r1], r2
    halt
";
        let (_, r1) = run(src, MemoryArchKind::banked_offset(8));
        let (_, r2) = run(src, MemoryArchKind::banked_offset(8));
        assert_eq!(r1.total_cycles(), r2.total_cycles());
        assert_eq!(r1.stats, r2.stats);
    }

    #[test]
    fn fast_timing_matches_exact_end_to_end() {
        let src = "
.threads 256
    tid   r0
    ishli r1, r0, 3
    iaddi r1, r1, 5
    iandi r1, r1, 0xFFF
    ld    r2, [r1]
    iadd  r2, r2, r0
    st    [r1], r2
    halt
";
        let p = assemble(src).unwrap();
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::banked_offset(4)] {
            let mut exact = Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096));
            let mut fast =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096).with_fast_timing());
            let re = exact.run_program(&p).unwrap();
            let rf = fast.run_program(&p).unwrap();
            assert_eq!(re.total_cycles(), rf.total_cycles(), "arch {arch}");
            assert_eq!(exact.mem().image(), fast.mem().image());
        }
    }

    #[test]
    fn trace_always_captured_by_facade() {
        let (m, r) = run(
            ".threads 32\ntid r0\nld r1, [r0]\nst [r0], r1\nhalt\n",
            MemoryArchKind::banked(16),
        );
        let trace = m.mem_trace().expect("trace captured");
        assert_eq!(trace.segments.len(), 2);
        assert_eq!(trace.mem_op_count(), r.stats.d_load_ops + r.stats.store_ops);
    }
}
