//! The SIMT machine: fetch/decode, 16 SPs, and the shared-memory access
//! path (paper Fig. 1).
//!
//! Execution model: one instruction at a time, executed for *every* thread
//! in the block before the next instruction starts (§III: "an instruction
//! will typically execute all threads before starting the next
//! instruction"). With `T` threads and 16 lanes, an instruction issues
//! `⌈T/16⌉` operations, one per clock for ALU classes; memory instructions
//! are timed by the configured [`SharedMemory`] and the §III-A controller
//! model ([`WritePipeline`]).
//!
//! Uniform control flow only: `jmp`/`bnz` must take the same direction in
//! every thread (SIMT divergence is out of the paper's scope and the
//! simulator reports it as an error rather than silently mis-timing).

use super::config::MachineConfig;
use super::regfile::RegFile;
use super::stats::{CycleStats, RunReport};
use crate::isa::inst::Instruction;
use crate::isa::opcode::{OpClass, Opcode};
use crate::isa::program::Program;
use crate::mem::arch::{OpKind, SharedMemory};
use crate::mem::banked::{BankedMemory, TimingMode};
use crate::mem::controller::WritePipeline;
use crate::mem::{LaneMask, LANES};

/// Simulation errors (all carry the faulting PC).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// A lane addressed past the end of shared memory.
    InvalidAddress { pc: usize, thread: u32, addr: u32, words: usize },
    /// Threads disagreed on a branch direction.
    DivergentBranch { pc: usize },
    /// Branch target outside the program.
    BadJumpTarget { pc: usize, target: u16 },
    /// The run exceeded `max_cycles` (runaway loop guard).
    CycleLimit { limit: u64 },
    /// Execution fell off the end of the instruction stream.
    MissingHalt,
    /// Program binary failed to decode.
    BadProgram(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::InvalidAddress { pc, thread, addr, words } => write!(
                f,
                "pc {pc}: thread {thread} addressed {addr} beyond shared memory ({words} words)"
            ),
            SimError::DivergentBranch { pc } => {
                write!(f, "pc {pc}: divergent branch (threads disagree)")
            }
            SimError::BadJumpTarget { pc, target } => {
                write!(f, "pc {pc}: jump target {target} outside program")
            }
            SimError::CycleLimit { limit } => write!(f, "exceeded cycle limit {limit}"),
            SimError::MissingHalt => write!(f, "execution fell off the end (missing halt)"),
            SimError::BadProgram(m) => write!(f, "bad program: {m}"),
        }
    }
}

impl std::error::Error for SimError {}

/// Classification of one executed memory instruction, for the Table III
/// D-load / TW-load split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LoadClass {
    Data,
    Twiddle,
}

/// One memory instruction's recorded operations (for the analytical
/// timing oracle): the instruction kind and each 16-lane operation's
/// addresses + active-lane mask.
#[derive(Debug, Clone)]
pub struct MemTraceInstr {
    pub kind: OpKind,
    pub ops: Vec<([u32; LANES], LaneMask)>,
}

/// The simulated processor.
pub struct Machine {
    cfg: MachineConfig,
    mem: Box<dyn SharedMemory>,
    write_pipe: WritePipeline,
    now: u64,
    stats: CycleStats,
    mem_trace: Vec<MemTraceInstr>,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Self {
        let mem: Box<dyn SharedMemory> = match cfg.arch {
            crate::mem::arch::MemoryArchKind::Banked { banks, mapping } => {
                let mut b = BankedMemory::new(cfg.mem_words, banks, mapping);
                if cfg.fast_timing {
                    b = b.with_mode(TimingMode::Fast);
                }
                if cfg.half_banks {
                    b = b.with_half_banks();
                }
                Box::new(b)
            }
            _ => cfg.arch.build(cfg.mem_words),
        };
        let write_pipe = WritePipeline::new(mem.write_buffer_ops());
        Self {
            cfg,
            mem,
            write_pipe,
            now: 0,
            stats: CycleStats::default(),
            mem_trace: Vec::new(),
        }
    }

    /// The memory-operation trace of the last run (empty unless
    /// [`MachineConfig::collect_mem_trace`] is set).
    pub fn mem_trace(&self) -> &[MemTraceInstr] {
        &self.mem_trace
    }

    /// The machine configuration.
    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    /// Direct shared-memory access (image loading / validation).
    pub fn mem(&self) -> &dyn SharedMemory {
        self.mem.as_ref()
    }

    /// Load a word image into shared memory starting at `base`.
    pub fn load_image(&mut self, base: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            self.mem.poke(base + i as u32, w);
        }
    }

    /// Load an f32 image (bit-cast) into shared memory starting at `base`.
    pub fn load_f32_image(&mut self, base: u32, values: &[f32]) {
        for (i, &v) in values.iter().enumerate() {
            self.mem.poke(base + i as u32, v.to_bits());
        }
    }

    /// Read back `n` f32 words starting at `base`.
    pub fn read_f32_image(&self, base: u32, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| f32::from_bits(self.mem.peek(base + i as u32)))
            .collect()
    }

    /// Read back `n` u32 words starting at `base`.
    pub fn read_image(&self, base: u32, n: usize) -> Vec<u32> {
        (0..n).map(|i| self.mem.peek(base + i as u32)).collect()
    }

    /// Run a program to `halt`, returning the per-class cycle report.
    ///
    /// The program is round-tripped through its binary encoding first —
    /// the simulator consumes what the assembler would produce, keeping
    /// the decode path honest.
    pub fn run_program(&mut self, program: &Program) -> Result<RunReport, SimError> {
        let words = program.encode();
        let insts: Vec<Instruction> = words
            .iter()
            .enumerate()
            .map(|(pc, &w)| {
                Instruction::decode(w).ok_or_else(|| SimError::BadProgram(format!("pc {pc}")))
            })
            .collect::<Result<_, _>>()?;

        let threads = program.threads;
        let mut regs = RegFile::new(threads);
        let start_clock = self.now;
        self.stats = CycleStats::default();
        self.mem_trace.clear();
        let n_ops = (threads as u64 + LANES as u64 - 1) / LANES as u64;

        let mut pc = 0usize;
        loop {
            if pc >= insts.len() {
                return Err(SimError::MissingHalt);
            }
            if self.now - start_clock > self.cfg.max_cycles {
                return Err(SimError::CycleLimit { limit: self.cfg.max_cycles });
            }
            let inst = insts[pc];
            self.stats.instructions += 1;
            match inst.op.class() {
                OpClass::Int | OpClass::Imm | OpClass::Fp => {
                    self.exec_alu(&mut regs, inst, threads);
                    self.charge_alu(inst.op.class(), n_ops);
                    pc += 1;
                }
                OpClass::Other => match inst.op {
                    Opcode::Halt => {
                        self.now += 1;
                        let drained = self.write_pipe.drain(self.now);
                        self.stats.drain_cycles += drained - self.now;
                        self.now = drained;
                        self.stats.other_cycles += 1;
                        break;
                    }
                    Opcode::Nop => {
                        self.stats.other_cycles += 1;
                        self.now += 1;
                        pc += 1;
                    }
                    Opcode::Jmp => {
                        let target = inst.imm as usize;
                        if target >= insts.len() {
                            return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                        }
                        self.stats.other_cycles += 1;
                        self.now += 1;
                        pc = target;
                    }
                    Opcode::Bnz => {
                        let taken = regs.get(0, inst.rd) != 0;
                        for t in 1..threads {
                            if (regs.get(t, inst.rd) != 0) != taken {
                                return Err(SimError::DivergentBranch { pc });
                            }
                        }
                        self.stats.other_cycles += 1;
                        self.now += 1;
                        if taken {
                            let target = inst.imm as usize;
                            if target >= insts.len() {
                                return Err(SimError::BadJumpTarget { pc, target: inst.imm });
                            }
                            pc = target;
                        } else {
                            pc += 1;
                        }
                    }
                    Opcode::Tid => {
                        for t in 0..threads {
                            regs.set(t, inst.rd, t);
                        }
                        self.stats.other_cycles += n_ops;
                        self.stats.operations += n_ops;
                        self.now += n_ops;
                        pc += 1;
                    }
                    _ => unreachable!("all Other opcodes handled"),
                },
                OpClass::Load => {
                    self.exec_load(&mut regs, inst, threads, pc)?;
                    pc += 1;
                }
                OpClass::Store => {
                    self.exec_store(&mut regs, inst, threads, pc)?;
                    pc += 1;
                }
            }
        }

        Ok(RunReport {
            program: program.name.clone(),
            arch: self.cfg.arch,
            threads,
            stats: self.stats,
            elapsed_cycles: self.now - start_clock,
        })
    }

    fn charge_alu(&mut self, class: OpClass, n_ops: u64) {
        match class {
            OpClass::Int => self.stats.int_cycles += n_ops,
            OpClass::Imm => self.stats.imm_cycles += n_ops,
            OpClass::Fp => self.stats.fp_cycles += n_ops,
            _ => unreachable!(),
        }
        self.stats.operations += n_ops;
        self.now += n_ops;
    }

    /// Execute an ALU instruction for every thread.
    ///
    /// §Perf: the opcode dispatch is hoisted *outside* the thread loop
    /// (one specialized tight loop per opcode) — this function is the
    /// simulator's hottest path (≈27% before the split; see
    /// EXPERIMENTS.md §Perf).
    fn exec_alu(&self, regs: &mut RegFile, inst: Instruction, threads: u32) {
        use Opcode::*;
        let imm = inst.imm as u32;
        let (rd, ra, rb) = (inst.rd, inst.ra, inst.rb);
        macro_rules! int_rr {
            ($f:expr) => {
                for t in 0..threads {
                    let v = $f(regs.get(t, ra), regs.get(t, rb));
                    regs.set(t, rd, v);
                }
            };
        }
        macro_rules! int_ri {
            ($f:expr) => {
                for t in 0..threads {
                    let v = $f(regs.get(t, ra));
                    regs.set(t, rd, v);
                }
            };
        }
        macro_rules! fp_rr {
            ($f:expr) => {
                for t in 0..threads {
                    let v = $f(regs.get_f32(t, ra), regs.get_f32(t, rb));
                    regs.set_f32(t, rd, v);
                }
            };
        }
        match inst.op {
            Iadd => int_rr!(|a: u32, b: u32| a.wrapping_add(b)),
            Isub => int_rr!(|a: u32, b: u32| a.wrapping_sub(b)),
            Imul => int_rr!(|a: u32, b: u32| a.wrapping_mul(b)),
            Iand => int_rr!(|a, b| a & b),
            Ior => int_rr!(|a, b| a | b),
            Ixor => int_rr!(|a, b| a ^ b),
            Ishl => int_rr!(|a: u32, b: u32| a << (b & 31)),
            Ishr => int_rr!(|a: u32, b: u32| a >> (b & 31)),
            Iaddi => int_ri!(|a: u32| a.wrapping_add(sign_extend(imm))),
            Imuli => int_ri!(|a: u32| a.wrapping_mul(sign_extend(imm))),
            Iandi => int_ri!(|a| a & imm),
            Iori => int_ri!(|a| a | imm),
            Ixori => int_ri!(|a| a ^ imm),
            Ishli => int_ri!(|a: u32| a << (imm & 31)),
            Ishri => int_ri!(|a: u32| a >> (imm & 31)),
            Ldi => {
                for t in 0..threads {
                    regs.set(t, rd, imm);
                }
            }
            Lui => {
                for t in 0..threads {
                    let low = regs.get(t, rd) & 0xFFFF;
                    regs.set(t, rd, (imm << 16) | low);
                }
            }
            Fadd => fp_rr!(|a, b| a + b),
            Fsub => fp_rr!(|a, b| a - b),
            Fmul => fp_rr!(|a, b| a * b),
            Fma => {
                for t in 0..threads {
                    let acc = regs.get_f32(t, rd);
                    let v = regs.get_f32(t, ra).mul_add(regs.get_f32(t, rb), acc);
                    regs.set_f32(t, rd, v);
                }
            }
            Fneg => {
                for t in 0..threads {
                    let v = -regs.get_f32(t, ra);
                    regs.set_f32(t, rd, v);
                }
            }
            Itof => {
                for t in 0..threads {
                    let v = regs.get(t, ra) as i32 as f32;
                    regs.set_f32(t, rd, v);
                }
            }
            _ => unreachable!("not an ALU opcode"),
        }
    }

    /// Gather one warp's addresses from register `ra`, with bounds checks.
    fn warp_addrs(
        &self,
        regs: &RegFile,
        ra: u8,
        warp: u32,
        threads: u32,
        pc: usize,
    ) -> Result<([u32; LANES], LaneMask), SimError> {
        let base_t = warp * LANES as u32;
        let mut addrs = [0u32; LANES];
        let mut mask: LaneMask = 0;
        for lane in 0..LANES {
            let t = base_t + lane as u32;
            if t >= threads {
                break;
            }
            let addr = regs.get(t, ra);
            if addr as usize >= self.cfg.mem_words {
                return Err(SimError::InvalidAddress {
                    pc,
                    thread: t,
                    addr,
                    words: self.cfg.mem_words,
                });
            }
            addrs[lane] = addr;
            mask |= 1 << lane;
        }
        Ok((addrs, mask))
    }

    /// Classify a load by its addresses (Table III splits data loads from
    /// twiddle loads).
    fn classify_load(&self, addrs: &[u32; LANES], mask: LaneMask) -> LoadClass {
        if let Some(region) = &self.cfg.tw_region {
            if mask != 0 {
                let lane = mask.trailing_zeros() as usize;
                if region.contains(&addrs[lane]) {
                    return LoadClass::Twiddle;
                }
            }
        }
        LoadClass::Data
    }

    fn exec_load(
        &mut self,
        regs: &mut RegFile,
        inst: Instruction,
        threads: u32,
        pc: usize,
    ) -> Result<(), SimError> {
        let n_warps = (threads as usize + LANES - 1) / LANES;
        let mut attributed = self.mem.overhead(OpKind::Read) as u64;
        let mut class = LoadClass::Data;
        let mut trace = self
            .cfg
            .collect_mem_trace
            .then(|| MemTraceInstr { kind: OpKind::Read, ops: Vec::with_capacity(n_warps) });
        for w in 0..n_warps {
            let (addrs, mask) = self.warp_addrs(regs, inst.ra, w as u32, threads, pc)?;
            if let Some(t) = trace.as_mut() {
                t.ops.push((addrs, mask));
            }
            if w == 0 {
                class = self.classify_load(&addrs, mask);
            }
            let op = self.mem.read_op(&addrs, mask);
            attributed += op.cycles.max(1) as u64;
            let base_t = w as u32 * LANES as u32;
            let mut m = mask;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                regs.set(base_t + lane as u32, inst.rd, op.data[lane]);
            }
        }
        if let Some(t) = trace {
            self.mem_trace.push(t);
        }
        // A read instruction pauses fetch/decode until writeback (§III-A).
        self.now += attributed;
        self.stats.operations += n_warps as u64;
        match class {
            LoadClass::Data => {
                self.stats.d_load_cycles += attributed;
                self.stats.d_load_ops += n_warps as u64;
            }
            LoadClass::Twiddle => {
                self.stats.tw_load_cycles += attributed;
                self.stats.tw_load_ops += n_warps as u64;
            }
        }
        Ok(())
    }

    fn exec_store(
        &mut self,
        regs: &mut RegFile,
        inst: Instruction,
        threads: u32,
        pc: usize,
    ) -> Result<(), SimError> {
        let n_warps = (threads as usize + LANES - 1) / LANES;
        let blocking = inst.op == Opcode::St;
        let overhead = self.mem.overhead(OpKind::Write);
        let start = self.now;
        let mut iss = self.now;
        let mut trace = self
            .cfg
            .collect_mem_trace
            .then(|| MemTraceInstr { kind: OpKind::Write, ops: Vec::with_capacity(n_warps) });
        for w in 0..n_warps {
            let (addrs, mask) = self.warp_addrs(regs, inst.ra, w as u32, threads, pc)?;
            if let Some(t) = trace.as_mut() {
                t.ops.push((addrs, mask));
            }
            let base_t = w as u32 * LANES as u32;
            let mut data = [0u32; LANES];
            let mut m = mask;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                data[lane] = regs.get(base_t + lane as u32, inst.rb);
            }
            let cost = self.mem.write_op(&addrs, &data, mask);
            let before = iss;
            iss = self.write_pipe.issue_nonblocking(iss, cost.max(1), overhead);
            // Anything beyond the single issue cycle was a buffer-full stall.
            self.stats.wbuf_stall_cycles += iss - before - 1;
        }
        if let Some(t) = trace {
            self.mem_trace.push(t);
        }
        self.stats.operations += n_warps as u64;
        self.stats.store_ops += n_warps as u64;
        if blocking {
            // Blocking write: hold the pipeline until the controller drains.
            let end = self.write_pipe.drain(iss);
            self.stats.store_cycles += end - start;
            self.now = end;
        } else {
            // Non-blocking: the pipeline continues after issue; attribute
            // the background service cost so the Store Cycles row still
            // reflects the memory work (the paper's accounting).
            self.stats.store_cycles +=
                (self.write_pipe.busy_until().saturating_sub(start)).max(iss - start);
            self.now = iss;
        }
        Ok(())
    }
}

/// 16-bit immediates are sign-extended for the arithmetic immediates
/// (`iaddi r, r, -1` must work); logical immediates use them zero-extended.
#[inline]
fn sign_extend(imm: u32) -> u32 {
    imm as u16 as i16 as i32 as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;
    use crate::mem::arch::MemoryArchKind;

    fn machine(arch: MemoryArchKind) -> Machine {
        Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096))
    }

    fn run(src: &str, arch: MemoryArchKind) -> (Machine, RunReport) {
        let p = assemble(src).expect("assembles");
        let mut m = machine(arch);
        let r = m.run_program(&p).expect("runs");
        (m, r)
    }

    #[test]
    fn tid_and_store_roundtrip() {
        // Each thread writes its tid to shared[tid].
        let src = "
.threads 64
    tid  r0
    st   [r0], r0
    halt
";
        let (m, r) = run(src, MemoryArchKind::banked(16));
        for t in 0..64 {
            assert_eq!(m.mem().peek(t), t);
        }
        assert_eq!(r.stats.store_ops, 4);
        assert_eq!(r.threads, 64);
    }

    #[test]
    fn alu_cycle_accounting() {
        // 64 threads = 4 operations per instruction.
        let src = "
.threads 64
    tid   r0
    ldi   r1, 3
    iadd  r2, r0, r1
    itof  r3, r2
    fadd  r4, r3, r3
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        assert_eq!(r.stats.imm_cycles, 4); // ldi
        assert_eq!(r.stats.int_cycles, 4); // iadd
        assert_eq!(r.stats.fp_cycles, 8); // itof + fadd
        assert_eq!(r.stats.other_cycles, 4 + 1); // tid (per-op) + halt
        assert_eq!(r.total_cycles(), 21);
        assert_eq!(r.stats.attributed_total(), 21);
    }

    #[test]
    fn multiport_load_costs_match_paper_model() {
        // 64 threads → 4 read ops × ⌈16/4⌉ = 16 cycles, zero overhead.
        let src = "
.threads 64
    tid  r0
    ld   r1, [r0]
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        assert_eq!(r.stats.d_load_cycles, 16);
        assert_eq!(r.stats.d_load_ops, 4);
    }

    #[test]
    fn banked_conflict_free_load() {
        // Consecutive tids → conflict-free: 4 ops + 12 overhead.
        let src = "
.threads 64
    tid  r0
    ld   r1, [r0]
    halt
";
        let (_, r) = run(src, MemoryArchKind::banked(16));
        assert_eq!(r.stats.d_load_cycles, 12 + 4);
    }

    #[test]
    fn banked_full_conflict_store() {
        // Every thread writes address tid*16 → all lanes hit bank 0:
        // each op costs 16; blocking store = 5 (overhead) + 64 cycles.
        let src = "
.threads 64
    tid   r0
    ishli r1, r0, 4
    st    [r1], r0
    halt
";
        let (_, r) = run(src, MemoryArchKind::banked(16));
        assert_eq!(r.stats.store_cycles, 5 + 4 * 16);
    }

    #[test]
    fn blocking_vs_nonblocking_store_elapsed() {
        let blocking = "
.threads 256
    tid   r0
    ishli r1, r0, 4
    st    [r1], r0
    halt
";
        let nonblocking = blocking.replace("st ", "stnb ");
        let (_, rb) = run(blocking, MemoryArchKind::banked(16));
        let (_, rn) = run(&nonblocking, MemoryArchKind::banked(16));
        // Same memory work...
        assert_eq!(rb.stats.store_ops, rn.stats.store_ops);
        // ...but the non-blocking variant only pays at the final drain,
        // which happens at halt here, so elapsed matches (halt waits);
        // issuing work *between* stnb and halt would overlap. Verify via
        // an instruction stream that does ALU work after the store.
        let overlapped = "
.threads 256
    tid   r0
    ishli r1, r0, 4
    stnb  [r1], r0
    itof  r2, r0
    fadd  r2, r2, r2
    fmul  r2, r2, r2
    halt
";
        let (_, ro) = run(overlapped, MemoryArchKind::banked(16));
        // The 48 FP cycles hide inside the store drain: elapsed is within
        // a few cycles of the non-overlapped run.
        assert!(
            ro.total_cycles() < rn.total_cycles() + 10,
            "overlap should hide ALU work: {} vs {}",
            ro.total_cycles(),
            rn.total_cycles()
        );
        assert!(ro.stats.drain_cycles > 0);
    }

    #[test]
    fn uniform_loop_runs() {
        // Loop 10 times using a uniform counter in r1.
        let src = "
.threads 32
    ldi   r1, 10
loop:
    iaddi r1, r1, -1
    bnz   r1, loop
    halt
";
        let (_, r) = run(src, MemoryArchKind::mp_4r1w());
        // ldi (2 ops) + 10×(iaddi 2 ops + bnz 1) + halt 1.
        assert_eq!(r.stats.imm_cycles, 2 + 20);
        assert_eq!(r.stats.other_cycles, 10 + 1);
    }

    #[test]
    fn divergent_branch_detected() {
        let src = "
.threads 32
    tid  r0
    bnz  r0, 0
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::banked(4));
        assert!(matches!(m.run_program(&p), Err(SimError::DivergentBranch { pc: 1 })));
    }

    #[test]
    fn out_of_bounds_address_detected() {
        let src = "
.threads 16
    ldi  r0, 0
    lui  r0, 1
    ld   r1, [r0]
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::banked(4));
        match m.run_program(&p) {
            Err(SimError::InvalidAddress { addr, .. }) => assert_eq!(addr, 65536),
            other => panic!("expected InvalidAddress, got {other:?}"),
        }
    }

    #[test]
    fn missing_halt_detected() {
        let p = assemble(".threads 16\nnop\n").unwrap();
        let mut m = machine(MemoryArchKind::mp_4r1w());
        assert!(matches!(m.run_program(&p), Err(SimError::MissingHalt)));
    }

    #[test]
    fn cycle_limit_guards_infinite_loops() {
        let src = "
.threads 16
loop:
    jmp loop
    halt
";
        let p = assemble(src).unwrap();
        let mut cfg = MachineConfig::for_arch(MemoryArchKind::mp_4r1w());
        cfg.max_cycles = 10_000;
        let mut m = Machine::new(cfg);
        assert!(matches!(m.run_program(&p), Err(SimError::CycleLimit { .. })));
    }

    #[test]
    fn sign_extended_immediates() {
        let src = "
.threads 16
    ldi   r0, 5
    iaddi r0, r0, -1
    halt
";
        let p = assemble(src).unwrap();
        let mut m = machine(MemoryArchKind::mp_4r1w());
        m.run_program(&p).unwrap();
        // No architectural way to observe registers directly; store them.
        let src2 = "
.threads 16
    ldi   r0, 5
    iaddi r0, r0, -1
    tid   r1
    st    [r1], r0
    halt
";
        let (m2, _) = run(src2, MemoryArchKind::mp_4r1w());
        assert_eq!(m2.mem().peek(0), 4);
    }

    #[test]
    fn fp_datapath_ieee() {
        let src = "
.threads 16
    tid   r0
    itof  r1, r0
    fmul  r2, r1, r1
    fneg  r3, r2
    fsub  r4, r2, r3
    st    [r0], r4
    halt
";
        let (m, _) = run(src, MemoryArchKind::banked(8));
        for t in 0..16u32 {
            let expect = 2.0 * (t as f32) * (t as f32);
            assert_eq!(f32::from_bits(m.mem().peek(t)), expect);
        }
    }

    #[test]
    fn fma_fused() {
        let src = "
.threads 16
    tid   r0
    itof  r1, r0
    ldi   r2, 3
    itof  r3, r2
    ldi   r4, 0
    itof  r5, r4
    fma   r5, r1, r3
    st    [r0], r5
    halt
";
        let (m, _) = run(src, MemoryArchKind::mp_4r1w());
        for t in 0..16u32 {
            assert_eq!(f32::from_bits(m.mem().peek(t)), 3.0 * t as f32);
        }
    }

    #[test]
    fn tw_region_classifies_loads() {
        let src = "
.threads 16
    tid   r0
    ld    r1, [r0]
    iaddi r2, r0, 100
    ld    r3, [r2]
    halt
";
        let p = assemble(src).unwrap();
        let cfg = MachineConfig::for_arch(MemoryArchKind::banked(16))
            .with_mem_words(4096)
            .with_tw_region(100..200);
        let mut m = Machine::new(cfg);
        let r = m.run_program(&p).unwrap();
        assert_eq!(r.stats.d_load_ops, 1);
        assert_eq!(r.stats.tw_load_ops, 1);
        assert!(r.stats.tw_load_cycles > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let src = "
.threads 128
    tid   r0
    ishli r1, r0, 2
    ld    r2, [r1]
    iadd  r2, r2, r0
    st    [r1], r2
    halt
";
        let (_, r1) = run(src, MemoryArchKind::banked_offset(8));
        let (_, r2) = run(src, MemoryArchKind::banked_offset(8));
        assert_eq!(r1.total_cycles(), r2.total_cycles());
        assert_eq!(r1.stats, r2.stats);
    }

    #[test]
    fn fast_timing_matches_exact_end_to_end() {
        let src = "
.threads 256
    tid   r0
    ishli r1, r0, 3
    iaddi r1, r1, 5
    iandi r1, r1, 0xFFF
    ld    r2, [r1]
    iadd  r2, r2, r0
    st    [r1], r2
    halt
";
        let p = assemble(src).unwrap();
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::banked_offset(4)] {
            let mut exact = Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096));
            let mut fast =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096).with_fast_timing());
            let re = exact.run_program(&p).unwrap();
            let rf = fast.run_program(&p).unwrap();
            assert_eq!(re.total_cycles(), rf.total_cycles(), "arch {arch}");
            assert_eq!(exact.mem().image(), fast.mem().image());
        }
    }
}
