//! Compiled-trace batch replay — one trace walk, all architectures
//! (DESIGN.md §Replay).
//!
//! [`replay`](crate::sim::replay::replay) charges one architecture per
//! walk, recomputing bank indices from raw addresses through
//! `dyn SharedMemory::op_cost` on every operation. But the per-operation
//! cost of *every* constructible architecture is a pure function of
//! quantities that can be precomputed once per trace
//! ([`crate::mem::compiled`]): the per-family conflict maxima and the
//! lane-population count. A [`CompiledTrace`] stores exactly those, in
//! structure-of-arrays form, so:
//!
//! - [`replay_compiled`] charges one architecture with O(1) per-op cost
//!   lookups — no address re-hashing, no dyn dispatch in the inner loop;
//! - [`replay_many`] walks the trace **once** and charges a whole slate
//!   of candidate architectures in that single pass (per-architecture
//!   clock + write-pipeline state advanced instruction by instruction) —
//!   the kernel under the multi-architecture sweep
//!   ([`crate::coordinator::runner::SweepRunner::run_with_cache`]) and
//!   the design-space explorer ([`crate::explore`]).
//!
//! Both are `RunReport`-bit-identical to the reference [`replay`]
//! (`rust/tests/replay_diff.rs` pins this across the nine paper
//! architectures × random parametric explorer points × random
//! programs/masks/strides; [`replay`] itself stays pinned to the coupled
//! simulator by `rust/tests/replay_parity.rs`).
//!
//! [`replay`]: crate::sim::replay::replay

use super::exec::{AluCharges, LoadClass, MemAccessKind, MemTrace, SimError};
use super::replay::charge_alu;
use super::stats::{CycleStats, RunReport};
use crate::mem::arch::{MemoryArchKind, OpKind};
use crate::mem::compiled::{compile_op, ArchCost, ACTIVE_SLOT, FAMILY_COUNT, GATHER_WIDTH};
use crate::mem::controller::WritePipeline;
use std::ops::Range;

/// One memory instruction of a compiled trace: its kind, the ALU charges
/// preceding it, and the slice of the operation arrays it owns.
#[derive(Debug, Clone)]
pub struct CompiledInstr {
    pub kind: MemAccessKind,
    pub before: AluCharges,
    /// Index range into the per-operation arrays.
    pub ops: Range<usize>,
}

/// A [`MemTrace`] compiled for batch replay: per-operation conflict
/// maxima for every bank-mapping family plus lane-population counts, in
/// structure-of-arrays layout. Built once per trace
/// ([`CompiledTrace::compile`], cached by
/// [`crate::coordinator::job::TraceCache::get_or_compile`]), charged
/// arbitrarily many times.
#[derive(Debug, Clone)]
pub struct CompiledTrace {
    program: String,
    threads: u32,
    mem_words: usize,
    instrs: Vec<CompiledInstr>,
    tail: AluCharges,
    /// Per-op gather rows, row-major with stride [`GATHER_WIDTH`]: the
    /// [`FAMILY_COUNT`] conflict-family maxima followed by the
    /// active-lane count at [`ACTIVE_SLOT`], so banked *and* multiport
    /// lanes resolve their cost with one branch-free
    /// `cost_table[row[gather_slot]]` lookup (DESIGN.md §Replay).
    gather: Vec<u8>,
    /// The architecture-independent part of the final [`CycleStats`]:
    /// every counter except the five memory-timing cycle fields
    /// (`d_load`/`tw_load`/`store`/`wbuf_stall`/`drain` cycles) is a pure
    /// function of the trace — ALU class cycles, all three op counts,
    /// `instructions`, `operations`, and the halt `other_cycles` — so it
    /// is accumulated once here instead of once per candidate per
    /// instruction.
    base_stats: CycleStats,
}

impl CompiledTrace {
    /// Compile `trace`: one walk over its operations, hashing each
    /// operation's addresses once per shift position instead of once per
    /// candidate architecture forever after.
    pub fn compile(trace: &MemTrace) -> Self {
        let n_ops = trace.mem_op_count() as usize;
        let mut gather = vec![0u8; n_ops * GATHER_WIDTH];
        let mut instrs = Vec::with_capacity(trace.segments.len());
        let mut base_stats = CycleStats::default();
        let mut next = 0usize;
        for seg in &trace.segments {
            let start = next;
            for (addrs, mask) in &seg.mem.ops {
                let row = &mut gather[next * GATHER_WIDTH..(next + 1) * GATHER_WIDTH];
                let families =
                    (&mut row[..FAMILY_COUNT]).try_into().expect("row is FAMILY_COUNT long");
                compile_op(addrs, *mask, families);
                row[ACTIVE_SLOT] = mask.count_ones() as u8;
                next += 1;
            }
            instrs.push(CompiledInstr { kind: seg.mem.kind, before: seg.before, ops: start..next });
            base_stats.add_alu(&seg.before);
            let n_ops = seg.mem.ops.len() as u64;
            base_stats.operations += n_ops;
            match seg.mem.kind {
                MemAccessKind::Load(LoadClass::Data) => base_stats.d_load_ops += n_ops,
                MemAccessKind::Load(LoadClass::Twiddle) => base_stats.tw_load_ops += n_ops,
                MemAccessKind::Store { .. } => base_stats.store_ops += n_ops,
            }
            base_stats.instructions += 1;
        }
        // Tail + halt, mirroring the reference replayer's finish sequence.
        base_stats.add_alu(&trace.tail);
        base_stats.instructions += 1;
        base_stats.other_cycles += 1;
        Self {
            program: trace.program.clone(),
            threads: trace.threads,
            mem_words: trace.mem_words,
            instrs,
            tail: trace.tail,
            gather,
            base_stats,
        }
    }

    /// Program name (propagated into replayed reports).
    pub fn program(&self) -> &str {
        &self.program
    }

    /// Shared-memory capacity (words) the trace executed against — the
    /// capacity every [`ArchCost`] is derived at, so compiled costs use
    /// the same shift clamp a live memory of this size would.
    pub fn mem_words(&self) -> usize {
        self.mem_words
    }

    /// Total compiled 16-lane memory operations.
    pub fn n_ops(&self) -> usize {
        self.gather.len() / GATHER_WIDTH
    }

    /// Number of memory instructions.
    pub fn n_instrs(&self) -> usize {
        self.instrs.len()
    }

    /// The cost model `arch` gets on this trace's capacity.
    pub fn arch_cost(&self, arch: MemoryArchKind) -> ArchCost {
        ArchCost::new(arch, self.mem_words)
    }

    /// The conflict-family row of operation `op`.
    #[inline]
    fn conflicts_of(&self, op: usize) -> &[u8] {
        &self.gather[op * GATHER_WIDTH..op * GATHER_WIDTH + FAMILY_COUNT]
    }

    /// Active-lane count of operation `op`.
    #[inline]
    fn active_of(&self, op: usize) -> u8 {
        self.gather[op * GATHER_WIDTH + ACTIVE_SLOT]
    }

    /// Full [`GATHER_WIDTH`]-byte gather row of operation `op` — the
    /// lane-packed replayer's per-op input.
    #[inline]
    pub(crate) fn gather_row(&self, op: usize) -> &[u8] {
        &self.gather[op * GATHER_WIDTH..(op + 1) * GATHER_WIDTH]
    }

    /// The compiled memory-instruction stream (for the packed replayer).
    #[inline]
    pub(crate) fn instrs(&self) -> &[CompiledInstr] {
        &self.instrs
    }

    /// ALU charges between the last memory instruction and halt.
    #[inline]
    pub(crate) fn tail_charges(&self) -> &AluCharges {
        &self.tail
    }

    /// Thread-block size (propagated into replayed reports).
    #[inline]
    pub(crate) fn threads(&self) -> u32 {
        self.threads
    }

    /// The precomputed architecture-independent [`CycleStats`] baseline
    /// (see the field docs).
    #[inline]
    pub(crate) fn base_stats(&self) -> CycleStats {
        self.base_stats
    }
}

/// Per-architecture replay state advanced instruction by instruction
/// during a batch walk.
struct ArchState {
    cost: ArchCost,
    stats: CycleStats,
    now: u64,
    pipe: WritePipeline,
    failed: Option<SimError>,
}

impl ArchState {
    fn new(cost: ArchCost) -> Self {
        Self {
            pipe: WritePipeline::new(cost.write_buffer_ops()),
            cost,
            stats: CycleStats::default(),
            now: 0,
            failed: None,
        }
    }

    /// Closed-form cost of compiled operation `op` (already floored at 1).
    #[inline]
    fn op_cost(&self, trace: &CompiledTrace, kind: OpKind, op: usize) -> u32 {
        self.cost.op_cost(kind, trace.conflicts_of(op), trace.active_of(op))
    }

    /// Charge one compiled memory instruction — the exact sequence of
    /// charges [`crate::sim::replay::replay`] applies per segment.
    fn charge(&mut self, trace: &CompiledTrace, instr: &CompiledInstr) {
        charge_alu(&mut self.stats, &mut self.now, &instr.before);
        let n_ops = instr.ops.len() as u64;
        match instr.kind {
            MemAccessKind::Load(class) => {
                let mut attributed = self.cost.overhead(OpKind::Read) as u64;
                for op in instr.ops.clone() {
                    attributed += self.op_cost(trace, OpKind::Read, op) as u64;
                }
                self.now += attributed;
                self.stats.operations += n_ops;
                match class {
                    LoadClass::Data => {
                        self.stats.d_load_cycles += attributed;
                        self.stats.d_load_ops += n_ops;
                    }
                    LoadClass::Twiddle => {
                        self.stats.tw_load_cycles += attributed;
                        self.stats.tw_load_ops += n_ops;
                    }
                }
            }
            MemAccessKind::Store { blocking } => {
                let overhead = self.cost.overhead(OpKind::Write);
                let start = self.now;
                let mut iss = self.now;
                for op in instr.ops.clone() {
                    let cost = self.op_cost(trace, OpKind::Write, op);
                    let before = iss;
                    iss = self.pipe.issue_nonblocking(iss, cost, overhead);
                    self.stats.wbuf_stall_cycles += iss.saturating_sub(before + 1);
                }
                self.stats.operations += n_ops;
                self.stats.store_ops += n_ops;
                if blocking {
                    let end = self.pipe.drain(iss);
                    self.stats.store_cycles += end - start;
                    self.now = end;
                } else {
                    self.stats.store_cycles +=
                        (self.pipe.busy_until().saturating_sub(start)).max(iss - start);
                    self.now = iss;
                }
            }
        }
        self.stats.instructions += 1;
    }

    /// Tail charges + the halt/drain sequence, producing the report.
    fn finish(mut self, trace: &CompiledTrace, max_cycles: u64) -> Result<RunReport, SimError> {
        if let Some(e) = self.failed {
            return Err(e);
        }
        charge_alu(&mut self.stats, &mut self.now, &trace.tail);
        if self.now > max_cycles {
            return Err(SimError::CycleLimit { limit: max_cycles });
        }
        self.stats.instructions += 1;
        self.now += 1;
        let drained = self.pipe.drain(self.now);
        self.stats.drain_cycles += drained - self.now;
        self.now = drained;
        self.stats.other_cycles += 1;
        Ok(RunReport {
            program: trace.program.clone(),
            arch: self.cost.arch(),
            threads: trace.threads,
            stats: self.stats,
            elapsed_cycles: self.now,
        })
    }
}

/// Charge every architecture in `archs` from one walk over `trace` —
/// the **scalar reference** batch replayer. The lane-packed kernel
/// ([`crate::sim::packed::replay_many_packed`]) is the production path;
/// this one stays as the differential anchor the packed kernel is pinned
/// against (which is itself pinned to the per-architecture [`replay`]).
///
/// Results come back in `archs` order, one per candidate; a slow
/// architecture that exceeds `max_cycles` yields its own
/// [`SimError::CycleLimit`] without disturbing the others (batch
/// isolation — the reference path would have returned the same error for
/// that architecture alone). `RunReport`-bit-identical to running
/// [`crate::sim::replay::replay`] per architecture.
///
/// [`replay`]: crate::sim::replay::replay
pub fn replay_many(
    trace: &CompiledTrace,
    archs: &[MemoryArchKind],
    max_cycles: u64,
) -> Vec<Result<RunReport, SimError>> {
    let mut states: Vec<ArchState> =
        archs.iter().map(|&a| ArchState::new(trace.arch_cost(a))).collect();
    // Failed candidates are swap-compacted out of the active index set
    // once, when they fail — not re-filtered on every instruction. The
    // charge order across candidates is irrelevant (states are
    // independent), so compaction cannot change any result.
    let mut active: Vec<usize> = (0..states.len()).collect();
    for instr in &trace.instrs {
        let mut i = 0;
        while i < active.len() {
            let state = &mut states[active[i]];
            state.charge(trace, instr);
            if state.now > max_cycles {
                state.failed = Some(SimError::CycleLimit { limit: max_cycles });
                active.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if active.is_empty() {
            break;
        }
    }
    states.into_iter().map(|s| s.finish(trace, max_cycles)).collect()
}

/// Single-architecture compiled replay — the compiled equivalent of
/// [`crate::sim::replay::replay`], used by the engine's warm-cache `Run`
/// path and the explorer's memoized scoring. A direct scalar walk: no
/// per-call `Vec` of states, no batch plumbing (the warm `Run` path
/// calls this once per request).
pub fn replay_compiled(
    trace: &CompiledTrace,
    arch: MemoryArchKind,
    max_cycles: u64,
) -> Result<RunReport, SimError> {
    let mut state = ArchState::new(trace.arch_cost(arch));
    for instr in &trace.instrs {
        state.charge(trace, instr);
        if state.now > max_cycles {
            return Err(SimError::CycleLimit { limit: max_cycles });
        }
    }
    state.finish(trace, max_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{FULL_MASK, LANES};
    use crate::sim::exec::MemInstr;
    use crate::sim::replay::replay;

    fn seq_addrs(stride: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = l as u32 * stride;
        }
        a
    }

    fn mixed_trace() -> MemTrace {
        let instrs = vec![
            MemInstr {
                kind: MemAccessKind::Load(LoadClass::Data),
                ops: vec![(seq_addrs(1), FULL_MASK), (seq_addrs(16), FULL_MASK)],
            },
            MemInstr {
                kind: MemAccessKind::Store { blocking: false },
                ops: vec![(seq_addrs(16), FULL_MASK); 4],
            },
            MemInstr {
                kind: MemAccessKind::Load(LoadClass::Twiddle),
                ops: vec![(seq_addrs(4), 0x0F0F)],
            },
            MemInstr {
                kind: MemAccessKind::Store { blocking: true },
                ops: vec![(seq_addrs(2), 0x00FF); 2],
            },
        ];
        MemTrace::from_mem_instrs("mixed", 256, instrs)
    }

    fn assert_reports_equal(a: &RunReport, b: &RunReport, ctx: &str) {
        assert_eq!(a.stats, b.stats, "{ctx}: stats");
        assert_eq!(a.elapsed_cycles, b.elapsed_cycles, "{ctx}: elapsed");
        assert_eq!(a.program, b.program, "{ctx}: program");
        assert_eq!(a.arch, b.arch, "{ctx}: arch");
        assert_eq!(a.threads, b.threads, "{ctx}: threads");
    }

    #[test]
    fn compile_shape_matches_trace() {
        let trace = mixed_trace();
        let ct = CompiledTrace::compile(&trace);
        assert_eq!(ct.n_instrs(), 4);
        assert_eq!(ct.n_ops() as u64, trace.mem_op_count());
        assert_eq!(ct.program(), "mixed");
        assert_eq!(ct.mem_words(), trace.mem_words);
        // Op layout: loads 0..2 (full), stores 2..6 (full), twiddle 6
        // (mask 0x0F0F → 8 lanes), blocking stores 7..9 (0x00FF → 8).
        assert_eq!(ct.active_of(0), 16);
        assert_eq!(ct.active_of(6), 8);
        assert_eq!(ct.active_of(8), 8);
        assert_eq!(ct.gather_row(0).len(), GATHER_WIDTH);
        assert_eq!(ct.gather_row(6)[ACTIVE_SLOT], 8);
    }

    #[test]
    fn base_stats_matches_arch_independent_counters() {
        // The precomputed baseline must equal every replayed report on
        // exactly the architecture-independent fields, regardless of the
        // architecture charged.
        let trace = mixed_trace();
        let ct = CompiledTrace::compile(&trace);
        let base = ct.base_stats();
        assert_eq!(base.d_load_cycles, 0);
        assert_eq!(base.tw_load_cycles, 0);
        assert_eq!(base.store_cycles, 0);
        assert_eq!(base.wbuf_stall_cycles, 0);
        assert_eq!(base.drain_cycles, 0);
        for arch in MemoryArchKind::table3_nine() {
            let s = replay_compiled(&ct, arch, u64::MAX).unwrap().stats;
            let mut masked = s;
            masked.d_load_cycles = 0;
            masked.tw_load_cycles = 0;
            masked.store_cycles = 0;
            masked.wbuf_stall_cycles = 0;
            masked.drain_cycles = 0;
            assert_eq!(masked, base, "{arch}");
        }
    }

    #[test]
    fn batch_replay_equals_reference_on_all_nine_archs() {
        let trace = mixed_trace();
        let ct = CompiledTrace::compile(&trace);
        let archs = MemoryArchKind::table3_nine();
        let batch = replay_many(&ct, &archs, u64::MAX);
        for (arch, got) in archs.iter().zip(&batch) {
            let mem = arch.build(trace.mem_words);
            let want = replay(&trace, mem.as_ref(), u64::MAX).unwrap();
            assert_reports_equal(got.as_ref().unwrap(), &want, &arch.label());
            let single = replay_compiled(&ct, *arch, u64::MAX).unwrap();
            assert_reports_equal(&single, &want, &format!("{} (single)", arch.label()));
        }
    }

    #[test]
    fn cycle_limit_isolated_per_architecture() {
        // A limit that the multiport memories meet but the fully
        // conflicted 16-bank walk exceeds: the batch must report the
        // failure only on the slow candidates.
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(16), FULL_MASK); 64],
        };
        let trace = MemTrace::from_mem_instrs("slow", 1024, vec![mi]);
        let ct = CompiledTrace::compile(&trace);
        let archs = [MemoryArchKind::mp_4r1w(), MemoryArchKind::banked(16)];
        let limit = 300; // 64 ops × 4 cycles multiport ≈ 256 < 300 < 12 + 64 × 16
        let out = replay_many(&ct, &archs, limit);
        assert!(out[0].is_ok(), "multiport fits under the limit");
        assert!(
            matches!(out[1], Err(SimError::CycleLimit { limit: 300 })),
            "banked16 must trip the limit: {:?}",
            out[1]
        );
        // And each verdict matches the reference path's.
        for (arch, got) in archs.iter().zip(&out) {
            let mem = arch.build(trace.mem_words);
            let want = replay(&trace, mem.as_ref(), limit);
            assert_eq!(got.is_ok(), want.is_ok(), "{arch}");
        }
    }

    #[test]
    fn empty_trace_is_just_halt() {
        let trace = MemTrace::from_mem_instrs("empty", 16, vec![]);
        let ct = CompiledTrace::compile(&trace);
        for arch in MemoryArchKind::table3_nine() {
            let r = replay_compiled(&ct, arch, 1000).unwrap();
            assert_eq!(r.total_cycles(), 1, "{arch}");
            assert_eq!(r.stats.instructions, 1);
        }
    }

    #[test]
    fn batch_order_matches_input_order() {
        let ct = CompiledTrace::compile(&mixed_trace());
        let archs =
            [MemoryArchKind::banked(4), MemoryArchKind::mp_4r2w(), MemoryArchKind::banked(4)];
        let out = replay_many(&ct, &archs, u64::MAX);
        assert_eq!(out.len(), 3);
        for (arch, r) in archs.iter().zip(&out) {
            assert_eq!(r.as_ref().unwrap().arch, *arch);
        }
        // Duplicate candidates are independent and identical.
        assert_reports_equal(
            out[0].as_ref().unwrap(),
            out[2].as_ref().unwrap(),
            "duplicate candidates",
        );
    }
}
