//! Timing replay engine — the architecture-dependent half of the
//! decoupled simulator (DESIGN.md §Two-phase).
//!
//! [`replay`] charges a [`MemTrace`] against any [`SharedMemory`]'s
//! controller/arbiter/bank timing model — the per-operation
//! [`SharedMemory::op_cost`] charge path, the §III-A per-instruction
//! overheads, and the write controller's circular buffer
//! ([`WritePipeline`]) — without touching data or registers. The result
//! is a [`RunReport`] bit-identical to running the program coupled on
//! that architecture ([`crate::sim::machine::Machine::run_program`] *is*
//! execute-then-replay, and `rust/tests/replay_parity.rs` pins the
//! cached-trace path to it across all nine architectures).
//!
//! The timing contract replayed here, from the paper:
//!
//! - ALU classes stream one 16-thread operation per clock;
//! - a **read** instruction pauses fetch/decode for its fixed overhead
//!   plus the conflict-spaced operation stream;
//! - a **blocking write** (`st`) holds the pipeline until the write
//!   controller drains; a **non-blocking write** (`stnb`) continues after
//!   one issue cycle per operation, stalling only when the circular
//!   buffer fills;
//! - `halt` waits for the write controller to drain (charged as
//!   `drain_cycles`).

use super::exec::{AluCharges, LoadClass, MemAccessKind, MemTrace, SimError};
use super::stats::{CycleStats, RunReport};
use crate::mem::arch::{OpKind, SharedMemory};
use crate::mem::controller::WritePipeline;

/// Replay `trace` against `mem`'s timing model.
///
/// `max_cycles` is the same runaway guard the coupled simulator applies:
/// the replayed clock is checked at every instruction boundary (a slow
/// architecture can exceed the limit even when functional execution
/// finished).
pub fn replay(
    trace: &MemTrace,
    mem: &dyn SharedMemory,
    max_cycles: u64,
) -> Result<RunReport, SimError> {
    let mut stats = CycleStats::default();
    let mut now = 0u64;
    let mut write_pipe = WritePipeline::new(mem.write_buffer_ops());

    for seg in &trace.segments {
        charge_alu(&mut stats, &mut now, &seg.before);
        let n_ops = seg.mem.ops.len() as u64;
        match seg.mem.kind {
            MemAccessKind::Load(class) => {
                // A read instruction pauses fetch/decode until writeback
                // (§III-A): fixed overhead + conflict-spaced operations.
                let mut attributed = mem.overhead(OpKind::Read) as u64;
                for (addrs, mask) in &seg.mem.ops {
                    attributed += mem.op_cost(OpKind::Read, addrs, *mask).max(1) as u64;
                }
                now += attributed;
                stats.operations += n_ops;
                match class {
                    LoadClass::Data => {
                        stats.d_load_cycles += attributed;
                        stats.d_load_ops += n_ops;
                    }
                    LoadClass::Twiddle => {
                        stats.tw_load_cycles += attributed;
                        stats.tw_load_ops += n_ops;
                    }
                }
            }
            MemAccessKind::Store { blocking } => {
                let overhead = mem.overhead(OpKind::Write);
                let start = now;
                let mut iss = now;
                for (addrs, mask) in &seg.mem.ops {
                    let cost = mem.op_cost(OpKind::Write, addrs, *mask);
                    let before = iss;
                    iss = write_pipe.issue_nonblocking(iss, cost.max(1), overhead);
                    // Anything beyond the single issue cycle was a
                    // buffer-full stall. Saturating: a controller that
                    // completes issue in the issue cycle itself must
                    // count zero stall, not underflow.
                    stats.wbuf_stall_cycles += iss.saturating_sub(before + 1);
                }
                stats.operations += n_ops;
                stats.store_ops += n_ops;
                if blocking {
                    // Blocking write: hold the pipeline until the
                    // controller drains.
                    let end = write_pipe.drain(iss);
                    stats.store_cycles += end - start;
                    now = end;
                } else {
                    // Non-blocking: the pipeline continues after issue;
                    // attribute the background service cost so the Store
                    // Cycles row still reflects the memory work (the
                    // paper's accounting).
                    stats.store_cycles +=
                        (write_pipe.busy_until().saturating_sub(start)).max(iss - start);
                    now = iss;
                }
            }
        }
        stats.instructions += 1;
        if now > max_cycles {
            return Err(SimError::CycleLimit { limit: max_cycles });
        }
    }

    charge_alu(&mut stats, &mut now, &trace.tail);
    if now > max_cycles {
        return Err(SimError::CycleLimit { limit: max_cycles });
    }
    // Halt: one issue cycle, then wait out the write controller.
    stats.instructions += 1;
    now += 1;
    let drained = write_pipe.drain(now);
    stats.drain_cycles += drained - now;
    now = drained;
    stats.other_cycles += 1;

    Ok(RunReport {
        program: trace.program.clone(),
        arch: mem.arch(),
        threads: trace.threads,
        stats,
        elapsed_cycles: now,
    })
}

/// Apply the ALU charges accumulated between memory instructions: each
/// class advances the clock by its cycle count (one cycle per 16-thread
/// operation, on every architecture). Shared with the compiled batch
/// replayer ([`crate::sim::compiled`]) so the two charge paths cannot
/// drift.
pub(crate) fn charge_alu(stats: &mut CycleStats, now: &mut u64, charges: &AluCharges) {
    stats.add_alu(charges);
    *now += charges.cycles();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::mem::{FULL_MASK, LANES};
    use crate::sim::exec::{LoadClass, MemInstr, MemTrace};

    fn seq_addrs(stride: u32) -> [u32; LANES] {
        let mut a = [0u32; LANES];
        for (l, x) in a.iter_mut().enumerate() {
            *x = l as u32 * stride;
        }
        a
    }

    fn replay_on(arch: MemoryArchKind, instrs: Vec<MemInstr>) -> RunReport {
        let trace = MemTrace::from_mem_instrs("synthetic", 256, instrs);
        let mem = arch.build(4096);
        replay(&trace, mem.as_ref(), u64::MAX).unwrap()
    }

    #[test]
    fn banked_load_overhead_plus_spacing() {
        // Conflict-free 16-bank load: 12 overhead + 1 cycle per op.
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(1), FULL_MASK); 4],
        };
        let r = replay_on(MemoryArchKind::banked(16), vec![mi]);
        assert_eq!(r.stats.d_load_cycles, 12 + 4);
        assert_eq!(r.stats.d_load_ops, 4);
        // Full conflict: stride 16 lands every lane in bank 0.
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(16), FULL_MASK)],
        };
        let r = replay_on(MemoryArchKind::banked(16), vec![mi]);
        assert_eq!(r.stats.d_load_cycles, 12 + 16);
    }

    #[test]
    fn multiport_costs_closed_form() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(1), FULL_MASK); 4],
        };
        let r = replay_on(MemoryArchKind::mp_4r1w(), vec![mi]);
        assert_eq!(r.stats.d_load_cycles, 16); // 4 ops × ⌈16/4⌉, zero overhead
    }

    #[test]
    fn blocking_store_drains() {
        // 16-bank blocking store, full conflict: 5 overhead + 4 × 16.
        let mi = MemInstr {
            kind: MemAccessKind::Store { blocking: true },
            ops: vec![(seq_addrs(16), FULL_MASK); 4],
        };
        let r = replay_on(MemoryArchKind::banked(16), vec![mi]);
        assert_eq!(r.stats.store_cycles, 5 + 4 * 16);
        assert_eq!(r.stats.drain_cycles, 0);
    }

    #[test]
    fn nonblocking_store_defers_to_halt_drain() {
        let mi = MemInstr {
            kind: MemAccessKind::Store { blocking: false },
            ops: vec![(seq_addrs(16), FULL_MASK); 4],
        };
        let r = replay_on(MemoryArchKind::banked(16), vec![mi]);
        // Same attributed store work as the blocking variant...
        assert_eq!(r.stats.store_cycles, 5 + 4 * 16);
        // ...but the clock only pays at the final halt drain.
        assert!(r.stats.drain_cycles > 0);
    }

    #[test]
    fn zero_latency_write_stream_counts_no_stalls() {
        // Regression (ISSUE 4 satellite): a stream of cost-1 non-blocking
        // writes drains as fast as it issues. `issue_nonblocking` returns
        // `before + 1` on every call, so the stall accounting sits exactly
        // on the saturation boundary — the old `iss - before - 1` was one
        // contract change away from a debug-build underflow panic. The
        // conflict-free multiport write path is the zero-issue-latency
        // extreme (zero overhead, cost 1 with a single active lane).
        let mi = MemInstr {
            kind: MemAccessKind::Store { blocking: false },
            ops: vec![(seq_addrs(1), 0x0001); 64], // one active lane: cost 1
        };
        let trace = MemTrace::from_mem_instrs("wbuf", 16, vec![mi]);
        let mem = MemoryArchKind::mp_4r1w().build(64);
        let r = replay(&trace, mem.as_ref(), u64::MAX).unwrap();
        assert_eq!(r.stats.wbuf_stall_cycles, 0, "cost-1 stream never fills the buffer");
        assert_eq!(r.stats.store_ops, 64);
        // Same invariant on the banked path (cost 1, overhead 5).
        let mem = MemoryArchKind::banked(16).build(1024);
        let r = replay(&trace, mem.as_ref(), u64::MAX).unwrap();
        assert_eq!(r.stats.wbuf_stall_cycles, 0);
    }

    #[test]
    fn twiddle_loads_split_out() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Twiddle),
            ops: vec![(seq_addrs(1), FULL_MASK)],
        };
        let r = replay_on(MemoryArchKind::banked(8), vec![mi]);
        assert_eq!(r.stats.tw_load_ops, 1);
        assert!(r.stats.tw_load_cycles > 0);
        assert_eq!(r.stats.d_load_ops, 0);
    }

    #[test]
    fn cycle_limit_enforced_on_slow_archs() {
        let mi = MemInstr {
            kind: MemAccessKind::Load(LoadClass::Data),
            ops: vec![(seq_addrs(16), FULL_MASK); 64],
        };
        let trace = MemTrace::from_mem_instrs("slow", 1024, vec![mi]);
        let mem = MemoryArchKind::banked(16).build(4096);
        assert!(matches!(
            replay(&trace, mem.as_ref(), 100),
            Err(SimError::CycleLimit { limit: 100 })
        ));
    }

    #[test]
    fn empty_trace_is_just_halt() {
        let trace = MemTrace::from_mem_instrs("empty", 16, vec![]);
        let mem = MemoryArchKind::mp_4r1w().build(64);
        let r = replay(&trace, mem.as_ref(), 1000).unwrap();
        assert_eq!(r.total_cycles(), 1);
        assert_eq!(r.stats.instructions, 1);
        assert_eq!(r.stats.other_cycles, 1);
    }
}
