//! The unified service-layer error: every failure a request can hit,
//! folded into one `std::error::Error` type so exit codes and messages
//! are derived in exactly one place.
//!
//! Before the service layer, the crate's consumers juggled three error
//! conventions: `SimError` from the simulator, bare `String`s from
//! parsing helpers, and `eprintln!` + ad-hoc exit codes in `main.rs`.
//! `ServiceError` absorbs all of them — `SimError` and `AsmError` fold
//! in via `From`, parse failures become typed variants carrying the
//! rejected input, and [`ServiceError::exit_code`] is the single
//! message→exit-code policy the CLI applies.

use crate::isa::asm::AsmError;
use crate::mem::arch::{self, MemoryArchKind};
use crate::sim::exec::SimError;
use std::fmt;

/// Anything a [`crate::service::SimtEngine`] request can fail with.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceError {
    /// The simulator faulted (bad program, invalid address, cycle
    /// limit, ...). `SimError` already implements `std::error::Error` +
    /// `Display`; it rides along as this error's `source`.
    Sim(SimError),
    /// Assembling a custom program failed (carries line context).
    Asm(AsmError),
    /// A program name the library does not know.
    UnknownProgram(String),
    /// A memory descriptor [`MemoryArchKind::parse`] rejects. The
    /// rendered hint quotes [`arch::PARSE_GRAMMAR`], so the
    /// message covers the parametric grammar, not just the paper nine.
    UnknownMemory(String),
    /// A malformed request: unparseable JSON, missing required field,
    /// unknown operation or strategy. Usage-class (exit code 2).
    BadRequest(String),
    /// An I/O failure, annotated with what was being attempted. The
    /// underlying `std::io::Error` is flattened to its message so the
    /// error stays `Clone` (responses are queued and re-rendered).
    Io { context: String, error: String },
    /// The server's backpressure bound rejected the request: `in_flight`
    /// wire lines were already admitted against a dispatcher of depth
    /// `depth` (DESIGN.md §Server). Retryable by the client; exit
    /// code 3 so scripted callers can distinguish "back off and retry"
    /// from usage (2) and execution (1) failures.
    Overloaded { in_flight: usize, depth: usize },
}

impl ServiceError {
    /// Annotate an I/O error with the operation that hit it.
    pub fn io(context: impl Into<String>, e: &std::io::Error) -> Self {
        Self::Io { context: context.into(), error: e.to_string() }
    }

    /// The process exit code this error maps to — the one place the
    /// CLI's exit policy lives. Usage-class errors (malformed request,
    /// unknown name) exit 2, execution failures exit 1, overload
    /// rejections exit 3 (retryable).
    pub fn exit_code(&self) -> i32 {
        match self {
            Self::UnknownProgram(_) | Self::UnknownMemory(_) | Self::BadRequest(_) => 2,
            Self::Sim(_) | Self::Asm(_) | Self::Io { .. } => 1,
            Self::Overloaded { .. } => 3,
        }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Sim(e) => write!(f, "simulation failed: {e}"),
            Self::Asm(e) => write!(f, "assembly failed: {e}"),
            Self::UnknownProgram(name) => {
                write!(f, "unknown program '{name}' (see `soft-simt list`)")
            }
            Self::UnknownMemory(s) => write!(
                f,
                "unknown memory '{s}' (paper set: {}; parametric: {})",
                MemoryArchKind::table3_nine()
                    .iter()
                    .map(|a| a.label())
                    .collect::<Vec<_>>()
                    .join(", "),
                arch::PARSE_GRAMMAR,
            ),
            Self::BadRequest(m) => write!(f, "bad request: {m}"),
            Self::Io { context, error } => write!(f, "{context}: {error}"),
            Self::Overloaded { in_flight, depth } => write!(
                f,
                "server overloaded: {in_flight} requests in flight (depth {depth}); retry later"
            ),
        }
    }
}

impl std::error::Error for ServiceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Sim(e) => Some(e),
            Self::Asm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SimError> for ServiceError {
    fn from(e: SimError) -> Self {
        Self::Sim(e)
    }
}

impl From<AsmError> for ServiceError {
    fn from(e: AsmError) -> Self {
        Self::Asm(e)
    }
}

/// Parse a memory descriptor, mapping rejection to the unified error
/// (with its grammar-bearing hint). The service's one arch-parsing
/// entry — the CLI and the wire codec both call it.
pub fn parse_arch(s: &str) -> Result<MemoryArchKind, ServiceError> {
    MemoryArchKind::parse(s).ok_or_else(|| ServiceError::UnknownMemory(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_split_usage_from_execution() {
        assert_eq!(ServiceError::BadRequest("x".into()).exit_code(), 2);
        assert_eq!(ServiceError::UnknownProgram("x".into()).exit_code(), 2);
        assert_eq!(ServiceError::UnknownMemory("x".into()).exit_code(), 2);
        assert_eq!(ServiceError::Sim(SimError::MissingHalt).exit_code(), 1);
        assert_eq!(
            ServiceError::Asm(AsmError { line: 1, msg: "x".into() }).exit_code(),
            1
        );
        assert_eq!(ServiceError::Overloaded { in_flight: 4, depth: 4 }.exit_code(), 3);
    }

    #[test]
    fn overloaded_message_names_the_bound_and_retry() {
        let msg = ServiceError::Overloaded { in_flight: 5, depth: 4 }.to_string();
        assert!(msg.contains("5 requests in flight"), "{msg}");
        assert!(msg.contains("depth 4"), "{msg}");
        assert!(msg.contains("retry"), "{msg}");
    }

    #[test]
    fn unknown_memory_hint_states_parametric_grammar() {
        let msg = ServiceError::UnknownMemory("17-banks".into()).to_string();
        assert!(msg.contains("16 Banks Offset"), "paper set listed: {msg}");
        assert!(msg.contains("banked8-offset3"), "parametric grammar listed: {msg}");
        assert!(msg.contains("{1,2,4,8}R"), "multiport grammar listed: {msg}");
    }

    #[test]
    fn parse_arch_accepts_parametric_labels() {
        assert!(parse_arch("banked8-offset3").is_ok());
        assert!(parse_arch("2r-1w").is_ok());
        assert!(parse_arch("16-banks-offset").is_ok());
        assert!(parse_arch("nope").is_err());
    }

    #[test]
    fn sources_chain_to_inner_errors() {
        use std::error::Error;
        let e = ServiceError::from(SimError::MissingHalt);
        assert!(e.source().is_some());
        assert!(ServiceError::BadRequest("x".into()).source().is_none());
    }
}
