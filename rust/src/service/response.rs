//! Typed responses — the engine's answer to each
//! [`Request`](super::request::Request) variant, carrying structured
//! results plus a `render()` that reproduces the CLI's stdout
//! byte-for-byte (pinned by `rust/tests/service.rs`).

use super::request::TableKind;
use crate::coordinator::advisor::Advice;
use crate::coordinator::job::BenchResult;
use crate::coordinator::report;
use crate::coordinator::validate::Check;
use crate::explore::{ExploreResult, SystemExploreResult};
use crate::mem::arch::{self, MemoryArchKind};
use crate::obs::MetricsSnapshot;
use crate::programs::library;
use crate::sim::stats::RunReport;

/// The engine's answer to one request. Each request variant is answered
/// by the like-named response variant (the wire `op` fields match, so
/// clients can pair responses to requests).
#[derive(Debug, Clone)]
pub enum Response {
    /// Full report for one cell.
    Run(RunReport),
    /// Full report for an assembled custom program (same payload shape
    /// as [`Response::Run`], distinct wire op).
    Asm(RunReport),
    /// Sweep results with their renderers (text tables + CSV).
    Sweep(SweepOutput),
    /// One rendered paper artifact.
    Table { which: TableKind, text: String },
    /// The advisor's ranked scorecard.
    Advise(Advice),
    /// The explorer's scorecards + Pareto frontier.
    Explore(ExploreResult),
    /// The system explorer's answer — an `Explore` request whose spec
    /// spans processors/lanes (or asks for the throughput-per-ALM
    /// objective) is served from the system model instead. Same wire op
    /// as `Explore`, so clients pair it by request as usual.
    SystemExplore(SystemExploreResult),
    /// Validation outcomes (a failing check is a *result*, not an
    /// error — see [`Response::exit_code`]).
    Validate(ValidationOutput),
    /// Disassembly of a library program.
    Disasm { program: String, text: String },
    /// Program library + memory-architecture sets.
    List(Listing),
    /// Session telemetry snapshot (counters, histograms, recent spans).
    Stats(MetricsSnapshot),
}

impl Response {
    /// Wire operation name (matches the request's).
    pub fn op(&self) -> &'static str {
        match self {
            Response::Run(_) => "run",
            Response::Asm(_) => "asm",
            Response::Sweep(_) => "sweep",
            Response::Table { .. } => "table",
            Response::Advise(_) => "advise",
            Response::Explore(_) => "explore",
            Response::SystemExplore(_) => "explore",
            Response::Validate(_) => "validate",
            Response::Disasm { .. } => "disasm",
            Response::List(_) => "list",
            Response::Stats(_) => "stats",
        }
    }

    /// The stdout text the CLI prints for this response — for `run`,
    /// `sweep` and `explore` byte-identical to the pre-service CLI
    /// (pinned by the parity tests in `rust/tests/service.rs`).
    pub fn render(&self) -> String {
        match self {
            Response::Run(report) | Response::Asm(report) => render_run_report(report),
            Response::Sweep(sweep) => sweep.render(),
            Response::Table { text, .. } => text.clone(),
            Response::Advise(advice) => advice.render(),
            Response::Explore(result) => result.render(),
            Response::SystemExplore(result) => result.render(),
            Response::Validate(v) => v.render(),
            Response::Disasm { text, .. } => text.clone(),
            Response::List(listing) => listing.render(),
            Response::Stats(snapshot) => snapshot.render_text(),
        }
    }

    /// Exit code for a *successful* response: 0 except for validation
    /// with failing checks (exit 1, as the validation suite always did).
    /// Together with [`super::error::ServiceError::exit_code`] this is
    /// the entire exit-code policy.
    pub fn exit_code(&self) -> i32 {
        match self {
            Response::Validate(v) if v.failed() > 0 => 1,
            _ => 0,
        }
    }
}

/// Sweep results plus the flags the renderers need.
#[derive(Debug, Clone)]
pub struct SweepOutput {
    /// Extended sweep (`--all`): reduction cells included.
    pub all: bool,
    pub results: Vec<BenchResult>,
}

impl SweepOutput {
    /// The sweep's stdout: Tables II + III (+ one table per registry
    /// extension member with `all`) + Fig. 9 — exactly the pre-service
    /// `sweep` output for the paper half.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&report::render_table2(&self.results));
        out.push_str(&report::render_table3(&self.results));
        if self.all {
            out.push_str(&report::render_extensions(&self.results));
        }
        out.push_str(&report::render_fig9(&self.results));
        out
    }

    /// Machine-readable counterpart (the `--csv` payload).
    pub fn csv(&self) -> String {
        report::sweep_csv(&self.results)
    }
}

/// The validation suite's outcome.
#[derive(Debug, Clone)]
pub struct ValidationOutput {
    pub checks: Vec<Check>,
    /// Why PJRT golden checks were skipped (stub build or missing
    /// artifacts); `None` when the artifact runtime loaded.
    pub pjrt_note: Option<String>,
}

impl ValidationOutput {
    pub fn failed(&self) -> usize {
        self.checks.iter().filter(|c| !c.passed).count()
    }

    /// Per-check lines plus the summary — the pre-service `validate`
    /// stdout (the PJRT note goes to stderr, client-side).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.checks {
            out.push_str(&format!(
                "[{}] {} — {}\n",
                if c.passed { "PASS" } else { "FAIL" },
                c.name,
                c.detail
            ));
        }
        out.push_str(&format!("\n{} checks, {} failed\n", self.checks.len(), self.failed()));
        out
    }
}

/// The `list` payload: registered programs, kernel-family grammars and
/// memory sets — all enumerated from the workload registry, so `list`
/// can never drift from what `run`/`sweep` accept.
#[derive(Debug, Clone)]
pub struct Listing {
    /// Benchmark-matrix member names, registry order.
    pub programs: Vec<String>,
    /// Kernel families as (id, member grammar).
    pub families: Vec<(String, String)>,
    /// Paper-set architectures with their Fmax in MHz.
    pub paper_archs: Vec<(String, f64)>,
}

impl Listing {
    /// Snapshot the current registry and paper architecture set.
    pub fn current() -> Self {
        use crate::programs::registry;
        Self {
            programs: library::program_names(),
            families: registry::families()
                .iter()
                .map(|f| (f.family.to_string(), f.grammar.to_string()))
                .collect(),
            paper_archs: MemoryArchKind::table3_nine()
                .into_iter()
                .map(|a| (a.label(), a.fmax_mhz()))
                .collect(),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::from("programs:\n");
        for p in &self.programs {
            out.push_str(&format!("  {p}\n"));
        }
        out.push_str("\nkernel families (any member name runs, not just the listed sizes):\n");
        for (family, grammar) in &self.families {
            out.push_str(&format!("  {family:10} {grammar}\n"));
        }
        out.push_str("\nmemory architectures (paper set):\n");
        for (label, fmax) in &self.paper_archs {
            out.push_str(&format!("  {label}  (fmax {fmax:.0} MHz)\n"));
        }
        out.push_str(&format!(
            "\nparametric space (see `explore`): {}\n",
            arch::PARSE_GRAMMAR
        ));
        out
    }
}

/// Render one run report exactly as the CLI prints it (the pre-service
/// `print_report`, line for line).
pub fn render_run_report(r: &RunReport) -> String {
    let s = &r.stats;
    let mut out = String::new();
    out.push_str(&format!("program      {}\n", r.program));
    out.push_str(&format!("memory       {}\n", r.arch));
    out.push_str(&format!("threads      {}\n", r.threads));
    out.push_str(&format!(
        "INT / Imm / FP / Other cycles: {} / {} / {} / {}\n",
        s.int_cycles, s.imm_cycles, s.fp_cycles, s.other_cycles
    ));
    out.push_str(&format!("D load   {} cycles over {} ops\n", s.d_load_cycles, s.d_load_ops));
    if s.tw_load_ops > 0 {
        out.push_str(&format!(
            "TW load  {} cycles over {} ops\n",
            s.tw_load_cycles, s.tw_load_ops
        ));
    }
    out.push_str(&format!("store    {} cycles over {} ops\n", s.store_cycles, s.store_ops));
    out.push_str(&format!(
        "stalls   write-buffer {} / drain {}\n",
        s.wbuf_stall_cycles, s.drain_cycles
    ));
    out.push_str(&format!(
        "total    {} cycles  ({:.2} us @ {:.0} MHz)\n",
        r.total_cycles(),
        r.time_us(),
        r.arch.fmax_mhz()
    ));
    if let Some(e) = r.r_bank_eff() {
        out.push_str(&format!("R bank eff.  {:.1}%\n", e * 100.0));
    }
    if let Some(e) = r.tw_bank_eff() {
        out.push_str(&format!("TW bank eff. {:.1}%\n", e * 100.0));
    }
    if let Some(e) = r.w_bank_eff() {
        out.push_str(&format!("W bank eff.  {:.1}%\n", e * 100.0));
    }
    out.push_str(&format!("compute eff. {:.1}%\n", r.compute_efficiency() * 100.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::BenchJob;

    #[test]
    fn run_render_has_every_paper_row() {
        let r = BenchJob::new("fft4096r8", MemoryArchKind::banked_offset(16)).run().unwrap();
        let text = render_run_report(&r.report);
        for needle in [
            "program      fft4096r8",
            "memory       16 Banks Offset",
            "TW load ",
            "stalls   write-buffer",
            "compute eff.",
        ] {
            assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
        }
    }

    #[test]
    fn listing_renders_programs_and_grammar() {
        let text = Listing::current().render();
        assert!(text.contains("transpose32"));
        assert!(text.contains("reduction4096"));
        assert!(text.contains("scan4096"));
        assert!(text.contains("histogram4096"));
        assert!(text.contains("stencil4096"));
        assert!(text.contains("gemm64"));
        assert!(text.contains("kernel families"));
        assert!(text.contains("16 Banks Offset"));
        assert!(text.contains(arch::PARSE_GRAMMAR));
    }

    #[test]
    fn listing_enumerates_the_registry_verbatim() {
        use crate::programs::registry;
        let listing = Listing::current();
        assert_eq!(listing.programs, registry::program_names());
        assert_eq!(listing.families.len(), registry::families().len());
    }

    #[test]
    fn validation_exit_code_tracks_failures() {
        let pass = Check { name: "a".into(), passed: true, detail: "ok".into() };
        let fail = Check { name: "b".into(), passed: false, detail: "no".into() };
        let good = Response::Validate(ValidationOutput {
            checks: vec![pass.clone()],
            pjrt_note: None,
        });
        assert_eq!(good.exit_code(), 0);
        let bad =
            Response::Validate(ValidationOutput { checks: vec![pass, fail], pjrt_note: None });
        assert_eq!(bad.exit_code(), 1);
        assert!(bad.render().contains("2 checks, 1 failed"));
    }
}
