//! Typed requests — one enum variant per operation the crate serves.
//!
//! A [`Request`] is fully parsed and validated at construction: memory
//! descriptors arrive as [`MemoryArchKind`] (not strings), table and
//! strategy selectors are enums, and the assembler's input is source
//! text. Client-side I/O stays client-side (reading `.asm` files,
//! writing `--csv`/`--json` outputs), which keeps the engine usable
//! behind any transport; the one engine-side filesystem touch is
//! `Validate`, which probes its `artifacts_dir` for PJRT golden
//! artifacts — deployments exposing `serve` to untrusted callers should
//! pin or drop that field. The wire codec ([`crate::service::wire`])
//! maps line-delimited JSON onto these types.

use crate::mem::arch::MemoryArchKind;

/// One operation for [`crate::service::SimtEngine::handle`]. Batches are
/// just slices of these ([`crate::service::SimtEngine::handle_batch`]);
/// every request in a batch shares the engine's trace cache.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Run one benchmark cell (program × memory) and report the paper's
    /// full metric set.
    Run { program: String, mem: MemoryArchKind },
    /// The paper sweep (51 cells), or the whole registry benchmark
    /// matrix (100+ cells across all seven kernel families) with `all`.
    Sweep { all: bool },
    /// Render one paper artifact (Table I needs no simulation; the
    /// others run the paper sweep through the engine cache).
    Table(TableKind),
    /// Rank every candidate memory for a workload (paper nine + XOR).
    Advise { program: String },
    /// Search the parametric memory design space for a workload.
    Explore { program: String, strategy: ExploreStrategy },
    /// Golden validation. `artifacts_dir` points at the PJRT artifacts
    /// (`None` = the default `artifacts/`); without them (or on the
    /// stub build) validation degrades to host references.
    Validate { artifacts_dir: Option<String> },
    /// Assemble `source` and run it on `mem`.
    Asm { source: String, mem: MemoryArchKind },
    /// Disassemble a library program.
    Disasm { program: String },
    /// The program library and memory-architecture sets.
    List,
    /// Session telemetry: a snapshot of a metrics registry (counters,
    /// latency histograms, recent request spans — DESIGN.md
    /// §Observability). `scope` picks which registry: the engine-global
    /// one every client shares, or the caller's own per-session
    /// bookkeeping (DESIGN.md §Server). Read-only and cheap; safe to
    /// interleave into batches (a stats item is a sequencing barrier in
    /// the concurrent batch path, so its snapshot still reflects every
    /// earlier item in the batch).
    Stats { scope: StatsScope },
}

impl Request {
    /// Wire operation name (the `"op"` field of the JSON encoding).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Run { .. } => "run",
            Request::Sweep { .. } => "sweep",
            Request::Table(_) => "table",
            Request::Advise { .. } => "advise",
            Request::Explore { .. } => "explore",
            Request::Validate { .. } => "validate",
            Request::Asm { .. } => "asm",
            Request::Disasm { .. } => "disasm",
            Request::List => "list",
            Request::Stats { .. } => "stats",
        }
    }
}

/// Which metrics registry a `Stats` request snapshots.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StatsScope {
    /// The engine-global registry shared by every client (the default,
    /// and the wire behavior when no `scope` field is sent).
    #[default]
    Engine,
    /// The caller's own per-session registry (DESIGN.md §Server). On
    /// the engine directly — i.e. outside any [`crate::server::Session`]
    /// — the engine registry *is* the session registry (single-session
    /// adapter semantics), so the snapshot differs only in its reported
    /// `scope` label.
    Session,
}

impl StatsScope {
    /// Wire name (the `"scope"` field of the JSON encoding, and the
    /// snapshot's reported `scope`).
    pub fn name(self) -> &'static str {
        match self {
            StatsScope::Engine => "engine",
            StatsScope::Session => "session",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "engine" => Some(Self::Engine),
            "session" => Some(Self::Session),
            _ => None,
        }
    }
}

/// Which paper artifact a `Table` request renders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableKind {
    /// Table I: resources + Fmax model (no simulation).
    Table1,
    /// Table II: transpose profiling.
    Table2,
    /// Table III: FFT profiling.
    Table3,
    /// Fig. 9: cost vs performance.
    Fig9,
}

impl TableKind {
    pub const ALL: [TableKind; 4] =
        [TableKind::Table1, TableKind::Table2, TableKind::Table3, TableKind::Fig9];

    /// Wire / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            TableKind::Table1 => "table1",
            TableKind::Table2 => "table2",
            TableKind::Table3 => "table3",
            TableKind::Fig9 => "fig9",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|t| t.name() == s)
    }

    /// Whether rendering needs sweep results (everything but Table I).
    pub fn needs_sweep(self) -> bool {
        !matches!(self, TableKind::Table1)
    }
}

/// Search strategy selector for `Explore` requests (mirrors
/// [`crate::explore::strategy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExploreStrategy {
    /// Exhaustive grid search.
    Exhaustive,
    /// Dominance-based successive halving (frontier-exact; the default).
    #[default]
    Halving,
}

impl ExploreStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ExploreStrategy::Exhaustive => "exhaustive",
            ExploreStrategy::Halving => "halving",
        }
    }

    /// Accepts the CLI aliases (`grid`, `pruning`) too.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "exhaustive" | "grid" => Some(Self::Exhaustive),
            "halving" | "pruning" => Some(Self::Halving),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_kinds_roundtrip_names() {
        for t in TableKind::ALL {
            assert_eq!(TableKind::parse(t.name()), Some(t));
        }
        assert_eq!(TableKind::parse("table4"), None);
        assert!(TableKind::Table2.needs_sweep());
        assert!(!TableKind::Table1.needs_sweep());
    }

    #[test]
    fn strategies_parse_with_aliases() {
        assert_eq!(ExploreStrategy::parse("exhaustive"), Some(ExploreStrategy::Exhaustive));
        assert_eq!(ExploreStrategy::parse("grid"), Some(ExploreStrategy::Exhaustive));
        assert_eq!(ExploreStrategy::parse("halving"), Some(ExploreStrategy::Halving));
        assert_eq!(ExploreStrategy::parse("pruning"), Some(ExploreStrategy::Halving));
        assert_eq!(ExploreStrategy::parse("dfs"), None);
        assert_eq!(ExploreStrategy::default(), ExploreStrategy::Halving);
    }

    #[test]
    fn stats_scopes_roundtrip_names() {
        for scope in [StatsScope::Engine, StatsScope::Session] {
            assert_eq!(StatsScope::parse(scope.name()), Some(scope));
        }
        assert_eq!(StatsScope::parse("global"), None);
        assert_eq!(StatsScope::default(), StatsScope::Engine);
    }

    #[test]
    fn ops_are_stable_wire_names() {
        assert_eq!(Request::List.op(), "list");
        assert_eq!(Request::Stats { scope: StatsScope::default() }.op(), "stats");
        assert_eq!(Request::Sweep { all: false }.op(), "sweep");
        assert_eq!(
            Request::Run {
                program: "transpose32".into(),
                mem: MemoryArchKind::banked_offset(16)
            }
            .op(),
            "run"
        );
    }
}
