//! Typed requests — one enum variant per operation the crate serves.
//!
//! A [`Request`] is fully parsed and validated at construction: memory
//! descriptors arrive as [`MemoryArchKind`] (not strings), table and
//! strategy selectors are enums, and the assembler's input is source
//! text. Client-side I/O stays client-side (reading `.asm` files,
//! writing `--csv`/`--json` outputs), which keeps the engine usable
//! behind any transport; the one engine-side filesystem touch is
//! `Validate`, which probes its `artifacts_dir` for PJRT golden
//! artifacts — deployments exposing `serve` to untrusted callers should
//! pin or drop that field. The wire codec ([`crate::service::wire`])
//! maps line-delimited JSON onto these types.

use crate::explore::system::SystemSpace;
use crate::explore::DesignSpace;
use crate::mem::arch::{MemoryArchKind, PARSE_GRAMMAR};
use crate::mem::mapping::BankMapping;
use crate::service::error::ServiceError;

/// Generate a wire-facing selector enum with the shared name/parse
/// idiom: a canonical wire name per variant (plus optional parse-only
/// aliases), `name()`, `parse()` and an `ALL` listing. One macro instead
/// of the three hand-rolled copies [`TableKind`], [`StatsScope`] and
/// [`ExploreStrategy`] used to carry — and the contract every future
/// selector ([`ExploreObjective`]) gets for free: `parse(name()) == id`,
/// unknown strings parse to `None`.
macro_rules! wire_enum {
    (
        $(#[$meta:meta])*
        $vis:vis enum $name:ident {
            $(
                $(#[$vmeta:meta])*
                $variant:ident = $canon:literal $(| $alias:literal)*
            ),+ $(,)?
        }
    ) => {
        $(#[$meta])*
        $vis enum $name {
            $( $(#[$vmeta])* $variant, )+
        }

        impl $name {
            /// Every variant, in declaration order.
            pub const ALL: &'static [$name] = &[ $( $name::$variant, )+ ];

            /// Canonical wire / CLI name.
            pub fn name(self) -> &'static str {
                match self {
                    $( $name::$variant => $canon, )+
                }
            }

            /// Parse a canonical name or any of its aliases.
            pub fn parse(s: &str) -> Option<Self> {
                match s {
                    $( $canon $(| $alias)* => Some($name::$variant), )+
                    _ => None,
                }
            }
        }
    };
}

/// One operation for [`crate::service::SimtEngine::handle`]. Batches are
/// just slices of these ([`crate::service::SimtEngine::handle_batch`]);
/// every request in a batch shares the engine's trace cache.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Run one benchmark cell (program × memory) and report the paper's
    /// full metric set.
    Run { program: String, mem: MemoryArchKind },
    /// The paper sweep (51 cells), or the whole registry benchmark
    /// matrix (100+ cells across all seven kernel families) with `all`.
    Sweep { all: bool },
    /// Render one paper artifact (Table I needs no simulation; the
    /// others run the paper sweep through the engine cache).
    Table(TableKind),
    /// Rank every candidate memory for a workload (paper nine + XOR).
    Advise { program: String },
    /// Search a memory design space for a workload. `spec` describes
    /// the space ([`ExploreSpec`]); `None` is the deprecated legacy
    /// shape and means exactly today's parametric space
    /// ([`crate::explore::DesignSpace::parametric`]) — every
    /// pre-redesign wire line keeps answering byte-identically.
    Explore { program: String, strategy: ExploreStrategy, spec: Option<ExploreSpec> },
    /// Golden validation. `artifacts_dir` points at the PJRT artifacts
    /// (`None` = the default `artifacts/`); without them (or on the
    /// stub build) validation degrades to host references.
    Validate { artifacts_dir: Option<String> },
    /// Assemble `source` and run it on `mem`.
    Asm { source: String, mem: MemoryArchKind },
    /// Disassemble a library program.
    Disasm { program: String },
    /// The program library and memory-architecture sets.
    List,
    /// Session telemetry: a snapshot of a metrics registry (counters,
    /// latency histograms, recent request spans — DESIGN.md
    /// §Observability). `scope` picks which registry: the engine-global
    /// one every client shares, or the caller's own per-session
    /// bookkeeping (DESIGN.md §Server). Read-only and cheap; safe to
    /// interleave into batches (a stats item is a sequencing barrier in
    /// the concurrent batch path, so its snapshot still reflects every
    /// earlier item in the batch).
    Stats { scope: StatsScope },
}

impl Request {
    /// Wire operation name (the `"op"` field of the JSON encoding).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Run { .. } => "run",
            Request::Sweep { .. } => "sweep",
            Request::Table(_) => "table",
            Request::Advise { .. } => "advise",
            Request::Explore { .. } => "explore",
            Request::Validate { .. } => "validate",
            Request::Asm { .. } => "asm",
            Request::Disasm { .. } => "disasm",
            Request::List => "list",
            Request::Stats { .. } => "stats",
        }
    }
}

wire_enum! {
    /// Which metrics registry a `Stats` request snapshots. The wire name
    /// is the `"scope"` field of the JSON encoding, and the snapshot's
    /// reported `scope`.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub enum StatsScope {
        /// The engine-global registry shared by every client (the
        /// default, and the wire behavior when no `scope` field is
        /// sent).
        #[default]
        Engine = "engine",
        /// The caller's own per-session registry (DESIGN.md §Server). On
        /// the engine directly — i.e. outside any
        /// [`crate::server::Session`] — the engine registry *is* the
        /// session registry (single-session adapter semantics), so the
        /// snapshot differs only in its reported `scope` label.
        Session = "session",
    }
}

wire_enum! {
    /// Which paper artifact a `Table` request renders.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum TableKind {
        /// Table I: resources + Fmax model (no simulation).
        Table1 = "table1",
        /// Table II: transpose profiling.
        Table2 = "table2",
        /// Table III: FFT profiling.
        Table3 = "table3",
        /// Fig. 9: cost vs performance.
        Fig9 = "fig9",
    }
}

impl TableKind {
    /// Whether rendering needs sweep results (everything but Table I).
    pub fn needs_sweep(self) -> bool {
        !matches!(self, TableKind::Table1)
    }
}

wire_enum! {
    /// Search strategy selector for `Explore` requests (mirrors
    /// [`crate::explore::strategy`]). `grid` and `pruning` are accepted
    /// CLI aliases.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub enum ExploreStrategy {
        /// Exhaustive grid search.
        Exhaustive = "exhaustive" | "grid",
        /// Dominance-based successive halving (frontier-exact; the
        /// default).
        #[default]
        Halving = "halving" | "pruning",
    }
}

wire_enum! {
    /// Ranking objective of an exploration ([`ExploreSpec::objective`]).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub enum ExploreObjective {
        /// The flat explorer's cycles × ALMs Pareto ranking (the
        /// default, and the only pre-redesign behavior).
        #[default]
        TimeArea = "time-area" | "time",
        /// The system explorer's `ops / (cycles/fmax) / alms` ranking.
        /// Selecting it promotes a spec without explicit `processors` /
        /// `lanes` to a system exploration over the single-core shapes.
        ThroughputPerAlm = "throughput-per-alm" | "throughput",
    }
}

/// A serializable description of the design space an `Explore` request
/// searches — the typed replacement for the old hardwired parametric
/// space. Every field is optional; an absent field means the parametric
/// default, and an absent spec altogether means exactly the legacy
/// behavior. The spec lowers onto the [`DesignSpace`] builder (flat
/// memory × capacity exploration) or, when it names `processors`,
/// `lanes` or the throughput objective, onto the system-space builder
/// ([`SystemSpace`], [`crate::explore::system`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExploreSpec {
    /// Banked bank counts (crossed with every mapping).
    pub banks: Option<Vec<u32>>,
    /// Bank mappings by name: `lsb`, `offset`, `offsetN`, `xor`.
    pub mappings: Option<Vec<String>>,
    /// Multiport configurations by compact label: `4r-1w`, `4r-2w`,
    /// `4r-1w-vb`, … An explicit empty list drops multiport entirely.
    pub multiport: Option<Vec<String>>,
    /// Candidate shared-memory capacities in KB.
    pub capacities_kb: Option<Vec<u32>>,
    /// System dimension: candidate core counts. Present ⇒ system
    /// exploration.
    pub processors: Option<Vec<u32>>,
    /// System dimension: candidate datapath widths in lanes. Present ⇒
    /// system exploration.
    pub lanes: Option<Vec<u32>>,
    /// Ranking objective (default [`ExploreObjective::TimeArea`]).
    pub objective: Option<ExploreObjective>,
    /// Minimum modeled clock (MHz) a point must reach — filters 600 MHz
    /// multiport points out of a 700 MHz design, say.
    pub target_clock_mhz: Option<f64>,
}

impl ExploreSpec {
    /// Whether this spec asks for the system-scale explorer: an explicit
    /// `processors`/`lanes` axis, or the throughput-per-ALM objective.
    pub fn is_system(&self) -> bool {
        self.processors.is_some()
            || self.lanes.is_some()
            || self.objective == Some(ExploreObjective::ThroughputPerAlm)
    }

    fn bad(what: &str, value: &str) -> ServiceError {
        ServiceError::BadRequest(format!(
            "unknown {what} '{value}' in explore spec ({PARSE_GRAMMAR})"
        ))
    }

    fn mapping_of(name: &str) -> Option<BankMapping> {
        match name {
            "lsb" => Some(BankMapping::Lsb),
            "xor" => Some(BankMapping::Xor),
            "offset" => Some(BankMapping::offset()),
            _ => {
                let shift = name.strip_prefix("offset")?.parse().ok()?;
                let m = BankMapping::Offset { shift };
                m.is_valid().then_some(m)
            }
        }
    }

    /// The spec's memory-architecture slate: banks × mappings plus the
    /// multiport labels, parametric defaults for absent fields.
    fn archs(&self) -> Result<Vec<MemoryArchKind>, ServiceError> {
        let banks = self.banks.clone().unwrap_or_else(|| vec![2, 4, 8, 16, 32]);
        for &b in &banks {
            if !MemoryArchKind::banked(b).is_valid() {
                return Err(Self::bad("bank count", &b.to_string()));
            }
        }
        let mappings: Vec<BankMapping> = match &self.mappings {
            None => vec![
                BankMapping::Lsb,
                BankMapping::Offset { shift: 1 },
                BankMapping::offset(),
                BankMapping::Offset { shift: 3 },
                BankMapping::Xor,
            ],
            Some(names) => names
                .iter()
                .map(|n| Self::mapping_of(n).ok_or_else(|| Self::bad("mapping", n)))
                .collect::<Result<_, _>>()?,
        };
        let multiport: Vec<MemoryArchKind> = match &self.multiport {
            None => vec![
                MemoryArchKind::mp_4r1w(),
                MemoryArchKind::mp_4r2w(),
                MemoryArchKind::mp_4r1w_vb(),
                MemoryArchKind::MultiPort { read_ports: 2, write_ports: 1, vb: false },
                MemoryArchKind::MultiPort { read_ports: 8, write_ports: 1, vb: false },
            ],
            Some(labels) => labels
                .iter()
                .map(|l| {
                    MemoryArchKind::parse(l)
                        .filter(|m| matches!(m, MemoryArchKind::MultiPort { .. }))
                        .ok_or_else(|| Self::bad("multiport config", l))
                })
                .collect::<Result<_, _>>()?,
        };
        let mut archs = Vec::new();
        for &b in &banks {
            for &m in &mappings {
                let a = MemoryArchKind::Banked { banks: b, mapping: m };
                if !archs.contains(&a) {
                    archs.push(a);
                }
            }
        }
        for a in multiport {
            if !archs.contains(&a) {
                archs.push(a);
            }
        }
        Ok(archs)
    }

    /// The spec's capacity slate (parametric default: dataset × 1/2/4).
    fn capacities(&self, dataset_kb: u32) -> Vec<u32> {
        let d = dataset_kb.max(1);
        self.capacities_kb.clone().unwrap_or_else(|| vec![d, 2 * d, 4 * d])
    }

    /// Lower onto the flat [`DesignSpace`] builder, with the parametric
    /// space's roofline and fits-dataset constraints and the optional
    /// target-clock filter.
    pub fn design_space(&self, dataset_kb: u32) -> Result<DesignSpace, ServiceError> {
        let mut space = DesignSpace::new().capacities_kb(self.capacities(dataset_kb));
        for a in self.archs()? {
            space = space.arch(a);
        }
        space = space.with_capacity_roofline().fits_dataset(dataset_kb.max(1));
        if let Some(t) = self.target_clock_mhz {
            space = space.constraint("fmax >= target clock", move |p| p.arch.fmax_mhz() >= t);
        }
        Ok(space)
    }

    /// Lower onto the system-space builder ([`SystemSpace`]); absent
    /// `processors`/`lanes` default to the {1,2,4} × {16,32,64} grid.
    pub fn system_space(&self, dataset_kb: u32) -> Result<SystemSpace, ServiceError> {
        use crate::explore::system::{MAX_LANES, MAX_PROCESSORS, SystemPoint};
        let processors = self.processors.clone().unwrap_or_else(|| vec![1, 2, 4]);
        let lanes = self.lanes.clone().unwrap_or_else(|| vec![16, 32, 64]);
        let probe = MemoryArchKind::banked(16);
        for &p in &processors {
            let pt = SystemPoint { processors: p, lanes: 16, mem: probe, capacity_kb: 8 };
            if !(p >= 1 && p <= MAX_PROCESSORS && pt.is_valid()) {
                return Err(Self::bad("processor count", &p.to_string()));
            }
        }
        for &l in &lanes {
            let pt = SystemPoint { processors: 1, lanes: l, mem: probe, capacity_kb: 8 };
            if !(l >= 1 && l <= MAX_LANES && pt.is_valid()) {
                return Err(Self::bad("lane count", &l.to_string()));
            }
        }
        let mut space = SystemSpace::new()
            .processors(processors)
            .lanes(lanes)
            .capacities_kb(self.capacities(dataset_kb));
        for a in self.archs()? {
            space = space.arch(a);
        }
        if let Some(t) = self.target_clock_mhz {
            space = space.target_clock_mhz(t);
        }
        Ok(space)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_kinds_roundtrip_names() {
        for &t in TableKind::ALL {
            assert_eq!(TableKind::parse(t.name()), Some(t));
        }
        assert_eq!(TableKind::parse("table4"), None);
        assert!(TableKind::Table2.needs_sweep());
        assert!(!TableKind::Table1.needs_sweep());
    }

    #[test]
    fn wire_enums_share_the_roundtrip_contract() {
        // The wire_enum! macro's invariant, over every generated enum:
        // parse ∘ name = id, and unknown strings parse to None.
        for &s in StatsScope::ALL {
            assert_eq!(StatsScope::parse(s.name()), Some(s));
        }
        for &s in ExploreStrategy::ALL {
            assert_eq!(ExploreStrategy::parse(s.name()), Some(s));
        }
        for &o in ExploreObjective::ALL {
            assert_eq!(ExploreObjective::parse(o.name()), Some(o));
        }
        assert_eq!(ExploreObjective::parse("latency"), None);
    }

    #[test]
    fn objective_parses_with_aliases_and_defaults_to_time_area() {
        assert_eq!(ExploreObjective::parse("throughput"), Some(ExploreObjective::ThroughputPerAlm));
        assert_eq!(ExploreObjective::parse("time"), Some(ExploreObjective::TimeArea));
        assert_eq!(ExploreObjective::default(), ExploreObjective::TimeArea);
    }

    #[test]
    fn default_spec_lowers_to_the_parametric_space() {
        // An all-absent spec must describe exactly the legacy space.
        let spec = ExploreSpec::default();
        assert!(!spec.is_system());
        let lowered = spec.design_space(8).unwrap();
        let parametric = DesignSpace::parametric(8);
        assert_eq!(lowered.points(), parametric.points());
    }

    #[test]
    fn spec_axes_narrow_the_flat_space() {
        let spec = ExploreSpec {
            banks: Some(vec![4, 16]),
            mappings: Some(vec!["offset2".into()]),
            multiport: Some(vec![]), // explicit empty: banked only
            capacities_kb: Some(vec![8, 16]),
            ..Default::default()
        };
        let pts = spec.design_space(8).unwrap().points();
        assert_eq!(pts.len(), 2 * 1 * 2);
        assert!(pts.iter().all(|p| matches!(p.arch, MemoryArchKind::Banked { .. })));
    }

    #[test]
    fn spec_system_promotion_rules() {
        assert!(ExploreSpec { processors: Some(vec![1, 2]), ..Default::default() }.is_system());
        assert!(ExploreSpec { lanes: Some(vec![32]), ..Default::default() }.is_system());
        assert!(ExploreSpec {
            objective: Some(ExploreObjective::ThroughputPerAlm),
            ..Default::default()
        }
        .is_system());
        assert!(!ExploreSpec {
            objective: Some(ExploreObjective::TimeArea),
            ..Default::default()
        }
        .is_system());
    }

    #[test]
    fn spec_system_space_defaults_and_filters() {
        let spec = ExploreSpec { processors: Some(vec![1, 2, 4]), ..Default::default() };
        let space = spec.system_space(8).unwrap();
        // Default lanes {16,32,64} × default 30-arch slate × 3 caps.
        assert_eq!(space.points().len(), 3 * 3 * 30 * 3);
        // A target clock above 600 MHz drops the 4R-2W points.
        let clocked = ExploreSpec {
            processors: Some(vec![1]),
            lanes: Some(vec![16]),
            target_clock_mhz: Some(700.0),
            ..Default::default()
        };
        let pts = clocked.system_space(8).unwrap().points();
        assert!(!pts.is_empty());
        assert!(pts.iter().all(|p| p.fmax_mhz() >= 700.0));
        assert!(!pts.iter().any(|p| p.mem == MemoryArchKind::mp_4r2w()));
    }

    #[test]
    fn spec_errors_quote_the_grammar() {
        let cases: Vec<ExploreSpec> = vec![
            ExploreSpec { banks: Some(vec![7]), ..Default::default() },
            ExploreSpec { mappings: Some(vec!["diagonal".into()]), ..Default::default() },
            ExploreSpec { multiport: Some(vec!["9r-9w".into()]), ..Default::default() },
        ];
        for spec in cases {
            let err = spec.design_space(8).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("explore spec"), "{msg}");
            assert!(msg.contains("banked8-offset3"), "grammar quoted: {msg}");
        }
        let err = ExploreSpec { processors: Some(vec![3]), ..Default::default() }
            .system_space(8)
            .unwrap_err();
        assert!(err.to_string().contains("processor count"), "{err}");
        let err = ExploreSpec { lanes: Some(vec![48]), ..Default::default() }
            .system_space(8)
            .unwrap_err();
        assert!(err.to_string().contains("lane count"), "{err}");
    }

    #[test]
    fn strategies_parse_with_aliases() {
        assert_eq!(ExploreStrategy::parse("exhaustive"), Some(ExploreStrategy::Exhaustive));
        assert_eq!(ExploreStrategy::parse("grid"), Some(ExploreStrategy::Exhaustive));
        assert_eq!(ExploreStrategy::parse("halving"), Some(ExploreStrategy::Halving));
        assert_eq!(ExploreStrategy::parse("pruning"), Some(ExploreStrategy::Halving));
        assert_eq!(ExploreStrategy::parse("dfs"), None);
        assert_eq!(ExploreStrategy::default(), ExploreStrategy::Halving);
    }

    #[test]
    fn stats_scopes_roundtrip_names() {
        for scope in [StatsScope::Engine, StatsScope::Session] {
            assert_eq!(StatsScope::parse(scope.name()), Some(scope));
        }
        assert_eq!(StatsScope::parse("global"), None);
        assert_eq!(StatsScope::default(), StatsScope::Engine);
    }

    #[test]
    fn ops_are_stable_wire_names() {
        assert_eq!(Request::List.op(), "list");
        assert_eq!(Request::Stats { scope: StatsScope::default() }.op(), "stats");
        assert_eq!(Request::Sweep { all: false }.op(), "sweep");
        assert_eq!(
            Request::Run {
                program: "transpose32".into(),
                mem: MemoryArchKind::banked_offset(16)
            }
            .op(),
            "run"
        );
    }
}
