//! The unified service layer — how the crate is consumed (DESIGN.md
//! §Service).
//!
//! The paper's end state is a memory-architecture decision *service*:
//! "a comprehensive set of data which will guide the reader in making an
//! informed memory architecture decision" (§I). This module is that
//! service's substrate. One long-lived [`SimtEngine`] session owns the
//! worker pool, a persistent trace cache, and the wiring to the program
//! library, the explorer and the footprint model; every operation the
//! crate performs — `run`, `sweep`, the paper tables, `advise`,
//! `explore`, `validate`, `asm`, `disasm`, `list`, `stats` — is a typed
//! [`Request`] answered with a typed [`Response`], and every failure is
//! one [`ServiceError`] (`SimError` and `AsmError` fold in via `From`),
//! so messages and exit codes are derived in exactly one place. The
//! session also owns a [`crate::obs::MetricsRegistry`]: every request is
//! counted, latency-histogrammed and span-recorded, and `Request::Stats`
//! answers a snapshot (DESIGN.md §Observability).
//!
//! Because the cache is session-scoped, request cost collapses across a
//! batch: a 51-cell paper sweep plus a design-space exploration plus any
//! number of repeat `run`s performs exactly **six** functional
//! executions (one per distinct workload) — counted by
//! [`SimtEngine::functional_executions`] and asserted in
//! `rust/tests/service.rs`.
//!
//! [`wire`] adds a dependency-free line-delimited JSON codec and the
//! transport loop behind `soft-simt serve` — written once against
//! [`wire::WireHandler`], so the stdin/stdout adapter and every socket
//! client of a [`crate::server::SocketServer`] (`serve --listen ADDR`,
//! DESIGN.md §Server) run the identical code path over a shared engine.
//! The CLI (`main.rs`) is a thin client of the same API: construct
//! request, `engine.handle()`, render response.
//!
//! ```no_run
//! use soft_simt::prelude::*;
//!
//! let engine = SimtEngine::new();
//! let resp = engine
//!     .handle(&Request::Run {
//!         program: "fft4096r16".into(),
//!         mem: MemoryArchKind::banked_offset(16),
//!     })
//!     .unwrap();
//! print!("{}", resp.render());
//! assert_eq!(engine.functional_executions(), 1);
//! ```

pub mod engine;
pub mod error;
pub mod request;
pub mod response;
pub mod wire;

pub use engine::SimtEngine;
pub use error::{parse_arch, ServiceError};
pub use request::{ExploreObjective, ExploreSpec, ExploreStrategy, Request, StatsScope, TableKind};
pub use response::{Listing, Response, SweepOutput, ValidationOutput};
