//! Line-delimited JSON wire codec + the `serve` loop.
//!
//! One request per line, one response line per request; a line holding a
//! JSON *array* of requests is a batch and is answered with one JSON
//! array of responses (order preserved, traces shared across the whole
//! batch). The codec is hand-rolled in the crate's established JSON
//! style (the explorer's `to_json`, the bench `BENCH_*.json` emitters) —
//! the crate is dependency-free, so this is the entire parser and
//! serializer.
//!
//! Request grammar (`"op"` selects the variant; other fields per op):
//!
//! ```text
//! {"op":"run","program":"transpose32","mem":"16-banks-offset"}
//! {"op":"sweep","all":true}
//! {"op":"table","which":"table2"}
//! {"op":"advise","program":"fft4096r16"}
//! {"op":"explore","program":"transpose32","strategy":"halving"}
//! {"op":"validate","artifacts":"artifacts"}
//! {"op":"asm","source":".threads 16\n    halt\n","mem":"16-banks"}
//! {"op":"disasm","program":"transpose32"}
//! {"op":"list"}
//! {"op":"stats"}
//! {"op":"stats","scope":"session"}
//! ```
//!
//! Responses carry `"ok"` plus structured fields per variant and the
//! CLI-rendered `"text"`. Errors are `{"ok":false,"error":...,
//! "exit_code":N}` — the same unified `ServiceError` policy the CLI
//! derives its exit codes from.

use super::engine::SimtEngine;
use super::error::{parse_arch, ServiceError};
use super::request::{ExploreObjective, ExploreSpec, ExploreStrategy, Request, StatsScope, TableKind};
use super::response::Response;
use crate::obs::{Phase, Span};
use crate::server::Dispatcher;
use crate::util::fmt::json_str;
use std::io::{BufRead, Write};

// ---------------------------------------------------------------------
// Minimal JSON value + parser.
// ---------------------------------------------------------------------

/// A parsed JSON value (objects keep insertion order; no number
/// distinction beyond f64 — ample for the wire grammar).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse one JSON document (the whole input must be consumed, modulo
/// trailing whitespace).
pub fn parse_json(input: &str) -> Result<Json, ServiceError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value().map_err(ServiceError::BadRequest)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(ServiceError::BadRequest(format!(
            "trailing input at byte {} of request line",
            p.pos
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            if (0xDC00..0xE000).contains(&hi) {
                                // A low surrogate with no preceding high
                                // half — same class as a lone high one.
                                return Err("lone surrogate".into());
                            }
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require \uXXXX low half.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u').map_err(|_| "bad surrogate pair")?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(format!(
                                            "bad surrogate pair \\u{hi:04x}\\u{lo:04x}"
                                        ));
                                    }
                                    let code =
                                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(code).ok_or("bad surrogate pair")?
                                } else {
                                    return Err("lone surrogate".into());
                                }
                            } else {
                                char::from_u32(hi).ok_or("bad \\u escape")?
                            };
                            out.push(c);
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (possibly multibyte).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid utf-8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        // Exactly four hex digits — `from_str_radix` alone would also
        // accept a leading `+` (`\u+12f` must not parse as an escape).
        let digits = &self.bytes[self.pos..end];
        if !digits.iter().all(|b| b.is_ascii_hexdigit()) {
            return Err("bad \\u escape".into());
        }
        let s = std::str::from_utf8(digits).map_err(|_| "bad \\u escape")?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape")?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

// ---------------------------------------------------------------------
// Request decode / encode.
// ---------------------------------------------------------------------

/// Decode one request object.
pub fn request_from_json(v: &Json) -> Result<Request, ServiceError> {
    if !matches!(v, Json::Obj(_)) {
        return Err(ServiceError::BadRequest("request must be a JSON object".into()));
    }
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ServiceError::BadRequest("missing string field 'op'".into()))?;
    let program = |field: &str| req_str_field(v, op, field);
    let mem = |default: &str| parse_arch(opt_str_field(v, "mem")?.unwrap_or(default));
    match op {
        "run" => Ok(Request::Run { program: program("program")?, mem: mem("16-banks-offset")? }),
        "sweep" => Ok(Request::Sweep {
            all: match v.get("all") {
                None | Some(Json::Null) => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => {
                    return Err(ServiceError::BadRequest(
                        "field 'all' must be a boolean".into(),
                    ))
                }
            },
        }),
        "table" => {
            let which = program("which")?;
            TableKind::parse(&which)
                .map(Request::Table)
                .ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "unknown table '{which}' (try: table1, table2, table3, fig9)"
                    ))
                })
        }
        "advise" => Ok(Request::Advise { program: program("program")? }),
        "explore" => {
            let strategy = match opt_str_field(v, "strategy")? {
                None => ExploreStrategy::default(),
                Some(s) => ExploreStrategy::parse(s).ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "unknown strategy '{s}' (try: exhaustive, halving)"
                    ))
                })?,
            };
            let spec = match v.get("spec") {
                None | Some(Json::Null) => None,
                Some(s @ Json::Obj(_)) => Some(explore_spec_from_json(s)?),
                Some(_) => {
                    return Err(ServiceError::BadRequest(
                        "field 'spec' must be an object".into(),
                    ))
                }
            };
            Ok(Request::Explore { program: program("program")?, strategy, spec })
        }
        "validate" => Ok(Request::Validate {
            artifacts_dir: opt_str_field(v, "artifacts")?.map(String::from),
        }),
        "asm" => Ok(Request::Asm { source: program("source")?, mem: mem("16-banks")? }),
        "disasm" => Ok(Request::Disasm { program: program("program")? }),
        "list" => Ok(Request::List),
        "stats" => {
            let scope = match opt_str_field(v, "scope")? {
                None => StatsScope::default(),
                Some(s) => StatsScope::parse(s).ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "unknown scope '{s}' (try: engine, session)"
                    ))
                })?,
            };
            Ok(Request::Stats { scope })
        }
        other => Err(ServiceError::BadRequest(format!("unknown op '{other}'"))),
    }
}

/// Fetch an optional string field, type-checked rather than silently
/// defaulted: a present-but-wrong-typed field is a `BadRequest` (a
/// client sending `"mem":16` must not be answered with the default
/// memory). An explicit `null` reads as absent.
fn opt_str_field<'a>(v: &'a Json, field: &str) -> Result<Option<&'a str>, ServiceError> {
    match v.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.as_str())),
        Some(_) => {
            Err(ServiceError::BadRequest(format!("field '{field}' must be a string")))
        }
    }
}

/// Fetch a required string field (op context in the error).
fn req_str_field(v: &Json, op: &str, field: &str) -> Result<String, ServiceError> {
    opt_str_field(v, field)?.map(String::from).ok_or_else(|| {
        ServiceError::BadRequest(format!("op '{op}' needs string field '{field}'"))
    })
}

/// Decode the typed `"spec"` object of an explore request. Unknown keys
/// are rejected — a typo'd axis name must not silently fall back to the
/// full default slate — and every present field is type-checked, same
/// policy as [`opt_str_field`]. An explicit `null` value reads as
/// absent. Semantic validation (bank counts, mapping names, lane
/// shapes) happens later, when the spec lowers onto a space
/// ([`ExploreSpec::design_space`] / [`ExploreSpec::system_space`]), so
/// decode errors are purely structural. Public because the CLI's
/// `explore --spec` flag decodes the same document standalone.
pub fn explore_spec_from_json(v: &Json) -> Result<ExploreSpec, ServiceError> {
    let Json::Obj(pairs) = v else {
        return Err(ServiceError::BadRequest("explore spec must be a JSON object".into()));
    };
    let mut spec = ExploreSpec::default();
    for (key, val) in pairs {
        if matches!(val, Json::Null) {
            continue;
        }
        match key.as_str() {
            "banks" => spec.banks = Some(u32_list(val, key)?),
            "mappings" => spec.mappings = Some(str_list(val, key)?),
            "multiport" => spec.multiport = Some(str_list(val, key)?),
            "capacities_kb" => spec.capacities_kb = Some(u32_list(val, key)?),
            "processors" => spec.processors = Some(u32_list(val, key)?),
            "lanes" => spec.lanes = Some(u32_list(val, key)?),
            "objective" => {
                let s = val.as_str().ok_or_else(|| {
                    ServiceError::BadRequest(
                        "spec field 'objective' must be a string".into(),
                    )
                })?;
                spec.objective = Some(ExploreObjective::parse(s).ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "unknown objective '{s}' (try: time-area, throughput-per-alm)"
                    ))
                })?);
            }
            "target_clock_mhz" => {
                let n = val.as_f64().filter(|n| n.is_finite() && *n > 0.0).ok_or_else(
                    || {
                        ServiceError::BadRequest(
                            "spec field 'target_clock_mhz' must be a positive number"
                                .into(),
                        )
                    },
                )?;
                spec.target_clock_mhz = Some(n);
            }
            other => {
                return Err(ServiceError::BadRequest(format!(
                    "unknown explore spec field '{other}' (known: banks, mappings, \
                     multiport, capacities_kb, processors, lanes, objective, \
                     target_clock_mhz)"
                )))
            }
        }
    }
    Ok(spec)
}

/// A spec axis holding small non-negative integers (bank counts, KB
/// capacities, core counts, lane counts).
fn u32_list(v: &Json, field: &str) -> Result<Vec<u32>, ServiceError> {
    let Json::Arr(items) = v else {
        return Err(ServiceError::BadRequest(format!(
            "spec field '{field}' must be an array of integers"
        )));
    };
    items
        .iter()
        .map(|item| {
            item.as_f64()
                .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= f64::from(u32::MAX))
                .map(|n| n as u32)
                .ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "spec field '{field}' must hold non-negative integers"
                    ))
                })
        })
        .collect()
}

/// A spec axis holding names (bank mappings, multiport descriptors).
fn str_list(v: &Json, field: &str) -> Result<Vec<String>, ServiceError> {
    let Json::Arr(items) = v else {
        return Err(ServiceError::BadRequest(format!(
            "spec field '{field}' must be an array of strings"
        )));
    };
    items
        .iter()
        .map(|item| {
            item.as_str().map(String::from).ok_or_else(|| {
                ServiceError::BadRequest(format!(
                    "spec field '{field}' must hold strings"
                ))
            })
        })
        .collect()
}

/// Parse one wire line: a request object or a batch array of them.
pub fn requests_from_line(line: &str) -> Result<Vec<Request>, ServiceError> {
    match parse_json(line)? {
        v @ Json::Obj(_) => Ok(vec![request_from_json(&v)?]),
        Json::Arr(items) => items.iter().map(request_from_json).collect(),
        _ => Err(ServiceError::BadRequest(
            "request line must be a JSON object or array of objects".into(),
        )),
    }
}

/// Encode a request as one wire line (round-trips through
/// [`request_from_json`]; pinned for every variant in
/// `rust/tests/service.rs`).
pub fn request_to_json(req: &Request) -> String {
    match req {
        Request::Run { program, mem } => format!(
            "{{\"op\":\"run\",\"program\":{},\"mem\":{}}}",
            json_str(program),
            json_str(&mem.label())
        ),
        Request::Sweep { all } => format!("{{\"op\":\"sweep\",\"all\":{all}}}"),
        Request::Table(which) => {
            format!("{{\"op\":\"table\",\"which\":{}}}", json_str(which.name()))
        }
        Request::Advise { program } => {
            format!("{{\"op\":\"advise\",\"program\":{}}}", json_str(program))
        }
        // An absent spec encodes to the exact pre-redesign byte
        // sequence (parity-pinned); a present spec appends only its
        // `Some` fields, in declaration order.
        Request::Explore { program, strategy, spec } => {
            let mut out = format!(
                "{{\"op\":\"explore\",\"program\":{},\"strategy\":{}",
                json_str(program),
                json_str(strategy.name())
            );
            if let Some(spec) = spec {
                out.push_str(&format!(",\"spec\":{}", spec_to_json(spec)));
            }
            out.push('}');
            out
        }
        Request::Validate { artifacts_dir } => match artifacts_dir {
            Some(dir) => format!("{{\"op\":\"validate\",\"artifacts\":{}}}", json_str(dir)),
            None => "{\"op\":\"validate\"}".to_string(),
        },
        Request::Asm { source, mem } => format!(
            "{{\"op\":\"asm\",\"source\":{},\"mem\":{}}}",
            json_str(source),
            json_str(&mem.label())
        ),
        Request::Disasm { program } => {
            format!("{{\"op\":\"disasm\",\"program\":{}}}", json_str(program))
        }
        Request::List => "{\"op\":\"list\"}".to_string(),
        // The default (engine) scope encodes bare, so pre-scope clients
        // and goldens see the exact byte sequence they always did.
        Request::Stats { scope: StatsScope::Engine } => "{\"op\":\"stats\"}".to_string(),
        Request::Stats { scope } => {
            format!("{{\"op\":\"stats\",\"scope\":{}}}", json_str(scope.name()))
        }
    }
}

/// Encode an [`ExploreSpec`], `Some` fields only, declaration order
/// (round-trips through [`spec_from_json`]).
fn spec_to_json(spec: &ExploreSpec) -> String {
    fn nums(items: &[u32]) -> String {
        items.iter().map(u32::to_string).collect::<Vec<_>>().join(",")
    }
    fn strs(items: &[String]) -> String {
        items.iter().map(String::as_str).map(json_str).collect::<Vec<_>>().join(",")
    }
    let mut fields = Vec::new();
    if let Some(b) = &spec.banks {
        fields.push(format!("\"banks\":[{}]", nums(b)));
    }
    if let Some(m) = &spec.mappings {
        fields.push(format!("\"mappings\":[{}]", strs(m)));
    }
    if let Some(m) = &spec.multiport {
        fields.push(format!("\"multiport\":[{}]", strs(m)));
    }
    if let Some(c) = &spec.capacities_kb {
        fields.push(format!("\"capacities_kb\":[{}]", nums(c)));
    }
    if let Some(p) = &spec.processors {
        fields.push(format!("\"processors\":[{}]", nums(p)));
    }
    if let Some(l) = &spec.lanes {
        fields.push(format!("\"lanes\":[{}]", nums(l)));
    }
    if let Some(o) = spec.objective {
        fields.push(format!("\"objective\":{}", json_str(o.name())));
    }
    if let Some(t) = spec.target_clock_mhz {
        fields.push(format!("\"target_clock_mhz\":{t}"));
    }
    format!("{{{}}}", fields.join(","))
}

// ---------------------------------------------------------------------
// Response encode.
// ---------------------------------------------------------------------

/// Encode one handled request as a single response line.
pub fn result_to_json(result: &Result<Response, ServiceError>) -> String {
    match result {
        Ok(resp) => response_to_json(resp),
        Err(e) => error_to_json(e),
    }
}

/// `{"ok":false,...}` for the unified error (same exit-code policy the
/// CLI applies).
pub fn error_to_json(e: &ServiceError) -> String {
    format!(
        "{{\"ok\":false,\"error\":{},\"exit_code\":{}}}",
        json_str(&e.to_string()),
        e.exit_code()
    )
}

/// `{"ok":true,"op":...,...,"text":...}` with per-variant structured
/// fields; `text` is the CLI rendering.
pub fn response_to_json(resp: &Response) -> String {
    let mut out = format!("{{\"ok\":true,\"op\":{}", json_str(resp.op()));
    match resp {
        Response::Run(r) | Response::Asm(r) => {
            let s = &r.stats;
            out.push_str(&format!(
                ",\"program\":{},\"memory\":{},\"threads\":{},\"total_cycles\":{},\
                 \"time_us\":{:.4},\"stats\":{{\"int_cycles\":{},\"imm_cycles\":{},\
                 \"fp_cycles\":{},\"other_cycles\":{},\"d_load_ops\":{},\"d_load_cycles\":{},\
                 \"tw_load_ops\":{},\"tw_load_cycles\":{},\"store_ops\":{},\"store_cycles\":{},\
                 \"wbuf_stall_cycles\":{},\"drain_cycles\":{}}}",
                json_str(&r.program),
                json_str(&r.arch.label()),
                r.threads,
                r.total_cycles(),
                r.time_us(),
                s.int_cycles,
                s.imm_cycles,
                s.fp_cycles,
                s.other_cycles,
                s.d_load_ops,
                s.d_load_cycles,
                s.tw_load_ops,
                s.tw_load_cycles,
                s.store_ops,
                s.store_cycles,
                s.wbuf_stall_cycles,
                s.drain_cycles,
            ));
        }
        Response::Sweep(sweep) => {
            out.push_str(&format!(
                ",\"all\":{},\"cells\":{},\"csv\":{}",
                sweep.all,
                sweep.results.len(),
                json_str(&sweep.csv())
            ));
        }
        Response::Table { which, .. } => {
            out.push_str(&format!(",\"which\":{}", json_str(which.name())));
        }
        Response::Advise(advice) => {
            out.push_str(&format!(
                ",\"program\":{},\"dataset_kb\":{},\"candidates\":{},\"fastest\":{},\
                 \"most_perf_per_area\":{}",
                json_str(&advice.program),
                advice.dataset_kb,
                advice.candidates.len(),
                json_str(&advice.fastest().arch.label()),
                json_str(&advice.most_efficient().arch.label()),
            ));
        }
        Response::Explore(result) => {
            // The explorer's own JSON document, flattened to one line
            // (its newlines are structural; in-string newlines are
            // escaped by `json_str`).
            out.push_str(&format!(",\"result\":{}", result.to_json().replace('\n', " ")));
        }
        Response::SystemExplore(result) => {
            // Same shape as the flat explorer: the system explorer's own
            // JSON document under "result", flattened to one line.
            out.push_str(&format!(",\"result\":{}", result.to_json().replace('\n', " ")));
        }
        Response::Validate(v) => {
            out.push_str(&format!(
                ",\"checks\":{},\"failed\":{},\"pjrt_note\":{}",
                v.checks.len(),
                v.failed(),
                v.pjrt_note.as_deref().map(json_str).unwrap_or_else(|| "null".into()),
            ));
        }
        Response::Disasm { program, .. } => {
            out.push_str(&format!(",\"program\":{}", json_str(program)));
        }
        Response::List(listing) => {
            let programs: Vec<String> =
                listing.programs.iter().map(String::as_str).map(json_str).collect();
            let families: Vec<String> = listing
                .families
                .iter()
                .map(|(f, g)| format!("{{\"family\":{},\"grammar\":{}}}", json_str(f), json_str(g)))
                .collect();
            let memories: Vec<String> =
                listing.paper_archs.iter().map(|(l, _)| json_str(l)).collect();
            out.push_str(&format!(
                ",\"programs\":[{}],\"families\":[{}],\"memories\":[{}]",
                programs.join(","),
                families.join(","),
                memories.join(",")
            ));
        }
        Response::Stats(snapshot) => {
            // The snapshot's own fields (counters / histograms / spans),
            // spliced brace-free into the response object. This is the
            // same document `serve --metrics-json` dumps standalone.
            out.push_str(&format!(",{}", snapshot.to_json_fields()));
        }
    }
    out.push_str(&format!(",\"text\":{}}}", json_str(&resp.render())));
    out
}

// ---------------------------------------------------------------------
// The serve loop.
// ---------------------------------------------------------------------

/// What a wire transport serves lines against: a bare [`SimtEngine`]
/// (the single-session CLI adapter) or a [`crate::server::Session`]
/// (one client of a shared engine, with its own bookkeeping). The
/// transport ([`serve_with`]) is written once against this trait, so
/// stdin/stdout and every socket client run the identical code path —
/// the byte-identity the parity tests pin.
pub trait WireHandler {
    /// Open the span covering one wire line, labelled `op`.
    fn line_span(&self, op: &'static str) -> Span;
    /// Serve one request inside the line's span (parse/render phases
    /// accrue to the same span around the dispatch).
    fn handle_in_span(&self, req: &Request, span: &mut Span)
        -> Result<Response, ServiceError>;
    /// Serve a batch line, responses in request order.
    fn handle_batch(&self, reqs: &[Request]) -> Vec<Result<Response, ServiceError>>;
    /// Record the finished line span.
    fn finish_line_span(&self, span: Span);
}

impl WireHandler for SimtEngine {
    fn line_span(&self, op: &'static str) -> Span {
        self.metrics().span(op)
    }

    fn handle_in_span(&self, req: &Request, span: &mut Span)
        -> Result<Response, ServiceError> {
        SimtEngine::handle_in_span(self, req, span)
    }

    fn handle_batch(&self, reqs: &[Request]) -> Vec<Result<Response, ServiceError>> {
        SimtEngine::handle_batch(self, reqs)
    }

    fn finish_line_span(&self, span: Span) {
        self.metrics().finish_span(span);
    }
}

/// Read request lines from `input`, answer each on `output` — the whole
/// transport of `soft-simt serve`. Blank lines are skipped; a malformed
/// line yields an `{"ok":false,...}` line and the loop continues; an
/// array line is answered with an array of responses. Every request in
/// the session shares the handler's engine (hence its trace cache).
///
/// Each wire line records one span in the handler's metrics registry:
/// the transport attributes JSON decode to `parse` and encode to
/// `render`. A single-request line dispatches inside that span; a batch
/// line's span is labelled `"batch"` and covers decode/render, while
/// its items fan out through [`WireHandler::handle_batch`] (responses
/// reassembled in submission order) and record their own per-request
/// spans.
pub fn serve<H: WireHandler, R: BufRead, W: Write>(
    handler: &H,
    input: R,
    output: W,
) -> std::io::Result<()> {
    serve_with(handler, None, input, output)
}

/// [`serve`] with an optional admission bound: when `limiter` is given,
/// each non-blank line first takes a [`Dispatcher`] permit (held until
/// the line's reply is written); past the configured depth the line is
/// answered `{"ok":false,...,"exit_code":3}` without decoding it —
/// overload rejection must stay cheap — and the loop continues. The
/// socket front-end shares one dispatcher across every client; the
/// stdin adapter passes `None` (one client cannot overload itself).
pub fn serve_with<H: WireHandler, R: BufRead, W: Write>(
    handler: &H,
    limiter: Option<&Dispatcher>,
    input: R,
    mut output: W,
) -> std::io::Result<()> {
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let _permit = match limiter.map(|d| d.admit()) {
            None => None,
            Some(Ok(permit)) => Some(permit),
            Some(Err(e)) => {
                writeln!(output, "{}", error_to_json(&e))?;
                output.flush()?;
                continue;
            }
        };
        let mut span = handler.line_span("line");
        let reply = match span.time(Phase::Parse, || parse_json(&line)) {
            Ok(Json::Arr(items)) => {
                span.set_op("batch");
                let decoded: Vec<Result<Request, ServiceError>> =
                    span.time(Phase::Parse, || {
                        items.iter().map(request_from_json).collect()
                    });
                let valid: Vec<Request> =
                    decoded.iter().filter_map(|d| d.as_ref().ok()).cloned().collect();
                let mut handled = handler.handle_batch(&valid).into_iter();
                let results: Vec<Result<Response, ServiceError>> = decoded
                    .into_iter()
                    .map(|d| match d {
                        Ok(_) => handled.next().expect("one result per valid request"),
                        Err(e) => Err(e),
                    })
                    .collect();
                let parts: Vec<String> = span
                    .time(Phase::Render, || results.iter().map(result_to_json).collect());
                format!("[{}]", parts.join(","))
            }
            Ok(v) => {
                let result = match span.time(Phase::Parse, || request_from_json(&v)) {
                    Ok(req) => {
                        span.set_op(req.op());
                        handler.handle_in_span(&req, &mut span)
                    }
                    Err(e) => Err(e),
                };
                span.time(Phase::Render, || result_to_json(&result))
            }
            Err(e) => error_to_json(&e),
        };
        handler.finish_line_span(span);
        writeln!(output, "{reply}")?;
        output.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse_json("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        let v = parse_json(r#"{"a":[1,{"b":"c"},false],"d":null}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Null));
        let Some(Json::Arr(items)) = v.get("a") else { panic!("array field") };
        assert_eq!(items.len(), 3);
        assert_eq!(items[1].get("b").and_then(Json::as_str), Some("c"));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse_json("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        // Surrogate pair: U+1F600.
        assert_eq!(
            parse_json("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1F600}".into())
        );
        // Raw multibyte UTF-8 passes through.
        assert_eq!(parse_json("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "{\"a\"}", "[1,]", "tru", "\"unterminated", "{} extra"] {
            assert!(parse_json(bad).is_err(), "'{bad}' must be rejected");
        }
        // A high surrogate must be followed by a valid low surrogate.
        assert!(parse_json("\"\\ud83d\\u0041\"").is_err(), "bad low surrogate rejected");
        assert!(parse_json("\"\\ud83dx\"").is_err(), "lone surrogate rejected");
    }

    #[test]
    fn rejects_lone_and_malformed_unicode_escapes() {
        for (bad, why) in [
            ("\"\\udc00\"", "unpaired low surrogate"),
            ("\"\\udfff\"", "unpaired low surrogate (top of range)"),
            ("\"\\ud800\"", "high surrogate at end of string"),
            ("\"\\ud800\\n\"", "high surrogate followed by a non-u escape"),
            ("\"\\ud800\\ud800\"", "high surrogate followed by another high"),
            ("\"\\u+12f\"", "sign accepted by from_str_radix is not a hex digit"),
            ("\"\\u12\"", "truncated escape"),
            ("\"\\u12g4\"", "non-hex digit"),
        ] {
            assert!(parse_json(bad).is_err(), "{why}: {bad}");
        }
        // The boundary neighbours still parse.
        assert_eq!(parse_json("\"\\ud7ff\"").unwrap(), Json::Str("\u{D7FF}".into()));
        assert_eq!(parse_json("\"\\ue000\"").unwrap(), Json::Str("\u{E000}".into()));
    }

    /// Escape/unescape round-trip: any string `json_str` encodes — raw
    /// multibyte UTF-8 (including chars above U+FFFF), control chars,
    /// quotes, backslashes — parses back to the identical string.
    #[test]
    fn escape_roundtrip_on_random_strings() {
        use crate::util::proptest::check;
        check("parse_json(json_str(s)) == s", 200, |rng| {
            let len = rng.below(24) as usize;
            let s: String = (0..len)
                .map(|_| match rng.below(6) {
                    // Printable ASCII, quotes and backslashes included.
                    0 | 1 => char::from_u32(0x20 + rng.below(0x5F)).unwrap(),
                    // Control characters (the \uXXXX emit path).
                    2 => char::from_u32(rng.below(0x20)).unwrap(),
                    // Multibyte BMP.
                    3 => ['é', 'ß', '中', '\u{D7FF}', '\u{E000}'][rng.below(5) as usize],
                    // Above U+FFFF (would need a surrogate pair if the
                    // encoder escaped it; it emits raw UTF-8 instead).
                    4 => ['\u{1F600}', '\u{10000}', '\u{10FFFF}'][rng.below(3) as usize],
                    _ => ['\n', '\t', '\r', '"', '\\'][rng.below(5) as usize],
                })
                .collect();
            let encoded = json_str(&s);
            assert_eq!(parse_json(&encoded).unwrap(), Json::Str(s), "via {encoded}");
        });
    }

    /// Escaped surrogate *pairs* decode to the astral scalar — the other
    /// direction of the round-trip (our encoder never emits pairs, but
    /// clients may).
    #[test]
    fn surrogate_pairs_decode_to_astral_chars() {
        for (pair, want) in [
            ("\"\\ud800\\udc00\"", '\u{10000}'),
            ("\"\\ud83d\\ude00\"", '\u{1F600}'),
            ("\"\\udbff\\udfff\"", '\u{10FFFF}'),
        ] {
            assert_eq!(parse_json(pair).unwrap(), Json::Str(want.to_string()), "{pair}");
        }
    }

    #[test]
    fn wrong_typed_optional_fields_are_rejected() {
        let e = requests_from_line("{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":16}")
            .unwrap_err();
        assert!(e.to_string().contains("'mem'"), "{e}");
        let e = requests_from_line("{\"op\":\"sweep\",\"all\":\"true\"}").unwrap_err();
        assert!(e.to_string().contains("'all'"), "{e}");
        let e = requests_from_line("{\"op\":\"validate\",\"artifacts\":3}").unwrap_err();
        assert!(e.to_string().contains("'artifacts'"), "{e}");
        // Explicit null reads as absent, matching the defaults.
        let reqs =
            requests_from_line("{\"op\":\"sweep\",\"all\":null}").unwrap();
        assert_eq!(reqs[0], Request::Sweep { all: false });
    }

    #[test]
    fn escape_roundtrip_through_parser() {
        let nasty = "line1\nline2\t\"quoted\" back\\slash\r\u{0001}";
        let encoded = json_str(nasty);
        assert_eq!(parse_json(&encoded).unwrap(), Json::Str(nasty.into()));
    }

    #[test]
    fn bad_requests_are_typed() {
        let e = requests_from_line("{\"op\":\"frobnicate\"}").unwrap_err();
        assert!(matches!(e, ServiceError::BadRequest(_)));
        assert_eq!(e.exit_code(), 2);
        let e = requests_from_line("{\"op\":\"run\"}").unwrap_err();
        assert!(e.to_string().contains("program"), "{e}");
        let e = requests_from_line("{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":\"x\"}")
            .unwrap_err();
        assert!(matches!(e, ServiceError::UnknownMemory(_)));
        assert!(requests_from_line("42").is_err());
    }

    #[test]
    fn defaults_match_the_cli() {
        let reqs =
            requests_from_line("{\"op\":\"run\",\"program\":\"transpose32\"}").unwrap();
        let Request::Run { mem, .. } = &reqs[0] else { panic!("run request") };
        assert_eq!(mem.label(), "16 Banks Offset");
        let reqs = requests_from_line("{\"op\":\"sweep\"}").unwrap();
        assert_eq!(reqs[0], Request::Sweep { all: false });
        let reqs =
            requests_from_line("{\"op\":\"explore\",\"program\":\"transpose32\"}").unwrap();
        let Request::Explore { strategy, .. } = &reqs[0] else { panic!("explore request") };
        assert_eq!(*strategy, ExploreStrategy::Halving);
    }

    #[test]
    fn explore_spec_decodes_typed_fields() {
        let reqs = requests_from_line(
            "{\"op\":\"explore\",\"program\":\"transpose32\",\"spec\":{\"banks\":[4,16],\
             \"mappings\":[\"offset2\"],\"processors\":[1,2],\"lanes\":[32],\
             \"objective\":\"throughput\",\"target_clock_mhz\":700}}",
        )
        .unwrap();
        let Request::Explore { spec: Some(spec), .. } = &reqs[0] else {
            panic!("explore with spec")
        };
        assert_eq!(spec.banks, Some(vec![4, 16]));
        assert_eq!(spec.mappings, Some(vec!["offset2".to_string()]));
        assert_eq!(spec.processors, Some(vec![1, 2]));
        assert_eq!(spec.lanes, Some(vec![32]));
        assert_eq!(spec.objective, Some(ExploreObjective::ThroughputPerAlm));
        assert_eq!(spec.target_clock_mhz, Some(700.0));
        assert!(spec.is_system());
        // Explicit null spec reads as absent, like every optional field.
        let reqs = requests_from_line(
            "{\"op\":\"explore\",\"program\":\"transpose32\",\"spec\":null}",
        )
        .unwrap();
        let Request::Explore { spec, .. } = &reqs[0] else { panic!("explore") };
        assert_eq!(*spec, None);
    }

    #[test]
    fn explore_spec_rejects_malformed_fields() {
        for (line, needle) in [
            ("{\"op\":\"explore\",\"program\":\"t\",\"spec\":3}", "'spec'"),
            ("{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"banks\":4}}", "'banks'"),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"banks\":[4.5]}}",
                "'banks'",
            ),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"processors\":[-1]}}",
                "'processors'",
            ),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"mappings\":[1]}}",
                "'mappings'",
            ),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"objective\":\"x\"}}",
                "objective",
            ),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\
                 \"spec\":{\"target_clock_mhz\":\"fast\"}}",
                "target_clock_mhz",
            ),
            (
                "{\"op\":\"explore\",\"program\":\"t\",\"spec\":{\"bankz\":[4]}}",
                "unknown explore spec field 'bankz'",
            ),
        ] {
            let e = requests_from_line(line).unwrap_err();
            assert!(matches!(e, ServiceError::BadRequest(_)), "{line}");
            assert!(e.to_string().contains(needle), "'{needle}' not in: {e}");
        }
    }

    #[test]
    fn specless_explore_encodes_the_legacy_bytes() {
        let req = Request::Explore {
            program: "transpose32".into(),
            strategy: ExploreStrategy::Halving,
            spec: None,
        };
        assert_eq!(
            request_to_json(&req),
            "{\"op\":\"explore\",\"program\":\"transpose32\",\"strategy\":\"halving\"}"
        );
    }

    #[test]
    fn spec_encode_emits_some_fields_in_declaration_order() {
        let req = Request::Explore {
            program: "t".into(),
            strategy: ExploreStrategy::Exhaustive,
            spec: Some(ExploreSpec {
                banks: Some(vec![4, 16]),
                lanes: Some(vec![16, 32]),
                objective: Some(ExploreObjective::ThroughputPerAlm),
                target_clock_mhz: Some(700.0),
                ..Default::default()
            }),
        };
        let line = request_to_json(&req);
        assert_eq!(
            line,
            "{\"op\":\"explore\",\"program\":\"t\",\"strategy\":\"exhaustive\",\
             \"spec\":{\"banks\":[4,16],\"lanes\":[16,32],\
             \"objective\":\"throughput-per-alm\",\"target_clock_mhz\":700}}"
        );
        // And the encoding round-trips.
        assert_eq!(requests_from_line(&line).unwrap()[0], req);
    }

    #[test]
    fn batch_lines_decode_in_order() {
        let reqs = requests_from_line(
            "[{\"op\":\"list\"},{\"op\":\"disasm\",\"program\":\"transpose32\"}]",
        )
        .unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0], Request::List);
        assert_eq!(reqs[1], Request::Disasm { program: "transpose32".into() });
    }
}
