//! `SimtEngine` — the long-lived session every consumer routes through.
//!
//! One engine owns the worker pool ([`SweepRunner`]), a persistent
//! [`TraceCache`], and the wiring to the program library, the footprint
//! model and the explorer. Requests go through [`SimtEngine::handle`]
//! (or [`SimtEngine::handle_batch`], responses in order), and every
//! operation shares the engine's cache: a 51-cell sweep plus an
//! exploration plus any number of repeat `Run`s costs exactly one
//! functional execution per distinct `(program, seed)` — six for the
//! paper set, counted by [`SimtEngine::functional_executions`] and
//! asserted in `rust/tests/service.rs`.
//!
//! The engine is `&self` throughout (the cache is internally locked, the
//! runner is immutable), so one engine can sit behind a transport and
//! serve callers without external synchronization.

use super::error::ServiceError;
use super::request::{ExploreStrategy, Request, TableKind};
use super::response::{Listing, Response, SweepOutput, ValidationOutput};
use crate::coordinator::advisor;
use crate::coordinator::job::{BenchJob, TraceCache};
use crate::coordinator::report;
use crate::coordinator::runner::SweepRunner;
use crate::coordinator::validate;
use crate::explore::{self, DesignSpace, Exhaustive, SearchStrategy, SuccessiveHalving};
use crate::isa::asm;
use crate::obs::{Counter, Hist, MetricsRegistry, Phase, Span};
use crate::programs::library;
use crate::runtime::ArtifactRuntime;
use crate::sim::config::MachineConfig;
use crate::sim::machine::Machine;
use std::sync::Arc;
use std::time::Instant;

/// The service session: worker pool + persistent trace cache + request
/// dispatch. See the module docs.
#[derive(Debug)]
pub struct SimtEngine {
    runner: SweepRunner,
    cache: TraceCache,
    /// Session telemetry (DESIGN.md §Observability). The engine owns
    /// the registry and shares it (`Arc`) into the runner and the
    /// cache, which the explorer and advisor in turn inherit — one set
    /// of counters for everything a session does.
    metrics: Arc<MetricsRegistry>,
}

impl Default for SimtEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SimtEngine {
    /// An engine with the default worker pool (one worker per core,
    /// capped at 16).
    pub fn new() -> Self {
        Self::with_runner(SweepRunner::default())
    }

    /// An engine over a caller-sized worker pool.
    pub fn with_runner(runner: SweepRunner) -> Self {
        let metrics = Arc::new(MetricsRegistry::new());
        let cache = TraceCache::new();
        cache.attach_metrics(Arc::clone(&metrics));
        let runner = runner.with_metrics(Arc::clone(&metrics));
        Self { runner, cache, metrics }
    }

    pub fn runner(&self) -> &SweepRunner {
        &self.runner
    }

    /// The session's trace cache (shared across every request).
    pub fn cache(&self) -> &TraceCache {
        &self.cache
    }

    /// The session's metrics registry. `Request::Stats` answers a
    /// snapshot of this; benches and the `--metrics-json` dump read the
    /// same source.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Functional executions performed so far — the
    /// `exec.functional_executions` counter: trace captures (each
    /// inserts one cache entry) plus coupled runs of custom `Asm`
    /// programs (which have no library cache key). Validation's
    /// functional checks are deliberately excluded — they verify
    /// *data*, which replay by construction cannot, so they are not a
    /// cost the cache could ever share. The engine's defining economy:
    /// repeat requests over cached workloads leave this counter
    /// unchanged. **Exact under concurrency**: captures count inside
    /// the trace store's single-flight initializer, so N clients racing
    /// on one cold key contribute exactly one increment
    /// (`rust/tests/server.rs` pins this).
    pub fn functional_executions(&self) -> u64 {
        self.metrics.get(Counter::FunctionalExecutions)
    }

    /// Serve one request. Errors are per-request values, never process
    /// state: the engine stays fully usable after any failure.
    pub fn handle(&self, req: &Request) -> Result<Response, ServiceError> {
        let mut span = self.metrics.span(req.op());
        let result = self.handle_in_span(req, &mut span);
        self.metrics.finish_span(span);
        result
    }

    /// [`Self::handle`] inside a caller-owned [`Span`] — the wire
    /// transport uses this so one span can also cover its parse/render
    /// phases. All request-level counters and the request-latency
    /// histogram are charged here.
    pub fn handle_in_span(
        &self,
        req: &Request,
        span: &mut Span,
    ) -> Result<Response, ServiceError> {
        let t0 = Instant::now();
        // Functional executions are counted at the point of capture —
        // inside the trace store's single-flight initializer (see
        // `TraceCache::get_or_capture`) — not by cache-size deltas, so
        // the count stays exact when requests overlap. Asm runs, which
        // have no cache key, count explicitly in dispatch.
        let result = self.dispatch(req, span);
        self.metrics.inc(Counter::RequestsServed);
        if result.is_err() {
            self.metrics.inc(Counter::RequestsErrors);
        }
        self.metrics.observe(Hist::RequestMicros, t0.elapsed().as_micros() as u64);
        result
    }

    /// Serve a batch, responses in request order. The whole batch shares
    /// the engine cache, so `{paper sweep, explore, N repeat runs}`
    /// costs the same six functional executions as the sweep alone. A
    /// failing request yields its error in place; later requests still
    /// run.
    ///
    /// Internally the batch is no longer strictly sequential:
    /// independent requests fan out onto the [`SweepRunner`] pool and
    /// are reassembled in submission order (DESIGN.md §Server). The one
    /// ordering-sensitive request is `Stats` — its snapshot-on-read
    /// semantics promise it reflects every earlier request in the batch
    /// — so stats items act as **sequencing barriers**: the requests
    /// before one complete first, the stats item runs alone, then the
    /// rest proceeds. Trace sharing makes this safe (concurrent items
    /// racing on one workload still cost one capture, single-flight);
    /// responses and per-request metrics are identical to the
    /// sequential path, only wall-clock and span ring order differ.
    pub fn handle_batch(&self, reqs: &[Request]) -> Vec<Result<Response, ServiceError>> {
        let mut out = Vec::with_capacity(reqs.len());
        for segment in
            reqs.split_inclusive(|r| matches!(r, Request::Stats { .. }))
        {
            let (concurrent, barrier) = match segment.last() {
                Some(Request::Stats { .. }) => {
                    (&segment[..segment.len() - 1], segment.last())
                }
                _ => (segment, None),
            };
            match concurrent {
                [] => {}
                [one] => out.push(self.handle(one)),
                many => out.extend(self.runner.map(many, |r| self.handle(r))),
            }
            if let Some(stats) = barrier {
                out.push(self.handle(stats));
            }
        }
        out
    }

    /// Attribute a timed sweep's phases to the request's span.
    fn span_sweep_phases(span: &mut Span, phases: &crate::coordinator::runner::SweepPhases) {
        span.add(Phase::Execute, phases.capture);
        span.add(Phase::Compile, phases.compile);
        span.add(Phase::Replay, phases.replay);
    }

    fn dispatch(&self, req: &Request, span: &mut Span) -> Result<Response, ServiceError> {
        match req {
            Request::Run { program, mem } => {
                self.require_program(program)?;
                let job = BenchJob::new(program.clone(), *mem);
                let key = job.trace_key();
                // One counted cache lookup per run (the capture path
                // re-checks via the uncounted peek).
                let cached = span.time(Phase::CacheLookup, || self.cache.get(&key));
                let warm = cached.is_some();
                let trace = match cached {
                    Some(trace) => trace,
                    None => span.time(Phase::Execute, || self.cache.get_or_capture(&job))?,
                };
                // A cold one-shot run charges the reference replayer —
                // compiling the per-op gather rows just to read one
                // arch's slot would cost more than it saves. From the
                // second touch of a trace on, runs are closed-form
                // compiled lookups through the direct single-arch walk
                // (no per-call batch state, no address re-hashing, no
                // dyn dispatch — DESIGN.md §Replay); batch requests
                // (Sweep/Table/Explore) instead go through the
                // lane-packed kernel via the runner. All paths are
                // RunReport-identical (replay_diff harness).
                let (result, replayed_in) = if warm {
                    let compiled =
                        span.time(Phase::Compile, || self.cache.get_or_compile(&key, &trace));
                    let t0 = Instant::now();
                    let result = job.replay_compiled(&compiled)?;
                    (result, t0.elapsed())
                } else {
                    let t0 = Instant::now();
                    let result = job.replay_trace(&trace)?;
                    (result, t0.elapsed())
                };
                span.add(Phase::Replay, replayed_in);
                self.metrics.inc(Counter::ReplayScalarInvocations);
                self.metrics
                    .add(Counter::ReplayWbufStallCycles, result.report.stats.wbuf_stall_cycles);
                self.metrics.observe(Hist::ReplayMicros, replayed_in.as_micros() as u64);
                Ok(Response::Run(result.report))
            }
            Request::Sweep { all } => {
                let jobs =
                    if *all { BenchJob::extended_sweep() } else { BenchJob::paper_sweep() };
                let (results, phases) = self.runner.run_with_cache_timed(&jobs, &self.cache)?;
                Self::span_sweep_phases(span, &phases);
                Ok(Response::Sweep(SweepOutput { all: *all, results }))
            }
            Request::Table(which) => {
                let text = if which.needs_sweep() {
                    let jobs = BenchJob::paper_sweep();
                    let (results, phases) =
                        self.runner.run_with_cache_timed(&jobs, &self.cache)?;
                    Self::span_sweep_phases(span, &phases);
                    match which {
                        TableKind::Table2 => report::render_table2(&results),
                        TableKind::Table3 => report::render_table3(&results),
                        _ => report::render_fig9(&results),
                    }
                } else {
                    report::render_table1()
                };
                Ok(Response::Table { which: *which, text })
            }
            Request::Advise { program } => {
                self.require_program(program)?;
                let advice = advisor::advise_with(program, &self.runner, &self.cache)?;
                Ok(Response::Advise(advice))
            }
            Request::Explore { program, strategy, spec } => {
                // A system-shaped spec (processors/lanes axes, or the
                // throughput-per-ALM objective) promotes the request to
                // the system explorer; any other spec narrows the flat
                // parametric space; no spec is the legacy request,
                // answered byte-identically (parity-pinned).
                if let Some(spec) = spec {
                    if spec.is_system() {
                        let space = spec.system_space(self.dataset_kb(program)?)?;
                        let result =
                            explore::explore_system(program, &space, &self.cache)?;
                        debug_assert!(result.captures <= 1);
                        return Ok(Response::SystemExplore(result));
                    }
                }
                let space = match spec {
                    Some(spec) => spec.design_space(self.dataset_kb(program)?)?,
                    None => self.explore_space(program)?,
                };
                let halving = SuccessiveHalving::default();
                let strategy: &dyn SearchStrategy = match strategy {
                    ExploreStrategy::Exhaustive => &Exhaustive,
                    ExploreStrategy::Halving => &halving,
                };
                let result =
                    explore::explore(program, &space, strategy, &self.runner, &self.cache)?;
                // The subsystem invariant, relaxed by the session cache:
                // at most one functional execution, zero when a prior
                // request already captured this workload.
                debug_assert!(result.captures <= 1);
                Ok(Response::Explore(result))
            }
            Request::Validate { artifacts_dir } => {
                let dir = artifacts_dir.as_deref().unwrap_or("artifacts");
                let (rt, note) = match ArtifactRuntime::new(dir) {
                    Ok(rt) => (Some(rt), None),
                    Err(e) => (
                        None,
                        Some(format!(
                            "PJRT unavailable ({e}); validating against host references only"
                        )),
                    ),
                };
                let checks = validate::validate_all(rt.as_ref());
                Ok(Response::Validate(ValidationOutput { checks, pjrt_note: note }))
            }
            Request::Asm { source, mem } => {
                let program = span.time(Phase::Parse, || asm::assemble(source))?;
                let mut machine = Machine::new(MachineConfig::for_arch(*mem));
                let t0 = Instant::now();
                let report = machine.run_program(&program)?;
                span.add(Phase::Execute, t0.elapsed());
                // A custom program has no library cache key; its coupled
                // run is a functional execution the counter must see.
                self.metrics.inc(Counter::FunctionalExecutions);
                Ok(Response::Asm(report))
            }
            Request::Disasm { program } => {
                let workload = library::program_by_name(program)
                    .ok_or_else(|| ServiceError::UnknownProgram(program.clone()))?;
                Ok(Response::Disasm {
                    program: program.clone(),
                    text: asm::disassemble(workload.program()),
                })
            }
            Request::List => Ok(Response::List(Listing::current())),
            // Snapshot-on-read: the counters the *snapshot* reports do
            // not yet include this request's own bookkeeping (served
            // count, latency), which lands in `handle_in_span` after
            // dispatch returns — so a Stats request never perturbs the
            // numbers it reports. Session scope on the bare engine is
            // the single-session adapter case: the engine registry IS
            // the session registry, only the label differs (a
            // `server::Session` intercepts this variant and snapshots
            // its own registry instead).
            Request::Stats { scope } => {
                let mut snap = self.metrics.snapshot();
                snap.scope = scope.name();
                Ok(Response::Stats(snap))
            }
        }
    }

    /// The parametric design space a spec-less `Explore` request for
    /// `program` will search — the single construction both the
    /// engine's dispatch and clients announcing the space's size use,
    /// so the two can never drift.
    pub fn explore_space(&self, program: &str) -> Result<DesignSpace, ServiceError> {
        Ok(DesignSpace::parametric(self.dataset_kb(program)?))
    }

    /// The workload's dataset size — the anchor every explore space's
    /// default capacity axis scales from.
    fn dataset_kb(&self, program: &str) -> Result<u32, ServiceError> {
        library::program_by_name(program)
            .map(|w| w.dataset_kb())
            .ok_or_else(|| ServiceError::UnknownProgram(program.to_string()))
    }

    fn require_program(&self, name: &str) -> Result<(), ServiceError> {
        // Cheap registry grammar check — no codegen, so a warm cached
        // `run` costs its timing replay and nothing else. Any member of
        // any registered kernel family is runnable, not just the sweep
        // sizes `List` enumerates.
        if !library::is_known_program(name) {
            return Err(ServiceError::UnknownProgram(name.to_string()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::arch::MemoryArchKind;
    use crate::service::request::StatsScope;

    fn run_req(program: &str, mem: MemoryArchKind) -> Request {
        Request::Run { program: program.into(), mem }
    }

    #[test]
    fn run_goes_through_the_cache() {
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let a = engine.handle(&run_req("transpose32", MemoryArchKind::banked(16))).unwrap();
        assert_eq!(engine.functional_executions(), 1);
        assert_eq!(engine.cache().compiled_len(), 0, "a cold one-shot run never compiles");
        // Same program, different memory: replay only, now closed-form.
        let b = engine.handle(&run_req("transpose32", MemoryArchKind::mp_4r1w())).unwrap();
        assert_eq!(engine.functional_executions(), 1, "second run replays the cached trace");
        assert_eq!(engine.cache().compiled_len(), 1, "warm runs charge the compiled trace");
        let (Response::Run(ra), Response::Run(rb)) = (&a, &b) else { panic!("run responses") };
        assert_eq!(ra.program, "transpose32");
        assert_ne!(ra.total_cycles(), 0);
        assert_ne!(ra.arch, rb.arch);
    }

    #[test]
    fn run_matches_coupled_bench_job() {
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let arch = MemoryArchKind::banked_offset(16);
        let Response::Run(report) = engine.handle(&run_req("fft4096r8", arch)).unwrap() else {
            panic!("run response");
        };
        let coupled = BenchJob::new("fft4096r8", arch).run().unwrap();
        assert_eq!(report.stats, coupled.report.stats);
        assert_eq!(report.total_cycles(), coupled.report.total_cycles());
    }

    #[test]
    fn errors_are_typed_and_engine_survives() {
        let engine = SimtEngine::with_runner(SweepRunner::new(1));
        let err = engine.handle(&run_req("nope", MemoryArchKind::banked(16))).unwrap_err();
        assert!(matches!(err, ServiceError::UnknownProgram(_)));
        assert_eq!(err.exit_code(), 2);
        let err = engine
            .handle(&Request::Asm { source: "halt\n".into(), mem: MemoryArchKind::banked(16) })
            .unwrap_err();
        assert!(matches!(err, ServiceError::Asm(_)), "missing .threads is an AsmError");
        // Still serves after errors.
        assert!(engine.handle(&Request::List).is_ok());
    }

    #[test]
    fn asm_counts_as_functional_execution() {
        let engine = SimtEngine::with_runner(SweepRunner::new(1));
        let src = ".threads 16\n    tid r0\n    st [r0], r0\n    halt\n";
        let resp = engine
            .handle(&Request::Asm { source: src.into(), mem: MemoryArchKind::banked(4) })
            .unwrap();
        assert!(matches!(resp, Response::Asm(_)));
        assert_eq!(engine.functional_executions(), 1);
        assert_eq!(engine.cache().len(), 0, "custom programs are not cache-keyed");
    }

    #[test]
    fn table1_needs_no_simulation() {
        let engine = SimtEngine::with_runner(SweepRunner::new(1));
        let resp = engine.handle(&Request::Table(TableKind::Table1)).unwrap();
        assert_eq!(engine.functional_executions(), 0);
        let Response::Table { text, .. } = resp else { panic!("table response") };
        assert!(text.contains("TABLE I"));
    }

    #[test]
    fn stats_snapshot_tracks_cache_and_replay_counters() {
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let req = run_req("transpose32", MemoryArchKind::banked(16));
        engine.handle(&req).unwrap(); // cold: counted miss + capture
        engine.handle(&req).unwrap(); // warm: counted hit, compiled replay
        let stats = Request::Stats { scope: StatsScope::Engine };
        let Response::Stats(snap) = engine.handle(&stats).unwrap() else {
            panic!("stats response");
        };
        assert_eq!(snap.scope, "engine");
        assert!(snap.counter("trace_cache.hits").unwrap() >= 1, "warm run must record a hit");
        assert_eq!(snap.counter("trace_cache.misses"), Some(1));
        assert_eq!(snap.counter("exec.functional_executions"), Some(1));
        assert_eq!(snap.counter("replay.scalar_invocations"), Some(2));
        assert_eq!(snap.counter("compiled.builds"), Some(1));
        assert_eq!(snap.counter("requests.served"), Some(2), "snapshot precedes own bookkeeping");
        assert_eq!(snap.counter("replay.packed_invocations"), Some(0), "runs replay scalar");
        assert_eq!(snap.counter("nonexistent.counter"), None);

        // Batch requests ride the lane-packed kernel: packed counters
        // must advance, and occupancy is bounded by the lane slots.
        engine.handle(&Request::Sweep { all: false }).unwrap();
        let m = engine.metrics();
        assert!(m.get(Counter::ReplayPackedInvocations) >= 1);
        let used = m.get(Counter::ReplayPackedLanesUsed);
        let slots = m.get(Counter::ReplayPackedLaneSlots);
        assert!(used >= 51, "51 sweep cells occupy at least 51 lanes: {used}");
        assert!(slots >= used, "occupancy ≤ 1: {used}/{slots}");
        assert!(m.get(Counter::ReplayWavefrontSegments) >= 1);
    }

    #[test]
    fn every_request_records_one_span() {
        let engine = SimtEngine::with_runner(SweepRunner::new(1));
        assert!(engine.metrics().recording(), "span recording defaults on");
        engine.handle(&run_req("transpose32", MemoryArchKind::banked(16))).unwrap();
        engine.handle(&Request::List).unwrap();
        let spans = engine.metrics().spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].op, "run");
        assert_eq!(spans[1].op, "list");
        for s in &spans {
            assert!(s.phase_sum_nanos() <= s.wall_nanos, "phases are sub-intervals of wall");
        }
        // The run span attributed its functional execution and replay.
        assert!(spans[0].phase_nanos[crate::obs::Phase::Execute as usize] > 0);
    }

    #[test]
    fn system_spec_explore_costs_one_functional_execution() {
        use crate::service::request::ExploreSpec;
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let resp = engine
            .handle(&Request::Explore {
                program: "transpose32".into(),
                strategy: ExploreStrategy::Exhaustive,
                spec: Some(ExploreSpec {
                    processors: Some(vec![1, 2, 4]),
                    lanes: Some(vec![16, 32, 64]),
                    ..Default::default()
                }),
            })
            .unwrap();
        // The whole {1,2,4}-core × {16,32,64}-lane × 30-arch × 3-cap
        // space scores from ONE functional execution of the workload.
        assert_eq!(engine.functional_executions(), 1);
        let Response::SystemExplore(result) = resp else { panic!("system response") };
        assert_eq!(result.captures, 1);
        assert_eq!(result.points_total, 3 * 3 * 30 * 3);
        assert_eq!(result.points_scored, result.points_total);
        assert!(!result.front.is_empty());
    }

    #[test]
    fn flat_spec_narrows_the_flat_explorer() {
        use crate::service::request::ExploreSpec;
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let resp = engine
            .handle(&Request::Explore {
                program: "transpose32".into(),
                strategy: ExploreStrategy::Exhaustive,
                spec: Some(ExploreSpec {
                    banks: Some(vec![4, 16]),
                    mappings: Some(vec!["offset".into()]),
                    multiport: Some(vec![]),
                    capacities_kb: Some(vec![8]),
                    ..Default::default()
                }),
            })
            .unwrap();
        let Response::Explore(result) = resp else { panic!("flat explore response") };
        assert_eq!(result.points_total, 2);
        assert_eq!(engine.functional_executions(), 1);
    }

    #[test]
    fn advise_and_explore_share_the_session_cache() {
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        engine.handle(&Request::Advise { program: "transpose32".into() }).unwrap();
        assert_eq!(engine.functional_executions(), 1);
        let resp = engine
            .handle(&Request::Explore {
                program: "transpose32".into(),
                strategy: ExploreStrategy::Halving,
                spec: None,
            })
            .unwrap();
        assert_eq!(engine.functional_executions(), 1, "explore reuses the advisor's trace");
        let Response::Explore(result) = resp else { panic!("explore response") };
        assert_eq!(result.captures, 0, "session cache was already warm");
        assert!(!result.front.is_empty());
    }
}
