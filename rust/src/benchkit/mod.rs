//! A small benchmarking harness (criterion is unavailable in this offline
//! environment, so the crate carries its own).
//!
//! Measures wall-clock over warmup + timed iterations and reports
//! mean / median / MAD / min; `cargo bench` binaries (`benches/*.rs`,
//! `harness = false`) use [`Bencher`] and print paper-style tables next to
//! the timing rows.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    /// Per-iteration durations, sorted.
    pub iters: Vec<Duration>,
}

impl Sample {
    pub fn min(&self) -> Duration {
        self.iters[0]
    }

    pub fn median(&self) -> Duration {
        self.iters[self.iters.len() / 2]
    }

    pub fn mean(&self) -> Duration {
        self.iters.iter().sum::<Duration>() / self.iters.len() as u32
    }

    /// Median absolute deviation — robust spread.
    pub fn mad(&self) -> Duration {
        let med = self.median();
        let mut devs: Vec<Duration> = self
            .iters
            .iter()
            .map(|&d| if d > med { d - med } else { med - d })
            .collect();
        devs.sort_unstable();
        devs[devs.len() / 2]
    }

    /// One-line report: `name  median ± mad (n=..)`.
    pub fn line(&self) -> String {
        format!(
            "{:40} {:>12} ± {:<10} (n={})",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mad()),
            self.iters.len()
        )
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// The harness: run closures with warmup and collect samples.
pub struct Bencher {
    warmup: u32,
    iters: u32,
    samples: Vec<Sample>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(2, 10)
    }
}

impl Bencher {
    pub fn new(warmup: u32, iters: u32) -> Self {
        assert!(iters > 0);
        Self { warmup, iters, samples: Vec::new() }
    }

    /// Benchmark `f`, which must return something observable (guards
    /// against the optimizer deleting the work).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: impl Into<String>, mut f: F) -> &Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut iters = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            iters.push(t0.elapsed());
        }
        iters.sort_unstable();
        self.samples.push(Sample { name: name.into(), iters });
        self.samples.last().unwrap()
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Print every sample line.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for s in &self.samples {
            out.push_str(&s.line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_iterations() {
        let mut b = Bencher::new(1, 5);
        b.bench("noop", || 42);
        assert_eq!(b.samples().len(), 1);
        assert_eq!(b.samples()[0].iters.len(), 5);
    }

    #[test]
    fn stats_are_ordered() {
        let mut b = Bencher::new(0, 9);
        b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        let s = &b.samples()[0];
        assert!(s.min() <= s.median());
        assert!(s.median() <= *s.iters.last().unwrap());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
    }

    #[test]
    fn report_one_line_per_sample() {
        let mut b = Bencher::new(0, 3);
        b.bench("a", || 1);
        b.bench("b", || 2);
        assert_eq!(b.report().lines().count(), 2);
    }
}
