//! Concurrent multi-client serving (DESIGN.md §Server).
//!
//! The paper's banked memories exist so many lanes can access shared
//! state concurrently; this module applies the same shape to serving
//! the simulator itself. Four layers, bottom to top:
//!
//! - [`store`] — the sharded, single-flight [`ShardedStore`] backing
//!   [`TraceCache`](crate::coordinator::job::TraceCache): warm reads
//!   are shard-read-lock-only `Arc` clones (traces are immutable after
//!   capture, like banks after a write drains), cold captures run
//!   exactly once per key however many sessions race for them.
//! - [`session`] — [`Session`]: one client's view of a shared
//!   `Arc<SimtEngine>`. All sessions share the trace store and worker
//!   pool; each keeps isolated bookkeeping (request counters, latency
//!   histogram, span ring) queryable via `{"op":"stats",
//!   "scope":"session"}`.
//! - [`dispatch`] — [`Dispatcher`]: a backpressure bound on in-flight
//!   wire lines. Past the configured depth, requests are rejected
//!   immediately with [`ServiceError::Overloaded`]
//!   (exit code 3, retryable) instead of queuing unboundedly.
//! - [`listen`] — [`SocketServer`]: `soft-simt serve --listen ADDR`
//!   accepting TCP or Unix-socket clients (`std::net` only), one reader
//!   thread per client feeding the shared dispatcher. The stdin/stdout
//!   loop is a thin single-session adapter over the same
//!   [`crate::service::wire::serve_with`] code path.
//!
//! [`ServiceError::Overloaded`]: crate::service::ServiceError::Overloaded

pub mod dispatch;
pub mod listen;
pub mod session;
pub mod store;

pub use dispatch::{Dispatcher, Permit};
pub use listen::{ListenAddr, SocketServer};
pub use session::Session;
pub use store::{ShardedStore, SHARDS};
