//! The sharded, single-flight trace store backing [`TraceCache`].
//!
//! The paper's banked memories turn one monolithic port into N banks so
//! 16 lanes can load concurrently; this store does the same to the trace
//! cache so N client sessions can *read* concurrently. Keys hash onto
//! [`SHARDS`] independent `RwLock<HashMap>` shards, and every entry is
//! an `Arc<OnceLock<T>>` **cell**:
//!
//! - **Warm reads** take only a shard *read* lock (shared, so readers
//!   never serialize behind each other) and clone the `Arc` out — the
//!   value itself (a captured [`MemTrace`] or a compiled trace) is
//!   immutable after initialization, exactly like a trace bank after
//!   capture. A warm read never acquires a write lock; the serve bench
//!   asserts this via [`Counter::StoreShardWriteLocks`].
//! - **Cold inserts** take the shard write lock just long enough to
//!   install an *empty* cell, then initialize it **outside** any shard
//!   lock via `OnceLock::get_or_init` — so an expensive functional
//!   execution never blocks the shard, and concurrent requesters of the
//!   same key block only on each other (single-flight: the work runs
//!   exactly once, everyone shares the one result).
//!
//! Contention telemetry rides the engine's [`MetricsRegistry`]:
//! write-lock acquisitions count [`Counter::StoreShardWriteLocks`], and
//! a read path that finds its shard briefly write-held counts
//! [`Counter::StoreShardReadContention`] before falling back to a
//! blocking read.
//!
//! [`TraceCache`]: crate::coordinator::job::TraceCache
//! [`MemTrace`]: crate::sim::exec::MemTrace

use crate::coordinator::job::TraceKey;
use crate::obs::{Counter, MetricsRegistry};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, OnceLock, RwLock, TryLockError};

/// Shard count — a power of two so the hash folds with a mask. 16
/// mirrors the paper's widest banking (16 banks for 16 lanes): enough
/// that concurrent sessions rarely collide, small enough that a full
/// scan ([`ShardedStore::count_initialized`]) stays trivial.
pub const SHARDS: usize = 16;

type Shard<T> = RwLock<HashMap<TraceKey, Arc<OnceLock<T>>>>;

/// A sharded map from [`TraceKey`] to a single-flight cell of `T`.
#[derive(Debug)]
pub struct ShardedStore<T> {
    shards: Vec<Shard<T>>,
}

impl<T> Default for ShardedStore<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ShardedStore<T> {
    pub fn new() -> Self {
        Self { shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect() }
    }

    fn shard(&self, key: &TraceKey) -> &Shard<T> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) & (SHARDS - 1)]
    }

    /// Read-lock a shard, preferring the non-blocking path; a busy
    /// shard (write-held during a cold insert) counts one contention
    /// event and falls back to the blocking read.
    fn read_shard<'a>(
        shard: &'a Shard<T>,
        metrics: Option<&MetricsRegistry>,
    ) -> std::sync::RwLockReadGuard<'a, HashMap<TraceKey, Arc<OnceLock<T>>>> {
        match shard.try_read() {
            Ok(guard) => guard,
            Err(TryLockError::WouldBlock) => {
                if let Some(m) = metrics {
                    m.inc(Counter::StoreShardReadContention);
                }
                shard.read().unwrap()
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// The initialized value under `key`, if any — the warm path. Takes
    /// only a shard read lock; an installed-but-uninitialized cell (a
    /// capture in flight on another thread) reads as absent, so callers
    /// that must share in-flight work go through [`Self::cell`].
    pub fn get(&self, key: &TraceKey, metrics: Option<&MetricsRegistry>) -> Option<T>
    where
        T: Clone,
    {
        let shard = self.shard(key);
        Self::read_shard(shard, metrics).get(key).and_then(|cell| cell.get().cloned())
    }

    /// The single-flight cell under `key`, installing an empty one if
    /// absent. Warm calls resolve on the read lock alone; only the call
    /// that actually installs the cell takes (and counts) the shard
    /// write lock. Initialize the returned cell with
    /// `OnceLock::get_or_init` — outside any shard lock.
    pub fn cell(&self, key: &TraceKey, metrics: Option<&MetricsRegistry>) -> Arc<OnceLock<T>> {
        let shard = self.shard(key);
        if let Some(cell) = Self::read_shard(shard, metrics).get(key) {
            return Arc::clone(cell);
        }
        if let Some(m) = metrics {
            m.inc(Counter::StoreShardWriteLocks);
        }
        let mut guard = match shard.write() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        Arc::clone(guard.entry(key.clone()).or_default())
    }

    /// Number of initialized entries satisfying `pred` (read locks
    /// only; an introspection path, not a hot one).
    pub fn count_initialized(&self, pred: impl Fn(&T) -> bool) -> usize {
        self.shards
            .iter()
            .map(|s| {
                Self::read_shard(s, None)
                    .values()
                    .filter(|cell| cell.get().is_some_and(&pred))
                    .count()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn key(name: &str) -> TraceKey {
        (name.to_string(), 0x5EED)
    }

    #[test]
    fn get_sees_only_initialized_cells() {
        let store: ShardedStore<u64> = ShardedStore::new();
        assert_eq!(store.get(&key("a"), None), None);
        let cell = store.cell(&key("a"), None);
        assert_eq!(store.get(&key("a"), None), None, "empty cell reads as absent");
        cell.get_or_init(|| 7);
        assert_eq!(store.get(&key("a"), None), Some(7));
        assert_eq!(store.count_initialized(|_| true), 1);
    }

    #[test]
    fn concurrent_initializers_run_exactly_once() {
        let store: Arc<ShardedStore<u64>> = Arc::new(ShardedStore::new());
        let runs = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = *store.cell(&key("shared"), None).get_or_init(|| {
                        runs.fetch_add(1, Ordering::Relaxed);
                        42
                    });
                    assert_eq!(v, 42);
                });
            }
        });
        assert_eq!(runs.load(Ordering::Relaxed), 1, "single-flight init");
        assert_eq!(store.count_initialized(|_| true), 1);
    }

    #[test]
    fn warm_cells_take_no_write_lock() {
        let metrics = MetricsRegistry::new();
        let store: ShardedStore<u64> = ShardedStore::new();
        store.cell(&key("a"), Some(&metrics)).get_or_init(|| 1);
        assert_eq!(metrics.get(Counter::StoreShardWriteLocks), 1);
        for _ in 0..10 {
            assert_eq!(store.get(&key("a"), Some(&metrics)), Some(1));
            store.cell(&key("a"), Some(&metrics));
        }
        assert_eq!(metrics.get(Counter::StoreShardWriteLocks), 1, "warm paths stay read-only");
    }

    #[test]
    fn distinct_keys_spread_over_shards() {
        let store: ShardedStore<u64> = ShardedStore::new();
        for i in 0..64 {
            store.cell(&key(&format!("k{i}")), None).get_or_init(|| i);
        }
        assert_eq!(store.count_initialized(|_| true), 64);
        let populated =
            store.shards.iter().filter(|s| !s.read().unwrap().is_empty()).count();
        assert!(populated > 1, "64 keys must not collapse onto one shard");
    }
}
