//! The socket front-end: `soft-simt serve --listen ADDR`.
//!
//! `std::net`/`std::os::unix::net` only (the crate is dependency-free):
//! a blocking accept loop, one reader thread per client. Each accepted
//! connection gets its own [`Session`] over the shared engine and runs
//! the *same* [`wire::serve_with`] transport the stdin adapter uses —
//! one code path, so socket clients and the stdin loop are
//! byte-identical per line (pinned by the CI socket-smoke diff). All
//! clients share one [`Dispatcher`], so the backpressure bound is
//! server-wide, not per-connection.
//!
//! Address grammar ([`ListenAddr::parse`]):
//!
//! - `HOST:PORT` (e.g. `127.0.0.1:7878`, `0.0.0.0:0`) — TCP;
//! - `unix:PATH` or any string containing `/` — a Unix domain socket
//!   (rejected at parse time on non-Unix platforms).

use super::dispatch::Dispatcher;
use super::session::Session;
use crate::service::wire;
use crate::service::{ServiceError, SimtEngine};
use std::io::BufReader;
use std::net::TcpListener;
use std::sync::Arc;

#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::PathBuf;

/// A parsed `--listen` address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    /// `HOST:PORT` for [`TcpListener::bind`].
    Tcp(String),
    /// Filesystem path of a Unix domain socket.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse the `--listen` grammar (see the module docs). Usage-class
    /// errors (`BadRequest`, exit code 2).
    pub fn parse(s: &str) -> Result<Self, ServiceError> {
        let unix_path = match s.strip_prefix("unix:") {
            Some(path) => Some(path),
            None if s.contains('/') => Some(s),
            None => None,
        };
        match unix_path {
            None => Ok(ListenAddr::Tcp(s.to_string())),
            #[cfg(unix)]
            Some(path) if !path.is_empty() => Ok(ListenAddr::Unix(PathBuf::from(path))),
            #[cfg(unix)]
            Some(_) => {
                Err(ServiceError::BadRequest("empty unix socket path in --listen".into()))
            }
            #[cfg(not(unix))]
            Some(_) => Err(ServiceError::BadRequest(
                "unix socket addresses are not supported on this platform".into(),
            )),
        }
    }
}

#[derive(Debug)]
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

/// The accept loop behind `serve --listen`. See the module docs.
#[derive(Debug)]
pub struct SocketServer {
    engine: Arc<SimtEngine>,
    dispatcher: Arc<Dispatcher>,
    listener: Listener,
}

impl SocketServer {
    /// Bind the address and set up the shared dispatcher (`depth` bounds
    /// in-flight wire lines across *all* clients). A stale Unix socket
    /// file from a previous run is removed first.
    pub fn bind(
        engine: Arc<SimtEngine>,
        addr: &ListenAddr,
        depth: usize,
    ) -> std::io::Result<Self> {
        let listener = match addr {
            ListenAddr::Tcp(hostport) => Listener::Tcp(TcpListener::bind(hostport)?),
            #[cfg(unix)]
            ListenAddr::Unix(path) => {
                match std::fs::remove_file(path) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e),
                }
                Listener::Unix(UnixListener::bind(path)?, path.clone())
            }
        };
        let dispatcher =
            Arc::new(Dispatcher::new(depth, Arc::clone(engine.metrics())));
        Ok(Self { engine, dispatcher, listener })
    }

    /// The bound address — for TCP this is the *resolved* one (port 0
    /// becomes the kernel's pick), which is what tests and the startup
    /// banner print.
    pub fn local_addr(&self) -> Option<String> {
        match &self.listener {
            Listener::Tcp(l) => l.local_addr().ok().map(|a| a.to_string()),
            #[cfg(unix)]
            Listener::Unix(_, path) => Some(path.display().to_string()),
        }
    }

    /// The shared backpressure bound.
    pub fn dispatcher(&self) -> &Arc<Dispatcher> {
        &self.dispatcher
    }

    /// Accept clients forever (until the listener errors), one session
    /// thread per connection. A single client's I/O failure closes that
    /// client only; the loop keeps accepting.
    pub fn run(&self) -> std::io::Result<()> {
        match &self.listener {
            Listener::Tcp(l) => {
                for stream in l.incoming() {
                    let stream = stream?;
                    let _ = stream.set_nodelay(true);
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("serve: dropping client (clone failed: {e})");
                            continue;
                        }
                    };
                    self.spawn_client(reader, stream);
                }
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                for stream in l.incoming() {
                    let stream = stream?;
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("serve: dropping client (clone failed: {e})");
                            continue;
                        }
                    };
                    self.spawn_client(reader, stream);
                }
            }
        }
        Ok(())
    }

    /// One client: a fresh [`Session`] over the shared engine, served by
    /// the common wire transport under the shared dispatcher.
    fn spawn_client<S>(&self, reader: S, writer: S)
    where
        S: std::io::Read + std::io::Write + Send + 'static,
    {
        let engine = Arc::clone(&self.engine);
        let dispatcher = Arc::clone(&self.dispatcher);
        std::thread::spawn(move || {
            let session = Session::new(engine);
            let name = format!("session {}", session.id());
            if let Err(e) =
                wire::serve_with(&session, Some(&dispatcher), BufReader::new(reader), writer)
            {
                eprintln!("serve: {name} closed: {e}");
            }
        });
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = &self.listener {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tcp_and_unix_addresses() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7878").unwrap(),
            ListenAddr::Tcp("127.0.0.1:7878".into())
        );
        assert_eq!(ListenAddr::parse("0.0.0.0:0").unwrap(), ListenAddr::Tcp("0.0.0.0:0".into()));
        #[cfg(unix)]
        {
            assert_eq!(
                ListenAddr::parse("unix:/tmp/soft-simt.sock").unwrap(),
                ListenAddr::Unix(PathBuf::from("/tmp/soft-simt.sock"))
            );
            assert_eq!(
                ListenAddr::parse("/tmp/soft-simt.sock").unwrap(),
                ListenAddr::Unix(PathBuf::from("/tmp/soft-simt.sock"))
            );
            assert!(ListenAddr::parse("unix:").is_err(), "empty path rejected");
        }
    }

    #[test]
    fn tcp_bind_resolves_port_zero() {
        let engine = Arc::new(SimtEngine::with_runner(
            crate::coordinator::runner::SweepRunner::new(1),
        ));
        let addr = ListenAddr::parse("127.0.0.1:0").unwrap();
        let server = SocketServer::bind(engine, &addr, 4).unwrap();
        let local = server.local_addr().unwrap();
        assert!(local.starts_with("127.0.0.1:"), "{local}");
        assert!(!local.ends_with(":0"), "port resolved: {local}");
        assert_eq!(server.dispatcher().depth(), 4);
    }
}
