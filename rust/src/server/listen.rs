//! The socket front-end: `soft-simt serve --listen ADDR`.
//!
//! `std::net`/`std::os::unix::net` only (the crate is dependency-free):
//! a blocking accept loop, one reader thread per client. Each accepted
//! connection gets its own [`Session`] over the shared engine and runs
//! the *same* [`wire::serve_with`] transport the stdin adapter uses —
//! one code path, so socket clients and the stdin loop are
//! byte-identical per line (pinned by the CI socket-smoke diff). All
//! clients share one [`Dispatcher`], so the backpressure bound is
//! server-wide, not per-connection.
//!
//! Address grammar ([`ListenAddr::parse`]):
//!
//! - `HOST:PORT` (e.g. `127.0.0.1:7878`, `0.0.0.0:0`) — TCP;
//! - `unix:PATH` or any string containing `/` — a Unix domain socket
//!   (rejected at parse time on non-Unix platforms).

use super::dispatch::Dispatcher;
use super::session::Session;
use crate::service::wire;
use crate::service::{ServiceError, SimtEngine};
use std::io::BufReader;
use std::net::TcpListener;
use std::sync::Arc;

#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::PathBuf;

/// A parsed `--listen` address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    /// `HOST:PORT` for [`TcpListener::bind`].
    Tcp(String),
    /// Filesystem path of a Unix domain socket.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse the `--listen` grammar (see the module docs). Usage-class
    /// errors (`BadRequest`, exit code 2).
    pub fn parse(s: &str) -> Result<Self, ServiceError> {
        let unix_path = match s.strip_prefix("unix:") {
            Some(path) => Some(path),
            None if s.contains('/') => Some(s),
            None => None,
        };
        match unix_path {
            None => Ok(ListenAddr::Tcp(s.to_string())),
            #[cfg(unix)]
            Some(path) if !path.is_empty() => Ok(ListenAddr::Unix(PathBuf::from(path))),
            #[cfg(unix)]
            Some(_) => {
                Err(ServiceError::BadRequest("empty unix socket path in --listen".into()))
            }
            #[cfg(not(unix))]
            Some(_) => Err(ServiceError::BadRequest(
                "unix socket addresses are not supported on this platform".into(),
            )),
        }
    }
}

#[derive(Debug)]
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix {
        listener: UnixListener,
        path: PathBuf,
        /// `(dev, ino)` of the socket file *this instance* created —
        /// `Drop` unlinks the path only while it still names that file,
        /// so a server that replaced us keeps its socket.
        bound_id: Option<(u64, u64)>,
    },
}

/// `(dev, ino)` identity of a path, if it can be stat'ed.
#[cfg(unix)]
fn file_id(path: &std::path::Path) -> Option<(u64, u64)> {
    use std::os::unix::fs::MetadataExt;
    std::fs::symlink_metadata(path).ok().map(|m| (m.dev(), m.ino()))
}

/// Remove a *stale* Unix socket file at `path`, if any: an existing
/// socket nobody answers on (a previous server died without cleanup).
/// A socket with a live listener is left in place — the caller's bind
/// then fails with `AddrInUse` instead of hijacking the running server's
/// clients. Non-socket files are never touched (bind fails naturally).
#[cfg(unix)]
fn remove_stale_socket(path: &std::path::Path) -> std::io::Result<()> {
    use std::os::unix::fs::FileTypeExt;
    let meta = match std::fs::symlink_metadata(path) {
        Ok(m) => m,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e),
    };
    if !meta.file_type().is_socket() {
        return Ok(()); // not ours to delete; UnixListener::bind will error
    }
    match std::os::unix::net::UnixStream::connect(path) {
        // Someone is serving on it right now — refuse to unlink.
        Ok(_) => Err(std::io::Error::new(
            std::io::ErrorKind::AddrInUse,
            format!("{} already has a live server", path.display()),
        )),
        // Connect-probe failed: the socket is an orphan; reclaim it.
        Err(_) => match std::fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e),
        },
    }
}

/// The accept loop behind `serve --listen`. See the module docs.
#[derive(Debug)]
pub struct SocketServer {
    engine: Arc<SimtEngine>,
    dispatcher: Arc<Dispatcher>,
    listener: Listener,
}

impl SocketServer {
    /// Bind the address and set up the shared dispatcher (`depth` bounds
    /// in-flight wire lines across *all* clients). A *stale* Unix socket
    /// file from a previous run (nobody answers a connect probe) is
    /// removed first; a live one refuses the bind with `AddrInUse`, and
    /// a non-socket file at the path is never deleted.
    pub fn bind(
        engine: Arc<SimtEngine>,
        addr: &ListenAddr,
        depth: usize,
    ) -> std::io::Result<Self> {
        let listener = match addr {
            ListenAddr::Tcp(hostport) => Listener::Tcp(TcpListener::bind(hostport)?),
            #[cfg(unix)]
            ListenAddr::Unix(path) => {
                remove_stale_socket(path)?;
                let listener = UnixListener::bind(path)?;
                Listener::Unix { listener, path: path.clone(), bound_id: file_id(path) }
            }
        };
        let dispatcher =
            Arc::new(Dispatcher::new(depth, Arc::clone(engine.metrics())));
        Ok(Self { engine, dispatcher, listener })
    }

    /// The bound address — for TCP this is the *resolved* one (port 0
    /// becomes the kernel's pick), which is what tests and the startup
    /// banner print.
    pub fn local_addr(&self) -> Option<String> {
        match &self.listener {
            Listener::Tcp(l) => l.local_addr().ok().map(|a| a.to_string()),
            #[cfg(unix)]
            Listener::Unix { path, .. } => Some(path.display().to_string()),
        }
    }

    /// The shared backpressure bound.
    pub fn dispatcher(&self) -> &Arc<Dispatcher> {
        &self.dispatcher
    }

    /// Accept clients forever (until the listener errors), one session
    /// thread per connection. A single client's I/O failure closes that
    /// client only; the loop keeps accepting.
    pub fn run(&self) -> std::io::Result<()> {
        match &self.listener {
            Listener::Tcp(l) => {
                for stream in l.incoming() {
                    let stream = stream?;
                    let _ = stream.set_nodelay(true);
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("serve: dropping client (clone failed: {e})");
                            continue;
                        }
                    };
                    self.spawn_client(reader, stream);
                }
            }
            #[cfg(unix)]
            Listener::Unix { listener: l, .. } => {
                for stream in l.incoming() {
                    let stream = stream?;
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("serve: dropping client (clone failed: {e})");
                            continue;
                        }
                    };
                    self.spawn_client(reader, stream);
                }
            }
        }
        Ok(())
    }

    /// One client: a fresh [`Session`] over the shared engine, served by
    /// the common wire transport under the shared dispatcher.
    fn spawn_client<S>(&self, reader: S, writer: S)
    where
        S: std::io::Read + std::io::Write + Send + 'static,
    {
        let engine = Arc::clone(&self.engine);
        let dispatcher = Arc::clone(&self.dispatcher);
        std::thread::spawn(move || {
            let session = Session::new(engine);
            let name = format!("session {}", session.id());
            if let Err(e) =
                wire::serve_with(&session, Some(&dispatcher), BufReader::new(reader), writer)
            {
                eprintln!("serve: {name} closed: {e}");
            }
        });
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        // Unlink only the socket file this instance created: if the path
        // has since been replaced (another server reclaimed it, or the
        // user put something else there), its `(dev, ino)` no longer
        // matches and the file is left alone.
        #[cfg(unix)]
        if let Listener::Unix { path, bound_id, .. } = &self.listener {
            if bound_id.is_some() && file_id(path) == *bound_id {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tcp_and_unix_addresses() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7878").unwrap(),
            ListenAddr::Tcp("127.0.0.1:7878".into())
        );
        assert_eq!(ListenAddr::parse("0.0.0.0:0").unwrap(), ListenAddr::Tcp("0.0.0.0:0".into()));
        #[cfg(unix)]
        {
            assert_eq!(
                ListenAddr::parse("unix:/tmp/soft-simt.sock").unwrap(),
                ListenAddr::Unix(PathBuf::from("/tmp/soft-simt.sock"))
            );
            assert_eq!(
                ListenAddr::parse("/tmp/soft-simt.sock").unwrap(),
                ListenAddr::Unix(PathBuf::from("/tmp/soft-simt.sock"))
            );
            assert!(ListenAddr::parse("unix:").is_err(), "empty path rejected");
        }
    }

    #[test]
    fn tcp_bind_resolves_port_zero() {
        let engine = Arc::new(SimtEngine::with_runner(
            crate::coordinator::runner::SweepRunner::new(1),
        ));
        let addr = ListenAddr::parse("127.0.0.1:0").unwrap();
        let server = SocketServer::bind(engine, &addr, 4).unwrap();
        let local = server.local_addr().unwrap();
        assert!(local.starts_with("127.0.0.1:"), "{local}");
        assert!(!local.ends_with(":0"), "port resolved: {local}");
        assert_eq!(server.dispatcher().depth(), 4);
    }

    #[cfg(unix)]
    fn test_engine() -> Arc<SimtEngine> {
        Arc::new(SimtEngine::with_runner(crate::coordinator::runner::SweepRunner::new(1)))
    }

    #[cfg(unix)]
    fn temp_sock(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("soft-simt-{tag}-{}.sock", std::process::id()))
    }

    /// A live server's socket must not be hijacked: the second bind on
    /// the same path fails with `AddrInUse` and the first server's file
    /// survives. A *stale* socket file (its server gone without cleanup)
    /// is reclaimed.
    #[cfg(unix)]
    #[test]
    fn bind_reclaims_stale_sockets_but_refuses_live_ones() {
        let path = temp_sock("stale-live");
        let addr = ListenAddr::parse(&format!("unix:{}", path.display())).unwrap();

        let live = SocketServer::bind(test_engine(), &addr, 2).unwrap();
        let err = SocketServer::bind(test_engine(), &addr, 2)
            .expect_err("second bind on a live socket must fail");
        assert_eq!(err.kind(), std::io::ErrorKind::AddrInUse, "{err}");
        assert!(path.exists(), "the live server's socket survives the refused bind");
        drop(live);
        assert!(!path.exists(), "drop cleans up the owner's socket");

        // A stale socket: bound directly (no SocketServer cleanup), its
        // listener dropped — the file remains, nobody answers.
        drop(UnixListener::bind(&path).unwrap());
        assert!(path.exists(), "orphaned socket file left behind");
        let server = SocketServer::bind(test_engine(), &addr, 2)
            .expect("stale socket is reclaimed");
        assert_eq!(server.local_addr().unwrap(), path.display().to_string());
        drop(server);
        assert!(!path.exists());
    }

    /// Drop unlinks only the file this instance bound: once the path
    /// names something else (here: a successor's socket), the dying
    /// server leaves it alone.
    #[cfg(unix)]
    #[test]
    fn drop_leaves_a_replaced_socket_path_alone() {
        let path = temp_sock("replaced");
        let addr = ListenAddr::parse(&format!("unix:{}", path.display())).unwrap();

        let old = SocketServer::bind(test_engine(), &addr, 2).unwrap();
        // Simulate the old server dying *after* a successor reclaimed the
        // path: remove its file, bind a new socket at the same path.
        std::fs::remove_file(&path).unwrap();
        let _successor = UnixListener::bind(&path).unwrap();
        drop(old);
        assert!(path.exists(), "the successor's socket must survive the old drop");
        let _ = std::fs::remove_file(&path);
    }

    /// A non-socket file at the path is never deleted — bind fails, the
    /// file survives.
    #[cfg(unix)]
    #[test]
    fn bind_never_deletes_a_non_socket_file() {
        let path = temp_sock("regular-file");
        std::fs::write(&path, b"not a socket").unwrap();
        let addr = ListenAddr::parse(&format!("unix:{}", path.display())).unwrap();
        assert!(SocketServer::bind(test_engine(), &addr, 2).is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"not a socket");
        let _ = std::fs::remove_file(&path);
    }
}
