//! Backpressure: a bound on concurrently admitted wire lines.
//!
//! The paper's write buffer is the same shape in hardware: a fixed-depth
//! queue that absorbs bursts and *stalls the issuer* when full, rather
//! than growing without bound. Here the policy is reject-not-stall —
//! a client pushed past the bound gets an immediate
//! [`ServiceError::Overloaded`] line (exit code 3, retryable) instead of
//! unbounded queueing, so saturated servers degrade by shedding load,
//! not by stretching every client's latency.
//!
//! The [`Dispatcher`] is a counter, not a queue: admission is one
//! compare-and-swap, rejection touches no lock, and the admitted work
//! itself still runs on the engine's [`SweepRunner`] pool. One
//! dispatcher is shared by every client of a
//! [`SocketServer`](crate::server::SocketServer).

use crate::obs::{Counter, MetricsRegistry};
use crate::service::ServiceError;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Bounds in-flight wire lines across every session of one server.
#[derive(Debug)]
pub struct Dispatcher {
    /// Maximum concurrently admitted lines.
    depth: usize,
    in_flight: AtomicUsize,
    /// Engine-global registry (rejections are a server-wide signal, not
    /// a per-session one).
    metrics: Arc<MetricsRegistry>,
}

impl Dispatcher {
    pub fn new(depth: usize, metrics: Arc<MetricsRegistry>) -> Self {
        Self { depth, in_flight: AtomicUsize::new(0), metrics }
    }

    /// The configured bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Lines currently admitted (racy by nature; exact only to an
    /// observer holding all permits).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Admit one wire line, or reject it with
    /// [`ServiceError::Overloaded`] (counted
    /// `server.overload_rejections`). The permit releases its slot on
    /// drop — hold it across the line's whole handle+render+write.
    pub fn admit(&self) -> Result<Permit<'_>, ServiceError> {
        let mut current = self.in_flight.load(Ordering::Relaxed);
        loop {
            if current >= self.depth {
                self.metrics.inc(Counter::OverloadRejections);
                return Err(ServiceError::Overloaded {
                    in_flight: current,
                    depth: self.depth,
                });
            }
            match self.in_flight.compare_exchange_weak(
                current,
                current + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(Permit { dispatcher: self }),
                Err(seen) => current = seen,
            }
        }
    }
}

/// One admitted wire line's slot; releases on drop.
#[derive(Debug)]
pub struct Permit<'a> {
    dispatcher: &'a Dispatcher,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.dispatcher.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_depth_then_rejects() {
        let metrics = Arc::new(MetricsRegistry::new());
        let d = Dispatcher::new(2, Arc::clone(&metrics));
        let a = d.admit().unwrap();
        let _b = d.admit().unwrap();
        assert_eq!(d.in_flight(), 2);
        let err = d.admit().unwrap_err();
        assert!(matches!(err, ServiceError::Overloaded { in_flight: 2, depth: 2 }));
        assert_eq!(err.exit_code(), 3);
        assert_eq!(metrics.get(Counter::OverloadRejections), 1);
        // A released slot is immediately reusable.
        drop(a);
        assert_eq!(d.in_flight(), 1);
        let _c = d.admit().unwrap();
    }

    #[test]
    fn depth_zero_rejects_everything() {
        let metrics = Arc::new(MetricsRegistry::new());
        let d = Dispatcher::new(0, metrics);
        assert!(d.admit().is_err());
        assert_eq!(d.in_flight(), 0);
    }

    #[test]
    fn concurrent_admissions_never_exceed_depth() {
        let metrics = Arc::new(MetricsRegistry::new());
        let d = Dispatcher::new(4, metrics);
        let peak = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..16 {
                s.spawn(|| {
                    for _ in 0..100 {
                        if let Ok(_permit) = d.admit() {
                            let now = d.in_flight();
                            peak.fetch_max(now, Ordering::Relaxed);
                            assert!(now <= 4, "depth exceeded: {now}");
                        }
                    }
                });
            }
        });
        assert_eq!(d.in_flight(), 0, "every permit released");
        assert!(peak.load(Ordering::Relaxed) >= 1);
    }
}
