//! One client's view of a shared engine.
//!
//! Every [`Session`] wraps the same `Arc<SimtEngine>`: requests
//! delegate to the engine, so all clients share the trace store, the
//! compiled-trace memo and the worker pool — N clients running one
//! workload still pay one functional execution. What a session does
//! *not* share is bookkeeping: it keeps its own
//! [`MetricsRegistry`] (request counters, latency histogram, span
//! ring), mirrored alongside the engine-global one, so
//! `{"op":"stats","scope":"session"}` answers *this client's* traffic
//! while `{"op":"stats"}` keeps answering the engine-wide view. A
//! client's errors land on its own `requests.errors` (and the global
//! registry), never on a neighbour's — the error-isolation guarantee
//! `rust/tests/server.rs` pins.
//!
//! The stdin/stdout `soft-simt serve` loop is exactly one of these over
//! the CLI's engine, so single-client behavior is byte-identical to the
//! pre-session transport (pinned by the serve parity tests).

use crate::obs::{Counter, Hist, MetricsRegistry, Span};
use crate::service::request::StatsScope;
use crate::service::wire::WireHandler;
use crate::service::{Request, Response, ServiceError, SimtEngine};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Session ids are process-global so log lines from different listeners
/// never collide.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// One client of a shared [`SimtEngine`]. See the module docs.
#[derive(Debug)]
pub struct Session {
    id: u64,
    engine: Arc<SimtEngine>,
    /// This client's isolated bookkeeping. Same registry type as the
    /// engine's, so the wire snapshot shape is identical — only the
    /// reported `scope` differs.
    metrics: Arc<MetricsRegistry>,
}

impl Session {
    /// Open a session over the shared engine (counted engine-wide as
    /// `server.sessions_opened`).
    pub fn new(engine: Arc<SimtEngine>) -> Self {
        engine.metrics().inc(Counter::SessionsOpened);
        Self {
            id: NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed),
            engine,
            metrics: Arc::new(MetricsRegistry::new()),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn engine(&self) -> &Arc<SimtEngine> {
        &self.engine
    }

    /// This session's own registry (the `scope: "session"` snapshot
    /// source).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Serve one request. Everything delegates to the shared engine —
    /// one exception: a session-scope `Stats` is answered entirely from
    /// this session's registry (the engine never sees it). Either way
    /// the session mirrors the engine's request bookkeeping (served /
    /// error counts, request latency) into its own registry.
    pub fn handle(&self, req: &Request) -> Result<Response, ServiceError> {
        let mut span = self.metrics.span(req.op());
        let result = self.handle_in_span(req, &mut span);
        self.finish_both(span);
        result
    }

    /// [`Self::handle`] inside a caller-owned span (the wire transport's
    /// entry point, mirroring [`SimtEngine::handle_in_span`]).
    pub fn handle_in_span(
        &self,
        req: &Request,
        span: &mut Span,
    ) -> Result<Response, ServiceError> {
        let t0 = Instant::now();
        let result = match req {
            // Snapshot-on-read, before this request's own bookkeeping
            // below — a session-scope stats never perturbs the numbers
            // it reports (same contract as the engine's).
            Request::Stats { scope: StatsScope::Session } => {
                let mut snap = self.metrics.snapshot();
                snap.scope = StatsScope::Session.name();
                Ok(Response::Stats(snap))
            }
            _ => self.engine.handle_in_span(req, span),
        };
        self.metrics.inc(Counter::RequestsServed);
        if result.is_err() {
            self.metrics.inc(Counter::RequestsErrors);
        }
        self.metrics.observe(Hist::RequestMicros, t0.elapsed().as_micros() as u64);
        result
    }

    /// Serve a batch, responses in request order — the same
    /// barrier-segmented concurrent fan-out as
    /// [`SimtEngine::handle_batch`] (stats items are sequencing
    /// barriers), run through [`Self::handle`] so each item lands on
    /// this session's bookkeeping too.
    pub fn handle_batch(&self, reqs: &[Request]) -> Vec<Result<Response, ServiceError>> {
        let mut out = Vec::with_capacity(reqs.len());
        for segment in reqs.split_inclusive(|r| matches!(r, Request::Stats { .. })) {
            let (concurrent, barrier) = match segment.last() {
                Some(Request::Stats { .. }) => {
                    (&segment[..segment.len() - 1], segment.last())
                }
                _ => (segment, None),
            };
            match concurrent {
                [] => {}
                [one] => out.push(self.handle(one)),
                many => out.extend(self.engine.runner().map(many, |r| self.handle(r))),
            }
            if let Some(stats) = barrier {
                out.push(self.handle(stats));
            }
        }
        out
    }

    /// Record a finished span into both rings: the session's (so
    /// session-scope stats show this client's recent requests) and the
    /// engine's (so the global view stays complete).
    fn finish_both(&self, span: Span) {
        if let Some(record) = span.finish() {
            self.metrics.record_span(record.clone());
            self.engine.metrics().record_span(record);
        }
    }
}

impl WireHandler for Session {
    fn line_span(&self, op: &'static str) -> Span {
        self.metrics.span(op)
    }

    fn handle_in_span(&self, req: &Request, span: &mut Span)
        -> Result<Response, ServiceError> {
        Session::handle_in_span(self, req, span)
    }

    fn handle_batch(&self, reqs: &[Request]) -> Vec<Result<Response, ServiceError>> {
        Session::handle_batch(self, reqs)
    }

    fn finish_line_span(&self, span: Span) {
        self.finish_both(span);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::SweepRunner;
    use crate::mem::arch::MemoryArchKind;

    fn shared_engine() -> Arc<SimtEngine> {
        Arc::new(SimtEngine::with_runner(SweepRunner::new(2)))
    }

    #[test]
    fn sessions_get_distinct_ids_and_are_counted() {
        let engine = shared_engine();
        let a = Session::new(Arc::clone(&engine));
        let b = Session::new(Arc::clone(&engine));
        assert_ne!(a.id(), b.id());
        assert_eq!(engine.metrics().get(Counter::SessionsOpened), 2);
    }

    #[test]
    fn session_scope_stats_report_only_own_traffic() {
        let engine = shared_engine();
        let a = Session::new(Arc::clone(&engine));
        let b = Session::new(Arc::clone(&engine));
        let run = Request::Run {
            program: "transpose32".into(),
            mem: MemoryArchKind::banked(16),
        };
        a.handle(&run).unwrap();
        a.handle(&run).unwrap();
        b.handle(&run).unwrap();

        let session_stats = Request::Stats { scope: StatsScope::Session };
        let Ok(Response::Stats(sa)) = a.handle(&session_stats) else { panic!("stats") };
        let Ok(Response::Stats(sb)) = b.handle(&session_stats) else { panic!("stats") };
        assert_eq!(sa.scope, "session");
        assert_eq!(sa.counter("requests.served"), Some(2), "a's own traffic only");
        assert_eq!(sb.counter("requests.served"), Some(1), "b's own traffic only");

        // The engine-global view spans all three runs (plus nothing from
        // the session-scope stats, which the engine never saw) and paid
        // one functional execution for the shared workload.
        let Ok(Response::Stats(se)) =
            a.handle(&Request::Stats { scope: StatsScope::Engine })
        else {
            panic!("stats")
        };
        assert_eq!(se.scope, "engine");
        assert_eq!(se.counter("requests.served"), Some(3));
        assert_eq!(se.counter("exec.functional_executions"), Some(1));
    }

    #[test]
    fn session_spans_land_in_both_rings() {
        let engine = shared_engine();
        let s = Session::new(Arc::clone(&engine));
        s.handle(&Request::List).unwrap();
        assert_eq!(s.metrics().spans().len(), 1);
        assert_eq!(engine.metrics().spans().len(), 1);
        assert_eq!(s.metrics().spans()[0].op, "list");
    }
}
