//! `soft-simt` — CLI for the Banked-Memories-for-Soft-SIMT reproduction.
//!
//! ```text
//! soft-simt table1                  # Table I  (resources + Fmax model)
//! soft-simt table2                  # Table II (transpose profiling)
//! soft-simt table3                  # Table III (FFT profiling)
//! soft-simt fig9                    # Fig. 9   (cost vs performance)
//! soft-simt sweep [--csv PATH]      # all 51 cells, text + optional CSV
//! soft-simt run -p PROG -m MEM      # one cell, full report
//! soft-simt validate [--artifacts DIR]   # golden validation suite
//! soft-simt asm FILE [-m MEM]       # assemble + run a custom program
//! soft-simt disasm PROG             # disassemble a generated program
//! soft-simt list                    # programs and memory architectures
//! soft-simt serve                   # JSON requests on stdin → stdout
//! soft-simt serve --listen ADDR     # concurrent TCP / unix-socket clients
//! soft-simt stats                   # session telemetry snapshot
//! ```
//!
//! The CLI is a thin client of the service layer: every command
//! constructs a typed [`Request`], routes it through one
//! [`SimtEngine`] session, and renders the [`Response`]. Errors are the
//! unified [`ServiceError`]; its `exit_code()` is the whole exit-code
//! policy. (clap is unavailable offline; parsing is hand-rolled.)

use soft_simt::coordinator::job::BenchJob;
use soft_simt::server::{ListenAddr, Session, SocketServer};
use soft_simt::service::{
    wire, ExploreStrategy, Request, Response, ServiceError, SimtEngine, StatsScope, TableKind,
};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Arc so `serve --listen` can share the one engine session across
    // client threads; every other command sees it as a plain reference.
    let engine = Arc::new(SimtEngine::new());
    let outcome = match args.first().map(String::as_str) {
        Some("table1") => cmd_table(&engine, TableKind::Table1),
        Some("table2") => cmd_table(&engine, TableKind::Table2),
        Some("table3") => cmd_table(&engine, TableKind::Table3),
        Some("fig9") => cmd_table(&engine, TableKind::Fig9),
        Some("sweep") => cmd_sweep(&engine, &args[1..]),
        Some("run") => cmd_run(&engine, &args[1..]),
        Some("advise") => cmd_advise(&engine, &args[1..]),
        Some("explore") => cmd_explore(&engine, &args[1..]),
        Some("validate") => cmd_validate(&engine, &args[1..]),
        Some("asm") => cmd_asm(&engine, &args[1..]),
        Some("disasm") => cmd_disasm(&engine, &args[1..]),
        Some("list") => cmd_list(&engine),
        Some("stats") => cmd_stats(&engine),
        Some("serve") => cmd_serve(&engine, &args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{HELP}");
            Ok(0)
        }
        Some(other) => Err(ServiceError::BadRequest(format!("unknown command '{other}'\n{HELP}"))),
    };
    // The single exit point: render the unified error, map to its code.
    let code = match outcome {
        Ok(code) => code,
        Err(e) => {
            eprintln!("{e}");
            e.exit_code()
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
soft-simt — Banked Memories for Soft SIMT Processors (reproduction)

USAGE:
  soft-simt table1                      print Table I (resources, Fmax model)
  soft-simt table2                      run the transpose sweep, print Table II
  soft-simt table3                      run the FFT sweep, print Table III
  soft-simt fig9                        print Fig. 9 (cost vs performance)
  soft-simt sweep [--csv PATH] [--all]  run all 51 paper cells (--all: the full
                                        100+-cell registry benchmark matrix)
  soft-simt run -p PROG -m MEM          run one benchmark cell
  soft-simt advise -p PROG              rank every memory for a workload
  soft-simt explore -p PROG [--strategy exhaustive|halving] [--json PATH]
                    [--spec PATH|JSON]  search the parametric memory design
                                        space (banks 2-32 x mappings x ports x
                                        capacity); print the Pareto frontier.
                                        --spec takes a typed space description
                                        (inline JSON or a file); specs naming
                                        processors/lanes (or the
                                        throughput-per-alm objective) search
                                        the system space (cores x lanes x
                                        memory x capacity) instead
  soft-simt validate [--artifacts DIR]  golden validation (PJRT when built)
  soft-simt asm FILE [-m MEM]           assemble and run a custom .asm file
  soft-simt disasm PROG                 print a generated program's assembly
  soft-simt list                        list programs and memory architectures
  soft-simt stats                       print the session's telemetry snapshot
                                        (counters, latency percentiles, spans)
  soft-simt serve [--metrics-json PATH] read line-delimited JSON requests on
                                        stdin, stream responses to stdout
                                        (one engine session: traces shared
                                        across all requests); on exit, dump a
                                        metrics snapshot to PATH if given
  soft-simt serve --listen ADDR [--depth N]
                                        accept concurrent TCP (HOST:PORT) or
                                        unix-socket (unix:PATH) clients; all
                                        sessions share one engine and trace
                                        store; N bounds in-flight requests
                                        (default 64; exit-code-3 rejections
                                        past it)
";

fn flag_value<'a>(args: &'a [String], names: &[&str]) -> Option<&'a str> {
    args.windows(2)
        .find(|w| names.contains(&w[0].as_str()))
        .map(|w| w[1].as_str())
}

fn required_program(cmd: &str, args: &[String]) -> Result<String, ServiceError> {
    flag_value(args, &["-p", "--program"])
        .map(String::from)
        .ok_or_else(|| ServiceError::BadRequest(format!("{cmd}: missing -p PROGRAM")))
}

/// Progress note for sweep-backed commands (stderr; the engine itself
/// never prints).
fn announce_sweep(engine: &SimtEngine, cells: usize) {
    eprintln!(
        "running {} benchmark cells on {} workers (trace-cached: execute once, replay per arch)...",
        cells,
        engine.runner().workers()
    );
}

fn cmd_table(engine: &SimtEngine, which: TableKind) -> Result<i32, ServiceError> {
    if which.needs_sweep() {
        announce_sweep(engine, BenchJob::paper_sweep().len());
    }
    let resp = engine.handle(&Request::Table(which))?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_sweep(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let all = rest.iter().any(|a| a == "--all");
    let cells =
        if all { BenchJob::extended_sweep().len() } else { BenchJob::paper_sweep().len() };
    announce_sweep(engine, cells);
    let resp = engine.handle(&Request::Sweep { all })?;
    print!("{}", resp.render());
    if let Some(path) = flag_value(rest, &["--csv"]) {
        let Response::Sweep(sweep) = &resp else { unreachable!("sweep answers sweep") };
        std::fs::write(path, sweep.csv())
            .map_err(|e| ServiceError::io(format!("writing {path}"), &e))?;
        eprintln!("wrote {path}");
    }
    Ok(resp.exit_code())
}

fn cmd_run(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let program = required_program("run", rest)?;
    let label = flag_value(rest, &["-m", "--mem"]).unwrap_or("16-banks-offset");
    let mem = soft_simt::service::parse_arch(label)?;
    let resp = engine.handle(&Request::Run { program, mem })?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_advise(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let program = required_program("advise", rest)?;
    let resp = engine.handle(&Request::Advise { program })?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_explore(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let program = required_program("explore", rest)?;
    let strategy = match flag_value(rest, &["--strategy"]) {
        None => ExploreStrategy::default(),
        Some(s) => ExploreStrategy::parse(s).ok_or_else(|| {
            ServiceError::BadRequest(format!("unknown strategy '{s}' (try: exhaustive, halving)"))
        })?,
    };
    let spec = match flag_value(rest, &["--spec"]) {
        None => None,
        Some(arg) => {
            // Inline JSON (starts with '{') or a path to a JSON file.
            let text = if arg.trim_start().starts_with('{') {
                arg.to_string()
            } else {
                std::fs::read_to_string(arg)
                    .map_err(|e| ServiceError::io(format!("reading {arg}"), &e))?
            };
            Some(wire::explore_spec_from_json(&wire::parse_json(&text)?)?)
        }
    };
    match &spec {
        None => {
            // Progress note: the engine exposes the exact space its
            // dispatch will build, so the note can never drift from the
            // search.
            let space = engine.explore_space(&program)?;
            eprintln!(
                "exploring {} design points ({} architectures) for {program} on {} workers...",
                space.points().len(),
                space.arch_count(),
                engine.runner().workers()
            );
        }
        Some(s) => eprintln!(
            "exploring a spec-defined {} space for {program}...",
            if s.is_system() { "system (processors x lanes x memory)" } else { "memory" }
        ),
    }
    let resp = engine.handle(&Request::Explore { program, strategy, spec })?;
    // The subsystem's guarantee, asserted where the user can see it: a
    // fresh CLI session serves the whole space from one execution.
    let json = match &resp {
        Response::Explore(result) => {
            assert_eq!(result.captures, 1, "explore must execute the workload exactly once");
            result.to_json()
        }
        Response::SystemExplore(result) => {
            assert_eq!(result.captures, 1, "explore must execute the workload exactly once");
            result.to_json()
        }
        _ => unreachable!("explore answers explore"),
    };
    print!("{}", resp.render());
    if let Some(path) = flag_value(rest, &["--json"]) {
        std::fs::write(path, json)
            .map_err(|e| ServiceError::io(format!("writing {path}"), &e))?;
        eprintln!("wrote {path}");
    }
    Ok(resp.exit_code())
}

fn cmd_validate(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let artifacts_dir = flag_value(rest, &["--artifacts"]).map(String::from);
    let resp = engine.handle(&Request::Validate { artifacts_dir })?;
    if let Response::Validate(v) = &resp {
        if let Some(note) = &v.pjrt_note {
            eprintln!("{note}");
        }
    }
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_asm(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let Some(path) = rest.first() else {
        return Err(ServiceError::BadRequest("asm: missing FILE".into()));
    };
    let source = std::fs::read_to_string(path)
        .map_err(|e| ServiceError::io(format!("reading {path}"), &e))?;
    let label = flag_value(rest, &["-m", "--mem"]).unwrap_or("16-banks");
    let mem = soft_simt::service::parse_arch(label)?;
    let resp = engine.handle(&Request::Asm { source, mem })?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_disasm(engine: &SimtEngine, rest: &[String]) -> Result<i32, ServiceError> {
    let Some(name) = rest.first() else {
        return Err(ServiceError::BadRequest("disasm: missing PROGRAM name".into()));
    };
    let resp = engine.handle(&Request::Disasm { program: name.clone() })?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_list(engine: &SimtEngine) -> Result<i32, ServiceError> {
    let resp = engine.handle(&Request::List)?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_stats(engine: &SimtEngine) -> Result<i32, ServiceError> {
    let resp = engine.handle(&Request::Stats { scope: StatsScope::Engine })?;
    print!("{}", resp.render());
    Ok(resp.exit_code())
}

fn cmd_serve(engine: &Arc<SimtEngine>, rest: &[String]) -> Result<i32, ServiceError> {
    let depth = match flag_value(rest, &["--depth"]) {
        None => 64,
        Some(s) => s.parse::<usize>().map_err(|_| {
            ServiceError::BadRequest(format!("serve: --depth must be a count, got '{s}'"))
        })?,
    };
    if let Some(addr) = flag_value(rest, &["--listen"]) {
        // Socket front-end: concurrent clients, one Session each, one
        // shared dispatcher bounding in-flight lines (DESIGN.md §Server).
        let addr = ListenAddr::parse(addr)?;
        let server = SocketServer::bind(Arc::clone(engine), &addr, depth)
            .map_err(|e| ServiceError::io("binding --listen address", &e))?;
        eprintln!(
            "listening on {} (depth {depth}, {} workers)",
            server.local_addr().unwrap_or_else(|| "<unknown>".into()),
            engine.runner().workers()
        );
        server.run().map_err(|e| ServiceError::io("accept loop", &e))?;
        return Ok(0);
    }
    let metrics_path = flag_value(rest, &["--metrics-json"]).map(String::from);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    // The stdin loop is a thin single-session adapter over the same
    // transport the socket clients run (byte-identical per line).
    let session = Session::new(Arc::clone(engine));
    wire::serve(&session, stdin.lock(), stdout.lock())
        .map_err(|e| ServiceError::io("serve loop", &e))?;
    if let Some(path) = &metrics_path {
        // End-of-session snapshot: the whole serve run's counters,
        // histograms and recent spans, as one JSON document.
        let mut doc = engine.metrics().snapshot().to_json();
        doc.push('\n');
        std::fs::write(path, doc).map_err(|e| ServiceError::io(format!("writing {path}"), &e))?;
        eprintln!("wrote {path}");
    }
    Ok(0)
}
