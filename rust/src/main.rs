//! `soft-simt` — CLI for the Banked-Memories-for-Soft-SIMT reproduction.
//!
//! ```text
//! soft-simt table1                  # Table I  (resources + Fmax model)
//! soft-simt table2                  # Table II (transpose profiling)
//! soft-simt table3                  # Table III (FFT profiling)
//! soft-simt fig9                    # Fig. 9   (cost vs performance)
//! soft-simt sweep [--csv PATH]      # all 51 cells, text + optional CSV
//! soft-simt run -p PROG -m MEM      # one cell, full report
//! soft-simt validate [--artifacts DIR]   # golden validation suite
//! soft-simt asm FILE [-m MEM]       # assemble + run a custom program
//! soft-simt disasm PROG             # disassemble a generated program
//! soft-simt list                    # programs and memory architectures
//! ```
//!
//! (clap is unavailable offline; parsing is hand-rolled.)

use soft_simt::coordinator::{job::BenchJob, job::TraceCache, report, runner::SweepRunner, validate};
use soft_simt::explore::{self, DesignSpace, Exhaustive, SearchStrategy, SuccessiveHalving};
use soft_simt::isa::asm;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::library;
use soft_simt::runtime::ArtifactRuntime;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;
use soft_simt::sim::stats::RunReport;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("table1") => cmd_table1(),
        Some("table2") => cmd_table("table2", &args[1..]),
        Some("table3") => cmd_table("table3", &args[1..]),
        Some("fig9") => cmd_table("fig9", &args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("advise") => cmd_advise(&args[1..]),
        Some("explore") => cmd_explore(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("asm") => cmd_asm(&args[1..]),
        Some("disasm") => cmd_disasm(&args[1..]),
        Some("list") => cmd_list(),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{HELP}");
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
soft-simt — Banked Memories for Soft SIMT Processors (reproduction)

USAGE:
  soft-simt table1                      print Table I (resources, Fmax model)
  soft-simt table2                      run the transpose sweep, print Table II
  soft-simt table3                      run the FFT sweep, print Table III
  soft-simt fig9                        print Fig. 9 (cost vs performance)
  soft-simt sweep [--csv PATH] [--all]  run all 51 cells (+reduction with --all)
  soft-simt run -p PROG -m MEM          run one benchmark cell
  soft-simt advise -p PROG              rank every memory for a workload
  soft-simt explore -p PROG [--strategy exhaustive|halving] [--json PATH]
                                        search the parametric memory design
                                        space (banks 2-32 x mappings x ports x
                                        capacity); print the Pareto frontier
  soft-simt validate [--artifacts DIR]  golden validation (PJRT when built)
  soft-simt asm FILE [-m MEM]           assemble and run a custom .asm file
  soft-simt disasm PROG                 print a generated program's assembly
  soft-simt list                        list programs and memory architectures
";

fn flag_value<'a>(args: &'a [String], names: &[&str]) -> Option<&'a str> {
    args.windows(2)
        .find(|w| names.contains(&w[0].as_str()))
        .map(|w| w[1].as_str())
}

fn parse_arch(s: &str) -> Result<MemoryArchKind, String> {
    MemoryArchKind::parse(s).ok_or_else(|| {
        format!(
            "unknown memory '{s}' (try one of: {})",
            MemoryArchKind::table3_nine()
                .iter()
                .map(|a| a.label())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })
}

fn run_sweep(jobs: &[BenchJob]) -> Option<Vec<soft_simt::coordinator::job::BenchResult>> {
    let runner = SweepRunner::default();
    eprintln!(
        "running {} benchmark cells on {} workers (trace-cached: execute once, replay per arch)...",
        jobs.len(),
        runner.workers()
    );
    match runner.run_cached(jobs) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("sweep failed: {e}");
            None
        }
    }
}

fn cmd_table1() -> i32 {
    print!("{}", report::render_table1());
    0
}

fn cmd_table(which: &str, _rest: &[String]) -> i32 {
    let jobs = BenchJob::paper_sweep();
    let Some(results) = run_sweep(&jobs) else { return 1 };
    match which {
        "table2" => print!("{}", report::render_table2(&results)),
        "table3" => print!("{}", report::render_table3(&results)),
        _ => print!("{}", report::render_fig9(&results)),
    }
    0
}

fn cmd_sweep(rest: &[String]) -> i32 {
    let all = rest.iter().any(|a| a == "--all");
    let jobs = if all { BenchJob::extended_sweep() } else { BenchJob::paper_sweep() };
    let Some(results) = run_sweep(&jobs) else { return 1 };
    print!("{}", report::render_table2(&results));
    print!("{}", report::render_table3(&results));
    if all {
        print!("{}", report::render_reduction(&results));
    }
    print!("{}", report::render_fig9(&results));
    if let Some(path) = flag_value(rest, &["--csv"]) {
        if let Err(e) = std::fs::write(path, report::sweep_csv(&results)) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    0
}

fn print_report(r: &RunReport) {
    let s = &r.stats;
    println!("program      {}", r.program);
    println!("memory       {}", r.arch);
    println!("threads      {}", r.threads);
    println!(
        "INT / Imm / FP / Other cycles: {} / {} / {} / {}",
        s.int_cycles, s.imm_cycles, s.fp_cycles, s.other_cycles
    );
    println!("D load   {} cycles over {} ops", s.d_load_cycles, s.d_load_ops);
    if s.tw_load_ops > 0 {
        println!("TW load  {} cycles over {} ops", s.tw_load_cycles, s.tw_load_ops);
    }
    println!("store    {} cycles over {} ops", s.store_cycles, s.store_ops);
    println!("stalls   write-buffer {} / drain {}", s.wbuf_stall_cycles, s.drain_cycles);
    println!(
        "total    {} cycles  ({:.2} us @ {:.0} MHz)",
        r.total_cycles(),
        r.time_us(),
        r.arch.fmax_mhz()
    );
    if let Some(e) = r.r_bank_eff() {
        println!("R bank eff.  {:.1}%", e * 100.0);
    }
    if let Some(e) = r.tw_bank_eff() {
        println!("TW bank eff. {:.1}%", e * 100.0);
    }
    if let Some(e) = r.w_bank_eff() {
        println!("W bank eff.  {:.1}%", e * 100.0);
    }
    println!("compute eff. {:.1}%", r.compute_efficiency() * 100.0);
}

fn cmd_run(rest: &[String]) -> i32 {
    let Some(program) = flag_value(rest, &["-p", "--program"]) else {
        eprintln!("run: missing -p PROGRAM");
        return 2;
    };
    let arch = match parse_arch(flag_value(rest, &["-m", "--mem"]).unwrap_or("16-banks-offset")) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match BenchJob::new(program, arch).run() {
        Ok(result) => {
            print_report(&result.report);
            0
        }
        Err(e) => {
            eprintln!("run failed: {e}");
            1
        }
    }
}

fn cmd_advise(rest: &[String]) -> i32 {
    let Some(program) = flag_value(rest, &["-p", "--program"]) else {
        eprintln!("advise: missing -p PROGRAM");
        return 2;
    };
    match soft_simt::coordinator::advisor::advise(program) {
        Ok(advice) => {
            print!("{}", advice.render());
            0
        }
        Err(e) => {
            eprintln!("advise failed: {e}");
            1
        }
    }
}

fn cmd_explore(rest: &[String]) -> i32 {
    let Some(program) = flag_value(rest, &["-p", "--program"]) else {
        eprintln!("explore: missing -p PROGRAM");
        return 2;
    };
    let Some(workload) = library::program_by_name(program) else {
        eprintln!("unknown program '{program}' (see `soft-simt list`)");
        return 2;
    };
    let strategy_name = flag_value(rest, &["--strategy"]).unwrap_or("halving");
    let strategy: Box<dyn SearchStrategy> = match strategy_name {
        "exhaustive" | "grid" => Box::new(Exhaustive),
        "halving" | "pruning" => Box::new(SuccessiveHalving::default()),
        other => {
            eprintln!("unknown strategy '{other}' (try: exhaustive, halving)");
            return 2;
        }
    };
    let space = DesignSpace::parametric(workload.dataset_kb());
    let runner = SweepRunner::default();
    let cache = TraceCache::new();
    eprintln!(
        "exploring {} design points ({} architectures) for {program} on {} workers...",
        space.points().len(),
        space.arch_count(),
        runner.workers()
    );
    match explore::explore(program, &space, strategy.as_ref(), &runner, &cache) {
        Ok(result) => {
            // The subsystem's guarantee, asserted where the user can see
            // it: the whole space was served by one functional execution.
            assert_eq!(result.captures, 1, "explore must execute the workload exactly once");
            print!("{}", result.render());
            if let Some(path) = flag_value(rest, &["--json"]) {
                if let Err(e) = std::fs::write(path, result.to_json()) {
                    eprintln!("writing {path}: {e}");
                    return 1;
                }
                eprintln!("wrote {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("explore failed: {e}");
            1
        }
    }
}

fn cmd_validate(rest: &[String]) -> i32 {
    let dir = flag_value(rest, &["--artifacts"]).unwrap_or("artifacts");
    let rt = match ArtifactRuntime::new(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("PJRT unavailable ({e:#}); validating against host references only");
            None
        }
    };
    let checks = validate::validate_all(rt.as_ref());
    let mut failed = 0;
    for c in &checks {
        println!("[{}] {} — {}", if c.passed { "PASS" } else { "FAIL" }, c.name, c.detail);
        if !c.passed {
            failed += 1;
        }
    }
    println!("\n{} checks, {} failed", checks.len(), failed);
    if failed > 0 {
        1
    } else {
        0
    }
}

fn cmd_asm(rest: &[String]) -> i32 {
    let Some(path) = rest.first() else {
        eprintln!("asm: missing FILE");
        return 2;
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    let program = match asm::assemble(&src) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let arch = match parse_arch(flag_value(rest, &["-m", "--mem"]).unwrap_or("16-banks")) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut machine = Machine::new(MachineConfig::for_arch(arch));
    match machine.run_program(&program) {
        Ok(report) => {
            print_report(&report);
            0
        }
        Err(e) => {
            eprintln!("simulation failed: {e}");
            1
        }
    }
}

fn cmd_disasm(rest: &[String]) -> i32 {
    let Some(name) = rest.first() else {
        eprintln!("disasm: missing PROGRAM name");
        return 2;
    };
    match library::program_by_name(name) {
        Some(w) => {
            print!("{}", asm::disassemble(w.program()));
            0
        }
        None => {
            eprintln!("unknown program '{name}' (see `soft-simt list`)");
            1
        }
    }
}

fn cmd_list() -> i32 {
    println!("programs:");
    for p in library::program_names() {
        println!("  {p}");
    }
    println!("\nmemory architectures (paper set):");
    for a in MemoryArchKind::table3_nine() {
        println!("  {}  (fmax {:.0} MHz)", a.label(), a.fmax_mhz());
    }
    println!(
        "\nparametric space (see `explore`): banked 2-32 banks x {{lsb, offsetN, xor}} \
         mappings, multiport {{1,2,4,8}}R x {{1,2}}W [-VB];\nlabels like 'banked8-offset3', \
         '2r-1w' parse anywhere a memory is accepted"
    );
    0
}
