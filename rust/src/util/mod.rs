//! Small shared utilities: deterministic PRNG, property-test harness,
//! bit tricks, and formatting helpers.
//!
//! `proptest`/`rand` are unavailable in this offline environment, so the
//! crate carries its own deterministic xorshift generator ([`rng::XorShift64`])
//! and a tiny property-testing harness ([`proptest`]) used across the test
//! suite.

pub mod bits;
pub mod fmt;
pub mod proptest;
pub mod rng;

pub use rng::XorShift64;
