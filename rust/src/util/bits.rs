//! Bit-level helpers mirroring the FPGA structures the paper builds out of
//! carry chains and fracturable LUTs.

/// Population count of a 16-lane access mask, as the paper's bank-conflict
/// counter does per column of the one-hot matrix (a 5-bit result: 0..=16).
#[inline]
pub fn popcount16(v: u16) -> u32 {
    v.count_ones()
}

/// Isolate the lowest set bit (`v & -v`) — the *software* shortcut that the
/// paper's carry-chain arbiter computes structurally (`v - 1` plus
/// transition detection). The arbiter module property-tests its own
/// hardware-faithful state machine against this closed form.
#[inline]
pub fn lowest_set_bit(v: u16) -> u16 {
    v & v.wrapping_neg()
}

/// True if `v` is one-hot (exactly one bit set).
#[inline]
pub fn is_one_hot(v: u16) -> bool {
    v != 0 && (v & (v - 1)) == 0
}

/// Ceiling division, used throughout the multiport timing model
/// (`ceil(active_lanes / ports)`).
#[inline]
pub fn ceil_div(a: u32, b: u32) -> u32 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `log2` of a power of two. Panics on non-powers (bank counts are 4/8/16).
#[inline]
pub fn log2_exact(v: u32) -> u32 {
    assert!(v.is_power_of_two(), "{v} is not a power of two");
    v.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_matches_naive() {
        for v in [0u16, 1, 0b1010, 0xFFFF, 0x8001] {
            let naive = (0..16).filter(|i| v >> i & 1 == 1).count() as u32;
            assert_eq!(popcount16(v), naive);
        }
    }

    #[test]
    fn lowest_set_bit_examples() {
        assert_eq!(lowest_set_bit(0b0001_0110), 0b0000_0010); // Fig. 6 row 1
        assert_eq!(lowest_set_bit(0b0001_0100), 0b0000_0100); // Fig. 6 row 2
        assert_eq!(lowest_set_bit(0b0001_0000), 0b0001_0000); // Fig. 6 row 3
        assert_eq!(lowest_set_bit(0), 0);
    }

    #[test]
    fn one_hot_detection() {
        assert!(!is_one_hot(0));
        assert!(is_one_hot(1));
        assert!(is_one_hot(0x8000));
        assert!(!is_one_hot(3));
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(16, 4), 4);
        assert_eq!(ceil_div(16, 1), 16);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(17, 4), 5);
    }

    #[test]
    fn log2_of_bank_counts() {
        assert_eq!(log2_exact(4), 2);
        assert_eq!(log2_exact(8), 3);
        assert_eq!(log2_exact(16), 4);
    }

    #[test]
    #[should_panic]
    fn log2_rejects_non_power() {
        log2_exact(12);
    }
}
