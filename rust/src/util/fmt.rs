//! Plain-text table rendering used by the report generators — the paper's
//! tables are regenerated as aligned ASCII (and CSV) rather than LaTeX.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with every column padded to its widest cell.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = width[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric tables).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a cycle count with thousands separators (`12_583` → `12,583`).
pub fn with_commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Format a ratio as a percentage with one decimal, like the paper's
/// efficiency columns ("38.1").
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

/// Format microseconds with two decimals, like the paper's Time rows.
pub fn us(x: f64) -> String {
    format!("{x:.2}")
}

/// Escape and quote a string as a JSON string literal. Shared by every
/// hand-rolled JSON emitter in the crate (the explorer's `to_json`, the
/// service wire codec, the bench JSON artifacts) — the crate is
/// dependency-free, so this *is* the JSON string encoder.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(["a", "bbb"]);
        t.row(["1", "2"]).row(["333", "4"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = TextTable::new(["a", "b"]);
        t.row(["only one"]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = TextTable::new(["x", "y"]);
        t.row(["1", "2"]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn commas() {
        assert_eq!(with_commas(0), "0");
        assert_eq!(with_commas(999), "999");
        assert_eq!(with_commas(1000), "1,000");
        assert_eq!(with_commas(12583), "12,583");
        assert_eq!(with_commas(1234567), "1,234,567");
    }

    #[test]
    fn pct_matches_paper_style() {
        assert_eq!(pct(0.381), "38.1");
        assert_eq!(pct(0.061), "6.1");
    }
}
