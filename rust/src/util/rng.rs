//! Deterministic xorshift64* PRNG.
//!
//! Used everywhere randomness is needed (property tests, synthetic
//! workloads, fuzz-style failure injection) so that every run of the test
//! suite and every benchmark workload is exactly reproducible from a seed.

/// xorshift64* generator (Vigna, 2016). Not cryptographic; deterministic,
/// fast, and good enough statistical quality for workload generation.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a non-zero seed. A zero seed is remapped to
    /// a fixed odd constant (xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next 32-bit value (upper half of the 64-bit output).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    /// Uses the widening-multiply method (Lemire) to avoid modulo bias
    /// beyond 1 part in 2^32.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0) is meaningless");
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[-1, 1)`, handy for synthetic signal data.
    pub fn signed_f32(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u32() as f64 / u32::MAX as f64) < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a vector with `n` random f32 samples in [-1, 1).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.signed_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = XorShift64::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi, "range endpoints should both occur");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift64::new(11);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = XorShift64::new(1234);
        let mut hist = [0u32; 8];
        let n = 80_000;
        for _ in 0..n {
            hist[r.below(8) as usize] += 1;
        }
        let expect = n / 8;
        for (i, &c) in hist.iter().enumerate() {
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < expect as u64 / 10,
                "bucket {i} count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
