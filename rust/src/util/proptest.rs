//! A minimal property-testing harness (the `proptest` crate is not
//! available offline).
//!
//! Usage (compile-checked here, executed by this module's unit tests —
//! doctest *execution* binaries land in /tmp without the xla rpath):
//! ```no_run
//! use soft_simt::util::proptest::check;
//! check("addition commutes", 1000, |rng| {
//!     let a = rng.next_u32() >> 8;
//!     let b = rng.next_u32() >> 8;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case receives a PRNG derived from a fixed master seed plus the case
//! index, so failures are reproducible and reported with the case seed.

use super::rng::XorShift64;

/// Master seed for all property tests. Changing it re-rolls every case in
/// the suite at once (handy for occasional re-fuzzing) while keeping CI
/// deterministic.
pub const MASTER_SEED: u64 = 0xC0FF_EE00_2025_0711;

/// Run `cases` random cases of `prop`. Panics (with the failing seed in the
/// message) if any case panics.
pub fn check<F: Fn(&mut XorShift64)>(name: &str, cases: u32, prop: F) {
    for i in 0..cases {
        let seed = MASTER_SEED ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShift64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` instead of
/// panicking — convenient when composing several assertions.
pub fn check_ok<F: Fn(&mut XorShift64) -> Result<(), String>>(name: &str, cases: u32, prop: F) {
    check(name, cases, |rng| {
        if let Err(msg) = prop(rng) {
            panic!("{msg}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 100, |rng| {
            let v = rng.next_u32();
            assert_eq!(v, v);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_rng| panic!("boom"));
    }

    #[test]
    fn check_ok_propagates_err() {
        let r = std::panic::catch_unwind(|| {
            check_ok("err prop", 1, |_| Err("nope".to_string()));
        });
        assert!(r.is_err());
    }

    #[test]
    fn cases_see_distinct_seeds() {
        use std::sync::Mutex;
        let seen = Mutex::new(std::collections::HashSet::new());
        check("distinct", 50, |rng| {
            seen.lock().unwrap().insert(rng.next_u64());
        });
        assert_eq!(seen.lock().unwrap().len(), 50);
    }
}
