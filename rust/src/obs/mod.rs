//! Engine-wide observability: counters, latency histograms, and
//! per-request spans (DESIGN.md §Observability).
//!
//! Dependency-free `std`, built so instrumentation can live *on* the
//! hot paths without slowing them:
//!
//! - [`MetricsRegistry`] — a fixed set of named [`Counter`]s over plain
//!   atomics plus power-of-two-bucket [`Histogram`]s, owned by the
//!   [`SimtEngine`](crate::service::SimtEngine) and shared (`Arc`) into
//!   the [`SweepRunner`](crate::coordinator::runner::SweepRunner), the
//!   [`TraceCache`](crate::coordinator::job::TraceCache), and — through
//!   those two — the design-space explorer.
//! - [`Span`] — one request's phase timings (`parse → cache_lookup →
//!   execute → compile → replay → render`), collected into a ring of
//!   recent [`SpanRecord`]s, with a zero-cost path when recording is
//!   disabled.
//! - [`MetricsSnapshot`] — the snapshot-on-read view every consumer
//!   shares: `Request::Stats`, the `soft-simt stats` CLI, the
//!   `serve --metrics-json` dump, and the bench overhead probes.
//!
//! The replay kernels themselves never touch an atomic per step: packed
//! walks tally into local [`ReplayTally`](crate::sim::packed::ReplayTally)s
//! and flush once per driver call, which is what keeps the bench-gated
//! `instrumented_overhead_pct` inside the ≤2% budget.

pub mod metrics;
pub mod span;

pub use metrics::{
    Counter, Hist, Histogram, HistogramCounts, HistogramSummary, MetricsRegistry,
    MetricsSnapshot, COUNTERS, HISTS, HIST_BUCKETS, SPAN_RING_CAP,
};
pub use span::{Phase, Span, SpanRecord, PHASES};
