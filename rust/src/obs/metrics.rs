//! The metrics registry: named atomic counters, fixed-bucket latency
//! histograms, and the ring of recent request spans.
//!
//! Everything here is dependency-free `std` and built for hot paths:
//!
//! - **Counters** are a fixed [`Counter`] enum indexing a
//!   `[AtomicU64; N]` — increments are single `Relaxed` `fetch_add`s,
//!   no locks, no hashing, no registration. The replay kernels never
//!   even pay the atomic per step: they tally into locals and flush
//!   once per walk (see `sim/packed.rs` and `coordinator/runner.rs`).
//! - **Histograms** are 32 power-of-two buckets (`0`, `[1,2)`, `[2,4)`,
//!   …, saturating at the top). Recording is one `leading_zeros` plus
//!   one atomic add; p50/p90/p99 are derived on snapshot by walking the
//!   bucket counts and reporting the containing bucket's upper bound.
//! - **Spans** live in a small mutex-guarded ring (per *request*, never
//!   per replay step), gated by an `AtomicBool` so disabling recording
//!   removes every clock read (DESIGN.md §Observability).
//!
//! Reads are snapshot-on-read ([`MetricsRegistry::snapshot`]): the
//! `Stats` service endpoint, the `--metrics-json` dump, and the benches
//! all consume the same [`MetricsSnapshot`].

use super::span::{Phase, Span, SpanRecord};
use crate::util::fmt::{json_str, TextTable};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of registered counters (the length of [`Counter::ALL`]).
pub const COUNTERS: usize = 18;

/// Every counter in the registry. Discriminants index the registry's
/// atomic array; [`Counter::name`] is the stable wire/text name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Requests the engine has answered (ok or error).
    RequestsServed,
    /// Requests that returned a `ServiceError`.
    RequestsErrors,
    /// Functional executions paid for: trace captures plus coupled
    /// `Asm` runs (promoted from the engine's old test-only counter).
    FunctionalExecutions,
    /// Counted trace-cache lookups that found a trace.
    TraceCacheHits,
    /// Counted trace-cache lookups that missed.
    TraceCacheMisses,
    /// Compiled-trace builds performed.
    CompiledBuilds,
    /// Compiled-trace lookups served from the memo.
    CompiledHits,
    /// Single-architecture replay walks (reference or compiled).
    ReplayScalarInvocations,
    /// Lane-packed batch replay driver calls.
    ReplayPackedInvocations,
    /// `LaneChunk`s charged by packed drivers.
    ReplayPackedChunks,
    /// Architecture lanes actually occupied across those chunks.
    ReplayPackedLanesUsed,
    /// Lane slots available (`chunks × ARCH_LANES`); with
    /// [`Counter::ReplayPackedLanesUsed`] this is packed occupancy.
    ReplayPackedLaneSlots,
    /// Chunk-segment advances walked (wavefront and single-threaded).
    ReplayWavefrontSegments,
    /// Write-pipeline stall cycles summed over replayed runs.
    ReplayWbufStallCycles,
    /// Shard write-lock acquisitions in the sharded trace store (cold
    /// inserts only; a warm read path that stays at zero is the
    /// lock-free-read guarantee the serve bench asserts).
    StoreShardWriteLocks,
    /// Read-lock acquisitions that found the shard momentarily busy and
    /// had to block (`try_read` miss → blocking `read`).
    StoreShardReadContention,
    /// `Session`s opened against the engine (stdin adapter + sockets).
    SessionsOpened,
    /// Requests rejected by the dispatcher's backpressure bound
    /// (`ServiceError::Overloaded`).
    OverloadRejections,
}

impl Counter {
    /// Every counter, in report order.
    pub const ALL: [Counter; COUNTERS] = [
        Counter::RequestsServed,
        Counter::RequestsErrors,
        Counter::FunctionalExecutions,
        Counter::TraceCacheHits,
        Counter::TraceCacheMisses,
        Counter::CompiledBuilds,
        Counter::CompiledHits,
        Counter::ReplayScalarInvocations,
        Counter::ReplayPackedInvocations,
        Counter::ReplayPackedChunks,
        Counter::ReplayPackedLanesUsed,
        Counter::ReplayPackedLaneSlots,
        Counter::ReplayWavefrontSegments,
        Counter::ReplayWbufStallCycles,
        Counter::StoreShardWriteLocks,
        Counter::StoreShardReadContention,
        Counter::SessionsOpened,
        Counter::OverloadRejections,
    ];

    /// Stable dotted wire/text name.
    pub fn name(self) -> &'static str {
        match self {
            Counter::RequestsServed => "requests.served",
            Counter::RequestsErrors => "requests.errors",
            Counter::FunctionalExecutions => "exec.functional_executions",
            Counter::TraceCacheHits => "trace_cache.hits",
            Counter::TraceCacheMisses => "trace_cache.misses",
            Counter::CompiledBuilds => "compiled.builds",
            Counter::CompiledHits => "compiled.hits",
            Counter::ReplayScalarInvocations => "replay.scalar_invocations",
            Counter::ReplayPackedInvocations => "replay.packed_invocations",
            Counter::ReplayPackedChunks => "replay.packed_chunks",
            Counter::ReplayPackedLanesUsed => "replay.packed_lanes_used",
            Counter::ReplayPackedLaneSlots => "replay.packed_lane_slots",
            Counter::ReplayWavefrontSegments => "replay.wavefront_segments",
            Counter::ReplayWbufStallCycles => "replay.wbuf_stall_cycles",
            Counter::StoreShardWriteLocks => "store.shard_write_locks",
            Counter::StoreShardReadContention => "store.shard_read_contention",
            Counter::SessionsOpened => "server.sessions_opened",
            Counter::OverloadRejections => "server.overload_rejections",
        }
    }
}

/// Number of registered histograms (the length of [`Hist::ALL`]).
pub const HISTS: usize = 2;

/// Every latency histogram in the registry (values in microseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Whole-request wall latency through `SimtEngine::handle`.
    RequestMicros,
    /// Replay-phase latency (warm runs and sweep batch-replay phases).
    ReplayMicros,
}

impl Hist {
    pub const ALL: [Hist; HISTS] = [Hist::RequestMicros, Hist::ReplayMicros];

    pub fn name(self) -> &'static str {
        match self {
            Hist::RequestMicros => "request_us",
            Hist::ReplayMicros => "replay_us",
        }
    }
}

/// Fixed bucket count: `0`, then 31 power-of-two ranges, saturating.
pub const HIST_BUCKETS: usize = 32;

/// Bucket index for a recorded value: bucket 0 holds exactly `0`,
/// bucket `i ≥ 1` holds `[2^(i-1), 2^i)`, and the top bucket absorbs
/// everything from `2^(HIST_BUCKETS-2)` up.
fn bucket_index(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        ((64 - value.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Largest value the bucket reports as its percentile estimate (its
/// inclusive upper bound; the saturating top bucket reports its nominal
/// bound).
fn bucket_upper_bound(index: usize) -> u64 {
    if index == 0 {
        0
    } else {
        (1u64 << index) - 1
    }
}

/// A lock-free fixed-bucket latency histogram.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one observation (units are the caller's; the registry's
    /// histograms use microseconds).
    pub fn record(&self, value: u64) {
        self.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy of the bucket counts.
    pub fn snapshot(&self) -> HistogramCounts {
        HistogramCounts { counts: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)) }
    }
}

/// Snapshot of one histogram's buckets, with percentile derivation.
#[derive(Debug, Clone)]
pub struct HistogramCounts {
    pub counts: [u64; HIST_BUCKETS],
}

impl HistogramCounts {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The `p`-th percentile (0 < p ≤ 1) as the upper bound of the
    /// bucket containing rank `ceil(p · total)`; 0 on an empty
    /// histogram. Example: after recording `1, 2, 4, 8`, `p50` is the
    /// bound of `[2,4)` = 3 and `p99` the bound of `[8,16)` = 15.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.total();
        if total == 0 {
            return 0;
        }
        let rank = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(HIST_BUCKETS - 1)
    }

    fn summary(&self, name: &'static str) -> HistogramSummary {
        HistogramSummary {
            name,
            count: self.total(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p99: self.percentile(0.99),
        }
    }
}

/// One histogram's derived summary, as reported by `Stats`.
#[derive(Debug, Clone)]
pub struct HistogramSummary {
    pub name: &'static str,
    pub count: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
}

/// Capacity of the recent-spans ring buffer.
pub const SPAN_RING_CAP: usize = 128;

/// The engine-wide registry. One per [`SimtEngine`] session, shared by
/// `Arc` into the runner and the trace cache.
///
/// [`SimtEngine`]: crate::service::SimtEngine
#[derive(Debug)]
pub struct MetricsRegistry {
    counters: [AtomicU64; COUNTERS],
    hists: [Histogram; HISTS],
    recording: AtomicBool,
    spans: Mutex<VecDeque<SpanRecord>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    /// A fresh registry with span recording **on** (the per-request
    /// cost is a handful of clock reads; turn it off for benchmarking
    /// the floor with [`Self::set_recording`]).
    pub fn new() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Histogram::new()),
            recording: AtomicBool::new(true),
            spans: Mutex::new(VecDeque::new()),
        }
    }

    pub fn inc(&self, counter: Counter) {
        self.counters[counter as usize].fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, counter: Counter, n: u64) {
        if n != 0 {
            self.counters[counter as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self, counter: Counter) -> u64 {
        self.counters[counter as usize].load(Ordering::Relaxed)
    }

    /// Record a histogram observation (microseconds for the built-ins).
    pub fn observe(&self, hist: Hist, value: u64) {
        self.hists[hist as usize].record(value);
    }

    /// Whether per-request span recording is enabled.
    pub fn recording(&self) -> bool {
        self.recording.load(Ordering::Relaxed)
    }

    pub fn set_recording(&self, on: bool) {
        self.recording.store(on, Ordering::Relaxed);
    }

    /// A span for one request — enabled iff recording is on, so the
    /// disabled path never reads a clock.
    pub fn span(&self, op: &'static str) -> Span {
        Span::new(op, self.recording())
    }

    /// Close a span into the ring (a no-op for disabled spans).
    pub fn finish_span(&self, span: Span) {
        if let Some(record) = span.finish() {
            self.record_span(record);
        }
    }

    /// Push a finished record, evicting the oldest past
    /// [`SPAN_RING_CAP`].
    pub fn record_span(&self, record: SpanRecord) {
        let mut ring = self.spans.lock().unwrap();
        if ring.len() == SPAN_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(record);
    }

    /// The recent spans, oldest first.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().iter().cloned().collect()
    }

    /// Point-in-time copy of everything — the one read path `Stats`,
    /// `--metrics-json` and the benches share. Counters are read
    /// `Relaxed`; concurrent writers may land between reads, which is
    /// fine for telemetry (each counter is individually exact).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            scope: "engine",
            counters: Counter::ALL.iter().map(|&c| (c.name(), self.get(c))).collect(),
            histograms: Hist::ALL
                .iter()
                .map(|&h| self.hists[h as usize].snapshot().summary(h.name()))
                .collect(),
            spans: self.spans(),
            recording: self.recording(),
        }
    }
}

/// What a `Stats` response carries: every counter, every histogram
/// summary, and the recent spans.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Which registry this snapshot reads: `"engine"` (the global
    /// registry every request shares) or `"session"` (one client's
    /// isolated bookkeeping — see `crate::server::Session`).
    pub scope: &'static str,
    /// `(name, value)` in [`Counter::ALL`] order.
    pub counters: Vec<(&'static str, u64)>,
    pub histograms: Vec<HistogramSummary>,
    pub spans: Vec<SpanRecord>,
    pub recording: bool,
}

impl MetricsSnapshot {
    /// Value of a counter by wire name (`None` for unknown names).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// Human-readable rendering (the CLI `stats` output and the
    /// `Stats` response's `text` field).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        // The engine scope keeps its pre-session header verbatim; only
        // the per-session view announces itself.
        out.push_str(&format!(
            "session metrics ({}span recording {})\n\n",
            if self.scope == "engine" { "" } else { "session scope, " },
            if self.recording { "on" } else { "off" }
        ));
        let mut counters = TextTable::new(vec!["counter", "value"]);
        for (name, value) in &self.counters {
            counters.row(vec![name.to_string(), value.to_string()]);
        }
        out.push_str(&counters.render());
        out.push('\n');
        let mut hists = TextTable::new(vec!["histogram", "count", "p50", "p90", "p99"]);
        for h in &self.histograms {
            hists.row(vec![
                h.name.to_string(),
                h.count.to_string(),
                format!("{} us", h.p50),
                format!("{} us", h.p90),
                format!("{} us", h.p99),
            ]);
        }
        out.push_str(&hists.render());
        out.push('\n');
        out.push_str(&format!(
            "recent spans: {} (ring capacity {})\n",
            self.spans.len(),
            SPAN_RING_CAP
        ));
        out
    }

    /// The snapshot's JSON fields, brace-free so the wire codec can
    /// splice them into a response object. Span/wall times are reported
    /// in microseconds.
    pub fn to_json_fields(&self) -> String {
        let counters: Vec<String> =
            self.counters.iter().map(|(n, v)| format!("{}:{v}", json_str(n))).collect();
        let hists: Vec<String> = self
            .histograms
            .iter()
            .map(|h| {
                format!(
                    "{}:{{\"count\":{},\"p50\":{},\"p90\":{},\"p99\":{}}}",
                    json_str(h.name),
                    h.count,
                    h.p50,
                    h.p90,
                    h.p99
                )
            })
            .collect();
        let spans: Vec<String> = self
            .spans
            .iter()
            .map(|s| {
                let phases: Vec<String> = Phase::ALL
                    .iter()
                    .map(|&p| format!("{}:{}", json_str(p.name()), s.phase_nanos[p as usize] / 1_000))
                    .collect();
                format!(
                    "{{\"op\":{},\"wall_us\":{},\"phases_us\":{{{}}}}}",
                    json_str(s.op),
                    s.wall_nanos / 1_000,
                    phases.join(",")
                )
            })
            .collect();
        format!(
            "\"scope\":{},\"recording\":{},\"counters\":{{{}}},\"histograms\":{{{}}},\"spans\":[{}]",
            json_str(self.scope),
            self.recording,
            counters.join(","),
            hists.join(","),
            spans.join(",")
        )
    }

    /// The snapshot as a standalone JSON object (the `--metrics-json`
    /// dump format).
    pub fn to_json(&self) -> String {
        format!("{{{}}}", self.to_json_fields())
    }
}
